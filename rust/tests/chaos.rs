//! Fault-isolation integration tests (PR 8): panic containment,
//! bisection, supervision, and the chaos soak, exercised end to end.
//!
//! These pin the fault contract from the outside, the way an operator
//! would observe it:
//!   * a poison request in a multi-request batch fails **alone** — its
//!     batch-mates complete with outputs bitwise identical to a
//!     fault-free run, and the containment counters account for every
//!     bisection step exactly;
//!   * a panic storm kills the route's engine incarnation, the
//!     supervisor restarts it, repeated deaths trip the circuit breaker
//!     (typed `Rejected::Unhealthy` sheds), and the half-open probe
//!     recovers the route — the process never exits;
//!   * the `wingan chaos --quick` soak holds all three harness
//!     properties (conservation, bitwise isolation, bounded recovery)
//!     on the real native backend with ~1% injected batch panics;
//!   * property: under *any* seeded fault script, every submitted
//!     request gets exactly one fate — no lost requests, no hangs.

use std::path::PathBuf;
use std::sync::mpsc::RecvTimeoutError;
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};
use wingan::chaos::{self, ChaosOptions};
use wingan::coordinator::{
    Coordinator, ExecBackend, Rejected, SchedulerKind, ServeConfig, ServeError, SupervisorConfig,
};
use wingan::faultinject::{FaultPlane, FaultSite};
use wingan::prop;
use wingan::runtime::{ArtifactEntry, Manifest};
use wingan::util::prng::Rng;

/// Mock route geometry: small enough that expected outputs are obvious.
const IN: usize = 8;
const OUT: usize = 6;
/// Sentinel input value the mock backend panics on — far outside anything
/// `Rng::normal_vec_f32` can produce.
const POISON: f32 = 1.0e9;

/// What the mock backend computes per sample — pure function of that
/// sample's own input, so outputs are invariant to batch composition
/// (the same contract the real engine keeps, and what makes bisected
/// re-execution bitwise safe).
fn expected_output(sample: &[f32]) -> Vec<f32> {
    (0..OUT).map(|j| sample[j % IN] * 2.0 + j as f32).collect()
}

/// Deterministic backend that panics iff a poison sample is present in
/// the packed batch — the trust violation containment exists for.
struct MockBackend;

impl ExecBackend for MockBackend {
    fn execute_artifact(&self, _name: &str, input: &[f32]) -> Result<Vec<f32>, String> {
        assert_eq!(input.len() % IN, 0, "packed batch must be whole samples");
        if input.contains(&POISON) {
            panic!("poison sample in batch");
        }
        Ok(input.chunks(IN).flat_map(expected_output).collect())
    }
}

/// A one-route manifest (`mock/gen`) over the given batch buckets, enough
/// for the router/batcher/supervisor stack without compiling anything.
fn mock_manifest(buckets: &[usize]) -> Manifest {
    Manifest {
        dir: PathBuf::new(),
        scale: "mock".into(),
        entries: buckets
            .iter()
            .map(|&b| ArtifactEntry {
                name: format!("mock_gen_b{b}"),
                kind: "generator".into(),
                model: "mock".into(),
                method: "gen".into(),
                batch: b,
                hlo: PathBuf::new(),
                input_shape: vec![b, IN],
                output_shape: vec![b, OUT],
                golden_input: PathBuf::new(),
                golden_output: PathBuf::new(),
            })
            .collect(),
    }
}

/// One poison request in a full batch of four: bisection must fail
/// exactly the poison request (typed `Crashed`) while its three
/// batch-mates complete bitwise-exact, and the containment counters must
/// account for every step of the bisection tree.
#[test]
fn bisection_fails_only_the_poison_request() {
    let serve = ServeConfig {
        // the bucket scheduler holds until the largest bucket (4) fills,
        // so all four requests deterministically share one batch
        scheduler: SchedulerKind::Bucket,
        max_wait: Duration::from_secs(10),
        // containment alone must handle this: storms stay out of reach
        supervisor: SupervisorConfig { storm_panics: 100, ..Default::default() },
        ..Default::default()
    };
    let coord =
        Coordinator::start_supervised(Arc::new(MockBackend), &mock_manifest(&[1, 2, 4]), serve)
            .expect("mock coordinator starts");

    let inputs: Vec<Vec<f32>> = (0..4)
        .map(|i| {
            if i == 2 {
                let mut v = vec![0.5f32; IN];
                v[3] = POISON;
                v
            } else {
                Rng::new(100 + i as u64).normal_vec_f32(IN)
            }
        })
        .collect();
    let receivers: Vec<_> = inputs
        .iter()
        .map(|inp| coord.submit("mock", "gen", inp.clone()).expect("admitted"))
        .collect();

    for (i, rx) in receivers.into_iter().enumerate() {
        let fate = rx.recv_timeout(Duration::from_secs(10)).expect("every request gets a fate");
        if i == 2 {
            match fate {
                Err(ServeError::Crashed(msg)) => {
                    assert!(msg.contains("poison"), "crash carries the panic message: {msg}")
                }
                Ok(_) => panic!("the poison request completed"),
                Err(e) => panic!("poison request got the wrong fate: {e}"),
            }
        } else {
            match fate {
                Ok(resp) => assert_eq!(
                    resp.output,
                    expected_output(&inputs[i]),
                    "batch-mate {i} must be bitwise identical to a fault-free run"
                ),
                Err(e) => panic!("innocent batch-mate {i} failed: {e}"),
            }
        }
    }

    // the bisection tree: [0,1,2,3] crashes -> [0,1] ok, [2,3] crashes
    // -> [2] crashes (quarantined), [3] ok. Three contained panics, two
    // bisection splits, one quarantined request.
    let m = coord.metrics();
    assert_eq!(m.panics_contained, 3, "batch + poisoned half + poisoned single");
    assert_eq!(m.bisection_retries, 2, "two splits isolate one poison among four");
    assert_eq!(m.requests_quarantined, 1);
    assert_eq!(m.responses, 3);

    // containment never killed the engine: no storm, no restart
    let health = coord.health();
    assert!(health.all_healthy(), "containment must not cost the route:\n{}", health.report());
    assert_eq!(health.route("mock/gen").expect("route reported").restarts, 0);
    coord.shutdown();
}

/// Two injected batch panics with `storm_panics = 1` and
/// `max_restarts = 2`: the first death restarts the engine, the second
/// trips the breaker (typed `Unhealthy` sheds at submit), and after the
/// cooldown the half-open probe — its fault budget spent — serves again
/// and the route settles back to Healthy. The process survives it all.
#[test]
fn storm_trips_the_breaker_and_the_probe_recovers() {
    let plane = Arc::new(FaultPlane::parse("seed=5;batch_exec:panic*2@1").expect("spec parses"));
    let serve = ServeConfig {
        faults: Some(plane.clone()),
        supervisor: SupervisorConfig {
            watchdog: Duration::from_secs(10),
            backoff_base: Duration::from_millis(1),
            backoff_max: Duration::from_millis(5),
            max_restarts: 2,
            restart_window: Duration::from_secs(60),
            breaker_cooldown: Duration::from_millis(300),
            probation: Duration::from_millis(20),
            storm_panics: 1,
            storm_window: Duration::from_secs(60),
        },
        ..Default::default()
    };
    let coord =
        Coordinator::start_supervised(Arc::new(MockBackend), &mock_manifest(&[1, 2, 4]), serve)
            .expect("mock coordinator starts");
    let input = Rng::new(7).normal_vec_f32(IN);

    // each guaranteed panic is contained (single-request batch ->
    // quarantined, typed Crashed), storms its incarnation, and charges a
    // death; the second death inside the window trips the breaker
    for i in 0..2 {
        let rx = coord.submit("mock", "gen", input.clone()).expect("admitted");
        match rx.recv_timeout(Duration::from_secs(10)).expect("fate") {
            Err(ServeError::Crashed(msg)) => {
                assert!(msg.contains("fault injected"), "request {i}: {msg}")
            }
            Ok(_) => panic!("request {i} should have crashed"),
            Err(e) => panic!("request {i} got the wrong fate: {e}"),
        }
    }
    assert_eq!(plane.fired_at(FaultSite::BatchExec), 2, "the fault budget is spent");

    // the supervisor registers the second death asynchronously; wait for
    // the breaker to open
    let t0 = Instant::now();
    loop {
        let h = coord.health();
        if h.route("mock/gen").expect("route reported").breaker == "open" {
            break;
        }
        assert!(t0.elapsed() < Duration::from_secs(5), "breaker never opened:\n{}", h.report());
        thread::sleep(Duration::from_millis(2));
    }

    // an open breaker sheds typed at submit — nothing queues onto an
    // engine the supervisor refuses to restart
    match coord.submit("mock", "gen", input.clone()) {
        Err(ServeError::Rejected(Rejected::Unhealthy { .. })) => {}
        Ok(_) => panic!("open breaker admitted a request"),
        Err(e) => panic!("open breaker shed with the wrong type: {e}"),
    }

    // cooldown elapses, the half-open probe survives (no fires left),
    // and the route serves correct bytes again
    let t0 = Instant::now();
    let resp = loop {
        assert!(t0.elapsed() < Duration::from_secs(10), "route never recovered");
        match coord.submit("mock", "gen", input.clone()) {
            Ok(rx) => match rx.recv_timeout(Duration::from_secs(10)).expect("fate") {
                Ok(resp) => break resp,
                Err(e) => panic!("post-recovery request failed: {e}"),
            },
            Err(e) if e.is_shed() => thread::sleep(Duration::from_millis(5)),
            Err(e) => panic!("hard submit failure during recovery: {e}"),
        }
    };
    assert_eq!(resp.output, expected_output(&input), "recovered route serves exact bytes");

    // probation passes and the ledger reads like the story above
    let t0 = Instant::now();
    let health = loop {
        let h = coord.health();
        if h.all_healthy() {
            break h;
        }
        assert!(t0.elapsed() < Duration::from_secs(5), "never Healthy again:\n{}", h.report());
        thread::sleep(Duration::from_millis(5));
    };
    let r = health.route("mock/gen").expect("route reported");
    assert_eq!(r.breaker, "closed");
    assert_eq!(r.total_deaths, 2, "storm death + breaker-tripping death");
    assert!(r.restarts >= 2, "backoff restart + probe restart, got {}", r.restarts);
    coord.shutdown();
}

/// The ISSUE's acceptance scenario on the real native backend: a seeded
/// chaos run (guaranteed storm burst + ~1% background batch panics)
/// against the identical fault-free schedule. `chaos::run` itself
/// enforces conservation (zero lost requests, 30 s deadlock detector),
/// bitwise identity for everything that completed in both runs, at least
/// one engine restart, and a final all-Healthy verdict — reaching this
/// function's `Ok` *is* the acceptance checklist, and the process never
/// exited along the way.
#[test]
fn chaos_quick_soak_holds_conservation_bitwise_and_recovery() {
    let out = std::env::temp_dir().join(format!("wingan_chaos_test_{}.json", std::process::id()));
    let opts = ChaosOptions {
        requests: 160,
        rate: 400.0,
        out: out.clone(),
        ..ChaosOptions::quick()
    };
    chaos::run(&opts).expect("chaos soak holds all three properties");
    let report = std::fs::read_to_string(&out).expect("machine-readable report written");
    assert!(report.contains("engine_restarts"), "report carries the recovery ledger: {report}");
    assert!(report.contains("bitwise_compared"), "report carries the isolation ledger: {report}");
    let _ = std::fs::remove_file(&out);
}

/// Generate a random-but-valid fault script: 1–3 rules over random
/// sites, actions, optional fire caps, and rates.
fn gen_script(rng: &mut Rng) -> String {
    let mut parts = vec![format!("seed={}", rng.next_u64() % 1000)];
    for _ in 0..(1 + rng.below(3)) {
        let site = ["batch_exec", "worker_chunk", "artifact_load"][rng.below(3)];
        let action = ["panic", "error", "wrong_shape", "delay=3"][rng.below(4)];
        let mut rule = format!("{site}:{action}");
        if rng.below(2) == 0 {
            rule.push_str(&format!("*{}", 1 + rng.below(3)));
        }
        rule.push_str(&format!("@{}", [0.05, 0.25, 1.0][rng.below(3)]));
        parts.push(rule);
    }
    parts.join(";")
}

/// Property: whatever a seeded fault script throws at the serving stack
/// — panics, typed errors, wrong shapes, delays, at any rate, including
/// storms that trip the breaker — every submitted request gets exactly
/// one fate: a response, a typed shed, or a typed crash. Never zero
/// (lost/hung), never two.
#[test]
fn every_request_gets_exactly_one_fate_under_any_fault_script() {
    const REQS: usize = 10;
    prop::forall("one_fate_per_request", 10, 0xFA17, gen_script, |spec| {
        let plane = FaultPlane::parse(spec)
            .map_err(|e| format!("generated spec '{spec}' must parse: {e}"))?;
        let serve = ServeConfig {
            faults: Some(Arc::new(plane)),
            supervisor: SupervisorConfig {
                watchdog: Duration::from_secs(10),
                backoff_base: Duration::from_millis(1),
                backoff_max: Duration::from_millis(10),
                max_restarts: 5,
                restart_window: Duration::from_secs(2),
                breaker_cooldown: Duration::from_millis(50),
                probation: Duration::from_millis(20),
                storm_panics: 3,
                storm_window: Duration::from_secs(1),
            },
            ..Default::default()
        };
        let coord =
            Coordinator::start_supervised(Arc::new(MockBackend), &mock_manifest(&[1, 2, 4]), serve)
                .map_err(|e| format!("start: {e}"))?;

        let mut receivers = Vec::new();
        let mut fates = 0usize;
        for i in 0..REQS {
            match coord.submit("mock", "gen", Rng::new(i as u64).normal_vec_f32(IN)) {
                Ok(rx) => receivers.push(rx),
                // a typed shed at submit (open breaker) is a legal fate
                Err(e) if e.is_shed() => fates += 1,
                Err(e) => return Err(format!("hard submit failure under '{spec}': {e}")),
            }
            thread::sleep(Duration::from_millis(1));
        }
        for (i, rx) in receivers.into_iter().enumerate() {
            match rx.recv_timeout(Duration::from_secs(15)) {
                // any reply — response, typed shed, typed crash — is
                // exactly one fate; which one is the fault plane's call
                Ok(_) => fates += 1,
                Err(RecvTimeoutError::Timeout) => {
                    return Err(format!("request {i}: no fate within 15s under '{spec}'"))
                }
                Err(RecvTimeoutError::Disconnected) => {
                    return Err(format!("request {i}: reply channel dropped without a fate"))
                }
            }
        }
        coord.shutdown();
        if fates == REQS {
            Ok(())
        } else {
            Err(format!("{fates} fates for {REQS} requests under '{spec}'"))
        }
    });
}
