//! Integration tests for the plan-artifact subsystem's serving workflow:
//! corrupt-artifact handling end to end (every failure typed, every
//! fallback clean) and the AOT compile → warm-serve path through the
//! coordinator, including the plan-cache metrics counters.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;
use wingan::artifact::{AnyPlan, ArtifactError, PlanKey, PlanStore};
use wingan::coordinator::{Coordinator, ServeConfig};
use wingan::engine::{Engine, NativeConfig, NativeRuntime, Planner, Precision};
use wingan::gan::zoo::{self, Scale};
use wingan::util::prng::Rng;

fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("wingan_artifact_it_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn published_store(tag: &str) -> (PlanStore, PlanKey, Vec<u8>) {
    let store = PlanStore::open(temp_dir(tag));
    let plan = Planner::default().compile_seeded(&zoo::dcgan(Scale::Tiny), 7);
    let key = PlanKey::new("dcgan", Scale::Tiny, Precision::F64, "winograd", 7);
    let path = store.publish(&key, &plan).unwrap();
    let bytes = std::fs::read(path).unwrap();
    (store, key, bytes)
}

/// The corrupt-artifact matrix: truncation, bad magic, wrong format
/// version, checksum damage, and a precision-tag/requested-tier mismatch
/// must each surface as the matching typed error — no panics anywhere.
#[test]
fn corrupt_artifacts_return_typed_errors() {
    let (store, key, good) = published_store("matrix");
    let path = store.path(&key);
    let reload = |bytes: &[u8]| {
        std::fs::write(&path, bytes).unwrap();
        store.load_uncached(&key)
    };

    // truncated file (several cut points, including mid-header)
    for cut in [0usize, 5, 11, 40, good.len() / 3, good.len() - 1] {
        match reload(&good[..cut]) {
            Err(ArtifactError::Truncated { .. }) | Err(ArtifactError::BadMagic { .. }) => {}
            other => panic!("cut {cut}: expected truncation-class error, got {other:?}"),
        }
    }

    // bad magic
    let mut bytes = good.clone();
    bytes[..8].copy_from_slice(b"NOTAPLAN");
    assert!(matches!(reload(&bytes), Err(ArtifactError::BadMagic { .. })));

    // wrong format version (the version u32 follows the 8-byte magic)
    let mut bytes = good.clone();
    bytes[8..12].copy_from_slice(&2u32.to_le_bytes());
    assert!(matches!(
        reload(&bytes),
        Err(ArtifactError::UnsupportedVersion { found: 2 })
    ));

    // checksum mismatch: flip a payload byte deep in the stream
    let mut bytes = good.clone();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x10;
    assert!(matches!(reload(&bytes), Err(ArtifactError::ChecksumMismatch { .. })));

    // precision-tag vs requested-tier mismatch: the intact f64 artifact
    // requested under the f32 key
    std::fs::write(&path, &good).unwrap();
    let f32_key = PlanKey { precision: Precision::F32, ..key.clone() };
    std::fs::copy(&path, store.path(&f32_key)).unwrap();
    assert!(matches!(
        store.load_uncached(&f32_key),
        Err(ArtifactError::PrecisionMismatch {
            artifact: Precision::F64,
            requested: Precision::F32,
        })
    ));

    // and the pristine file still loads
    assert!(store.load_uncached(&key).is_ok());
    let _ = std::fs::remove_dir_all(store.root());
}

/// `NativeRuntime::build` survives a store where every artifact is broken
/// in a different way: each failure is counted, each route recompiles, and
/// execution matches a store-free runtime bit for bit.
#[test]
fn native_runtime_falls_back_cleanly_from_a_poisoned_store() {
    let dir = temp_dir("poisoned");
    let cfg = NativeConfig {
        scale: Scale::Tiny,
        buckets: vec![1, 2],
        workers: 2,
        models: Some(vec!["dcgan".into()]),
        plan_store: Some(dir.clone()),
        ..Default::default()
    };
    // seed the store, then poison both route artifacts differently
    let seeded = NativeRuntime::build(&cfg);
    assert_eq!(seeded.plan_stats().published, 2);
    let scale_dir = dir.join("tiny");
    let mut files: Vec<PathBuf> =
        std::fs::read_dir(&scale_dir).unwrap().map(|e| e.unwrap().path()).collect();
    files.sort();
    assert_eq!(files.len(), 2);
    std::fs::write(&files[0], b"garbage, not even magic").unwrap();
    let good = std::fs::read(&files[1]).unwrap();
    std::fs::write(&files[1], &good[..good.len() - 9]).unwrap();

    let rebuilt = NativeRuntime::build(&cfg);
    let stats = rebuilt.plan_stats();
    assert_eq!(stats.load_failures, 2);
    assert_eq!(stats.fallback_compiles, 2);
    assert_eq!(stats.artifact_hits, 0);
    assert_eq!(stats.published, 2, "fallback republishes");

    let clean = NativeRuntime::build(&NativeConfig { plan_store: None, ..cfg.clone() });
    let mut rng = Rng::new(99);
    for name in ["dcgan_winograd_b2", "dcgan_tdc_b1"] {
        let engine = clean.engine("dcgan", name.split('_').nth(1).unwrap()).unwrap();
        let batch = if name.ends_with("b2") { 2 } else { 1 };
        let x = rng.normal_vec_f32(batch * engine.input_len());
        assert_eq!(
            rebuilt.execute(name, &x).unwrap(),
            clean.execute(name, &x).unwrap(),
            "{name}: fallback path must serve the same bits as a store-free build"
        );
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// The AOT compile → warm-serve workflow through the coordinator: a store
/// populated ahead of time boots the native server without invoking the
/// planner (observable via the plan-cache metrics counters), and serves
/// responses bitwise-identical to a compile-in-process coordinator.
#[test]
fn coordinator_boots_warm_from_a_populated_store_and_matches_in_process() {
    let dir = temp_dir("warmserve");
    // "wingan compile" equivalent: publish both route plans ahead of time
    // (the fast route at both tiers, so any resolved precision boots warm)
    let store = PlanStore::open(dir.clone());
    for (method, select) in wingan::engine::ROUTE_METHODS {
        let planner = Planner::new(wingan::engine::PlanOptions {
            select,
            ..Default::default()
        });
        let plan = planner.compile_seeded(&zoo::dcgan(Scale::Tiny), 42);
        let k64 = PlanKey::new("dcgan", Scale::Tiny, Precision::F64, method, 42);
        store.publish(&k64, &plan).unwrap();
        if method == "winograd" {
            let k32 = PlanKey::new("dcgan", Scale::Tiny, Precision::F32, method, 42);
            store.publish(&k32, &plan.lower::<f32>()).unwrap();
        }
    }

    let serve_cfg = ServeConfig {
        max_wait: Duration::from_millis(5),
        preload_models: Some(vec!["dcgan".into()]),
        ..Default::default()
    };
    let native = NativeConfig {
        scale: Scale::Tiny,
        buckets: vec![1, 2],
        workers: 2,
        plan_store: Some(dir.clone()),
        ..Default::default()
    };
    let warm = Coordinator::start_native(native.clone(), serve_cfg.clone()).unwrap();
    let m = warm.metrics();
    assert_eq!(m.plan_cache.artifact_hits, 2, "both routes must come off disk");
    assert_eq!(m.plan_cache.fallback_compiles, 0, "a warm store never invokes the planner");
    assert_eq!(m.plan_cache.load_failures, 0);
    assert!(m.used_plan_store());

    let cold =
        Coordinator::start_native(NativeConfig { plan_store: None, ..native }, serve_cfg).unwrap();
    assert!(!cold.metrics().used_plan_store());

    let route = warm.router().route("dcgan", "winograd").unwrap();
    let mut rng = Rng::new(4242);
    for _ in 0..3 {
        let input = rng.normal_vec_f32(route.sample_input_len);
        let a = warm.generate("dcgan", "winograd", input.clone()).unwrap();
        let b = cold.generate("dcgan", "winograd", input).unwrap();
        assert_eq!(a.output, b.output, "warm boot must serve the exact compiled bits");
    }
    warm.shutdown();
    cold.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

/// The store cache hands every caller the same `Arc` — one deserialized
/// plan shared by all consumers — and a shared-store `Engine` built from
/// it executes the exact plan bits.
#[test]
fn loaded_plans_are_shared_and_executable() {
    let (store, key, _) = published_store("shared");
    let a = store.load(&key).unwrap();
    let b = store.load(&key).unwrap();
    let (pa, pb) = match (&a, &b) {
        (AnyPlan::F64(x), AnyPlan::F64(y)) => (x.clone(), y.clone()),
        _ => panic!("expected the f64 tier"),
    };
    assert!(Arc::ptr_eq(&pa, &pb));
    let engine = Engine::with_workers(pa, 2);
    let mut rng = Rng::new(5);
    let (c, h, w) = engine.plan().input_shape;
    let x = wingan::util::tensor::Tensor3::from_vec(c, h, w, rng.normal_vec(c * h * w));
    let run = engine.run(&x);
    assert_eq!((run.y.c, run.y.h, run.y.w), engine.plan().output_shape);
    assert!(run.events.mults > 0);
    let _ = std::fs::remove_dir_all(store.root());
}
