//! Golden-vector tests for the Winograd transform kernels and the
//! structural-sparsity phase-case table — hard-coded expected values
//! (computed independently with exact rational arithmetic / numpy), so the
//! sparse-skip bookkeeping and both transform families are pinned without
//! reference to the engine or the functional simulator.
//!
//! Covers every (K_D, S, P) kernel class of the paper's Table I:
//! (5, 2, 2), (4, 2, 1), (3, 1, 1).

use wingan::tdc::{self, default_padding};
use wingan::util::prng::Rng;
use wingan::util::tensor::{Filter4, Tensor3};
use wingan::winograd::f43::{
    filter_transform6, input_transform6, inverse_transform6, live_positions6, Tile6,
};
use wingan::winograd::sparsity::{c_of_kc, classify, nonzero_positions, phase_cases, Case};
use wingan::winograd::transforms::{
    filter_transform, input_transform, inverse_transform, Tile4,
};

/// Table I kernel classes (K_D, S, P).
const TABLE1_CLASSES: [(usize, usize, usize); 3] = [(5, 2, 2), (4, 2, 1), (3, 1, 1)];

const F9: [[f64; 3]; 3] = [[1.0, 2.0, 3.0], [4.0, 5.0, 6.0], [7.0, 8.0, 9.0]];

// ---------------------------------------------------------------------------
// F(2x2, 3x3): all transform constants are dyadic rationals, so the golden
// values are exact in f64 and the asserts are exact equality.
// ---------------------------------------------------------------------------

#[test]
fn f23_filter_transform_golden() {
    // U = G f G^T for f = [[1..9]] (numpy golden, exact dyadics)
    let want: Tile4 = [
        [1.0, 3.0, 1.0, 3.0],
        [6.0, 11.25, 3.75, 9.0],
        [2.0, 3.75, 1.25, 3.0],
        [7.0, 12.0, 4.0, 9.0],
    ];
    let got = filter_transform(&F9);
    assert_eq!(got, want);
}

#[test]
fn f23_input_transform_golden() {
    // V = B^T z B for z = [[1..16]] (numpy golden, exact integers)
    let z: Tile4 = [
        [1.0, 2.0, 3.0, 4.0],
        [5.0, 6.0, 7.0, 8.0],
        [9.0, 10.0, 11.0, 12.0],
        [13.0, 14.0, 15.0, 16.0],
    ];
    let want: Tile4 = [
        [0.0, -16.0, 0.0, 0.0],
        [-4.0, 34.0, 2.0, -4.0],
        [0.0, 8.0, 0.0, 0.0],
        [0.0, -16.0, 0.0, 0.0],
    ];
    assert_eq!(input_transform(&z), want);
}

#[test]
fn f23_full_pipeline_golden() {
    // A^T [(G f G^T) ⊙ (B^T z B)] A == the direct 2x2 valid correlation
    // of z with f: [[348, 393], [528, 573]] — exactly.
    let z: Tile4 = [
        [1.0, 2.0, 3.0, 4.0],
        [5.0, 6.0, 7.0, 8.0],
        [9.0, 10.0, 11.0, 12.0],
        [13.0, 14.0, 15.0, 16.0],
    ];
    let u = filter_transform(&F9);
    let v = input_transform(&z);
    let mut m: Tile4 = [[0.0; 4]; 4];
    for i in 0..4 {
        for j in 0..4 {
            m[i][j] = u[i][j] * v[i][j];
        }
    }
    let y = inverse_transform(&m);
    assert_eq!(y, [[348.0, 393.0], [528.0, 573.0]]);
}

// ---------------------------------------------------------------------------
// F(4x4, 3x3): G6 has 1/6-family constants (not exactly representable), so
// goldens are exact rationals asserted to 1e-12.
// ---------------------------------------------------------------------------

#[test]
fn f43_filter_transform_golden() {
    // U = G6 f G6^T for f = [[1..9]], exact rationals via fractions.Fraction
    let want: [[f64; 6]; 6] = [
        [1.0 / 16.0, -1.0 / 4.0, -1.0 / 12.0, 17.0 / 96.0, 3.0 / 32.0, 3.0 / 4.0],
        [-1.0 / 2.0, 5.0 / 4.0, 5.0 / 12.0, -19.0 / 24.0, -3.0 / 8.0, -3.0],
        [-1.0 / 6.0, 5.0 / 12.0, 5.0 / 36.0, -19.0 / 72.0, -1.0 / 8.0, -1.0],
        [37.0 / 96.0, -11.0 / 12.0, -11.0 / 36.0, 329.0 / 576.0, 17.0 / 64.0, 17.0 / 8.0],
        [7.0 / 32.0, -1.0 / 2.0, -1.0 / 6.0, 59.0 / 192.0, 9.0 / 64.0, 9.0 / 8.0],
        [7.0 / 4.0, -4.0, -4.0 / 3.0, 59.0 / 24.0, 9.0 / 8.0, 9.0],
    ];
    let got = filter_transform6(&F9);
    for i in 0..6 {
        for j in 0..6 {
            assert!(
                (got[i][j] - want[i][j]).abs() < 1e-12,
                "U6[{i}][{j}] = {} want {}",
                got[i][j],
                want[i][j]
            );
        }
    }
}

#[test]
fn f43_input_transform_golden() {
    // V = B^T z B for z = 0..35 row-major (numpy golden, exact integers —
    // B^T is all-integer so equality is exact)
    let mut z: Tile6 = [[0.0; 6]; 6];
    for (i, row) in z.iter_mut().enumerate() {
        for (j, v) in row.iter_mut().enumerate() {
            *v = (i * 6 + j) as f64;
        }
    }
    let want: Tile6 = [
        [0.0, 216.0, 0.0, 0.0, 0.0, 0.0],
        [36.0, 210.0, 18.0, -36.0, 12.0, 36.0],
        [0.0, 108.0, 0.0, 0.0, 0.0, 0.0],
        [0.0, -216.0, 0.0, 0.0, 0.0, 0.0],
        [0.0, 72.0, 0.0, 0.0, 0.0, 0.0],
        [0.0, 216.0, 0.0, 0.0, 0.0, 0.0],
    ];
    assert_eq!(input_transform6(&z), want);
}

#[test]
fn f43_full_pipeline_golden() {
    // whole F(4,3) tile vs the direct 4x4 valid correlation of z=0..35
    // with f=1..9: rows [429..564], [699..834], [969..1104], [1239..1374]
    let mut z: Tile6 = [[0.0; 6]; 6];
    for (i, row) in z.iter_mut().enumerate() {
        for (j, v) in row.iter_mut().enumerate() {
            *v = (i * 6 + j) as f64;
        }
    }
    let u = filter_transform6(&F9);
    let v = input_transform6(&z);
    let mut m: Tile6 = [[0.0; 6]; 6];
    for i in 0..6 {
        for j in 0..6 {
            m[i][j] = u[i][j] * v[i][j];
        }
    }
    let y = inverse_transform6(&m);
    let want = [
        [429.0, 474.0, 519.0, 564.0],
        [699.0, 744.0, 789.0, 834.0],
        [969.0, 1014.0, 1059.0, 1104.0],
        [1239.0, 1284.0, 1329.0, 1374.0],
    ];
    for i in 0..4 {
        for j in 0..4 {
            assert!(
                (y[i][j] - want[i][j]).abs() < 1e-9,
                "Y[{i}][{j}] = {} want {}",
                y[i][j],
                want[i][j]
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Sparsity phase-case table (paper Fig. 3/6), every Table I kernel class.
// ---------------------------------------------------------------------------

#[test]
fn phase_case_table_golden_all_table1_classes() {
    // (5,2,2): phases (py,px) row-major -> Dense, OneLine, OneLine, TwoLines
    assert_eq!(
        phase_cases(5, 2, 2),
        vec![Case::Dense, Case::OneLine, Case::OneLine, Case::TwoLines]
    );
    // (4,2,1): every phase is Case 3 (TwoLines)
    assert_eq!(phase_cases(4, 2, 1), vec![Case::TwoLines; 4]);
    // (3,1,1): single dense phase
    assert_eq!(phase_cases(3, 1, 1), vec![Case::Dense]);
}

#[test]
fn phase_case_table_matches_structural_derivation() {
    // the precomputed table must agree with the from-scratch tap analysis
    for &(k, s, p) in &TABLE1_CLASSES {
        let table = phase_cases(k, s, p);
        let mut derived = Vec::new();
        for py in 0..s {
            let ty = tdc::phase_taps_1d(k, s, p, py);
            for px in 0..s {
                let tx = tdc::phase_taps_1d(k, s, p, px);
                derived.push(classify(
                    ty.real_taps().clamp(1, 3),
                    tx.real_taps().clamp(1, 3),
                ));
            }
        }
        assert_eq!(table, derived, "K={k} S={s} P={p}");
        assert_eq!(p, default_padding(k, s), "Table I paddings");
    }
}

#[test]
fn live_position_counts_golden() {
    // paper eq. 5: C(K_C) = 49 / 36 / 16
    assert_eq!(c_of_kc(5, 2, 2), 49);
    assert_eq!(c_of_kc(4, 2, 1), 36);
    assert_eq!(c_of_kc(3, 1, 1), 16);
    // per-case live positions and zero-row counts
    assert_eq!(Case::Dense.live_positions(), 16);
    assert_eq!(Case::OneLine.live_positions(), 12);
    assert_eq!(Case::TwoLines.live_positions(), 9);
    assert_eq!(Case::OneLine.zero_rows(), 4); // n
    assert_eq!(Case::TwoLines.zero_rows(), 7); // 2n - 1
    // F(4,3) ablation counterparts
    assert_eq!(live_positions6(3, 3), 36);
    assert_eq!(live_positions6(3, 2), 30);
    assert_eq!(live_positions6(2, 2), 25);
}

#[test]
fn nonzero_position_masks_golden() {
    // row-major live indices in the 4x4 tile
    assert_eq!(nonzero_positions(3, 3), (0..16).collect::<Vec<_>>());
    assert_eq!(
        nonzero_positions(3, 2),
        vec![0, 1, 2, 4, 5, 6, 8, 9, 10, 12, 13, 14]
    );
    assert_eq!(nonzero_positions(2, 3), (0..12).collect::<Vec<_>>());
    assert_eq!(nonzero_positions(2, 2), vec![0, 1, 2, 4, 5, 6, 8, 9, 10]);
}

#[test]
fn transformed_subfilter_zeros_exactly_match_table_every_class() {
    // decompose a random filter bank for each Table I class, transform every
    // phase sub-filter, and check the *actual* zero pattern equals the
    // table's predicted mask — the invariant the com-PE skip logic relies on
    let mut rng = Rng::new(0x601D);
    for &(k, s, p) in &TABLE1_CLASSES {
        let w = Filter4::from_vec(2, 2, k, k, rng.normal_vec(2 * 2 * k * k));
        let phases = tdc::decompose(&w, s, p);
        let cases = phase_cases(k, s, p);
        assert_eq!(phases.len(), cases.len(), "K={k}");
        for (ph, case) in phases.iter().zip(&cases) {
            let live = nonzero_positions(ph.ry.clamp(1, 3), ph.rx.clamp(1, 3));
            assert_eq!(live.len(), case.live_positions(), "K={k}");
            let bank = wingan::winograd::transforms::filter_bank_transform(&ph.g);
            for tile in &bank {
                for pos in 0..16 {
                    let (i, j) = (pos / 4, pos % 4);
                    if live.contains(&pos) {
                        assert!(
                            tile[i][j].abs() > 1e-12,
                            "K={k}: predicted-live position {pos} is zero"
                        );
                    } else {
                        assert_eq!(
                            tile[i][j], 0.0,
                            "K={k}: predicted-zero position {pos} is non-zero"
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn winograd_deconv_golden_small_integer_case() {
    // a fully hand-checkable deconv: 1x1 input [[2]], K=3 S=1 P=1 filter
    // 1..9 — standard deconv output is the flipped-kernel center region
    let x = Tensor3::from_vec(1, 1, 1, vec![2.0]);
    let w = Filter4::from_vec(1, 1, 3, 3, (1..=9).map(f64::from).collect());
    let y = tdc::deconv_naive(&x, &w, 1, 1);
    assert_eq!((y.c, y.h, y.w), (1, 1, 1));
    // oy=0, ox=0, P=1: ky=kx=1 -> w[1][1] = 5; y = 2 * 5
    assert_eq!(y.at(0, 0, 0), 10.0);
    let via_tdc = tdc::tdc_deconv(&x, &w, 1, 1);
    assert_eq!(via_tdc.at(0, 0, 0), 10.0);
}

#[test]
fn engine_f64_golden_small_integer_case() {
    // the pre-refactor f64 pin, as a hard-coded value rather than a
    // cross-check: the precision-tiered engine on the same hand-checkable
    // deconv must still produce exactly 10.0 (all constants dyadic, every
    // datapath exact), through both a Linear and a Relu plan — and the
    // f32 tier, whose operands are exact small integers, matches bitwise
    use std::sync::Arc;
    use wingan::engine::{Engine, ModelPlan, PlanOptions, Planner, Select};
    use wingan::gan::workload::Method;
    use wingan::gan::zoo::{Activation, Kind, Layer};

    let w = Filter4::from_vec(1, 1, 3, 3, (1..=9).map(f64::from).collect());
    let planner = Planner::new(PlanOptions {
        select: Select::Force(Method::Tdc),
        ..Default::default()
    });
    for (act, want) in [(Activation::Linear, 10.0), (Activation::Relu, 10.0)] {
        let l = Layer {
            kind: Kind::Deconv,
            c_in: 1,
            c_out: 1,
            k: 3,
            s: 1,
            p: 1,
            h_in: 1,
            w_in: 1,
            act,
        };
        let plan = Arc::new(ModelPlan {
            model: "golden".into(),
            input_shape: (1, 1, 1),
            output_shape: (1, 1, 1),
            layers: vec![planner.compile_layer(&l, w.clone())],
        });
        let x = Tensor3::from_vec(1, 1, 1, vec![2.0]);
        let run = Engine::with_workers(plan.clone(), 2).run(&x);
        assert_eq!(run.y.at(0, 0, 0), want, "{act:?}");
        // f32 tier: exact integers at both precisions -> bitwise 10.0
        let run32 = Engine::with_workers(Arc::new(plan.lower::<f32>()), 2)
            .run(&Tensor3::<f32>::from_vec(1, 1, 1, vec![2.0]));
        assert_eq!(run32.y.at(0, 0, 0), want as f32, "{act:?} f32");
    }
    // a negative input flips the sign and Relu clamps it to exactly 0
    let l = Layer {
        kind: Kind::Deconv,
        c_in: 1,
        c_out: 1,
        k: 3,
        s: 1,
        p: 1,
        h_in: 1,
        w_in: 1,
        act: Activation::Relu,
    };
    let plan = ModelPlan {
        model: "golden-neg".into(),
        input_shape: (1, 1, 1),
        output_shape: (1, 1, 1),
        layers: vec![planner.compile_layer(&l, w)],
    };
    let run = Engine::with_workers(plan, 1).run(&Tensor3::from_vec(1, 1, 1, vec![-2.0]));
    assert_eq!(run.y.at(0, 0, 0), 0.0);
}
