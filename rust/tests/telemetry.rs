//! Telemetry-plane integration tests (PR 10): end-to-end request tracing
//! and the scrapeable metrics plane, exercised over real TCP against
//! in-process [`ReplicaServer`]s and a [`FleetRouter`].
//!
//! Three contracts are pinned here:
//!
//! * **one trace per request, attempt-level failover detail** — a routed
//!   request whose first attempt dies on the wire (deterministic
//!   `conn_drop` fault) leaves exactly one retrievable trace covering
//!   admission → queue → batch → per-layer engine stages → wire, with an
//!   `attempt` span per try carrying the replica address and verdict;
//! * **golden scrape formats** — the `MetricsQuery` wire verb serves
//!   stable-key JSON (byte-stable under parse → re-serialize, BTreeMap
//!   key order) and well-formed Prometheus text exposition with the
//!   `wingan_stages_*` stage-latency keys;
//! * **tracing is bitwise invisible** — engine outputs and every
//!   [`Events`] counter are identical with sampling off or on, at every
//!   worker count (property test over random Winograd-able layers).
//!
//! The flight recorder is process-global, so every test in this binary
//! serializes on one mutex and restores sampling-off before exiting.

use std::collections::BTreeSet;
use std::net::{SocketAddr, TcpStream};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Duration;
use wingan::coordinator::ServeConfig;
use wingan::engine::{Engine, ModelPlan, NativeConfig, PlanOptions, Planner, Select};
use wingan::faultinject::FaultPlane;
use wingan::fleet::wire::{self, WireMsg};
use wingan::fleet::{FleetConfig, FleetRouter, ReplicaConfig, ReplicaServer};
use wingan::gan::workload::Method;
use wingan::gan::zoo::{Activation, Kind, Layer, Scale};
use wingan::prop::forall;
use wingan::tdc;
use wingan::telemetry::{self, export};
use wingan::util::json::{self, Json};
use wingan::util::prng::Rng;
use wingan::util::tensor::{Filter4, Tensor3};

/// The flight recorder is one per process; tests that configure it must
/// not interleave. Poison is survivable — a failed test must not cascade.
static RECORDER_GUARD: Mutex<()> = Mutex::new(());

fn recorder_lock() -> MutexGuard<'static, ()> {
    RECORDER_GUARD.lock().unwrap_or_else(|p| p.into_inner())
}

/// A tiny-scale single-model replica config: fast to boot, real engine.
fn tiny_cfg(faults: Option<&str>) -> ReplicaConfig {
    ReplicaConfig {
        native: NativeConfig {
            scale: Scale::Tiny,
            workers: 2,
            models: Some(vec!["dcgan".into()]),
            ..Default::default()
        },
        serve: ServeConfig {
            drain_deadline: Duration::from_secs(2),
            ..Default::default()
        },
        fleet_faults: faults.map(|spec| Arc::new(FaultPlane::parse(spec).expect("fault spec"))),
    }
}

/// One connect-send-recv round trip with bounded timeouts.
fn rpc(addr: SocketAddr, msg: &WireMsg) -> WireMsg {
    let mut s =
        TcpStream::connect_timeout(&addr, Duration::from_secs(2)).expect("connect to replica");
    let _ = s.set_read_timeout(Some(Duration::from_secs(30)));
    let _ = s.set_write_timeout(Some(Duration::from_secs(5)));
    wire::send(&mut s, msg).expect("send");
    wire::recv(&mut s).expect("recv")
}

/// Ask the replica's health document for the first route's input length
/// — the tests stay agnostic to zoo geometry.
fn first_route_input_len(addr: SocketAddr) -> usize {
    let WireMsg::HealthReply { json: text } = rpc(addr, &WireMsg::HealthQuery) else {
        panic!("health query answered with a non-health frame")
    };
    let doc = json::parse(&text).expect("health JSON parses");
    let routes = doc.get("routes").and_then(Json::as_arr).expect("routes array");
    routes[0].get("input_len").and_then(Json::as_usize).expect("input_len")
}

/// Deduplicate a merged trace dump on `(node, seq)` — the router's
/// cross-process merge fans the `TraceQuery` verb out to every replica,
/// and in these single-process tests the replica shares the router's
/// recorder, so every span arrives twice (once local, once scraped).
fn dedup_spans(spans: &[Json]) -> Vec<Json> {
    let mut seen: BTreeSet<(String, i64)> = BTreeSet::new();
    spans
        .iter()
        .filter(|sp| {
            let node = sp.get("node").and_then(Json::as_str).unwrap_or("").to_string();
            let seq = sp.get("seq").and_then(Json::as_f64).unwrap_or(-1.0) as i64;
            seen.insert((node, seq))
        })
        .cloned()
        .collect()
}

fn stage_of(sp: &Json) -> String {
    sp.get("stage").and_then(Json::as_str).unwrap_or("").to_string()
}

/// The acceptance bar of the tracing tentpole: one routed request whose
/// first attempt is dropped on the wire (deterministic `conn_drop` fault,
/// exactly one fire) produces **one** retrievable trace that covers the
/// whole datapath and names every attempt with its replica and verdict
/// (100 = transport failure, 0 = served).
#[test]
fn a_retried_request_leaves_one_trace_with_every_attempt_replica_and_verdict() {
    let _guard = recorder_lock();
    let rec = telemetry::recorder();
    rec.configure(1, 0, "itest-fleet");
    rec.reset();

    // drop the first *request* connection without a reply; health probes
    // never consult the fault plane, so readiness is undisturbed
    let server = ReplicaServer::spawn("127.0.0.1:0", tiny_cfg(Some("seed=1;conn_drop:error*1@1")))
        .expect("replica");
    assert!(server.wait_ready(Duration::from_secs(120)), "replica boots");
    let addr = server.addr();
    let input_len = first_route_input_len(addr);

    let router = FleetRouter::new(FleetConfig {
        replicas: vec![addr.to_string()],
        ..Default::default()
    })
    .expect("router");
    assert!(router.wait_all_ready(Duration::from_secs(30)), "fleet admits the replica");

    let trace: u64 = 0x00AB_0000_0001;
    let resp = router
        .submit_traced("dcgan", "winograd", vec![0.25; input_len], None, trace)
        .expect("the retry serves the request");
    assert!(!resp.output.is_empty(), "a served request has output");

    let doc = router.trace_json(trace);
    assert_eq!(doc.get("trace").and_then(Json::as_f64), Some(trace as f64));
    let merged = doc.get("spans").and_then(Json::as_arr).expect("spans array");
    let spans = dedup_spans(merged);
    assert!(!spans.is_empty(), "a traced request must leave spans");
    for sp in &spans {
        assert_eq!(
            sp.get("trace").and_then(Json::as_f64),
            Some(trace as f64),
            "a trace dump filtered by id holds that trace only: {sp:?}"
        );
    }

    // attempt-level failover detail, in wall-clock order: the dropped
    // first attempt, then the served retry — both naming the replica
    let attempts: Vec<(u64, u64, String)> = spans
        .iter()
        .filter(|sp| stage_of(sp) == "attempt")
        .map(|sp| {
            (
                sp.get("a").and_then(Json::as_f64).expect("attempt ordinal") as u64,
                sp.get("b").and_then(Json::as_f64).expect("attempt verdict") as u64,
                sp.get("label").and_then(Json::as_str).expect("replica label").to_string(),
            )
        })
        .collect();
    assert_eq!(attempts.len(), 2, "exactly two attempts must be recorded: {attempts:?}");
    assert_eq!(
        attempts[0],
        (1, 100, addr.to_string()),
        "first attempt: transport failure at the replica"
    );
    assert_eq!(attempts[1], (2, 0, addr.to_string()), "second attempt: served");

    // the one trace covers the whole datapath, across the wire
    let stages: BTreeSet<String> = spans.iter().map(stage_of).collect();
    for want in ["admission", "queue", "batch_assemble", "dispatch", "wire", "attempt"] {
        assert!(stages.contains(want), "stage '{want}' missing from the trace: {stages:?}");
    }
    assert!(
        stages.contains("winograd_gemm") || stages.contains("layer_exec"),
        "per-layer engine stages must attach to the trace: {stages:?}"
    );

    // the router's own scrape carries the fleet rollup and the attempt
    // stage histogram the trace fed
    let m = router.metrics_json();
    assert_eq!(m.get("role").and_then(Json::as_str), Some("router"));
    assert!(m.get("fleet").is_some(), "router metrics nest the fleet status");
    let stages_obj = m.get("stages").and_then(Json::as_obj).expect("stage histograms");
    assert!(stages_obj.contains_key("attempt"), "attempt histogram present: {stages_obj:?}");

    drop(router);
    server.shutdown();
    rec.configure(0, 0, "itest-fleet");
    rec.reset();
}

/// Golden scrape formats over the wire verb: stable-key JSON that
/// byte-round-trips through the parser, and well-formed Prometheus text
/// with the stage-latency keys the CI smoke asserts on.
#[test]
fn metrics_scrape_serves_stable_key_json_and_well_formed_prometheus() {
    let _guard = recorder_lock();
    let rec = telemetry::recorder();
    rec.configure(1, 0, "itest-scrape");
    rec.reset();

    let server = ReplicaServer::spawn("127.0.0.1:0", tiny_cfg(None)).expect("replica");
    assert!(server.wait_ready(Duration::from_secs(120)), "replica boots");
    let addr = server.addr();
    let input_len = first_route_input_len(addr);

    // serve one traced request so the stage histograms are non-empty
    match rpc(
        addr,
        &WireMsg::Request {
            id: 1,
            model: "dcgan".into(),
            method: "winograd".into(),
            deadline_us: 0,
            input: vec![0.5; input_len],
            trace: 0x00AB_0000_0002,
        },
    ) {
        WireMsg::Response { .. } => {}
        other => panic!("traced request failed: {other:?}"),
    }

    // JSON view: golden top-level shape, byte-stable serialization
    let WireMsg::MetricsReply { body } = rpc(addr, &WireMsg::MetricsQuery { format: wire::format::JSON })
    else {
        panic!("metrics query answered with a non-metrics frame")
    };
    let doc = json::parse(&body).expect("metrics JSON parses");
    for key in ["role", "node", "ready", "generation", "in_flight", "metrics", "stages"] {
        assert!(doc.get(key).is_some(), "metrics doc missing '{key}':\n{body}");
    }
    assert_eq!(doc.get("role").and_then(Json::as_str), Some("replica"));
    assert_eq!(doc.get("node").and_then(Json::as_str), Some("itest-scrape"));
    assert_eq!(doc.get("ready"), Some(&Json::Bool(true)));
    assert_eq!(
        json::to_string_pretty(&doc),
        body,
        "BTreeMap key order + shortest-roundtrip floats make the scrape byte-stable"
    );
    let stages = doc.get("stages").and_then(Json::as_obj).expect("stage histograms");
    assert!(
        stages.contains_key("winograd_gemm") || stages.contains_key("layer_exec"),
        "a traced request must feed the stage histograms: {stages:?}"
    );

    // Prometheus view: well-formed exposition carrying the stage-latency
    // keys; string leaves are projected out
    let WireMsg::MetricsReply { body: prom } =
        rpc(addr, &WireMsg::MetricsQuery { format: wire::format::PROMETHEUS })
    else {
        panic!("metrics query answered with a non-metrics frame")
    };
    assert!(export::prometheus_well_formed(&prom), "exposition must parse:\n{prom}");
    for key in ["wingan_ready 1", "wingan_in_flight 0"] {
        assert!(prom.contains(key), "'{key}' missing:\n{prom}");
    }
    assert!(
        prom.lines().any(|l| l.starts_with("wingan_stages_") && l.contains("_p99_ms ")),
        "stage-latency keys missing:\n{prom}"
    );
    assert!(!prom.contains("itest-scrape"), "string leaves are JSON-only:\n{prom}");

    server.shutdown();
    rec.configure(0, 0, "itest-scrape");
    rec.reset();
}

/// Random Winograd-able deconv layer (the paper's K_C <= 3 classes).
#[derive(Debug)]
struct TraceCase {
    x: Tensor3,
    w: Filter4,
    s: usize,
    p: usize,
}

fn gen_winograd_case(rng: &mut Rng) -> TraceCase {
    let configs = [(5usize, 2usize), (4, 2), (3, 1), (6, 3), (2, 2), (6, 2)];
    loop {
        let (k, s) = configs[rng.below(configs.len())];
        if tdc::kc(k, s) > 3 {
            continue;
        }
        let p = tdc::default_padding(k, s);
        let c_in = rng.int_in(1, 4);
        let c_out = rng.int_in(1, 3);
        let h = rng.int_in(1, 7);
        let w = rng.int_in(1, 7);
        return TraceCase {
            x: Tensor3::from_vec(c_in, h, w, rng.normal_vec(c_in * h * w)),
            w: Filter4::from_vec(c_in, c_out, k, k, rng.normal_vec(c_in * c_out * k * k)),
            s,
            p,
        };
    }
}

/// The no-perturbation pillar: with sampling on, under a live trace
/// context, the engine's f64 outputs and every [`Events`] counter are
/// bitwise identical to the untraced run — at every worker count.
/// Recording reads clocks and appends to rings, never touches the
/// arithmetic; this pins that claim on randomized layers.
#[test]
fn prop_tracing_on_or_off_is_bitwise_invisible_at_every_worker_count() {
    let _guard = recorder_lock();
    let rec = telemetry::recorder();
    forall(
        "tracing on == tracing off, bitwise + events",
        12,
        0x7E1E,
        gen_winograd_case,
        |c| {
            let l = Layer {
                kind: Kind::Deconv,
                c_in: c.x.c,
                c_out: c.w.c_out,
                k: c.w.kh,
                s: c.s,
                p: c.p,
                h_in: c.x.h,
                w_in: c.x.w,
                act: Activation::Linear,
            };
            let planner = Planner::new(PlanOptions {
                select: Select::Force(Method::Winograd),
                ..Default::default()
            });
            let lp = planner.compile_layer(&l, c.w.clone());
            if lp.method != Method::Winograd {
                return Err("expected a winograd-method plan".into());
            }
            let plan = Arc::new(ModelPlan {
                model: "prop-trace".into(),
                input_shape: (c.x.c, c.x.h, c.x.w),
                output_shape: (c.w.c_out, c.s * c.x.h, c.s * c.x.w),
                layers: vec![lp],
            });
            // baseline: sampling off, no trace context
            rec.configure(0, 0, "prop-trace");
            let base = Engine::with_workers(plan.clone(), 2).run(&c.x);
            // sampling on, every run under a live trace
            rec.configure(1, 0, "prop-trace");
            for workers in [1usize, 2, 5] {
                let traced = telemetry::with_trace(77, || {
                    Engine::with_workers(plan.clone(), workers).run(&c.x)
                });
                let d = traced.y.max_abs_diff(&base.y);
                if d != 0.0 {
                    return Err(format!("workers={workers}: traced diff {d} (must be 0)"));
                }
                if traced.events != base.events {
                    return Err(format!(
                        "workers={workers}: events {:?} != untraced {:?}",
                        traced.events, base.events
                    ));
                }
            }
            // non-vacuous: the traced runs really recorded per-layer spans
            let spans = rec.spans(Some(77));
            if spans.is_empty() {
                return Err("traced runs recorded no spans — the property is vacuous".into());
            }
            rec.configure(0, 0, "prop-trace");
            rec.reset();
            Ok(())
        },
    );
}
