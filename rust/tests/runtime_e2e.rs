//! End-to-end tests over the PJRT runtime + serving coordinator using the
//! real AOT artifacts. Requires `make artifacts` to have run; tests skip
//! (with a loud message) when the manifest is absent so plain `cargo test`
//! works on a fresh checkout.

use std::path::{Path, PathBuf};
use std::time::Duration;
use wingan::coordinator::{Coordinator, ServeConfig};
use wingan::runtime::{Manifest, Runtime};
use wingan::util::bin;
use wingan::util::prng::Rng;

const TOL: f32 = 2e-4;

fn artifacts_dir() -> Option<PathBuf> {
    // tests run from the crate root
    let p = Path::new("artifacts");
    if p.join("manifest.json").exists() {
        Some(p.to_path_buf())
    } else {
        eprintln!("SKIP: artifacts/manifest.json missing — run `make artifacts`");
        None
    }
}

/// The PJRT backend is gated off in offline builds (no `xla` crate); skip
/// rather than panic when artifacts exist but the backend does not.
fn runtime() -> Option<Runtime> {
    match Runtime::new() {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("SKIP: {e:#}");
            None
        }
    }
}

#[test]
fn layer_artifacts_match_jax_goldens() {
    let Some(dir) = artifacts_dir() else { return };
    let m = Manifest::load(&dir).unwrap();
    let Some(mut rt) = runtime() else { return };
    for e in m.entries.iter().filter(|e| e.kind == "layer") {
        rt.load(e).unwrap();
        let diff = rt.verify_golden(&e.name).unwrap();
        assert!(diff < TOL, "{}: max|Δ| {diff}", e.name);
    }
}

#[test]
fn generator_artifacts_match_jax_goldens_b1() {
    let Some(dir) = artifacts_dir() else { return };
    let m = Manifest::load(&dir).unwrap();
    let Some(mut rt) = runtime() else { return };
    for e in m.entries.iter().filter(|e| e.kind == "generator" && e.batch == 1) {
        rt.load(e).unwrap();
        let diff = rt.verify_golden(&e.name).unwrap();
        assert!(diff < TOL, "{}: max|Δ| {diff}", e.name);
    }
}

#[test]
fn winograd_and_tdc_artifacts_compute_same_function() {
    // the paper's equivalence claim at the whole-generator level, executed
    // by the rust runtime on fresh random inputs
    let Some(dir) = artifacts_dir() else { return };
    let m = Manifest::load(&dir).unwrap();
    let Some(mut rt) = runtime() else { return };
    let win = m.find("dcgan_b1").unwrap().clone();
    let tdc = m.find("dcgan_tdc_b1").unwrap().clone();
    rt.load(&win).unwrap();
    rt.load(&tdc).unwrap();
    let mut rng = Rng::new(99);
    for _ in 0..3 {
        let x = rng.normal_vec_f32(win.input_len());
        let a = rt.execute("dcgan_b1", &x).unwrap();
        let b = rt.execute("dcgan_tdc_b1", &x).unwrap();
        let diff = bin::max_abs_diff(&a, &b);
        assert!(diff < 2e-3, "winograd vs tdc generator outputs differ: {diff}");
    }
}

#[test]
fn runtime_rejects_bad_input_length() {
    let Some(dir) = artifacts_dir() else { return };
    let m = Manifest::load(&dir).unwrap();
    let Some(mut rt) = runtime() else { return };
    let e = m.find("deconv_k5s2").unwrap().clone();
    rt.load(&e).unwrap();
    assert!(rt.execute("deconv_k5s2", &[0.0; 3]).is_err());
    assert!(rt.execute("not_loaded", &[0.0; 3]).is_err());
}

#[test]
fn batched_execution_is_consistent_with_single() {
    // executing [x; 4] through the b4 bucket must reproduce the b1 outputs
    let Some(dir) = artifacts_dir() else { return };
    let m = Manifest::load(&dir).unwrap();
    let Some(mut rt) = runtime() else { return };
    let b1 = m.find("dcgan_b1").unwrap().clone();
    let b4 = m.find("dcgan_b4").unwrap().clone();
    rt.load(&b1).unwrap();
    rt.load(&b4).unwrap();
    let mut rng = Rng::new(7);
    let sample = rng.normal_vec_f32(b1.input_len());
    let single = rt.execute("dcgan_b1", &sample).unwrap();
    let mut batched_in = Vec::new();
    for _ in 0..4 {
        batched_in.extend_from_slice(&sample);
    }
    let batched = rt.execute("dcgan_b4", &batched_in).unwrap();
    let n = single.len();
    for i in 0..4 {
        let diff = bin::max_abs_diff(&batched[i * n..(i + 1) * n], &single);
        assert!(diff < 1e-4, "batch lane {i} diverges: {diff}");
    }
}

#[test]
fn coordinator_serves_and_matches_direct_execution() {
    let Some(dir) = artifacts_dir() else { return };
    let manifest = Manifest::load(&dir).unwrap();

    // direct execution for reference
    let Some(mut rt) = runtime() else { return };
    let b1 = manifest.find("dcgan_b1").unwrap().clone();
    rt.load(&b1).unwrap();
    let mut rng = Rng::new(21);
    let inputs: Vec<Vec<f32>> = (0..6).map(|_| rng.normal_vec_f32(b1.input_len())).collect();
    let reference: Vec<Vec<f32>> =
        inputs.iter().map(|x| rt.execute("dcgan_b1", x).unwrap()).collect();
    drop(rt);

    // serve the same inputs through the coordinator (batching allowed)
    let coord = match Coordinator::start(
        manifest,
        ServeConfig {
            max_wait: Duration::from_millis(2),
            preload_models: Some(vec!["dcgan".into()]),
            ..Default::default()
        },
    ) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("SKIP: {e:#}");
            return;
        }
    };
    let pending: Vec<_> = inputs
        .iter()
        .map(|x| coord.submit("dcgan", "winograd", x.clone()).unwrap())
        .collect();
    for (rx, want) in pending.into_iter().zip(&reference) {
        let resp = rx.recv().unwrap().unwrap();
        let diff = bin::max_abs_diff(&resp.output, want);
        assert!(diff < 1e-4, "served output diverges from direct execution: {diff}");
    }
    let metrics = coord.metrics();
    assert_eq!(metrics.responses, 6);
    assert!(metrics.batches >= 1);
    coord.shutdown();
}

#[test]
fn coordinator_rejects_invalid_requests() {
    let Some(dir) = artifacts_dir() else { return };
    let manifest = Manifest::load(&dir).unwrap();
    let coord = match Coordinator::start(
        manifest,
        ServeConfig { max_wait: Duration::from_millis(1), preload_models: Some(vec![]), ..Default::default() },
    ) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("SKIP: {e:#}");
            return;
        }
    };
    assert!(coord.submit("nope", "winograd", vec![0.0; 4]).is_err());
    assert!(coord.submit("dcgan", "winograd", vec![0.0; 3]).is_err());
    coord.shutdown();
}
