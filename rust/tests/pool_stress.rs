//! Pool-focused tests: the persistent worker pool under concurrent serving
//! load, and the two-level batch scheduler's bitwise-equivalence contract.
//!
//! * **Stress** — many OS threads push `run_batch` calls through one shared
//!   [`WorkerPool`] concurrently; every output must equal the serial
//!   (1-worker) reference bit for bit.
//! * **Regression** — batch-level (`SampleLevel`) and stripe-level
//!   (`StripeLevel`) scheduling produce bitwise-identical outputs and event
//!   counts for every zoo model.

use std::sync::Arc;

use wingan::engine::pool::WorkerPool;
use wingan::engine::{BatchSchedule, Engine, NativeConfig, NativeRuntime, Planner};
use wingan::gan::zoo::{self, Scale};
use wingan::util::prng::Rng;
use wingan::util::tensor::Tensor3;

fn rand3(rng: &mut Rng, shape: (usize, usize, usize)) -> Tensor3 {
    let (c, h, w) = shape;
    Tensor3::from_vec(c, h, w, rng.normal_vec(c * h * w))
}

#[test]
fn stress_concurrent_run_batch_through_one_shared_pool() {
    let g = zoo::dcgan(Scale::Tiny);
    // one compiled plan shared by both engines (Arc clone, no deep clone)
    let plan = Arc::new(Planner::default().compile_seeded(&g, 11));

    // serial ground truth on a single worker (everything runs inline)
    let serial = Engine::with_workers(plan.clone(), 1);

    let pool = WorkerPool::shared(4);
    let shared = Engine::with_pool(plan.clone(), pool.clone());

    const CALLERS: usize = 8;
    const BATCH: usize = 5;
    const ROUNDS: usize = 3;

    // per-caller deterministic inputs + their serial references
    let mut rng = Rng::new(500);
    let inputs: Vec<Vec<Tensor3>> = (0..CALLERS * ROUNDS)
        .map(|_| (0..BATCH).map(|_| rand3(&mut rng, plan.input_shape)).collect())
        .collect();
    let want: Vec<Vec<Tensor3>> = inputs
        .iter()
        .map(|xs| serial.run_batch(xs).into_iter().map(|r| r.y).collect())
        .collect();

    std::thread::scope(|s| {
        let handles: Vec<_> = (0..CALLERS)
            .map(|caller| {
                let shared = &shared;
                let inputs = &inputs;
                let want = &want;
                s.spawn(move || {
                    for round in 0..ROUNDS {
                        let idx = caller * ROUNDS + round;
                        let runs = shared.run_batch(&inputs[idx]);
                        assert_eq!(runs.len(), BATCH);
                        for (b, run) in runs.iter().enumerate() {
                            assert_eq!(
                                run.y.max_abs_diff(&want[idx][b]),
                                0.0,
                                "caller {caller} round {round} sample {b}: \
                                 concurrent pooled output must equal serial reference"
                            );
                        }
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().expect("stress caller panicked");
        }
    });

    // the pool is still healthy after the storm
    assert_eq!(pool.threads(), 4);
    let after = shared.run(&inputs[0][0]);
    assert_eq!(after.y.max_abs_diff(&want[0][0]), 0.0);
}

#[test]
fn batch_and_stripe_scheduling_bitwise_identical_for_every_zoo_model() {
    let mut rng = Rng::new(501);
    for g in zoo::all(Scale::Tiny) {
        let plan = Arc::new(Planner::default().compile_seeded(&g, 9));
        let engine = Engine::with_workers(plan.clone(), 3);
        let xs: Vec<Tensor3> = (0..4).map(|_| rand3(&mut rng, plan.input_shape)).collect();
        let sample = engine.run_batch_with(&xs, BatchSchedule::SampleLevel);
        let stripe = engine.run_batch_with(&xs, BatchSchedule::StripeLevel);
        assert_eq!(sample.len(), xs.len(), "{}", g.name);
        for b in 0..xs.len() {
            assert_eq!(
                sample[b].y.max_abs_diff(&stripe[b].y),
                0.0,
                "{} sample {b}: schedules must agree bit for bit",
                g.name
            );
            assert_eq!(sample[b].events.mults, stripe[b].events.mults, "{}", g.name);
            assert_eq!(sample[b].events.stripes, stripe[b].events.stripes, "{}", g.name);
            assert_eq!(sample[b].events.tiles, stripe[b].events.tiles, "{}", g.name);
            assert_eq!(
                sample[b].events.linebuf_reads, stripe[b].events.linebuf_reads,
                "{}",
                g.name
            );
            assert_eq!(
                sample[b].events.linebuf_writes, stripe[b].events.linebuf_writes,
                "{}",
                g.name
            );
        }
    }
}

#[test]
fn native_runtime_serves_concurrent_batches_on_one_pool() {
    let rt = Arc::new(NativeRuntime::build(&NativeConfig {
        scale: Scale::Tiny,
        buckets: vec![1, 4],
        workers: 3,
        models: Some(vec!["dcgan".into()]),
        ..Default::default()
    }));
    let wino = rt.engine("dcgan", "winograd").expect("route");
    assert!(Arc::ptr_eq(wino.pool(), rt.pool()), "route engines must share the server pool");

    let entry_len = wino.input_len() * 4;
    let input: Vec<f32> = (0..entry_len).map(|i| ((i % 17) as f32 - 8.0) / 8.0).collect();
    let want = rt.execute("dcgan_winograd_b4", &input).expect("reference execute");

    std::thread::scope(|s| {
        let handles: Vec<_> = (0..6)
            .map(|_| {
                let rt = rt.clone();
                let input = input.clone();
                let want = want.clone();
                s.spawn(move || {
                    for _ in 0..2 {
                        let out = rt.execute("dcgan_winograd_b4", &input).expect("execute");
                        assert_eq!(out, want, "concurrent execute must be deterministic");
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().expect("execute caller panicked");
        }
    });
}
