//! End-to-end tests over the native engine serving path: plan compilation
//! + batched request serving through `coordinator::server` for every zoo
//! model, with deterministic Events/latency accounting checks. No PJRT, no
//! artifacts on disk — this suite always runs.

use std::time::{Duration, Instant};
use wingan::coordinator::{Coordinator, ServeConfig};
use wingan::engine::{native_manifest, NativeConfig, NativeRuntime};
use wingan::gan::zoo::Scale;
use wingan::util::bin;
use wingan::util::prng::Rng;

fn tiny_cfg() -> NativeConfig {
    NativeConfig {
        scale: Scale::Tiny,
        buckets: vec![1, 2, 4],
        workers: 2,
        seed: 9,
        models: None,
        ..Default::default()
    }
}

const ZOO_IDS: [&str; 4] = ["dcgan", "artgan", "discogan", "gpgan"];

#[test]
fn serves_batched_requests_for_every_zoo_model() {
    let coord = Coordinator::start_native(
        tiny_cfg(),
        ServeConfig { max_wait: Duration::from_millis(10), preload_models: None, ..Default::default() },
    )
    .unwrap();
    let mut rng = Rng::new(31);
    let mut expected_responses = 0u64;
    for model in ZOO_IDS {
        let route = coord.router().route(model, "winograd").unwrap();
        let (input_len, output_len) = (route.sample_input_len, route.sample_output_len);
        let buckets = route.bucket_sizes();
        assert_eq!(buckets, vec![1, 2, 4], "{model}");
        // burst of 4 requests: the batcher may group them into any mix of
        // the advertised buckets, but every response must come back with a
        // legal bucket and the right output geometry
        let pending: Vec<_> = (0..4)
            .map(|_| coord.submit(model, "winograd", rng.normal_vec_f32(input_len)).unwrap())
            .collect();
        expected_responses += 4;
        for rx in pending {
            let resp = rx.recv().unwrap().unwrap();
            assert_eq!(resp.output.len(), output_len, "{model}");
            assert!(buckets.contains(&resp.batch_size), "{model}: {}", resp.batch_size);
            assert!(resp.output.iter().all(|v| v.is_finite()), "{model}");
        }
    }
    let m = coord.metrics();
    assert_eq!(m.responses, expected_responses);
    assert_eq!(m.requests, expected_responses);
    assert!(m.batches >= ZOO_IDS.len() as u64);
    // exec latency is recorded once per executed batch, queue/e2e per request
    assert_eq!(m.exec_latency.count(), m.batches);
    assert_eq!(m.queue_latency.count(), expected_responses);
    assert!(m.exec_latency.mean() > 0.0);
    coord.shutdown();
}

#[test]
fn events_accounting_monotone_with_batch_size() {
    // deterministic accounting: a bucket-b execution does exactly b times
    // the single-sample work, so cumulative events are strictly monotone
    // in total samples served
    let cfg = NativeConfig { models: Some(vec!["dcgan".into()]), ..tiny_cfg() };
    let rt = NativeRuntime::build(&cfg);
    let manifest = native_manifest(&cfg);
    let e1 = manifest.find("dcgan_winograd_b1").unwrap().clone();

    let mut rng = Rng::new(5);
    let sample = rng.normal_vec_f32(e1.input_len());
    rt.execute("dcgan_winograd_b1", &sample).unwrap();
    let per_sample = rt.events();
    assert!(per_sample.mults > 0 && per_sample.tiles > 0 && per_sample.stripes > 0);

    let mut cumulative = vec![per_sample.clone()];
    for b in [2usize, 4] {
        let entry = manifest.find(&format!("dcgan_winograd_b{b}")).unwrap().clone();
        let mut input = Vec::new();
        for _ in 0..b {
            input.extend_from_slice(&sample);
        }
        assert_eq!(input.len(), entry.input_len());
        rt.execute(&entry.name, &input).unwrap();
        cumulative.push(rt.events());
    }
    // cumulative counters strictly increase batch over batch...
    for w in cumulative.windows(2) {
        assert!(w[1].mults > w[0].mults);
        assert!(w[1].linebuf_reads > w[0].linebuf_reads);
        assert!(w[1].linebuf_writes > w[0].linebuf_writes);
        assert!(w[1].tiles > w[0].tiles);
        assert!(w[1].stripes > w[0].stripes);
    }
    // ... and exactly linearly: after 1 + 2 + 4 samples, every counter is
    // 7x the single-sample cost
    let total = rt.events();
    assert_eq!(total.mults, per_sample.mults * 7);
    assert_eq!(total.tiles, per_sample.tiles * 7);
    assert_eq!(total.stripes, per_sample.stripes * 7);
}

#[test]
fn exec_latency_tracks_batch_work() {
    // a bucket-4 batch does 4x the bucket-1 compute; after warmup its
    // execution cannot be faster than a single-sample run
    let cfg = NativeConfig { models: Some(vec!["dcgan".into()]), ..tiny_cfg() };
    let rt = NativeRuntime::build(&cfg);
    let manifest = native_manifest(&cfg);
    let e1 = manifest.find("dcgan_winograd_b1").unwrap().clone();
    let e4 = manifest.find("dcgan_winograd_b4").unwrap().clone();
    let mut rng = Rng::new(6);
    let sample = rng.normal_vec_f32(e1.input_len());
    let mut batch4 = Vec::new();
    for _ in 0..4 {
        batch4.extend_from_slice(&sample);
    }
    // warmup both routes
    rt.execute(&e1.name, &sample).unwrap();
    rt.execute(&e4.name, &batch4).unwrap();
    // best-of-3 to shrug off scheduler noise
    let best = |f: &dyn Fn()| {
        (0..3)
            .map(|_| {
                let t = Instant::now();
                f();
                t.elapsed()
            })
            .min()
            .unwrap()
    };
    let t1 = best(&|| {
        rt.execute(&e1.name, &sample).unwrap();
    });
    let t4 = best(&|| {
        rt.execute(&e4.name, &batch4).unwrap();
    });
    assert!(
        t4 >= t1,
        "batch-4 exec ({t4:?}) should not beat single-sample exec ({t1:?})"
    );
}

#[test]
fn served_outputs_match_direct_engine_execution() {
    // the coordinator path (batcher + packing + engine thread) must return
    // exactly what a direct NativeRuntime execution returns
    let cfg = NativeConfig { models: Some(vec!["gpgan".into()]), ..tiny_cfg() };
    let direct = NativeRuntime::build(&cfg);
    let manifest = native_manifest(&cfg);
    let e1 = manifest.find("gpgan_winograd_b1").unwrap().clone();
    let mut rng = Rng::new(77);
    let inputs: Vec<Vec<f32>> = (0..3).map(|_| rng.normal_vec_f32(e1.input_len())).collect();
    let reference: Vec<Vec<f32>> =
        inputs.iter().map(|x| direct.execute(&e1.name, x).unwrap()).collect();

    let coord = Coordinator::start_native(
        cfg,
        ServeConfig {
            max_wait: Duration::from_millis(2),
            preload_models: Some(vec!["gpgan".into()]),
            ..Default::default()
        },
    )
    .unwrap();
    for (x, want) in inputs.iter().zip(&reference) {
        let resp = coord.generate("gpgan", "winograd", x.clone()).unwrap();
        // same plan, same engine arithmetic -> bitwise equal f32
        assert_eq!(bin::max_abs_diff(&resp.output, want), 0.0);
    }
    coord.shutdown();
}

#[test]
fn tdc_route_is_the_reference_anchor() {
    // A/B the fast route against the bit-exact TDC route per model
    let coord = Coordinator::start_native(
        tiny_cfg(),
        ServeConfig { max_wait: Duration::from_millis(2), preload_models: None, ..Default::default() },
    )
    .unwrap();
    let mut rng = Rng::new(13);
    for model in ZOO_IDS {
        let route = coord.router().route(model, "winograd").unwrap();
        let input = rng.normal_vec_f32(route.sample_input_len);
        let a = coord.generate(model, "winograd", input.clone()).unwrap();
        let b = coord.generate(model, "tdc", input).unwrap();
        let diff = bin::max_abs_diff(&a.output, &b.output);
        assert!(diff < 1e-3, "{model}: winograd vs tdc diff {diff}");
    }
    coord.shutdown();
}

#[test]
fn f32_tier_serves_end_to_end_and_tracks_the_reference() {
    // the whole coordinator path on a forced-f32 fast route: outputs must
    // stay finite, deterministic, and within single-precision rounding of
    // the f64 tdc reference anchor
    let coord = Coordinator::start_native(
        NativeConfig {
            precision: Some(wingan::engine::Precision::F32),
            models: Some(vec!["dcgan".into()]),
            ..tiny_cfg()
        },
        ServeConfig { max_wait: Duration::from_millis(2), preload_models: None, ..Default::default() },
    )
    .unwrap();
    let mut rng = Rng::new(23);
    let route = coord.router().route("dcgan", "winograd").unwrap();
    let input = rng.normal_vec_f32(route.sample_input_len);
    let fast = coord.generate("dcgan", "winograd", input.clone()).unwrap();
    let again = coord.generate("dcgan", "winograd", input.clone()).unwrap();
    assert_eq!(fast.output, again.output, "f32 tier must be deterministic");
    let anchor = coord.generate("dcgan", "tdc", input).unwrap();
    let diff = bin::max_abs_diff(&fast.output, &anchor.output);
    assert!(diff < 1e-3, "f32 fast route vs f64 reference anchor: {diff}");
    assert!(fast.output.iter().all(|v| v.is_finite()));
    coord.shutdown();
}

#[test]
fn coordinator_rejects_invalid_native_requests() {
    let coord = Coordinator::start_native(
        NativeConfig { models: Some(vec!["dcgan".into()]), ..tiny_cfg() },
        ServeConfig { max_wait: Duration::from_millis(1), preload_models: None, ..Default::default() },
    )
    .unwrap();
    assert!(coord.submit("nope", "winograd", vec![0.0; 4]).is_err());
    assert!(coord.submit("dcgan", "winograd", vec![0.0; 3]).is_err());
    assert!(coord.submit("dcgan", "nope", vec![0.0; 4]).is_err());
    coord.shutdown();
}
