//! Fleet wire-protocol integration tests (PR 9): the replica server
//! exercised over real TCP, the way the router (or a hostile peer)
//! actually reaches it.
//!
//! The pure codec properties — truncation at every cut, oversized
//! length prefixes, bad tags, random bytes never panicking the decoder
//! — live inline in `fleet::wire`; the fate-cache and breaker state
//! machines are pinned in their own modules. These tests cover what
//! only a socket can: lifecycle phases observable on the wire
//! (NOT_READY → ready → DRAINING → stopped), the health document served
//! to provers, retry idempotency across *connections*, and a torn frame
//! from one client never taking the server down for the next.

use std::net::{SocketAddr, TcpStream};
use std::sync::Arc;
use std::time::{Duration, Instant};
use wingan::coordinator::ServeConfig;
use wingan::engine::NativeConfig;
use wingan::faultinject::FaultPlane;
use wingan::fleet::wire::{self, RecvError, WireMsg};
use wingan::fleet::{ReplicaConfig, ReplicaServer};
use wingan::gan::zoo::Scale;
use wingan::util::json::{self, Json};
use wingan::util::prng::Rng;

/// A tiny-scale single-model replica config: fast to boot, real engine.
fn tiny_cfg() -> ReplicaConfig {
    ReplicaConfig {
        native: NativeConfig {
            scale: Scale::Tiny,
            workers: 2,
            models: Some(vec!["dcgan".into()]),
            ..Default::default()
        },
        serve: ServeConfig {
            drain_deadline: Duration::from_secs(2),
            ..Default::default()
        },
        fleet_faults: None,
    }
}

/// One connect-send-recv round trip with bounded timeouts.
fn rpc(addr: SocketAddr, msg: &WireMsg) -> Result<WireMsg, String> {
    let mut s = TcpStream::connect_timeout(&addr, Duration::from_secs(2))
        .map_err(|e| format!("connect: {e}"))?;
    let _ = s.set_read_timeout(Some(Duration::from_secs(30)));
    let _ = s.set_write_timeout(Some(Duration::from_secs(5)));
    wire::send(&mut s, msg).map_err(|e| format!("send: {e}"))?;
    wire::recv(&mut s).map_err(|e| format!("recv: {e}"))
}

/// Ask the replica's health document for the first route's input length
/// — the tests stay agnostic to zoo geometry.
fn first_route_input_len(addr: SocketAddr) -> usize {
    let WireMsg::HealthReply { json: text } = rpc(addr, &WireMsg::HealthQuery).expect("health")
    else {
        panic!("health query answered with a non-health frame")
    };
    let doc = json::parse(&text).expect("health JSON parses");
    let routes = doc.get("routes").and_then(Json::as_arr).expect("routes array");
    routes[0].get("input_len").and_then(Json::as_usize).expect("input_len")
}

fn request(id: u64, input: Vec<f32>) -> WireMsg {
    WireMsg::Request {
        id,
        model: "dcgan".into(),
        method: "winograd".into(),
        deadline_us: 0,
        input,
        trace: 0,
    }
}

/// The boot gap is observable and typed: a replica still compiling (four
/// models makes the gap wide) answers `NOT_READY` — a retryable verdict,
/// never a hang or a dropped connection — and serves normally once the
/// boot lands.
#[test]
fn requests_in_the_boot_gap_get_typed_not_ready() {
    let mut cfg = tiny_cfg();
    // all four zoo models: the boot is guaranteed to outlast our probe
    cfg.native.models = None;
    let server = ReplicaServer::spawn("127.0.0.1:0", cfg).expect("binds");
    let addr = server.addr();

    // immediately after bind, before the warm-boot lands
    match rpc(addr, &request(1, vec![0.0; 4])) {
        Ok(WireMsg::Error { code, .. }) if code == wire::code::NOT_READY => {
            assert!(wire::retryable(code), "NOT_READY must be retryable");
        }
        // on a fast machine the boot can win the race; the deliberately
        // wrong input length then gets the shape verdict instead
        Ok(WireMsg::Error { code, .. }) if code == wire::code::BAD_INPUT_LENGTH => {}
        other => panic!("boot-gap request got {other:?}"),
    }

    assert!(server.wait_ready(Duration::from_secs(120)), "boot eventually lands");
    let input_len = first_route_input_len(addr);
    match rpc(addr, &request(2, Rng::new(3).normal_vec_f32(input_len))) {
        Ok(WireMsg::Response { id, output, .. }) => {
            assert_eq!(id, 2);
            assert!(!output.is_empty());
        }
        other => panic!("post-boot request got {other:?}"),
    }
    server.shutdown();
}

/// The health document is machine-readable and carries the contract
/// keys: role, readiness, generation, the route table, and the
/// coordinator's own health + metrics once booted.
#[test]
fn health_document_parses_and_carries_the_stable_keys() {
    let server = ReplicaServer::spawn("127.0.0.1:0", tiny_cfg()).expect("binds");
    assert!(server.wait_ready(Duration::from_secs(120)), "boot lands");
    let WireMsg::HealthReply { json: text } =
        rpc(server.addr(), &WireMsg::HealthQuery).expect("health")
    else {
        panic!("non-health frame")
    };
    let doc = json::parse(&text).expect("health JSON parses");
    assert_eq!(doc.get("role").and_then(Json::as_str), Some("replica"));
    assert!(matches!(doc.get("ready"), Some(Json::Bool(true))));
    assert!(matches!(doc.get("draining"), Some(Json::Bool(false))));
    assert!(doc.get("generation").and_then(Json::as_usize).is_some());
    let routes = doc.get("routes").and_then(Json::as_arr).expect("routes");
    assert!(!routes.is_empty(), "a ready replica advertises its routes");
    for r in routes {
        assert!(r.get("model").and_then(Json::as_str).is_some());
        assert!(r.get("method").and_then(Json::as_str).is_some());
        assert!(r.get("input_len").and_then(Json::as_usize).is_some());
        assert!(r.get("output_len").and_then(Json::as_usize).is_some());
    }
    let coord = doc.get("coordinator").expect("coordinator block");
    assert!(
        matches!(coord.get("health").and_then(|h| h.get("all_healthy")), Some(Json::Bool(true))),
        "booted replica reports a healthy coordinator"
    );
    assert!(coord.get("metrics").and_then(|m| m.get("requests")).is_some());
    server.shutdown();
}

/// Retry idempotency end to end: the identical `Request` frame sent
/// twice — on two separate connections, the way a router retry actually
/// arrives — executes once and replays the recorded fate, bitwise
/// identical down to the encoded frame.
#[test]
fn resent_request_frames_replay_the_fate_bitwise_identically() {
    let server = ReplicaServer::spawn("127.0.0.1:0", tiny_cfg()).expect("binds");
    assert!(server.wait_ready(Duration::from_secs(120)), "boot lands");
    let addr = server.addr();
    let input_len = first_route_input_len(addr);
    let msg = request(77, Rng::new(11).normal_vec_f32(input_len));

    let first = rpc(addr, &msg).expect("first send");
    assert!(matches!(first, WireMsg::Response { .. }), "got {first:?}");
    for round in 0..2 {
        let again = rpc(addr, &msg).expect("resend");
        assert_eq!(
            again.encode(),
            first.encode(),
            "resend {round}: replayed fate must be bitwise identical"
        );
    }
    server.shutdown();
}

/// "At most one execution per id" also holds when the duplicate arrives
/// *while* the first execution is still in flight — the router's io
/// timeout can resend an id a stalled replica is still working on. The
/// duplicate must wait for the original's fate and replay it bitwise,
/// never start a second execution.
#[test]
fn duplicate_id_in_flight_waits_and_shares_the_single_execution() {
    let mut cfg = tiny_cfg();
    // stall the first request 500 ms between admission and execution, so
    // the duplicate provably lands while the original is in flight
    cfg.fleet_faults = Some(Arc::new(
        FaultPlane::parse("seed=1;replica_stall:delay=500ms*1@1").expect("fault plane"),
    ));
    let server = ReplicaServer::spawn("127.0.0.1:0", cfg).expect("binds");
    assert!(server.wait_ready(Duration::from_secs(120)), "boot lands");
    let addr = server.addr();
    let input_len = first_route_input_len(addr);
    let msg = request(42, Rng::new(13).normal_vec_f32(input_len));

    let (first, second) = std::thread::scope(|s| {
        let m = &msg;
        let a = s.spawn(move || rpc(addr, m));
        std::thread::sleep(Duration::from_millis(150));
        let b = rpc(addr, m);
        (a.join().expect("first sender"), b)
    });
    let first = first.expect("first reply");
    let second = second.expect("duplicate reply");
    assert!(matches!(first, WireMsg::Response { .. }), "got {first:?}");
    assert_eq!(
        second.encode(),
        first.encode(),
        "the waiting duplicate shares the original's fate, bitwise"
    );

    // the engine saw exactly one request: the duplicate never executed
    let WireMsg::HealthReply { json: text } = rpc(addr, &WireMsg::HealthQuery).expect("health")
    else {
        panic!("non-health frame")
    };
    let doc = json::parse(&text).expect("parses");
    let requests = doc
        .get("coordinator")
        .and_then(|c| c.get("metrics"))
        .and_then(|m| m.get("requests"))
        .and_then(Json::as_usize)
        .expect("requests metric");
    assert_eq!(requests, 1, "one id, one execution — however many times it is sent");
    server.shutdown();
}

/// Drain over the wire: after `Drain`, new requests answer typed
/// `DRAINING` (retryable — the router routes around it), and the health
/// document flips its `draining` flag so the prober deregisters the
/// replica before shutdown.
#[test]
fn drained_replica_sheds_typed_and_reports_draining() {
    let server = ReplicaServer::spawn("127.0.0.1:0", tiny_cfg()).expect("binds");
    assert!(server.wait_ready(Duration::from_secs(120)), "boot lands");
    let addr = server.addr();
    let input_len = first_route_input_len(addr);

    assert_eq!(rpc(addr, &WireMsg::Drain).expect("drain"), WireMsg::Ok);
    match rpc(addr, &request(5, Rng::new(5).normal_vec_f32(input_len))) {
        Ok(WireMsg::Error { code, .. }) => {
            assert_eq!(code, wire::code::DRAINING);
            assert!(wire::retryable(code), "DRAINING must be retryable");
        }
        other => panic!("request to a draining replica got {other:?}"),
    }
    let WireMsg::HealthReply { json: text } = rpc(addr, &WireMsg::HealthQuery).expect("health")
    else {
        panic!("non-health frame")
    };
    let doc = json::parse(&text).expect("parses");
    assert!(matches!(doc.get("draining"), Some(Json::Bool(true))));
    assert!(
        matches!(doc.get("ready"), Some(Json::Bool(false))),
        "a draining replica is not ready for new work"
    );
    server.shutdown();
}

/// The remote `Shutdown` verb is acknowledged and stops the serve loop
/// — the graceful path a rolling decommission takes.
#[test]
fn shutdown_verb_is_acknowledged_and_stops_the_replica() {
    let server = ReplicaServer::spawn("127.0.0.1:0", tiny_cfg()).expect("binds");
    assert!(server.wait_ready(Duration::from_secs(120)), "boot lands");
    assert_eq!(rpc(server.addr(), &WireMsg::Shutdown).expect("shutdown verb"), WireMsg::Ok);
    let t0 = Instant::now();
    while server.alive() && t0.elapsed() < Duration::from_secs(10) {
        std::thread::sleep(Duration::from_millis(10));
    }
    assert!(!server.alive(), "remote Shutdown stops the serve loop");
    server.join();
}

/// Hostile peers cost one connection, never the server: a torn frame
/// (length prefix promising more than is sent) and raw garbage bytes are
/// both absorbed, and the next well-formed client is served normally.
#[test]
fn torn_frames_and_garbage_never_take_the_server_down() {
    use std::io::Write;
    let server = ReplicaServer::spawn("127.0.0.1:0", tiny_cfg()).expect("binds");
    assert!(server.wait_ready(Duration::from_secs(120)), "boot lands");
    let addr = server.addr();

    // torn frame: header promises 100 bytes, the peer hangs up after 3
    {
        let mut s = TcpStream::connect(addr).expect("connect");
        s.write_all(&100u32.to_le_bytes()).expect("header");
        s.write_all(&[1, 1, 0]).expect("partial body");
    } // dropped: mid-frame EOF on the server side

    // raw garbage: not even a plausible header
    {
        let mut s = TcpStream::connect(addr).expect("connect");
        s.write_all(&[0xFF; 64]).expect("garbage");
    }

    // the server shrugs both off and keeps serving
    let input_len = first_route_input_len(addr);
    match rpc(addr, &request(9, Rng::new(9).normal_vec_f32(input_len))) {
        Ok(WireMsg::Response { id, .. }) => assert_eq!(id, 9),
        other => panic!("post-hostility request got {other:?}"),
    }
    server.shutdown();
}

/// Clean close vs torn frame is distinguishable client-side too: a
/// well-formed query followed by our own clean close leaves the server
/// running, and `recv` on a socket the server never writes to times out
/// as an Io error, not a panic.
#[test]
fn reply_frames_to_the_server_cost_the_connection_not_the_process() {
    let server = ReplicaServer::spawn("127.0.0.1:0", tiny_cfg()).expect("binds");
    assert!(server.wait_ready(Duration::from_secs(120)), "boot lands");
    let addr = server.addr();

    // sending a reply-type frame to a server is a protocol violation:
    // it drops the connection (no reply) rather than answering
    let mut s = TcpStream::connect(addr).expect("connect");
    let _ = s.set_read_timeout(Some(Duration::from_secs(5)));
    wire::send(
        &mut s,
        &WireMsg::Response { id: 1, batch_size: 1, queue_us: 0, exec_us: 0, output: vec![] },
    )
    .expect("send");
    match wire::recv(&mut s) {
        Err(RecvError::Closed) | Err(RecvError::Io(_)) => {}
        other => panic!("protocol violation should cost the connection, got {other:?}"),
    }

    // and the server is still alive for legitimate clients
    assert!(server.alive());
    let WireMsg::HealthReply { .. } = rpc(addr, &WireMsg::HealthQuery).expect("health") else {
        panic!("non-health frame")
    };
    server.shutdown();
}
