//! Property-based tests over the algorithmic substrates (own `prop`
//! harness; see rust/src/prop.rs). These pin the paper's core claims on
//! randomized inputs:
//!   * TDC DeConv == standard DeConv (Fig. 2)
//!   * zero-padded DeConv == standard DeConv (Fig. 1b)
//!   * the Winograd dataflow through line buffers == standard DeConv
//!   * sparse engine's skipped work == the structural zero count
//!   * the cycle model's invariants (monotonicity, bandwidth-boundedness)
//!   * batcher conservation (no loss, no dup, FIFO)

use std::time::{Duration, Instant};
use wingan::accel::functional::{run_tdc_deconv, run_winograd_deconv};
use wingan::accel::{simulate_layer, AccelConfig};
use wingan::coordinator::batcher::{BatchPolicy, DynamicBatcher};
use wingan::coordinator::request::GenRequest;
use wingan::gan::workload::{layer_mults, Method};
use wingan::gan::zoo::{Kind, Layer};
use wingan::prop::forall;
use wingan::tdc;
use wingan::util::prng::Rng;
use wingan::util::tensor::{Filter4, Tensor3};
use wingan::winograd;

/// Random deconv problem drawn from the paper's kernel classes plus a few
/// off-paper (K, S) combos that still satisfy the TDC offset bound.
#[derive(Debug)]
struct DeconvCase {
    x: Tensor3,
    w: Filter4,
    s: usize,
    p: usize,
}

fn gen_case(rng: &mut Rng) -> DeconvCase {
    let configs = [(5usize, 2usize), (4, 2), (3, 1), (6, 3), (2, 2), (6, 2)];
    let (k, s) = configs[rng.below(configs.len())];
    let p = tdc::default_padding(k, s);
    let c_in = rng.int_in(1, 4);
    let c_out = rng.int_in(1, 3);
    let h = rng.int_in(1, 7);
    let w = rng.int_in(1, 7);
    DeconvCase {
        x: Tensor3::from_vec(c_in, h, w, rng.normal_vec(c_in * h * w)),
        w: Filter4::from_vec(c_in, c_out, k, k, rng.normal_vec(c_in * c_out * k * k)),
        s,
        p,
    }
}

#[test]
fn prop_tdc_equals_standard_deconv() {
    forall("tdc == standard", 48, 0xA11CE, gen_case, |c| {
        let want = tdc::deconv_naive(&c.x, &c.w, c.s, c.p);
        let got = tdc::tdc_deconv(&c.x, &c.w, c.s, c.p);
        let d = want.max_abs_diff(&got);
        if d < 1e-10 {
            Ok(())
        } else {
            Err(format!("max diff {d} for K={} S={}", c.w.kh, c.s))
        }
    });
}

#[test]
fn prop_zero_padded_equals_standard_deconv() {
    forall("zero-padded == standard", 48, 0xB0B, gen_case, |c| {
        let want = tdc::deconv_naive(&c.x, &c.w, c.s, c.p);
        let got = tdc::zero_padded_deconv(&c.x, &c.w, c.s, c.p);
        let d = want.max_abs_diff(&got);
        if d < 1e-10 {
            Ok(())
        } else {
            Err(format!("max diff {d}"))
        }
    });
}

#[test]
fn prop_winograd_dataflow_equals_standard_deconv() {
    // the paper's headline equivalence, through the full line-buffered
    // architecture simulation (only K_C <= 3 classes are Winograd-able)
    forall(
        "winograd dataflow == standard",
        32,
        0xF00D,
        |rng| loop {
            let c = gen_case(rng);
            if tdc::kc(c.w.kh, c.s) <= 3 {
                return c;
            }
        },
        |c| {
            let want = tdc::deconv_naive(&c.x, &c.w, c.s, c.p);
            let got = run_winograd_deconv(&c.x, &c.w, c.s, c.p);
            let d = want.max_abs_diff(&got.y);
            if d < 1e-9 {
                Ok(())
            } else {
                Err(format!("max diff {d} for K={} S={}", c.w.kh, c.s))
            }
        },
    );
}

#[test]
fn prop_sparse_engine_work_matches_structural_zero_count() {
    forall(
        "skipped mults == structural zeros",
        32,
        0x5EED,
        |rng| loop {
            let c = gen_case(rng);
            // tile-aligned so the analytic count is exact
            if tdc::kc(c.w.kh, c.s) <= 3 && c.x.h % 2 == 0 && c.x.w % 2 == 0 {
                return c;
            }
        },
        |c| {
            let win = run_winograd_deconv(&c.x, &c.w, c.s, c.p);
            let l = Layer {
                kind: Kind::Deconv,
                c_in: c.x.c,
                c_out: c.w.c_out,
                k: c.w.kh,
                s: c.s,
                p: c.p,
                h_in: c.x.h,
                w_in: c.x.w,
            };
            let want = layer_mults(&l, Method::Winograd);
            if win.events.mults == want {
                Ok(())
            } else {
                Err(format!("measured {} != analytic {}", win.events.mults, want))
            }
        },
    );
}

#[test]
fn prop_tdc_dataflow_equals_standard() {
    forall("tdc dataflow == standard", 32, 0xCAFE, gen_case, |c| {
        let want = tdc::deconv_naive(&c.x, &c.w, c.s, c.p);
        let got = run_tdc_deconv(&c.x, &c.w, c.s, c.p);
        let d = want.max_abs_diff(&got.y);
        if d < 1e-10 {
            Ok(())
        } else {
            Err(format!("max diff {d}"))
        }
    });
}

#[test]
fn prop_winograd_transform_linearity() {
    // G (a f + b g) G^T == a GfG^T + b GgG^T — the transform is linear, so
    // transformed-weight reuse across channel tiles is sound
    forall(
        "filter transform linear",
        64,
        0x11EA,
        |rng| {
            let mut f = [[0.0; 3]; 3];
            let mut g = [[0.0; 3]; 3];
            for i in 0..3 {
                for j in 0..3 {
                    f[i][j] = rng.normal();
                    g[i][j] = rng.normal();
                }
            }
            (f, g, rng.normal(), rng.normal())
        },
        |&(f, g, a, b)| {
            let mut fg = [[0.0; 3]; 3];
            for i in 0..3 {
                for j in 0..3 {
                    fg[i][j] = a * f[i][j] + b * g[i][j];
                }
            }
            let lhs = winograd::transforms::filter_transform(&fg);
            let uf = winograd::transforms::filter_transform(&f);
            let ug = winograd::transforms::filter_transform(&g);
            for i in 0..4 {
                for j in 0..4 {
                    let rhs = a * uf[i][j] + b * ug[i][j];
                    if (lhs[i][j] - rhs).abs() > 1e-9 {
                        return Err(format!("nonlinear at ({i},{j})"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_cycle_model_monotone_in_workload() {
    forall(
        "cycle time monotone in channels",
        32,
        0x7135,
        |rng| {
            let (k, s) = [(5usize, 2usize), (4, 2), (3, 1)][rng.below(3)];
            Layer {
                kind: Kind::Deconv,
                c_in: rng.int_in(8, 256),
                c_out: rng.int_in(8, 256),
                k,
                s,
                p: tdc::default_padding(k, s),
                h_in: rng.int_in(4, 32),
                w_in: rng.int_in(4, 32),
            }
        },
        |l| {
            let cfg = AccelConfig::default();
            for m in Method::ALL {
                let base = simulate_layer(l, m, &cfg).t_total;
                let mut big = *l;
                big.c_in *= 2;
                let t2 = simulate_layer(&big, m, &cfg).t_total;
                if t2 < base {
                    return Err(format!("{m:?}: doubling C_in reduced time"));
                }
                let mut wide = *l;
                wide.w_in *= 2;
                let t3 = simulate_layer(&wide, m, &cfg).t_total;
                if t3 < base {
                    return Err(format!("{m:?}: doubling W reduced time"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_cycle_model_never_beats_both_bounds() {
    // wall-clock >= max(compute-only, transfer-only) per layer
    forall(
        "t_total >= max(T_C, T_D)",
        32,
        0xB0047,
        |rng| {
            let (k, s) = [(5usize, 2usize), (4, 2), (3, 1)][rng.below(3)];
            Layer {
                kind: Kind::Deconv,
                c_in: rng.int_in(8, 512),
                c_out: rng.int_in(8, 512),
                k,
                s,
                p: tdc::default_padding(k, s),
                h_in: rng.int_in(4, 64),
                w_in: rng.int_in(4, 64),
            }
        },
        |l| {
            let cfg = AccelConfig::default();
            for m in Method::ALL {
                let sim = simulate_layer(l, m, &cfg);
                let bound = sim.t_compute.max(sim.t_transfer);
                if sim.t_total + 1e-12 < bound {
                    return Err(format!(
                        "{m:?}: total {} < max(compute {}, transfer {})",
                        sim.t_total, sim.t_compute, sim.t_transfer
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_batcher_conserves_requests_in_fifo_order() {
    forall(
        "batcher conservation + FIFO",
        48,
        0xBA7C4,
        |rng| {
            let n = rng.int_in(1, 64);
            let buckets = match rng.below(3) {
                0 => vec![1, 4, 8],
                1 => vec![2, 16],
                _ => vec![1],
            };
            (n, buckets)
        },
        |(n, buckets)| {
            let mut b = DynamicBatcher::new(BatchPolicy::new(
                buckets.clone(),
                Duration::from_millis(1),
            ));
            let t = Instant::now();
            let mut out = Vec::new();
            for i in 0..*n as u64 {
                b.push(GenRequest {
                    id: i,
                    model: "m".into(),
                    method: "w".into(),
                    input: Vec::new(),
                    enqueued: t,
                });
                while let Some(batch) = b.poll(t) {
                    if batch.requests.len() > batch.bucket {
                        return Err("batch exceeds bucket".into());
                    }
                    out.extend(batch.requests.iter().map(|r| r.id));
                }
            }
            while let Some(batch) = b.flush() {
                out.extend(batch.requests.iter().map(|r| r.id));
            }
            if out != (0..*n as u64).collect::<Vec<_>>() {
                return Err(format!("ids out of order or lost: {out:?}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_json_roundtrip() {
    use wingan::util::json::{self, Json};
    forall(
        "json roundtrip",
        64,
        0x15031,
        |rng| gen_json(rng, 3),
        |v| {
            let text = json::to_string_pretty(v);
            match json::parse(&text) {
                Ok(back) if &back == v => Ok(()),
                Ok(back) => Err(format!("roundtrip changed value: {back:?}")),
                Err(e) => Err(format!("reparse failed: {e}")),
            }
        },
    );

    fn gen_json(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.below(2) == 0),
            2 => Json::Num((rng.normal() * 100.0).round()),
            3 => Json::Str(format!("s{}-\"quoted\"\n", rng.below(1000))),
            4 => Json::Arr((0..rng.below(4)).map(|_| gen_json(rng, depth - 1)).collect()),
            _ => Json::Obj(
                (0..rng.below(4))
                    .map(|i| (format!("k{i}"), gen_json(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
}
