//! Property-based tests over the algorithmic substrates (own `prop`
//! harness; see rust/src/prop.rs). These pin the paper's core claims on
//! randomized inputs:
//!   * TDC DeConv == standard DeConv (Fig. 2)
//!   * zero-padded DeConv == standard DeConv (Fig. 1b)
//!   * the Winograd dataflow through line buffers == standard DeConv
//!   * sparse engine's skipped work == the structural zero count
//!   * the cycle model's invariants (monotonicity, bandwidth-boundedness)
//!   * batcher conservation (no loss, no dup, FIFO) — for both the bucket
//!     baseline and the continuous scheduler under arbitrary
//!     admit/poll/observe interleavings with typed sheds

use std::sync::Arc;
use std::time::{Duration, Instant};
use wingan::accel::functional::{run_tdc_deconv, run_winograd_deconv};
use wingan::accel::{simulate_layer, AccelConfig};
use wingan::coordinator::batcher::{BatchPolicy, ContinuousBatcher, DynamicBatcher};
use wingan::coordinator::request::{GenRequest, Rejected};
use wingan::engine::{self, Engine, ModelPlan, PlanOptions, Planner, Select};
use wingan::gan::workload::{layer_mults, Method};
use wingan::gan::zoo::{self, Activation, Gan, Kind, Layer, Scale};
use wingan::prop::forall;
use wingan::tdc;
use wingan::util::prng::Rng;
use wingan::util::tensor::{Filter4, Tensor3};
use wingan::winograd;
use wingan::winograd::kernel::{multiply_batch, KernelKind, RunList};
use wingan::winograd::layout::{
    engine_multiply, engine_multiply_batch, reorder_filter, reorder_input_tile,
};

/// Random deconv problem drawn from the paper's kernel classes plus a few
/// off-paper (K, S) combos that still satisfy the TDC offset bound.
#[derive(Debug)]
struct DeconvCase {
    x: Tensor3,
    w: Filter4,
    s: usize,
    p: usize,
}

fn gen_case(rng: &mut Rng) -> DeconvCase {
    let configs = [(5usize, 2usize), (4, 2), (3, 1), (6, 3), (2, 2), (6, 2)];
    let (k, s) = configs[rng.below(configs.len())];
    let p = tdc::default_padding(k, s);
    let c_in = rng.int_in(1, 4);
    let c_out = rng.int_in(1, 3);
    let h = rng.int_in(1, 7);
    let w = rng.int_in(1, 7);
    DeconvCase {
        x: Tensor3::from_vec(c_in, h, w, rng.normal_vec(c_in * h * w)),
        w: Filter4::from_vec(c_in, c_out, k, k, rng.normal_vec(c_in * c_out * k * k)),
        s,
        p,
    }
}

#[test]
fn prop_tdc_equals_standard_deconv() {
    forall("tdc == standard", 48, 0xA11CE, gen_case, |c| {
        let want = tdc::deconv_naive(&c.x, &c.w, c.s, c.p);
        let got = tdc::tdc_deconv(&c.x, &c.w, c.s, c.p);
        let d = want.max_abs_diff(&got);
        if d < 1e-10 {
            Ok(())
        } else {
            Err(format!("max diff {d} for K={} S={}", c.w.kh, c.s))
        }
    });
}

#[test]
fn prop_zero_padded_equals_standard_deconv() {
    forall("zero-padded == standard", 48, 0xB0B, gen_case, |c| {
        let want = tdc::deconv_naive(&c.x, &c.w, c.s, c.p);
        let got = tdc::zero_padded_deconv(&c.x, &c.w, c.s, c.p);
        let d = want.max_abs_diff(&got);
        if d < 1e-10 {
            Ok(())
        } else {
            Err(format!("max diff {d}"))
        }
    });
}

#[test]
fn prop_winograd_dataflow_equals_standard_deconv() {
    // the paper's headline equivalence, through the full line-buffered
    // architecture simulation (only K_C <= 3 classes are Winograd-able)
    forall(
        "winograd dataflow == standard",
        32,
        0xF00D,
        |rng| loop {
            let c = gen_case(rng);
            if tdc::kc(c.w.kh, c.s) <= 3 {
                return c;
            }
        },
        |c| {
            let want = tdc::deconv_naive(&c.x, &c.w, c.s, c.p);
            let got = run_winograd_deconv(&c.x, &c.w, c.s, c.p);
            let d = want.max_abs_diff(&got.y);
            if d < 1e-9 {
                Ok(())
            } else {
                Err(format!("max diff {d} for K={} S={}", c.w.kh, c.s))
            }
        },
    );
}

#[test]
fn prop_sparse_engine_work_matches_structural_zero_count() {
    forall(
        "skipped mults == structural zeros",
        32,
        0x5EED,
        |rng| loop {
            let c = gen_case(rng);
            // tile-aligned so the analytic count is exact
            if tdc::kc(c.w.kh, c.s) <= 3 && c.x.h % 2 == 0 && c.x.w % 2 == 0 {
                return c;
            }
        },
        |c| {
            let win = run_winograd_deconv(&c.x, &c.w, c.s, c.p);
            let l = Layer {
                kind: Kind::Deconv,
                c_in: c.x.c,
                c_out: c.w.c_out,
                k: c.w.kh,
                s: c.s,
                p: c.p,
                h_in: c.x.h,
                w_in: c.x.w,
                act: Activation::Linear,
            };
            let want = layer_mults(&l, Method::Winograd);
            if win.events.mults == want {
                Ok(())
            } else {
                Err(format!("measured {} != analytic {}", win.events.mults, want))
            }
        },
    );
}

/// Random one-stripe batched-GEMM problem: a Winograd-able kernel class, a
/// strip of `tiles` horizontally adjacent 4x4 windows, random channels.
#[derive(Debug)]
struct StripeCase {
    x: Tensor3,
    w: Filter4,
    s: usize,
    p: usize,
    tiles: usize,
}

fn gen_stripe_case(rng: &mut Rng) -> StripeCase {
    // every Winograd-able (K_C <= 3) class of the zoo plus off-paper combos
    let configs = [(5usize, 2usize), (4, 2), (3, 1), (2, 2)];
    let (k, s) = configs[rng.below(configs.len())];
    let p = tdc::default_padding(k, s);
    let c_in = rng.int_in(1, 5);
    let c_out = rng.int_in(1, 4);
    let tiles = rng.int_in(1, 6);
    let wpix = 2 * tiles + 2; // m*tiles + (n - m) columns: `tiles` windows
    StripeCase {
        x: Tensor3::from_vec(c_in, 4, wpix, rng.normal_vec(c_in * 4 * wpix)),
        w: Filter4::from_vec(c_in, c_out, k, k, rng.normal_vec(c_in * c_out * k * k)),
        s,
        p,
        tiles,
    }
}

#[test]
fn prop_batched_gemm_bitwise_equals_per_tile_multiply() {
    // the PR-3 kernel contract: for every phase of every kernel class, the
    // stripe-batched GEMM must reproduce the per-tile com-PE multiply bit
    // for bit at every (tile, position, channel), and issue exactly the
    // same multiplication count
    forall("batched GEMM == per-tile com-PE, bitwise", 48, 0x6E44, gen_stripe_case, |c| {
        let (c_in, c_out) = (c.x.c, c.w.c_out);
        for ph in &tdc::decompose(&c.w, c.s, c.p) {
            let rf = reorder_filter(ph);
            // gather the stripe into the position-major [pos][ci][tiles]
            // layout the engine's pre-PE builds
            let mut v = vec![0.0; 16 * c_in * c.tiles];
            for tx in 0..c.tiles {
                let vt = reorder_input_tile(&c.x, 0, tx);
                for pos in 0..16 {
                    for ci in 0..c_in {
                        v[(pos * c_in + ci) * c.tiles + tx] = vt.at(pos, ci);
                    }
                }
            }
            let mut m = vec![1.0; c_out * 16 * c.tiles]; // dirty: kernel must zero it
            let mults = engine_multiply_batch(&rf, &v, c.tiles, &mut m);
            let mut want_mults = 0;
            for tx in 0..c.tiles {
                let vt = reorder_input_tile(&c.x, 0, tx);
                let (m_acc, per_tile) = engine_multiply(&rf, &vt);
                want_mults += per_tile;
                for co in 0..c_out {
                    for pos in 0..16 {
                        let got = m[(co * 16 + pos) * c.tiles + tx];
                        let want = m_acc[co][pos / 4][pos % 4];
                        if got != want {
                            return Err(format!(
                                "case {:?} tile {tx} pos {pos} co {co}: {got} != {want}",
                                rf.case
                            ));
                        }
                    }
                }
            }
            if mults != want_mults {
                return Err(format!("mults {mults} != per-tile total {want_mults}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_winograd_engine_bitwise_equals_per_tile_dataflow() {
    // the PR-3 datapath contract: the stripe-batched engine must equal the
    // per-tile functional dataflow bit for bit — outputs *and* every
    // Events counter — at every worker count, including ragged last
    // stripes (odd H/W force tile padding; workers > stripes force
    // short chunks)
    forall(
        "stripe-batched engine == per-tile dataflow, bitwise + events",
        16,
        0x57121E,
        |rng| loop {
            let c = gen_case(rng);
            if tdc::kc(c.w.kh, c.s) <= 3 {
                return c;
            }
        },
        |c| {
            let l = Layer {
                kind: Kind::Deconv,
                c_in: c.x.c,
                c_out: c.w.c_out,
                k: c.w.kh,
                s: c.s,
                p: c.p,
                h_in: c.x.h,
                w_in: c.x.w,
                act: Activation::Linear,
            };
            let planner = Planner::new(PlanOptions {
                select: Select::Force(Method::Winograd),
                ..Default::default()
            });
            let lp = planner.compile_layer(&l, c.w.clone());
            if lp.method != Method::Winograd {
                return Err("expected a winograd-method plan".into());
            }
            let plan = Arc::new(ModelPlan {
                model: "prop-stripe".into(),
                input_shape: (c.x.c, c.x.h, c.x.w),
                output_shape: (c.w.c_out, c.s * c.x.h, c.s * c.x.w),
                layers: vec![lp],
            });
            let func = run_winograd_deconv(&c.x, &c.w, c.s, c.p);
            for workers in [1usize, 2, 5] {
                let run = Engine::with_workers(plan.clone(), workers).run(&c.x);
                let d = run.y.max_abs_diff(&func.y);
                if d != 0.0 {
                    return Err(format!("workers={workers}: diff {d} (must be bitwise 0)"));
                }
                if run.events != func.events {
                    return Err(format!(
                        "workers={workers}: events {:?} != per-tile {:?}",
                        run.events, func.events
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_tdc_dataflow_equals_standard() {
    forall("tdc dataflow == standard", 32, 0xCAFE, gen_case, |c| {
        let want = tdc::deconv_naive(&c.x, &c.w, c.s, c.p);
        let got = run_tdc_deconv(&c.x, &c.w, c.s, c.p);
        let d = want.max_abs_diff(&got.y);
        if d < 1e-10 {
            Ok(())
        } else {
            Err(format!("max diff {d}"))
        }
    });
}

#[test]
fn prop_winograd_transform_linearity() {
    // G (a f + b g) G^T == a GfG^T + b GgG^T — the transform is linear, so
    // transformed-weight reuse across channel tiles is sound
    forall(
        "filter transform linear",
        64,
        0x11EA,
        |rng| {
            let mut f = [[0.0; 3]; 3];
            let mut g = [[0.0; 3]; 3];
            for i in 0..3 {
                for j in 0..3 {
                    f[i][j] = rng.normal();
                    g[i][j] = rng.normal();
                }
            }
            (f, g, rng.normal(), rng.normal())
        },
        |&(f, g, a, b)| {
            let mut fg = [[0.0; 3]; 3];
            for i in 0..3 {
                for j in 0..3 {
                    fg[i][j] = a * f[i][j] + b * g[i][j];
                }
            }
            let lhs = winograd::transforms::filter_transform(&fg);
            let uf = winograd::transforms::filter_transform(&f);
            let ug = winograd::transforms::filter_transform(&g);
            for i in 0..4 {
                for j in 0..4 {
                    let rhs = a * uf[i][j] + b * ug[i][j];
                    if (lhs[i][j] - rhs).abs() > 1e-9 {
                        return Err(format!("nonlinear at ({i},{j})"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_cycle_model_monotone_in_workload() {
    forall(
        "cycle time monotone in channels",
        32,
        0x7135,
        |rng| {
            let (k, s) = [(5usize, 2usize), (4, 2), (3, 1)][rng.below(3)];
            Layer {
                kind: Kind::Deconv,
                c_in: rng.int_in(8, 256),
                c_out: rng.int_in(8, 256),
                k,
                s,
                p: tdc::default_padding(k, s),
                h_in: rng.int_in(4, 32),
                w_in: rng.int_in(4, 32),
                act: Activation::Linear,
            }
        },
        |l| {
            let cfg = AccelConfig::default();
            for m in Method::ALL {
                let base = simulate_layer(l, m, &cfg).t_total;
                let mut big = *l;
                big.c_in *= 2;
                let t2 = simulate_layer(&big, m, &cfg).t_total;
                if t2 < base {
                    return Err(format!("{m:?}: doubling C_in reduced time"));
                }
                let mut wide = *l;
                wide.w_in *= 2;
                let t3 = simulate_layer(&wide, m, &cfg).t_total;
                if t3 < base {
                    return Err(format!("{m:?}: doubling W reduced time"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_cycle_model_never_beats_both_bounds() {
    // wall-clock >= max(compute-only, transfer-only) per layer
    forall(
        "t_total >= max(T_C, T_D)",
        32,
        0xB0047,
        |rng| {
            let (k, s) = [(5usize, 2usize), (4, 2), (3, 1)][rng.below(3)];
            Layer {
                kind: Kind::Deconv,
                c_in: rng.int_in(8, 512),
                c_out: rng.int_in(8, 512),
                k,
                s,
                p: tdc::default_padding(k, s),
                h_in: rng.int_in(4, 64),
                w_in: rng.int_in(4, 64),
                act: Activation::Linear,
            }
        },
        |l| {
            let cfg = AccelConfig::default();
            for m in Method::ALL {
                let sim = simulate_layer(l, m, &cfg);
                let bound = sim.t_compute.max(sim.t_transfer);
                if sim.t_total + 1e-12 < bound {
                    return Err(format!(
                        "{m:?}: total {} < max(compute {}, transfer {})",
                        sim.t_total, sim.t_compute, sim.t_transfer
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_batcher_conserves_requests_in_fifo_order() {
    forall(
        "batcher conservation + FIFO",
        48,
        0xBA7C4,
        |rng| {
            let n = rng.int_in(1, 64);
            let buckets = match rng.below(3) {
                0 => vec![1, 4, 8],
                1 => vec![2, 16],
                _ => vec![1],
            };
            (n, buckets)
        },
        |(n, buckets)| {
            let mut b = DynamicBatcher::new(BatchPolicy::new(
                buckets.clone(),
                Duration::from_millis(1),
            ));
            let t = Instant::now();
            let mut out = Vec::new();
            for i in 0..*n as u64 {
                b.push(GenRequest {
                    id: i,
                    model: "m".into(),
                    method: "w".into(),
                    input: Vec::new(),
                    enqueued: t,
                    deadline: None,
                    trace: 0,
                });
                while let Some(batch) = b.poll(t) {
                    if batch.requests.len() > batch.bucket {
                        return Err("batch exceeds bucket".into());
                    }
                    out.extend(batch.requests.iter().map(|r| r.id));
                }
            }
            while let Some(batch) = b.flush() {
                out.extend(batch.requests.iter().map(|r| r.id));
            }
            if out != (0..*n as u64).collect::<Vec<_>>() {
                return Err(format!("ids out of order or lost: {out:?}"));
            }
            Ok(())
        },
    );
}

/// One scripted step against a per-route set of continuous batchers.
#[derive(Debug, Clone)]
enum ContOp {
    /// submit a request to `route` with an optional SLO budget (ms)
    Admit { route: usize, budget_ms: Option<u64> },
    /// engine polls `route` for a dispatch
    Poll { route: usize },
    /// engine reports a batch service time for `route`
    Observe { route: usize, service_ms: u64 },
}

/// A randomized continuous-batching scenario: shared policy knobs plus a
/// time-stamped op script (offsets in ms from a mock epoch, monotone).
#[derive(Debug)]
struct ContCase {
    buckets: Vec<usize>,
    max_wait: Duration,
    queue_cap: usize,
    n_routes: usize,
    ops: Vec<(u64, ContOp)>,
}

fn gen_cont_case(rng: &mut Rng) -> ContCase {
    let buckets = match rng.below(3) {
        0 => vec![1, 4, 8],
        1 => vec![2, 16],
        _ => vec![1],
    };
    // all three hold regimes: work-conserving, finite window, never-partial
    let max_wait = match rng.below(3) {
        0 => Duration::ZERO,
        1 => Duration::from_millis(1),
        _ => Duration::MAX,
    };
    let queue_cap = rng.int_in(1, 6);
    let n_routes = rng.int_in(1, 3);
    let n_ops = rng.int_in(1, 96);
    let mut t = 0u64;
    let mut ops = Vec::with_capacity(n_ops);
    for _ in 0..n_ops {
        t += rng.below(3) as u64;
        let route = rng.below(n_routes);
        let op = match rng.below(8) {
            0 => ContOp::Poll { route },
            1 => ContOp::Observe { route, service_ms: rng.int_in(1, 20) as u64 },
            // admit-heavy mix so small queue_caps actually overflow; a
            // 0ms budget is an already-expired deadline at admission
            _ => ContOp::Admit {
                route,
                budget_ms: if rng.below(2) == 0 { Some(rng.below(10) as u64) } else { None },
            },
        };
        ops.push((t, op));
    }
    ContCase { buckets, max_wait, queue_cap, n_routes, ops }
}

#[test]
fn prop_continuous_batcher_conserves_requests() {
    forall("continuous batcher conservation", 64, 0xC0117, gen_cont_case, |case| {
        let t0 = Instant::now();
        let at = |ms: u64| t0 + Duration::from_millis(ms);
        let mut batchers: Vec<ContinuousBatcher> = (0..case.n_routes)
            .map(|_| {
                ContinuousBatcher::new(
                    BatchPolicy::new(case.buckets.clone(), case.max_wait),
                    case.queue_cap,
                )
            })
            .collect();
        // per-route FIFO of admitted-but-undecided ids, and the single
        // recorded outcome per issued id
        let mut pending: Vec<Vec<u64>> = vec![Vec::new(); case.n_routes];
        let mut outcome: std::collections::BTreeMap<u64, &'static str> =
            std::collections::BTreeMap::new();
        let mut next_id = 0u64;
        let mut decide = |id: u64, what: &'static str| -> Result<(), String> {
            match outcome.insert(id, what) {
                None => Ok(()),
                Some(prev) => Err(format!("request {id} decided twice: {prev} then {what}")),
            }
        };

        let width = *case.buckets.last().unwrap();
        let consume = |route: usize,
                           pending: &mut Vec<Vec<u64>>,
                           batch: &wingan::coordinator::ReadyBatch,
                           decide: &mut dyn FnMut(u64, &'static str) -> Result<(), String>,
                           what: &'static str|
         -> Result<(), String> {
            if batch.requests.is_empty() || batch.requests.len() > batch.bucket {
                return Err(format!(
                    "illegal batch: {} requests in bucket {}",
                    batch.requests.len(),
                    batch.bucket
                ));
            }
            if !case.buckets.contains(&batch.bucket) || batch.requests.len() > width {
                return Err(format!("unadvertised shape: bucket {}", batch.bucket));
            }
            let model = format!("route{route}");
            if batch.requests.iter().any(|r| r.model != model) {
                return Err(format!("route mixing in a {model} batch"));
            }
            let ids: Vec<u64> = batch.requests.iter().map(|r| r.id).collect();
            if pending[route].len() < ids.len() || pending[route][..ids.len()] != ids[..] {
                return Err(format!(
                    "batch {ids:?} is not the FIFO prefix of pending {:?}",
                    pending[route]
                ));
            }
            pending[route].drain(..ids.len());
            for id in ids {
                decide(id, what)?;
            }
            Ok(())
        };

        for (ms, op) in &case.ops {
            let now = *ms;
            match op {
                ContOp::Admit { route, budget_ms } => {
                    let id = next_id;
                    next_id += 1;
                    let req = GenRequest {
                        id,
                        model: format!("route{route}"),
                        method: "w".into(),
                        input: Vec::new(),
                        enqueued: at(now),
                        deadline: budget_ms.map(|b| at(now + b)),
                        trace: 0,
                    };
                    match batchers[*route].admit(req, at(now)) {
                        Ok(()) => pending[*route].push(id),
                        Err((back, rej)) => {
                            if back.id != id {
                                return Err(format!(
                                    "rejection returned request {} for submit {id}",
                                    back.id
                                ));
                            }
                            match rej {
                                Rejected::QueueFull { depth, cap } => {
                                    if cap != case.queue_cap || depth < cap {
                                        return Err(format!(
                                            "queue-full shed below capacity: {depth}/{cap}"
                                        ));
                                    }
                                }
                                Rejected::DeadlineInfeasible { .. } => {
                                    if budget_ms.is_none() {
                                        return Err(format!(
                                            "best-effort request {id} deadline-shed"
                                        ));
                                    }
                                }
                            }
                            decide(id, "rejected")?;
                        }
                    }
                }
                ContOp::Poll { route } => {
                    let d = batchers[*route].poll(at(now));
                    for (r, rej) in &d.shed {
                        if !matches!(rej, Rejected::DeadlineInfeasible { .. }) {
                            return Err(format!("dispatch shed with verdict {rej:?}"));
                        }
                        match pending[*route].iter().position(|&id| id == r.id) {
                            Some(i) => {
                                pending[*route].remove(i);
                            }
                            None => return Err(format!("shed unknown request {}", r.id)),
                        }
                        decide(r.id, "shed")?;
                    }
                    if let Some(batch) = &d.batch {
                        consume(*route, &mut pending, batch, &mut decide, "batched")?;
                    }
                }
                ContOp::Observe { route, service_ms } => {
                    batchers[*route].observe(Duration::from_millis(*service_ms));
                }
            }
        }

        // stream end: flush drains every admitted survivor, FIFO, no sheds
        for route in 0..case.n_routes {
            while let Some(batch) = batchers[route].flush() {
                consume(route, &mut pending, &batch, &mut decide, "flushed")?;
            }
            if !pending[route].is_empty() {
                return Err(format!("route{route} lost requests: {:?}", pending[route]));
            }
            if batchers[route].queued() != 0 {
                return Err(format!("route{route} still holds work after flush"));
            }
        }
        // exactly-once: every issued id has exactly one recorded fate
        if outcome.len() as u64 != next_id {
            let missing: Vec<u64> =
                (0..next_id).filter(|id| !outcome.contains_key(id)).collect();
            return Err(format!("requests with no fate: {missing:?}"));
        }
        Ok(())
    });
}

/// Random mini-generator: 1-3 chained deconv layers drawn from the paper's
/// kernel classes, with random channel widths and a random input tensor.
#[derive(Debug)]
struct ModelCase {
    gan: Gan,
    weights: Vec<Filter4>,
    x: Tensor3,
}

fn gen_model_case(rng: &mut Rng) -> ModelCase {
    let n_layers = rng.int_in(1, 3);
    let mut layers = Vec::new();
    let mut c = rng.int_in(1, 4);
    let mut h = rng.int_in(1, 4);
    let c0 = c;
    let h0 = h;
    for li in 0..n_layers {
        let (k, s) = [(5usize, 2usize), (4, 2), (3, 1)][rng.below(3)];
        let c_next = rng.int_in(1, 4);
        // random activations on the hand-off path (zoo-style: relu-ish
        // hidden layers, tanh-able output layer) — every engine contract
        // must hold with them in the chain
        let act = if li + 1 == n_layers {
            [Activation::Linear, Activation::Tanh][rng.below(2)]
        } else {
            [Activation::Linear, Activation::Relu, Activation::LeakyRelu][rng.below(3)]
        };
        layers.push(Layer::deconv(c, c_next, k, s, h).with_act(act));
        c = c_next;
        h *= s;
    }
    let gan = Gan { name: "prop-mini", year: 2026, layers };
    let weights = gan
        .layers
        .iter()
        .map(|l| {
            Filter4::from_vec(
                l.c_in,
                l.c_out,
                l.k,
                l.k,
                rng.normal_vec(l.c_in * l.c_out * l.k * l.k),
            )
        })
        .collect();
    let x = Tensor3::from_vec(c0, h0, h0, rng.normal_vec(c0 * h0 * h0));
    ModelCase { gan, weights, x }
}

#[test]
fn prop_engine_tdc_plans_bit_identical_to_composed_reference() {
    // the tentpole numerics contract: whole-model execution through
    // precompiled TDC plans reproduces the layer-composed standard-DeConv
    // reference bit for bit, for any worker count
    forall(
        "engine(Tdc) == composed reference, bitwise",
        24,
        0xE7617E,
        gen_model_case,
        |c| {
            let planner = Planner::new(PlanOptions {
                select: Select::Force(Method::Tdc),
                ..Default::default()
            });
            // one compiled plan, shared across worker counts via Arc
            let plan = Arc::new(planner.compile(&c.gan, c.weights.clone()));
            let want = engine::reference_forward(&plan, &c.x);
            for workers in [1usize, 3] {
                let run = Engine::with_workers(plan.clone(), workers).run(&c.x);
                let d = run.y.max_abs_diff(&want);
                if d != 0.0 {
                    return Err(format!("workers={workers}: max diff {d} (must be 0.0)"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_engine_auto_plans_match_reference_within_rounding() {
    // Winograd-method plans change the arithmetic (that's the point); the
    // result must still agree with the reference to f64 rounding, and be
    // bitwise stable across worker counts
    forall(
        "engine(Auto) ~= composed reference",
        16,
        0xFA57,
        gen_model_case,
        |c| {
            let plan = Arc::new(Planner::default().compile(&c.gan, c.weights.clone()));
            let want = engine::reference_forward(&plan, &c.x);
            let r1 = Engine::with_workers(plan.clone(), 1).run(&c.x);
            let r3 = Engine::with_workers(plan.clone(), 3).run(&c.x);
            if r1.y.max_abs_diff(&r3.y) != 0.0 {
                return Err("worker count changed the bits".into());
            }
            let scale = want.data.iter().fold(1.0f64, |m, v| m.max(v.abs()));
            let rel = r1.y.max_abs_diff(&want) / scale;
            if rel < 1e-9 {
                Ok(())
            } else {
                Err(format!("relative diff {rel}"))
            }
        },
    );
}

#[test]
fn prop_engine_events_sum_per_layer() {
    // aggregate events must equal the per-layer sum (no work lost or
    // double-counted by the worker pool)
    forall("engine events add up", 16, 0xAD0, gen_model_case, |c| {
        let run = Engine::with_workers(
            Planner::default().compile(&c.gan, c.weights.clone()),
            2,
        )
        .run(&c.x);
        let mut sum = wingan::accel::functional::Events::default();
        for e in &run.per_layer {
            sum.merge(e);
        }
        if sum == run.events && run.events.mults > 0 {
            Ok(())
        } else {
            Err(format!("per-layer {sum:?} != total {:?}", run.events))
        }
    });
}

#[test]
fn engine_pinned_to_reference_on_all_four_zoo_generators() {
    // the acceptance pin: every Table-I generator, whole-model, through the
    // engine — TDC plans bitwise-equal to the composed reference, Auto
    // (Winograd fast path) equal to rounding
    let mut rng = Rng::new(0x200);
    for g in zoo::all(Scale::Tiny) {
        let exact_planner = Planner::new(PlanOptions {
            select: Select::Force(Method::Tdc),
            ..Default::default()
        });
        let exact_plan = exact_planner.compile_seeded(&g, 17);
        let (c, h, w) = exact_plan.input_shape;
        let x = Tensor3::from_vec(c, h, w, rng.normal_vec(c * h * w));
        let want = engine::reference_forward(&exact_plan, &x);

        let run = Engine::with_workers(exact_plan.clone(), 2).run(&x);
        assert_eq!(
            run.y.max_abs_diff(&want),
            0.0,
            "{}: TDC plan must be bit-identical to the composed reference",
            g.name
        );

        let auto_plan = Planner::default().compile_seeded(&g, 17);
        assert!(auto_plan.n_winograd_layers() > 0, "{}", g.name);
        let fast = Engine::with_workers(auto_plan, 2).run(&x);
        let scale = want.data.iter().fold(1.0f64, |m, v| m.max(v.abs()));
        let rel = fast.y.max_abs_diff(&want) / scale;
        assert!(rel < 1e-9, "{}: Winograd whole-model relative diff {rel}", g.name);
        // the fast path must actually skip work: fewer multiplies than TDC
        assert!(
            fast.events.mults < run.events.mults,
            "{}: winograd {} vs tdc {} multiplies",
            g.name,
            fast.events.mults,
            run.events.mults
        );
    }
}

/// PR-4 precision-tier contract, randomized: an f32-lowered plan tracks
/// the f64 reference within single-precision accumulation error, at every
/// worker count, with identical Events — and stays bitwise worker-count
/// invariant like the f64 tier.
#[test]
fn prop_f32_plans_track_f64_reference_and_are_worker_invariant() {
    forall(
        "f32 plan ~= f64 reference, bitwise across workers",
        16,
        0xF3270,
        gen_model_case,
        |c| {
            let plan64 = Arc::new(Planner::default().compile(&c.gan, c.weights.clone()));
            let plan32 = Arc::new(plan64.lower::<f32>());
            let want = engine::reference_forward(&plan64, &c.x);
            let x32: Tensor3<f32> = c.x.cast_to();
            let r64 = Engine::with_workers(plan64.clone(), 2).run(&c.x);
            let r1 = Engine::with_workers(plan32.clone(), 1).run(&x32);
            let r3 = Engine::with_workers(plan32.clone(), 3).run(&x32);
            if r1.y.max_abs_diff(&r3.y) != 0.0 {
                return Err("f32 worker count changed the bits".into());
            }
            if r1.events != r3.events || r1.events != r64.events {
                return Err(format!(
                    "events must be precision/worker independent: {:?} vs {:?} vs {:?}",
                    r1.events, r3.events, r64.events
                ));
            }
            // f32 inputs/weights are the rounded f64 ones, so the output
            // error is bounded by accumulation noise: scale-relative 1e-4
            // is ~1000 ulps of headroom at these tiny channel counts
            let scale = want.data.iter().fold(1.0f64, |m, v| m.max(v.abs()));
            let rel = r1.y.cast_to::<f64>().max_abs_diff(&want) / scale;
            if rel < 1e-4 {
                Ok(())
            } else {
                Err(format!("f32 relative diff {rel}"))
            }
        },
    );
}

/// The blocked GEMM micro-kernel's bitwise contract at the f32 tier: for
/// every phase of every kernel class, the stripe-batched blocked kernel
/// reproduces the per-tile com-PE multiply bit for bit in f32 — the same
/// property `prop_batched_gemm_bitwise_equals_per_tile_multiply` pins in
/// f64 (the f32 operands are the casts of the f64 ones, so both tiers of
/// the kernel face identical inputs).
#[test]
fn prop_batched_gemm_bitwise_equals_per_tile_multiply_f32() {
    forall("blocked GEMM == per-tile com-PE, bitwise, f32", 32, 0x6E32, gen_stripe_case, |c| {
        let (c_in, c_out) = (c.x.c, c.w.c_out);
        let x32: Tensor3<f32> = c.x.cast_to();
        for ph in &tdc::decompose(&c.w, c.s, c.p) {
            let rf: wingan::winograd::layout::ReorderedFilter<f32> =
                reorder_filter(ph).cast_to();
            let mut v = vec![0.0f32; 16 * c_in * c.tiles];
            for tx in 0..c.tiles {
                let vt = reorder_input_tile(&x32, 0, tx);
                for pos in 0..16 {
                    for ci in 0..c_in {
                        v[(pos * c_in + ci) * c.tiles + tx] = vt.at(pos, ci);
                    }
                }
            }
            let mut m = vec![1.0f32; c_out * 16 * c.tiles]; // dirty: kernel must zero it
            let mults = engine_multiply_batch(&rf, &v, c.tiles, &mut m);
            let mut want_mults = 0;
            for tx in 0..c.tiles {
                let vt = reorder_input_tile(&x32, 0, tx);
                let (m_acc, per_tile) = engine_multiply(&rf, &vt);
                want_mults += per_tile;
                for co in 0..c_out {
                    for pos in 0..16 {
                        let got = m[(co * 16 + pos) * c.tiles + tx];
                        let want = m_acc[co][pos / 4][pos % 4];
                        if got != want {
                            return Err(format!(
                                "f32 case {:?} tile {tx} pos {pos} co {co}: {got} != {want}",
                                rf.case
                            ));
                        }
                    }
                }
            }
            if mults != want_mults {
                return Err(format!("mults {mults} != per-tile total {want_mults}"));
            }
        }
        Ok(())
    });
}

/// Full-zoo f32 pin: every Table-I generator served at the f32 tier is
/// bitwise invariant to worker count *and* batch schedule with identical
/// Events, and tracks the f64 reference within tolerance.
#[test]
fn f32_zoo_bitwise_schedule_invariant_and_within_tolerance() {
    let mut rng = Rng::new(0x320);
    for g in zoo::all(Scale::Tiny) {
        let plan64 = Arc::new(Planner::default().compile_seeded(&g, 17));
        let plan32 = Arc::new(plan64.lower::<f32>());
        let (c, h, w) = plan64.input_shape;
        let xs64: Vec<Tensor3> =
            (0..3).map(|_| Tensor3::from_vec(c, h, w, rng.normal_vec(c * h * w))).collect();
        let xs32: Vec<Tensor3<f32>> = xs64.iter().map(|x| x.cast_to()).collect();

        let e2 = Engine::with_workers(plan32.clone(), 2);
        let sample = e2.run_batch_with(&xs32, wingan::engine::BatchSchedule::SampleLevel);
        let stripe = e2.run_batch_with(&xs32, wingan::engine::BatchSchedule::StripeLevel);
        let e5 = Engine::with_workers(plan32.clone(), 5);
        let wide = e5.run_batch_with(&xs32, wingan::engine::BatchSchedule::StripeLevel);
        for i in 0..xs32.len() {
            assert_eq!(
                sample[i].y.max_abs_diff(&stripe[i].y),
                0.0,
                "{} sample {i}: f32 schedules must agree bit for bit",
                g.name
            );
            assert_eq!(
                stripe[i].y.max_abs_diff(&wide[i].y),
                0.0,
                "{} sample {i}: f32 worker counts must agree bit for bit",
                g.name
            );
            assert_eq!(sample[i].events, stripe[i].events, "{} sample {i}", g.name);
            assert_eq!(stripe[i].events, wide[i].events, "{} sample {i}", g.name);

            let want = engine::reference_forward(&plan64, &xs64[i]);
            let scale = want.data.iter().fold(1.0f64, |m, v| m.max(v.abs()));
            let rel = stripe[i].y.cast_to::<f64>().max_abs_diff(&want) / scale;
            assert!(rel < 1e-3, "{} sample {i}: f32 vs f64 reference rel {rel}", g.name);
        }
    }
}

/// Gather a one-row stripe of `tiles` adjacent 4x4 windows into the
/// position-major `[pos][c_in][tiles]` layout the engine's pre-PE builds.
fn gather_stripe<E: wingan::engine::Elem>(x: &Tensor3<E>, tiles: usize) -> Vec<E> {
    let c_in = x.c;
    let mut v = vec![E::ZERO; 16 * c_in * tiles];
    for tx in 0..tiles {
        let vt = reorder_input_tile(x, 0, tx);
        for pos in 0..16 {
            for ci in 0..c_in {
                v[(pos * c_in + ci) * tiles + tx] = vt.at(pos, ci);
            }
        }
    }
    v
}

/// PR-6 dispatch contract: for every phase of every kernel class, both
/// dispatched micro-kernels (blocked scalar and explicit SIMD — the SIMD
/// bodies accumulate mul-then-add in the same ascending-`c_in` order, no
/// FMA) reproduce the blocked reference GEMM **bit for bit**, at both
/// precision tiers, with the same issued-multiply count.
#[test]
fn prop_dispatched_kernels_bitwise_equal_blocked_reference() {
    forall("scalar/simd kernels == blocked reference, bitwise", 32, 0x51D3, gen_stripe_case, |c| {
        let c_out = c.w.c_out;
        let x32: Tensor3<f32> = c.x.cast_to();
        for ph in &tdc::decompose(&c.w, c.s, c.p) {
            let rf = reorder_filter(ph);
            let rf32: wingan::winograd::layout::ReorderedFilter<f32> = rf.cast_to();
            let v = gather_stripe(&c.x, c.tiles);
            let v32 = gather_stripe(&x32, c.tiles);
            let mut want = vec![1.0f64; c_out * 16 * c.tiles];
            let want_mults = engine_multiply_batch(&rf, &v, c.tiles, &mut want);
            let mut want32 = vec![1.0f32; c_out * 16 * c.tiles];
            let want_mults32 = engine_multiply_batch(&rf32, &v32, c.tiles, &mut want32);
            for kind in [KernelKind::Scalar, KernelKind::Simd] {
                let mut m = vec![1.0f64; c_out * 16 * c.tiles];
                let mults = multiply_batch(kind, &rf, &v, c.tiles, &mut m);
                if m != want {
                    return Err(format!("f64 {kind:?} case {:?}: bits differ", rf.case));
                }
                if mults != want_mults {
                    return Err(format!("f64 {kind:?}: mults {mults} != {want_mults}"));
                }
                let mut m32 = vec![1.0f32; c_out * 16 * c.tiles];
                let mults32 = multiply_batch(kind, &rf32, &v32, c.tiles, &mut m32);
                if m32 != want32 {
                    return Err(format!("f32 {kind:?} case {:?}: bits differ", rf.case));
                }
                if mults32 != want_mults32 {
                    return Err(format!("f32 {kind:?}: mults {mults32} != {want_mults32}"));
                }
            }
        }
        Ok(())
    });
}

/// PR-6 zero-skip contract: with dead `c_in` runs injected into the slab
/// weights and the run-list rebuilt, both dispatched kernels produce the
/// same values as the dense blocked reference over the same (zeroed)
/// weights, while issuing strictly fewer multiplies.
#[test]
fn prop_zero_skip_equals_dense_with_injected_runs() {
    forall("zero-skip == dense on injected dead runs", 32, 0x2E80, gen_stripe_case, |c| {
        let c_out = c.w.c_out;
        let v = gather_stripe(&c.x, c.tiles);
        for ph in &tdc::decompose(&c.w, c.s, c.p) {
            let mut rf = reorder_filter(ph);
            if rf.live.is_empty() {
                continue;
            }
            // kill a position-dependent c_in range across every c_out row,
            // so each position's register blocks get a dead run
            let (c_in, n_live) = (rf.c_in, rf.live.len());
            for pi in 0..n_live {
                let lo = pi % c_in;
                let hi = (lo + 1 + pi % 3).min(c_in);
                for co in 0..c_out {
                    for ci in lo..hi {
                        rf.u[(pi * c_out + co) * c_in + ci] = 0.0;
                    }
                }
            }
            rf.skip = RunList::build(n_live, c_out, c_in, &rf.u);
            if rf.skip.is_none() {
                return Err("injected runs must surface in the run-list".into());
            }
            let mut dense = vec![1.0f64; c_out * 16 * c.tiles];
            let dense_mults = engine_multiply_batch(&rf, &v, c.tiles, &mut dense);
            for kind in [KernelKind::Scalar, KernelKind::Simd] {
                let mut m = vec![1.0f64; c_out * 16 * c.tiles];
                let mults = multiply_batch(kind, &rf, &v, c.tiles, &mut m);
                if m != dense {
                    return Err(format!("{kind:?} case {:?}: skip changed values", rf.case));
                }
                if mults >= dense_mults {
                    return Err(format!(
                        "{kind:?}: skip issued {mults} >= dense {dense_mults}"
                    ));
                }
            }
        }
        Ok(())
    });
}

/// PR-6 degenerate-phase regression, end to end: a K=1 S=2 deconv layer
/// compiles three of its four phases to explicitly empty slabs (this used
/// to panic inside `phase_taps_1d` before any plan existed), executes
/// through the engine against the composed reference, and survives an
/// artifact round-trip bit for bit.
#[test]
fn degenerate_phase_plans_execute_and_roundtrip() {
    use wingan::artifact::{decode, encode, ArtifactMeta, PlanPayload};
    use wingan::winograd::sparsity::Case;

    let g = Gan {
        name: "degen-mini",
        year: 2026,
        layers: vec![
            Layer::deconv(3, 4, 1, 2, 4).with_act(Activation::Relu),
            Layer::deconv(4, 2, 3, 1, 8).with_act(Activation::Tanh),
        ],
    };
    let planner = Planner::new(PlanOptions {
        select: Select::Force(Method::Winograd),
        ..Default::default()
    });
    let plan = Arc::new(planner.compile_seeded(&g, 5));
    let empties = plan.layers[0]
        .reordered
        .iter()
        .filter(|rf| rf.case == Case::Empty && rf.live.is_empty())
        .count();
    assert_eq!(empties, 3, "K=1 S=2 must compile three empty phases");

    let mut rng = Rng::new(0xD367);
    let (c, h, w) = plan.input_shape;
    let x = Tensor3::from_vec(c, h, w, rng.normal_vec(c * h * w));
    let want = engine::reference_forward(&plan, &x);
    let run = Engine::with_workers(plan.clone(), 2).run(&x);
    let scale = want.data.iter().fold(1.0f64, |m, v| m.max(v.abs()));
    let rel = run.y.max_abs_diff(&want) / scale;
    assert!(rel < 1e-9, "degenerate-phase engine relative diff {rel}");

    let meta = ArtifactMeta { scale: "tiny".into(), method: "winograd".into(), seed: 5 };
    let bytes = encode(&*plan, &meta);
    let back = match decode(&bytes).unwrap().payload {
        PlanPayload::F64(p) => Arc::new(p),
        PlanPayload::F32(_) => panic!("published f64"),
    };
    let warm = Engine::with_workers(back, 2).run(&x);
    assert_eq!(run.y.max_abs_diff(&warm.y), 0.0, "round trip changed bits");
    assert_eq!(run.events, warm.events, "round trip changed events");
}

#[test]
fn prop_json_roundtrip() {
    use wingan::util::json::{self, Json};
    forall(
        "json roundtrip",
        64,
        0x15031,
        |rng| gen_json(rng, 3),
        |v| {
            let text = json::to_string_pretty(v);
            match json::parse(&text) {
                Ok(back) if &back == v => Ok(()),
                Ok(back) => Err(format!("roundtrip changed value: {back:?}")),
                Err(e) => Err(format!("reparse failed: {e}")),
            }
        },
    );

    fn gen_json(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.below(2) == 0),
            2 => Json::Num((rng.normal() * 100.0).round()),
            3 => Json::Str(format!("s{}-\"quoted\"\n", rng.below(1000))),
            4 => Json::Arr((0..rng.below(4)).map(|_| gen_json(rng, depth - 1)).collect()),
            _ => Json::Obj(
                (0..rng.below(4))
                    .map(|i| (format!("k{i}"), gen_json(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
}

/// PR-5 acceptance pin, f64 tier: for every zoo model at `Scale::Tiny`
/// (both route methods — DSE-raced `winograd` plans and forced-`tdc`
/// reference plans), a plan serialized to the artifact codec and loaded
/// back produces **bitwise-identical** engine outputs and identical
/// `Events` to the freshly compiled plan, on randomized inputs.
#[test]
fn prop_plan_artifact_roundtrip_is_bitwise_invisible_f64() {
    use wingan::artifact::{AnyPlan, PlanKey, PlanStore};
    use wingan::engine::Precision;

    let dir = std::env::temp_dir()
        .join(format!("wingan_prop_store_f64_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let store = PlanStore::open(dir.clone());
    for g in zoo::all(Scale::Tiny) {
        for (method, select) in wingan::engine::ROUTE_METHODS {
            let planner = Planner::new(PlanOptions { select, ..Default::default() });
            let compiled = Arc::new(planner.compile_seeded(&g, 23));
            let key = PlanKey::new(g.name, Scale::Tiny, Precision::F64, method, 23);
            store.publish(&key, &*compiled).unwrap();
            let loaded = match store.load(&key).unwrap() {
                AnyPlan::F64(p) => p,
                AnyPlan::F32(_) => panic!("published f64"),
            };
            let fresh = Engine::with_workers(compiled.clone(), 2);
            let warm = Engine::with_workers(loaded, 2);
            let (c, h, w) = compiled.input_shape;
            forall(
                "loaded f64 plan executes bit-identically to the compiled plan",
                8,
                0xA27 ^ g.name.len() as u64 ^ method.len() as u64,
                |rng| Tensor3::from_vec(c, h, w, rng.normal_vec(c * h * w)),
                |x| {
                    let a = fresh.run(x);
                    let b = warm.run(x);
                    if a.y.max_abs_diff(&b.y) != 0.0 {
                        return Err(format!("{} {method}: round trip changed bits", g.name));
                    }
                    if a.events != b.events || a.per_layer != b.per_layer {
                        return Err(format!("{} {method}: round trip changed events", g.name));
                    }
                    Ok(())
                },
            );
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// PR-5 acceptance pin, f32 tier: the artifact of a *lowered* f32 plan
/// round-trips bitwise — a loaded f32 artifact executes identically to the
/// lowered-then-roundtripped plan (lowering itself quantizes, so the f64
/// tier is not the comparison anchor here).
#[test]
fn prop_plan_artifact_roundtrip_is_bitwise_invisible_f32() {
    use wingan::artifact::{AnyPlan, PlanKey, PlanStore};
    use wingan::engine::Precision;

    let dir = std::env::temp_dir()
        .join(format!("wingan_prop_store_f32_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let store = PlanStore::open(dir.clone());
    for g in zoo::all(Scale::Tiny) {
        let lowered = Arc::new(Planner::default().compile_seeded(&g, 23).lower::<f32>());
        let key = PlanKey::new(g.name, Scale::Tiny, Precision::F32, "winograd", 23);
        store.publish(&key, &*lowered).unwrap();
        let loaded = match store.load(&key).unwrap() {
            AnyPlan::F32(p) => p,
            AnyPlan::F64(_) => panic!("published f32"),
        };
        let fresh = Engine::with_workers(lowered.clone(), 2);
        let warm = Engine::with_workers(loaded, 2);
        let (c, h, w) = lowered.input_shape;
        forall(
            "loaded f32 plan executes bit-identically to the lowered plan",
            8,
            0xF32A ^ g.name.len() as u64,
            |rng| {
                let x64 = Tensor3::from_vec(c, h, w, rng.normal_vec(c * h * w));
                x64.cast_to::<f32>()
            },
            |x| {
                let a = fresh.run(x);
                let b = warm.run(x);
                if a.y.max_abs_diff(&b.y) != 0.0 {
                    return Err(format!("{}: f32 round trip changed bits", g.name));
                }
                if a.events != b.events {
                    return Err(format!("{}: f32 round trip changed events", g.name));
                }
                Ok(())
            },
        );
    }
    let _ = std::fs::remove_dir_all(&dir);
}
