//! Overload and shed behaviour of the serving coordinator under real
//! concurrency: submitters racing past the admission gate on the native
//! backend. These pin PR 7's overload contract:
//!   * the per-route queue is **bounded** — depth never exceeds
//!     `queue_cap` no matter how hard submitters push (the old unbounded
//!     channel's OOM-shaped growth is structurally gone);
//!   * every shed is **typed** — clients observe exactly as many
//!     `ServeError::Rejected` responses as the coordinator counts;
//!   * admitted requests are **served exactly** — outputs bitwise-equal
//!     to a serial direct-engine reference, regardless of how batches
//!     formed under pressure;
//!   * shutdown is a **drain, not a shed** — admitted requests still get
//!     answers.

use std::sync::Arc;
use std::thread;
use std::time::Duration;
use wingan::coordinator::{Coordinator, Rejected, ServeConfig, ServeError};
use wingan::engine::{NativeConfig, NativeRuntime};
use wingan::gan::zoo::Scale;
use wingan::util::bin;
use wingan::util::prng::Rng;

fn tiny_native() -> NativeConfig {
    NativeConfig {
        scale: Scale::Tiny,
        buckets: vec![1, 2, 4],
        workers: 2,
        seed: 11,
        models: Some(vec!["dcgan".into()]),
        ..Default::default()
    }
}

/// Deterministic per-(thread, request) input so reference outputs can be
/// recomputed independently of scheduling.
fn input_for(thread: usize, i: usize, len: usize) -> Vec<f32> {
    Rng::new(0x5EED ^ ((thread as u64) << 32) ^ i as u64).normal_vec_f32(len)
}

#[test]
fn concurrent_overload_sheds_typed_and_conserves() {
    const THREADS: usize = 4;
    const PER_THREAD: usize = 24;
    const CAP: usize = 2;

    let coord = Arc::new(
        Coordinator::start_native(
            tiny_native(),
            ServeConfig { queue_cap: CAP, ..Default::default() },
        )
        .unwrap(),
    );
    let input_len = coord.router().route("dcgan", "winograd").unwrap().sample_input_len;

    // submitters race a queue of capacity 2 with a tight burst: channel
    // sends are microseconds, generator batches are not, so the gate must
    // reject most of the burst — and every outcome must be typed
    let mut joins = Vec::new();
    for t in 0..THREADS {
        let coord = coord.clone();
        joins.push(thread::spawn(move || {
            let mut pending = Vec::new();
            let mut shed = 0u64;
            for i in 0..PER_THREAD {
                match coord.submit("dcgan", "winograd", input_for(t, i, input_len)) {
                    Ok(rx) => pending.push((i, rx)),
                    Err(e) => {
                        assert!(e.is_shed(), "submit failed non-shed: {e}");
                        assert!(
                            matches!(e, ServeError::Rejected(Rejected::QueueFull { cap: CAP, .. })),
                            "wrong shed type: {e}"
                        );
                        shed += 1;
                    }
                }
            }
            let mut served = Vec::new();
            for (i, rx) in pending {
                // no SLO configured: every admitted request must be served
                let resp = rx.recv().unwrap().unwrap();
                served.push((i, resp.output));
            }
            (served, shed)
        }));
    }
    let results: Vec<_> = joins.into_iter().map(|j| j.join().unwrap()).collect();
    let served_total: u64 = results.iter().map(|(s, _)| s.len() as u64).sum();
    let shed_total: u64 = results.iter().map(|(_, s)| *s).sum();

    // conservation: every submission either served or typed-shed
    assert_eq!(served_total + shed_total, (THREADS * PER_THREAD) as u64);
    assert!(shed_total > 0, "a 96-request burst against a 2-deep queue must shed");
    assert!(served_total > 0, "the engine must still serve under overload");

    let m = coord.metrics();
    assert_eq!(m.responses, served_total, "coordinator served-count matches clients");
    assert_eq!(m.shed_queue_full, shed_total, "every client-observed shed is counted");
    assert_eq!(m.shed_deadline, 0, "no SLO configured: no deadline sheds");
    let r = &m.routes["dcgan/winograd"];
    assert_eq!(r.admitted, served_total);
    assert_eq!(r.completed, served_total);
    assert_eq!(r.shed_queue_full, shed_total);
    assert!(r.peak_depth <= CAP, "bounded queue: peak {} > cap {CAP}", r.peak_depth);
    assert_eq!(r.depth, 0, "drained: nothing left in flight");

    // bitwise check: whatever batches formed under pressure, each served
    // output equals a serial single-sample reference execution (the engine
    // is bit-invariant to batch schedule)
    let reference = NativeRuntime::build(&tiny_native());
    for (t, (served, _)) in results.iter().enumerate() {
        for (i, output) in served {
            let want = reference.execute("dcgan_winograd_b1", &input_for(t, *i, input_len)).unwrap();
            assert_eq!(
                bin::max_abs_diff(output, &want),
                0.0,
                "thread {t} request {i}: served output diverges from serial reference"
            );
        }
    }
    Arc::try_unwrap(coord).ok().expect("all clients joined").shutdown();
}

#[test]
fn submit_bound_is_an_oom_regression_gate() {
    // regression: `Coordinator::submit` used to push into an unbounded
    // channel — overload grew memory without limit. Now a single-threaded
    // flood sheds typed errors while in-flight depth stays pinned at the
    // configured bound.
    const CAP: usize = 8;
    const FLOOD: usize = 5_000;
    let coord = Coordinator::start_native(
        tiny_native(),
        ServeConfig { queue_cap: CAP, ..Default::default() },
    )
    .unwrap();
    let input_len = coord.router().route("dcgan", "winograd").unwrap().sample_input_len;
    let input = input_for(0, 0, input_len);

    let mut pending = Vec::new();
    let mut shed = 0u64;
    for _ in 0..FLOOD {
        match coord.submit("dcgan", "winograd", input.clone()) {
            Ok(rx) => pending.push(rx),
            Err(ServeError::Rejected(Rejected::QueueFull { depth, cap })) => {
                assert_eq!(cap, CAP);
                assert!(depth >= cap, "queue-full shed below capacity: {depth}/{cap}");
                shed += 1;
            }
            Err(e) => panic!("flood produced a non-shed error: {e}"),
        }
    }
    assert!(shed > 0, "a {FLOOD}-request flood must hit the {CAP}-slot bound");
    assert_eq!(pending.len() as u64 + shed, FLOOD as u64);
    for rx in pending {
        assert!(rx.recv().unwrap().is_ok(), "admitted requests all complete");
    }
    let m = coord.metrics();
    let r = &m.routes["dcgan/winograd"];
    assert!(r.peak_depth <= CAP, "peak depth {} breached the bound {CAP}", r.peak_depth);
    assert_eq!(m.shed_queue_full, shed);
    coord.shutdown();
}

#[test]
fn shutdown_drains_admitted_requests() {
    // shutdown is a drain, not a shed: requests admitted before the
    // shutdown signal still get real answers from the flush
    let coord = Coordinator::start_native(
        tiny_native(),
        ServeConfig { queue_cap: 16, ..Default::default() },
    )
    .unwrap();
    let input_len = coord.router().route("dcgan", "winograd").unwrap().sample_input_len;
    let pending: Vec<_> = (0..4)
        .map(|i| coord.submit("dcgan", "winograd", input_for(9, i, input_len)).unwrap())
        .collect();
    coord.shutdown();
    for rx in pending {
        let resp = rx.recv().unwrap().unwrap();
        assert!(resp.output.iter().all(|v| v.is_finite()));
    }
}

#[test]
fn expired_slo_comes_back_as_a_typed_reply() {
    // a zero-budget SLO is expired by the time the engine sees it: the
    // reply channel must carry the typed verdict, the shed must be
    // counted, and the gate slot must come back (later submits succeed)
    let coord = Coordinator::start_native(
        tiny_native(),
        ServeConfig { queue_cap: 4, ..Default::default() },
    )
    .unwrap();
    let input_len = coord.router().route("dcgan", "winograd").unwrap().sample_input_len;
    let input = input_for(3, 0, input_len);

    let rx = coord
        .submit_with_deadline("dcgan", "winograd", input.clone(), Some(Duration::ZERO))
        .unwrap();
    match rx.recv().unwrap() {
        Err(ServeError::Rejected(Rejected::DeadlineInfeasible { .. })) => {}
        other => panic!("expected a typed deadline shed, got {other:?}"),
    }
    let m = coord.metrics();
    assert_eq!(m.shed_deadline, 1);
    assert_eq!(m.routes["dcgan/winograd"].shed_deadline, 1);

    // the slot came back: a best-effort request on the same route serves
    let resp = coord.generate("dcgan", "winograd", input).unwrap();
    assert!(resp.output.iter().all(|v| v.is_finite()));
    assert_eq!(coord.metrics().routes["dcgan/winograd"].depth, 0);
    coord.shutdown();
}
