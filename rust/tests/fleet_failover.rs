//! Fleet failover integration tests (PR 9): a [`FleetRouter`] fronting
//! real in-process [`ReplicaServer`]s over real TCP, driven by the same
//! open-loop arrival plans the loadgen uses.
//!
//! The contract under test is the acceptance bar of the fleet tier:
//! killing a replica mid-load loses **zero** requests (every arrival gets
//! exactly one fate: a completion bitwise identical to a single-process
//! reference, or a typed shed — never a hang, never corrupted bytes), a
//! rolling republish marches every replica to the new store generation
//! one at a time and leaves the fleet all-ready, a fully dead fleet sheds
//! a typed `FleetUnavailable` verdict fast, and the `replica_exit` fault
//! site has real process-death semantics.

use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};
use wingan::artifact::PlanStore;
use wingan::coordinator::{Coordinator, Rejected, ServeConfig, ServeError};
use wingan::engine::NativeConfig;
use wingan::faultinject::FaultPlane;
use wingan::fleet::wire::{self, WireMsg};
use wingan::fleet::{drive_open_loop, FleetConfig, FleetRouter, ReplicaConfig, ReplicaServer};
use wingan::gan::zoo::Scale;
use wingan::loadgen::{ArrivalPlan, RouteLoad, TrafficProfile};
use wingan::util::lock_unpoisoned;

/// A fresh per-test plan-store root (pid-scoped so parallel test
/// processes never collide).
fn fresh_store(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("wingan-fleet-failover-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).expect("store dir");
    dir
}

/// The one engine config every party in a test shares — baseline
/// coordinator and replicas alike — so bitwise comparisons are
/// meaningful: same scale, same weight seed, same store.
fn native(store: &Path) -> NativeConfig {
    NativeConfig {
        scale: Scale::Tiny,
        workers: 2,
        models: Some(vec!["dcgan".into()]),
        plan_store: Some(store.to_path_buf()),
        ..Default::default()
    }
}

fn rep_cfg(store: &Path) -> ReplicaConfig {
    ReplicaConfig {
        native: native(store),
        serve: ServeConfig {
            drain_deadline: Duration::from_secs(2),
            ..Default::default()
        },
        fleet_faults: None,
    }
}

/// Boot a single-process baseline coordinator (its fallback compiles
/// populate the store the replicas warm-boot from), draw the arrival
/// plan, and execute every arrival serially for the reference outputs.
fn reference_run(store: &Path, n: usize, rate: f64, seed: u64) -> (ArrivalPlan, Vec<Vec<f32>>) {
    let coord =
        Coordinator::start_native(native(store), ServeConfig::default()).expect("baseline boots");
    let input_len =
        coord.router().route("dcgan", "winograd").expect("route exists").sample_input_len;
    let profile = TrafficProfile {
        routes: vec![RouteLoad { model: "dcgan".into(), method: "winograd".into(), weight: 1.0 }],
    };
    let plan = ArrivalPlan::generate(&profile, &[input_len], n, rate, seed);
    let refs = plan
        .arrivals
        .iter()
        .map(|a| {
            coord
                .generate("dcgan", "winograd", a.input.clone())
                .expect("reference generate")
                .output
        })
        .collect();
    coord.shutdown();
    (plan, refs)
}

/// The acceptance drill: two replicas behind the router, one killed
/// mid-load (process-death semantics: connections severed, no drain).
/// Zero requests lost, every completion bitwise identical to the serial
/// single-process reference, and the fleet recovers to all-ready once a
/// replacement replica is admitted.
#[test]
fn killing_a_replica_mid_load_loses_nothing_and_stays_bitwise_faithful() {
    let store = fresh_store("kill");
    let (plan, refs) = reference_run(&store, 48, 300.0, 7);

    let a = ReplicaServer::spawn("127.0.0.1:0", rep_cfg(&store)).expect("replica a");
    let b = ReplicaServer::spawn("127.0.0.1:0", rep_cfg(&store)).expect("replica b");
    assert!(a.wait_ready(Duration::from_secs(120)), "replica a boots");
    assert!(b.wait_ready(Duration::from_secs(120)), "replica b boots");
    let victim_addr = a.addr().to_string();

    let router = FleetRouter::new(FleetConfig {
        replicas: vec![victim_addr.clone(), b.addr().to_string()],
        ..Default::default()
    })
    .expect("router");
    assert!(router.wait_all_ready(Duration::from_secs(30)), "fleet admits");

    let kill_at = plan.arrivals.len() / 3;
    let victim = Mutex::new(Some(a));
    let fates = drive_open_loop(
        &plan,
        4,
        Some((kill_at, || {
            if let Some(v) = lock_unpoisoned(&victim).take() {
                v.kill();
            }
        })),
        |_i, arr| router.submit("dcgan", "winograd", arr.input.clone(), None),
    );

    let offered = plan.arrivals.len();
    let (mut completed, mut shed) = (0usize, 0usize);
    for (i, fate) in fates.iter().enumerate() {
        match fate.as_ref().expect("zero lost: every arrival has exactly one fate") {
            Ok(resp) => {
                assert_eq!(
                    resp.output, refs[i],
                    "request {i}: fleet output must be bitwise identical to the reference"
                );
                completed += 1;
            }
            Err(e) if e.is_shed() => shed += 1,
            Err(other) => {
                panic!("request {i}: a mid-run kill must never surface as a hard error: {other}")
            }
        }
    }
    assert_eq!(completed + shed, offered, "conservation: completed + shed == offered");
    assert!(
        completed > offered / 2,
        "most requests survive the kill via failover (completed {completed}/{offered}, shed {shed})"
    );

    // recovery: deregister the corpse, admit a replacement, all-ready again
    router.remove_replica(&victim_addr);
    let replacement = ReplicaServer::spawn("127.0.0.1:0", rep_cfg(&store)).expect("replacement");
    assert!(replacement.wait_ready(Duration::from_secs(120)), "replacement boots");
    router.add_replica(&replacement.addr().to_string()).expect("admit replacement");
    assert!(router.wait_all_ready(Duration::from_secs(30)), "fleet recovers to all-ready");

    b.shutdown();
    replacement.shutdown();
    drop(router);
    let _ = std::fs::remove_dir_all(&store);
}

/// Rolling republish: bump the store's generation tag and roll — every
/// replica ends on the new generation with its breaker closed, the fleet
/// is all-ready afterwards, and the republished plans produce the same
/// bits for the same input.
#[test]
fn rolling_republish_marches_every_replica_to_the_new_generation() {
    let store = fresh_store("roll");
    let (plan, refs) = reference_run(&store, 1, 100.0, 11);
    let probe_input = plan.arrivals[0].input.clone();

    let store_handle = PlanStore::open(&store);
    let g1 = store_handle.bump_generation().expect("publish g1");

    let a = ReplicaServer::spawn("127.0.0.1:0", rep_cfg(&store)).expect("replica a");
    let b = ReplicaServer::spawn("127.0.0.1:0", rep_cfg(&store)).expect("replica b");
    assert!(a.wait_ready(Duration::from_secs(120)), "replica a boots");
    assert!(b.wait_ready(Duration::from_secs(120)), "replica b boots");

    let router = FleetRouter::new(FleetConfig {
        replicas: vec![a.addr().to_string(), b.addr().to_string()],
        ..Default::default()
    })
    .expect("router");
    assert!(router.wait_all_ready(Duration::from_secs(30)), "fleet admits");
    for r in &router.status().replicas {
        assert_eq!(r.generation, g1, "{}: boots at the published generation", r.addr);
    }

    let pre = router.submit("dcgan", "winograd", probe_input.clone(), None).expect("pre-roll");
    assert_eq!(pre.output, refs[0], "pre-roll output matches the reference");

    let g2 = store_handle.bump_generation().expect("publish g2");
    router.roll_to_generation(g2, Duration::from_secs(300)).expect("roll completes");

    let status = router.status();
    assert!(status.all_ready(), "a completed roll leaves the fleet all-ready");
    for r in &status.replicas {
        assert_eq!(r.generation, g2, "{}: rolled to the new generation", r.addr);
        assert_eq!(r.breaker, "closed", "{}: readmitted with a closed breaker", r.addr);
        assert!(!r.rolling, "{}: roll flag cleared", r.addr);
    }

    let post = router.submit("dcgan", "winograd", probe_input, None).expect("post-roll");
    assert_eq!(post.output, refs[0], "the republished plans produce the same bits");

    a.shutdown();
    b.shutdown();
    drop(router);
    let _ = std::fs::remove_dir_all(&store);
}

/// Graceful degradation: when every replica is out, the router sheds a
/// typed [`Rejected::FleetUnavailable`] verdict quickly — it never hangs
/// a client on a dead fleet.
#[test]
fn a_fully_dead_fleet_sheds_typed_fleet_unavailable() {
    let store = fresh_store("dead");
    let (plan, _refs) = reference_run(&store, 1, 100.0, 3);
    let input = plan.arrivals[0].input.clone();

    let only = ReplicaServer::spawn("127.0.0.1:0", rep_cfg(&store)).expect("replica");
    assert!(only.wait_ready(Duration::from_secs(120)), "replica boots");
    let router = FleetRouter::new(FleetConfig {
        replicas: vec![only.addr().to_string()],
        ..Default::default()
    })
    .expect("router");
    assert!(router.wait_all_ready(Duration::from_secs(30)), "fleet admits");

    only.kill();
    let t0 = Instant::now();
    while router.status().ready_count() > 0 && t0.elapsed() < Duration::from_secs(10) {
        std::thread::sleep(Duration::from_millis(20));
    }
    assert_eq!(router.status().ready_count(), 0, "the prober evicts the dead replica");

    let t1 = Instant::now();
    match router.submit("dcgan", "winograd", input, None) {
        Err(ServeError::Rejected(Rejected::FleetUnavailable { replicas })) => {
            assert_eq!(replicas, 1, "the verdict names the fleet size");
        }
        Ok(_) => panic!("a dead fleet cannot complete requests"),
        Err(other) => panic!("expected FleetUnavailable, got {other}"),
    }
    assert!(
        t1.elapsed() < Duration::from_secs(10),
        "graceful degradation sheds fast, never hangs"
    );
    assert!(router.status().shed_unavailable >= 1, "the shed is counted");

    drop(router);
    let _ = std::fs::remove_dir_all(&store);
}

/// The `replica_exit` fault site has process-death semantics: the first
/// request trips it, the connection is severed with no reply, and the
/// replica's serve loop is down — the drill `wingan chaos --fleet` leans
/// on, pinned in isolation.
#[test]
fn replica_exit_fault_site_kills_the_replica_abruptly() {
    use std::net::TcpStream;
    let store = fresh_store("exit");
    let (plan, _refs) = reference_run(&store, 1, 100.0, 5);

    let mut cfg = rep_cfg(&store);
    cfg.fleet_faults =
        Some(Arc::new(FaultPlane::parse("seed=1;replica_exit:error*1@1").expect("fault plane")));
    let server = ReplicaServer::spawn("127.0.0.1:0", cfg).expect("replica");
    assert!(server.wait_ready(Duration::from_secs(120)), "replica boots");
    let addr = server.addr();

    let mut s = TcpStream::connect(addr).expect("connect");
    let _ = s.set_read_timeout(Some(Duration::from_secs(10)));
    wire::send(
        &mut s,
        &WireMsg::Request {
            id: 1,
            model: "dcgan".into(),
            method: "winograd".into(),
            deadline_us: 0,
            input: plan.arrivals[0].input.clone(),
            trace: 0,
        },
    )
    .expect("send");
    assert!(wire::recv(&mut s).is_err(), "an exiting replica severs the connection, no reply");

    let t0 = Instant::now();
    while server.alive() && t0.elapsed() < Duration::from_secs(10) {
        std::thread::sleep(Duration::from_millis(10));
    }
    assert!(!server.alive(), "replica_exit stops the serve loop (process-death semantics)");
    server.join();
    let _ = std::fs::remove_dir_all(&store);
}
