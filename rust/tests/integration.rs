//! Integration tests across modules that don't need the PJRT artifacts
//! (those live in runtime_e2e.rs): zoo ↔ workload ↔ simulator ↔ energy ↔
//! resource ↔ dse consistency, and the report/CLI surfaces.

use wingan::accel::functional::run_winograd_deconv;
use wingan::accel::{simulate_model, AccelConfig};
use wingan::cli::Args;
use wingan::energy::{energy_of, EnergyParams};
use wingan::gan::workload::Method;
use wingan::gan::zoo::{self, Scale};
use wingan::report;
use wingan::tdc;
use wingan::util::prng::Rng;
use wingan::util::tensor::{Filter4, Tensor3};

#[test]
fn fig8_speedup_shape_matches_paper() {
    // who wins, by roughly what factor (paper: DCGAN 8.38/2.85,
    // ArtGAN 7.5/1.78, DiscoGAN & GP-GAN 7.15/1.85)
    let cfg = AccelConfig::default();
    let expect = [
        ("DCGAN", 8.38, 2.85),
        ("ArtGAN", 7.5, 1.78),
        ("DiscoGAN", 7.15, 1.85),
        ("GP-GAN", 7.15, 1.85),
    ];
    for (g, (name, zp_claim, tdc_claim)) in zoo::all(Scale::Paper).iter().zip(expect) {
        assert_eq!(g.name, name);
        let zp = simulate_model(g, Method::ZeroPadded, &cfg, true);
        let td = simulate_model(g, Method::Tdc, &cfg, true);
        let wi = simulate_model(g, Method::Winograd, &cfg, true);
        let s_zp = zp.t_total / wi.t_total;
        let s_td = td.t_total / wi.t_total;
        // within 25% of the paper's claims — same substrate shape
        assert!((s_zp / zp_claim - 1.0).abs() < 0.25, "{name}: ZP speedup {s_zp} vs {zp_claim}");
        assert!((s_td / tdc_claim - 1.0).abs() < 0.25, "{name}: TDC speedup {s_td} vs {tdc_claim}");
    }
}

#[test]
fn fig9_energy_shape_matches_paper() {
    let cfg = AccelConfig::default();
    let ep = EnergyParams::default();
    let models = zoo::all(Scale::Paper);
    let mean_zp: f64 = models
        .iter()
        .map(|g| wingan::energy::fig9_row(g, &cfg, &ep).saving_vs_zp())
        .sum::<f64>()
        / models.len() as f64;
    let mean_td: f64 = models
        .iter()
        .map(|g| wingan::energy::fig9_row(g, &cfg, &ep).saving_vs_tdc())
        .sum::<f64>()
        / models.len() as f64;
    // paper: 3.65x mean vs zero-padded, 1.74x vs TDC
    assert!((mean_zp / 3.65 - 1.0).abs() < 0.25, "mean ZP saving {mean_zp}");
    assert!((mean_td / 1.74 - 1.0).abs() < 0.25, "mean TDC saving {mean_td}");
}

#[test]
fn table2_model_tracks_paper_within_tolerance() {
    let cfg = AccelConfig::default();
    let g = zoo::dcgan(Scale::Paper);
    let ours = wingan::resource::report(&g, &cfg, Method::Winograd);
    let base = wingan::resource::report(&g, &cfg, Method::Tdc);
    let po = wingan::resource::PAPER_TABLE2_OURS;
    let p14 = wingan::resource::PAPER_TABLE2_TDC;
    let close = |m: usize, p: usize, tol: f64| (m as f64 - p as f64).abs() / p as f64 <= tol;
    assert_eq!(ours.dsp48e, po.dsp48e);
    assert_eq!(base.dsp48e, p14.dsp48e);
    assert!(close(ours.bram18k, po.bram18k, 0.05));
    assert!(close(base.bram18k, p14.bram18k, 0.05));
    assert!(close(ours.lut, po.lut, 0.10));
    assert!(close(ours.ff, po.ff, 0.10));
    assert_eq!(base.lut, p14.lut);
    assert_eq!(base.ff, p14.ff);
}

#[test]
fn dse_selects_paper_tiling() {
    let best = wingan::dse::optimal(&zoo::all(Scale::Paper), &wingan::dse::VIRTEX7_485T);
    assert_eq!((best.t_m, best.t_n), (4, 128));
    assert!(best.feasible);
}

#[test]
fn functional_and_cycle_sims_agree_on_mult_counts() {
    // the measured event counts of the functional simulator must equal the
    // analytic counts the cycle/energy models consume — on a real
    // (small-scale) DCGAN layer geometry
    let g = zoo::dcgan(Scale::Small);
    let l = g.layers[2]; // 32 -> 16 at 16x16 (small scale)
    let mut rng = Rng::new(5);
    let x = Tensor3::from_vec(l.c_in, l.h_in, l.w_in, rng.normal_vec(l.c_in * l.h_in * l.w_in));
    let w = Filter4::from_vec(l.c_in, l.c_out, l.k, l.k, rng.normal_vec(l.c_in * l.c_out * l.k * l.k));
    let run = run_winograd_deconv(&x, &w, l.s, l.p);
    assert_eq!(run.events.mults, wingan::gan::workload::layer_mults(&l, Method::Winograd));
    // and the dataflow computes the right answer on that geometry
    let want = tdc::deconv_naive(&x, &w, l.s, l.p);
    assert!(want.max_abs_diff(&run.y) < 1e-9);
}

#[test]
fn energy_breakdown_consistent_with_totals() {
    let cfg = AccelConfig::default();
    let ep = EnergyParams::default();
    for g in zoo::all(Scale::Paper) {
        for m in Method::ALL {
            let sim = simulate_model(&g, m, &cfg, true);
            let b = energy_of(&sim, &g, &ep);
            let sum = b.compute + b.onchip + b.offchip + b.rearrange;
            assert!((b.total() - sum).abs() < 1e-15);
            assert!(b.total() > 0.0);
        }
    }
}

#[test]
fn small_scale_zoo_matches_python_artifact_shapes() {
    // python/compile/model.py zoo('small') must agree with rust Scale::Small
    // — the manifest records python's shapes; here we check the rust side
    // derives the same output geometry (64x64x3 generators).
    for g in zoo::all(Scale::Small) {
        let last = g.layers.last().unwrap();
        assert_eq!((last.c_out, last.h_out(), last.w_out()), (3, 64, 64), "{}", g.name);
    }
    // channel scaling: /8 with floor 4
    assert_eq!(zoo::dcgan(Scale::Small).layers[0].c_in, 1024 / 8);
    assert_eq!(zoo::artgan(Scale::Small).layers[0].c_in, 512 / 8);
}

#[test]
fn reports_render_and_contain_key_claims() {
    let s = report::all_tables();
    for needle in [
        "DCGAN",
        "ArtGAN",
        "DiscoGAN",
        "GP-GAN",
        "ZP/Win",
        "2560",       // Table II DSP row
        "8.38x/2.85x", // paper claim cited in fig8 footer
    ] {
        assert!(s.contains(needle), "missing {needle:?} in:\n{s}");
    }
}

#[test]
fn cli_roundtrip_for_documented_commands() {
    for cmd in [
        "tables --fig8",
        "sim --model dcgan --zero-skip",
        "serve --model dcgan --requests 64 --rate 200 --max-wait-ms 20",
        "verify --artifacts artifacts",
    ] {
        let args = Args::parse(cmd.split_whitespace().map(String::from)).unwrap();
        assert!(args.subcommand.is_some(), "{cmd}");
    }
}

#[test]
fn table1_reproduces_kernel_classes() {
    let t = report::table1();
    assert!(t.contains("DCGAN"));
    // K_D=5 S=2 K_C=3 row for DCGAN, 4/2/2 for the K4 models
    assert!(t.contains('5'), "{t}");
    let zoo_paper = zoo::all(Scale::Paper);
    assert_eq!(zoo_paper.iter().map(|g| g.n_deconv()).collect::<Vec<_>>(), vec![4, 5, 4, 4]);
}

#[test]
fn deconv_only_flag_consistency() {
    // full-model sim includes the encoder and is strictly slower
    let cfg = AccelConfig::default();
    let g = zoo::discogan(Scale::Paper);
    let dec = simulate_model(&g, Method::Winograd, &cfg, true);
    let full = simulate_model(&g, Method::Winograd, &cfg, false);
    assert!(full.t_total > dec.t_total);
    assert_eq!(full.layers.len() - dec.layers.len(), g.n_conv());
}
