//! `wingan loadgen` — open-loop load-generation harness for the serving
//! coordinator.
//!
//! The harness answers the question the unit tests cannot: *what does the
//! scheduler do under sustained, realistic traffic?* It drives a native
//! coordinator with **open-loop Poisson arrivals** (arrival times are
//! drawn up front and never slowed down by slow responses — the honest
//! way to measure an overloaded server) over a **mixed traffic profile**
//! (multiple zoo models and both route methods, which also mixes
//! precision tiers: fast routes serve the resolved f32/f64 tier, the
//! `tdc` reference route always serves f64), and reports
//! achieved-vs-offered rate, shed fraction, and latency percentiles.
//!
//! The run is an **A/B at equal offered load**: the identical
//! pre-generated arrival schedule (same seed → same arrival offsets,
//! same route choices, same input tensors) is replayed against
//! [`SchedulerKind::Continuous`] and [`SchedulerKind::Bucket`]
//! coordinators, and both outcomes land in one
//! [`crate::benchlib::BenchReport`] (`BENCH_pr7.json`) so the perf
//! trajectory records the scheduler comparison machine-readably.
//!
//! Offered load is expressed relative to **calibrated capacity**: a
//! short pre-run measures each route's full-width batch service time on
//! a hold-forever bucket coordinator (submit exactly `width` requests →
//! exactly one full batch → its `exec_time` is the service time), and
//! the mix-weighted capacity follows. `--load 1.2` (the default) then
//! means "offer 20% more than the engine can sustain" — the regime where
//! admission control earns its keep.
//!
//! Every run **asserts conservation**: submitted = completed +
//! typed-shed (client-observed), and the coordinator's shed counters
//! must match what the client saw. A lost request fails the run.

use crate::benchlib::BenchReport;
use crate::coordinator::{Coordinator, SchedulerKind, ServeConfig};
use crate::engine::serve::NativeConfig;
use crate::gan::zoo::Scale;
use crate::util::prng::Rng;
use anyhow::{ensure, Context, Result};
use std::path::PathBuf;
use std::time::{Duration, Instant};

/// One route in the traffic mix, with its share of offered requests.
#[derive(Clone, Debug)]
pub struct RouteLoad {
    /// zoo model id ("dcgan", "gpgan", ...)
    pub model: String,
    /// route method ("winograd" fast tier, "tdc" f64 reference tier)
    pub method: String,
    /// fraction of offered traffic on this route (weights sum to 1)
    pub weight: f64,
}

/// The mixed model/method/precision traffic profile a loadgen run offers.
#[derive(Clone, Debug)]
pub struct TrafficProfile {
    /// routes in the mix, weights summing to 1
    pub routes: Vec<RouteLoad>,
}

impl TrafficProfile {
    /// The standard serving mix: mostly the dcgan fast route, with a
    /// second model and the f64 `tdc` reference route in the blend so
    /// every run exercises cross-model and cross-precision batching.
    pub fn standard() -> TrafficProfile {
        TrafficProfile {
            routes: vec![
                RouteLoad { model: "dcgan".into(), method: "winograd".into(), weight: 0.6 },
                RouteLoad { model: "gpgan".into(), method: "winograd".into(), weight: 0.2 },
                RouteLoad { model: "dcgan".into(), method: "tdc".into(), weight: 0.2 },
            ],
        }
    }

    /// The distinct model ids in the mix (for `NativeConfig::models`).
    pub fn models(&self) -> Vec<String> {
        let mut out: Vec<String> = Vec::new();
        for r in &self.routes {
            if !out.contains(&r.model) {
                out.push(r.model.clone());
            }
        }
        out
    }

    /// Pick a route index by weight from one uniform draw in `[0, 1)`.
    pub fn pick(&self, u: f64) -> usize {
        let mut acc = 0.0;
        for (i, r) in self.routes.iter().enumerate() {
            acc += r.weight;
            if u < acc {
                return i;
            }
        }
        self.routes.len() - 1
    }
}

/// Loadgen run options (see `wingan loadgen --help` text in `main.rs`).
#[derive(Clone, Debug)]
pub struct LoadgenOptions {
    /// zoo scale the engines compile at (tiny default: fast, CI-friendly)
    pub scale: Scale,
    /// total requests offered per scheduler run
    pub requests: usize,
    /// explicit offered rate (req/s); `None` = `load` × calibrated capacity
    pub rate: Option<f64>,
    /// offered load as a multiple of calibrated capacity (default 1.2:
    /// moderate overload, the regime admission control exists for)
    pub load: f64,
    /// explicit per-request SLO budget; `None` = 4 × the slowest route's
    /// calibrated full-batch service time
    pub slo: Option<Duration>,
    /// per-route admission bound (queue + channel)
    pub queue_cap: usize,
    /// hold window for the bucket baseline (the continuous scheduler
    /// always runs work-conserving, `max_wait = 0`)
    pub bucket_max_wait: Duration,
    /// workload + arrival-schedule seed (same seed → both schedulers see
    /// byte-identical traffic)
    pub seed: u64,
    /// worker threads (0 = env/core default)
    pub workers: usize,
    /// where to write the machine-readable report
    pub out: PathBuf,
    /// drive a remote fleet router (`host:port`) over the wire protocol
    /// instead of an in-process coordinator (see [`run_remote`])
    pub connect: Option<String>,
}

impl Default for LoadgenOptions {
    fn default() -> Self {
        LoadgenOptions {
            scale: Scale::Tiny,
            requests: 800,
            rate: None,
            load: 1.2,
            slo: None,
            queue_cap: 256,
            bucket_max_wait: Duration::from_millis(20),
            seed: 7,
            workers: 0,
            out: PathBuf::from("BENCH_pr7.json"),
            connect: None,
        }
    }
}

impl LoadgenOptions {
    /// The short configuration behind `--quick`: enough traffic to fill
    /// wide batches and trip admission control, small enough for a CI
    /// smoke step.
    pub fn quick() -> LoadgenOptions {
        LoadgenOptions { requests: 200, ..Default::default() }
    }
}

/// One request in the pre-generated open-loop schedule.
///
/// Public so the chaos harness ([`crate::chaos`]) can replay the exact
/// schedule a fault-free baseline saw under fault injection.
#[derive(Clone, Debug)]
pub struct Arrival {
    /// offset from the run start at which this request is submitted
    pub offset: Duration,
    /// index into the profile's route list
    pub route: usize,
    /// the input tensor (identical across both scheduler runs)
    pub input: Vec<f32>,
}

/// The full arrival schedule, generated once and replayed verbatim
/// against each scheduler so the A/B compares at equal offered load.
pub struct ArrivalPlan {
    /// the schedule, sorted by offset
    pub arrivals: Vec<Arrival>,
    /// offered rate the schedule was drawn at (req/s)
    pub rate: f64,
}

impl ArrivalPlan {
    /// Draw a deterministic open-loop Poisson schedule: same seed → same
    /// arrival offsets, route choices, and input tensors.
    pub fn generate(
        profile: &TrafficProfile,
        input_lens: &[usize],
        requests: usize,
        rate: f64,
        seed: u64,
    ) -> ArrivalPlan {
        let mut rng = Rng::new(seed);
        let mut t = Duration::ZERO;
        let mut arrivals = Vec::with_capacity(requests);
        for _ in 0..requests {
            let route = profile.pick(rng.uniform());
            arrivals.push(Arrival {
                offset: t,
                route,
                input: rng.normal_vec_f32(input_lens[route]),
            });
            t += Duration::from_secs_f64(rng.exponential(rate));
        }
        ArrivalPlan { arrivals, rate }
    }
}

/// What one scheduler run observed, client-side and coordinator-side.
#[derive(Clone, Debug)]
pub struct SchedulerOutcome {
    /// which scheduler ran
    pub scheduler: SchedulerKind,
    /// requests offered (the full arrival plan)
    pub offered: u64,
    /// offered rate over the submission window (req/s)
    pub offered_rate: f64,
    /// requests answered with an output
    pub completed: u64,
    /// completions whose queue+exec time fit the SLO budget (goodput)
    pub in_slo: u64,
    /// typed sheds observed at `submit` (admission gate)
    pub shed_submit: u64,
    /// typed sheds observed on the reply channel (deadline sheds)
    pub shed_reply: u64,
    /// wall clock from first submit until every reply (or shed) arrived
    pub wall: Duration,
    /// e2e latency percentiles over completions, seconds (p50, p99, p999)
    pub tail: (f64, f64, f64),
}

impl SchedulerOutcome {
    /// Completions per wall-clock second (every answer, on-time or late).
    pub fn achieved_rate(&self) -> f64 {
        self.completed as f64 / self.wall.as_secs_f64()
    }

    /// In-SLO completions per wall-clock second — the sustained
    /// throughput of *useful* work, the number an SLO-bound deployment
    /// actually gets to keep.
    pub fn goodput(&self) -> f64 {
        self.in_slo as f64 / self.wall.as_secs_f64()
    }

    /// Fraction of offered requests shed with a typed rejection.
    pub fn shed_fraction(&self) -> f64 {
        (self.shed_submit + self.shed_reply) as f64 / self.offered as f64
    }

    /// One human-readable report block.
    pub fn report(&self) -> String {
        let (p50, p99, p999) = self.tail;
        format!(
            "{:?}: offered {:.0}/s  achieved {:.0}/s  goodput {:.0}/s  \
             shed {:.1}% ({} gate + {} deadline)  \
             p50={:.2}ms p99={:.2}ms p999={:.2}ms  wall={:.2}s",
            self.scheduler,
            self.offered_rate,
            self.achieved_rate(),
            self.goodput(),
            self.shed_fraction() * 100.0,
            self.shed_submit,
            self.shed_reply,
            p50 * 1e3,
            p99 * 1e3,
            p999 * 1e3,
            self.wall.as_secs_f64(),
        )
    }
}

/// Per-route calibration: full-width batch service time.
struct Calibration {
    /// service time of one full-width batch per profile route
    service: Vec<Duration>,
    /// batch width per profile route
    width: Vec<usize>,
    /// per-sample input length per profile route (for schedule generation)
    input_lens: Vec<usize>,
}

impl Calibration {
    /// Mix-weighted sustainable rate: the engine spends
    /// `weight × service / width` seconds per offered request on each
    /// route, so capacity is the reciprocal of the weighted sum.
    fn capacity(&self, profile: &TrafficProfile) -> f64 {
        let cost_per_req: f64 = profile
            .routes
            .iter()
            .zip(self.service.iter().zip(&self.width))
            .map(|(r, (s, w))| r.weight * s.as_secs_f64() / *w as f64)
            .sum();
        1.0 / cost_per_req
    }

    /// The slowest route's full-batch service time (the SLO default's
    /// anchor).
    fn slowest(&self) -> Duration {
        self.service.iter().copied().max().unwrap_or(Duration::from_millis(1))
    }
}

fn native_config(opts: &LoadgenOptions, profile: &TrafficProfile) -> NativeConfig {
    NativeConfig {
        scale: opts.scale,
        workers: opts.workers,
        models: Some(profile.models()),
        ..Default::default()
    }
}

/// Measure each route's full-width batch service time: a hold-forever
/// bucket coordinator (`max_wait = MAX`) dispatches nothing until the
/// width fills, so submitting exactly `width` requests produces exactly
/// one full batch whose `exec_time` is the service time. Two rounds per
/// route; the warm second round is the measurement.
fn calibrate(opts: &LoadgenOptions, profile: &TrafficProfile) -> Result<Calibration> {
    let serve = ServeConfig {
        scheduler: SchedulerKind::Bucket,
        max_wait: Duration::MAX,
        queue_cap: opts.queue_cap.max(64),
        ..Default::default()
    };
    let coord = Coordinator::start_native(native_config(opts, profile), serve)?;
    let mut rng = Rng::new(opts.seed ^ 0xCA11_B8A7);
    let mut service = Vec::with_capacity(profile.routes.len());
    let mut width = Vec::with_capacity(profile.routes.len());
    let mut input_lens = Vec::with_capacity(profile.routes.len());
    for r in &profile.routes {
        let route = coord.router().route(&r.model, &r.method).map_err(anyhow::Error::msg)?;
        let w = *route.bucket_sizes().last().expect("route advertises buckets");
        let input_len = route.sample_input_len;
        input_lens.push(input_len);
        let mut t_full = Duration::ZERO;
        for _round in 0..2 {
            let pending: Vec<_> = (0..w)
                .map(|_| coord.submit(&r.model, &r.method, rng.normal_vec_f32(input_len)))
                .collect::<std::result::Result<_, _>>()
                .map_err(anyhow::Error::msg)?;
            for rx in pending {
                let resp = rx
                    .recv()
                    .context("engine died during calibration")?
                    .map_err(anyhow::Error::msg)?;
                ensure!(
                    resp.batch_size == w,
                    "calibration batch split: got bucket {} for width {w}",
                    resp.batch_size
                );
                t_full = resp.exec_time;
            }
        }
        service.push(t_full);
        width.push(w);
    }
    coord.shutdown();
    Ok(Calibration { service, width, input_lens })
}

/// Replay the arrival plan against one scheduler and tally the outcome.
/// Asserts request conservation (client-side and against the
/// coordinator's shed counters) — a lost request fails the run.
fn run_one(
    kind: SchedulerKind,
    opts: &LoadgenOptions,
    profile: &TrafficProfile,
    plan: &ArrivalPlan,
    slo: Duration,
) -> Result<SchedulerOutcome> {
    let serve = ServeConfig {
        scheduler: kind,
        max_wait: match kind {
            SchedulerKind::Continuous => Duration::ZERO,
            SchedulerKind::Bucket => opts.bucket_max_wait,
        },
        queue_cap: opts.queue_cap,
        slo: Some(slo),
        ..Default::default()
    };
    let coord = Coordinator::start_native(native_config(opts, profile), serve)?;

    let t0 = Instant::now();
    let mut pending = Vec::with_capacity(plan.arrivals.len());
    let mut shed_submit = 0u64;
    for a in &plan.arrivals {
        let target = t0 + a.offset;
        let now = Instant::now();
        if target > now {
            std::thread::sleep(target - now);
        }
        let r = &profile.routes[a.route];
        match coord.submit(&r.model, &r.method, a.input.clone()) {
            Ok(rx) => pending.push(rx),
            Err(e) if e.is_shed() => shed_submit += 1,
            Err(e) => anyhow::bail!("submit failed hard (not a shed): {e}"),
        }
    }
    let submit_window = t0.elapsed();

    let mut completed = 0u64;
    let mut in_slo = 0u64;
    let mut shed_reply = 0u64;
    for rx in pending {
        match rx.recv().context("engine died mid-run")? {
            Ok(resp) => {
                completed += 1;
                // queue+exec is the server-side e2e, measured per request
                // without client-side recv-ordering skew
                if resp.queue_time + resp.exec_time <= slo {
                    in_slo += 1;
                }
            }
            Err(e) if e.is_shed() => shed_reply += 1,
            Err(e) => anyhow::bail!("request failed hard (not a shed): {e}"),
        }
    }
    let wall = t0.elapsed();
    let m = coord.metrics();
    coord.shutdown();

    let offered = plan.arrivals.len() as u64;
    // conservation: every offered request is answered or typed-shed
    ensure!(
        completed + shed_submit + shed_reply == offered,
        "lost requests: {completed} completed + {shed_submit} gate-shed + \
         {shed_reply} reply-shed != {offered} offered"
    );
    // and the coordinator's typed-shed counters must agree with what the
    // client observed
    ensure!(
        m.shed_total() == shed_submit + shed_reply,
        "shed counters diverge: coordinator says {}, client saw {}",
        m.shed_total(),
        shed_submit + shed_reply
    );

    Ok(SchedulerOutcome {
        scheduler: kind,
        offered,
        offered_rate: offered as f64 / submit_window.as_secs_f64().max(1e-9),
        completed,
        in_slo,
        shed_submit,
        shed_reply,
        wall,
        tail: m.e2e_latency.tail(),
    })
}

/// Run the full loadgen A/B: calibrate capacity, generate one open-loop
/// Poisson arrival plan, replay it against the continuous and bucket
/// schedulers, print both outcomes, and write `BENCH_pr7.json`. Returns
/// the (continuous, bucket) outcomes.
pub fn run(opts: &LoadgenOptions) -> Result<(SchedulerOutcome, SchedulerOutcome)> {
    let profile = TrafficProfile::standard();
    println!(
        "loadgen: calibrating {} route(s) at {:?} scale...",
        profile.routes.len(),
        opts.scale
    );
    let cal = calibrate(opts, &profile)?;
    let capacity = cal.capacity(&profile);
    let rate = opts.rate.unwrap_or(capacity * opts.load);
    let slo = opts.slo.unwrap_or_else(|| cal.slowest() * 4);
    for (r, (s, w)) in profile.routes.iter().zip(cal.service.iter().zip(&cal.width)) {
        println!(
            "  {}/{}: width {w}, full-batch service {:.3}ms",
            r.model,
            r.method,
            s.as_secs_f64() * 1e3
        );
    }
    println!(
        "loadgen: capacity ~{capacity:.0} req/s; offering {rate:.0} req/s \
         ({} requests, SLO {:.1}ms, queue cap {}, seed {})",
        opts.requests,
        slo.as_secs_f64() * 1e3,
        opts.queue_cap,
        opts.seed
    );

    let plan = ArrivalPlan::generate(&profile, &cal.input_lens, opts.requests, rate, opts.seed);

    let continuous = run_one(SchedulerKind::Continuous, opts, &profile, &plan, slo)?;
    println!("  {}", continuous.report());
    let bucket = run_one(SchedulerKind::Bucket, opts, &profile, &plan, slo)?;
    println!("  {}", bucket.report());

    let mut rep = BenchReport::new("loadgen");
    rep.metric("offered_rate_rps", plan.rate);
    rep.metric("calibrated_capacity_rps", capacity);
    rep.metric("slo_ms", slo.as_secs_f64() * 1e3);
    for o in [&continuous, &bucket] {
        let tag = match o.scheduler {
            SchedulerKind::Continuous => "continuous",
            SchedulerKind::Bucket => "bucket",
        };
        let (p50, p99, p999) = o.tail;
        rep.metric(&format!("{tag}_achieved_rps"), o.achieved_rate());
        rep.metric(&format!("{tag}_goodput_rps"), o.goodput());
        rep.metric(&format!("{tag}_shed_fraction"), o.shed_fraction());
        rep.metric(&format!("{tag}_p50_ms"), p50 * 1e3);
        rep.metric(&format!("{tag}_p99_ms"), p99 * 1e3);
        rep.metric(&format!("{tag}_p999_ms"), p999 * 1e3);
        rep.metric(&format!("{tag}_completed"), o.completed as f64);
        rep.metric(&format!("{tag}_lost"), 0.0); // conservation asserted above
    }
    // the headline A/B factors: sustained useful throughput and tail
    // latency at equal offered load
    rep.metric(
        "throughput_vs_bucket",
        continuous.achieved_rate() / bucket.achieved_rate().max(1e-9),
    );
    rep.metric(
        "goodput_vs_bucket",
        continuous.goodput() / bucket.goodput().max(1e-9),
    );
    rep.metric("p99_bucket_over_continuous", bucket.tail.1 / continuous.tail.1.max(1e-9));
    // trace-derived stage breakdown (empty unless sampling was armed via
    // --trace-sample: the default A/B stays untraced so its numbers are
    // comparable run over run)
    for (key, value) in crate::telemetry::bench_stage_metrics() {
        rep.metric(&key, value);
    }
    rep.write(&opts.out).with_context(|| format!("writing {}", opts.out.display()))?;
    println!(
        "loadgen: wrote {} (throughput x{:.2}, goodput x{:.2}, bucket p99 {:.1}x higher)",
        opts.out.display(),
        continuous.achieved_rate() / bucket.achieved_rate().max(1e-9),
        continuous.goodput() / bucket.goodput().max(1e-9),
        bucket.tail.1 / continuous.tail.1.max(1e-9),
    );
    Ok((continuous, bucket))
}

/// `wingan loadgen --connect <router>`: drive a remote fleet router over
/// the wire protocol instead of an in-process coordinator.
///
/// The traffic mix is [`TrafficProfile::standard`] filtered to the
/// routes the fleet actually advertises (learned from the router's
/// status document, weights renormalised), replayed open-loop by a pool
/// of client threads — one TCP connection per request, the same
/// stateless pattern the router itself uses toward replicas. There is no
/// local engine to calibrate against, so `--rate` is required, and the
/// SLO (default 500 ms) rides along as the wire deadline budget.
///
/// Asserts the same conservation contract as the in-process harness:
/// every offered request completes or sheds typed; a transport failure
/// or untyped error fails the run. Latency is client-observed RTT
/// through router + replica + engine.
pub fn run_remote(opts: &LoadgenOptions, addr: &str) -> Result<()> {
    use crate::coordinator::{GenResponse, Histogram, ServeError};
    use crate::fleet::wire::{self, WireMsg};
    use crate::util::json::{self, Json};
    use crate::util::lock_unpoisoned;
    use std::net::{SocketAddr, TcpStream, ToSocketAddrs};
    use std::sync::atomic::{AtomicU64, Ordering};
    use std::sync::Mutex;

    let sock: SocketAddr = addr
        .to_socket_addrs()
        .with_context(|| format!("bad router address '{addr}'"))?
        .next()
        .with_context(|| format!("router address '{addr}' resolves to nothing"))?;
    let rate = opts
        .rate
        .context("--connect needs an explicit --rate: there is no local engine to calibrate")?;

    let rpc = |msg: &WireMsg, timeout: Duration| -> std::result::Result<WireMsg, String> {
        let mut s = TcpStream::connect_timeout(&sock, Duration::from_secs(2))
            .map_err(|e| format!("connect {sock}: {e}"))?;
        let _ = s.set_nodelay(true);
        let _ = s.set_read_timeout(Some(timeout));
        let _ = s.set_write_timeout(Some(timeout));
        wire::send(&mut s, msg).map_err(|e| format!("send: {e}"))?;
        wire::recv(&mut s).map_err(|e| format!("recv: {e}"))
    };

    // discover what the fleet serves from the router status document
    let reply = rpc(&WireMsg::HealthQuery, Duration::from_secs(5))
        .map_err(|e| anyhow::anyhow!("router health query failed: {e}"))?;
    let WireMsg::HealthReply { json: text } = reply else {
        anyhow::bail!("router answered the health query with a non-health frame")
    };
    let doc = json::parse(&text).map_err(|e| anyhow::anyhow!("bad router status JSON: {e}"))?;
    let advertised = doc
        .get("routes")
        .and_then(Json::as_arr)
        .context("router status carries no routes")?;
    let mut available: Vec<(String, String, usize)> = Vec::new();
    for r in advertised {
        if let (Some(model), Some(method), Some(input_len)) = (
            r.get("model").and_then(Json::as_str),
            r.get("method").and_then(Json::as_str),
            r.get("input_len").and_then(Json::as_usize),
        ) {
            available.push((model.to_string(), method.to_string(), input_len));
        }
    }
    ensure!(!available.is_empty(), "fleet advertises no routes (replicas not ready yet?)");

    // standard mix filtered to advertised routes, weights renormalised
    let mut routes = Vec::new();
    let mut input_lens = Vec::new();
    for r in TrafficProfile::standard().routes {
        if let Some((_, _, len)) =
            available.iter().find(|(m, me, _)| *m == r.model && *me == r.method)
        {
            input_lens.push(*len);
            routes.push(r);
        }
    }
    ensure!(!routes.is_empty(), "no overlap between the standard mix and the fleet's routes");
    let total: f64 = routes.iter().map(|r| r.weight).sum();
    for r in &mut routes {
        r.weight /= total;
    }
    let profile = TrafficProfile { routes };

    let slo = opts.slo.unwrap_or(Duration::from_millis(500));
    let plan = ArrivalPlan::generate(&profile, &input_lens, opts.requests, rate, opts.seed);
    println!(
        "loadgen: driving router {addr} with {} requests at {rate:.0} req/s over {} \
         route(s), SLO {:.0}ms, seed {}",
        opts.requests,
        profile.routes.len(),
        slo.as_secs_f64() * 1e3,
        opts.seed
    );

    let lat = Mutex::new(Histogram::new());
    let in_slo = AtomicU64::new(0);
    let clients = if opts.workers == 0 { 8 } else { opts.workers };
    let t0 = Instant::now();
    let fates = crate::fleet::drive_open_loop(&plan, clients, None::<(usize, fn())>, |i, a| {
        let r = &profile.routes[a.route];
        // the client is the outermost admission point: if this process's
        // recorder is armed (--trace-sample), the minted id rides the
        // wire and names the request in every downstream recorder too
        let trace = crate::telemetry::recorder().maybe_mint();
        let msg = WireMsg::Request {
            id: i as u64,
            model: r.model.clone(),
            method: r.method.clone(),
            deadline_us: slo.as_micros() as u64,
            input: a.input.clone(),
            trace,
        };
        let sent = Instant::now();
        let reply = rpc(&msg, slo + Duration::from_secs(10));
        if trace != 0 {
            let verdict = match &reply {
                Ok(WireMsg::Response { .. }) => 0,
                Ok(WireMsg::Error { code, .. }) => *code as u64,
                Ok(_) => 101,
                Err(_) => 100,
            };
            crate::telemetry::record_span(
                trace,
                crate::telemetry::Stage::Wire,
                sent,
                sent.elapsed(),
                i as u64,
                verdict,
                addr,
            );
        }
        match reply {
            Ok(WireMsg::Response { batch_size, queue_us, exec_us, output, .. }) => {
                let rtt = sent.elapsed();
                lock_unpoisoned(&lat).record(rtt);
                if rtt <= slo {
                    in_slo.fetch_add(1, Ordering::Relaxed);
                }
                Ok(GenResponse {
                    id: i as u64,
                    output,
                    batch_size: batch_size as usize,
                    queue_time: Duration::from_micros(queue_us),
                    exec_time: Duration::from_micros(exec_us),
                })
            }
            Ok(WireMsg::Error { code, a: ea, b: eb, detail, .. }) => {
                Err(wire::error_from_wire(code, ea, eb, &detail))
            }
            Ok(_) => Err(ServeError::Execution("router sent an unexpected frame".into())),
            Err(e) => Err(ServeError::Execution(format!("router transport failed: {e}"))),
        }
    });
    let wall = t0.elapsed();

    let mut completed = 0u64;
    let mut shed = 0u64;
    for (i, fate) in fates.iter().enumerate() {
        match fate {
            Some(Ok(_)) => completed += 1,
            Some(Err(e)) if e.is_shed() => shed += 1,
            Some(Err(e)) => anyhow::bail!("request {i} failed hard (not a typed shed): {e}"),
            None => anyhow::bail!("request {i} was never dispatched — lost"),
        }
    }
    let offered = plan.arrivals.len() as u64;
    ensure!(
        completed + shed == offered,
        "lost requests: {completed} completed + {shed} shed != {offered} offered"
    );

    let in_slo = in_slo.load(Ordering::Relaxed);
    let (p50, p99, p999) = lock_unpoisoned(&lat).tail();
    println!(
        "loadgen: remote — offered {offered}, completed {completed} ({in_slo} in SLO), \
         shed {shed}, p50={:.2}ms p99={:.2}ms p999={:.2}ms, wall={:.2}s",
        p50 * 1e3,
        p99 * 1e3,
        p999 * 1e3,
        wall.as_secs_f64()
    );

    let mut rep = BenchReport::new("loadgen-remote");
    rep.metric("offered_rate_rps", rate);
    rep.metric("offered", offered as f64);
    rep.metric("completed", completed as f64);
    rep.metric("in_slo", in_slo as f64);
    rep.metric("shed", shed as f64);
    rep.metric("shed_fraction", shed as f64 / offered as f64);
    rep.metric("achieved_rps", completed as f64 / wall.as_secs_f64().max(1e-9));
    rep.metric("slo_ms", slo.as_secs_f64() * 1e3);
    rep.metric("rtt_p50_ms", p50 * 1e3);
    rep.metric("rtt_p99_ms", p99 * 1e3);
    rep.metric("rtt_p999_ms", p999 * 1e3);
    rep.metric("lost", 0.0); // conservation ensured above
    // client-side stage breakdown (Wire spans land here only if this
    // process's recorder was armed with --trace-sample)
    for (key, value) in crate::telemetry::bench_stage_metrics() {
        rep.metric(&key, value);
    }
    rep.write(&opts.out).with_context(|| format!("writing {}", opts.out.display()))?;
    println!("loadgen: wrote {}", opts.out.display());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn standard_profile_weights_sum_to_one() {
        let p = TrafficProfile::standard();
        let sum: f64 = p.routes.iter().map(|r| r.weight).sum();
        assert!((sum - 1.0).abs() < 1e-12);
        assert_eq!(p.models(), vec!["dcgan".to_string(), "gpgan".to_string()]);
    }

    #[test]
    fn route_pick_respects_weights_and_covers_the_tail() {
        let p = TrafficProfile::standard();
        assert_eq!(p.pick(0.0), 0);
        assert_eq!(p.pick(0.59), 0);
        assert_eq!(p.pick(0.61), 1);
        assert_eq!(p.pick(0.81), 2);
        // u == 1.0 can't occur from uniform(), but the clamp must hold
        assert_eq!(p.pick(1.0), 2);
    }

    #[test]
    fn arrival_plan_is_deterministic_and_monotone() {
        let p = TrafficProfile::standard();
        let lens = [8usize, 8, 8];
        let a = ArrivalPlan::generate(&p, &lens, 50, 500.0, 42);
        let b = ArrivalPlan::generate(&p, &lens, 50, 500.0, 42);
        assert_eq!(a.arrivals.len(), 50);
        for (x, y) in a.arrivals.iter().zip(&b.arrivals) {
            assert_eq!(x.offset, y.offset);
            assert_eq!(x.route, y.route);
            assert_eq!(x.input, y.input, "same seed must give identical inputs");
        }
        for w in a.arrivals.windows(2) {
            assert!(w[0].offset <= w[1].offset, "arrival offsets must be sorted");
        }
        // a different seed gives a different schedule
        let c = ArrivalPlan::generate(&p, &lens, 50, 500.0, 43);
        assert!(a.arrivals.iter().zip(&c.arrivals).any(|(x, y)| x.offset != y.offset));
    }

    #[test]
    fn capacity_is_the_weighted_reciprocal() {
        // one route, width 8, 10ms per full batch -> 800 req/s
        let profile = TrafficProfile {
            routes: vec![RouteLoad { model: "m".into(), method: "w".into(), weight: 1.0 }],
        };
        let cal = Calibration {
            service: vec![Duration::from_millis(10)],
            width: vec![8],
            input_lens: vec![8],
        };
        assert!((cal.capacity(&profile) - 800.0).abs() < 1e-6);
        assert_eq!(cal.slowest(), Duration::from_millis(10));
    }

    #[test]
    fn outcome_rates_and_shed_fraction() {
        let o = SchedulerOutcome {
            scheduler: SchedulerKind::Continuous,
            offered: 100,
            offered_rate: 100.0,
            completed: 80,
            in_slo: 60,
            shed_submit: 15,
            shed_reply: 5,
            wall: Duration::from_secs(2),
            tail: (0.010, 0.040, 0.080),
        };
        assert!((o.achieved_rate() - 40.0).abs() < 1e-9);
        assert!((o.goodput() - 30.0).abs() < 1e-9);
        assert!((o.shed_fraction() - 0.20).abs() < 1e-12);
        let r = o.report();
        assert!(r.contains("Continuous"), "{r}");
        assert!(r.contains("p99=40.00ms"), "{r}");
    }
}
