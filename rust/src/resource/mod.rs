//! FPGA resource model (paper Table II): DSP48E, BRAM18K, LUT, FF estimates
//! for the Winograd accelerator and the TDC baseline [14] on a Virtex7-485T.
//!
//! Structure-derived where the architecture dictates it, calibrated once
//! against Table II's [14] row where only HLS implementation constants can
//! decide (per-MAC LUT/FF control cost). Calibration constants are
//! documented inline; the Table II bench prints model vs paper side by side.
//!
//! Derivations (see DESIGN.md §1):
//! * one f32 MAC = 3 DSP (multiplier) + 2 DSP (adder) = **5 DSP48E**, so
//!   the T_m x T_n array costs 5·T_m·T_n = 2560 — Table II's DSP row for
//!   both designs.
//! * BRAM: input line buffer (n+m lines, T_n banks), output line buffer
//!   (2mS lines, T_m banks), double-buffered weight banks (2·T_n), and —
//!   only for the Winograd design — the n²xN rearrangement buffer the
//!   paper's §III.B/§V.C discusses. These land on 388 vs Table II's 384
//!   for [14] and 516 vs 520 for ours, within ~1% each.

use crate::accel::config::AccelConfig;
use crate::accel::linebuf::bram18k_for;
use crate::gan::workload::Method;
use crate::gan::zoo::{Gan, Kind};
use crate::tdc;
use crate::winograd::sparsity::c_of_kc;
use crate::winograd::transforms::{M as M_TILE, N as N_TILE};

/// Resource report for one design.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Resources {
    pub bram18k: usize,
    pub dsp48e: usize,
    pub lut: usize,
    pub ff: usize,
}

/// DSP usage: 5 DSP48E per f32 MAC lane (3 fmul + 2 fadd), same for every
/// method — the paper keeps tiling (and hence DSP count) identical to [14].
pub fn dsp48e(cfg: &AccelConfig) -> usize {
    5 * cfg.t_m * cfg.t_n
}

/// BRAM18K for running `g` with `method` at `cfg` tiling.
pub fn bram18k(g: &Gan, cfg: &AccelConfig, method: Method) -> usize {
    // widest input/output feature maps across deconv layers
    let w_in_max = g
        .layers
        .iter()
        .filter(|l| l.kind == Kind::Deconv)
        .map(|l| l.w_in)
        .max()
        .unwrap_or(0);
    let w_out_max = g
        .layers
        .iter()
        .filter(|l| l.kind == Kind::Deconv)
        .map(|l| l.w_out())
        .max()
        .unwrap_or(0);
    let max_kc = g
        .layers
        .iter()
        .filter(|l| l.kind == Kind::Deconv)
        .map(|l| l.kc())
        .max()
        .unwrap_or(3);
    let max_s = g
        .layers
        .iter()
        .filter(|l| l.kind == Kind::Deconv)
        .map(|l| l.s)
        .max()
        .unwrap_or(2);

    match method {
        Method::Winograd => {
            // input: n+m lines of T_n maps, one bank per lane
            let input = bram18k_for((N_TILE + M_TILE) * w_in_max * cfg.t_n, cfg.t_n);
            // output: 2mS lines of T_m maps
            let output =
                bram18k_for(2 * M_TILE * max_s * w_out_max * cfg.t_m, cfg.t_m);
            // weights: double-buffered transformed filters, 2*T_n banks,
            // depth = T_m * C(K_C) live words per group
            let c = c_of_kc(
                max_kc * max_s.min(2), // K_D back-of-envelope: K_C*S covers 4/5
                max_s,
                tdc::default_padding(max_kc * max_s.min(2), max_s),
            );
            let weights = bram18k_for(2 * c * cfg.t_m * cfg.t_n, 2 * cfg.t_n);
            // the n^2 x N rearrangement buffer (transformed input tiles),
            // ping-pong, one tile-row stripe deep
            let tiles_w = w_in_max.div_ceil(M_TILE);
            let rearrange = bram18k_for(
                N_TILE * N_TILE * cfg.t_n * 2 * tiles_w,
                cfg.t_n,
            );
            input + output + weights + rearrange
        }
        Method::Tdc => {
            let input = bram18k_for((max_kc + 1) * w_in_max * cfg.t_n, cfg.t_n);
            let output = bram18k_for(2 * max_s * w_out_max * cfg.t_m, cfg.t_m);
            let weights = bram18k_for(
                2 * max_s * max_s * max_kc * max_kc * cfg.t_m * cfg.t_n,
                2 * cfg.t_n,
            );
            input + output + weights
        }
        Method::ZeroPadded => {
            let k = max_kc * max_s; // approx K_D
            let input = bram18k_for((k + 1) * w_out_max * cfg.t_n, cfg.t_n);
            let output = bram18k_for(2 * w_out_max * cfg.t_m, cfg.t_m);
            let weights = bram18k_for(2 * k * k * cfg.t_m * cfg.t_n, 2 * cfg.t_n);
            input + output + weights
        }
    }
}

// ---------------------------------------------------------------------------
// LUT/FF model. Calibrated constants:
//  * per-MAC control/datapath glue: 160 LUT, 196 FF  (calibrated so the
//    [14] row reproduces Table II exactly: 512*160 + 12344 = 94264 LUT,
//    512*196 + 7274 = 107626 FF)
//  * base (AXI/DDR controller, FSMs): 12344 LUT, 7274 FF
//  * one f32 adder implemented in fabric: ~214 LUT / 227 FF (Xilinx
//    Floating-Point Operator v7.1 tables, no-DSP configuration)
// ---------------------------------------------------------------------------

const LUT_PER_MAC: usize = 160;
const FF_PER_MAC: usize = 196;
const LUT_BASE: usize = 12_344;
const FF_BASE: usize = 7_274;
const LUT_PER_FADD: usize = 214;
const FF_PER_FADD: usize = 227;

/// Fabric adders dedicated to the pre-PE input transform per T_n lane
/// (B^T Z B = 32 adds per tile, time-multiplexed onto 1 adder/lane across
/// the 16+ cycles a tile spends in the engine).
const PRE_PE_ADDERS_PER_LANE: usize = 1;
/// Post-PE sparse inverse transform adders per T_m lane (A^T M A <= 24
/// adds per tile over 4 output pixels).
const POST_PE_ADDERS_PER_LANE: usize = 6;
/// Gather/reorder muxing per T_n lane (the "additional logic elements ...
/// to determine the inputs according to the values of the output indexes").
const LUT_GATHER_PER_LANE: usize = 124;
const FF_GATHER_PER_LANE: usize = 72;

/// LUT/FF for the TDC baseline [14].
pub fn lut_ff_tdc(cfg: &AccelConfig) -> (usize, usize) {
    (
        LUT_BASE + LUT_PER_MAC * cfg.t_m * cfg.t_n,
        FF_BASE + FF_PER_MAC * cfg.t_m * cfg.t_n,
    )
}

/// LUT/FF for the Winograd design: [14] plus pre-PE, post-PE and gather
/// logic (the paper: "we implemented those PEs using LUTs and FFs").
pub fn lut_ff_winograd(cfg: &AccelConfig) -> (usize, usize) {
    let (base_lut, base_ff) = lut_ff_tdc(cfg);
    let pre = PRE_PE_ADDERS_PER_LANE * cfg.t_n;
    let post = POST_PE_ADDERS_PER_LANE * cfg.t_m;
    let lut = base_lut + (pre + post) * LUT_PER_FADD + LUT_GATHER_PER_LANE * cfg.t_n;
    let ff = base_ff + (pre + post) * FF_PER_FADD + FF_GATHER_PER_LANE * cfg.t_n;
    (lut, ff)
}

/// Full Table II style report for one design/method on a model.
pub fn report(g: &Gan, cfg: &AccelConfig, method: Method) -> Resources {
    let (lut, ff) = match method {
        Method::Winograd => lut_ff_winograd(cfg),
        _ => lut_ff_tdc(cfg),
    };
    Resources { bram18k: bram18k(g, cfg, method), dsp48e: dsp48e(cfg), lut, ff }
}

/// Paper Table II reference values (DCGAN on Virtex7-485T).
pub const PAPER_TABLE2_TDC: Resources =
    Resources { bram18k: 384, dsp48e: 2560, lut: 94_264, ff: 107_626 };
pub const PAPER_TABLE2_OURS: Resources =
    Resources { bram18k: 520, dsp48e: 2560, lut: 142_711, ff: 151_395 };

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gan::zoo::{self, Scale};

    fn cfg() -> AccelConfig {
        AccelConfig::default()
    }

    #[test]
    fn dsp_matches_table2_exactly() {
        assert_eq!(dsp48e(&cfg()), 2560);
    }

    #[test]
    fn tdc_lut_ff_match_table2_exactly() {
        let (lut, ff) = lut_ff_tdc(&cfg());
        assert_eq!(lut, PAPER_TABLE2_TDC.lut);
        assert_eq!(ff, PAPER_TABLE2_TDC.ff);
    }

    #[test]
    fn winograd_bram_within_5pct_of_table2() {
        let g = zoo::dcgan(Scale::Paper);
        let b = bram18k(&g, &cfg(), Method::Winograd) as f64;
        let rel = (b - 520.0).abs() / 520.0;
        assert!(rel < 0.05, "model {b} vs paper 520");
    }

    #[test]
    fn tdc_bram_within_5pct_of_table2() {
        let g = zoo::dcgan(Scale::Paper);
        let b = bram18k(&g, &cfg(), Method::Tdc) as f64;
        let rel = (b - 384.0).abs() / 384.0;
        assert!(rel < 0.05, "model {b} vs paper 384");
    }

    #[test]
    fn winograd_lut_ff_within_10pct_of_table2() {
        let (lut, ff) = lut_ff_winograd(&cfg());
        let rl = (lut as f64 - 142_711.0).abs() / 142_711.0;
        let rf = (ff as f64 - 151_395.0).abs() / 151_395.0;
        assert!(rl < 0.10, "LUT model {lut} vs paper 142711");
        assert!(rf < 0.10, "FF model {ff} vs paper 151395");
    }

    #[test]
    fn winograd_uses_more_bram_and_lut_than_tdc() {
        // the structural claim of Table II
        let g = zoo::dcgan(Scale::Paper);
        let ours = report(&g, &cfg(), Method::Winograd);
        let base = report(&g, &cfg(), Method::Tdc);
        assert!(ours.bram18k > base.bram18k);
        assert!(ours.lut > base.lut);
        assert!(ours.ff > base.ff);
        assert_eq!(ours.dsp48e, base.dsp48e);
    }

    #[test]
    fn fits_485t_envelope() {
        let g = zoo::dcgan(Scale::Paper);
        let ours = report(&g, &cfg(), Method::Winograd);
        assert!(ours.dsp48e <= crate::dse::VIRTEX7_485T.dsp48e);
        assert!(ours.bram18k <= crate::dse::VIRTEX7_485T.bram18k);
        assert!(ours.lut <= crate::dse::VIRTEX7_485T.lut);
        assert!(ours.ff <= crate::dse::VIRTEX7_485T.ff);
    }
}
