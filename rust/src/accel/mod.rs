//! FPGA accelerator simulator substrate (paper §IV–V).
//!
//! * [`config`] — testbed parameters (Virtex7-485T @ 100 MHz, 4 GB/s DDR3,
//!   T_m = 4, T_n = 128).
//! * [`linebuf`] — functional + geometric line-buffer models (§IV.B).
//! * [`cycle`] — stripe-accurate performance model (eqs. 5–9) for the
//!   zero-padded, TDC, and Winograd engines.
//! * [`functional`] — executes the Winograd/TDC dataflows on real tensors
//!   through the line buffers; bit-exact vs the standard DeConv and the
//!   source of measured event counts.

pub mod config;
pub mod cycle;
pub mod functional;
pub mod linebuf;

pub use config::AccelConfig;
pub use cycle::{simulate_layer, simulate_model, LayerSim, ModelSim};
