//! Cycle-level performance model of the three DeConv accelerators
//! (paper §IV.C eqs. 5–9 generalised to per-case zero-row skipping).
//!
//! The model is stripe-phase-accurate: for every layer it derives the
//! per-stripe compute time `T_C` (eq. 5), per-stripe transfer time `T_D`
//! (eq. 6), and the prologue `T_I` (eq. 8); ping-pong line buffers overlap
//! the two, so a stripe costs `max(T_C, T_D)` and the layer costs
//! `T_I + stripes * max(T_C, T_D)`.
//!
//! For S = m = 2 (every Table-I layer) the Winograd compute expression
//! reduces *exactly* to the paper's eq. 5 with `C(K_C)` ∈ {49, 36, 16} —
//! see `winograd::sparsity::c_of_kc` and the tests below.

use crate::accel::config::AccelConfig;
use crate::gan::workload::{self, Method};
use crate::gan::zoo::{Gan, Kind, Layer};
use crate::tdc;
use crate::winograd::sparsity::phase_cases;
use crate::winograd::transforms::{M as M_TILE, N as N_TILE};

/// Simulation result for one layer.
#[derive(Clone, Debug)]
pub struct LayerSim {
    pub method: Method,
    /// compute cycles summed over stripes
    pub cycles_compute: u64,
    /// seconds of pure compute (Σ T_C)
    pub t_compute: f64,
    /// seconds of pure transfer (Σ T_D)
    pub t_transfer: f64,
    /// prologue seconds (T_I, eq. 8)
    pub t_prologue: f64,
    /// wall-clock seconds with ping-pong overlap
    pub t_total: f64,
    /// row/tile-row stripes processed
    pub stripes: u64,
    /// multiplications issued (zero rows skipped for Winograd)
    pub mults: u64,
    /// off-chip traffic in bytes (in + out + weights)
    pub offchip_bytes: u64,
    /// off-chip activation traffic only (in + out)
    pub offchip_activation_bytes: u64,
    /// off-chip weight traffic only (amortisable across frames)
    pub offchip_weight_bytes: u64,
    /// on-chip buffer accesses (operand reads for issued mults)
    pub onchip_accesses: u64,
    /// pre/post-PE transform adds (Winograd only)
    pub transform_adds: u64,
    /// multiplications whose activation operand is a structural zero
    /// (zero-padded baseline only: the inserted-zero products). They cost
    /// cycles but almost no dynamic energy (no operand toggling).
    pub zero_operand_mults: u64,
}

/// Simulation result for a whole model.
#[derive(Clone, Debug)]
pub struct ModelSim {
    pub model: String,
    pub method: Method,
    pub layers: Vec<LayerSim>,
    pub t_total: f64,
    pub mults: u64,
    pub offchip_bytes: u64,
    pub offchip_activation_bytes: u64,
    pub offchip_weight_bytes: u64,
    pub onchip_accesses: u64,
    pub transform_adds: u64,
    pub zero_operand_mults: u64,
}

impl ModelSim {
    /// Effective throughput in GOP/s, counting the TDC-equivalent spatial
    /// work (2 ops per spatial multiply-accumulate) — the paper's
    /// "computational roof" numerator (eq. 9).
    pub fn effective_gops(&self, g: &Gan, deconv_only: bool) -> f64 {
        let work: u64 = g
            .layers
            .iter()
            .filter(|l| !deconv_only || l.kind == Kind::Deconv)
            .map(|l| 2 * workload::layer_mults(l, Method::Tdc))
            .sum();
        work as f64 / self.t_total / 1e9
    }
}

/// Per-stripe quantities for one layer under one method.
///
/// Weight traffic is tracked separately from the per-stripe activation
/// traffic: weights stream into the ping-pong weight buffers overlapped
/// with compute (the paper's eq. 6 accordingly models `T_D` from output
/// data only), so they count toward off-chip bytes (energy, Fig. 9) but
/// not toward the stripe-level transfer/compute race.
struct StripePlan {
    stripes: u64,
    compute_cycles_per_stripe: u64,
    in_bytes_per_stripe: u64,
    out_bytes_per_stripe: u64,
    /// first-n-input-rows prologue (the input part of eq. 8)
    prologue_bytes: u64,
    /// full-layer weight stream (overlapped; energy accounting only)
    weight_bytes: u64,
}

fn plan_deconv(l: &Layer, method: Method, cfg: &AccelConfig) -> StripePlan {
    let word = cfg.word_bytes as u64;
    let (m_out, n_in) = (l.c_out as u64, l.c_in as u64);
    let (h, w) = (l.h_in as u64, l.w_in as u64);
    let s = l.s as u64;
    let groups_n = n_in.div_ceil(cfg.t_n as u64);
    match method {
        Method::Winograd => {
            let tiles_w = w.div_ceil(M_TILE as u64);
            let stripes = h.div_ceil(M_TILE as u64);
            // Σ over phases: ceil(M/T_m) filter groups × live positions.
            // The dataflow reorganisation groups same-case filters, so a
            // group costs its case's live count — eq. 5's C(K_C)/m² term.
            let per_tile: u64 = phase_cases(l.k, l.s, l.p)
                .iter()
                .map(|c| m_out.div_ceil(cfg.t_m as u64) * c.live_positions() as u64)
                .sum();
            let compute = groups_n * tiles_w * per_tile;
            // new input rows per tile-row stripe: m rows of all N maps
            let in_b = M_TILE as u64 * w * n_in * word;
            // output: m*S rows of all M maps at width W_O = S*W
            let out_b = (M_TILE as u64 * s) * (s * w) * m_out * word;
            // weights: live transformed words, streamed overlapped
            let weights =
                m_out * n_in * crate::winograd::sparsity::c_of_kc(l.k, l.s, l.p) as u64 * word;
            // prologue: first n input rows (input part of eq. 8)
            let prologue = N_TILE as u64 * w * n_in * word;
            StripePlan {
                stripes,
                compute_cycles_per_stripe: compute,
                in_bytes_per_stripe: in_b,
                out_bytes_per_stripe: out_b,
                prologue_bytes: prologue,
                weight_bytes: weights,
            }
        }
        Method::Tdc => {
            let kc = tdc::kc(l.k, l.s) as u64;
            let stripes = h;
            let groups_m = (s * s * m_out).div_ceil(cfg.t_m as u64);
            let compute = groups_m * groups_n * w * kc * kc;
            let in_b = w * n_in * word;
            let out_b = s * (s * w) * m_out * word;
            let weights = s * s * m_out * n_in * kc * kc * word;
            let prologue = kc * w * n_in * word;
            StripePlan {
                stripes,
                compute_cycles_per_stripe: compute,
                in_bytes_per_stripe: in_b,
                out_bytes_per_stripe: out_b,
                prologue_bytes: prologue,
                weight_bytes: weights,
            }
        }
        Method::ZeroPadded => {
            let k = l.k as u64;
            let (ho, wo) = (s * h, s * w);
            let stripes = ho;
            let groups_m = m_out.div_ceil(cfg.t_m as u64);
            let mut compute = groups_m * groups_n * wo * k * k;
            if cfg.zp_zero_skip {
                // GANAX-style: ideally only 1/S² of dilated pixels are
                // non-zero; control overhead keeps part of the zero work.
                let ideal = compute / (s * s);
                let skipped = ((compute - ideal) as f64 * cfg.zp_skip_efficiency) as u64;
                compute -= skipped;
            }
            // the zero-padded flow materialises the up-scaled map ([9];
            // GANAX's motivating inefficiency): the dilation stage writes
            // the S^2-larger map out once (prologue) and the conv engine
            // reads it back row by row, zeros included.
            let in_b = wo * n_in * word;
            let out_b = wo * m_out * word;
            let weights = m_out * n_in * k * k * word;
            let prologue = s * s * h * w * n_in * word // dilated-map write
                + k * wo * n_in * word; // first K dilated rows
            StripePlan {
                stripes,
                compute_cycles_per_stripe: compute,
                in_bytes_per_stripe: in_b,
                out_bytes_per_stripe: out_b,
                prologue_bytes: prologue,
                weight_bytes: weights,
            }
        }
    }
}

fn plan_conv(l: &Layer, cfg: &AccelConfig) -> StripePlan {
    // DiscoGAN's encoder convs run identically on every accelerator
    // (spatial conv on the T_m x T_n array).
    let word = cfg.word_bytes as u64;
    let (m_out, n_in) = (l.c_out as u64, l.c_in as u64);
    let (ho, wo) = (l.h_out() as u64, l.w_out() as u64);
    let k = l.k as u64;
    let compute =
        m_out.div_ceil(cfg.t_m as u64) * n_in.div_ceil(cfg.t_n as u64) * wo * k * k;
    StripePlan {
        stripes: ho,
        compute_cycles_per_stripe: compute,
        in_bytes_per_stripe: l.s as u64 * l.w_in as u64 * n_in * word,
        out_bytes_per_stripe: wo * m_out * word,
        prologue_bytes: k * l.w_in as u64 * n_in * word,
        weight_bytes: m_out * n_in * k * k * word,
    }
}

/// Simulate one layer under one method.
pub fn simulate_layer(l: &Layer, method: Method, cfg: &AccelConfig) -> LayerSim {
    let plan = match l.kind {
        Kind::Deconv => plan_deconv(l, method, cfg),
        Kind::Conv => plan_conv(l, cfg),
    };
    let t_c_stripe = plan.compute_cycles_per_stripe as f64 * cfg.cycle_time();
    let t_d_stripe =
        (plan.in_bytes_per_stripe + plan.out_bytes_per_stripe) as f64 / cfg.bandwidth;
    let t_i = plan.prologue_bytes as f64 / cfg.bandwidth;
    let t_total = t_i + plan.stripes as f64 * t_c_stripe.max(t_d_stripe);
    // off-chip activation traffic: prologue input rows + steady-state
    // stripes (minus the stripes whose input arrived in the prologue)
    let act_bytes = plan.prologue_bytes
        + plan.stripes * (plan.in_bytes_per_stripe + plan.out_bytes_per_stripe)
        - (N_TILE as u64 / M_TILE as u64).min(plan.stripes) * plan.in_bytes_per_stripe;
    let offchip = plan.weight_bytes + act_bytes;
    LayerSim {
        method,
        cycles_compute: plan.stripes * plan.compute_cycles_per_stripe,
        t_compute: plan.stripes as f64 * t_c_stripe,
        t_transfer: plan.stripes as f64 * t_d_stripe,
        t_prologue: t_i,
        t_total,
        stripes: plan.stripes,
        mults: workload::layer_mults(l, method),
        offchip_bytes: offchip,
        offchip_activation_bytes: act_bytes,
        offchip_weight_bytes: plan.weight_bytes,
        onchip_accesses: workload::layer_onchip_accesses(l, method),
        transform_adds: workload::layer_transform_adds(l, method),
        zero_operand_mults: if l.kind == Kind::Deconv && method == Method::ZeroPadded {
            // all products beyond the real (TDC-equivalent) taps hit an
            // inserted zero
            workload::layer_mults(l, Method::ZeroPadded)
                - workload::layer_mults(l, Method::Tdc)
        } else {
            0
        },
    }
}

/// Simulate a whole model. `deconv_only` mirrors the paper's Fig. 8 scope
/// ("we focused on DeConv performance").
pub fn simulate_model(g: &Gan, method: Method, cfg: &AccelConfig, deconv_only: bool) -> ModelSim {
    let layers: Vec<LayerSim> = g
        .layers
        .iter()
        .filter(|l| !deconv_only || l.kind == Kind::Deconv)
        .map(|l| simulate_layer(l, method, cfg))
        .collect();
    ModelSim {
        model: g.name.to_string(),
        method,
        t_total: layers.iter().map(|l| l.t_total).sum(),
        mults: layers.iter().map(|l| l.mults).sum(),
        offchip_bytes: layers.iter().map(|l| l.offchip_bytes).sum(),
        offchip_activation_bytes: layers.iter().map(|l| l.offchip_activation_bytes).sum(),
        offchip_weight_bytes: layers.iter().map(|l| l.offchip_weight_bytes).sum(),
        onchip_accesses: layers.iter().map(|l| l.onchip_accesses).sum(),
        transform_adds: layers.iter().map(|l| l.transform_adds).sum(),
        zero_operand_mults: layers.iter().map(|l| l.zero_operand_mults).sum(),
        layers,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gan::zoo::{self, Scale};

    fn cfg() -> AccelConfig {
        AccelConfig::default()
    }

    #[test]
    fn winograd_compute_matches_paper_eq5() {
        // For S = m = 2 our per-case sum must equal eq. 5:
        // ceil(S²M/T_m)·ceil(N/T_n)·ceil(W_I/m)·C(K_C)/m² cycles per stripe.
        let g = zoo::dcgan(Scale::Paper);
        let l = &g.layers[1]; // 512 -> 256, K=5, S=2, 8x8
        let sim = simulate_layer(l, Method::Winograd, &cfg());
        let c = cfg();
        let eq5_per_stripe = ((l.s * l.s * l.c_out) as u64).div_ceil(c.t_m as u64)
            * (l.c_in as u64).div_ceil(c.t_n as u64)
            * (l.w_in as u64).div_ceil(2)
            * 49
            / 4;
        assert_eq!(
            sim.cycles_compute,
            sim.stripes * eq5_per_stripe,
            "per-case sum should reduce to eq. 5 for S=m=2"
        );
    }

    #[test]
    fn method_ordering_per_model() {
        for g in zoo::all(Scale::Paper) {
            let zp = simulate_model(&g, Method::ZeroPadded, &cfg(), true);
            let td = simulate_model(&g, Method::Tdc, &cfg(), true);
            let wi = simulate_model(&g, Method::Winograd, &cfg(), true);
            assert!(wi.t_total < td.t_total, "{}: winograd < tdc", g.name);
            assert!(td.t_total < zp.t_total, "{}: tdc < zero-padded", g.name);
        }
    }

    #[test]
    fn dcgan_speedups_in_paper_band() {
        // Paper Fig. 8: DCGAN 8.38x vs zero-padded, 2.85x vs TDC. Our
        // simulator reproduces the shape; accept a band around the claims.
        let g = zoo::dcgan(Scale::Paper);
        let zp = simulate_model(&g, Method::ZeroPadded, &cfg(), true);
        let td = simulate_model(&g, Method::Tdc, &cfg(), true);
        let wi = simulate_model(&g, Method::Winograd, &cfg(), true);
        let s_zp = zp.t_total / wi.t_total;
        let s_td = td.t_total / wi.t_total;
        assert!(s_zp > 6.0 && s_zp < 10.0, "ZP speedup {s_zp}");
        assert!(s_td > 2.2 && s_td < 3.4, "TDC speedup {s_td}");
    }

    #[test]
    fn zero_skip_helps_zero_padded_but_not_past_tdc() {
        let g = zoo::dcgan(Scale::Paper);
        let plain = simulate_model(&g, Method::ZeroPadded, &cfg(), true);
        let skip = simulate_model(
            &g,
            Method::ZeroPadded,
            &cfg().with_zero_skip(true),
            true,
        );
        let td = simulate_model(&g, Method::Tdc, &cfg(), true);
        assert!(skip.t_total < plain.t_total);
        assert!(td.t_total <= skip.t_total, "TDC has no skip overhead");
    }

    #[test]
    fn cycles_scale_with_workload() {
        // monotonicity: doubling channels should not reduce time
        let mut l = zoo::dcgan(Scale::Paper).layers[0];
        let base = simulate_layer(&l, Method::Winograd, &cfg()).t_total;
        l.c_in *= 2;
        let bigger = simulate_layer(&l, Method::Winograd, &cfg()).t_total;
        assert!(bigger >= base);
    }

    #[test]
    fn bandwidth_bound_when_starved() {
        // at tiny bandwidth the layer becomes transfer-bound: total ≈ T_D
        let g = zoo::dcgan(Scale::Paper);
        let l = &g.layers[3];
        let starved = cfg().with_bandwidth(1e6);
        let sim = simulate_layer(l, Method::Winograd, &starved);
        assert!(sim.t_transfer > sim.t_compute * 10.0);
        assert!((sim.t_total - (sim.t_prologue + sim.t_transfer)).abs() / sim.t_total < 1e-9);
    }

    #[test]
    fn deconv_only_excludes_encoder() {
        let g = zoo::discogan(Scale::Paper);
        let dec = simulate_model(&g, Method::Winograd, &cfg(), true);
        let full = simulate_model(&g, Method::Winograd, &cfg(), false);
        assert_eq!(dec.layers.len(), 4);
        assert_eq!(full.layers.len(), 9);
        assert!(full.t_total > dec.t_total);
    }
}
