//! Line buffers (paper §IV.B): simple-dual-port on-chip row buffers that
//! overlap PE compute with DDR transfer (ping-pong).
//!
//! Two roles here:
//! * a *functional* ring-of-rows buffer used by the functional simulator
//!   (windows are read out of it exactly as the hardware would), with
//!   access counting for the energy model;
//! * *geometry* helpers (`bram18k_for`) shared with the resource model:
//!   the paper stores `n+m` lines of `T_n` input maps and `2*m*S` lines of
//!   `T_m` output maps.

/// Functional line buffer: holds the most recent `depth` rows of a
/// `channels x width` feature-map slab. Rows are pushed whole (modelling a
/// DDR burst into one bank) and read through 2D windows.
#[derive(Clone, Debug)]
pub struct LineBuffer {
    pub channels: usize,
    pub width: usize,
    pub depth: usize,
    /// ring of rows; rows[r][c * width + x] with r relative to `first_row`
    rows: Vec<Vec<f64>>,
    /// absolute index of the oldest row held
    first_row: usize,
    n_rows_pushed: usize,
    /// counted accesses for the energy model
    pub reads: u64,
    pub writes: u64,
}

impl LineBuffer {
    pub fn new(channels: usize, width: usize, depth: usize) -> Self {
        LineBuffer {
            channels,
            width,
            depth,
            rows: Vec::new(),
            first_row: 0,
            n_rows_pushed: 0,
            reads: 0,
            writes: 0,
        }
    }

    /// Push one row (all channels); evicts the oldest row when full.
    pub fn push_row(&mut self, row: Vec<f64>) {
        assert_eq!(row.len(), self.channels * self.width, "row size mismatch");
        self.writes += row.len() as u64;
        if self.rows.len() == self.depth {
            self.rows.remove(0);
            self.first_row += 1;
        }
        self.rows.push(row);
        self.n_rows_pushed += 1;
    }

    /// Number of rows pushed so far (absolute row cursor).
    pub fn rows_pushed(&self) -> usize {
        self.n_rows_pushed
    }

    /// Read element (c, absolute_row, x); panics if the row was evicted —
    /// that would be a dataflow bug (window slid past the buffer depth).
    pub fn read(&mut self, c: usize, abs_row: usize, x: usize) -> f64 {
        assert!(
            abs_row >= self.first_row && abs_row < self.first_row + self.rows.len(),
            "row {abs_row} not resident (have {}..{})",
            self.first_row,
            self.first_row + self.rows.len()
        );
        self.reads += 1;
        self.rows[abs_row - self.first_row][c * self.width + x]
    }

    /// Read an `RH x RW` window for one channel with a single residency
    /// check (models the hardware's wide window-select read; still counts
    /// every word for the energy model).
    pub fn read_window<const RH: usize, const RW: usize>(
        &mut self,
        c: usize,
        top_abs_row: usize,
        left: usize,
    ) -> [[f64; RW]; RH] {
        assert!(
            top_abs_row >= self.first_row
                && top_abs_row + RH <= self.first_row + self.rows.len(),
            "window rows {top_abs_row}..{} not resident (have {}..{})",
            top_abs_row + RH,
            self.first_row,
            self.first_row + self.rows.len()
        );
        self.reads += (RH * RW) as u64;
        let mut out = [[0.0; RW]; RH];
        for (i, row) in out.iter_mut().enumerate() {
            let src = &self.rows[top_abs_row - self.first_row + i]
                [c * self.width + left..c * self.width + left + RW];
            row.copy_from_slice(src);
        }
        out
    }
}

/// BRAM18K blocks needed to hold `words` f32 words with `banks` independent
/// ports-worth of banking. A Virtex-7 BRAM18K holds 512 x 36b = 512 words
/// of 32 bits (with parity bits unused); simple dual port.
pub fn bram18k_for(words: usize, banks: usize) -> usize {
    let per_bank_words = words.div_ceil(banks.max(1));
    let blocks_per_bank = per_bank_words.div_ceil(512);
    blocks_per_bank * banks.max(1)
}

/// Input line-buffer geometry (paper: `n+m` lines of `T_n` maps).
pub fn input_buffer_words(t_n: usize, width: usize, n: usize, m: usize) -> usize {
    (n + m) * width * t_n
}

/// Output line-buffer geometry (paper: `2*m*S` lines of `T_m` maps, widths
/// are output widths `S * W_I`).
pub fn output_buffer_words(t_m: usize, width_out: usize, m: usize, s: usize) -> usize {
    2 * m * s * width_out * t_m
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_eviction_and_reads() {
        let mut lb = LineBuffer::new(1, 4, 2);
        lb.push_row(vec![0.0, 1.0, 2.0, 3.0]);
        lb.push_row(vec![4.0, 5.0, 6.0, 7.0]);
        assert_eq!(lb.read(0, 0, 1), 1.0);
        assert_eq!(lb.read(0, 1, 2), 6.0);
        lb.push_row(vec![8.0, 9.0, 10.0, 11.0]);
        assert_eq!(lb.read(0, 2, 0), 8.0);
    }

    #[test]
    #[should_panic(expected = "not resident")]
    fn evicted_row_panics() {
        let mut lb = LineBuffer::new(1, 2, 2);
        lb.push_row(vec![0.0, 0.0]);
        lb.push_row(vec![0.0, 0.0]);
        lb.push_row(vec![0.0, 0.0]);
        lb.read(0, 0, 0);
    }

    #[test]
    fn access_counters() {
        let mut lb = LineBuffer::new(2, 3, 2);
        lb.push_row(vec![0.0; 6]);
        lb.read(1, 0, 2);
        lb.read(0, 0, 0);
        assert_eq!(lb.writes, 6);
        assert_eq!(lb.reads, 2);
    }

    #[test]
    fn bram_geometry() {
        // 512 words exactly fit one block
        assert_eq!(bram18k_for(512, 1), 1);
        assert_eq!(bram18k_for(513, 1), 2);
        // banking multiplies block granularity
        assert_eq!(bram18k_for(1024, 4), 4);
        assert_eq!(bram18k_for(100, 4), 4);
    }

    #[test]
    fn paper_buffer_shapes() {
        // n+m = 6 lines of T_n=128 maps, width 32: 6*32*128 words
        assert_eq!(input_buffer_words(128, 32, 4, 2), 6 * 32 * 128);
        // 2*m*S = 8 lines of T_m=4 maps at output width 64
        assert_eq!(output_buffer_words(4, 64, 2, 2), 8 * 64 * 4);
    }
}
