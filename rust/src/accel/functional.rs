//! Functional accelerator simulator: executes the Winograd DeConv dataflow
//! on real tensors *through the modelled architecture* — phase-padded
//! windows read from line buffers, pre-PE transform + reorder, com-PE
//! multiply over live rows only, sparse post-PE inverse transform, phase
//! interleave — and is checked bit-for-bit (f64) against the standard
//! DeConv reference.
//!
//! This is the architecture-level evidence for the paper's Fig. 2/3
//! equivalence claim: the fast algorithm on this dataflow computes exactly
//! the standard DeConv. It also produces *measured* event counts (mults,
//! buffer accesses) that the cycle and energy models are validated against.

use crate::accel::linebuf::LineBuffer;
use crate::tdc::{self, PhaseFilter};
use crate::util::elem::Elem;
use crate::util::tensor::{Filter4, Tensor3};
use crate::winograd::layout::{engine_multiply, reorder_filter, ReorderedTile};
use crate::winograd::transforms::{input_transform, inverse_transform, Tile4, M, N};

/// Measured events from a functional run.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct Events {
    pub mults: u64,
    pub linebuf_reads: u64,
    pub linebuf_writes: u64,
    pub tiles: u64,
    pub stripes: u64,
}

impl Events {
    /// Accumulate another event count into this one (used by the engine's
    /// per-layer / per-worker aggregation).
    pub fn merge(&mut self, other: &Events) {
        self.mults += other.mults;
        self.linebuf_reads += other.linebuf_reads;
        self.linebuf_writes += other.linebuf_writes;
        self.tiles += other.tiles;
        self.stripes += other.stripes;
    }
}

/// Result of simulating one DeConv layer functionally.
#[derive(Debug)]
pub struct FunctionalRun {
    pub y: Tensor3,
    pub events: Events,
}

/// Phase-padded input view for tile-aligned Winograd: shift by the phase's
/// TDC input offset and zero-pad to `(ho_t + R - 1) x (wo_t + R - 1)`.
/// Shared with the precompiled-plan engine (`crate::engine`) so the two
/// datapaths stay bit-identical by construction; generic over the element
/// precision because the engine runs it at both tiers.
pub fn phase_padded<E: Elem>(
    x: &Tensor3<E>,
    ph: &PhaseFilter<E>,
    ho_t: usize,
    wo_t: usize,
) -> Tensor3<E> {
    let mut out = Tensor3::zeros(0, 0, 0);
    phase_padded_into(x, ph, ho_t, wo_t, &mut out);
    out
}

/// [`phase_padded`] into a caller-owned scratch tensor: identical contents,
/// but the scratch's allocation is reused across phases and layers. This is
/// the variant the execution engine's per-run scratch arena uses, so the
/// full phase-padded map is materialized without a fresh allocation per
/// phase.
pub fn phase_padded_into<E: Elem>(
    x: &Tensor3<E>,
    ph: &PhaseFilter<E>,
    ho_t: usize,
    wo_t: usize,
    out: &mut Tensor3<E>,
) {
    let ly = (-ph.d0y) as usize;
    let lx = (-ph.d0x) as usize;
    let ry = (ho_t + crate::winograd::R - 1) - x.h - ly;
    let rx = (wo_t + crate::winograd::R - 1) - x.w - lx;
    x.pad_into(ly, ry, lx, rx, out);
}

/// Simulate one Winograd DeConv layer through the line-buffered dataflow.
pub fn run_winograd_deconv(x: &Tensor3, w: &Filter4, s: usize, p: usize) -> FunctionalRun {
    let mut y = Tensor3::zeros(w.c_out, s * x.h, s * x.w);
    let mut ev = Events::default();
    let phases = tdc::decompose(w, s, p);

    // tile-aligned per-phase output extent
    let ho_t = x.h.div_ceil(M) * M;
    let wo_t = x.w.div_ceil(M) * M;
    let tiles_h = ho_t / M;
    let tiles_w = wo_t / M;

    for (idx, ph) in phases.iter().enumerate() {
        let (py, px) = (idx / s, idx % s);
        let rf = reorder_filter(ph);
        if rf.live.is_empty() {
            // degenerate zero-tap phase: identically zero sub-filter, so
            // its output samples stay at the pre-zeroed y — skip the whole
            // dataflow for this phase (the engine does the same)
            continue;
        }
        let xp = phase_padded(x, ph, ho_t, wo_t);

        // input line buffer: n+m lines of the phase-padded map (paper §IV.B)
        let mut lb = LineBuffer::new(xp.c, xp.w, N + M);
        // prologue: first n rows
        for row in 0..N {
            lb.push_row(row_of(&xp, row));
        }

        for ty in 0..tiles_h {
            ev.stripes += 1;
            let base_row = M * ty;
            // ensure rows [base_row, base_row + N) resident
            while lb.rows_pushed() < base_row + N {
                let r = lb.rows_pushed();
                lb.push_row(row_of(&xp, r));
            }
            for tx in 0..tiles_w {
                ev.tiles += 1;
                // pre-PE: window select + B^T Z B + reorder to n^2 x N
                let mut v = vec![0.0; 16 * xp.c];
                for ci in 0..xp.c {
                    let z: Tile4 = lb.read_window::<N, N>(ci, base_row, M * tx);
                    let vt = input_transform(&z);
                    for i in 0..N {
                        for j in 0..N {
                            v[(i * N + j) * xp.c + ci] = vt[i][j];
                        }
                    }
                }
                let vt = ReorderedTile { c_in: xp.c, v };
                // com-PE: live rows only
                let (m_acc, mults) = engine_multiply(&rf, &vt);
                ev.mults += mults as u64;
                // post-PE: sparse inverse transform + phase scatter
                for co in 0..w.c_out {
                    let yt = inverse_transform(&m_acc[co]);
                    for a in 0..M {
                        for b in 0..M {
                            let oy = M * ty + a;
                            let ox = M * tx + b;
                            if oy < x.h && ox < x.w {
                                *y.at_mut(co, s * oy + py, s * ox + px) = yt[a][b];
                            }
                        }
                    }
                }
            }
        }
        ev.linebuf_reads += lb.reads;
        ev.linebuf_writes += lb.writes;
    }
    FunctionalRun { y, events: ev }
}

/// Simulate the TDC baseline dataflow (row line buffer, S^2 correlations).
pub fn run_tdc_deconv(x: &Tensor3, w: &Filter4, s: usize, p: usize) -> FunctionalRun {
    let kc = tdc::kc(w.kh, s);
    let phases = tdc::decompose(w, s, p);
    let mut y = Tensor3::zeros(w.c_out, s * x.h, s * x.w);
    let mut ev = Events::default();
    for (idx, ph) in phases.iter().enumerate() {
        let (py, px) = (idx / s, idx % s);
        let xp = tdc::phase_pad(x, ph.d0y, ph.d0x, kc);
        let mut lb = LineBuffer::new(xp.c, xp.w, kc + 1);
        for row in 0..kc {
            lb.push_row(row_of(&xp, row));
        }
        for oy in 0..x.h {
            ev.stripes += 1;
            while lb.rows_pushed() < oy + kc {
                let r = lb.rows_pushed();
                lb.push_row(row_of(&xp, r));
            }
            for ox in 0..x.w {
                for co in 0..w.c_out {
                    let mut acc = 0.0;
                    for ci in 0..xp.c {
                        for ky in 0..kc {
                            for kx in 0..kc {
                                acc += lb.read(ci, oy + ky, ox + kx) * ph.g.at(ci, co, ky, kx);
                                ev.mults += 1;
                            }
                        }
                    }
                    *y.at_mut(co, s * oy + py, s * ox + px) = acc;
                }
            }
        }
        ev.linebuf_reads += lb.reads;
        ev.linebuf_writes += lb.writes;
    }
    FunctionalRun { y, events: ev }
}

fn row_of(x: &Tensor3, row: usize) -> Vec<f64> {
    let mut out = Vec::with_capacity(x.c * x.w);
    for c in 0..x.c {
        for j in 0..x.w {
            out.push(x.at(c, row, j));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gan::workload::{layer_mults, Method};
    use crate::gan::zoo::Layer;
    use crate::tdc::{deconv_naive, default_padding};
    use crate::util::prng::Rng;

    fn rand3(rng: &mut Rng, c: usize, h: usize, w: usize) -> Tensor3 {
        Tensor3::from_vec(c, h, w, rng.normal_vec(c * h * w))
    }

    fn rand4(rng: &mut Rng, ci: usize, co: usize, k: usize) -> Filter4 {
        Filter4::from_vec(ci, co, k, k, rng.normal_vec(ci * co * k * k))
    }

    #[test]
    fn winograd_dataflow_equals_standard_deconv() {
        let mut rng = Rng::new(500);
        for &(k, s) in &[(5usize, 2usize), (4, 2), (3, 1)] {
            let p = default_padding(k, s);
            let x = rand3(&mut rng, 3, 6, 8);
            let w = rand4(&mut rng, 3, 2, k);
            let want = deconv_naive(&x, &w, s, p);
            let run = run_winograd_deconv(&x, &w, s, p);
            assert!(
                want.max_abs_diff(&run.y) < 1e-10,
                "K={k} S={s}: {}",
                want.max_abs_diff(&run.y)
            );
        }
    }

    #[test]
    fn tdc_dataflow_equals_standard_deconv() {
        let mut rng = Rng::new(501);
        for &(k, s) in &[(5usize, 2usize), (4, 2), (3, 1)] {
            let p = default_padding(k, s);
            let x = rand3(&mut rng, 2, 5, 7);
            let w = rand4(&mut rng, 2, 3, k);
            let want = deconv_naive(&x, &w, s, p);
            let run = run_tdc_deconv(&x, &w, s, p);
            assert!(want.max_abs_diff(&run.y) < 1e-10, "K={k} S={s}");
        }
    }

    #[test]
    fn measured_mults_match_analytic_model() {
        // tile-aligned case: the functional engine's issued multiplications
        // must equal the Fig. 4 analytic count exactly
        let mut rng = Rng::new(502);
        for &(k, s) in &[(5usize, 2usize), (4, 2)] {
            let p = default_padding(k, s);
            let (c_in, c_out, h, w_sp) = (3usize, 2usize, 8usize, 8usize);
            let x = rand3(&mut rng, c_in, h, w_sp);
            let w = rand4(&mut rng, c_in, c_out, k);
            let run = run_winograd_deconv(&x, &w, s, p);
            let l = Layer {
                kind: crate::gan::zoo::Kind::Deconv,
                c_in,
                c_out,
                k,
                s,
                p,
                h_in: h,
                w_in: w_sp,
                act: crate::gan::zoo::Activation::Linear,
            };
            assert_eq!(run.events.mults, layer_mults(&l, Method::Winograd), "K={k}");
            let run_t = run_tdc_deconv(&x, &w, s, p);
            assert_eq!(run_t.events.mults, layer_mults(&l, Method::Tdc), "K={k} tdc");
        }
    }

    #[test]
    fn winograd_issues_fewer_mults_than_tdc() {
        let mut rng = Rng::new(503);
        let x = rand3(&mut rng, 2, 8, 8);
        let w = rand4(&mut rng, 2, 2, 4);
        let wi = run_winograd_deconv(&x, &w, 2, 1);
        let td = run_tdc_deconv(&x, &w, 2, 1);
        assert!(wi.events.mults < td.events.mults);
        // K=4: exactly 9/16 of the TDC multiplications (all Case 3)
        assert_eq!(wi.events.mults * 16, td.events.mults * 9);
    }

    #[test]
    fn odd_sizes_tile_pad_correctly() {
        let mut rng = Rng::new(504);
        let x = rand3(&mut rng, 2, 5, 7); // odd H, W force tile padding
        let w = rand4(&mut rng, 2, 3, 5);
        let want = deconv_naive(&x, &w, 2, 2);
        let run = run_winograd_deconv(&x, &w, 2, 2);
        assert!(want.max_abs_diff(&run.y) < 1e-10);
    }
}
