//! Accelerator configuration: the paper's testbed parameters
//! (§V.A: Xilinx Virtex7 485T, 100 MHz, DDR3 @ 4 GB/s, f32) and the
//! tiling factors chosen by the DSE (§IV.C: T_m = 4, T_n = 128).

/// Static configuration of one simulated accelerator instance.
#[derive(Clone, Copy, Debug)]
pub struct AccelConfig {
    /// output-feature-map tile factor (PE rows)
    pub t_m: usize,
    /// input-feature-map tile factor (PE columns)
    pub t_n: usize,
    /// clock frequency in Hz
    pub freq_hz: f64,
    /// off-chip bandwidth in bytes/second
    pub bandwidth: f64,
    /// word width in bytes (single-precision float)
    pub word_bytes: usize,
    /// zero-activation skipping for the zero-padded baseline (GANAX-style
    /// [10]); models their "skip some of the padded zero activations" with
    /// a control-overhead factor. Off for the plain baseline.
    pub zp_zero_skip: bool,
    /// fraction of ideal skip the MIMD-SIMD control actually achieves
    /// (GANAX reports ~0.6-0.8 of ideal; only used when zp_zero_skip)
    pub zp_skip_efficiency: f64,
}

impl Default for AccelConfig {
    fn default() -> Self {
        AccelConfig {
            t_m: 4,
            t_n: 128,
            freq_hz: 100e6,
            bandwidth: 4.0e9,
            word_bytes: 4,
            zp_zero_skip: false,
            zp_skip_efficiency: 0.7,
        }
    }
}

impl AccelConfig {
    /// Parallel multipliers in the com-PE array.
    pub fn macs(&self) -> usize {
        self.t_m * self.t_n
    }

    /// Seconds per cycle.
    pub fn cycle_time(&self) -> f64 {
        1.0 / self.freq_hz
    }

    pub fn with_tiles(mut self, t_m: usize, t_n: usize) -> Self {
        self.t_m = t_m;
        self.t_n = t_n;
        self
    }

    pub fn with_bandwidth(mut self, bytes_per_s: f64) -> Self {
        self.bandwidth = bytes_per_s;
        self
    }

    pub fn with_zero_skip(mut self, on: bool) -> Self {
        self.zp_zero_skip = on;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_matches_paper_testbed() {
        let c = AccelConfig::default();
        assert_eq!(c.t_m, 4);
        assert_eq!(c.t_n, 128);
        assert_eq!(c.macs(), 512);
        assert_eq!(c.freq_hz, 100e6);
        assert_eq!(c.bandwidth, 4.0e9);
        assert_eq!(c.word_bytes, 4);
    }

    #[test]
    fn builders() {
        let c = AccelConfig::default().with_tiles(8, 64).with_bandwidth(1e9);
        assert_eq!(c.macs(), 512);
        assert_eq!(c.bandwidth, 1e9);
    }
}
