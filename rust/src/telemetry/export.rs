//! Scrape formats for the telemetry plane.
//!
//! Every scrapeable endpoint (the `MetricsQuery` wire verb on replica and
//! router) serves the same underlying document in two formats:
//!
//! * **stable-key JSON** — the [`crate::util::json::Json`] document
//!   assembled by the serving tier (metrics snapshot + stage histograms +
//!   role/health fields); BTreeMap ordering makes the key order, and
//!   therefore the serialized bytes for a given state, deterministic;
//! * **Prometheus text exposition** — [`prometheus`] flattens that same
//!   document into `wingan_*` gauge lines, so any Prometheus-compatible
//!   scraper can ingest the fleet without a sidecar.
//!
//! The Prometheus view is a *projection*: numeric and boolean leaves are
//! kept (path segments joined with `_`, sanitized to the metric-name
//! alphabet), strings and arrays are dropped (they are reachable through
//! the JSON view). Stage histograms therefore surface as
//! `wingan_stages_<stage>_{count,mean_ms,p50_ms,p95_ms,p99_ms,p999_ms,max_ms}`
//! — the stage-latency keys the CI smoke asserts on.

use crate::util::json::Json;
use std::fmt::Write as _;

/// Flatten `doc` into Prometheus text exposition format.
///
/// Each numeric (or boolean, as 0/1) leaf becomes one gauge sample named
/// `wingan_<path>` where `<path>` joins the object keys from the root
/// with `_`, lowercased, with every character outside `[a-z0-9_]`
/// replaced by `_`. A `# TYPE <name> gauge` comment precedes every
/// sample, in the document's (stable) key order. Non-finite numbers,
/// strings, nulls, and arrays are omitted.
pub fn prometheus(doc: &Json) -> String {
    let mut out = String::new();
    flatten("wingan", doc, &mut out);
    out
}

fn flatten(path: &str, v: &Json, out: &mut String) {
    match v {
        Json::Num(n) => {
            if n.is_finite() {
                let _ = writeln!(out, "# TYPE {path} gauge");
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = writeln!(out, "{path} {}", *n as i64);
                } else {
                    let _ = writeln!(out, "{path} {n}");
                }
            }
        }
        Json::Bool(b) => {
            let _ = writeln!(out, "# TYPE {path} gauge");
            let _ = writeln!(out, "{path} {}", u8::from(*b));
        }
        Json::Obj(map) => {
            for (k, val) in map {
                flatten(&format!("{path}_{}", sanitize(k)), val, out);
            }
        }
        Json::Null | Json::Str(_) | Json::Arr(_) => {}
    }
}

/// Map an arbitrary JSON key into the Prometheus metric-name alphabet.
fn sanitize(key: &str) -> String {
    key.chars()
        .map(|c| match c.to_ascii_lowercase() {
            c if c.is_ascii_lowercase() || c.is_ascii_digit() => c,
            _ => '_',
        })
        .collect()
}

/// True when `text` is well-formed Prometheus text exposition: every
/// line is either a `#`-prefixed comment or `<name> <float>` with a
/// valid metric name. The CI smoke and the unit tests share this
/// definition of "parses".
pub fn prometheus_well_formed(text: &str) -> bool {
    if text.trim().is_empty() {
        return false;
    }
    text.lines().all(|line| {
        if line.is_empty() || line.starts_with('#') {
            return true;
        }
        let Some((name, value)) = line.split_once(' ') else {
            return false;
        };
        let name_ok = !name.is_empty()
            && !name.starts_with(|c: char| c.is_ascii_digit())
            && name.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':');
        name_ok && value.parse::<f64>().is_ok()
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::{self, parse};

    #[test]
    fn flattens_nested_numeric_leaves_in_stable_order() {
        let doc = parse(
            r#"{"requests": 7, "stages": {"winograd_gemm": {"count": 2, "p99_ms": 1.5}},
                "role": "replica", "ready": true}"#,
        )
        .unwrap();
        let text = prometheus(&doc);
        let lines: Vec<&str> = text.lines().filter(|l| !l.starts_with('#')).collect();
        assert_eq!(
            lines,
            vec![
                "wingan_ready 1",
                "wingan_requests 7",
                "wingan_stages_winograd_gemm_count 2",
                "wingan_stages_winograd_gemm_p99_ms 1.5",
            ],
            "BTreeMap order makes the exposition deterministic"
        );
        assert!(text.contains("# TYPE wingan_requests gauge"));
        assert!(prometheus_well_formed(&text), "{text}");
    }

    #[test]
    fn strings_arrays_and_nulls_are_projected_out() {
        let doc = parse(r#"{"role": "router", "routes": [1, 2], "x": null, "n": 3}"#).unwrap();
        let text = prometheus(&doc);
        assert!(text.contains("wingan_n 3"));
        assert!(!text.contains("router"), "{text}");
        assert!(!text.contains("routes"), "{text}");
        assert!(prometheus_well_formed(&text));
    }

    #[test]
    fn hostile_keys_are_sanitized() {
        let doc = json::obj(vec![(
            "dcgan/winograd p99 (ms)",
            json::num(2.0),
        )]);
        let text = prometheus(&doc);
        assert!(text.contains("wingan_dcgan_winograd_p99__ms_ 2"), "{text}");
        assert!(prometheus_well_formed(&text), "sanitized names must stay well-formed: {text}");
    }

    #[test]
    fn non_finite_numbers_are_skipped() {
        let doc = json::obj(vec![("ok", json::num(1.0)), ("bad", json::num(f64::NAN))]);
        let text = prometheus(&doc);
        assert!(text.contains("wingan_ok 1"));
        assert!(!text.contains("bad"), "{text}");
        assert!(prometheus_well_formed(&text));
    }

    #[test]
    fn well_formedness_rejects_garbage() {
        assert!(!prometheus_well_formed(""));
        assert!(!prometheus_well_formed("   \n"));
        assert!(!prometheus_well_formed("not a metric line at all"));
        assert!(!prometheus_well_formed("1leading_digit 3"));
        assert!(!prometheus_well_formed("name not_a_number"));
        assert!(prometheus_well_formed("# HELP x\nwingan_x 1\n"));
    }
}
