//! End-to-end request tracing and the in-process flight recorder.
//!
//! The serving tiers (PRs 7–9) answer *whether* the fleet is healthy;
//! this module answers *where the time went*. It gives every sampled
//! request a [`TraceId`] minted at admission (router or coordinator),
//! records compact [`SpanEvent`]s at each stage of the datapath —
//! admission, queue, batch-assemble, dispatch, the per-layer Winograd
//! engine stages (input transform / Winograd-domain GEMM / inverse
//! transform / activation), wire round-trips, and per-attempt failover
//! verdicts — and exposes the result through the scrapeable telemetry
//! plane ([`export`], the `MetricsQuery`/`TraceQuery` wire verbs, and the
//! `wingan trace` / `wingan top` CLI frontends).
//!
//! # Design constraints
//!
//! * **~Zero cost when disabled.** Sampling defaults to off; every
//!   recording site guards on one relaxed atomic load (the same idiom as
//!   the fault-injection plane's enable flag) and the trace id `0` means
//!   "untraced" everywhere, so the hot path pays a branch, not a lock.
//! * **Never perturbs outputs.** Recording only reads clocks and appends
//!   to ring buffers; it runs strictly outside the arithmetic, so f64
//!   outputs and [`crate::accel::functional::Events`] counts are
//!   bit-identical with tracing on or off (pinned by proptest).
//! * **Lock-light and poison-safe.** Span events land in fixed-size
//!   per-worker ring buffers (each thread hashes to its own slot, so the
//!   per-ring mutexes are effectively uncontended) taken through
//!   [`crate::util::lock_unpoisoned`] — a contained engine panic cannot
//!   poison the recorder, which is exactly when the rings are most
//!   valuable: a `Crashed`/bisection incident can be reconstructed
//!   post-mortem from the events that led up to it.
//! * **Seeded-sampleable.** The 1-in-N sampling decision and the minted
//!   trace ids are a pure function of the configured `(sample_every,
//!   seed)` and the admission counter, so a given load replays with the
//!   same requests traced.
//!
//! Trace ids are minted below 2^53 so they survive the JSON number
//! round-trip (the wire carries them as `u64`, the telemetry docs as
//! f64-exact integers).

pub mod export;

use crate::coordinator::metrics::Histogram;
use crate::util::json::{self, Json};
use crate::util::lock_unpoisoned;
use std::cell::Cell;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::{Duration, Instant};

/// A request's end-to-end trace identity. `0` means "untraced" — every
/// recording site treats it as "do nothing", and the wire omits the
/// optional trace field entirely for untraced requests so their frames
/// are byte-identical to the pre-telemetry encoding.
pub type TraceId = u64;

/// Number of ring buffers the recorder shards events over. Threads hash
/// to a slot by arrival order; 16 slots keep the per-ring mutexes
/// effectively private to one worker under typical pool widths.
const N_RINGS: usize = 16;

/// Per-ring event capacity. The recorder is a *flight recorder*: old
/// events are overwritten, post-mortems see the most recent
/// `N_RINGS * RING_CAP` spans.
const RING_CAP: usize = 4096;

/// The datapath stages a span can describe, in request-lifecycle order.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Stage {
    /// Admission verdict at a coordinator (`a` = admitted route queue
    /// depth, `b` = shed code, 0 = admitted).
    Admission,
    /// Time spent queued in the batcher (`a` = batch size it left in).
    Queue,
    /// A batch was assembled and released (`a` = requests, `b` = padded
    /// bucket size; duration = oldest member's wait).
    BatchAssemble,
    /// Batch execution at the dispatch boundary (`a` = bucket).
    Dispatch,
    /// Per-layer Winograd input-transform gather (`a` = layer index).
    InputTransform,
    /// Per-layer Winograd-domain GEMM (`a` = layer index).
    WinogradGemm,
    /// Per-layer inverse transform (`a` = layer index).
    InverseTransform,
    /// Per-layer activation application (`a` = layer index).
    Activation,
    /// Whole-layer execution for non-Winograd layers (`a` = layer index).
    LayerExec,
    /// One wire round-trip as observed by the router (`label` = replica
    /// address).
    Wire,
    /// One routing attempt and its verdict (`a` = attempt ordinal,
    /// `b` = verdict code: 0 ok, otherwise the wire error code;
    /// `label` = replica address).
    Attempt,
}

/// Every stage, in declaration (request-lifecycle) order.
pub const STAGES: [Stage; 11] = [
    Stage::Admission,
    Stage::Queue,
    Stage::BatchAssemble,
    Stage::Dispatch,
    Stage::InputTransform,
    Stage::WinogradGemm,
    Stage::InverseTransform,
    Stage::Activation,
    Stage::LayerExec,
    Stage::Wire,
    Stage::Attempt,
];

impl Stage {
    /// Stable snake_case name — the key used in telemetry JSON and the
    /// `stage` label in the Prometheus exposition. Never rename.
    pub fn name(self) -> &'static str {
        match self {
            Stage::Admission => "admission",
            Stage::Queue => "queue",
            Stage::BatchAssemble => "batch_assemble",
            Stage::Dispatch => "dispatch",
            Stage::InputTransform => "input_transform",
            Stage::WinogradGemm => "winograd_gemm",
            Stage::InverseTransform => "inverse_transform",
            Stage::Activation => "activation",
            Stage::LayerExec => "layer_exec",
            Stage::Wire => "wire",
            Stage::Attempt => "attempt",
        }
    }

    fn index(self) -> usize {
        match self {
            Stage::Admission => 0,
            Stage::Queue => 1,
            Stage::BatchAssemble => 2,
            Stage::Dispatch => 3,
            Stage::InputTransform => 4,
            Stage::WinogradGemm => 5,
            Stage::InverseTransform => 6,
            Stage::Activation => 7,
            Stage::LayerExec => 8,
            Stage::Wire => 9,
            Stage::Attempt => 10,
        }
    }
}

/// One compact span: a stage of one traced request's life, with a
/// start offset (µs since this process's recorder epoch), a duration,
/// and two stage-specific integer details plus an optional short label
/// (replica address, shed cause, ...). Cross-process times are relative
/// to each node's own epoch — the tree shows per-node stage breakdowns,
/// not a global clock.
#[derive(Clone, Debug)]
pub struct SpanEvent {
    /// The trace this span belongs to.
    pub trace: TraceId,
    /// Global record order within this process (total order tiebreak).
    pub seq: u64,
    /// Which datapath stage this span measures.
    pub stage: Stage,
    /// Start, µs since the recorder epoch of the emitting process.
    pub start_us: u64,
    /// Duration in µs.
    pub dur_us: u64,
    /// Stage-specific detail (see [`Stage`] docs).
    pub a: u64,
    /// Stage-specific detail (see [`Stage`] docs).
    pub b: u64,
    /// Short free-form detail: replica address, verdict, ...
    pub label: String,
}

impl SpanEvent {
    /// Stable-key JSON for trace dumps; `node` identifies the emitting
    /// process (set via [`FlightRecorder::configure`]).
    pub fn to_json(&self, node: &str) -> Json {
        json::obj(vec![
            ("node", json::s(node)),
            ("trace", json::num(self.trace as f64)),
            ("seq", json::num(self.seq as f64)),
            ("stage", json::s(self.stage.name())),
            ("start_us", json::num(self.start_us as f64)),
            ("dur_us", json::num(self.dur_us as f64)),
            ("a", json::num(self.a as f64)),
            ("b", json::num(self.b as f64)),
            ("label", json::s(&self.label)),
        ])
    }
}

/// One ring: the newest `RING_CAP` events recorded by the threads that
/// hash here, plus per-stage latency histograms accumulated since the
/// last reset (scrapes merge the rings' histograms into the rollup).
struct Ring {
    events: VecDeque<SpanEvent>,
    hists: Vec<Histogram>,
}

impl Ring {
    fn new() -> Ring {
        Ring {
            events: VecDeque::with_capacity(RING_CAP),
            hists: (0..STAGES.len()).map(|_| Histogram::new()).collect(),
        }
    }
}

/// The process-wide flight recorder: sampling policy + sharded span
/// rings. One per process, reached through [`recorder`].
pub struct FlightRecorder {
    enabled: AtomicBool,
    /// 1-in-N sampling at trace mint; 0 = tracing off.
    sample_every: AtomicU64,
    seed: AtomicU64,
    /// Admissions seen by [`FlightRecorder::maybe_mint`] (sampled or not).
    admissions: AtomicU64,
    /// Global event sequence (total order across rings).
    seq: AtomicU64,
    node: Mutex<String>,
    epoch: Instant,
    rings: Vec<Mutex<Ring>>,
}

static RECORDER: OnceLock<FlightRecorder> = OnceLock::new();
static NEXT_SLOT: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    static SLOT: Cell<usize> = const { Cell::new(usize::MAX) };
    static CURRENT: Cell<TraceId> = const { Cell::new(0) };
}

/// The process-wide recorder (created on first use, tracing off).
pub fn recorder() -> &'static FlightRecorder {
    RECORDER.get_or_init(FlightRecorder::new)
}

/// The trace id the current thread is executing under (`0` = none).
/// Set per batch by the coordinator's dispatch path so the engine's
/// per-layer stage spans attach to the request's trace without
/// threading a parameter through [`crate::coordinator::ExecBackend`].
pub fn current_trace() -> TraceId {
    CURRENT.with(|c| c.get())
}

/// Run `f` with the thread's current trace set to `trace`, restoring
/// the previous value afterwards — including across unwinds, so a
/// contained engine panic cannot leak a stale trace id onto the
/// dispatch thread.
pub fn with_trace<R>(trace: TraceId, f: impl FnOnce() -> R) -> R {
    struct Restore(TraceId);
    impl Drop for Restore {
        fn drop(&mut self) {
            CURRENT.with(|c| c.set(self.0));
        }
    }
    let prev = CURRENT.with(|c| {
        let p = c.get();
        c.set(trace);
        p
    });
    let _restore = Restore(prev);
    f()
}

impl FlightRecorder {
    fn new() -> FlightRecorder {
        FlightRecorder {
            enabled: AtomicBool::new(false),
            sample_every: AtomicU64::new(0),
            seed: AtomicU64::new(0),
            admissions: AtomicU64::new(0),
            seq: AtomicU64::new(0),
            node: Mutex::new("node".to_string()),
            epoch: Instant::now(),
            rings: (0..N_RINGS).map(|_| Mutex::new(Ring::new())).collect(),
        }
    }

    /// Set the sampling policy and this process's node label.
    /// `sample_every = 0` disables tracing entirely; `1` traces every
    /// request; `N` traces one in `N`, with the seed choosing *which*
    /// residue is sampled (deterministic for a deterministic load).
    pub fn configure(&self, sample_every: u64, seed: u64, node: &str) {
        *lock_unpoisoned(&self.node) = node.to_string();
        self.seed.store(seed, Ordering::Relaxed);
        self.sample_every.store(sample_every, Ordering::Relaxed);
        self.enabled.store(sample_every > 0, Ordering::Release);
    }

    /// Whether any sampling is configured — the one-load fast guard
    /// every recording site checks first.
    #[inline]
    pub fn enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    /// This process's node label (as set by [`FlightRecorder::configure`]).
    pub fn node(&self) -> String {
        lock_unpoisoned(&self.node).clone()
    }

    /// Admission-time sampling decision: returns a fresh nonzero
    /// [`TraceId`] for a sampled request, `0` otherwise. Ids encode the
    /// seed (high bits) and the admission ordinal (low bits) and stay
    /// below 2^53 for f64-exact JSON transport.
    pub fn maybe_mint(&self) -> TraceId {
        if !self.enabled() {
            return 0;
        }
        let every = self.sample_every.load(Ordering::Relaxed).max(1);
        let seed = self.seed.load(Ordering::Relaxed);
        let n = self.admissions.fetch_add(1, Ordering::Relaxed);
        if n % every != seed % every {
            return 0;
        }
        (((seed & 0xF_FFFF) + 1) << 32) | ((n + 1) & 0xFFFF_FFFF)
    }

    /// Record one span. No-op when tracing is disabled or `trace == 0`.
    pub fn record(
        &self,
        trace: TraceId,
        stage: Stage,
        start: Instant,
        dur: Duration,
        a: u64,
        b: u64,
        label: &str,
    ) {
        if trace == 0 || !self.enabled() {
            return;
        }
        let start_us =
            start.checked_duration_since(self.epoch).unwrap_or_default().as_micros() as u64;
        let ev = SpanEvent {
            trace,
            seq: self.seq.fetch_add(1, Ordering::Relaxed),
            stage,
            start_us,
            dur_us: dur.as_micros() as u64,
            a,
            b,
            label: label.to_string(),
        };
        let slot = SLOT.with(|s| {
            let mut v = s.get();
            if v == usize::MAX {
                v = NEXT_SLOT.fetch_add(1, Ordering::Relaxed);
                s.set(v);
            }
            v % N_RINGS
        });
        let mut ring = lock_unpoisoned(&self.rings[slot]);
        if ring.events.len() == RING_CAP {
            ring.events.pop_front();
        }
        ring.events.push_back(ev);
        ring.hists[stage.index()].record(dur);
    }

    /// Record a span that started at `t0` and ends now.
    pub fn stamp(&self, trace: TraceId, stage: Stage, t0: Instant, a: u64, b: u64, label: &str) {
        if trace == 0 || !self.enabled() {
            return;
        }
        self.record(trace, stage, t0, t0.elapsed(), a, b, label);
    }

    /// Snapshot the recorded spans — all of them, or one trace's —
    /// ordered by `(start_us, seq)`.
    pub fn spans(&self, trace: Option<TraceId>) -> Vec<SpanEvent> {
        let mut out = Vec::new();
        for ring in &self.rings {
            let ring = lock_unpoisoned(ring);
            let wanted = ring.events.iter().filter(|e| match trace {
                Some(t) => e.trace == t,
                None => true,
            });
            out.extend(wanted.cloned());
        }
        out.sort_by_key(|e| (e.start_us, e.seq));
        out
    }

    /// Per-stage latency histograms merged across every ring (stages
    /// with no samples are omitted).
    pub fn stage_histograms(&self) -> Vec<(Stage, Histogram)> {
        let mut merged: Vec<Histogram> = (0..STAGES.len()).map(|_| Histogram::new()).collect();
        for ring in &self.rings {
            let ring = lock_unpoisoned(ring);
            for (m, h) in merged.iter_mut().zip(&ring.hists) {
                m.merge(h);
            }
        }
        STAGES
            .iter()
            .zip(merged)
            .filter(|(_, h)| h.count() > 0)
            .map(|(&s, h)| (s, h))
            .collect()
    }

    /// The stage histograms as a stable-key JSON object
    /// (`stage name -> histogram snapshot`).
    pub fn stages_json(&self) -> Json {
        Json::Obj(
            self.stage_histograms()
                .into_iter()
                .map(|(s, h)| (s.name().to_string(), h.to_json()))
                .collect(),
        )
    }

    /// A trace dump document: `{node, sampled, spans: [...]}` — the
    /// whole flight recorder, or one trace when `trace` is given.
    /// `limit` caps the span count (newest kept).
    pub fn trace_json(&self, trace: Option<TraceId>, limit: usize) -> Json {
        let node = self.node();
        let mut spans = self.spans(trace);
        if spans.len() > limit {
            spans.drain(..spans.len() - limit);
        }
        json::obj(vec![
            ("node", json::s(&node)),
            (
                "trace",
                match trace {
                    Some(t) => json::num(t as f64),
                    None => Json::Null,
                },
            ),
            ("sampled", json::num(self.seq.load(Ordering::Relaxed) as f64)),
            ("spans", Json::Arr(spans.iter().map(|e| e.to_json(&node)).collect())),
        ])
    }

    /// Forget every recorded span and histogram and restart the
    /// admission counter. Sampling policy and node label are kept.
    /// Test/bench plumbing — scrapes never reset.
    pub fn reset(&self) {
        for ring in &self.rings {
            let mut ring = lock_unpoisoned(ring);
            ring.events.clear();
            ring.hists = (0..STAGES.len()).map(|_| Histogram::new()).collect();
        }
        self.admissions.store(0, Ordering::Relaxed);
        self.seq.store(0, Ordering::Relaxed);
    }
}

/// Convenience wrapper over `recorder().record(...)` — the form the
/// datapath call sites use.
#[inline]
pub fn record_span(
    trace: TraceId,
    stage: Stage,
    start: Instant,
    dur: Duration,
    a: u64,
    b: u64,
    label: &str,
) {
    if trace != 0 {
        recorder().record(trace, stage, start, dur, a, b, label);
    }
}

/// Stage-latency key/value pairs for a BENCH report: for every pipeline
/// stage with at least one sample in the process-global recorder,
/// `stage_<name>_count`, `stage_<name>_p50_ms`, and `stage_<name>_p99_ms`.
/// Empty when sampling is off, so bench harnesses attach whatever tracing
/// saw without paying for (or polluting the report of) an untraced run.
pub fn bench_stage_metrics() -> Vec<(String, f64)> {
    let mut out = Vec::new();
    for (stage, h) in recorder().stage_histograms() {
        let (p50, p99, _) = h.tail();
        out.push((format!("stage_{}_count", stage.name()), h.count() as f64));
        out.push((format!("stage_{}_p50_ms", stage.name()), p50 * 1e3));
        out.push((format!("stage_{}_p99_ms", stage.name()), p99 * 1e3));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    // Tests construct private local recorders so they can run in
    // parallel with anything else in the binary; only the thread-local
    // trace-context tests touch process-global state (their own
    // thread's cell).

    #[test]
    fn disabled_recorder_mints_nothing_and_records_nothing() {
        let r = FlightRecorder::new();
        r.configure(0, 7, "t0");
        assert_eq!(r.maybe_mint(), 0);
        r.record(42, Stage::Queue, Instant::now(), Duration::from_millis(1), 0, 0, "");
        assert!(r.spans(None).is_empty(), "disabled recorder must stay empty");
        assert!(r.stage_histograms().is_empty());
    }

    #[test]
    fn sampling_is_seeded_and_deterministic() {
        let r = FlightRecorder::new();
        r.configure(4, 2, "t1");
        let first: Vec<TraceId> = (0..8).map(|_| r.maybe_mint()).collect();
        r.reset();
        let second: Vec<TraceId> = (0..8).map(|_| r.maybe_mint()).collect();
        assert_eq!(first, second, "same (every, seed) must sample the same admissions");
        let minted: Vec<&TraceId> = first.iter().filter(|&&t| t != 0).collect();
        assert_eq!(minted.len(), 2, "1-in-4 over 8 admissions mints twice: {first:?}");
        // seed picks a different residue
        r.configure(4, 3, "t1");
        r.reset();
        let shifted: Vec<TraceId> = (0..8).map(|_| r.maybe_mint()).collect();
        let pos = |v: &[TraceId]| v.iter().position(|&t| t != 0).unwrap();
        assert_ne!(pos(&first), pos(&shifted), "seed must move the sampled residue");
    }

    #[test]
    fn minted_ids_are_nonzero_unique_and_f64_exact() {
        let r = FlightRecorder::new();
        r.configure(1, 999, "t2");
        let ids: Vec<TraceId> = (0..100).map(|_| r.maybe_mint()).collect();
        for &id in &ids {
            assert_ne!(id, 0);
            assert!(id < (1 << 53), "trace id must survive f64 transport: {id}");
            assert_eq!((id as f64) as u64, id);
        }
        let mut dedup = ids.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), ids.len(), "ids must be unique");
    }

    #[test]
    fn rings_wrap_and_spans_sort_by_start() {
        let r = FlightRecorder::new();
        r.configure(1, 0, "t3");
        let t0 = Instant::now();
        // overfill from this one thread: its ring keeps the newest RING_CAP
        for i in 0..(RING_CAP + 10) {
            r.record(5, Stage::Queue, t0, Duration::from_micros(i as u64), i as u64, 0, "");
        }
        let spans = r.spans(Some(5));
        assert_eq!(spans.len(), RING_CAP, "ring must cap at RING_CAP");
        // the oldest events were overwritten, the newest survive
        assert_eq!(spans.last().unwrap().a, (RING_CAP + 9) as u64);
        assert!(spans.windows(2).all(|w| (w[0].start_us, w[0].seq) <= (w[1].start_us, w[1].seq)));
    }

    #[test]
    fn stage_histograms_merge_across_rings_and_filter_empties() {
        let r = FlightRecorder::new();
        r.configure(1, 0, "t4");
        let t0 = Instant::now();
        r.record(9, Stage::WinogradGemm, t0, Duration::from_millis(2), 0, 0, "");
        // record from another thread so a second ring is populated
        std::thread::scope(|s| {
            s.spawn(|| {
                r.record(9, Stage::WinogradGemm, t0, Duration::from_millis(4), 1, 0, "");
            });
        });
        let hists = r.stage_histograms();
        assert_eq!(hists.len(), 1, "only the recorded stage appears");
        assert_eq!(hists[0].0, Stage::WinogradGemm);
        assert_eq!(hists[0].1.count(), 2, "merge must fold both rings");
        let doc = r.stages_json();
        assert!(doc.get("winograd_gemm").is_some());
        assert!(doc.get("queue").is_none());
    }

    #[test]
    fn recorder_survives_a_panicking_recorder_thread() {
        let r = FlightRecorder::new();
        r.configure(1, 0, "t5");
        r.record(7, Stage::Dispatch, Instant::now(), Duration::from_millis(1), 0, 0, "pre");
        // poison every ring mutex the hard way: panic while holding it
        std::thread::scope(|s| {
            for ring in &r.rings {
                let h = s.spawn(move || {
                    let _guard = ring.lock().unwrap();
                    panic!("poison the ring");
                });
                assert!(h.join().is_err(), "the poisoning thread must have panicked");
            }
        });
        // the flight recorder still records and still dumps — that is
        // the whole point of a post-mortem recorder
        r.record(7, Stage::Dispatch, Instant::now(), Duration::from_millis(1), 1, 0, "post");
        let spans = r.spans(Some(7));
        assert!(spans.iter().any(|e| e.label == "post"), "recording after poison must work");
        assert!(spans.iter().any(|e| e.label == "pre"), "pre-poison events must survive");
    }

    #[test]
    fn with_trace_restores_across_unwinds() {
        assert_eq!(current_trace(), 0);
        with_trace(11, || {
            assert_eq!(current_trace(), 11);
            with_trace(22, || assert_eq!(current_trace(), 22));
            assert_eq!(current_trace(), 11);
            let _ = std::panic::catch_unwind(|| with_trace(33, || panic!("boom")));
            assert_eq!(current_trace(), 11, "unwind must restore the previous trace");
        });
        assert_eq!(current_trace(), 0);
    }

    #[test]
    fn trace_json_filters_limits_and_labels_the_node() {
        let r = FlightRecorder::new();
        r.configure(1, 0, "nodeX");
        let t0 = Instant::now();
        for i in 0..5 {
            r.record(100, Stage::Queue, t0, Duration::from_micros(i), i, 0, "");
            r.record(200, Stage::Wire, t0, Duration::from_micros(i), i, 0, "r1");
        }
        let doc = r.trace_json(Some(200), 3);
        assert_eq!(doc.get("node").and_then(Json::as_str), Some("nodeX"));
        assert_eq!(doc.get("trace").and_then(Json::as_f64), Some(200.0));
        let spans = doc.get("spans").and_then(Json::as_arr).unwrap();
        assert_eq!(spans.len(), 3, "limit keeps the newest spans");
        for sp in spans {
            assert_eq!(sp.get("trace").and_then(Json::as_f64), Some(200.0));
            assert_eq!(sp.get("stage").and_then(Json::as_str), Some("wire"));
            assert_eq!(sp.get("node").and_then(Json::as_str), Some("nodeX"));
        }
    }

    #[test]
    fn stage_names_are_stable_and_indexed() {
        for (i, s) in STAGES.iter().enumerate() {
            assert_eq!(s.index(), i);
            assert!(!s.name().is_empty());
            assert!(s.name().chars().all(|c| c.is_ascii_lowercase() || c == '_'));
        }
    }
}
