//! `wingan chaos` — deterministic fault-injection soak for the
//! fault-isolated serving tier.
//!
//! The harness proves the containment story end to end, the way a unit
//! test cannot: it drives a real supervised native coordinator with a
//! seeded open-loop arrival schedule **twice** — once fault-free (the
//! baseline), once with a [`crate::faultinject::FaultPlane`] injecting
//! panics into batch execution and worker chunks — and asserts the three
//! properties the serving tier promises under faults:
//!
//! 1. **Conservation** — every submitted request gets exactly one fate
//!    (response, typed shed, or typed crash error). A request that never
//!    hears back, or hears back twice, fails the run. A 30-second
//!    per-request fate timeout doubles as the deadlock detector.
//! 2. **Bitwise isolation** — every request that completes in *both*
//!    runs returns bitwise-identical bytes. Containment bisects poisoned
//!    batches and re-executes the survivors, and the engine's
//!    batch-composition invariance means those re-executions must not
//!    perturb a single bit of anyone else's output.
//! 3. **Bounded recovery** — injected panic storms kill engine
//!    incarnations, the supervisor restarts them (restart count > 0 under
//!    the built-in spec), and every route is Healthy again by the end of
//!    the run. The process itself never exits.
//!
//! The outcome lands in a [`crate::benchlib::BenchReport`]
//! (`BENCH_pr8.json` by default) so CI's bench-trajectory artifact
//! records the soak machine-readably, next to the perf reports.

use crate::benchlib::BenchReport;
use crate::coordinator::{Coordinator, Metrics, ServeConfig, SupervisorConfig};
use crate::engine::serve::NativeConfig;
use crate::faultinject::FaultPlane;
use crate::gan::zoo::Scale;
use crate::loadgen::{ArrivalPlan, TrafficProfile};
use anyhow::{ensure, Context, Result};
use std::path::PathBuf;
use std::sync::mpsc::RecvTimeoutError;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Chaos soak options (see `wingan chaos --help` text in `main.rs`).
#[derive(Clone, Debug)]
pub struct ChaosOptions {
    /// zoo scale the engines compile at (tiny default: fast, CI-friendly)
    pub scale: Scale,
    /// requests offered per run (each spec runs the schedule twice:
    /// baseline + faulted)
    pub requests: usize,
    /// offered arrival rate, req/s (moderate by default — chaos measures
    /// fates under faults, not admission control under overload)
    pub rate: f64,
    /// per-route admission bound
    pub queue_cap: usize,
    /// schedule + fault seed (same seed → same arrivals, same faults)
    pub seed: u64,
    /// worker threads (0 = env/core default)
    pub workers: usize,
    /// fault spec override; `None` = [`ChaosOptions::default_spec`]
    pub spec: Option<String>,
    /// where to write the machine-readable report
    pub out: PathBuf,
}

impl Default for ChaosOptions {
    fn default() -> Self {
        ChaosOptions {
            scale: Scale::Tiny,
            requests: 600,
            rate: 300.0,
            queue_cap: 512,
            seed: 11,
            workers: 0,
            spec: None,
            out: PathBuf::from("BENCH_pr8.json"),
        }
    }
}

impl ChaosOptions {
    /// The short configuration behind `--quick`: enough traffic to form
    /// real batches and ride out a storm, small enough for a CI smoke
    /// step.
    pub fn quick() -> ChaosOptions {
        ChaosOptions { requests: 240, ..Default::default() }
    }

    /// The built-in fault spec: a deterministic four-panic burst at the
    /// front (guaranteed to storm at least one route's engine, by
    /// pigeonhole over the three-route mix, so recovery is always
    /// exercised), a ~1% background panic rate over batch execution for
    /// the rest of the run, and a capped dose of worker-chunk panics so
    /// the pool's re-raise path is on the menu too.
    pub fn default_spec(&self) -> String {
        format!(
            "seed={};batch_exec:panic*4@1;batch_exec:panic@0.01;worker_chunk:panic*2@0.01",
            self.seed
        )
    }

    /// Supervision tuned for a short soak: storms trip after two
    /// contained panics, restarts back off in milliseconds (not seconds),
    /// probation is short enough to reach Healthy before the final health
    /// check, and the breaker's restart budget is effectively unbounded —
    /// the soak asserts *recovery*, and the breaker's own behaviour has
    /// dedicated unit tests.
    fn supervisor(&self) -> SupervisorConfig {
        SupervisorConfig {
            watchdog: Duration::from_secs(10),
            backoff_base: Duration::from_millis(2),
            backoff_max: Duration::from_millis(50),
            max_restarts: 1000,
            restart_window: Duration::from_secs(1),
            breaker_cooldown: Duration::from_millis(200),
            probation: Duration::from_millis(100),
            storm_panics: 2,
            storm_window: Duration::from_secs(5),
        }
    }
}

/// What one replay of the schedule observed.
struct Replay {
    /// per-arrival-index output; `None` = typed shed or crash casualty
    outputs: Vec<Option<Vec<f32>>>,
    completed: u64,
    /// typed admission/deadline/unhealthy sheds (submit + reply side)
    shed: u64,
    /// typed crash casualties ([`crate::coordinator::ServeError::Crashed`]
    /// / `Execution` / `EngineShutdown`)
    casualties: u64,
    /// lifetime engine restarts summed over routes
    restarts: u64,
    /// every route Healthy at the end of the run
    healthy: bool,
    metrics: Metrics,
}

fn native_cfg(opts: &ChaosOptions, profile: &TrafficProfile) -> NativeConfig {
    NativeConfig {
        scale: opts.scale,
        workers: opts.workers,
        models: Some(profile.models()),
        ..Default::default()
    }
}

/// Replay the arrival plan against one freshly started coordinator and
/// record every request's fate. Consumes (and shuts down) the
/// coordinator; after all fates are in, polls route health for up to
/// three seconds so in-flight restarts can finish probation.
fn replay(
    coord: Coordinator,
    profile: &TrafficProfile,
    plan: &ArrivalPlan,
    label: &str,
) -> Result<Replay> {
    let t0 = Instant::now();
    let mut pending = Vec::with_capacity(plan.arrivals.len());
    let mut shed = 0u64;
    for (i, a) in plan.arrivals.iter().enumerate() {
        let target = t0 + a.offset;
        let now = Instant::now();
        if target > now {
            std::thread::sleep(target - now);
        }
        let r = &profile.routes[a.route];
        match coord.submit(&r.model, &r.method, a.input.clone()) {
            Ok(rx) => pending.push((i, rx)),
            Err(e) if e.is_shed() => shed += 1,
            Err(e) => anyhow::bail!("{label}: submit failed hard (not a typed shed): {e}"),
        }
    }

    let mut outputs: Vec<Option<Vec<f32>>> = vec![None; plan.arrivals.len()];
    let mut completed = 0u64;
    let mut casualties = 0u64;
    for (i, rx) in pending {
        // a generous per-fate timeout is the deadlock detector: if
        // containment or supervision ever wedged, the run fails here
        // instead of hanging CI
        match rx.recv_timeout(Duration::from_secs(30)) {
            Ok(Ok(resp)) => {
                outputs[i] = Some(resp.output);
                completed += 1;
            }
            Ok(Err(e)) if e.is_shed() => shed += 1,
            Ok(Err(crate::coordinator::ServeError::Crashed(_)))
            | Ok(Err(crate::coordinator::ServeError::Execution(_)))
            | Ok(Err(crate::coordinator::ServeError::EngineShutdown)) => casualties += 1,
            Ok(Err(e)) => anyhow::bail!("{label}: request {i} failed unexpectedly: {e}"),
            Err(RecvTimeoutError::Timeout) => {
                anyhow::bail!("{label}: request {i} got no fate within 30s (deadlock?)")
            }
            Err(RecvTimeoutError::Disconnected) => {
                anyhow::bail!("{label}: request {i} lost — reply channel dropped without a fate")
            }
        }
    }

    // conservation: every offered request has exactly one recorded fate
    let offered = plan.arrivals.len() as u64;
    ensure!(
        completed + shed + casualties == offered,
        "{label}: lost requests — {completed} completed + {shed} shed + \
         {casualties} crashed != {offered} offered"
    );

    // bounded recovery: give restarted incarnations time to clear
    // probation, then read the final verdict
    let settle = Instant::now();
    let mut health = coord.health();
    while !health.all_healthy() && settle.elapsed() < Duration::from_secs(3) {
        std::thread::sleep(Duration::from_millis(20));
        health = coord.health();
    }
    let restarts: u64 = health.routes.values().map(|r| r.restarts).sum();
    let healthy = health.all_healthy();
    let metrics = coord.metrics();
    coord.shutdown();
    Ok(Replay { outputs, completed, shed, casualties, restarts, healthy, metrics })
}

/// Run the full soak: baseline replay, faulted replay of the identical
/// schedule, then the conservation / bitwise / recovery assertions and
/// the machine-readable report. Any violated property returns an error
/// (and a non-zero exit from `wingan chaos`).
pub fn run(opts: &ChaosOptions) -> Result<()> {
    let profile = TrafficProfile::standard();
    let spec = opts.spec.clone().unwrap_or_else(|| opts.default_spec());
    let plane =
        Arc::new(FaultPlane::parse(&spec).map_err(|e| anyhow::anyhow!("bad fault spec: {e}"))?);
    println!(
        "chaos: {} requests at {:.0} req/s over {} route(s), seed {}, spec '{spec}'",
        opts.requests,
        opts.rate,
        profile.routes.len(),
        opts.seed
    );

    let serve = ServeConfig {
        queue_cap: opts.queue_cap,
        supervisor: opts.supervisor(),
        ..Default::default()
    };

    // baseline: same schedule, no faults — the bitwise reference
    let coord = Coordinator::start_native(native_cfg(opts, &profile), serve.clone())?;
    let input_lens: Vec<usize> = profile
        .routes
        .iter()
        .map(|r| {
            coord
                .router()
                .route(&r.model, &r.method)
                .map(|route| route.sample_input_len)
                .map_err(anyhow::Error::msg)
        })
        .collect::<Result<_>>()?;
    let plan = ArrivalPlan::generate(&profile, &input_lens, opts.requests, opts.rate, opts.seed);
    let base = replay(coord, &profile, &plan, "baseline")?;
    ensure!(
        base.casualties == 0,
        "baseline run crashed {} request(s) with no faults injected",
        base.casualties
    );
    println!(
        "chaos: baseline — {} completed, {} shed, every request accounted for",
        base.completed, base.shed
    );

    // faulted: identical schedule, fault plane installed
    let faulted_serve = ServeConfig { faults: Some(plane.clone()), ..serve };
    let coord = Coordinator::start_native(native_cfg(opts, &profile), faulted_serve)?;
    let fault = replay(coord, &profile, &plan, "faulted")?;
    println!(
        "chaos: faulted  — {} completed, {} shed, {} crashed ({} fault(s) fired)",
        fault.completed,
        fault.shed,
        fault.casualties,
        plane.total_fired()
    );
    println!("chaos: {}", plane.summary());

    // bitwise isolation: everything that completed in both runs must
    // match exactly — containment's bisected re-executions never perturb
    // a surviving batch-mate's bytes
    let mut compared = 0u64;
    for (i, (b, f)) in base.outputs.iter().zip(&fault.outputs).enumerate() {
        if let (Some(b), Some(f)) = (b, f) {
            ensure!(
                b == f,
                "request {i} diverged bitwise between the baseline and faulted runs"
            );
            compared += 1;
        }
    }
    ensure!(compared > 0, "no request completed in both runs; soak proved nothing");

    // bounded recovery: the storm killed at least one incarnation, the
    // supervisor brought it back, and the final verdict is Healthy
    ensure!(fault.healthy, "route(s) still unhealthy after the recovery settle window");
    if opts.spec.is_none() {
        // the built-in spec guarantees a storm; a user-supplied spec may
        // be delay-only, so these floors only apply to the default
        ensure!(
            fault.metrics.panics_contained >= 1,
            "built-in spec fired no contained panics"
        );
        ensure!(fault.restarts >= 1, "storm never restarted an engine incarnation");
    }

    let mut rep = BenchReport::new("chaos");
    rep.metric("offered", plan.arrivals.len() as f64);
    rep.metric("baseline_completed", base.completed as f64);
    rep.metric("faulted_completed", fault.completed as f64);
    rep.metric("faulted_shed", fault.shed as f64);
    rep.metric("faulted_crashed", fault.casualties as f64);
    rep.metric("faults_fired", plane.total_fired() as f64);
    rep.metric("panics_contained", fault.metrics.panics_contained as f64);
    rep.metric("bisection_retries", fault.metrics.bisection_retries as f64);
    rep.metric("requests_quarantined", fault.metrics.requests_quarantined as f64);
    rep.metric("engine_restarts", fault.restarts as f64);
    rep.metric("bitwise_compared", compared as f64);
    rep.metric("bitwise_mismatches", 0.0); // ensured above
    rep.metric("lost_requests", 0.0); // conservation ensured per replay
    // trace-derived stage breakdown (present only when the recorder was
    // armed via --trace-sample; tracing never perturbs the bitwise
    // assertions above — it only reads clocks)
    for (key, value) in crate::telemetry::bench_stage_metrics() {
        rep.metric(&key, value);
    }
    rep.write(&opts.out).with_context(|| format!("writing {}", opts.out.display()))?;
    println!(
        "chaos: PASS — conservation held twice, {compared} outputs bitwise-identical, \
         {} restart(s), wrote {}",
        fault.restarts,
        opts.out.display()
    );
    Ok(())
}

/// `wingan chaos --fleet`: the kill-a-replica soak over the fleet tier.
///
/// One seeded open-loop schedule runs twice: first against a
/// single-process coordinator (the bitwise baseline — it also populates
/// a fresh shared [`PlanStore`](crate::artifact::PlanStore) via fallback
/// compile-and-publish), then through a
/// [`FleetRouter`](crate::fleet::FleetRouter) fronting **three**
/// warm-booted replicas while faults fly:
///
/// * replica 0 randomly **drops connections** (`conn_drop`) — the router
///   must fail those requests over without losing them;
/// * replica 2 randomly **stalls** (`replica_stall`) — slow, not dead;
/// * replica 1 is **killed abruptly** mid-run at a deterministic point
///   in the schedule, then replaced (new ephemeral port) and readmitted.
///
/// The run asserts the fleet promises: **conservation** (completed +
/// typed-shed + typed-casualty = offered; no request without a fate),
/// **bitwise equality** (every request completing in both runs matches
/// the single-process baseline exactly — determinism is what makes
/// cross-replica re-execution safe), and **bounded recovery** (the
/// replacement replica joins and the fleet reports all-ready again,
/// timed). Results land in `BENCH_pr9.json`.
pub fn run_fleet(opts: &ChaosOptions) -> Result<()> {
    use crate::fleet::{drive_open_loop, FleetConfig, FleetRouter, ReplicaConfig, ReplicaServer};

    let profile = TrafficProfile::standard();
    let store_root =
        std::env::temp_dir().join(format!("wingan-fleet-chaos-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&store_root);
    std::fs::create_dir_all(&store_root)
        .with_context(|| format!("creating {}", store_root.display()))?;
    println!(
        "chaos --fleet: {} requests at {:.0} req/s, seed {}, store {}",
        opts.requests,
        opts.rate,
        opts.seed,
        store_root.display()
    );

    let native = NativeConfig {
        plan_store: Some(store_root.clone()),
        ..native_cfg(opts, &profile)
    };
    let serve = ServeConfig {
        queue_cap: opts.queue_cap,
        supervisor: opts.supervisor(),
        // kills must not block on a long drain
        drain_deadline: Duration::from_secs(2),
        ..Default::default()
    };

    // ---- baseline: single process, no faults; populates the store ----
    let coord = Coordinator::start_native(native.clone(), serve.clone())?;
    let input_lens: Vec<usize> = profile
        .routes
        .iter()
        .map(|r| {
            coord
                .router()
                .route(&r.model, &r.method)
                .map(|route| route.sample_input_len)
                .map_err(anyhow::Error::msg)
        })
        .collect::<Result<_>>()?;
    let plan = ArrivalPlan::generate(&profile, &input_lens, opts.requests, opts.rate, opts.seed);
    let base = replay(coord, &profile, &plan, "fleet-baseline")?;
    ensure!(
        base.casualties == 0,
        "fleet baseline crashed {} request(s) with no faults injected",
        base.casualties
    );
    println!(
        "chaos --fleet: baseline — {} completed, {} shed, store populated",
        base.completed, base.shed
    );

    // tag the generation the fleet serves, then boot the fleet from it
    let store = crate::artifact::PlanStore::open(&store_root);
    let generation = store.bump_generation().context("tagging the store generation")?;

    let replica_cfg = |spec: Option<String>| -> Result<ReplicaConfig> {
        let fleet_faults = match spec {
            Some(s) => Some(Arc::new(
                FaultPlane::parse(&s).map_err(|e| anyhow::anyhow!("bad fleet fault spec: {e}"))?,
            )),
            None => None,
        };
        Ok(ReplicaConfig { native: native.clone(), serve: serve.clone(), fleet_faults })
    };
    // replica 0 drops connections, replica 2 stalls, replica 1 is clean
    // (it dies the hard way instead)
    let specs = [
        Some(format!("seed={};conn_drop:error*2@0.05", opts.seed)),
        None,
        Some(format!("seed={};replica_stall:delay=20ms*2@0.05", opts.seed.wrapping_add(1))),
    ];
    let mut replicas = Vec::new();
    for spec in specs {
        replicas.push(ReplicaServer::spawn("127.0.0.1:0", replica_cfg(spec)?)?);
    }
    for r in &replicas {
        ensure!(
            r.wait_ready(Duration::from_secs(60)),
            "replica {} never became ready: {:?}",
            r.addr(),
            r.boot_error()
        );
    }
    let addrs: Vec<String> = replicas.iter().map(|r| r.addr().to_string()).collect();
    let router = FleetRouter::new(FleetConfig {
        replicas: addrs.clone(),
        store: Some(store_root.clone()),
        ..FleetConfig::default()
    })
    .map_err(anyhow::Error::msg)?;
    ensure!(router.wait_all_ready(Duration::from_secs(30)), "fleet never became all-ready");

    // ---- faulted fleet run: kill replica 1 mid-schedule ----
    let kill_at = plan.arrivals.len() * 2 / 5;
    let victim_addr = addrs[1].clone();
    let mut drained = replicas.drain(..);
    let (conn_dropper, victim, staller) = (
        drained.next().expect("replica 0"),
        drained.next().expect("replica 1"),
        drained.next().expect("replica 2"),
    );
    drop(drained);
    let victim = std::sync::Mutex::new(Some(victim));
    let fates = drive_open_loop(
        &plan,
        8,
        Some((kill_at, || {
            if let Some(v) = crate::util::lock_unpoisoned(&victim).take() {
                println!("chaos --fleet: killing replica {victim_addr} at arrival {kill_at}");
                v.kill();
            }
        })),
        |_i, a| {
            let r = &profile.routes[a.route];
            router.submit(&r.model, &r.method, a.input.clone(), None)
        },
    );

    // ---- conservation: every arrival has exactly one typed fate ----
    let mut completed = 0u64;
    let mut shed = 0u64;
    let mut casualties = 0u64;
    let mut outputs: Vec<Option<Vec<f32>>> = vec![None; plan.arrivals.len()];
    for (i, fate) in fates.into_iter().enumerate() {
        match fate {
            Some(Ok(resp)) => {
                outputs[i] = Some(resp.output);
                completed += 1;
            }
            Some(Err(e)) if e.is_shed() => shed += 1,
            Some(Err(
                crate::coordinator::ServeError::Crashed(_)
                | crate::coordinator::ServeError::Execution(_)
                | crate::coordinator::ServeError::EngineShutdown,
            )) => casualties += 1,
            Some(Err(e)) => anyhow::bail!("fleet request {i} failed hard (not typed): {e}"),
            None => anyhow::bail!("fleet request {i} was never dispatched — lost"),
        }
    }
    let offered = plan.arrivals.len() as u64;
    ensure!(
        completed + shed + casualties == offered,
        "fleet run lost requests: {completed} completed + {shed} shed + \
         {casualties} casualties != {offered} offered"
    );
    println!(
        "chaos --fleet: fleet — {completed} completed, {shed} shed, {casualties} \
         casualties; every request accounted for"
    );

    // ---- bitwise equality against the single-process baseline ----
    let mut compared = 0u64;
    for (i, (b, f)) in base.outputs.iter().zip(&outputs).enumerate() {
        if let (Some(b), Some(f)) = (b, f) {
            ensure!(
                b == f,
                "request {i} diverged bitwise between single-process and fleet serving"
            );
            compared += 1;
        }
    }
    ensure!(compared > 0, "no request completed in both runs; soak proved nothing");

    // ---- bounded recovery: replace the dead replica, refill the fleet ----
    let t_recover = Instant::now();
    router.remove_replica(&victim_addr);
    let replacement = ReplicaServer::spawn("127.0.0.1:0", replica_cfg(None)?)?;
    ensure!(
        replacement.wait_ready(Duration::from_secs(60)),
        "replacement replica never became ready: {:?}",
        replacement.boot_error()
    );
    router.add_replica(&replacement.addr().to_string()).map_err(anyhow::Error::msg)?;
    ensure!(
        router.wait_all_ready(Duration::from_secs(20)),
        "fleet never recovered to all-ready after the replacement joined"
    );
    let recovery = t_recover.elapsed();
    let status = router.status();
    println!(
        "chaos --fleet: recovered to all-ready in {:.0}ms ({} failovers, {} shed \
         unavailable, generation {})",
        recovery.as_secs_f64() * 1e3,
        status.failovers,
        status.shed_unavailable,
        generation
    );

    let mut rep = BenchReport::new("chaos-fleet");
    rep.metric("offered", offered as f64);
    rep.metric("baseline_completed", base.completed as f64);
    rep.metric("fleet_completed", completed as f64);
    rep.metric("fleet_shed", shed as f64);
    rep.metric("fleet_casualties", casualties as f64);
    rep.metric("failovers", status.failovers as f64);
    rep.metric("shed_unavailable", status.shed_unavailable as f64);
    rep.metric("bitwise_compared", compared as f64);
    rep.metric("bitwise_mismatches", 0.0); // ensured above
    rep.metric("lost_requests", 0.0); // conservation ensured above
    rep.metric("recovery_ms", recovery.as_secs_f64() * 1e3);
    rep.metric("replicas", 3.0);
    rep.metric("store_generation", generation as f64);
    for (key, value) in crate::telemetry::bench_stage_metrics() {
        rep.metric(&key, value);
    }
    rep.write(&opts.out).with_context(|| format!("writing {}", opts.out.display()))?;
    println!(
        "chaos --fleet: PASS — zero lost, {compared} outputs bitwise-identical to the \
         single-process baseline, recovery {:.0}ms, wrote {}",
        recovery.as_secs_f64() * 1e3,
        opts.out.display()
    );

    conn_dropper.shutdown();
    staller.shutdown();
    replacement.shutdown();
    drop(router);
    let _ = std::fs::remove_dir_all(&store_root);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_spec_parses_and_targets_the_serving_sites() {
        let opts = ChaosOptions::default();
        let plane = FaultPlane::parse(&opts.default_spec()).expect("built-in spec must parse");
        // the burst rule is first: four guaranteed batch panics
        assert_eq!(
            plane.check(crate::faultinject::FaultSite::BatchExec),
            Some(crate::faultinject::FaultAction::Panic)
        );
    }

    #[test]
    fn quick_profile_is_smaller_but_same_shape() {
        let q = ChaosOptions::quick();
        let d = ChaosOptions::default();
        assert!(q.requests < d.requests);
        assert_eq!(q.seed, d.seed, "quick must stay on the replayable default seed");
        assert_eq!(q.out, d.out);
    }

    #[test]
    fn soak_supervision_is_tuned_for_fast_recovery() {
        let s = ChaosOptions::default().supervisor();
        assert_eq!(s.storm_panics, 2, "the four-panic burst must storm at least one route");
        assert!(s.probation < Duration::from_secs(1), "probation must clear inside the settle");
        assert!(s.max_restarts >= 100, "the soak asserts recovery, not breaker trips");
    }
}
