//! Deterministic fault-injection plane for the serving path.
//!
//! Chaos tooling only earns its keep when a failure found once can be
//! replayed forever, so everything here is **seeded and counter-driven**:
//! a [`FaultPlane`] is parsed from a spec string (CLI `serve
//! --inject-faults`, env `WINGAN_FAULTS`, or built programmatically in
//! tests), and every instrumented site asks [`FaultPlane::check`] whether
//! this particular *check* — the k-th time that rule has ever been
//! consulted — should fire. The decision is a pure hash of
//! `(seed, rule, k)`, so the same spec replays the same fault schedule on
//! every run, independent of wall-clock timing.
//!
//! # Spec grammar
//!
//! ```text
//! spec   := part (';' part)*
//! part   := 'seed=' <u64>  |  rule
//! rule   := site ':' action [ '*' <max-fires> ] [ '@' <rate> ]
//! site   := 'worker_chunk' | 'batch_exec' | 'artifact_load'
//!         | 'conn_drop' | 'replica_stall' | 'replica_exit'
//! action := 'panic' | 'wrong_shape' | 'error' | 'delay=' <millis> [ 'ms' ]
//! ```
//!
//! `@rate` (default `1.0`) is the per-check firing probability under the
//! seeded hash; `*N` (default unlimited) caps how many times the rule may
//! fire in total. `batch_exec:panic*5@1` is a deterministic five-panic
//! burst (the storm used to trip the circuit breaker in tests);
//! `batch_exec:panic@0.01` injects a panic into ~1% of batches forever.
//!
//! # Instrumented sites
//!
//! * [`FaultSite::WorkerChunk`] — inside [`crate::engine::WorkerPool`]
//!   chunk dispatch (both the inline and queued paths), before the chunk
//!   closure runs.
//! * [`FaultSite::BatchExec`] — in the coordinator's `run_batch`, around
//!   the [`crate::coordinator::ExecBackend`] call.
//! * [`FaultSite::ArtifactLoad`] — in the plan-store load path of
//!   [`crate::engine::NativeRuntime::build`], corrupting the load result.
//! * [`FaultSite::ConnDrop`] — in a fleet replica's connection loop
//!   ([`crate::fleet::replica`]): the connection is dropped without a
//!   reply, as if the process vanished mid-request.
//! * [`FaultSite::ReplicaStall`] — in the replica's request path: the
//!   reply is delayed (default 50 ms, or the rule's `delay=` duration),
//!   simulating a stalled peer the router must route around.
//! * [`FaultSite::ReplicaExit`] — in the replica's request path: the
//!   whole replica stops serving abruptly (accept loop exits, live
//!   connections drop), the fleet equivalent of a process kill.
//!
//! # Cost when disabled
//!
//! There is no global registry and no feature flag: a plane is an explicit
//! `Option<Arc<FaultPlane>>` threaded through
//! [`crate::coordinator::ServeConfig`] / [`crate::engine::NativeConfig`].
//! When it is `None` (every production configuration), the hot paths pay
//! one already-predicted branch per batch or chunk dispatch and touch no
//! shared state — the closest "compiled out" a library crate without
//! feature gates can get.
//!
//! # Determinism caveat
//!
//! A rule's k-th check decision is a pure function of `(seed, rule, k)`,
//! and check indices are allocated atomically — so the *number* of fires
//! after N checks is exactly reproducible. At the one concurrent site
//! (`WorkerChunk`, checked from pool workers) *which thread* draws a
//! firing index may vary run to run; the single-threaded serving sites
//! (`BatchExec`, `ArtifactLoad`) replay bit-identically.

use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// A named injection point in the serving path.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultSite {
    /// Worker-pool chunk dispatch ([`crate::engine::WorkerPool`]).
    WorkerChunk,
    /// Batch execution in the coordinator engine loop.
    BatchExec,
    /// Plan-artifact load in [`crate::engine::NativeRuntime::build`].
    ArtifactLoad,
    /// Fleet replica connection handling: drop the connection mid-request
    /// without a reply ([`crate::fleet::replica`]).
    ConnDrop,
    /// Fleet replica request path: stall the reply (a slow peer).
    ReplicaStall,
    /// Fleet replica request path: the replica stops serving abruptly.
    ReplicaExit,
}

impl FaultSite {
    /// All sites, in spec-grammar order.
    pub const ALL: [FaultSite; 6] = [
        FaultSite::WorkerChunk,
        FaultSite::BatchExec,
        FaultSite::ArtifactLoad,
        FaultSite::ConnDrop,
        FaultSite::ReplicaStall,
        FaultSite::ReplicaExit,
    ];

    /// The spec-grammar name (`worker_chunk` / `batch_exec` /
    /// `artifact_load` / `conn_drop` / `replica_stall` / `replica_exit`).
    pub fn name(self) -> &'static str {
        match self {
            FaultSite::WorkerChunk => "worker_chunk",
            FaultSite::BatchExec => "batch_exec",
            FaultSite::ArtifactLoad => "artifact_load",
            FaultSite::ConnDrop => "conn_drop",
            FaultSite::ReplicaStall => "replica_stall",
            FaultSite::ReplicaExit => "replica_exit",
        }
    }

    fn parse(s: &str) -> Result<FaultSite, String> {
        match s {
            "worker_chunk" => Ok(FaultSite::WorkerChunk),
            "batch_exec" => Ok(FaultSite::BatchExec),
            "artifact_load" => Ok(FaultSite::ArtifactLoad),
            "conn_drop" => Ok(FaultSite::ConnDrop),
            "replica_stall" => Ok(FaultSite::ReplicaStall),
            "replica_exit" => Ok(FaultSite::ReplicaExit),
            other => Err(format!(
                "unknown fault site '{other}' (expected worker_chunk, batch_exec, \
                 artifact_load, conn_drop, replica_stall or replica_exit)"
            )),
        }
    }
}

impl fmt::Display for FaultSite {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// What a firing rule does at its site.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultAction {
    /// Unwind with a panic (`fault injected: ...` payload).
    Panic,
    /// Sleep for the given duration before proceeding (exercises the
    /// stuck-batch watchdog without corrupting any result).
    Delay(Duration),
    /// Corrupt the result shape (the site truncates or garbles its
    /// output so downstream validation must catch it).
    WrongShape,
    /// Return a typed error instead of a result.
    Error,
}

impl FaultAction {
    fn parse(s: &str) -> Result<FaultAction, String> {
        if let Some(ms) = s.strip_prefix("delay=") {
            let ms = ms.strip_suffix("ms").unwrap_or(ms);
            let ms: u64 = ms
                .parse()
                .map_err(|_| format!("bad delay '{s}' (expected delay=<millis>[ms])"))?;
            return Ok(FaultAction::Delay(Duration::from_millis(ms)));
        }
        match s {
            "panic" => Ok(FaultAction::Panic),
            "wrong_shape" => Ok(FaultAction::WrongShape),
            "error" => Ok(FaultAction::Error),
            other => Err(format!(
                "unknown fault action '{other}' (expected panic, wrong_shape, error or delay=<ms>)"
            )),
        }
    }
}

impl fmt::Display for FaultAction {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FaultAction::Panic => f.write_str("panic"),
            FaultAction::Delay(d) => write!(f, "delay={}ms", d.as_millis()),
            FaultAction::WrongShape => f.write_str("wrong_shape"),
            FaultAction::Error => f.write_str("error"),
        }
    }
}

/// One parsed rule plus its live counters.
#[derive(Debug)]
struct Rule {
    site: FaultSite,
    action: FaultAction,
    /// Per-check firing probability in `[0, 1]`, pre-scaled to a u64
    /// threshold: the rule is hash-eligible when
    /// `hash(seed, rule, k) < threshold`.
    threshold: u64,
    /// Cap on total fires (`u64::MAX` when the spec gave no `*N`).
    max_fires: u64,
    /// Times this rule has been consulted.
    checks: AtomicU64,
    /// Times this rule has fired.
    fired: AtomicU64,
}

impl Rule {
    fn parse(part: &str) -> Result<Rule, String> {
        let (site, rest) = part
            .split_once(':')
            .ok_or_else(|| format!("bad fault rule '{part}' (expected site:action[*N][@rate])"))?;
        let site = FaultSite::parse(site.trim())?;
        let (rest, rate) = match rest.rsplit_once('@') {
            Some((head, rate)) => {
                let rate: f64 = rate
                    .parse()
                    .map_err(|_| format!("bad fault rate '@{rate}' in '{part}'"))?;
                if !(0.0..=1.0).contains(&rate) {
                    return Err(format!("fault rate {rate} out of [0,1] in '{part}'"));
                }
                (head, rate)
            }
            None => (rest, 1.0),
        };
        let (action, max_fires) = match rest.rsplit_once('*') {
            Some((head, n)) => {
                let n: u64 = n
                    .parse()
                    .map_err(|_| format!("bad fault fire cap '*{n}' in '{part}'"))?;
                (head, n)
            }
            None => (rest, u64::MAX),
        };
        let action = FaultAction::parse(action.trim())?;
        // rate 1.0 must always fire: (1.0 * 2^64) saturates to u64::MAX and
        // the comparison below is strict, so nudge it to all-ones exactly.
        let threshold = if rate >= 1.0 {
            u64::MAX
        } else {
            (rate * (u64::MAX as f64)) as u64
        };
        Ok(Rule {
            site,
            action,
            threshold,
            max_fires,
            checks: AtomicU64::new(0),
            fired: AtomicU64::new(0),
        })
    }

    fn eligible(&self, seed: u64, rule_idx: u64, k: u64) -> bool {
        if self.threshold == u64::MAX {
            return true;
        }
        hash64(seed ^ rule_idx.wrapping_mul(0x9e37_79b9_7f4a_7c15), k) < self.threshold
    }
}

/// splitmix64 finalizer — a well-mixed pure hash of `(stream, k)`.
fn hash64(stream: u64, k: u64) -> u64 {
    let mut z = stream.wrapping_add(k.wrapping_mul(0x9e37_79b9_7f4a_7c15));
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// A seeded set of injection rules. Build with [`FaultPlane::parse`] or
/// [`FaultPlane::from_env`], share as `Arc<FaultPlane>`, consult with
/// [`FaultPlane::check`].
#[derive(Debug)]
pub struct FaultPlane {
    seed: u64,
    rules: Vec<Rule>,
}

impl FaultPlane {
    /// Parse a spec string (see the module-level grammar). Errors carry
    /// the offending fragment.
    pub fn parse(spec: &str) -> Result<FaultPlane, String> {
        let mut seed = 0u64;
        let mut rules = Vec::new();
        for part in spec.split(';') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            if let Some(s) = part.strip_prefix("seed=") {
                seed = s.parse().map_err(|_| format!("bad fault seed '{part}'"))?;
            } else {
                rules.push(Rule::parse(part)?);
            }
        }
        if rules.is_empty() {
            return Err(format!("fault spec '{spec}' contains no rules"));
        }
        Ok(FaultPlane { seed, rules })
    }

    /// Read the `WINGAN_FAULTS` env var: `Ok(None)` when unset or empty,
    /// `Ok(Some(plane))` on a valid spec, `Err` on a malformed one.
    pub fn from_env() -> Result<Option<Arc<FaultPlane>>, String> {
        match std::env::var("WINGAN_FAULTS") {
            Ok(spec) if !spec.trim().is_empty() => {
                FaultPlane::parse(&spec).map(|p| Some(Arc::new(p)))
            }
            _ => Ok(None),
        }
    }

    /// Consult the plane at `site`. Every rule bound to the site advances
    /// its check counter; the first rule that is hash-eligible for its
    /// check index *and* under its fire cap fires and returns its action.
    pub fn check(&self, site: FaultSite) -> Option<FaultAction> {
        let mut hit = None;
        for (idx, rule) in self.rules.iter().enumerate() {
            if rule.site != site {
                continue;
            }
            let k = rule.checks.fetch_add(1, Ordering::Relaxed);
            if hit.is_some() || !rule.eligible(self.seed, idx as u64, k) {
                continue;
            }
            // claim a fire slot; lose the race past the cap and stay quiet
            let claimed = rule
                .fired
                .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |f| {
                    (f < rule.max_fires).then_some(f + 1)
                })
                .is_ok();
            if claimed {
                hit = Some(rule.action);
            }
        }
        hit
    }

    /// Total fires at `site` so far, across all rules.
    pub fn fired_at(&self, site: FaultSite) -> u64 {
        self.rules
            .iter()
            .filter(|r| r.site == site)
            .map(|r| r.fired.load(Ordering::Relaxed))
            .sum()
    }

    /// Total fires across all sites.
    pub fn total_fired(&self) -> u64 {
        self.rules.iter().map(|r| r.fired.load(Ordering::Relaxed)).sum()
    }

    /// One-line observability summary (`site:action fired/checks` per
    /// rule), for the chaos report.
    pub fn summary(&self) -> String {
        let mut out = format!("faults(seed={})", self.seed);
        for r in &self.rules {
            out.push_str(&format!(
                " {}:{} fired={}/{}",
                r.site,
                r.action,
                r.fired.load(Ordering::Relaxed),
                r.checks.load(Ordering::Relaxed)
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_grammar() {
        let p = FaultPlane::parse(
            "seed=7; batch_exec:panic*5@1; worker_chunk:delay=50ms@0.25; \
             artifact_load:wrong_shape; batch_exec:error*1",
        )
        .unwrap();
        assert_eq!(p.seed, 7);
        assert_eq!(p.rules.len(), 4);
        assert_eq!(p.rules[0].site, FaultSite::BatchExec);
        assert_eq!(p.rules[0].action, FaultAction::Panic);
        assert_eq!(p.rules[0].max_fires, 5);
        assert_eq!(p.rules[1].action, FaultAction::Delay(Duration::from_millis(50)));
        assert!(p.rules[1].threshold < u64::MAX / 2);
        assert_eq!(p.rules[2].max_fires, u64::MAX);
        assert_eq!(p.rules[3].max_fires, 1);
    }

    #[test]
    fn rejects_malformed_specs() {
        for bad in [
            "",
            "seed=7",                 // no rules
            "batch_exec",             // no action
            "nowhere:panic",          // bad site
            "batch_exec:explode",     // bad action
            "batch_exec:panic@1.5",   // rate out of range
            "batch_exec:panic@lots",  // non-numeric rate
            "batch_exec:panic*many",  // non-numeric cap
            "batch_exec:delay=soon",  // non-numeric delay
            "seed=green; batch_exec:panic",
        ] {
            assert!(FaultPlane::parse(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn burst_fires_exactly_n_then_stops() {
        let p = FaultPlane::parse("batch_exec:panic*3@1").unwrap();
        let fires: Vec<bool> =
            (0..10).map(|_| p.check(FaultSite::BatchExec).is_some()).collect();
        assert_eq!(fires, [true, true, true, false, false, false, false, false, false, false]);
        assert_eq!(p.fired_at(FaultSite::BatchExec), 3);
        // other sites never see it
        assert!(p.check(FaultSite::WorkerChunk).is_none());
        assert!(p.check(FaultSite::ArtifactLoad).is_none());
    }

    #[test]
    fn rate_is_seed_deterministic_and_roughly_proportional() {
        let run = |seed: u64| -> Vec<bool> {
            let p = FaultPlane::parse(&format!("seed={seed}; batch_exec:panic@0.1")).unwrap();
            (0..2000).map(|_| p.check(FaultSite::BatchExec).is_some()).collect()
        };
        let a = run(42);
        let b = run(42);
        assert_eq!(a, b, "same seed must replay the same fault schedule");
        let fired = a.iter().filter(|&&f| f).count();
        assert!((100..400).contains(&fired), "~10% of 2000 checks, got {fired}");
        let c = run(43);
        assert_ne!(a, c, "different seeds should differ somewhere");
    }

    #[test]
    fn first_eligible_rule_wins_but_all_counters_advance() {
        let p = FaultPlane::parse("batch_exec:panic*1@1; batch_exec:error@1").unwrap();
        assert_eq!(p.check(FaultSite::BatchExec), Some(FaultAction::Panic));
        // panic rule is capped out; the error rule (whose counter also
        // advanced on check 0) fires from its own index
        assert_eq!(p.check(FaultSite::BatchExec), Some(FaultAction::Error));
        assert_eq!(p.rules[1].checks.load(Ordering::Relaxed), 2);
        assert_eq!(p.rules[1].fired.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn fleet_sites_parse_and_fire_independently() {
        let p = FaultPlane::parse(
            "seed=3; conn_drop:error*1@1; replica_stall:delay=20ms*1@1; replica_exit:error*1@1",
        )
        .unwrap();
        assert_eq!(p.check(FaultSite::ConnDrop), Some(FaultAction::Error));
        assert_eq!(
            p.check(FaultSite::ReplicaStall),
            Some(FaultAction::Delay(Duration::from_millis(20)))
        );
        assert_eq!(p.check(FaultSite::ReplicaExit), Some(FaultAction::Error));
        // caps exhausted; engine-tier sites never see fleet rules
        assert!(p.check(FaultSite::ConnDrop).is_none());
        assert!(p.check(FaultSite::BatchExec).is_none());
        assert_eq!(p.total_fired(), 3);
        // every site name round-trips through the parser
        for site in FaultSite::ALL {
            assert_eq!(FaultSite::parse(site.name()), Ok(site));
        }
    }

    #[test]
    fn summary_reports_counters() {
        let p = FaultPlane::parse("seed=9; batch_exec:panic*1").unwrap();
        p.check(FaultSite::BatchExec);
        p.check(FaultSite::BatchExec);
        let s = p.summary();
        assert!(s.contains("seed=9"), "{s}");
        assert!(s.contains("batch_exec:panic fired=1/2"), "{s}");
    }
}
