//! Shared substrates: tensors, PRNG, JSON, binary tensor I/O.
//!
//! The offline build has no third-party crates beyond `xla`/`anyhow`, so the
//! pieces a production service would pull from serde/rand/etc. are
//! implemented here, small and fully tested.

pub mod bin;
pub mod elem;
pub mod json;
pub mod prng;
pub mod tensor;

/// Lock a mutex, recovering the data if a previous holder panicked.
///
/// The serving tier contains engine panics at the batch boundary
/// ([`crate::coordinator`]); a shared lock that turns one contained panic
/// into poison for every *other* route would defeat that isolation, so the
/// pool queue, metrics, and supervisor locks all take the guard through
/// here. The protected values are counters, queues of owned messages, and
/// pure state machines — each individual mutation is complete-or-absent
/// under unwinding, so the data is still coherent after a panicking
/// holder.
pub fn lock_unpoisoned<T>(m: &std::sync::Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}
