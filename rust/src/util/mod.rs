//! Shared substrates: tensors, PRNG, JSON, binary tensor I/O.
//!
//! The offline build has no third-party crates beyond `xla`/`anyhow`, so the
//! pieces a production service would pull from serde/rand/etc. are
//! implemented here, small and fully tested.

pub mod bin;
pub mod elem;
pub mod json;
pub mod prng;
pub mod tensor;
