//! Scalar element abstraction for the precision-tiered execution datapath.
//!
//! The execution engine is generic over [`Elem`], with exactly two
//! instantiations:
//!
//! * **`f64` — the reference tier.** Bit-identity contracts (engine TDC
//!   plans vs the layer-composed standard-DeConv reference, stripe-batched
//!   GEMM vs the per-tile dataflow) are stated and tested at this
//!   precision. `f64` plans compute exactly what they did before the
//!   datapath became generic.
//! * **`f32` — the serving fast path.** Halves the bytes every hot-loop
//!   stream moves (the reordered filter slabs, the gathered tile matrices,
//!   the activation maps) and doubles effective SIMD width, mirroring the
//!   reduced-precision deployment the paper's FPGA datapath (and the
//!   Winograd-CNN DSE literature) assumes. `f32` plans carry a *tolerance*
//!   contract against the `f64` reference and the same bitwise
//!   worker-count/schedule-invariance contract as `f64`.
//!
//! [`Precision`] is the runtime-facing tag for the two tiers: plan
//! lowering, the serving config (`NativeConfig::precision`), the
//! `wingan serve --precision` flag and the `WINGAN_PRECISION` environment
//! variable all speak it.

use std::fmt;
use std::ops::{Add, AddAssign, Mul, Sub};

/// Runtime tag for the two supported element precisions.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Precision {
    /// Single precision: the serving fast path (half the memory traffic,
    /// double the SIMD width of the reference tier).
    F32,
    /// Double precision: the reference tier every numerics contract is
    /// anchored to.
    F64,
}

impl Precision {
    /// Parse a user-facing precision name (`"f32"`/`"f64"`, plus the
    /// common aliases `float32`/`single` and `float64`/`double`).
    pub fn parse(s: &str) -> Result<Precision, String> {
        match s.trim().to_ascii_lowercase().as_str() {
            "f32" | "float32" | "single" => Ok(Precision::F32),
            "f64" | "float64" | "double" => Ok(Precision::F64),
            other => Err(format!("unknown precision '{other}' (expected f32 or f64)")),
        }
    }

    /// Canonical lowercase label (`"f32"` / `"f64"`).
    pub fn label(self) -> &'static str {
        match self {
            Precision::F32 => "f32",
            Precision::F64 => "f64",
        }
    }

    /// Bytes per scalar word at this precision.
    pub fn word_bytes(self) -> usize {
        match self {
            Precision::F32 => 4,
            Precision::F64 => 8,
        }
    }
}

impl fmt::Display for Precision {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.label())
    }
}

/// Scalar element type of the execution datapath: everything the tensors,
/// Winograd transforms, reordered filter slabs, GEMM micro-kernel and the
/// whole `engine` need from a float, and nothing more.
///
/// Implemented for `f32` and `f64` only. The arithmetic surface is kept to
/// `+`, `-`, `*`, `+=` and ordering so that every kernel written against
/// `Elem` performs the *same sequence of IEEE operations* at either
/// precision — which is what makes the per-precision bitwise invariance
/// contracts (worker count, batch schedule, blocked vs naive GEMM) hold
/// uniformly.
pub trait Elem:
    Copy
    + PartialEq
    + PartialOrd
    + Send
    + Sync
    + fmt::Debug
    + Add<Output = Self>
    + Sub<Output = Self>
    + Mul<Output = Self>
    + AddAssign
    + 'static
{
    /// Additive identity (the value buffers are zero-filled with).
    const ZERO: Self;
    /// The [`Precision`] tag of this element type.
    const PRECISION: Precision;

    /// Convert from `f64`, rounding to nearest for `f32`. Plan lowering
    /// uses this: Winograd filter transforms are always computed in `f64`
    /// and quantized *after* `G g Gᵀ`, never before.
    fn from_f64(v: f64) -> Self;
    /// Widen (exactly, for both implementors) to `f64`.
    fn to_f64(self) -> f64;
    /// Convert from an `f32` wire value (exact for both implementors —
    /// the serving boundary speaks `f32`).
    fn from_f32(v: f32) -> Self;
    /// Narrow to the `f32` wire format (rounds for `f64`).
    fn to_f32(self) -> f32;
    /// Hyperbolic tangent at this precision (the `tanh` output layers).
    fn tanh(self) -> Self;
    /// Append this value's IEEE-754 little-endian byte representation
    /// (4 bytes for `f32`, 8 for `f64`) — the on-disk word encoding of the
    /// plan-artifact codec ([`crate::artifact`]).
    fn write_le(self, out: &mut Vec<u8>);
    /// Decode one value from exactly [`Precision::word_bytes`] little-endian
    /// bytes: the bit-exact inverse of [`Elem::write_le`] at either
    /// precision (round-tripping a plan through the codec changes no bits).
    fn from_le(bytes: &[u8]) -> Self;
}

impl Elem for f32 {
    const ZERO: f32 = 0.0;
    const PRECISION: Precision = Precision::F32;

    #[inline]
    fn from_f64(v: f64) -> f32 {
        v as f32
    }
    #[inline]
    fn to_f64(self) -> f64 {
        self as f64
    }
    #[inline]
    fn from_f32(v: f32) -> f32 {
        v
    }
    #[inline]
    fn to_f32(self) -> f32 {
        self
    }
    #[inline]
    fn tanh(self) -> f32 {
        f32::tanh(self)
    }
    #[inline]
    fn write_le(self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.to_le_bytes());
    }
    #[inline]
    fn from_le(bytes: &[u8]) -> f32 {
        f32::from_le_bytes(bytes.try_into().expect("f32 word is 4 bytes"))
    }
}

impl Elem for f64 {
    const ZERO: f64 = 0.0;
    const PRECISION: Precision = Precision::F64;

    #[inline]
    fn from_f64(v: f64) -> f64 {
        v
    }
    #[inline]
    fn to_f64(self) -> f64 {
        self
    }
    #[inline]
    fn from_f32(v: f32) -> f64 {
        v as f64
    }
    #[inline]
    fn to_f32(self) -> f32 {
        self as f32
    }
    #[inline]
    fn tanh(self) -> f64 {
        f64::tanh(self)
    }
    #[inline]
    fn write_le(self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.to_le_bytes());
    }
    #[inline]
    fn from_le(bytes: &[u8]) -> f64 {
        f64::from_le_bytes(bytes.try_into().expect("f64 word is 8 bytes"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn precision_parse_and_labels() {
        assert_eq!(Precision::parse("f32").unwrap(), Precision::F32);
        assert_eq!(Precision::parse(" F64 ").unwrap(), Precision::F64);
        assert_eq!(Precision::parse("single").unwrap(), Precision::F32);
        assert_eq!(Precision::parse("double").unwrap(), Precision::F64);
        assert!(Precision::parse("f16").is_err());
        assert_eq!(Precision::F32.label(), "f32");
        assert_eq!(format!("{}", Precision::F64), "f64");
        assert_eq!(Precision::F32.word_bytes(), 4);
        assert_eq!(Precision::F64.word_bytes(), 8);
    }

    #[test]
    fn elem_roundtrips() {
        assert_eq!(<f32 as Elem>::from_f64(0.5), 0.5f32);
        assert_eq!(0.5f32.to_f64(), 0.5f64);
        assert_eq!(<f64 as Elem>::from_f32(1.25), 1.25f64);
        assert_eq!(<f32 as Elem>::PRECISION, Precision::F32);
        assert_eq!(<f64 as Elem>::PRECISION, Precision::F64);
        // f64 -> f32 rounds to nearest; f32 -> f64 is exact
        let x = 0.1f64;
        assert_eq!(<f32 as Elem>::from_f64(x), 0.1f32);
        assert_eq!(0.1f32.to_f64() as f32, 0.1f32);
    }

    #[test]
    fn le_bytes_roundtrip_is_bit_exact() {
        let mut buf = Vec::new();
        for v in [0.0f64, -0.0, 0.1, -1.5e300, f64::MIN_POSITIVE] {
            buf.clear();
            v.write_le(&mut buf);
            assert_eq!(buf.len(), Precision::F64.word_bytes());
            let back = <f64 as Elem>::from_le(&buf);
            assert_eq!(back.to_bits(), v.to_bits());
        }
        for v in [0.0f32, -0.0, 0.1, 3.4e38, f32::MIN_POSITIVE] {
            buf.clear();
            v.write_le(&mut buf);
            assert_eq!(buf.len(), Precision::F32.word_bytes());
            let back = <f32 as Elem>::from_le(&buf);
            assert_eq!(back.to_bits(), v.to_bits());
        }
    }

    #[test]
    fn elem_arithmetic_matches_native() {
        fn fma_chain<E: Elem>(vals: &[E]) -> E {
            let mut acc = E::ZERO;
            for &v in vals {
                acc += v * v;
            }
            acc
        }
        assert_eq!(fma_chain(&[1.0f64, 2.0, 3.0]), 14.0);
        assert_eq!(fma_chain(&[1.0f32, 2.0, 3.0]), 14.0);
    }
}
