//! Minimal dense tensor types used across the substrates, generic over the
//! scalar element type.
//!
//! The numeric substrates default to `E = f64` so that algorithm-equivalence
//! tests can assert tight (often exact) tolerances; the execution engine's
//! f32 serving fast path instantiates the same types at `E = f32` — same
//! layout, same operation order, half the bytes. The PJRT runtime hot path
//! uses raw `f32` buffers and never touches these types.

use crate::util::elem::Elem;

/// Channel-first 3-D tensor `[C, H, W]`.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor3<E: Elem = f64> {
    pub c: usize,
    pub h: usize,
    pub w: usize,
    pub data: Vec<E>,
}

impl<E: Elem> Tensor3<E> {
    pub fn zeros(c: usize, h: usize, w: usize) -> Tensor3<E> {
        Tensor3 { c, h, w, data: vec![E::ZERO; c * h * w] }
    }

    pub fn from_vec(c: usize, h: usize, w: usize, data: Vec<E>) -> Tensor3<E> {
        assert_eq!(data.len(), c * h * w, "tensor3 shape/data mismatch");
        Tensor3 { c, h, w, data }
    }

    #[inline]
    pub fn at(&self, c: usize, y: usize, x: usize) -> E {
        debug_assert!(c < self.c && y < self.h && x < self.w);
        self.data[(c * self.h + y) * self.w + x]
    }

    #[inline]
    pub fn at_mut(&mut self, c: usize, y: usize, x: usize) -> &mut E {
        debug_assert!(c < self.c && y < self.h && x < self.w);
        &mut self.data[(c * self.h + y) * self.w + x]
    }

    /// Zero-pad spatially: `top`/`bot` rows above/below, `left`/`right`
    /// columns. Returns a new tensor of shape `[C, H+top+bot, W+left+right]`.
    pub fn pad(&self, top: usize, bot: usize, left: usize, right: usize) -> Tensor3<E> {
        let mut out = Tensor3::zeros(0, 0, 0);
        self.pad_into(top, bot, left, right, &mut out);
        out
    }

    /// [`Tensor3::pad`] into a caller-owned scratch tensor: `out` is resized
    /// (reusing its allocation once warm), zero-filled, and the interior
    /// copied row by row. Produces bit-identical contents to `pad` — the
    /// execution engine's scratch arenas rely on that equivalence to keep
    /// padded-view reuse invisible to the numerics.
    pub fn pad_into(
        &self,
        top: usize,
        bot: usize,
        left: usize,
        right: usize,
        out: &mut Tensor3<E>,
    ) {
        out.c = self.c;
        out.h = self.h + top + bot;
        out.w = self.w + left + right;
        // clear + resize zero-fills the whole buffer without reallocating
        // once capacity has grown to the layer's working-set high-water mark
        out.data.clear();
        out.data.resize(out.c * out.h * out.w, E::ZERO);
        for c in 0..self.c {
            for y in 0..self.h {
                let src = (c * self.h + y) * self.w;
                let dst = (c * out.h + y + top) * out.w + left;
                out.data[dst..dst + self.w].copy_from_slice(&self.data[src..src + self.w]);
            }
        }
    }

    /// Max absolute element-wise difference (computed in `f64` for either
    /// element precision); shapes must match.
    pub fn max_abs_diff(&self, other: &Tensor3<E>) -> f64 {
        assert_eq!((self.c, self.h, self.w), (other.c, other.h, other.w));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a.to_f64() - b.to_f64()).abs())
            .fold(0.0, f64::max)
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    /// Convert every element to another precision (`f64 → f32` rounds to
    /// nearest; `f32 → f64` is exact). Same shape, fresh buffer.
    pub fn cast_to<T: Elem>(&self) -> Tensor3<T> {
        Tensor3 {
            c: self.c,
            h: self.h,
            w: self.w,
            data: self.data.iter().map(|&v| T::from_f64(v.to_f64())).collect(),
        }
    }
}

/// DeConv / Conv filter bank in conv-transpose layout `[C_in, C_out, K_h, K_w]`.
#[derive(Clone, Debug, PartialEq)]
pub struct Filter4<E: Elem = f64> {
    pub c_in: usize,
    pub c_out: usize,
    pub kh: usize,
    pub kw: usize,
    pub data: Vec<E>,
}

impl<E: Elem> Filter4<E> {
    pub fn zeros(c_in: usize, c_out: usize, kh: usize, kw: usize) -> Filter4<E> {
        Filter4 { c_in, c_out, kh, kw, data: vec![E::ZERO; c_in * c_out * kh * kw] }
    }

    pub fn from_vec(c_in: usize, c_out: usize, kh: usize, kw: usize, data: Vec<E>) -> Filter4<E> {
        assert_eq!(data.len(), c_in * c_out * kh * kw, "filter4 shape/data mismatch");
        Filter4 { c_in, c_out, kh, kw, data }
    }

    #[inline]
    pub fn at(&self, ci: usize, co: usize, ky: usize, kx: usize) -> E {
        debug_assert!(ci < self.c_in && co < self.c_out && ky < self.kh && kx < self.kw);
        self.data[((ci * self.c_out + co) * self.kh + ky) * self.kw + kx]
    }

    #[inline]
    pub fn at_mut(&mut self, ci: usize, co: usize, ky: usize, kx: usize) -> &mut E {
        debug_assert!(ci < self.c_in && co < self.c_out && ky < self.kh && kx < self.kw);
        &mut self.data[((ci * self.c_out + co) * self.kh + ky) * self.kw + kx]
    }

    /// Convert every tap to another precision (see [`Tensor3::cast_to`]).
    pub fn cast_to<T: Elem>(&self) -> Filter4<T> {
        Filter4 {
            c_in: self.c_in,
            c_out: self.c_out,
            kh: self.kh,
            kw: self.kw,
            data: self.data.iter().map(|&v| T::from_f64(v.to_f64())).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor3_indexing_roundtrip() {
        let mut t = Tensor3::zeros(2, 3, 4);
        *t.at_mut(1, 2, 3) = 5.0;
        assert_eq!(t.at(1, 2, 3), 5.0);
        assert_eq!(t.at(0, 0, 0), 0.0);
        assert_eq!(t.numel(), 24);
    }

    #[test]
    fn tensor3_pad_places_content() {
        let t = Tensor3::from_vec(1, 1, 2, vec![1.0, 2.0]);
        let p = t.pad(1, 0, 2, 1);
        assert_eq!((p.h, p.w), (2, 5));
        assert_eq!(p.at(0, 1, 2), 1.0);
        assert_eq!(p.at(0, 1, 3), 2.0);
        assert_eq!(p.at(0, 0, 0), 0.0);
    }

    #[test]
    fn filter4_layout() {
        let mut f = Filter4::zeros(2, 3, 4, 4);
        *f.at_mut(1, 2, 3, 0) = 7.0;
        assert_eq!(f.at(1, 2, 3, 0), 7.0);
        assert_eq!(f.data.len(), 2 * 3 * 16);
    }

    #[test]
    fn pad_into_reuses_buffer_and_matches_pad() {
        let t = Tensor3::from_vec(2, 2, 3, (0..12).map(|v| v as f64).collect());
        let mut scratch = Tensor3::zeros(0, 0, 0);
        // first use grows the buffer; a later smaller pad must still be
        // fully zeroed outside the interior (no stale data)
        t.pad_into(3, 3, 3, 3, &mut scratch);
        assert_eq!(scratch.data, t.pad(3, 3, 3, 3).data);
        t.pad_into(1, 0, 0, 2, &mut scratch);
        let want = t.pad(1, 0, 0, 2);
        assert_eq!((scratch.c, scratch.h, scratch.w), (want.c, want.h, want.w));
        assert_eq!(scratch.data, want.data);
    }

    #[test]
    fn max_abs_diff_basic() {
        let a = Tensor3::from_vec(1, 1, 2, vec![1.0, 2.0]);
        let b = Tensor3::from_vec(1, 1, 2, vec![1.5, 2.0]);
        assert_eq!(a.max_abs_diff(&b), 0.5);
    }

    #[test]
    fn f32_tensors_share_the_generic_surface() {
        let t: Tensor3<f32> = Tensor3::from_vec(1, 2, 2, vec![1.0, -2.0, 3.0, -4.0]);
        let p = t.pad(0, 1, 1, 0);
        assert_eq!((p.c, p.h, p.w), (1, 3, 3));
        assert_eq!(p.at(0, 0, 1), 1.0);
        assert_eq!(p.at(0, 2, 2), 0.0);
        let back: Tensor3<f64> = t.cast_to();
        assert_eq!(back.at(0, 1, 1), -4.0);
        // f32 -> f64 -> f32 is the identity
        assert_eq!(back.cast_to::<f32>().data, t.data);
    }

    #[test]
    fn cast_rounds_f64_to_nearest_f32() {
        let t = Tensor3::from_vec(1, 1, 1, vec![0.1f64]);
        let c: Tensor3<f32> = t.cast_to();
        assert_eq!(c.data[0], 0.1f32);
        let f = Filter4::from_vec(1, 1, 1, 1, vec![0.3f64]);
        assert_eq!(f.cast_to::<f32>().data[0], 0.3f32);
    }
}
