//! Deterministic PRNG substrate (no external `rand` available offline).
//!
//! SplitMix64 core with uniform / normal / exponential helpers. Used by the
//! workload generator, property tests, and example drivers. Deterministic
//! across platforms (pure integer arithmetic + IEEE ops).

/// SplitMix64: tiny, fast, passes BigCrush when used as a stream.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53 mantissa bits
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n). n must be > 0.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in [lo, hi] inclusive.
    #[inline]
    pub fn int_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi >= lo);
        lo + self.below(hi - lo + 1)
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let mut u1 = self.uniform();
        if u1 < 1e-300 {
            u1 = 1e-300;
        }
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Exponential with rate lambda (mean 1/lambda); used for Poisson
    /// request inter-arrival times in the workload generator.
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        assert!(lambda > 0.0);
        let mut u = self.uniform();
        if u >= 1.0 {
            u = 1.0 - 1e-16;
        }
        -(1.0 - u).ln() / lambda
    }

    /// Vector of standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.normal()).collect()
    }

    /// f32 vector of standard normals (runtime input buffers).
    pub fn normal_vec_f32(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal() as f32).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn normal_moments_roughly_standard() {
        let mut r = Rng::new(3);
        let n = 50_000;
        let xs = r.normal_vec(n);
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn exponential_mean_matches_rate() {
        let mut r = Rng::new(9);
        let n = 50_000;
        let mean = (0..n).map(|_| r.exponential(4.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.25).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn int_in_bounds() {
        let mut r = Rng::new(11);
        for _ in 0..1000 {
            let v = r.int_in(3, 9);
            assert!((3..=9).contains(&v));
        }
    }
}
