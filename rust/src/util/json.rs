//! Minimal JSON substrate (parser + writer) — no serde available offline.
//!
//! Scope: everything the artifact manifest and the CLI/report outputs need:
//! objects, arrays, strings with escapes, numbers (f64), bools, null. The
//! parser is a straightforward recursive-descent over bytes; it rejects
//! trailing garbage and unterminated constructs with a byte offset in the
//! error message.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as usize),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// `[1,2,3]` -> `vec![1,2,3]`; None on any non-integer element.
    pub fn as_usize_vec(&self) -> Option<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }
}

#[derive(Debug)]
pub struct JsonError {
    pub offset: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err<T>(&self, msg: impl Into<String>) -> Result<T, JsonError> {
        Err(JsonError { offset: self.pos, msg: msg.into() })
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            self.err(format!("expected '{}'", b as char))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => self.err(format!("unexpected byte '{}'", c as char)),
            None => self.err("unexpected end of input"),
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            self.err(format!("invalid literal, expected {lit}"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        match s.parse::<f64>() {
            Ok(n) => Ok(Json::Num(n)),
            Err(_) => self.err(format!("bad number '{s}'")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return self.err("unterminated string"),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b't') => out.push('\t'),
                    Some(b'r') => out.push('\r'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        if self.pos + 4 > self.bytes.len() {
                            return self.err("truncated \\u escape");
                        }
                        let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
                            .map_err(|_| JsonError { offset: self.pos, msg: "bad \\u".into() })?;
                        let cp = u32::from_str_radix(hex, 16)
                            .map_err(|_| JsonError { offset: self.pos, msg: "bad \\u".into() })?;
                        self.pos += 4;
                        // BMP only (manifest never emits surrogate pairs)
                        out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                    }
                    _ => return self.err("bad escape"),
                },
                Some(c) => out.push(c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return self.err("expected ',' or ']'"),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return self.err("expected ',' or '}'"),
            }
        }
    }
}

/// Parse a complete JSON document (rejects trailing non-whitespace).
pub fn parse(text: &str) -> Result<Json, JsonError> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return p.err("trailing garbage after document");
    }
    Ok(v)
}

/// Serialize with 2-space indentation (stable key order via BTreeMap).
pub fn to_string_pretty(v: &Json) -> String {
    let mut s = String::new();
    write_value(v, 0, &mut s);
    s
}

/// Serialize compactly on one line (stable key order via BTreeMap) — the
/// format for machine-tailable outputs like `--stats-every` stderr lines,
/// where one document per line is the contract.
pub fn to_string(v: &Json) -> String {
    let mut s = String::new();
    write_compact(v, &mut s);
    s
}

fn write_compact(v: &Json, out: &mut String) {
    match v {
        Json::Null | Json::Bool(_) | Json::Num(_) | Json::Str(_) => write_value(v, 0, out),
        Json::Arr(items) => {
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_compact(item, out);
            }
            out.push(']');
        }
        Json::Obj(map) => {
            out.push('{');
            for (i, (k, val)) in map.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_string(k, out);
                out.push(':');
                write_compact(val, out);
            }
            out.push('}');
        }
    }
}

fn write_value(v: &Json, indent: usize, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 9e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{n}"));
            }
        }
        Json::Str(s) => write_string(s, out),
        Json::Arr(items) => {
            if items.is_empty() {
                out.push_str("[]");
                return;
            }
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                out.push('\n');
                out.push_str(&"  ".repeat(indent + 1));
                write_value(item, indent + 1, out);
            }
            out.push('\n');
            out.push_str(&"  ".repeat(indent));
            out.push(']');
        }
        Json::Obj(map) => {
            if map.is_empty() {
                out.push_str("{}");
                return;
            }
            out.push('{');
            for (i, (k, val)) in map.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                out.push('\n');
                out.push_str(&"  ".repeat(indent + 1));
                write_string(k, out);
                out.push_str(": ");
                write_value(val, indent + 1, out);
            }
            out.push('\n');
            out.push_str(&"  ".repeat(indent));
            out.push('}');
        }
    }
}

fn write_string(s: &str, out: &mut String) {
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience: `obj!{ "k" => v, ... }`-style builder helpers.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let doc = r#"{"a": [1, 2, {"b": "x", "c": false}], "d": {}}"#;
        let v = parse(doc).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("x")
        );
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(parse("{} x").is_err());
        assert!(parse("[1,").is_err());
        assert!(parse("\"abc").is_err());
    }

    #[test]
    fn roundtrip_pretty() {
        let doc = r#"{"name": "dcgan_b1", "shape": [1, 32], "ok": true, "f": 1.5}"#;
        let v = parse(doc).unwrap();
        let s = to_string_pretty(&v);
        assert_eq!(parse(&s).unwrap(), v);
    }

    #[test]
    fn compact_is_one_line_and_round_trips() {
        let doc = r#"{"name": "dcgan_b1", "shape": [1, 32], "ok": true, "f": 1.5, "e": {}}"#;
        let v = parse(doc).unwrap();
        let c = to_string(&v);
        assert!(!c.contains('\n'), "compact output must be a single line: {c}");
        assert!(!c.contains(": "), "compact output has no cosmetic spaces: {c}");
        assert_eq!(parse(&c).unwrap(), v);
        assert_eq!(c, r#"{"e":{},"f":1.5,"name":"dcgan_b1","ok":true,"shape":[1,32]}"#);
    }

    #[test]
    fn usize_vec_accessor() {
        let v = parse("[1, 2, 3]").unwrap();
        assert_eq!(v.as_usize_vec(), Some(vec![1, 2, 3]));
        let bad = parse("[1, 2.5]").unwrap();
        assert_eq!(bad.as_usize_vec(), None);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(parse("\"\\u0041\"").unwrap(), Json::Str("A".into()));
    }
}
