//! Runtime: PJRT execution of the AOT artifacts (HLO text → compile →
//! execute).
//!
//! * [`manifest`] — the python/rust contract: `python/compile/aot.py`
//!   writes a `manifest.json` describing each artifact ([`ArtifactEntry`]:
//!   model, method, batch bucket, shapes, golden vectors); [`Manifest`]
//!   loads and indexes it. The native backend synthesises the same
//!   manifest shape with no files behind it
//!   ([`crate::engine::native_manifest`]), so the coordinator's router is
//!   backend-agnostic.
//! * [`client`] — the execution engine. In offline builds the `xla` crate
//!   is unavailable, so [`Runtime`] preserves the full API but reports
//!   itself unavailable at construction; `rust/tests/runtime_e2e.rs`
//!   un-skips automatically once a real PJRT backend is restored.

pub mod client;
pub mod manifest;

pub use client::Runtime;
pub use manifest::{ArtifactEntry, Manifest};
