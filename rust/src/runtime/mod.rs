//! Runtime: PJRT execution of the AOT artifacts (HLO text -> compile ->
//! execute). See `manifest` for the python/rust contract and `client` for
//! the execution engine.

pub mod client;
pub mod manifest;

pub use client::Runtime;
pub use manifest::{ArtifactEntry, Manifest};
