//! PJRT runtime: loads HLO-text artifacts and executes them on the CPU
//! PJRT client via the `xla` crate.
//!
//! This is the request-path compute engine — python is never involved.
//! HLO *text* is the interchange format (see `python/compile/aot.py`);
//! computations were lowered with `return_tuple=True`, so results unwrap
//! with `to_tuple1()`.

use crate::runtime::manifest::{ArtifactEntry, Manifest};
use crate::util::bin;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;

/// A compiled artifact ready to execute.
pub struct Loaded {
    pub entry: ArtifactEntry,
    exe: xla::PjRtLoadedExecutable,
}

/// The PJRT runtime: one CPU client + a cache of compiled executables.
pub struct Runtime {
    client: xla::PjRtClient,
    loaded: HashMap<String, Loaded>,
}

impl Runtime {
    /// Create a CPU PJRT client.
    pub fn new() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().map_err(to_anyhow)?;
        Ok(Runtime { client, loaded: HashMap::new() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile one artifact (no-op if already cached).
    pub fn load(&mut self, entry: &ArtifactEntry) -> Result<()> {
        if self.loaded.contains_key(&entry.name) {
            return Ok(());
        }
        let path = entry
            .hlo
            .to_str()
            .ok_or_else(|| anyhow!("non-utf8 artifact path {:?}", entry.hlo))?;
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(to_anyhow)
            .with_context(|| format!("parsing HLO text {path}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(to_anyhow)
            .with_context(|| format!("compiling {}", entry.name))?;
        self.loaded.insert(entry.name.clone(), Loaded { entry: entry.clone(), exe });
        Ok(())
    }

    /// Compile every artifact in the manifest.
    pub fn load_all(&mut self, manifest: &Manifest) -> Result<usize> {
        for e in &manifest.entries {
            self.load(e)?;
        }
        Ok(manifest.entries.len())
    }

    pub fn is_loaded(&self, name: &str) -> bool {
        self.loaded.contains_key(name)
    }

    pub fn loaded_names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.loaded.keys().cloned().collect();
        v.sort();
        v
    }

    /// Execute an artifact on a flat f32 input of the manifest shape.
    pub fn execute(&self, name: &str, input: &[f32]) -> Result<Vec<f32>> {
        let l = self
            .loaded
            .get(name)
            .ok_or_else(|| anyhow!("artifact {name} not loaded"))?;
        if input.len() != l.entry.input_len() {
            bail!(
                "artifact {name}: input length {} != expected {} (shape {:?})",
                input.len(),
                l.entry.input_len(),
                l.entry.input_shape
            );
        }
        let dims: Vec<i64> = l.entry.input_shape.iter().map(|&d| d as i64).collect();
        let lit = xla::Literal::vec1(input).reshape(&dims).map_err(to_anyhow)?;
        let result = l.exe.execute::<xla::Literal>(&[lit]).map_err(to_anyhow)?;
        let out = result[0][0]
            .to_literal_sync()
            .map_err(to_anyhow)?
            .to_tuple1()
            .map_err(to_anyhow)?;
        let values = out.to_vec::<f32>().map_err(to_anyhow)?;
        if values.len() != l.entry.output_len() {
            bail!(
                "artifact {name}: output length {} != manifest {}",
                values.len(),
                l.entry.output_len()
            );
        }
        Ok(values)
    }

    /// Run the artifact on its golden input and return the max abs error
    /// vs the golden output (the rust-vs-jax numerics check).
    pub fn verify_golden(&self, name: &str) -> Result<f32> {
        let l = self
            .loaded
            .get(name)
            .ok_or_else(|| anyhow!("artifact {name} not loaded"))?;
        let x = bin::read_f32(&l.entry.golden_input)?;
        let want = bin::read_f32(&l.entry.golden_output)?;
        let got = self.execute(name, &x)?;
        if got.len() != want.len() {
            bail!("artifact {name}: golden length mismatch");
        }
        Ok(bin::max_abs_diff(&got, &want))
    }
}

/// xla::Error doesn't implement std::error::Error compatibly with anyhow's
/// blanket conversions in all versions; go through Display.
fn to_anyhow<E: std::fmt::Display>(e: E) -> anyhow::Error {
    anyhow!("{e}")
}
