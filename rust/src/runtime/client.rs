//! PJRT runtime: loads HLO-text artifacts and executes them on a CPU PJRT
//! client.
//!
//! **Offline gate.** The real implementation drives the `xla` crate
//! (PJRT C-API bindings); that crate is unavailable in this build
//! environment, so this module compiles a stub that preserves the full
//! `Runtime` API and fails fast — [`Runtime::new`] always errors, and
//! every other method (unreachable without a constructed runtime, but kept
//! for API parity) reports the same condition. Everything above this layer
//! is written against the API only:
//! * the serving path has a native, pure-rust execution backend
//!   ([`crate::engine`]) that does not need PJRT at all;
//! * `runtime_e2e.rs` tests and the PJRT benches skip when either the
//!   artifacts or this backend are unavailable.
//!
//! Restoring the real backend is a matter of adding the `xla` dependency
//! and reinstating the `PjRtClient::cpu()` / `compile()` / `execute()`
//! calls; the method contracts (input/output lengths validated against the
//! manifest, golden-vector verification) are unchanged.

use crate::runtime::manifest::{ArtifactEntry, Manifest};
use anyhow::{bail, Result};

/// The PJRT runtime: one CPU client + a cache of compiled executables.
///
/// In this offline build [`Runtime::new`] always returns an error; callers
/// that can run without PJRT (the coordinator's native backend, the benches,
/// the e2e tests) treat that as "backend unavailable" and fall back or skip.
pub struct Runtime {
    _unconstructable: (),
}

const OFFLINE_MSG: &str = "PJRT backend unavailable: this build has no `xla` crate \
     (offline environment). Use the native engine backend \
     (`Coordinator::start_native` / `wingan::engine`) instead.";

impl Runtime {
    /// Create a CPU PJRT client. Always fails in the offline build.
    pub fn new() -> Result<Runtime> {
        bail!("{OFFLINE_MSG}");
    }

    pub fn platform(&self) -> String {
        "unavailable".to_string()
    }

    /// Compile one artifact (no-op if already cached).
    pub fn load(&mut self, _entry: &ArtifactEntry) -> Result<()> {
        bail!("{OFFLINE_MSG}");
    }

    /// Compile every artifact in the manifest.
    pub fn load_all(&mut self, manifest: &Manifest) -> Result<usize> {
        for e in &manifest.entries {
            self.load(e)?;
        }
        Ok(manifest.entries.len())
    }

    pub fn is_loaded(&self, _name: &str) -> bool {
        false
    }

    pub fn loaded_names(&self) -> Vec<String> {
        Vec::new()
    }

    /// Execute an artifact on a flat f32 input of the manifest shape.
    pub fn execute(&self, _name: &str, _input: &[f32]) -> Result<Vec<f32>> {
        bail!("{OFFLINE_MSG}");
    }

    /// Run the artifact on its golden input and return the max abs error
    /// vs the golden output (the rust-vs-jax numerics check).
    pub fn verify_golden(&self, _name: &str) -> Result<f32> {
        bail!("{OFFLINE_MSG}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn offline_runtime_reports_unavailable() {
        let err = Runtime::new().unwrap_err();
        assert!(format!("{err:#}").contains("PJRT backend unavailable"));
    }
}
