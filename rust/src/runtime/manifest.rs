//! Artifact manifest: the index `python/compile/aot.py` writes next to the
//! HLO text files. The manifest is the only contract between the python
//! build path and the rust serving path.

use crate::util::json::{self, Json};
use anyhow::{anyhow, bail, Context, Result};
use std::path::{Path, PathBuf};

/// One AOT-compiled computation.
#[derive(Clone, Debug)]
pub struct ArtifactEntry {
    pub name: String,
    /// "generator" (full model) or "layer" (single deconv op)
    pub kind: String,
    pub model: String,
    /// compute path baked into the HLO: "winograd" | "tdc" | "zero_pad"
    pub method: String,
    pub batch: usize,
    pub hlo: PathBuf,
    pub input_shape: Vec<usize>,
    pub output_shape: Vec<usize>,
    pub golden_input: PathBuf,
    pub golden_output: PathBuf,
}

impl ArtifactEntry {
    pub fn input_len(&self) -> usize {
        self.input_shape.iter().product()
    }

    pub fn output_len(&self) -> usize {
        self.output_shape.iter().product()
    }

    /// Per-sample input length (shape without the leading batch dim).
    pub fn sample_input_len(&self) -> usize {
        if self.kind == "generator" {
            self.input_shape[1..].iter().product()
        } else {
            self.input_len()
        }
    }

    pub fn sample_output_len(&self) -> usize {
        if self.kind == "generator" {
            self.output_shape[1..].iter().product()
        } else {
            self.output_len()
        }
    }
}

/// The parsed manifest plus its base directory.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub scale: String,
    pub entries: Vec<ArtifactEntry>,
}

fn field<'a>(obj: &'a Json, key: &str, ctx: &str) -> Result<&'a Json> {
    obj.get(key).ok_or_else(|| anyhow!("manifest entry {ctx}: missing field '{key}'"))
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts` first)", path.display()))?;
        let doc = json::parse(&text).map_err(|e| anyhow!("{}: {e}", path.display()))?;
        let version = doc.get("version").and_then(Json::as_usize).unwrap_or(0);
        if version != 1 {
            bail!("unsupported manifest version {version}");
        }
        let scale =
            doc.get("scale").and_then(Json::as_str).unwrap_or("unknown").to_string();
        let mut entries = Vec::new();
        for (i, e) in doc
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest: missing 'artifacts' array"))?
            .iter()
            .enumerate()
        {
            let ctx = format!("#{i}");
            let name = field(e, "name", &ctx)?
                .as_str()
                .ok_or_else(|| anyhow!("entry {ctx}: name not a string"))?
                .to_string();
            let get_str = |k: &str| -> Result<String> {
                Ok(field(e, k, &name)?
                    .as_str()
                    .ok_or_else(|| anyhow!("entry {name}: {k} not a string"))?
                    .to_string())
            };
            let get_shape = |k: &str| -> Result<Vec<usize>> {
                field(e, k, &name)?
                    .as_usize_vec()
                    .ok_or_else(|| anyhow!("entry {name}: {k} not an int array"))
            };
            entries.push(ArtifactEntry {
                kind: get_str("kind")?,
                model: get_str("model")?,
                method: get_str("method")?,
                batch: field(e, "batch", &name)?
                    .as_usize()
                    .ok_or_else(|| anyhow!("entry {name}: batch not an int"))?,
                hlo: dir.join(get_str("hlo")?),
                input_shape: get_shape("input_shape")?,
                output_shape: get_shape("output_shape")?,
                golden_input: dir.join(get_str("golden_input")?),
                golden_output: dir.join(get_str("golden_output")?),
                name,
            });
        }
        Ok(Manifest { dir: dir.to_path_buf(), scale, entries })
    }

    pub fn find(&self, name: &str) -> Option<&ArtifactEntry> {
        self.entries.iter().find(|e| e.name == name)
    }

    /// Generator artifacts for one model+method, sorted by batch size —
    /// these are the batch buckets the dynamic batcher packs into.
    pub fn buckets(&self, model: &str, method: &str) -> Vec<&ArtifactEntry> {
        let mut v: Vec<&ArtifactEntry> = self
            .entries
            .iter()
            .filter(|e| e.kind == "generator" && e.model == model && e.method == method)
            .collect();
        v.sort_by_key(|e| e.batch);
        v
    }

    /// Distinct generator model names.
    pub fn models(&self) -> Vec<String> {
        let mut v: Vec<String> = self
            .entries
            .iter()
            .filter(|e| e.kind == "generator")
            .map(|e| e.model.clone())
            .collect();
        v.sort();
        v.dedup();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, body: &str) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.json"), body).unwrap();
    }

    #[test]
    fn parses_minimal_manifest() {
        let dir = std::env::temp_dir().join("wingan_manifest_test");
        write_manifest(
            &dir,
            r#"{"version": 1, "scale": "small", "artifacts": [
                {"name": "m_b1", "kind": "generator", "model": "m",
                 "method": "winograd", "batch": 1, "hlo": "m_b1.hlo.txt",
                 "input_shape": [1, 32], "output_shape": [1, 3, 4, 4],
                 "golden_input": "golden/m.in.bin",
                 "golden_output": "golden/m.out.bin"}]}"#,
        );
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.entries.len(), 1);
        let e = m.find("m_b1").unwrap();
        assert_eq!(e.batch, 1);
        assert_eq!(e.sample_input_len(), 32);
        assert_eq!(e.sample_output_len(), 48);
        assert_eq!(m.models(), vec!["m".to_string()]);
    }

    #[test]
    fn rejects_bad_version() {
        let dir = std::env::temp_dir().join("wingan_manifest_test2");
        write_manifest(&dir, r#"{"version": 9, "artifacts": []}"#);
        assert!(Manifest::load(&dir).is_err());
    }

    #[test]
    fn missing_field_is_reported_with_entry_name() {
        let dir = std::env::temp_dir().join("wingan_manifest_test3");
        write_manifest(
            &dir,
            r#"{"version": 1, "artifacts": [{"name": "x", "kind": "generator"}]}"#,
        );
        let err = Manifest::load(&dir).unwrap_err().to_string();
        assert!(err.contains('x'), "{err}");
    }

    #[test]
    fn buckets_sorted_by_batch() {
        let dir = std::env::temp_dir().join("wingan_manifest_test4");
        let entry = |name: &str, batch: usize| {
            format!(
                r#"{{"name": "{name}", "kind": "generator", "model": "m",
                 "method": "winograd", "batch": {batch}, "hlo": "x",
                 "input_shape": [{batch}, 2], "output_shape": [{batch}, 2],
                 "golden_input": "g", "golden_output": "g"}}"#
            )
        };
        write_manifest(
            &dir,
            &format!(
                r#"{{"version": 1, "artifacts": [{}, {}, {}]}}"#,
                entry("m_b8", 8),
                entry("m_b1", 1),
                entry("m_b4", 4)
            ),
        );
        let m = Manifest::load(&dir).unwrap();
        let b: Vec<usize> = m.buckets("m", "winograd").iter().map(|e| e.batch).collect();
        assert_eq!(b, vec![1, 4, 8]);
    }
}
