//! Structural sparsity of Winograd-transformed TDC sub-filters
//! (paper Fig. 3 + Fig. 6).
//!
//! A TDC sub-filter with `r < 3` real taps in a dimension, zero-padded to
//! 3 taps before `G f G^T`, produces a transformed tile whose 4th line in
//! that dimension is *structurally* zero (G row 3 = [0,0,1] touches only
//! the padded tap). In the reordered `n^2 x N` layout those become whole
//! zero rows — "vector-level sparsity" — that the accelerating engine skips.

use crate::winograd::transforms::N;

/// Paper Fig. 6 case taxonomy, extended with the degenerate empty case.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Case {
    /// 3x3 support: no structural zeros (16 live positions).
    Dense,
    /// one dim has 2 taps: n = 4 zero rows (12 live positions).
    OneLine,
    /// both dims have 2 taps: 2n-1 = 7 zero rows (9 live positions).
    TwoLines,
    /// zero real taps in some dim: the sub-filter is identically zero and
    /// the whole phase is skipped (0 live positions). Outside the paper's
    /// taxonomy — reachable only for exotic (K, S, P) combos.
    Empty,
}

impl Case {
    pub fn number(self) -> usize {
        match self {
            Case::Dense => 1,
            Case::OneLine => 2,
            Case::TwoLines => 3,
            Case::Empty => 0,
        }
    }

    /// Live (non-zero) Winograd positions out of n^2 = 16.
    pub fn live_positions(self) -> usize {
        match self {
            Case::Dense => 16,
            Case::OneLine => 12,
            Case::TwoLines => 9,
            Case::Empty => 0,
        }
    }

    /// Structurally-zero rows in the n^2 x N layout.
    pub fn zero_rows(self) -> usize {
        16 - self.live_positions()
    }
}

/// Classify a sub-filter by its structural support (real taps per dim).
/// Zero taps in either dim is the degenerate [`Case::Empty`].
pub fn classify(ry: usize, rx: usize) -> Case {
    assert!(ry <= 3 && rx <= 3);
    if ry == 0 || rx == 0 {
        return Case::Empty;
    }
    match (ry >= 3, rx >= 3) {
        (true, true) => Case::Dense,
        (true, false) | (false, true) => Case::OneLine,
        (false, false) => Case::TwoLines,
    }
}

/// Row-major list of live Winograd positions in the 4x4 tile for a
/// sub-filter with (ry, rx) real taps. len == classify(ry,rx).live_positions().
pub fn nonzero_positions(ry: usize, rx: usize) -> Vec<usize> {
    let mut out = Vec::with_capacity(16);
    for i in 0..N {
        if i == 3 && ry < 3 {
            continue;
        }
        for j in 0..N {
            if j == 3 && rx < 3 {
                continue;
            }
            out.push(i * N + j);
        }
    }
    out
}

/// Total live Winograd-domain multiplications across the S^2 sub-filters of
/// a (K, S, P) deconv, per (c_in, c_out) pair per m x m tile — the paper's
/// `C(K_C)`: 49 for (5,2), 36 for (4,2), 16 for (3,1).
pub fn c_of_kc(k: usize, s: usize, p: usize) -> usize {
    let mut total = 0;
    for py in 0..s {
        let ty = crate::tdc::phase_taps_1d(k, s, p, py);
        for px in 0..s {
            let tx = crate::tdc::phase_taps_1d(k, s, p, px);
            total += classify(ty.real_taps().min(3), tx.real_taps().min(3)).live_positions();
        }
    }
    total
}

/// Per-phase sparsity cases of a (K, S, P) deconv, row-major over (py, px).
///
/// The paper's three kernel classes are answered from a precomputed table
/// (the cycle model calls this in its inner sweep); everything else falls
/// through to the structural derivation.
pub fn phase_cases(k: usize, s: usize, p: usize) -> Vec<Case> {
    match (k, s, p) {
        (5, 2, 2) => return vec![Case::Dense, Case::OneLine, Case::OneLine, Case::TwoLines],
        (4, 2, 1) => return vec![Case::TwoLines; 4],
        (3, 1, 1) => return vec![Case::Dense],
        _ => {}
    }
    let mut out = Vec::with_capacity(s * s);
    for py in 0..s {
        let ty = crate::tdc::phase_taps_1d(k, s, p, py);
        for px in 0..s {
            let tx = crate::tdc::phase_taps_1d(k, s, p, px);
            out.push(classify(ty.real_taps().min(3), tx.real_taps().min(3)));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tdc::default_padding;
    use crate::util::prng::Rng;
    use crate::util::tensor::Filter4;
    use crate::winograd::transforms::filter_bank_transform;

    #[test]
    fn case_counts() {
        assert_eq!(classify(3, 3), Case::Dense);
        assert_eq!(classify(3, 2), Case::OneLine);
        assert_eq!(classify(2, 3), Case::OneLine);
        assert_eq!(classify(2, 2), Case::TwoLines);
        assert_eq!(Case::Dense.live_positions(), 16);
        assert_eq!(Case::OneLine.live_positions(), 12);
        assert_eq!(Case::TwoLines.live_positions(), 9);
        assert_eq!(Case::OneLine.zero_rows(), 4); // n
        assert_eq!(Case::TwoLines.zero_rows(), 7); // 2n - 1
    }

    #[test]
    fn c_of_kc_matches_paper_eq5() {
        assert_eq!(c_of_kc(5, 2, default_padding(5, 2)), 49);
        assert_eq!(c_of_kc(4, 2, default_padding(4, 2)), 36);
        assert_eq!(c_of_kc(3, 1, default_padding(3, 1)), 16);
    }

    #[test]
    fn degenerate_phases_classify_as_empty() {
        assert_eq!(classify(0, 2), Case::Empty);
        assert_eq!(classify(2, 0), Case::Empty);
        assert_eq!(Case::Empty.live_positions(), 0);
        assert_eq!(Case::Empty.number(), 0);
        assert_eq!(Case::Empty.zero_rows(), 16);
        // K=1, S=2, P=0: only phase (0,0) carries the tap; the three
        // degenerate phases contribute zero live positions
        let cases = phase_cases(1, 2, default_padding(1, 2));
        assert_eq!(
            cases,
            vec![Case::TwoLines, Case::Empty, Case::Empty, Case::Empty]
        );
        assert_eq!(c_of_kc(1, 2, default_padding(1, 2)), 9);
    }

    #[test]
    fn k4_all_phases_case3() {
        // the paper: "when K_D is 4 ... all transformed filters operate in Case 3"
        let cases = phase_cases(4, 2, 1);
        assert_eq!(cases, vec![Case::TwoLines; 4]);
    }

    #[test]
    fn k5_phase_case_mix() {
        let cases = phase_cases(5, 2, 2);
        assert_eq!(
            cases,
            vec![Case::Dense, Case::OneLine, Case::OneLine, Case::TwoLines]
        );
    }

    #[test]
    fn nonzero_positions_agree_with_actual_transform_zeros() {
        // transform random sub-filters and check the predicted mask is exact
        let mut rng = Rng::new(300);
        for &(ry, rx) in &[(3usize, 3usize), (3, 2), (2, 3), (2, 2)] {
            let g = Filter4::from_vec(1, 1, ry, rx, rng.normal_vec(ry * rx));
            let u = &filter_bank_transform(&g)[0];
            let live = nonzero_positions(ry, rx);
            for pos in 0..16 {
                let (i, j) = (pos / 4, pos % 4);
                if live.contains(&pos) {
                    // generically non-zero (random filter)
                    assert!(u[i][j].abs() > 1e-12, "({ry},{rx}) pos {pos} unexpectedly zero");
                } else {
                    assert_eq!(u[i][j], 0.0, "({ry},{rx}) pos {pos} should be structural zero");
                }
            }
        }
    }
}
