//! The paper's dataflow reorganisation (Fig. 5): flatten transformed 4x4
//! filters/tiles into an `n^2 x N` matrix layout so that structural zeros
//! become whole zero *rows* shared by every channel — vector-level sparsity
//! the com-PE array can skip without any per-element predication.
//!
//! This module owns the reordered representations used by both the
//! functional accelerator simulator (`accel::functional`) and the cycle
//! model (`accel::cycle`).

use crate::tdc::PhaseFilter;
use crate::util::tensor::Tensor3;
use crate::winograd::sparsity::{classify, nonzero_positions, Case};
use crate::winograd::transforms::{filter_bank_transform, input_transform, Tile4, M, N};

/// One TDC phase's filters in the Winograd domain, reordered with zero rows
/// removed: `u[p][co][ci]` for p over the *live* positions only.
#[derive(Clone, Debug)]
pub struct ReorderedFilter {
    pub case: Case,
    /// live position indices into the row-major 4x4 (len 16/12/9)
    pub live: Vec<usize>,
    pub c_in: usize,
    pub c_out: usize,
    /// `[live.len() * c_out * c_in]`, position-major
    pub u: Vec<f64>,
    /// phase input offsets (from the TDC decomposition)
    pub d0y: isize,
    pub d0x: isize,
}

impl ReorderedFilter {
    #[inline]
    pub fn at(&self, p: usize, co: usize, ci: usize) -> f64 {
        self.u[(p * self.c_out + co) * self.c_in + ci]
    }

    /// Multiplications per (tile, c_in, c_out): the live position count.
    pub fn mults_per_tile(&self) -> usize {
        self.live.len()
    }
}

/// Build the reordered Winograd-domain filter for one TDC phase.
pub fn reorder_filter(ph: &PhaseFilter) -> ReorderedFilter {
    let case = classify(ph.ry.clamp(1, 3), ph.rx.clamp(1, 3));
    let live = nonzero_positions(ph.ry.clamp(1, 3), ph.rx.clamp(1, 3));
    let bank = filter_bank_transform(&ph.g); // [ci*c_out] of Tile4
    let (c_in, c_out) = (ph.g.c_in, ph.g.c_out);
    let mut u = vec![0.0; live.len() * c_out * c_in];
    for (pi, &pos) in live.iter().enumerate() {
        let (i, j) = (pos / N, pos % N);
        for co in 0..c_out {
            for ci in 0..c_in {
                u[(pi * c_out + co) * c_in + ci] = bank[ci * c_out + co][i][j];
            }
        }
    }
    ReorderedFilter { case, live, c_in, c_out, u, d0y: ph.d0y, d0x: ph.d0x }
}

/// Transformed input tiles for one tile position, reordered: `v[pos][ci]`
/// over all 16 positions (the pre-PE computes all of V; the *gather* of
/// live rows happens when feeding the com-PEs).
#[derive(Clone, Debug)]
pub struct ReorderedTile {
    pub c_in: usize,
    /// `[16 * c_in]`, position-major
    pub v: Vec<f64>,
}

impl ReorderedTile {
    #[inline]
    pub fn at(&self, pos: usize, ci: usize) -> f64 {
        self.v[pos * self.c_in + ci]
    }
}

/// Extract + transform + reorder the 4x4 input tile at (tile_y, tile_x)
/// (stride m = 2) from a padded feature map. This is the pre-PE.
pub fn reorder_input_tile(x: &Tensor3, ty: usize, tx: usize) -> ReorderedTile {
    let mut v = vec![0.0; 16 * x.c];
    for ci in 0..x.c {
        let mut z: Tile4 = [[0.0; N]; N];
        for i in 0..N {
            for j in 0..N {
                z[i][j] = x.at(ci, M * ty + i, M * tx + j);
            }
        }
        let vt = input_transform(&z);
        for i in 0..N {
            for j in 0..N {
                v[(i * N + j) * x.c + ci] = vt[i][j];
            }
        }
    }
    ReorderedTile { c_in: x.c, v }
}

/// com-PE array: multiply-accumulate over live rows only.
/// Returns the Winograd-domain accumulator `m[co] -> Tile4` (zeros at
/// skipped positions) and the number of multiplications actually issued.
pub fn engine_multiply(rf: &ReorderedFilter, vt: &ReorderedTile) -> (Vec<Tile4>, usize) {
    assert_eq!(rf.c_in, vt.c_in);
    let mut m_acc = vec![[[0.0; N]; N]; rf.c_out];
    let mut mults = 0;
    for (pi, &pos) in rf.live.iter().enumerate() {
        let (i, j) = (pos / N, pos % N);
        // slice-based dot products: bounds checks hoisted, autovectorised
        let v_row = &vt.v[pos * rf.c_in..(pos + 1) * rf.c_in];
        for co in 0..rf.c_out {
            let u_row = &rf.u[(pi * rf.c_out + co) * rf.c_in..][..rf.c_in];
            let acc: f64 = u_row.iter().zip(v_row).map(|(u, v)| u * v).sum();
            m_acc[co][i][j] = acc;
            mults += rf.c_in;
        }
    }
    (m_acc, mults)
}

/// Stripe-batched com-PE array: one Winograd-domain GEMM per live position
/// instead of one GEMV per tile.
///
/// `v` is the gathered tile matrix for a whole stripe of `tiles` tiles,
/// position-major `[pos][c_in][tiles]` over all 16 positions (the layout
/// [`crate::engine::Scratch`] builds during the pre-PE gather); `m` is the
/// Winograd-domain accumulator `[c_out][pos][tiles]`, zeroed here so
/// skipped (structurally zero) positions stay zero for the inverse
/// transform. For each live position `p` this multiplies the `c_out x c_in`
/// filter block `U_p` against the `c_in x tiles` tile-column block `V_p` —
/// the filter slab is streamed **once per stripe** instead of once per
/// tile, and the inner loop is a contiguous AXPY over tiles that
/// autovectorizes.
///
/// Bitwise contract: each output element accumulates over `c_in` in the
/// same order as [`engine_multiply`] (a sequential fold from 0.0), so for
/// any tile `t`, `m[co][pos][t]` is **bit-identical** to
/// `engine_multiply(rf, tile_t).0[co][pos/4][pos%4]`. The engine's
/// stripe-batched datapath and the per-tile functional simulator stay
/// exactly equal through this property (pinned by the proptests).
///
/// Returns the number of multiplications issued:
/// `live.len() * c_out * c_in * tiles`, exactly `tiles` times what
/// [`engine_multiply`] reports per tile.
pub fn engine_multiply_batch(rf: &ReorderedFilter, v: &[f64], tiles: usize, m: &mut [f64]) -> usize {
    assert_eq!(v.len(), N * N * rf.c_in * tiles, "gathered tile matrix shape");
    assert_eq!(m.len(), rf.c_out * N * N * tiles, "winograd accumulator shape");
    m.fill(0.0);
    for (pi, &pos) in rf.live.iter().enumerate() {
        for co in 0..rf.c_out {
            let out = &mut m[(co * N * N + pos) * tiles..][..tiles];
            let u_base = (pi * rf.c_out + co) * rf.c_in;
            for ci in 0..rf.c_in {
                let u = rf.u[u_base + ci];
                let row = &v[(pos * rf.c_in + ci) * tiles..][..tiles];
                for (acc, &vv) in out.iter_mut().zip(row) {
                    *acc += u * vv;
                }
            }
        }
    }
    rf.live.len() * rf.c_out * rf.c_in * tiles
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tdc::{decompose, default_padding};
    use crate::util::prng::Rng;
    use crate::util::tensor::Filter4;
    use crate::winograd::transforms::inverse_transform;

    #[test]
    fn reordered_filter_shapes_and_cases() {
        let mut rng = Rng::new(400);
        let w = Filter4::from_vec(2, 3, 5, 5, rng.normal_vec(2 * 3 * 25));
        let phases = decompose(&w, 2, default_padding(5, 2));
        let rf: Vec<ReorderedFilter> = phases.iter().map(reorder_filter).collect();
        assert_eq!(rf[0].case, Case::Dense);
        assert_eq!(rf[0].live.len(), 16);
        assert_eq!(rf[1].case, Case::OneLine);
        assert_eq!(rf[3].case, Case::TwoLines);
        assert_eq!(rf[3].live.len(), 9);
        // C(K_C): sum of live positions across phases == 49
        let total: usize = rf.iter().map(|r| r.live.len()).sum();
        assert_eq!(total, 49);
    }

    // the stripe-batched kernel's bitwise equivalence to per-tile
    // `engine_multiply` is pinned by the randomized
    // `prop_batched_gemm_bitwise_equals_per_tile_multiply` property in
    // rust/tests/proptests.rs (48 cases over every kernel class, dirty
    // accumulator seeding) — no duplicate fixed-case test here.

    #[test]
    fn engine_multiply_equals_dense_math() {
        // sparse engine on one tile == dense winograd conv on that tile
        let mut rng = Rng::new(401);
        let w = Filter4::from_vec(3, 2, 4, 4, rng.normal_vec(3 * 2 * 16));
        let phases = decompose(&w, 2, default_padding(4, 2));
        let ph = &phases[0];
        let rf = reorder_filter(ph);
        let x = Tensor3::from_vec(3, 4, 4, rng.normal_vec(3 * 16));
        let vt = reorder_input_tile(&x, 0, 0);
        let (m_acc, mults) = engine_multiply(&rf, &vt);
        assert_eq!(mults, 9 * 2 * 3); // case 3: 9 live positions
        // dense reference: winograd_conv2d on the same tile
        let y_ref = crate::winograd::transforms::winograd_conv2d(&x, &ph.g);
        for co in 0..2 {
            let yt = inverse_transform(&m_acc[co]);
            for a in 0..2 {
                for b in 0..2 {
                    assert!((yt[a][b] - y_ref.at(co, a, b)).abs() < 1e-10);
                }
            }
        }
    }
}
