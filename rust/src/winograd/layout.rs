//! The paper's dataflow reorganisation (Fig. 5): flatten transformed 4x4
//! filters/tiles into an `n^2 x N` matrix layout so that structural zeros
//! become whole zero *rows* shared by every channel — vector-level sparsity
//! the com-PE array can skip without any per-element predication.
//!
//! This module owns the reordered representations used by both the
//! functional accelerator simulator (`accel::functional`) and the cycle
//! model (`accel::cycle`), plus the **register/cache-blocked GEMM
//! micro-kernel** the execution engine's stripe-batched datapath runs on.
//! All of it is generic over the scalar element ([`Elem`]): the f64
//! reference tier and the f32 serving fast path execute the identical
//! operation sequence.

use crate::tdc::PhaseFilter;
use crate::util::elem::Elem;
use crate::util::tensor::Tensor3;
use crate::winograd::kernel::RunList;
use crate::winograd::sparsity::{classify, nonzero_positions, Case};
use crate::winograd::transforms::{filter_bank_transform, input_transform, Tile4, M, N};

/// One TDC phase's filters in the Winograd domain, reordered with zero rows
/// removed: `u[p][co][ci]` for p over the *live* positions only.
///
/// A degenerate zero-tap phase (possible for exotic (K, S, P) combos where
/// a phase receives no real taps) is represented as an **explicitly empty
/// slab**: `case == Case::Empty`, `live.is_empty()`, `u.is_empty()`. The
/// engine and the functional simulator skip such phases outright.
#[derive(Clone, Debug)]
pub struct ReorderedFilter<E: Elem = f64> {
    pub case: Case,
    /// live position indices into the row-major 4x4 (len 16/12/9, or 0 for
    /// an empty slab)
    pub live: Vec<usize>,
    pub c_in: usize,
    pub c_out: usize,
    /// `[live.len() * c_out * c_in]`, position-major
    pub u: Vec<E>,
    /// runtime zero-skip run-list over `u` (see
    /// [`crate::winograd::kernel::RunList`]); `None` when fully dense
    pub skip: Option<RunList>,
    /// phase input offsets (from the TDC decomposition)
    pub d0y: isize,
    pub d0x: isize,
}

impl<E: Elem> ReorderedFilter<E> {
    #[inline]
    pub fn at(&self, p: usize, co: usize, ci: usize) -> E {
        self.u[(p * self.c_out + co) * self.c_in + ci]
    }

    /// Multiplications per (tile, c_in, c_out): the live position count.
    pub fn mults_per_tile(&self) -> usize {
        self.live.len()
    }

    /// The same reordered slab at another precision. Plan lowering uses
    /// this so the `G g Gᵀ` transform is always computed in f64 and only
    /// the finished Winograd-domain weights are quantized. The zero-skip
    /// run-list is **rebuilt** from the quantized weights (not copied):
    /// f32 quantization can flush tiny weights to zero and create runs the
    /// f64 slab did not have.
    pub fn cast_to<T: Elem>(&self) -> ReorderedFilter<T> {
        let u: Vec<T> = self.u.iter().map(|&v| T::from_f64(v.to_f64())).collect();
        let skip = RunList::build(self.live.len(), self.c_out, self.c_in, &u);
        ReorderedFilter {
            case: self.case,
            live: self.live.clone(),
            c_in: self.c_in,
            c_out: self.c_out,
            u,
            skip,
            d0y: self.d0y,
            d0x: self.d0x,
        }
    }
}

/// Build the reordered Winograd-domain filter for one TDC phase (f64; the
/// f32 tier is produced by [`ReorderedFilter::cast_to`] *after* the exact
/// transform).
pub fn reorder_filter(ph: &PhaseFilter) -> ReorderedFilter {
    let (c_in, c_out) = (ph.g.c_in, ph.g.c_out);
    if ph.ry == 0 || ph.rx == 0 {
        // degenerate zero-tap phase: the sub-filter is identically zero.
        // The old `.clamp(1, 3)` silently promoted it to a 1-tap filter and
        // produced a live slab of zeros; return an explicitly empty slab
        // instead so the engine skips the phase outright.
        return ReorderedFilter {
            case: Case::Empty,
            live: Vec::new(),
            c_in,
            c_out,
            u: Vec::new(),
            skip: None,
            d0y: ph.d0y,
            d0x: ph.d0x,
        };
    }
    let case = classify(ph.ry.min(3), ph.rx.min(3));
    let live = nonzero_positions(ph.ry.min(3), ph.rx.min(3));
    let bank = filter_bank_transform(&ph.g); // [ci*c_out] of Tile4
    let mut u = vec![0.0; live.len() * c_out * c_in];
    for (pi, &pos) in live.iter().enumerate() {
        let (i, j) = (pos / N, pos % N);
        for co in 0..c_out {
            for ci in 0..c_in {
                u[(pi * c_out + co) * c_in + ci] = bank[ci * c_out + co][i][j];
            }
        }
    }
    let skip = RunList::build(live.len(), c_out, c_in, &u);
    ReorderedFilter { case, live, c_in, c_out, u, skip, d0y: ph.d0y, d0x: ph.d0x }
}

/// Transformed input tiles for one tile position, reordered: `v[pos][ci]`
/// over all 16 positions (the pre-PE computes all of V; the *gather* of
/// live rows happens when feeding the com-PEs).
#[derive(Clone, Debug)]
pub struct ReorderedTile<E: Elem = f64> {
    pub c_in: usize,
    /// `[16 * c_in]`, position-major
    pub v: Vec<E>,
}

impl<E: Elem> ReorderedTile<E> {
    #[inline]
    pub fn at(&self, pos: usize, ci: usize) -> E {
        self.v[pos * self.c_in + ci]
    }
}

/// Extract + transform + reorder the 4x4 input tile at (tile_y, tile_x)
/// (stride m = 2) from a padded feature map. This is the pre-PE.
pub fn reorder_input_tile<E: Elem>(x: &Tensor3<E>, ty: usize, tx: usize) -> ReorderedTile<E> {
    let mut v = vec![E::ZERO; 16 * x.c];
    for ci in 0..x.c {
        let mut z: Tile4<E> = [[E::ZERO; N]; N];
        for i in 0..N {
            for j in 0..N {
                z[i][j] = x.at(ci, M * ty + i, M * tx + j);
            }
        }
        let vt = input_transform(&z);
        for i in 0..N {
            for j in 0..N {
                v[(i * N + j) * x.c + ci] = vt[i][j];
            }
        }
    }
    ReorderedTile { c_in: x.c, v }
}

/// com-PE array: multiply-accumulate over live rows only.
/// Returns the Winograd-domain accumulator `m[co] -> Tile4` (zeros at
/// skipped positions) and the number of multiplications actually issued.
pub fn engine_multiply<E: Elem>(
    rf: &ReorderedFilter<E>,
    vt: &ReorderedTile<E>,
) -> (Vec<Tile4<E>>, usize) {
    assert_eq!(rf.c_in, vt.c_in);
    let mut m_acc = vec![[[E::ZERO; N]; N]; rf.c_out];
    let mut mults = 0;
    for (pi, &pos) in rf.live.iter().enumerate() {
        let (i, j) = (pos / N, pos % N);
        // slice-based dot products: bounds checks hoisted, autovectorised
        let v_row = &vt.v[pos * rf.c_in..(pos + 1) * rf.c_in];
        for co in 0..rf.c_out {
            let u_row = &rf.u[(pi * rf.c_out + co) * rf.c_in..][..rf.c_in];
            let acc = u_row
                .iter()
                .zip(v_row)
                .fold(E::ZERO, |acc, (&u, &v)| acc + u * v);
            m_acc[co][i][j] = acc;
            mults += rf.c_in;
        }
    }
    (m_acc, mults)
}

/// Register-tile rows (`c_out` direction) of the blocked GEMM micro-kernel.
pub const GEMM_MR: usize = 4;
/// Register-tile columns (`tiles` direction) of the blocked micro-kernel:
/// `GEMM_MR x GEMM_NR` accumulators live in registers across the whole
/// `c_in` reduction of a cache block.
pub const GEMM_NR: usize = 8;
/// `c_in` cache-block depth: one block streams a `c_out x CI_BLOCK` slab
/// panel against a `CI_BLOCK x GEMM_NR` tile panel that stays resident.
pub const CI_BLOCK: usize = 128;

/// Stripe-batched com-PE array: one Winograd-domain GEMM per live position
/// instead of one GEMV per tile, executed by a **register/cache-blocked
/// micro-kernel**.
///
/// `v` is the gathered tile matrix for a whole stripe of `tiles` tiles,
/// position-major `[pos][c_in][tiles]` over all 16 positions (the layout
/// [`crate::engine::Scratch`] builds during the pre-PE gather); `m` is the
/// Winograd-domain accumulator `[c_out][pos][tiles]`, zeroed here so
/// skipped (structurally zero) positions stay zero for the inverse
/// transform. For each live position `p` this multiplies the `c_out x c_in`
/// filter block `U_p` against the `c_in x tiles` tile-column block `V_p`.
///
/// Blocking: the `c_in` reduction is split into cache blocks of
/// [`CI_BLOCK`] channels (the `CI_BLOCK x GEMM_NR` tile panel stays
/// cache-resident while the filter slab — the big stream, read once per
/// stripe — is consumed), and inside a block a `GEMM_MR x GEMM_NR` tile of
/// accumulators is held in registers for the whole reduction, so each
/// tile-panel row is loaded once per `GEMM_MR` output channels instead of
/// once per channel and the partial sums never round-trip memory inside a
/// block. Edge tiles (`c_out % GEMM_MR`, `tiles % GEMM_NR`,
/// `c_in % CI_BLOCK`) run the same code on short slices.
///
/// Bitwise contract: each output element accumulates over `c_in` in
/// ascending order from `E::ZERO` — cache blocks resume from the exact
/// stored partial, register tiling never reassociates the reduction — so
/// for any tile `t`, `m[co][pos][t]` is **bit-identical** to
/// `engine_multiply(rf, tile_t).0[co][pos/4][pos%4]` at either precision.
/// The engine's stripe-batched datapath, the per-tile functional simulator
/// and the pre-blocking PR-3 kernel all stay exactly equal through this
/// property (pinned by the proptests).
///
/// Returns the number of multiplications issued:
/// `live.len() * c_out * c_in * tiles`, exactly `tiles` times what
/// [`engine_multiply`] reports per tile.
pub fn engine_multiply_batch<E: Elem>(
    rf: &ReorderedFilter<E>,
    v: &[E],
    tiles: usize,
    m: &mut [E],
) -> usize {
    assert_eq!(v.len(), N * N * rf.c_in * tiles, "gathered tile matrix shape");
    assert_eq!(m.len(), rf.c_out * N * N * tiles, "winograd accumulator shape");
    let (c_in, c_out) = (rf.c_in, rf.c_out);
    m.fill(E::ZERO);
    for (pi, &pos) in rf.live.iter().enumerate() {
        let u_slab = &rf.u[pi * c_out * c_in..][..c_out * c_in];
        let v_panel = &v[pos * c_in * tiles..][..c_in * tiles];
        for ci0 in (0..c_in).step_by(CI_BLOCK) {
            let ci1 = (ci0 + CI_BLOCK).min(c_in);
            for co0 in (0..c_out).step_by(GEMM_MR) {
                let mr = GEMM_MR.min(c_out - co0);
                for t0 in (0..tiles).step_by(GEMM_NR) {
                    let nr = GEMM_NR.min(tiles - t0);
                    // load the register tile with the partial sums of the
                    // previous cache blocks (zeros for the first)
                    let mut acc = [[E::ZERO; GEMM_NR]; GEMM_MR];
                    for (mi, a) in acc.iter_mut().take(mr).enumerate() {
                        let row = &m[((co0 + mi) * N * N + pos) * tiles + t0..][..nr];
                        a[..nr].copy_from_slice(row);
                    }
                    for ci in ci0..ci1 {
                        let row = &v_panel[ci * tiles + t0..][..nr];
                        for (mi, a) in acc.iter_mut().take(mr).enumerate() {
                            let u = u_slab[(co0 + mi) * c_in + ci];
                            for (x, &vv) in a.iter_mut().zip(row) {
                                *x += u * vv;
                            }
                        }
                    }
                    for (mi, a) in acc.iter().take(mr).enumerate() {
                        let out = &mut m[((co0 + mi) * N * N + pos) * tiles + t0..][..nr];
                        out.copy_from_slice(&a[..nr]);
                    }
                }
            }
        }
    }
    rf.live.len() * c_out * c_in * tiles
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tdc::{decompose, default_padding};
    use crate::util::prng::Rng;
    use crate::util::tensor::Filter4;
    use crate::winograd::transforms::inverse_transform;

    #[test]
    fn reordered_filter_shapes_and_cases() {
        let mut rng = Rng::new(400);
        let w = Filter4::from_vec(2, 3, 5, 5, rng.normal_vec(2 * 3 * 25));
        let phases = decompose(&w, 2, default_padding(5, 2));
        let rf: Vec<ReorderedFilter> = phases.iter().map(reorder_filter).collect();
        assert_eq!(rf[0].case, Case::Dense);
        assert_eq!(rf[0].live.len(), 16);
        assert_eq!(rf[1].case, Case::OneLine);
        assert_eq!(rf[3].case, Case::TwoLines);
        assert_eq!(rf[3].live.len(), 9);
        // C(K_C): sum of live positions across phases == 49
        let total: usize = rf.iter().map(|r| r.live.len()).sum();
        assert_eq!(total, 49);
    }

    // the blocked kernel's bitwise equivalence to per-tile `engine_multiply`
    // is pinned by the randomized
    // `prop_batched_gemm_bitwise_equals_per_tile_multiply` property in
    // rust/tests/proptests.rs (48 cases over every kernel class, dirty
    // accumulator seeding, both precisions) — no duplicate fixed-case test
    // here. The geometry edge cases the register tiling must survive
    // (c_out % GEMM_MR, tiles % GEMM_NR, c_in % CI_BLOCK all non-zero) are
    // inside that generator's range.

    #[test]
    fn degenerate_phase_yields_empty_slab() {
        // K=1, S=2, P=0: only phase (0,0) receives a real tap; the other
        // three phases are zero-tap degenerate. Before the fix they were
        // silently promoted to 1-tap filters (live slabs of zeros).
        let mut rng = Rng::new(404);
        let w = Filter4::from_vec(3, 2, 1, 1, rng.normal_vec(3 * 2));
        let phases = decompose(&w, 2, default_padding(1, 2));
        assert_eq!(phases.len(), 4);
        let rf: Vec<ReorderedFilter> = phases.iter().map(reorder_filter).collect();
        assert_eq!(rf[0].case, Case::TwoLines, "phase (0,0) carries the 1x1 tap");
        assert_eq!(rf[0].live.len(), 9);
        for (i, r) in rf.iter().enumerate().skip(1) {
            assert_eq!(r.case, Case::Empty, "phase {i}");
            assert!(r.live.is_empty() && r.u.is_empty(), "phase {i}");
            assert_eq!(r.mults_per_tile(), 0);
            // empty slabs survive precision lowering unchanged
            let r32: ReorderedFilter<f32> = r.cast_to();
            assert!(r32.live.is_empty() && r32.u.is_empty());
        }
        // the engine-side contract: an empty slab issues zero work
        let x = Tensor3::from_vec(3, 4, 4, rng.normal_vec(3 * 16));
        let vt = reorder_input_tile(&x, 0, 0);
        let (m_acc, mults) = engine_multiply(&rf[1], &vt);
        assert_eq!(mults, 0);
        assert!(m_acc.iter().all(|t| t.iter().flatten().all(|&v| v == 0.0)));
    }

    #[test]
    fn engine_multiply_equals_dense_math() {
        // sparse engine on one tile == dense winograd conv on that tile
        let mut rng = Rng::new(401);
        let w = Filter4::from_vec(3, 2, 4, 4, rng.normal_vec(3 * 2 * 16));
        let phases = decompose(&w, 2, default_padding(4, 2));
        let ph = &phases[0];
        let rf = reorder_filter(ph);
        let x = Tensor3::from_vec(3, 4, 4, rng.normal_vec(3 * 16));
        let vt = reorder_input_tile(&x, 0, 0);
        let (m_acc, mults) = engine_multiply(&rf, &vt);
        assert_eq!(mults, 9 * 2 * 3); // case 3: 9 live positions
        // dense reference: winograd_conv2d on the same tile
        let y_ref = crate::winograd::transforms::winograd_conv2d(&x, &ph.g);
        for co in 0..2 {
            let yt = inverse_transform(&m_acc[co]);
            for a in 0..2 {
                for b in 0..2 {
                    assert!((yt[a][b] - y_ref.at(co, a, b)).abs() < 1e-10);
                }
            }
        }
    }

    #[test]
    fn blocked_kernel_spans_register_and_cache_edges() {
        // deterministic wide-geometry case exercising every blocking edge:
        // c_in crosses CI_BLOCK, c_out crosses GEMM_MR, tiles crosses
        // GEMM_NR — the blocked kernel must equal per-tile engine_multiply
        // bit for bit in both precisions
        let mut rng = Rng::new(402);
        let (c_in, c_out, tiles) = (CI_BLOCK + 3, GEMM_MR + 2, GEMM_NR + 5);
        let w = Filter4::from_vec(c_in, c_out, 4, 4, rng.normal_vec(c_in * c_out * 16));
        let phases = decompose(&w, 2, default_padding(4, 2));
        let rf64 = reorder_filter(&phases[0]);
        let rf32: ReorderedFilter<f32> = rf64.cast_to();
        let wpix = 2 * tiles + 2;
        let x64 = Tensor3::from_vec(c_in, 4, wpix, rng.normal_vec(c_in * 4 * wpix));
        let x32: Tensor3<f32> = x64.cast_to();

        fn check<E: Elem>(rf: &ReorderedFilter<E>, x: &Tensor3<E>, tiles: usize) {
            let c_in = x.c;
            let mut v = vec![E::ZERO; 16 * c_in * tiles];
            for tx in 0..tiles {
                let vt = reorder_input_tile(x, 0, tx);
                for pos in 0..16 {
                    for ci in 0..c_in {
                        v[(pos * c_in + ci) * tiles + tx] = vt.at(pos, ci);
                    }
                }
            }
            let mut m = vec![E::ZERO; rf.c_out * 16 * tiles];
            let mults = engine_multiply_batch(rf, &v, tiles, &mut m);
            assert_eq!(mults, rf.live.len() * rf.c_out * c_in * tiles);
            for tx in 0..tiles {
                let vt = reorder_input_tile(x, 0, tx);
                let (m_acc, _) = engine_multiply(rf, &vt);
                for co in 0..rf.c_out {
                    for pos in 0..16 {
                        assert!(
                            m[(co * 16 + pos) * tiles + tx] == m_acc[co][pos / 4][pos % 4],
                            "tile {tx} pos {pos} co {co}"
                        );
                    }
                }
            }
        }
        check(&rf64, &x64, tiles);
        check(&rf32, &x32, tiles);
    }

    #[test]
    fn cast_to_preserves_structure_and_rounds_weights() {
        let mut rng = Rng::new(403);
        let w = Filter4::from_vec(2, 2, 5, 5, rng.normal_vec(2 * 2 * 25));
        let phases = decompose(&w, 2, default_padding(5, 2));
        let rf = reorder_filter(&phases[0]);
        let rf32: ReorderedFilter<f32> = rf.cast_to();
        assert_eq!(rf32.case, rf.case);
        assert_eq!(rf32.live, rf.live);
        assert_eq!((rf32.c_in, rf32.c_out), (rf.c_in, rf.c_out));
        for (a, b) in rf32.u.iter().zip(&rf.u) {
            assert_eq!(*a, *b as f32, "quantized after the f64 transform");
        }
    }
}
