//! Arch-dispatched SIMD micro-kernels for the Winograd-domain GEMM, with
//! runtime zero-skip (PR 6).
//!
//! The blocked scalar kernel ([`engine_multiply_batch`]) leans on
//! autovectorization and multiplies through every `c_in` lane. This module
//! makes both decisions explicit:
//!
//! * **Kernel dispatch** ([`KernelKind`]): an AVX2 path (x86_64) and a NEON
//!   path (aarch64) via `std::arch`, with the blocked scalar loop as the
//!   portable fallback. The choice is feature-detected once at plan-compile
//!   (or artifact-load) time and recorded on
//!   [`crate::engine::TileGeometry::kernel`], so the dispatch decision is
//!   part of the compiled plan — visible in `wingan plan inspect` — rather
//!   than re-probed per call.
//! * **Runtime zero-skip** ([`RunList`]): the reorder step already removes
//!   the *structurally* zero rows (paper Fig. 5/6); a lowered f32 slab or a
//!   pruned model can additionally carry all-zero runs along `c_in` inside
//!   a live row. [`RunList::build`] scans each reordered slab once per
//!   (position, `c_out` register block) and [`multiply_batch`] iterates
//!   only the live runs.
//!
//! # Bitwise contract
//!
//! [`multiply_batch`] preserves [`engine_multiply_batch`]'s accumulation
//! contract exactly: every output element accumulates over `c_in` in
//! ascending order from `E::ZERO`, one `acc + u * v` rounding per step.
//! The SIMD paths vectorize along the `tiles` dimension (each vector lane
//! is a different output element) and use separate multiply and add
//! instructions — **no FMA** — so each lane executes the identical IEEE
//! operation sequence as the scalar loop. Consequently
//! `multiply_batch(Scalar, ..)` and `multiply_batch(Simd, ..)` are
//! **bit-identical to each other and to [`engine_multiply_batch`]** at both
//! precisions (pinned by the proptests).
//!
//! Zero-skip keeps the same ascending order over the *surviving* channels.
//! Skipping a channel whose weights are exactly `±0.0` removes terms of
//! the form `acc + (±0.0 * v)`, which can only flip the sign of an exactly
//! zero partial sum (`-0.0 + 0.0 == +0.0`) — the skip path is therefore
//! value-equal (`==`) to the dense path everywhere, and bit-equal whenever
//! no partial sum is a negative zero.
//!
//! [`engine_multiply_batch`]: crate::winograd::layout::engine_multiply_batch

use crate::util::elem::Elem;
use crate::winograd::layout::{ReorderedFilter, CI_BLOCK, GEMM_MR, GEMM_NR};
use crate::winograd::transforms::N;
use std::any::TypeId;

/// Which micro-kernel family a compiled plan's Winograd GEMM runs on.
///
/// Recorded on [`crate::engine::TileGeometry`] at plan-compile /
/// artifact-load time ([`crate::engine::Planner::resolve_kernel`]); the
/// default is the portable blocked scalar kernel.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum KernelKind {
    /// The register/cache-blocked scalar loop (autovectorized; portable).
    #[default]
    Scalar,
    /// Explicit `std::arch` SIMD: AVX2 on x86_64, NEON on aarch64. Falls
    /// back to the scalar loop per edge block (ragged `tiles % GEMM_NR`)
    /// and wholesale on hosts without the instruction set.
    Simd,
}

impl KernelKind {
    /// Parse a kernel name (`"scalar"` / `"simd"`, case-insensitive) — the
    /// value space of the CLI `--kernel` flag and the `WINGAN_KERNEL`
    /// environment variable.
    pub fn parse(s: &str) -> Result<KernelKind, String> {
        match s.trim().to_ascii_lowercase().as_str() {
            "scalar" => Ok(KernelKind::Scalar),
            "simd" => Ok(KernelKind::Simd),
            other => Err(format!("unknown kernel '{other}' (expected scalar or simd)")),
        }
    }

    /// Stable lowercase label (artifact `describe` output, serve boot log).
    pub fn label(self) -> &'static str {
        match self {
            KernelKind::Scalar => "scalar",
            KernelKind::Simd => "simd",
        }
    }
}

#[cfg(target_arch = "x86_64")]
fn simd_available_impl() -> bool {
    std::arch::is_x86_feature_detected!("avx2")
}

#[cfg(target_arch = "aarch64")]
fn simd_available_impl() -> bool {
    true // NEON is baseline on aarch64
}

#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
fn simd_available_impl() -> bool {
    false
}

/// Whether this host can run the [`KernelKind::Simd`] paths: AVX2 on
/// x86_64 (runtime-detected), always on aarch64 (NEON is baseline), never
/// elsewhere. Requesting `Simd` where this is `false` resolves to `Scalar`
/// (see [`crate::engine::Planner::resolve_kernel`]) — including for
/// artifacts compiled on a different host.
pub fn simd_available() -> bool {
    simd_available_impl()
}

/// Compact per-slab run-list of the *live* `c_in` ranges, one list per
/// (live position, `c_out` register block of [`GEMM_MR`] rows): the
/// within-slab runtime sparsity that [`multiply_batch`] skips.
///
/// Block `b = pi * n_blocks_per_pos + cb` (position-major, `cb` the
/// `c_out / GEMM_MR` block index) owns `runs[offsets[b]..offsets[b + 1]]`;
/// each run `(s, e)` is a half-open `c_in` range in which at least one of
/// the block's `GEMM_MR` rows has a non-zero Winograd-domain weight.
/// Channels outside every run contribute only exact-zero products for the
/// whole register block and are skipped.
///
/// A `RunList` is **derived data** — a pure function of the slab weights.
/// It is built by [`crate::winograd::layout::reorder_filter`], rebuilt
/// after precision lowering ([`ReorderedFilter::cast_to`]; f32 quantization
/// can only create new zeros), and verified against a rebuild when decoded
/// from a plan artifact.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RunList {
    /// `n_blocks + 1` cumulative run counts; block `b` owns
    /// `runs[offsets[b]..offsets[b+1]]`.
    pub offsets: Vec<u32>,
    /// half-open `(start, end)` live `c_in` ranges, ascending and
    /// non-overlapping within a block
    pub runs: Vec<(u32, u32)>,
}

impl RunList {
    /// `c_out` register blocks per live position.
    pub fn blocks_per_pos(c_out: usize) -> usize {
        c_out.div_ceil(GEMM_MR)
    }

    /// Scan a position-major slab `u[(pi * c_out + co) * c_in + ci]` for
    /// all-zero `c_in` runs per (position, register block). Returns `None`
    /// when every block is fully live (the common dense case — seeded
    /// random weights have no exact zeros), so dense slabs pay nothing.
    pub fn build<E: Elem>(n_live: usize, c_out: usize, c_in: usize, u: &[E]) -> Option<RunList> {
        debug_assert_eq!(u.len(), n_live * c_out * c_in);
        let n_cb = RunList::blocks_per_pos(c_out);
        let mut offsets = Vec::with_capacity(n_live * n_cb + 1);
        offsets.push(0u32);
        let mut runs: Vec<(u32, u32)> = Vec::new();
        let mut any_dead = false;
        for pi in 0..n_live {
            for cb in 0..n_cb {
                let co0 = cb * GEMM_MR;
                let mr = GEMM_MR.min(c_out - co0);
                let mut run_start: Option<u32> = None;
                for ci in 0..c_in {
                    let live = (0..mr)
                        .any(|mi| u[(pi * c_out + co0 + mi) * c_in + ci] != E::ZERO);
                    if live {
                        if run_start.is_none() {
                            run_start = Some(ci as u32);
                        }
                    } else {
                        any_dead = true;
                        if let Some(s) = run_start.take() {
                            runs.push((s, ci as u32));
                        }
                    }
                }
                if let Some(s) = run_start.take() {
                    runs.push((s, c_in as u32));
                }
                offsets.push(runs.len() as u32);
            }
        }
        if any_dead {
            Some(RunList { offsets, runs })
        } else {
            None
        }
    }

    /// Number of (position, register-block) entries.
    pub fn n_blocks(&self) -> usize {
        self.offsets.len() - 1
    }

    /// The live runs of block `b`.
    pub fn runs_for(&self, b: usize) -> &[(u32, u32)] {
        &self.runs[self.offsets[b] as usize..self.offsets[b + 1] as usize]
    }

    /// Live channels covered by block `b` (sum of run lengths).
    pub fn covered(&self, b: usize) -> usize {
        self.runs_for(b).iter().map(|&(s, e)| (e - s) as usize).sum()
    }

    /// Total skipped (channel, row) products per tile across the whole
    /// slab — the observability number `describe` and the benches report.
    pub fn skipped_products(&self, c_out: usize, c_in: usize) -> usize {
        let n_cb = RunList::blocks_per_pos(c_out);
        let mut skipped = 0;
        for b in 0..self.n_blocks() {
            let co0 = (b % n_cb) * GEMM_MR;
            let mr = GEMM_MR.min(c_out - co0);
            skipped += (c_in - self.covered(b)) * mr;
        }
        skipped
    }

    /// Structural sanity for decoded run-lists: offsets are monotone and
    /// sized `n_blocks + 1`, runs ascending / non-overlapping / non-empty
    /// and inside `[0, c_in)`.
    pub fn is_well_formed(&self, n_live: usize, c_out: usize, c_in: usize) -> bool {
        let n_blocks = n_live * RunList::blocks_per_pos(c_out);
        if self.offsets.len() != n_blocks + 1 || self.offsets[0] != 0 {
            return false;
        }
        if self.offsets.windows(2).any(|w| w[0] > w[1]) {
            return false;
        }
        if *self.offsets.last().unwrap() as usize != self.runs.len() {
            return false;
        }
        for b in 0..n_blocks {
            let mut prev_end = 0u32;
            for &(s, e) in self.runs_for(b) {
                if s >= e || e > c_in as u32 || s < prev_end {
                    return false;
                }
                prev_end = e;
            }
        }
        true
    }
}

/// The arch-dispatched, sparsity-aware Winograd-domain GEMM: the blocked
/// loop of [`engine_multiply_batch`] with (a) the inner register-tile
/// update routed to the `kind` micro-kernel and (b) the `c_in` reduction
/// iterating only the live runs of `rf.skip` (when present).
///
/// Layouts and blocking are identical to [`engine_multiply_batch`]:
/// `v` is the gathered tile matrix `[pos][c_in][tiles]`, `m` the
/// Winograd-domain accumulator `[c_out][pos][tiles]`, zeroed here.
///
/// Returns the number of multiplications actually issued:
/// `live.len() * c_out * c_in * tiles` for a dense slab (exactly what
/// [`engine_multiply_batch`] reports), minus `tiles *`
/// [`RunList::skipped_products`] when zero runs are skipped.
///
/// See the module docs for the bitwise contract (SIMD == scalar at both
/// precisions; zero-skip value-equal to dense).
///
/// [`engine_multiply_batch`]: crate::winograd::layout::engine_multiply_batch
pub fn multiply_batch<E: Elem>(
    kind: KernelKind,
    rf: &ReorderedFilter<E>,
    v: &[E],
    tiles: usize,
    m: &mut [E],
) -> usize {
    assert_eq!(v.len(), N * N * rf.c_in * tiles, "gathered tile matrix shape");
    assert_eq!(m.len(), rf.c_out * N * N * tiles, "winograd accumulator shape");
    let (c_in, c_out) = (rf.c_in, rf.c_out);
    let simd = kind == KernelKind::Simd;
    let n_cb = RunList::blocks_per_pos(c_out);
    let dense_run = [(0u32, c_in as u32)];
    m.fill(E::ZERO);
    for (pi, &pos) in rf.live.iter().enumerate() {
        let u_slab = &rf.u[pi * c_out * c_in..][..c_out * c_in];
        let v_panel = &v[pos * c_in * tiles..][..c_in * tiles];
        for ci0 in (0..c_in).step_by(CI_BLOCK) {
            let ci1 = (ci0 + CI_BLOCK).min(c_in);
            for co0 in (0..c_out).step_by(GEMM_MR) {
                let mr = GEMM_MR.min(c_out - co0);
                let runs: &[(u32, u32)] = match &rf.skip {
                    Some(sk) => sk.runs_for(pi * n_cb + co0 / GEMM_MR),
                    None => &dense_run,
                };
                for &(rs, re) in runs {
                    // clip the run to this cache block; runs are ascending,
                    // so per output element the `c_in` order stays ascending
                    let (s, e) = ((rs as usize).max(ci0), (re as usize).min(ci1));
                    if s >= e {
                        continue;
                    }
                    for t0 in (0..tiles).step_by(GEMM_NR) {
                        let nr = GEMM_NR.min(tiles - t0);
                        // load the register tile with the partial sums of
                        // the previous cache blocks / runs
                        let mut acc = [[E::ZERO; GEMM_NR]; GEMM_MR];
                        for (mi, a) in acc.iter_mut().take(mr).enumerate() {
                            let row = &m[((co0 + mi) * N * N + pos) * tiles + t0..][..nr];
                            a[..nr].copy_from_slice(row);
                        }
                        accumulate_run(
                            &mut acc, mr, nr, u_slab, co0, c_in, v_panel, tiles, t0, s, e, simd,
                        );
                        for (mi, a) in acc.iter().take(mr).enumerate() {
                            let out = &mut m[((co0 + mi) * N * N + pos) * tiles + t0..][..nr];
                            out.copy_from_slice(&a[..nr]);
                        }
                    }
                }
            }
        }
    }
    issued_mults(rf, tiles)
}

/// Multiplications [`multiply_batch`] issues for this slab at stripe width
/// `tiles` — the dense count minus the zero-skipped products.
pub fn issued_mults<E: Elem>(rf: &ReorderedFilter<E>, tiles: usize) -> usize {
    let dense = rf.live.len() * rf.c_out * rf.c_in * tiles;
    match &rf.skip {
        Some(sk) => dense - sk.skipped_products(rf.c_out, rf.c_in) * tiles,
        None => dense,
    }
}

/// Accumulate `acc[mi][x] += u[co0+mi][ci] * v[ci][t0+x]` for
/// `ci in ci_s..ci_e`, ascending — the register-tile inner loop. Dispatches
/// to the arch SIMD path on full-width (`nr == GEMM_NR`) blocks when
/// requested and available; otherwise runs the scalar sequence (which the
/// SIMD paths replicate lane for lane).
#[allow(clippy::too_many_arguments)]
#[inline]
fn accumulate_run<E: Elem>(
    acc: &mut [[E; GEMM_NR]; GEMM_MR],
    mr: usize,
    nr: usize,
    u_slab: &[E],
    co0: usize,
    c_in: usize,
    v_panel: &[E],
    tiles: usize,
    t0: usize,
    ci_s: usize,
    ci_e: usize,
    simd: bool,
) {
    if simd && nr == GEMM_NR && simd_run(acc, mr, u_slab, co0, c_in, v_panel, tiles, t0, ci_s, ci_e)
    {
        return;
    }
    for ci in ci_s..ci_e {
        let row = &v_panel[ci * tiles + t0..][..nr];
        for (mi, a) in acc.iter_mut().take(mr).enumerate() {
            let u = u_slab[(co0 + mi) * c_in + ci];
            for (x, &vv) in a.iter_mut().zip(row) {
                *x += u * vv;
            }
        }
    }
}

/// Reinterpret a slice of `E` as `T`. Sound only when `E` and `T` are the
/// same type (checked by `TypeId`); used to reach the monomorphic
/// `f32`/`f64` SIMD kernels from the generic driver.
#[inline]
fn cast_slice<E: 'static, T: 'static>(s: &[E]) -> &[T] {
    debug_assert_eq!(TypeId::of::<E>(), TypeId::of::<T>());
    // SAFETY: E == T (TypeId equality above), so layout and validity match.
    unsafe { std::slice::from_raw_parts(s.as_ptr().cast::<T>(), s.len()) }
}

/// [`cast_slice`] for the register-tile accumulator array.
#[inline]
fn cast_acc<E: 'static, T: 'static>(
    a: &mut [[E; GEMM_NR]; GEMM_MR],
) -> &mut [[T; GEMM_NR]; GEMM_MR] {
    debug_assert_eq!(TypeId::of::<E>(), TypeId::of::<T>());
    // SAFETY: E == T (TypeId equality above), so layout and validity match.
    unsafe { &mut *(a as *mut [[E; GEMM_NR]; GEMM_MR]).cast::<[[T; GEMM_NR]; GEMM_MR]>() }
}

/// Try the arch SIMD path for one full-width register-tile update. Returns
/// `false` (caller runs the scalar loop) off x86_64/aarch64, when AVX2 is
/// absent, or for element types without a vector kernel.
#[allow(clippy::too_many_arguments)]
#[inline]
fn simd_run<E: Elem>(
    acc: &mut [[E; GEMM_NR]; GEMM_MR],
    mr: usize,
    u_slab: &[E],
    co0: usize,
    c_in: usize,
    v_panel: &[E],
    tiles: usize,
    t0: usize,
    ci_s: usize,
    ci_e: usize,
) -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        if !std::arch::is_x86_feature_detected!("avx2") {
            return false;
        }
        if TypeId::of::<E>() == TypeId::of::<f64>() {
            // SAFETY: AVX2 detected above; E is f64; the caller guarantees
            // a full-width block (t0 + GEMM_NR <= tiles) and in-bounds
            // (co0 + mr, ci_e) indices.
            unsafe {
                avx2::run_f64(
                    cast_acc::<E, f64>(acc),
                    mr,
                    cast_slice::<E, f64>(u_slab),
                    co0,
                    c_in,
                    cast_slice::<E, f64>(v_panel),
                    tiles,
                    t0,
                    ci_s,
                    ci_e,
                );
            }
            return true;
        }
        if TypeId::of::<E>() == TypeId::of::<f32>() {
            // SAFETY: as above, with E == f32.
            unsafe {
                avx2::run_f32(
                    cast_acc::<E, f32>(acc),
                    mr,
                    cast_slice::<E, f32>(u_slab),
                    co0,
                    c_in,
                    cast_slice::<E, f32>(v_panel),
                    tiles,
                    t0,
                    ci_s,
                    ci_e,
                );
            }
            return true;
        }
        false
    }
    #[cfg(target_arch = "aarch64")]
    {
        if TypeId::of::<E>() == TypeId::of::<f64>() {
            // SAFETY: NEON is baseline on aarch64; E is f64; the caller
            // guarantees a full-width block and in-bounds indices.
            unsafe {
                neon::run_f64(
                    cast_acc::<E, f64>(acc),
                    mr,
                    cast_slice::<E, f64>(u_slab),
                    co0,
                    c_in,
                    cast_slice::<E, f64>(v_panel),
                    tiles,
                    t0,
                    ci_s,
                    ci_e,
                );
            }
            return true;
        }
        if TypeId::of::<E>() == TypeId::of::<f32>() {
            // SAFETY: as above, with E == f32.
            unsafe {
                neon::run_f32(
                    cast_acc::<E, f32>(acc),
                    mr,
                    cast_slice::<E, f32>(u_slab),
                    co0,
                    c_in,
                    cast_slice::<E, f32>(v_panel),
                    tiles,
                    t0,
                    ci_s,
                    ci_e,
                );
            }
            return true;
        }
        false
    }
    #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
    {
        let _ = (acc, mr, u_slab, co0, c_in, v_panel, tiles, t0, ci_s, ci_e);
        false
    }
}

/// AVX2 register-tile kernels: 8 output tiles per vector step (`GEMM_NR`
/// lanes along the contiguous `tiles` dimension), broadcast weight,
/// separate `vmulp*` + `vaddp*` so every lane matches the scalar rounding
/// sequence exactly (no FMA — see the module docs).
#[cfg(target_arch = "x86_64")]
mod avx2 {
    use super::{GEMM_MR, GEMM_NR};
    use std::arch::x86_64::*;

    /// One full-width f64 register-tile update (`GEMM_MR x GEMM_NR` = two
    /// `__m256d` per row).
    ///
    /// # Safety
    /// AVX2 must be available; `t0 + GEMM_NR <= tiles`,
    /// `ci_e * tiles <= v_panel.len()`, `(co0 + mr) * c_in <= u_slab.len()`.
    #[target_feature(enable = "avx2")]
    #[allow(clippy::too_many_arguments, clippy::needless_range_loop)]
    pub unsafe fn run_f64(
        acc: &mut [[f64; GEMM_NR]; GEMM_MR],
        mr: usize,
        u_slab: &[f64],
        co0: usize,
        c_in: usize,
        v_panel: &[f64],
        tiles: usize,
        t0: usize,
        ci_s: usize,
        ci_e: usize,
    ) {
        let mut r = [[_mm256_setzero_pd(); 2]; GEMM_MR];
        for mi in 0..mr {
            r[mi][0] = _mm256_loadu_pd(acc[mi].as_ptr());
            r[mi][1] = _mm256_loadu_pd(acc[mi].as_ptr().add(4));
        }
        for ci in ci_s..ci_e {
            let vp = v_panel.as_ptr().add(ci * tiles + t0);
            let v0 = _mm256_loadu_pd(vp);
            let v1 = _mm256_loadu_pd(vp.add(4));
            for mi in 0..mr {
                let u = _mm256_set1_pd(*u_slab.get_unchecked((co0 + mi) * c_in + ci));
                r[mi][0] = _mm256_add_pd(r[mi][0], _mm256_mul_pd(u, v0));
                r[mi][1] = _mm256_add_pd(r[mi][1], _mm256_mul_pd(u, v1));
            }
        }
        for mi in 0..mr {
            _mm256_storeu_pd(acc[mi].as_mut_ptr(), r[mi][0]);
            _mm256_storeu_pd(acc[mi].as_mut_ptr().add(4), r[mi][1]);
        }
    }

    /// One full-width f32 register-tile update (one `__m256` per row).
    ///
    /// # Safety
    /// Same preconditions as [`run_f64`].
    #[target_feature(enable = "avx2")]
    #[allow(clippy::too_many_arguments, clippy::needless_range_loop)]
    pub unsafe fn run_f32(
        acc: &mut [[f32; GEMM_NR]; GEMM_MR],
        mr: usize,
        u_slab: &[f32],
        co0: usize,
        c_in: usize,
        v_panel: &[f32],
        tiles: usize,
        t0: usize,
        ci_s: usize,
        ci_e: usize,
    ) {
        let mut r = [_mm256_setzero_ps(); GEMM_MR];
        for mi in 0..mr {
            r[mi] = _mm256_loadu_ps(acc[mi].as_ptr());
        }
        for ci in ci_s..ci_e {
            let v0 = _mm256_loadu_ps(v_panel.as_ptr().add(ci * tiles + t0));
            for mi in 0..mr {
                let u = _mm256_set1_ps(*u_slab.get_unchecked((co0 + mi) * c_in + ci));
                r[mi] = _mm256_add_ps(r[mi], _mm256_mul_ps(u, v0));
            }
        }
        for mi in 0..mr {
            _mm256_storeu_ps(acc[mi].as_mut_ptr(), r[mi]);
        }
    }
}

/// NEON register-tile kernels (aarch64): same lane discipline as the AVX2
/// pair — broadcast weight, separate `fmul` + `fadd`, no FMA.
#[cfg(target_arch = "aarch64")]
mod neon {
    use super::{GEMM_MR, GEMM_NR};
    use std::arch::aarch64::*;

    /// One full-width f64 register-tile update (four `float64x2_t` per row).
    ///
    /// # Safety
    /// `t0 + GEMM_NR <= tiles`, `ci_e * tiles <= v_panel.len()`,
    /// `(co0 + mr) * c_in <= u_slab.len()`.
    #[target_feature(enable = "neon")]
    #[allow(clippy::too_many_arguments, clippy::needless_range_loop)]
    pub unsafe fn run_f64(
        acc: &mut [[f64; GEMM_NR]; GEMM_MR],
        mr: usize,
        u_slab: &[f64],
        co0: usize,
        c_in: usize,
        v_panel: &[f64],
        tiles: usize,
        t0: usize,
        ci_s: usize,
        ci_e: usize,
    ) {
        let mut r = [[vdupq_n_f64(0.0); 4]; GEMM_MR];
        for mi in 0..mr {
            for q in 0..4 {
                r[mi][q] = vld1q_f64(acc[mi].as_ptr().add(2 * q));
            }
        }
        for ci in ci_s..ci_e {
            let vp = v_panel.as_ptr().add(ci * tiles + t0);
            let v = [vld1q_f64(vp), vld1q_f64(vp.add(2)), vld1q_f64(vp.add(4)), vld1q_f64(vp.add(6))];
            for mi in 0..mr {
                let u = vdupq_n_f64(*u_slab.get_unchecked((co0 + mi) * c_in + ci));
                for q in 0..4 {
                    r[mi][q] = vaddq_f64(r[mi][q], vmulq_f64(u, v[q]));
                }
            }
        }
        for mi in 0..mr {
            for q in 0..4 {
                vst1q_f64(acc[mi].as_mut_ptr().add(2 * q), r[mi][q]);
            }
        }
    }

    /// One full-width f32 register-tile update (two `float32x4_t` per row).
    ///
    /// # Safety
    /// Same preconditions as [`run_f64`].
    #[target_feature(enable = "neon")]
    #[allow(clippy::too_many_arguments, clippy::needless_range_loop)]
    pub unsafe fn run_f32(
        acc: &mut [[f32; GEMM_NR]; GEMM_MR],
        mr: usize,
        u_slab: &[f32],
        co0: usize,
        c_in: usize,
        v_panel: &[f32],
        tiles: usize,
        t0: usize,
        ci_s: usize,
        ci_e: usize,
    ) {
        let mut r = [[vdupq_n_f32(0.0); 2]; GEMM_MR];
        for mi in 0..mr {
            r[mi][0] = vld1q_f32(acc[mi].as_ptr());
            r[mi][1] = vld1q_f32(acc[mi].as_ptr().add(4));
        }
        for ci in ci_s..ci_e {
            let vp = v_panel.as_ptr().add(ci * tiles + t0);
            let v0 = vld1q_f32(vp);
            let v1 = vld1q_f32(vp.add(4));
            for mi in 0..mr {
                let u = vdupq_n_f32(*u_slab.get_unchecked((co0 + mi) * c_in + ci));
                r[mi][0] = vaddq_f32(r[mi][0], vmulq_f32(u, v0));
                r[mi][1] = vaddq_f32(r[mi][1], vmulq_f32(u, v1));
            }
        }
        for mi in 0..mr {
            vst1q_f32(acc[mi].as_mut_ptr(), r[mi][0]);
            vst1q_f32(acc[mi].as_mut_ptr().add(4), r[mi][1]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tdc::{decompose, default_padding};
    use crate::util::prng::Rng;
    use crate::util::tensor::{Filter4, Tensor3};
    use crate::winograd::layout::{
        engine_multiply_batch, reorder_filter, reorder_input_tile,
    };

    #[test]
    fn kernel_kind_parses_and_labels() {
        assert_eq!(KernelKind::parse("scalar").unwrap(), KernelKind::Scalar);
        assert_eq!(KernelKind::parse(" SIMD ").unwrap(), KernelKind::Simd);
        assert!(KernelKind::parse("avx512").is_err());
        assert_eq!(KernelKind::Scalar.label(), "scalar");
        assert_eq!(KernelKind::Simd.label(), "simd");
        assert_eq!(KernelKind::default(), KernelKind::Scalar);
    }

    #[test]
    fn run_list_of_a_dense_slab_is_none() {
        let mut rng = Rng::new(600);
        let w = Filter4::from_vec(5, 3, 4, 4, rng.normal_vec(5 * 3 * 16));
        let rf = reorder_filter(&decompose(&w, 2, default_padding(4, 2))[0]);
        assert!(rf.skip.is_none(), "random normal weights have no exact zeros");
        assert_eq!(issued_mults(&rf, 7), rf.live.len() * 3 * 5 * 7);
    }

    #[test]
    fn run_list_finds_injected_zero_runs() {
        // 1 live position, c_out = 2 (one register block), c_in = 10 with
        // channels 3..6 zeroed across all rows of the block
        let c_in = 10;
        let mut u = vec![1.0f64; 2 * c_in];
        for ci in 3..6 {
            u[ci] = 0.0;
            u[c_in + ci] = 0.0;
        }
        let sk = RunList::build(1, 2, c_in, &u).expect("zeros present");
        assert_eq!(sk.n_blocks(), 1);
        assert_eq!(sk.runs_for(0), &[(0, 3), (6, 10)]);
        assert_eq!(sk.covered(0), 7);
        assert_eq!(sk.skipped_products(2, c_in), 3 * 2);
        assert!(sk.is_well_formed(1, 2, c_in));
        // a channel dead in only one row of the block stays live
        let mut u2 = vec![1.0f64; 2 * c_in];
        u2[4] = 0.0;
        assert!(RunList::build(1, 2, c_in, &u2).is_none());
    }

    #[test]
    fn well_formedness_rejects_malformed_lists() {
        let ok = RunList { offsets: vec![0, 1], runs: vec![(2, 5)] };
        assert!(ok.is_well_formed(1, 4, 8));
        let bad_order = RunList { offsets: vec![0, 2], runs: vec![(4, 6), (1, 3)] };
        assert!(!bad_order.is_well_formed(1, 4, 8));
        let bad_bounds = RunList { offsets: vec![0, 1], runs: vec![(2, 9)] };
        assert!(!bad_bounds.is_well_formed(1, 4, 8));
        let empty_run = RunList { offsets: vec![0, 1], runs: vec![(3, 3)] };
        assert!(!empty_run.is_well_formed(1, 4, 8));
        let bad_offsets = RunList { offsets: vec![0, 1], runs: vec![(0, 8)] };
        assert!(!bad_offsets.is_well_formed(2, 4, 8));
    }

    /// Gather a one-stripe `[pos][ci][tiles]` matrix like the engine's
    /// pre-PE does.
    fn gather(x: &Tensor3, tiles: usize) -> Vec<f64> {
        let c_in = x.c;
        let mut v = vec![0.0; 16 * c_in * tiles];
        for tx in 0..tiles {
            let vt = reorder_input_tile(x, 0, tx);
            for pos in 0..16 {
                for ci in 0..c_in {
                    v[(pos * c_in + ci) * tiles + tx] = vt.at(pos, ci);
                }
            }
        }
        v
    }

    #[test]
    fn simd_and_scalar_kernels_match_the_blocked_reference_bitwise() {
        // geometry that crosses every blocking edge (cache block, register
        // rows, ragged tiles) — both kernel kinds must equal the dense
        // blocked reference bit for bit, in f64 and f32
        let mut rng = Rng::new(601);
        let (c_in, c_out, tiles) = (CI_BLOCK + 5, GEMM_MR + 3, 2 * GEMM_NR + 3);
        let w = Filter4::from_vec(c_in, c_out, 4, 4, rng.normal_vec(c_in * c_out * 16));
        let rf64 = reorder_filter(&decompose(&w, 2, default_padding(4, 2))[0]);
        let rf32: ReorderedFilter<f32> = rf64.cast_to();
        let wpix = 2 * tiles + 2;
        let x64 = Tensor3::from_vec(c_in, 4, wpix, rng.normal_vec(c_in * 4 * wpix));
        let v64 = gather(&x64, tiles);
        let v32: Vec<f32> = v64.iter().map(|&v| v as f32).collect();

        let mut want64 = vec![0.0f64; c_out * 16 * tiles];
        let dense = engine_multiply_batch(&rf64, &v64, tiles, &mut want64);
        for kind in [KernelKind::Scalar, KernelKind::Simd] {
            let mut got = vec![1.0f64; c_out * 16 * tiles]; // dirty
            let mults = multiply_batch(kind, &rf64, &v64, tiles, &mut got);
            assert_eq!(mults, dense, "{kind:?} f64 mult count");
            assert!(got == want64, "{kind:?} f64 must be bitwise dense-identical");
        }

        let mut want32 = vec![0.0f32; c_out * 16 * tiles];
        engine_multiply_batch(&rf32, &v32, tiles, &mut want32);
        for kind in [KernelKind::Scalar, KernelKind::Simd] {
            let mut got = vec![1.0f32; c_out * 16 * tiles];
            multiply_batch(kind, &rf32, &v32, tiles, &mut got);
            assert!(got == want32, "{kind:?} f32 must be bitwise dense-identical");
        }
    }

    #[test]
    fn zero_skip_equals_dense_on_slabs_with_injected_runs() {
        let mut rng = Rng::new(602);
        let (c_in, c_out, tiles) = (24usize, 6usize, GEMM_NR + 1);
        let w = Filter4::from_vec(c_in, c_out, 4, 4, rng.normal_vec(c_in * c_out * 16));
        let mut rf = reorder_filter(&decompose(&w, 2, default_padding(4, 2))[0]);
        // zero whole c_in runs across all c_out rows (prune-style sparsity)
        for pi in 0..rf.live.len() {
            for co in 0..c_out {
                for ci in (pi % 3)..(pi % 3 + 5) {
                    rf.u[(pi * c_out + co) * c_in + ci] = 0.0;
                }
            }
        }
        rf.skip = RunList::build(rf.live.len(), c_out, c_in, &rf.u);
        let sk = rf.skip.as_ref().expect("injected zeros must be found");
        assert!(sk.skipped_products(c_out, c_in) > 0);

        let wpix = 2 * tiles + 2;
        let x = Tensor3::from_vec(c_in, 4, wpix, rng.normal_vec(c_in * 4 * wpix));
        let v = gather(&x, tiles);
        // dense reference: same zeroed slab, no skip metadata
        let mut dense_rf = rf.clone();
        dense_rf.skip = None;
        let mut want = vec![0.0f64; c_out * 16 * tiles];
        let dense_mults = multiply_batch(KernelKind::Scalar, &dense_rf, &v, tiles, &mut want);
        for kind in [KernelKind::Scalar, KernelKind::Simd] {
            let mut got = vec![1.0f64; c_out * 16 * tiles];
            let mults = multiply_batch(kind, &rf, &v, tiles, &mut got);
            assert!(mults < dense_mults, "{kind:?} must actually skip work");
            assert_eq!(mults, issued_mults(&rf, tiles));
            // value-equal everywhere (bit-equal up to the ±0.0 caveat,
            // which random data never hits)
            assert!(got == want, "{kind:?} zero-skip must equal dense");
        }
    }

    #[test]
    fn simd_resolution_is_consistent_with_the_host() {
        // simd_available() is a pure host property; multiply_batch(Simd, ..)
        // must work either way (falling back to scalar lanes when absent)
        let mut rng = Rng::new(603);
        let w = Filter4::from_vec(3, 2, 4, 4, rng.normal_vec(3 * 2 * 16));
        let rf = reorder_filter(&decompose(&w, 2, default_padding(4, 2))[0]);
        let x = Tensor3::from_vec(3, 4, 2 * 4 + 2, rng.normal_vec(3 * 4 * 10));
        let v = gather(&x, 4);
        let mut a = vec![0.0f64; 2 * 16 * 4];
        let mut b = vec![0.0f64; 2 * 16 * 4];
        multiply_batch(KernelKind::Scalar, &rf, &v, 4, &mut a);
        multiply_batch(KernelKind::Simd, &rf, &v, 4, &mut b);
        assert!(a == b);
        let _ = simd_available();
    }
}
