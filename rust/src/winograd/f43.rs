//! F(4x4, 3x3) Winograd ablation — why the paper fixes uniform F(2x2,3x3).
//!
//! Larger tiles (m=4, n=6) cut Winograd-domain multiplications further
//! (C' = 121 vs an F(2,3)-equivalent 196 for K_D=5: another ~1.6x), but:
//!   * the transforms need real multipliers (G has 1/6, 1/12, 1/24 terms;
//!     B^T has 4, 5 — no longer shift/add-only adder trees), growing the
//!     pre/post-PE fabric cost that Table II already shows dominating;
//!   * f32 numerical error grows by roughly an order of magnitude (the
//!     transform matrices are worse conditioned), which the tests here
//!     quantify;
//!   * the padded-sub-filter sparsity is relatively weaker: a 2-tap
//!     dimension kills 1 line of 6 (17%) instead of 1 of 4 (25%).
//! This module implements the F(4,3) math and exposes the comparison used
//! by the fig4 bench ablation.

use crate::tdc;
use crate::util::tensor::{Filter4, Tensor3};

pub const M4: usize = 4;
pub const N6: usize = 6;

/// B^T (6x6) — Lavin & Gray (2016), F(4x4, 3x3).
pub const BT6: [[f64; 6]; 6] = [
    [4.0, 0.0, -5.0, 0.0, 1.0, 0.0],
    [0.0, -4.0, -4.0, 1.0, 1.0, 0.0],
    [0.0, 4.0, -4.0, -1.0, 1.0, 0.0],
    [0.0, -2.0, -1.0, 2.0, 1.0, 0.0],
    [0.0, 2.0, -1.0, -2.0, 1.0, 0.0],
    [0.0, 4.0, 0.0, -5.0, 0.0, 1.0],
];

/// G (6x3).
pub const G6: [[f64; 3]; 6] = [
    [1.0 / 4.0, 0.0, 0.0],
    [-1.0 / 6.0, -1.0 / 6.0, -1.0 / 6.0],
    [-1.0 / 6.0, 1.0 / 6.0, -1.0 / 6.0],
    [1.0 / 24.0, 1.0 / 12.0, 1.0 / 6.0],
    [1.0 / 24.0, -1.0 / 12.0, 1.0 / 6.0],
    [0.0, 0.0, 1.0],
];

/// A^T (4x6).
pub const AT6: [[f64; 6]; 4] = [
    [1.0, 1.0, 1.0, 1.0, 1.0, 0.0],
    [0.0, 1.0, -1.0, 2.0, -2.0, 0.0],
    [0.0, 1.0, 1.0, 4.0, 4.0, 0.0],
    [0.0, 1.0, -1.0, 8.0, -8.0, 1.0],
];

pub type Tile6 = [[f64; N6]; N6];

/// U = G f G^T with r<=3 support zero-padded to 3x3.
pub fn filter_transform6(f: &[[f64; 3]; 3]) -> Tile6 {
    let mut tmp = [[0.0; 3]; 6];
    for i in 0..6 {
        for j in 0..3 {
            tmp[i][j] = (0..3).map(|t| G6[i][t] * f[t][j]).sum();
        }
    }
    let mut u = [[0.0; N6]; N6];
    for i in 0..6 {
        for j in 0..6 {
            u[i][j] = (0..3).map(|t| tmp[i][t] * G6[j][t]).sum();
        }
    }
    u
}

/// V = B^T z B.
pub fn input_transform6(z: &Tile6) -> Tile6 {
    let mut tmp = [[0.0; N6]; N6];
    for i in 0..6 {
        for j in 0..6 {
            tmp[i][j] = (0..6).map(|t| BT6[i][t] * z[t][j]).sum();
        }
    }
    let mut v = [[0.0; N6]; N6];
    for i in 0..6 {
        for j in 0..6 {
            v[i][j] = (0..6).map(|t| tmp[i][t] * BT6[j][t]).sum();
        }
    }
    v
}

/// Y = A^T M A : 6x6 -> 4x4.
pub fn inverse_transform6(m: &Tile6) -> [[f64; M4]; M4] {
    let mut tmp = [[0.0; N6]; M4];
    for i in 0..4 {
        for j in 0..6 {
            tmp[i][j] = (0..6).map(|t| AT6[i][t] * m[t][j]).sum();
        }
    }
    let mut y = [[0.0; M4]; M4];
    for i in 0..4 {
        for j in 0..4 {
            y[i][j] = (0..6).map(|t| tmp[i][t] * AT6[j][t]).sum();
        }
    }
    y
}

/// Structural live positions in the 6x6 transformed tile for a sub-filter
/// with (ry, rx) real taps: G6 row 5 = [0,0,1] only touches tap 2, so a
/// 2-tap dimension zeroes 1 line of 6.
pub fn live_positions6(ry: usize, rx: usize) -> usize {
    let ly = if ry >= 3 { 6 } else { 5 };
    let lx = if rx >= 3 { 6 } else { 5 };
    ly * lx
}

/// C'(K_C): total live F(4,3)-domain multiplications across the S^2
/// sub-filters per (c_in, c_out) per 4x4 output tile.
pub fn c43_of_kc(k: usize, s: usize, p: usize) -> usize {
    let mut total = 0;
    for py in 0..s {
        let ty = tdc::phase_taps_1d(k, s, p, py);
        for px in 0..s {
            let tx = tdc::phase_taps_1d(k, s, p, px);
            total += live_positions6(ty.real_taps().clamp(1, 3), tx.real_taps().clamp(1, 3));
        }
    }
    total
}

/// Multiplications per deconv output pixel under each algorithm, for the
/// fig4 ablation table: (TDC spatial, F(2,3), F(4,3)).
///
/// Each input tile yields `S^2 * m^2` deconv outputs (m^2 per phase), so
/// the per-output costs are `K_C^2`, `C/(S^2*4)` and `C'/(S^2*16)`.
pub fn mults_per_output(k: usize, s: usize, p: usize) -> (f64, f64, f64) {
    let kc = tdc::kc(k, s) as f64;
    (
        kc * kc,
        crate::winograd::sparsity::c_of_kc(k, s, p) as f64 / (s * s * 4) as f64,
        c43_of_kc(k, s, p) as f64 / (s * s * 16) as f64,
    )
}

/// Dense F(4,3) valid correlation (reference for the numerics comparison).
/// (H-2, W-2) must be divisible by 4.
pub fn winograd43_conv2d(x: &Tensor3, g: &Filter4) -> Tensor3 {
    let (ho, wo) = (x.h - 2, x.w - 2);
    assert!(ho % M4 == 0 && wo % M4 == 0);
    let mut y = Tensor3::zeros(g.c_out, ho, wo);
    // transform the filter bank
    let mut u = Vec::with_capacity(g.c_in * g.c_out);
    for ci in 0..g.c_in {
        for co in 0..g.c_out {
            let mut f = [[0.0; 3]; 3];
            for ky in 0..g.kh.min(3) {
                for kx in 0..g.kw.min(3) {
                    f[ky][kx] = g.at(ci, co, ky, kx);
                }
            }
            u.push(filter_transform6(&f));
        }
    }
    for ty in 0..ho / M4 {
        for tx in 0..wo / M4 {
            let mut m_acc = vec![[[0.0; N6]; N6]; g.c_out];
            for ci in 0..x.c {
                let mut z = [[0.0; N6]; N6];
                for i in 0..N6 {
                    for j in 0..N6 {
                        z[i][j] = x.at(ci, M4 * ty + i, M4 * tx + j);
                    }
                }
                let v = input_transform6(&z);
                for co in 0..g.c_out {
                    let ut = &u[ci * g.c_out + co];
                    for i in 0..N6 {
                        for j in 0..N6 {
                            m_acc[co][i][j] += ut[i][j] * v[i][j];
                        }
                    }
                }
            }
            for co in 0..g.c_out {
                let yt = inverse_transform6(&m_acc[co]);
                for a in 0..M4 {
                    for b in 0..M4 {
                        *y.at_mut(co, M4 * ty + a, M4 * tx + b) = yt[a][b];
                    }
                }
            }
        }
    }
    y
}

/// f32-precision error comparison on a single tile: run the same 3x3
/// correlation through F(2,3) and F(4,3) with ALL arithmetic in f32, and
/// report the max abs error of each vs the exact f64 direct result.
/// F(4,3)'s worse-conditioned transforms (entries up to 8, fractions
/// 1/24) amplify rounding — the numerics half of the ablation.
pub fn f32_error_comparison(seed: u64) -> (f64, f64) {
    use crate::util::prng::Rng;
    let mut rng = Rng::new(seed);
    // one 6x6 input patch covers both: F(4,3) uses all of it, F(2,3) tiles it
    let z: Vec<f32> = rng.normal_vec(36).iter().map(|&v| v as f32).collect();
    let f: Vec<f32> = rng.normal_vec(9).iter().map(|&v| v as f32).collect();

    // exact f64 valid correlation (4x4 outputs)
    let x64 = Tensor3::from_vec(1, 6, 6, z.iter().map(|&v| v as f64).collect());
    let g64 = Filter4::from_vec(1, 1, 3, 3, f.iter().map(|&v| v as f64).collect());
    let exact = crate::tdc::correlate_valid(&x64, &g64);

    // generic f32 matrix helpers
    fn mat_f32(a: &[Vec<f32>], b: &[Vec<f32>]) -> Vec<Vec<f32>> {
        let (n, k, m) = (a.len(), b.len(), b[0].len());
        let mut out = vec![vec![0f32; m]; n];
        for i in 0..n {
            for j in 0..m {
                let mut acc = 0f32;
                for t in 0..k {
                    acc += a[i][t] * b[t][j];
                }
                out[i][j] = acc;
            }
        }
        let _ = k;
        out
    }
    fn tr(a: &[Vec<f32>]) -> Vec<Vec<f32>> {
        (0..a[0].len()).map(|j| a.iter().map(|r| r[j]).collect()).collect()
    }
    let grid = |v: &[f32], n: usize| -> Vec<Vec<f32>> {
        (0..n).map(|i| v[i * n..(i + 1) * n].to_vec()).collect()
    };
    let f_grid = grid(&f, 3);

    // F(4,3) in f32 on the whole 6x6 patch -> 4x4 outputs
    let bt6: Vec<Vec<f32>> = BT6.iter().map(|r| r.iter().map(|&v| v as f32).collect()).collect();
    let g6: Vec<Vec<f32>> = G6.iter().map(|r| r.iter().map(|&v| v as f32).collect()).collect();
    let at6: Vec<Vec<f32>> = AT6.iter().map(|r| r.iter().map(|&v| v as f32).collect()).collect();
    let z6 = grid(&z, 6);
    let v6 = mat_f32(&mat_f32(&bt6, &z6), &tr(&bt6));
    let u6 = mat_f32(&mat_f32(&g6, &f_grid), &tr(&g6));
    let m6: Vec<Vec<f32>> =
        (0..6).map(|i| (0..6).map(|j| u6[i][j] * v6[i][j]).collect()).collect();
    let y43 = mat_f32(&mat_f32(&at6, &m6), &tr(&at6));

    // F(2,3) in f32, tiling the 4x4 output into four 2x2 tiles
    let btm: Vec<Vec<f32>> = crate::winograd::transforms::BT
        .iter()
        .map(|r| r.iter().map(|&v| v as f32).collect())
        .collect();
    let gm: Vec<Vec<f32>> = crate::winograd::transforms::G
        .iter()
        .map(|r| r.iter().map(|&v| v as f32).collect())
        .collect();
    let atm: Vec<Vec<f32>> = crate::winograd::transforms::AT
        .iter()
        .map(|r| r.iter().map(|&v| v as f32).collect())
        .collect();
    let u4 = mat_f32(&mat_f32(&gm, &f_grid), &tr(&gm));
    let mut y23 = vec![vec![0f32; 4]; 4];
    for ty in 0..2 {
        for tx in 0..2 {
            let z4: Vec<Vec<f32>> = (0..4)
                .map(|i| (0..4).map(|j| z[(2 * ty + i) * 6 + 2 * tx + j]).collect())
                .collect();
            let v4 = mat_f32(&mat_f32(&btm, &z4), &tr(&btm));
            let m4: Vec<Vec<f32>> =
                (0..4).map(|i| (0..4).map(|j| u4[i][j] * v4[i][j]).collect()).collect();
            let t = mat_f32(&mat_f32(&atm, &m4), &tr(&atm));
            for a in 0..2 {
                for b in 0..2 {
                    y23[2 * ty + a][2 * tx + b] = t[a][b];
                }
            }
        }
    }

    let mut e23 = 0f64;
    let mut e43 = 0f64;
    for i in 0..4 {
        for j in 0..4 {
            let want = exact.at(0, i, j);
            e23 = e23.max((y23[i][j] as f64 - want).abs());
            e43 = e43.max((y43[i][j] as f64 - want).abs());
        }
    }
    (e23, e43)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tdc::correlate_valid;
    use crate::util::prng::Rng;

    #[test]
    fn f43_identity_1d() {
        // F(4,3) on a known 1D signal embedded in 2D
        let mut rng = Rng::new(1);
        let x = Tensor3::from_vec(1, 6, 6, rng.normal_vec(36));
        let g = Filter4::from_vec(1, 1, 3, 3, rng.normal_vec(9));
        let want = correlate_valid(&x, &g);
        let got = winograd43_conv2d(&x, &g);
        assert!(want.max_abs_diff(&got) < 1e-9, "{}", want.max_abs_diff(&got));
    }

    #[test]
    fn f43_multichannel() {
        let mut rng = Rng::new(2);
        let x = Tensor3::from_vec(3, 10, 14, rng.normal_vec(3 * 10 * 14));
        let g = Filter4::from_vec(3, 2, 3, 3, rng.normal_vec(3 * 2 * 9));
        let want = correlate_valid(&x, &g);
        let got = winograd43_conv2d(&x, &g);
        assert!(want.max_abs_diff(&got) < 1e-8);
    }

    #[test]
    fn c43_constants() {
        // K5S2: 36 + 30 + 30 + 25 = 121; K4S2: 4 * 25 = 100; K3S1: 36
        assert_eq!(c43_of_kc(5, 2, 2), 121);
        assert_eq!(c43_of_kc(4, 2, 1), 100);
        assert_eq!(c43_of_kc(3, 1, 1), 36);
    }

    #[test]
    fn f43_reduces_mults_further_than_f23() {
        for (k, s) in [(5usize, 2usize), (4, 2), (3, 1)] {
            let p = tdc::default_padding(k, s);
            let (td, f23, f43) = mults_per_output(k, s, p);
            assert!(f43 < f23, "K={k}: f43 {f43} vs f23 {f23}");
            assert!(f23 < td, "K={k}");
        }
    }

    #[test]
    fn f43_numerics_are_worse_than_f23() {
        // the ablation's point: larger tiles trade accuracy for mults
        let mut worse = 0;
        for seed in 0..8 {
            let (e23, e43) = f32_error_comparison(seed);
            if e43 > e23 {
                worse += 1;
            }
            assert!(e23 < 5e-5, "F(2,3) f32 error unexpectedly large: {e23}");
        }
        assert!(worse >= 6, "F(4,3) should usually have larger f32 error ({worse}/8)");
    }

    #[test]
    fn live_positions_structure() {
        assert_eq!(live_positions6(3, 3), 36);
        assert_eq!(live_positions6(3, 2), 30);
        assert_eq!(live_positions6(2, 2), 25);
    }
}
