//! Winograd F(2x2, 3x3) minimal-filtering transforms (paper eq. 3/4).
//!
//! `m = 2` outputs per dim, `r = 3` taps per dim, `n = m + r - 1 = 4`.
//! Filters with fewer than 3 real taps (the TDC sub-filters of a K_D=4 or
//! K_D=5 deconv) are zero-padded to 3x3 before the `G f G^T` transform,
//! which is what creates the structural zero patterns of Fig. 3.

use crate::util::elem::Elem;
use crate::util::tensor::{Filter4, Tensor3};

pub const M: usize = 2;
pub const R: usize = 3;
pub const N: usize = 4;

/// B^T: 4x4 input transform.
pub const BT: [[f64; 4]; 4] = [
    [1.0, 0.0, -1.0, 0.0],
    [0.0, 1.0, 1.0, 0.0],
    [0.0, -1.0, 1.0, 0.0],
    [0.0, 1.0, 0.0, -1.0],
];

/// G: 4x3 filter transform.
pub const G: [[f64; 3]; 3 + 1] = [
    [1.0, 0.0, 0.0],
    [0.5, 0.5, 0.5],
    [0.5, -0.5, 0.5],
    [0.0, 0.0, 1.0],
];

/// A^T: 2x4 inverse (output) transform.
pub const AT: [[f64; 4]; 2] = [
    [1.0, 1.0, 1.0, 0.0],
    [0.0, 1.0, -1.0, -1.0],
];

/// A transformed 4x4 tile (defaults to the f64 reference tier; the
/// execution engine instantiates it per plan precision).
pub type Tile4<E = f64> = [[E; N]; N];

/// `U = G f G^T` for a single 2D filter, zero-padding r<3 supports to 3x3.
pub fn filter_transform(f: &[[f64; 3]; 3]) -> Tile4 {
    // tmp = G f : 4x3
    let mut tmp = [[0.0; 3]; 4];
    for i in 0..4 {
        for j in 0..3 {
            let mut acc = 0.0;
            for t in 0..3 {
                acc += G[i][t] * f[t][j];
            }
            tmp[i][j] = acc;
        }
    }
    // U = tmp G^T : 4x4
    let mut u = [[0.0; N]; N];
    for i in 0..4 {
        for j in 0..4 {
            let mut acc = 0.0;
            for t in 0..3 {
                acc += tmp[i][t] * G[j][t];
            }
            u[i][j] = acc;
        }
    }
    u
}

/// `V = B^T z B` for a 4x4 input tile, via the adder-tree formulation the
/// FPGA pre-PE uses (rows then columns; 32 adds, no multiplies). Generic
/// over the element precision: the same add/sub sequence runs at `f32` on
/// the serving fast path and at `f64` on the reference tier.
pub fn input_transform<E: Elem>(z: &Tile4<E>) -> Tile4<E> {
    #[inline]
    fn bt_lines<E: Elem>(a: [E; 4]) -> [E; 4] {
        [a[0] - a[2], a[1] + a[2], a[2] - a[1], a[1] - a[3]]
    }
    let mut rows = [[E::ZERO; N]; N];
    for j in 0..N {
        let col = bt_lines([z[0][j], z[1][j], z[2][j], z[3][j]]);
        for i in 0..N {
            rows[i][j] = col[i];
        }
    }
    let mut v = [[E::ZERO; N]; N];
    for i in 0..N {
        let line = bt_lines(rows[i]);
        v[i] = line;
    }
    v
}

/// `Y = A^T M A`: 4x4 Winograd-domain accumulator -> 2x2 spatial outputs.
/// Generic over the element precision like [`input_transform`].
pub fn inverse_transform<E: Elem>(m: &Tile4<E>) -> [[E; M]; M] {
    #[inline]
    fn at_lines<E: Elem>(a: [E; 4]) -> [E; 2] {
        [a[0] + a[1] + a[2], a[1] - a[2] - a[3]]
    }
    let mut half = [[E::ZERO; 2]; N]; // half[j] = A^T applied down column j
    for j in 0..N {
        half[j] = at_lines([m[0][j], m[1][j], m[2][j], m[3][j]]);
    }
    let mut y = [[E::ZERO; M]; M];
    for a in 0..M {
        y[a] = at_lines([half[0][a], half[1][a], half[2][a], half[3][a]]);
    }
    y
}

/// Transform a filter bank `[C_in, C_out, r, r]` (r <= 3, zero-padded) into
/// Winograd-domain tiles, flattened index `[ci][co] -> Tile4`.
pub fn filter_bank_transform(g: &Filter4) -> Vec<Tile4> {
    assert!(g.kh <= R && g.kw <= R);
    let mut out = Vec::with_capacity(g.c_in * g.c_out);
    for ci in 0..g.c_in {
        for co in 0..g.c_out {
            let mut f = [[0.0; 3]; 3];
            for ky in 0..g.kh {
                for kx in 0..g.kw {
                    f[ky][kx] = g.at(ci, co, ky, kx);
                }
            }
            out.push(filter_transform(&f));
        }
    }
    out
}

/// Dense Winograd valid correlation of `x[C_in,H,W]` with
/// `g[C_in,C_out,r,r]` (r<=3): reference for the sparse engine and the
/// functional simulator. (H-2, W-2) must be tile-aligned (even).
pub fn winograd_conv2d(x: &Tensor3, g: &Filter4) -> Tensor3 {
    let (ho, wo) = (x.h - (R - 1), x.w - (R - 1));
    assert!(ho % M == 0 && wo % M == 0, "tile-align inputs first");
    let u = filter_bank_transform(g);
    let mut y = Tensor3::zeros(g.c_out, ho, wo);
    for ty in 0..ho / M {
        for tx in 0..wo / M {
            // accumulate in the Winograd domain over input channels
            let mut m_acc = vec![[[0.0; N]; N]; g.c_out];
            for ci in 0..x.c {
                let mut z = [[0.0; N]; N];
                for i in 0..N {
                    for j in 0..N {
                        z[i][j] = x.at(ci, M * ty + i, M * tx + j);
                    }
                }
                let v = input_transform(&z);
                for co in 0..g.c_out {
                    let ut = &u[ci * g.c_out + co];
                    let acc = &mut m_acc[co];
                    for i in 0..N {
                        for j in 0..N {
                            acc[i][j] += ut[i][j] * v[i][j];
                        }
                    }
                }
            }
            for co in 0..g.c_out {
                let yt = inverse_transform(&m_acc[co]);
                for a in 0..M {
                    for b in 0..M {
                        *y.at_mut(co, M * ty + a, M * tx + b) = yt[a][b];
                    }
                }
            }
        }
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tdc::correlate_valid;
    use crate::util::prng::Rng;

    #[test]
    fn f23_1d_identity_check() {
        // F(2,3) on a known signal: y = correlate(z, f)
        let z = [1.0, 2.0, 3.0, 4.0];
        let f = [0.5, -1.0, 2.0];
        let expect = [
            z[0] * f[0] + z[1] * f[1] + z[2] * f[2],
            z[1] * f[0] + z[2] * f[1] + z[3] * f[2],
        ];
        // build as 2D with the second dim trivial (tap 0 = 1)
        let mut f2 = [[0.0; 3]; 3];
        f2[0] = [f[0], 0.0, 0.0];
        f2[1] = [f[1], 0.0, 0.0];
        f2[2] = [f[2], 0.0, 0.0];
        let u = filter_transform(&f2);
        let mut z2 = [[0.0; 4]; 4];
        for i in 0..4 {
            z2[i][0] = z[i];
        }
        let v = input_transform(&z2);
        let mut m = [[0.0; 4]; 4];
        for i in 0..4 {
            for j in 0..4 {
                m[i][j] = u[i][j] * v[i][j];
            }
        }
        let y = inverse_transform(&m);
        assert!((y[0][0] - expect[0]).abs() < 1e-12);
        assert!((y[1][0] - expect[1]).abs() < 1e-12);
    }

    #[test]
    fn padded_2tap_filter_zeroes_last_line() {
        // 2x2 support zero-padded to 3x3 -> transformed row 3 and col 3 zero
        let f = [[1.0, 2.0, 0.0], [3.0, 4.0, 0.0], [0.0, 0.0, 0.0]];
        let u = filter_transform(&f);
        for t in 0..4 {
            assert_eq!(u[3][t], 0.0, "row 3 position {t}");
            assert_eq!(u[t][3], 0.0, "col 3 position {t}");
        }
        // and the 3x3 interior is generically non-zero
        assert!(u[0][0] != 0.0);
    }

    #[test]
    fn dense_winograd_matches_direct_correlation() {
        let mut rng = Rng::new(200);
        let x = Tensor3::from_vec(3, 8, 10, rng.normal_vec(3 * 8 * 10));
        for r in [2usize, 3] {
            let g = Filter4::from_vec(3, 4, r, r, rng.normal_vec(3 * 4 * r * r));
            // pad the filter bank to 3x3 for the direct reference
            let mut g3 = Filter4::zeros(3, 4, 3, 3);
            for ci in 0..3 {
                for co in 0..4 {
                    for ky in 0..r {
                        for kx in 0..r {
                            *g3.at_mut(ci, co, ky, kx) = g.at(ci, co, ky, kx);
                        }
                    }
                }
            }
            let y_ref = correlate_valid(&x, &g3);
            let y_win = winograd_conv2d(&x, &g);
            assert!(y_ref.max_abs_diff(&y_win) < 1e-10, "r={r}");
        }
    }

    #[test]
    fn input_transform_matches_matrix_form() {
        let mut rng = Rng::new(201);
        let mut z = [[0.0; 4]; 4];
        for row in z.iter_mut() {
            for v in row.iter_mut() {
                *v = rng.normal();
            }
        }
        let fast = input_transform(&z);
        // slow: V = BT z BT^T(applied as B on the right)
        let mut tmp = [[0.0; 4]; 4];
        for i in 0..4 {
            for j in 0..4 {
                let mut acc = 0.0;
                for t in 0..4 {
                    acc += BT[i][t] * z[t][j];
                }
                tmp[i][j] = acc;
            }
        }
        let mut slow = [[0.0; 4]; 4];
        for i in 0..4 {
            for j in 0..4 {
                let mut acc = 0.0;
                for t in 0..4 {
                    acc += tmp[i][t] * BT[j][t];
                }
                slow[i][j] = acc;
            }
        }
        for i in 0..4 {
            for j in 0..4 {
                assert!((fast[i][j] - slow[i][j]).abs() < 1e-12);
            }
        }
    }
}
