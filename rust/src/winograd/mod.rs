//! Winograd minimal-filtering substrate (paper §II.B, §III).
//!
//! * [`transforms`] — the F(2×2, 3×3) matrices and transform kernels:
//!   input `Bᵀ Z B`, filter `G g Gᵀ`, inverse `Aᵀ M A`, with tile sizes
//!   [`M`] (output), [`N`] (input) and filter support [`R`].
//! * [`f43`] — the F(4×3) variant used for analytic comparisons.
//! * [`sparsity`] — Table I: TDC phase filters fall into structural
//!   sparsity [`Case`]s in the Winograd domain; [`classify`] detects the
//!   case, [`c_of_kc`] counts the surviving (live) positions that the
//!   accelerator actually multiplies.
//! * [`layout`] — the zero-row-free `n² × N` reordered filter layout
//!   (§III.B): filters are regrouped so the com-PE array multiplies only
//!   live rows, which is what restores PE utilization after the
//!   TDC × Winograd combination.
//! * [`kernel`] — the arch-dispatched GEMM micro-kernels the engine's
//!   stripe-batched datapath runs on: explicit AVX2/NEON paths with the
//!   blocked scalar loop as fallback ([`KernelKind`]), plus the runtime
//!   zero-skip [`RunList`] that extends the structural (vector-level)
//!   sparsity with within-slab run sparsity.
//!
//! The python oracle (`python/tests/test_winograd.py`,
//! `test_sparsity.py`) pins these kernels; the engine consumes them
//! exclusively through precompiled plans.

pub mod f43;
pub mod kernel;
pub mod layout;
pub mod sparsity;
pub mod transforms;

pub use kernel::{multiply_batch, simd_available, KernelKind, RunList};
pub use sparsity::{c_of_kc, classify, phase_cases, Case};
pub use transforms::{M, N, R};
