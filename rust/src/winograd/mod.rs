//! Winograd minimal-filtering substrate: F(2x2,3x3) transforms, structural
//! sparsity analysis of TDC sub-filters, and the reordered `n^2 x N`
//! dataflow layout (paper §II.B, §III).

pub mod f43;
pub mod layout;
pub mod sparsity;
pub mod transforms;

pub use sparsity::{c_of_kc, classify, phase_cases, Case};
pub use transforms::{M, N, R};
