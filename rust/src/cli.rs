//! Tiny CLI argument parser (no clap offline): subcommand + `--key value`
//! flags + `--bool-flag` switches.

use crate::coordinator::SchedulerKind;
use crate::util::elem::Precision;
use crate::winograd::kernel::KernelKind;
use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    flags: BTreeMap<String, String>,
    switches: Vec<String>,
    positionals: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Result<Args, String> {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                if key.is_empty() {
                    return Err("empty flag '--'".into());
                }
                match it.peek() {
                    Some(v) if !v.starts_with("--") => {
                        let v = it.next().unwrap();
                        out.flags.insert(key.to_string(), v);
                    }
                    _ => out.switches.push(key.to_string()),
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(a);
            } else {
                // extra positionals after the subcommand (e.g.
                // `plan inspect <artifact>`); the consumer validates which
                // subcommands accept them and with what arity
                out.positionals.push(a);
            }
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Args, String> {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(String::as_str)
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{key}: '{v}' is not an integer")),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{key}: '{v}' is not a number")),
        }
    }

    /// The shared worker-pool sizing flag, `--workers N`. Returns 0 when
    /// absent — the "no explicit request" value every consumer resolves
    /// through [`crate::engine::resolve_workers`] (env `WINGAN_WORKERS`,
    /// then one thread per core), so CLI, env and default sizing share one
    /// override path. An **explicit** `--workers 0` is rejected: a
    /// zero-worker pool can never run anything, and silently treating it
    /// as "unset" would mask the typo.
    pub fn get_workers(&self) -> Result<usize, String> {
        match self.get_usize("workers", 0)? {
            0 if self.get("workers").is_some() => {
                Err("--workers: 0 is not a valid pool size (need at least 1 worker, \
                     or omit the flag for one worker per core)"
                    .into())
            }
            n => Ok(n),
        }
    }

    /// The serving-precision flag, `--precision f32|f64|auto`. Returns
    /// `None` when absent or `auto` — the "no explicit request" value every
    /// consumer resolves through
    /// [`crate::engine::resolve_precision`] (env `WINGAN_PRECISION`, then
    /// the per-plan dse recommendation), so CLI, env and default precision
    /// selection share one override path, exactly like pool sizing.
    pub fn get_precision(&self) -> Result<Option<Precision>, String> {
        match self.get("precision") {
            None => Ok(None),
            Some(v) if v.eq_ignore_ascii_case("auto") => Ok(None),
            Some(v) => Precision::parse(v).map(Some).map_err(|e| format!("--precision: {e}")),
        }
    }

    /// The GEMM micro-kernel flag, `--kernel scalar|simd|auto`. Returns
    /// `None` when absent or `auto` — the "no explicit request" value
    /// every consumer resolves through [`crate::engine::resolve_kernel`]
    /// (env `WINGAN_KERNEL`, then the host capability probe), mirroring
    /// [`Args::get_precision`].
    pub fn get_kernel(&self) -> Result<Option<KernelKind>, String> {
        match self.get("kernel") {
            None => Ok(None),
            Some(v) if v.eq_ignore_ascii_case("auto") => Ok(None),
            Some(v) => KernelKind::parse(v).map(Some).map_err(|e| format!("--kernel: {e}")),
        }
    }

    /// The batch-scheduler flag, `--scheduler continuous|bucket`.
    /// Defaults to [`SchedulerKind::Continuous`] when absent — the
    /// production scheduler; `bucket` selects the PR-6 baseline the
    /// loadgen harness A/Bs against.
    pub fn get_scheduler(&self) -> Result<SchedulerKind, String> {
        match self.get("scheduler") {
            None => Ok(SchedulerKind::Continuous),
            Some(v) => SchedulerKind::parse(v).map_err(|e| format!("--scheduler: {e}")),
        }
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch) || self.flags.contains_key(switch)
    }

    /// The `i`-th positional argument after the subcommand (0-based) —
    /// `wingan plan inspect <file>` sees `positional(0) == "inspect"` and
    /// `positional(1) == "<file>"`.
    pub fn positional(&self, i: usize) -> Option<&str> {
        self.positionals.get(i).map(String::as_str)
    }

    /// Number of positional arguments after the subcommand.
    pub fn n_positionals(&self) -> usize {
        self.positionals.len()
    }

    /// Error if any positional argument (beyond the subcommand) was given
    /// — the policy for every `wingan` subcommand except `plan`.
    pub fn reject_positionals(&self) -> Result<(), String> {
        match self.positional(0) {
            Some(stray) => Err(format!("unexpected positional argument '{stray}'")),
            None => Ok(()),
        }
    }

    /// Error if any bare (non-flag) argument was given, including the
    /// would-be subcommand — the policy for flags-only binaries (the
    /// examples), where a stray bare word is always a forgotten flag name.
    /// (The first bare word always lands in `subcommand`, so checking it
    /// covers the positionals too; the delegation is belt-and-braces.)
    pub fn reject_bare_args(&self) -> Result<(), String> {
        match self.subcommand.as_deref() {
            Some(stray) => Err(format!("unexpected positional argument '{stray}'")),
            None => self.reject_positionals(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("serve --model dcgan --requests 64 --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("serve"));
        assert_eq!(a.get("model"), Some("dcgan"));
        assert_eq!(a.get_usize("requests", 0).unwrap(), 64);
        assert!(a.has("verbose"));
        assert!(!a.has("quiet"));
    }

    #[test]
    fn defaults() {
        let a = parse("sim");
        assert_eq!(a.get_or("model", "all"), "all");
        assert_eq!(a.get_usize("requests", 16).unwrap(), 16);
        assert_eq!(a.get_f64("rate", 1.5).unwrap(), 1.5);
    }

    #[test]
    fn workers_flag_defaults_to_unset() {
        assert_eq!(parse("serve").get_workers().unwrap(), 0);
        assert_eq!(parse("serve --workers 6").get_workers().unwrap(), 6);
        assert!(parse("serve --workers lots").get_workers().is_err());
    }

    #[test]
    fn explicit_zero_workers_is_rejected() {
        // regression: `--workers 0` used to parse as the "unset" sentinel
        // and silently fall through to env/core sizing
        let err = parse("serve --workers 0").get_workers().unwrap_err();
        assert!(err.contains("at least 1"), "{err}");
    }

    #[test]
    fn kernel_flag_defaults_to_unset() {
        assert_eq!(parse("serve").get_kernel().unwrap(), None);
        assert_eq!(parse("serve --kernel auto").get_kernel().unwrap(), None);
        assert_eq!(
            parse("serve --kernel simd").get_kernel().unwrap(),
            Some(KernelKind::Simd)
        );
        assert_eq!(
            parse("serve --kernel Scalar").get_kernel().unwrap(),
            Some(KernelKind::Scalar)
        );
        assert!(parse("serve --kernel avx512").get_kernel().is_err());
    }

    #[test]
    fn precision_flag_defaults_to_unset() {
        assert_eq!(parse("serve").get_precision().unwrap(), None);
        assert_eq!(parse("serve --precision auto").get_precision().unwrap(), None);
        assert_eq!(
            parse("serve --precision f32").get_precision().unwrap(),
            Some(Precision::F32)
        );
        assert_eq!(
            parse("serve --precision F64").get_precision().unwrap(),
            Some(Precision::F64)
        );
        assert!(parse("serve --precision f16").get_precision().is_err());
    }

    #[test]
    fn scheduler_flag_defaults_to_continuous() {
        assert_eq!(parse("serve").get_scheduler().unwrap(), SchedulerKind::Continuous);
        assert_eq!(
            parse("serve --scheduler bucket").get_scheduler().unwrap(),
            SchedulerKind::Bucket
        );
        assert_eq!(
            parse("serve --scheduler Continuous").get_scheduler().unwrap(),
            SchedulerKind::Continuous
        );
        let err = parse("serve --scheduler fifo").get_scheduler().unwrap_err();
        assert!(err.contains("fifo"), "{err}");
    }

    #[test]
    fn bad_number_reported() {
        let a = parse("serve --requests abc");
        assert!(a.get_usize("requests", 0).is_err());
    }

    #[test]
    fn collects_positionals_after_the_subcommand() {
        let a = parse("plan inspect store/tiny/dcgan.winograd.f64.plan");
        assert_eq!(a.subcommand.as_deref(), Some("plan"));
        assert_eq!(a.positional(0), Some("inspect"));
        assert_eq!(a.positional(1), Some("store/tiny/dcgan.winograd.f64.plan"));
        assert_eq!(a.positional(2), None);
        assert_eq!(a.n_positionals(), 2);
        // flags still parse around positionals
        let b = parse("plan inspect x.plan --verbose");
        assert_eq!(b.positional(1), Some("x.plan"));
        assert!(b.has("verbose"));
        assert_eq!(parse("sim").n_positionals(), 0);
    }

    #[test]
    fn positional_rejection_policies() {
        // subcommand consumers: the subcommand itself is fine, extras fail
        assert!(parse("serve --model dcgan").reject_positionals().is_ok());
        let err = parse("serve dcgan").reject_positionals().unwrap_err();
        assert!(err.contains("dcgan"), "{err}");
        // flags-only consumers: even the would-be subcommand fails
        assert!(parse("--model dcgan").reject_bare_args().is_ok());
        let err = parse("dcgan --requests 4").reject_bare_args().unwrap_err();
        assert!(err.contains("dcgan"), "{err}");
        let err = parse("x y").reject_bare_args().unwrap_err();
        assert!(err.contains('x'), "{err}");
    }

    #[test]
    fn switch_before_flag() {
        let a = parse("x --flush --rate 2.0");
        assert!(a.has("flush"));
        assert_eq!(a.get_f64("rate", 0.0).unwrap(), 2.0);
    }
}
