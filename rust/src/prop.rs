//! Mini property-testing substrate (proptest is unavailable offline).
//!
//! `forall` drives a generator + checker over many seeded cases and, on
//! failure, reports the exact seed and case index so the failure replays
//! deterministically (`replay`). No shrinking — generators are kept small
//! enough that raw counterexamples are readable.

use crate::util::prng::Rng;
use std::fmt::Debug;

/// Number of cases for a standard property run (override per call).
pub const DEFAULT_CASES: usize = 64;

/// Run `check` over `cases` generated inputs. Panics with a replayable
/// seed on the first failure.
pub fn forall<T: Debug>(
    name: &str,
    cases: usize,
    base_seed: u64,
    generate: impl Fn(&mut Rng) -> T,
    check: impl Fn(&T) -> Result<(), String>,
) {
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(seed);
        let input = generate(&mut rng);
        if let Err(msg) = check(&input) {
            panic!(
                "property '{name}' failed at case {case} (seed {seed:#x}):\n  {msg}\n  input: {input:?}"
            );
        }
    }
}

/// Replay a single failing case by seed.
pub fn replay<T: Debug>(
    seed: u64,
    generate: impl Fn(&mut Rng) -> T,
    check: impl Fn(&T) -> Result<(), String>,
) -> Result<(), String> {
    let mut rng = Rng::new(seed);
    check(&generate(&mut rng))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_completes() {
        forall(
            "sum-commutes",
            32,
            1,
            |r| (r.below(100) as i64, r.below(100) as i64),
            |&(a, b)| {
                if a + b == b + a {
                    Ok(())
                } else {
                    Err("math broke".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property 'always-fails'")]
    fn failing_property_reports_seed() {
        forall("always-fails", 4, 2, |r| r.below(10), |_| Err("nope".into()));
    }

    #[test]
    fn replay_reproduces() {
        // generate the same value twice from the same seed
        let gen = |r: &mut Rng| r.below(1000);
        let mut r1 = Rng::new(42);
        let v = gen(&mut r1);
        assert!(replay(42, gen, |&x| if x == v { Ok(()) } else { Err("diverged".into()) })
            .is_ok());
    }
}
