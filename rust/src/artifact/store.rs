//! On-disk plan store: `(model, scale, precision, method, seed)` keys →
//! versioned artifact files under a store root, with atomic
//! write-then-rename publishing and an in-process `Arc` cache.
//!
//! Layout: `<root>/<scale>/<model>.<method>.<precision>.plan` — one file
//! per serving route per precision tier (the `tdc` reference route only
//! ever exists at `f64`). `wingan compile` populates a store ahead of time
//! and writes a human-readable `manifest.json` next to the scale
//! directories; `wingan serve --plan-store <dir>` (via
//! [`crate::engine::NativeConfig::plan_store`]) loads from it at startup,
//! falling back to in-process compilation — and then publishing the result
//! — for any key it cannot load.
//!
//! Publishing is **atomic**: the encoded bytes are written to a temporary
//! file in the destination directory and `rename(2)`d into place, so a
//! concurrent reader sees either the old artifact or the new one, never a
//! torn write. Loading validates magic, format version, section checksums
//! and the full key (precision tier, model id, scale, route method, weight
//! seed) before the plan is admitted to the cache; every failure is a typed
//! [`ArtifactError`], never a panic.

use crate::artifact::codec::{self, ArtifactError, ArtifactMeta, ArtifactResult, PlanPayload};
use crate::engine::plan::ModelPlan;
use crate::engine::serve::model_id;
use crate::gan::zoo::Scale;
use crate::util::elem::{Elem, Precision};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

/// Identity of one stored plan: everything that determines the compiled
/// bytes. `model` is the route id (`"dcgan"`), `method` the serving route
/// method (`"winograd"` for DSE-raced plans, `"tdc"` for the forced
/// reference datapath), `seed` the deterministic weight seed.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct PlanKey {
    /// route/model id (lowercase, e.g. `"dcgan"`)
    pub model: String,
    /// zoo scale the plan was compiled at
    pub scale: Scale,
    /// precision tier of the stored plan
    pub precision: Precision,
    /// serving route method (`"winograd"` / `"tdc"`)
    pub method: String,
    /// deterministic weight seed
    pub seed: u64,
}

impl PlanKey {
    /// Convenience constructor (normalizes the model through
    /// [`model_id`], so `"GP-GAN"` and `"gpgan"` name the same artifact).
    pub fn new(
        model: &str,
        scale: Scale,
        precision: Precision,
        method: &str,
        seed: u64,
    ) -> PlanKey {
        PlanKey {
            model: model_id(model),
            scale,
            precision,
            method: method.to_string(),
            seed,
        }
    }

    /// File name of this key's artifact (`dcgan.winograd.f64.plan`). The
    /// seed is validated from the artifact header, not the name — one slot
    /// per route and tier.
    pub fn file_name(&self) -> String {
        format!("{}.{}.{}.plan", self.model, self.method, self.precision.label())
    }

    /// Store-relative path (`tiny/dcgan.winograd.f64.plan`).
    pub fn rel_path(&self) -> PathBuf {
        Path::new(self.scale.label()).join(self.file_name())
    }
}

/// A loaded plan at whichever tier its artifact was tagged with, shared
/// behind an `Arc` — the store's cache hands the *same* allocation to every
/// route (and every engine) that asks for the same key.
#[derive(Clone, Debug)]
pub enum AnyPlan {
    /// single-precision (serving fast tier) plan
    F32(Arc<ModelPlan<f32>>),
    /// double-precision (reference tier) plan
    F64(Arc<ModelPlan<f64>>),
}

impl AnyPlan {
    /// The precision tier of the loaded plan.
    pub fn precision(&self) -> Precision {
        match self {
            AnyPlan::F32(_) => Precision::F32,
            AnyPlan::F64(_) => Precision::F64,
        }
    }

    /// Zoo model name (e.g. `"DCGAN"`).
    pub fn model(&self) -> &str {
        match self {
            AnyPlan::F32(p) => &p.model,
            AnyPlan::F64(p) => &p.model,
        }
    }

    /// `[C, H, W]` of one input sample.
    pub fn input_shape(&self) -> (usize, usize, usize) {
        match self {
            AnyPlan::F32(p) => p.input_shape,
            AnyPlan::F64(p) => p.input_shape,
        }
    }

    /// `[C, H, W]` of one output sample.
    pub fn output_shape(&self) -> (usize, usize, usize) {
        match self {
            AnyPlan::F32(p) => p.output_shape,
            AnyPlan::F64(p) => p.output_shape,
        }
    }

    /// Number of compiled layers.
    pub fn n_layers(&self) -> usize {
        match self {
            AnyPlan::F32(p) => p.layers.len(),
            AnyPlan::F64(p) => p.layers.len(),
        }
    }
}

impl From<PlanPayload> for AnyPlan {
    fn from(p: PlanPayload) -> AnyPlan {
        match p {
            PlanPayload::F32(p) => AnyPlan::F32(Arc::new(p)),
            PlanPayload::F64(p) => AnyPlan::F64(Arc::new(p)),
        }
    }
}

/// Counters for one serving startup against a plan store — how many routes
/// came up warm (artifact hit), cold (fallback compile), or found a broken
/// artifact on the way (load failure; always followed by a clean fallback).
/// Surfaced through [`crate::coordinator::Metrics`] so warm-vs-cold
/// behavior is observable from the serving metrics snapshot.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PlanCacheStats {
    /// routes whose plan loaded from an artifact (no planner invocation)
    pub artifact_hits: u64,
    /// routes compiled in-process (cold store, or after a load failure)
    pub fallback_compiles: u64,
    /// artifacts that existed but failed validation (corrupt, wrong
    /// version, key mismatch, ...)
    pub load_failures: u64,
    /// freshly compiled plans published back into the store
    pub published: u64,
    /// broken artifacts moved aside to `<file>.quarantined` instead of
    /// being left in place to fail on every boot
    pub quarantined: u64,
}

/// Write `bytes` to `path` atomically: parent directories are created, the
/// bytes land in a same-directory temp file (unique per process *and* per
/// call, so racing writers never share one), and a rename moves them into
/// place — readers observe the old file or the new one, never a torn
/// write. The temp file is removed on every failure path. Artifact
/// publishes and `wingan compile`'s manifest both go through this.
pub fn atomic_write(path: &Path, bytes: &[u8]) -> std::io::Result<()> {
    use std::sync::atomic::{AtomicU64, Ordering};
    static TMP_SEQ: AtomicU64 = AtomicU64::new(0);
    let dir = path.parent().unwrap_or_else(|| Path::new("."));
    std::fs::create_dir_all(dir)?;
    let name = path.file_name().map(|n| n.to_string_lossy().into_owned()).unwrap_or_default();
    let tmp = dir.join(format!(
        ".{name}.tmp.{}.{}",
        std::process::id(),
        TMP_SEQ.fetch_add(1, Ordering::Relaxed)
    ));
    let result = std::fs::write(&tmp, bytes).and_then(|_| std::fs::rename(&tmp, path));
    if result.is_err() {
        let _ = std::fs::remove_file(&tmp);
    }
    result
}

/// Name of the store-root file holding the on-disk generation tag.
pub const GENERATION_FILE: &str = "GENERATION";

/// Read a store's on-disk **generation tag**: a monotonic counter kept in
/// an ASCII `GENERATION` file at the store root, bumped by `wingan
/// compile` after it republishes a plan set. Fleet replicas record the
/// generation they warm-booted from and the fleet router watches this
/// file to roll a republish through the fleet one replica at a time — so
/// the tag, not file mtimes, is the coordination point. A missing or
/// unparsable file reads as generation `0` (a store that has never been
/// republished), never an error: the tag is advisory for rolling, not
/// load-bearing for correctness.
pub fn read_generation(root: &Path) -> u64 {
    std::fs::read_to_string(root.join(GENERATION_FILE))
        .ok()
        .and_then(|s| s.trim().parse::<u64>().ok())
        .unwrap_or(0)
}

/// The in-process cache plus its publish generation: the counter bumps
/// (under the same lock) whenever a publish invalidates, so a load that
/// read its bytes *before* a concurrent publish can detect that and
/// decline to cache the pre-publish plan.
#[derive(Debug, Default)]
struct CacheInner {
    plans: HashMap<PlanKey, AnyPlan>,
    generation: u64,
}

/// The on-disk plan store (see the module docs for layout and atomicity).
/// Cheap to construct; directories are created lazily on first publish.
#[derive(Debug)]
pub struct PlanStore {
    root: PathBuf,
    cache: Mutex<CacheInner>,
}

impl PlanStore {
    /// A store rooted at `root`. Nothing is touched on disk until the
    /// first [`PlanStore::publish`].
    pub fn open(root: impl Into<PathBuf>) -> PlanStore {
        PlanStore { root: root.into(), cache: Mutex::new(CacheInner::default()) }
    }

    /// The store's root directory.
    pub fn root(&self) -> &Path {
        &self.root
    }

    /// Absolute path of `key`'s artifact file.
    pub fn path(&self, key: &PlanKey) -> PathBuf {
        self.root.join(key.rel_path())
    }

    /// Number of plans currently held by the in-process cache.
    pub fn cached(&self) -> usize {
        self.cache.lock().unwrap().plans.len()
    }

    /// The store's current **generation tag** (see [`read_generation`]).
    pub fn generation(&self) -> u64 {
        read_generation(&self.root)
    }

    /// Advance the store's generation tag by one (atomic replace of the
    /// `GENERATION` file) and return the new value. Called by `wingan
    /// compile` after a full republish; deliberately **not** called from
    /// [`PlanStore::publish`], so a replica's self-healing fallback
    /// publish can never kick off a fleet-wide rolling reload by itself.
    pub fn bump_generation(&self) -> std::io::Result<u64> {
        let next = read_generation(&self.root) + 1;
        std::fs::create_dir_all(&self.root)?;
        atomic_write(&self.root.join(GENERATION_FILE), next.to_string().as_bytes())?;
        Ok(next)
    }

    /// Load `key`'s plan, serving repeats from the in-process cache: every
    /// caller asking this store handle for the same key gets a clone of
    /// the same `Arc<ModelPlan>`, so one deserialized plan is shared —
    /// note each [`crate::engine::NativeRuntime::build`] opens its own
    /// handle (and each route loads a distinct key), so the cache pays off
    /// for library callers and repeated loads, not across server startups.
    pub fn load(&self, key: &PlanKey) -> ArtifactResult<AnyPlan> {
        let generation = {
            let cache = self.cache.lock().unwrap();
            if let Some(hit) = cache.plans.get(key) {
                return Ok(hit.clone());
            }
            cache.generation
        };
        let plan = self.load_uncached(key)?;
        let mut cache = self.cache.lock().unwrap();
        // cache only if no publish invalidated while we were reading: a
        // publish that raced this load may have renamed a newer artifact
        // into place after our read, and caching the pre-publish plan
        // would pin the stale bytes on this handle forever
        if cache.generation == generation {
            cache.plans.insert(key.clone(), plan.clone());
        }
        Ok(plan)
    }

    /// Load `key`'s plan straight from disk, bypassing (and not warming)
    /// the cache — read, header-first key validation, then the full
    /// checksum + decode. A mismatched artifact (wrong tier, model, scale,
    /// method or seed) is rejected from the META section alone, before any
    /// of the multi-megabyte layer payloads are decoded. The cold-start
    /// benchmarks measure this path.
    pub fn load_uncached(&self, key: &PlanKey) -> ArtifactResult<AnyPlan> {
        let path = self.path(key);
        let bytes = std::fs::read(&path).map_err(|e| {
            if e.kind() == std::io::ErrorKind::NotFound {
                ArtifactError::Missing { path: path.clone() }
            } else {
                ArtifactError::Io { path: path.clone(), detail: e.to_string() }
            }
        })?;
        let h = codec::decode_header(&bytes)?;
        if h.precision != key.precision {
            return Err(ArtifactError::PrecisionMismatch {
                artifact: h.precision,
                requested: key.precision,
            });
        }
        let checks: [(&'static str, &str, &str); 3] = [
            ("model id", &h.model_id, &key.model),
            ("scale", &h.scale, key.scale.label()),
            ("route method", &h.method, &key.method),
        ];
        for (field, artifact, requested) in checks {
            if artifact != requested {
                return Err(ArtifactError::KeyMismatch {
                    field,
                    artifact: artifact.to_string(),
                    requested: requested.to_string(),
                });
            }
        }
        if h.seed != key.seed {
            return Err(ArtifactError::KeyMismatch {
                field: "weight seed",
                artifact: h.seed.to_string(),
                requested: key.seed.to_string(),
            });
        }
        Ok(AnyPlan::from(codec::decode(&bytes)?.payload))
    }

    /// Move `key`'s artifact aside to `<file>.quarantined` (atomic
    /// same-directory rename, replacing any previous quarantine for the
    /// slot) and log why. Called when an artifact **exists but is
    /// unusable** — corrupt bytes, stale format, a plan that no longer
    /// matches the zoo — so the broken file stops failing on every boot
    /// yet stays on disk for post-mortem instead of being silently
    /// overwritten by the fallback republish. Returns `true` if a file was
    /// actually moved. The cache entry (if any) is dropped and the publish
    /// generation bumped, exactly like a publish.
    pub fn quarantine(&self, key: &PlanKey, reason: &str) -> bool {
        let path = self.path(key);
        let mut quarantined = path.clone();
        quarantined.set_file_name(format!("{}.quarantined", key.file_name()));
        let moved = std::fs::rename(&path, &quarantined).is_ok();
        if moved {
            eprintln!(
                "plan store: quarantined {} -> {} ({reason})",
                path.display(),
                quarantined.display()
            );
            let mut cache = self.cache.lock().unwrap_or_else(|e| e.into_inner());
            cache.plans.remove(key);
            cache.generation += 1;
        }
        moved
    }

    /// Publish a compiled plan under `key`: encode, write to a temporary
    /// file in the destination directory, then atomically rename into
    /// place. Returns the artifact's final path. The plan's precision must
    /// match `key.precision` (the one mistake this API could silently
    /// invert is rejected as [`ArtifactError::PrecisionMismatch`]).
    pub fn publish<E: Elem>(&self, key: &PlanKey, plan: &ModelPlan<E>) -> ArtifactResult<PathBuf> {
        if E::PRECISION != key.precision {
            return Err(ArtifactError::PrecisionMismatch {
                artifact: E::PRECISION,
                requested: key.precision,
            });
        }
        if model_id(&plan.model) != key.model {
            return Err(ArtifactError::KeyMismatch {
                field: "model id",
                artifact: model_id(&plan.model),
                requested: key.model.clone(),
            });
        }
        let meta = ArtifactMeta {
            scale: key.scale.label().to_string(),
            method: key.method.clone(),
            seed: key.seed,
        };
        let bytes = codec::encode(plan, &meta);
        let path = self.path(key);
        atomic_write(&path, &bytes).map_err(|e| ArtifactError::Io {
            path: path.clone(),
            detail: e.to_string(),
        })?;
        // drop any cached plan for this key — and bump the generation so a
        // load whose file read raced this publish declines to cache — so a
        // handle that loaded before the publish can never keep serving the
        // pre-publish bytes
        let mut cache = self.cache.lock().unwrap();
        cache.plans.remove(key);
        cache.generation += 1;
        Ok(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::plan::{PlanOptions, Planner, Select};
    use crate::gan::workload::Method;
    use crate::gan::zoo::{self, Scale};

    fn temp_store(tag: &str) -> PlanStore {
        let dir = std::env::temp_dir().join(format!("wingan_store_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        PlanStore::open(dir)
    }

    fn key(precision: Precision) -> PlanKey {
        PlanKey::new("dcgan", Scale::Tiny, precision, "winograd", 7)
    }

    fn plan() -> ModelPlan {
        Planner::default().compile_seeded(&zoo::dcgan(Scale::Tiny), 7)
    }

    #[test]
    fn publish_then_load_roundtrips_both_tiers() {
        let store = temp_store("roundtrip");
        let p = plan();
        let k64 = key(Precision::F64);
        let path = store.publish(&k64, &p).unwrap();
        assert!(path.ends_with("tiny/dcgan.winograd.f64.plan"));
        let loaded = store.load(&k64).unwrap();
        assert_eq!(loaded.precision(), Precision::F64);
        assert_eq!(loaded.model(), "DCGAN");
        assert_eq!(loaded.input_shape(), p.input_shape);
        assert_eq!(loaded.n_layers(), p.layers.len());

        let k32 = key(Precision::F32);
        store.publish(&k32, &p.lower::<f32>()).unwrap();
        let loaded32 = store.load(&k32).unwrap();
        assert_eq!(loaded32.precision(), Precision::F32);
        let _ = std::fs::remove_dir_all(store.root());
    }

    #[test]
    fn cache_shares_one_arc_across_loads() {
        let store = temp_store("cache");
        let k = key(Precision::F64);
        store.publish(&k, &plan()).unwrap();
        let a = store.load(&k).unwrap();
        let b = store.load(&k).unwrap();
        match (&a, &b) {
            (AnyPlan::F64(pa), AnyPlan::F64(pb)) => {
                assert!(Arc::ptr_eq(pa, pb), "cache must hand out the same allocation");
            }
            _ => panic!("wrong tier"),
        }
        assert_eq!(store.cached(), 1);
        // republishing the key invalidates the cached plan: the next load
        // re-reads the (possibly new) bytes instead of the old Arc
        store.publish(&k, &plan()).unwrap();
        assert_eq!(store.cached(), 0, "publish must invalidate the key's cache entry");
        let c = store.load(&k).unwrap();
        match (&a, &c) {
            (AnyPlan::F64(pa), AnyPlan::F64(pc)) => {
                assert!(!Arc::ptr_eq(pa, pc), "post-publish load must not reuse the old Arc");
            }
            _ => panic!("wrong tier"),
        }
        let _ = std::fs::remove_dir_all(store.root());
    }

    #[test]
    fn missing_artifact_is_typed_not_a_failure() {
        let store = temp_store("missing");
        assert!(matches!(
            store.load(&key(Precision::F64)),
            Err(ArtifactError::Missing { .. })
        ));
    }

    #[test]
    fn precision_tag_must_match_the_requested_tier() {
        let store = temp_store("precmismatch");
        let k64 = key(Precision::F64);
        store.publish(&k64, &plan()).unwrap();
        // an f64 artifact parked at the f32 key's path: the file-level
        // precision tag wins and the mismatch is typed
        let k32 = key(Precision::F32);
        std::fs::create_dir_all(store.path(&k32).parent().unwrap()).unwrap();
        std::fs::copy(store.path(&k64), store.path(&k32)).unwrap();
        assert!(matches!(
            store.load(&k32),
            Err(ArtifactError::PrecisionMismatch {
                artifact: Precision::F64,
                requested: Precision::F32
            })
        ));
        let _ = std::fs::remove_dir_all(store.root());
    }

    #[test]
    fn header_key_fields_are_validated() {
        let store = temp_store("keycheck");
        let k = key(Precision::F64);
        store.publish(&k, &plan()).unwrap();
        // same file requested under a different seed → typed key mismatch
        let wrong_seed = PlanKey { seed: 8, ..k.clone() };
        assert!(matches!(
            store.load(&wrong_seed),
            Err(ArtifactError::KeyMismatch { field: "weight seed", .. })
        ));
        // and under a different method (file copied to the tdc slot)
        let tdc_key = PlanKey { method: "tdc".into(), ..k.clone() };
        std::fs::copy(store.path(&k), store.path(&tdc_key)).unwrap();
        assert!(matches!(
            store.load(&tdc_key),
            Err(ArtifactError::KeyMismatch { field: "route method", .. })
        ));
        let _ = std::fs::remove_dir_all(store.root());
    }

    #[test]
    fn publish_rejects_tier_and_model_mismatches() {
        let store = temp_store("pubcheck");
        let p = plan();
        assert!(matches!(
            store.publish(&key(Precision::F32), &p),
            Err(ArtifactError::PrecisionMismatch { .. })
        ));
        let other = PlanKey::new("gpgan", Scale::Tiny, Precision::F64, "winograd", 7);
        assert!(matches!(
            store.publish(&other, &p),
            Err(ArtifactError::KeyMismatch { field: "model id", .. })
        ));
        // nothing was written
        assert!(matches!(
            store.load(&key(Precision::F32)),
            Err(ArtifactError::Missing { .. })
        ));
    }

    #[test]
    fn publish_overwrites_atomically_and_leaves_no_temp_files() {
        let store = temp_store("atomic");
        let k = key(Precision::F64);
        store.publish(&k, &plan()).unwrap();
        let first = std::fs::metadata(store.path(&k)).unwrap().len();
        // republish (e.g. a recompile with identical inputs): same bytes,
        // no stray temp files in the directory
        store.publish(&k, &plan()).unwrap();
        assert_eq!(std::fs::metadata(store.path(&k)).unwrap().len(), first);
        let dir = store.path(&k);
        let entries: Vec<String> = std::fs::read_dir(dir.parent().unwrap())
            .unwrap()
            .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
            .collect();
        assert!(entries.iter().all(|n| !n.contains(".tmp.")), "{entries:?}");
        let _ = std::fs::remove_dir_all(store.root());
    }

    #[test]
    fn quarantine_moves_the_artifact_aside_and_invalidates_the_cache() {
        let store = temp_store("quarantine");
        let k = key(Precision::F64);
        store.publish(&k, &plan()).unwrap();
        store.load(&k).unwrap();
        assert_eq!(store.cached(), 1);
        assert!(store.quarantine(&k, "checksum mismatch in test"));
        assert!(!store.path(&k).exists(), "original slot must be empty");
        let q = store.path(&k).with_file_name("dcgan.winograd.f64.plan.quarantined");
        assert!(q.exists(), "quarantined file must exist at {q:?}");
        assert_eq!(store.cached(), 0, "quarantine must drop the cached plan");
        assert!(matches!(store.load(&k), Err(ArtifactError::Missing { .. })));
        // quarantining an already-empty slot is a quiet no-op
        assert!(!store.quarantine(&k, "again"));
        // a second quarantine after a republish replaces the parked file
        store.publish(&k, &plan()).unwrap();
        assert!(store.quarantine(&k, "second"));
        assert!(q.exists());
        let _ = std::fs::remove_dir_all(store.root());
    }

    #[test]
    fn tdc_plans_store_under_their_route_method() {
        let store = temp_store("tdcroute");
        let planner = Planner::new(PlanOptions {
            select: Select::Force(Method::Tdc),
            ..Default::default()
        });
        let p = planner.compile_seeded(&zoo::gpgan(Scale::Tiny), 7);
        let k = PlanKey::new("GP-GAN", Scale::Tiny, Precision::F64, "tdc", 7);
        assert_eq!(k.model, "gpgan", "PlanKey::new normalizes model ids");
        let path = store.publish(&k, &p).unwrap();
        assert!(path.ends_with("tiny/gpgan.tdc.f64.plan"));
        let loaded = store.load(&k).unwrap();
        assert_eq!(loaded.model(), "GP-GAN");
        let _ = std::fs::remove_dir_all(store.root());
    }
}
