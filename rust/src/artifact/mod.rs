//! Plan-artifact subsystem: versioned serialization of compiled plans, an
//! on-disk plan store, and the AOT compile → warm-serve workflow.
//!
//! Everything the paper front-loads — TDC phase decomposition, Winograd
//! `G g Gᵀ` filter transforms, sparsity reordering, DSE method selection —
//! lands in a [`crate::engine::ModelPlan`]. Before this subsystem, every
//! `wingan serve` process recompiled those plans at startup; now the
//! compiled configuration is a **persisted deployment artifact**, the way
//! the DeConv design-methodology and Winograd-DSE literature treats it:
//!
//! * [`codec`] — the self-describing binary format (magic + format version
//!   + precision tag + model metadata + checksummed payload sections),
//!   explicit little-endian, no external serde dependency. Round trips are
//!   **bit-exact** at both precision tiers: a loaded plan executes
//!   identically, bit for bit, to the plan that was published.
//! * [`store`] — [`PlanStore`]: `(model, scale, precision, method, seed)`
//!   keys → artifact files under a store root, atomic write-then-rename
//!   publishing, load-time checksum/version/key validation, and an
//!   in-process `Arc` cache so repeated loads of a key through one store
//!   handle share a single deserialized plan.
//!
//! Workflow: `wingan compile --store <dir>` AOT-compiles zoo models (both
//! serving scales, both precision tiers, both route methods) into the
//! store plus a human-readable manifest; `wingan serve --plan-store <dir>`
//! (i.e. [`crate::engine::NativeConfig::plan_store`]) makes cold start a
//! file read instead of a recompile, falling back to in-process
//! compilation — and publishing the result — for any missing or invalid
//! artifact. Warm-vs-cold behavior is observable through the plan-cache
//! counters ([`PlanCacheStats`] → [`crate::coordinator::Metrics`]), and
//! `wingan plan inspect <artifact>` prints one artifact's manifest view.

pub mod codec;
pub mod store;

pub use codec::{
    decode, decode_header, describe, encode, fnv1a64, ArtifactError, ArtifactHeader,
    ArtifactMeta, ArtifactResult, DecodedArtifact, PlanPayload, SectionInfo, FORMAT_VERSION,
    MAGIC, MIN_FORMAT_VERSION,
};
pub use store::{atomic_write, read_generation, AnyPlan, PlanCacheStats, PlanKey, PlanStore};
