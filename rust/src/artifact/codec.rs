//! Self-describing binary codec for compiled plans — format v2 (reads v1).
//!
//! The paper's whole pipeline is ahead-of-time: phase decomposition,
//! `G g Gᵀ` filter transforms, sparsity reordering and DSE method selection
//! all finish before the first request. This codec makes that work a
//! **deployment artifact**: a [`crate::engine::ModelPlan`] (at either
//! precision tier) serializes to a versioned, checksummed byte stream that
//! round-trips **bit-exactly** — a loaded plan executes identically, bit
//! for bit, to the plan that was published (pinned by the round-trip
//! proptests across the zoo).
//!
//! # Wire format (all integers little-endian)
//!
//! ```text
//! [8]  magic  "WGANPLAN"
//! [4]  u32    format version (currently 2; v1 still decodes)
//! [1]  u8     precision tag  (1 = f32, 2 = f64)
//! then one META section followed by exactly `layer_count` LAYR sections:
//!   [4]  u32  section tag ("META" / "LAYR" as LE ASCII)
//!   [8]  u64  payload byte length
//!   [..]      payload
//!   [8]  u64  FNV-1a 64 checksum of the payload
//! ```
//!
//! **v2 additions** (absent from v1 payloads): each LAYR section carries a
//! one-byte GEMM micro-kernel tag (0 = scalar, 1 = simd) right after the
//! tile-geometry words, and each reordered slab carries its runtime
//! zero-skip run-list ([`crate::winograd::kernel::RunList`]) — a one-byte
//! presence flag, then the block-offset and run arrays. Decoding **v1**
//! artifacts re-derives both: the kernel resolves from the loading host's
//! capability probe and the run-lists rebuild from the decoded slab
//! weights, so old artifacts execute on the new dispatched datapath
//! unchanged. Decoding **v2** rebuilds the run-lists too and rejects any
//! artifact whose stored lists disagree with the rebuild — a stale or
//! tampered skip section can never elide live products. A v2 kernel tag of
//! `simd` on a host without AVX2/NEON quietly clamps to `scalar` (the plan
//! is otherwise identical; the tag only picks the dispatch route).
//!
//! The META payload carries the model/deployment metadata (model name +
//! route id, zoo scale, route method, weight seed, input/output shapes,
//! layer count); each LAYR payload carries one complete
//! [`crate::engine::LayerPlan`] — layer geometry + activation, the compiled
//! method decision, raw weights, the TDC phase filter bank, the reordered
//! Winograd slabs with their live-position lists, tile/line-buffer
//! geometry. Scalar words are written at the plan's native width (4 bytes
//! f32 / 8 bytes f64), so the f32 tier's artifacts are half the size —
//! the same bandwidth story as the serving fast path.
//!
//! # Safety contract
//!
//! [`decode`] never panics on hostile bytes: every read is bounds-checked
//! ([`ArtifactError::Truncated`]), every section is checksummed
//! ([`ArtifactError::ChecksumMismatch`]), every enum tag and every
//! structural invariant the execution engine relies on (weight-bank shapes,
//! live positions `< 16`, reordered-slab lengths, tile geometry) is
//! validated ([`ArtifactError::Malformed`]). No external serde dependency —
//! the build stays offline.

use crate::engine::plan::{LayerPlan, ModelPlan, TileGeometry};
use crate::engine::serve::model_id;
use crate::gan::workload::Method;
use crate::gan::zoo::{Activation, Kind, Layer};
use crate::tdc::{self, PhaseFilter};
use crate::util::elem::{Elem, Precision};
use crate::util::tensor::Filter4;
use crate::winograd::kernel::{simd_available, KernelKind, RunList};
use crate::winograd::layout::ReorderedFilter;
use crate::winograd::sparsity::Case;
use crate::winograd::transforms::M as M_TILE;
use std::fmt;
use std::path::PathBuf;

/// Leading file magic: identifies a wingan plan artifact.
pub const MAGIC: [u8; 8] = *b"WGANPLAN";
/// Current on-disk format version — what [`encode`] writes. Bump on any
/// wire-format change; readers accept
/// [`MIN_FORMAT_VERSION`]`..=FORMAT_VERSION` and reject everything else
/// with [`ArtifactError::UnsupportedVersion`] (see README "Artifacts &
/// cold start" for the versioning policy).
pub const FORMAT_VERSION: u32 = 2;
/// Oldest format version this build still decodes (v1: no kernel tags, no
/// zero-skip sections — both re-derived at load time).
pub const MIN_FORMAT_VERSION: u32 = 1;

/// Section tag for the model-metadata section ("META" as LE ASCII).
const TAG_META: u32 = u32::from_le_bytes(*b"META");
/// Section tag for a per-layer plan section ("LAYR" as LE ASCII).
const TAG_LAYER: u32 = u32::from_le_bytes(*b"LAYR");

/// Sanity cap on the declared layer count — no zoo generator comes close;
/// anything larger is a corrupt or hostile header, not a model.
const MAX_LAYERS: usize = 4096;
/// Sanity cap on channel counts and spatial extents (paper scale tops out
/// at 1024 channels / 64 pixels; 2²⁰ leaves generous headroom while
/// keeping every derived product far from overflow).
const MAX_EXTENT: usize = 1 << 20;
/// Sanity cap on kernel width (paper kernels are 3–5).
const MAX_KERNEL: usize = 512;
/// Sanity cap on stride — also bounds the phase count `S²`, so a hostile
/// stride can never drive a pre-payload allocation.
const MAX_STRIDE: usize = 64;

/// Typed error for every way loading a plan artifact can fail. The serving
/// path treats [`ArtifactError::Missing`] as a cold store (silent fallback
/// to in-process compilation) and every other variant as a load failure
/// (counted, logged, then the same clean fallback).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ArtifactError {
    /// No artifact file at the key's path — a cold store, not a failure.
    Missing {
        /// the path that was probed
        path: PathBuf,
    },
    /// Filesystem error other than not-found while reading or publishing.
    Io {
        /// the path being read or written
        path: PathBuf,
        /// the rendered `std::io::Error`
        detail: String,
    },
    /// The file does not start with [`MAGIC`] — not a plan artifact.
    BadMagic {
        /// the first 8 bytes found instead
        found: [u8; 8],
    },
    /// The artifact was written by an incompatible format version.
    UnsupportedVersion {
        /// the version tag in the file
        found: u32,
    },
    /// The byte stream ended before a declared structure completed.
    Truncated {
        /// what was being read when the bytes ran out
        context: String,
    },
    /// A section's payload does not match its stored FNV-1a checksum.
    ChecksumMismatch {
        /// the section whose checksum failed ("META", "LAYR[i]")
        section: String,
    },
    /// The artifact carries a different precision tier than requested.
    PrecisionMismatch {
        /// the tier tagged in the file
        artifact: Precision,
        /// the tier the store key asked for
        requested: Precision,
    },
    /// A header field disagrees with the store key used to load it
    /// (model id, scale, method or weight seed).
    KeyMismatch {
        /// which header field mismatched
        field: &'static str,
        /// the value in the artifact
        artifact: String,
        /// the value the key requested
        requested: String,
    },
    /// Structurally invalid payload (bad enum tag, inconsistent shapes,
    /// trailing bytes, ...).
    Malformed {
        /// human-readable description of the violation
        detail: String,
    },
}

impl fmt::Display for ArtifactError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ArtifactError::Missing { path } => {
                write!(f, "no plan artifact at {}", path.display())
            }
            ArtifactError::Io { path, detail } => {
                write!(f, "plan artifact io error at {}: {detail}", path.display())
            }
            ArtifactError::BadMagic { found } => {
                write!(f, "not a plan artifact (magic {found:02x?})")
            }
            ArtifactError::UnsupportedVersion { found } => {
                write!(
                    f,
                    "unsupported plan-artifact format version {found} (this build reads \
                     v{MIN_FORMAT_VERSION}..=v{FORMAT_VERSION})"
                )
            }
            ArtifactError::Truncated { context } => {
                write!(f, "plan artifact truncated while reading {context}")
            }
            ArtifactError::ChecksumMismatch { section } => {
                write!(f, "plan artifact checksum mismatch in section {section}")
            }
            ArtifactError::PrecisionMismatch { artifact, requested } => {
                write!(f, "plan artifact is {artifact}, but {requested} was requested")
            }
            ArtifactError::KeyMismatch { field, artifact, requested } => {
                write!(f, "plan artifact {field} is '{artifact}', but the store key says '{requested}'")
            }
            ArtifactError::Malformed { detail } => {
                write!(f, "malformed plan artifact: {detail}")
            }
        }
    }
}

impl std::error::Error for ArtifactError {}

/// Shorthand result for codec/store operations.
pub type ArtifactResult<T> = Result<T, ArtifactError>;

/// FNV-1a 64-bit checksum (the section integrity check: fast, dependency
/// free, and plenty for detecting torn writes and bit rot — artifacts are
/// trusted local files, not an authentication boundary).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

// ---------------------------------------------------------------------------
// little-endian writer primitives
// ---------------------------------------------------------------------------

fn put_u8(out: &mut Vec<u8>, v: u8) {
    out.push(v);
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_usize(out: &mut Vec<u8>, v: usize) {
    put_u64(out, v as u64);
}

fn put_i64(out: &mut Vec<u8>, v: i64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    put_u64(out, s.len() as u64);
    out.extend_from_slice(s.as_bytes());
}

fn put_elems<E: Elem>(out: &mut Vec<u8>, data: &[E]) {
    out.reserve(data.len() * E::PRECISION.word_bytes());
    for &v in data {
        v.write_le(out);
    }
}

fn put_filter<E: Elem>(out: &mut Vec<u8>, f: &Filter4<E>) {
    put_usize(out, f.c_in);
    put_usize(out, f.c_out);
    put_usize(out, f.kh);
    put_usize(out, f.kw);
    put_elems(out, &f.data);
}

fn put_section(out: &mut Vec<u8>, tag: u32, payload: &[u8]) {
    put_u32(out, tag);
    put_u64(out, payload.len() as u64);
    out.extend_from_slice(payload);
    put_u64(out, fnv1a64(payload));
}

// ---------------------------------------------------------------------------
// bounds-checked reader
// ---------------------------------------------------------------------------

/// Cursor over an artifact byte buffer. Every read is bounds-checked and
/// returns a typed error instead of panicking — the whole no-panic
/// guarantee of [`decode`] rests on this type.
struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(buf: &'a [u8]) -> Reader<'a> {
        Reader { buf, pos: 0 }
    }

    fn take(&mut self, n: usize, context: &str) -> ArtifactResult<&'a [u8]> {
        let end = self.pos.checked_add(n).filter(|&e| e <= self.buf.len()).ok_or_else(|| {
            ArtifactError::Truncated { context: context.to_string() }
        })?;
        let out = &self.buf[self.pos..end];
        self.pos = end;
        Ok(out)
    }

    fn u8(&mut self, context: &str) -> ArtifactResult<u8> {
        Ok(self.take(1, context)?[0])
    }

    fn u32(&mut self, context: &str) -> ArtifactResult<u32> {
        let b = self.take(4, context)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self, context: &str) -> ArtifactResult<u64> {
        let b = self.take(8, context)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    fn i64(&mut self, context: &str) -> ArtifactResult<i64> {
        Ok(self.u64(context)? as i64)
    }

    fn usize(&mut self, context: &str) -> ArtifactResult<usize> {
        usize::try_from(self.u64(context)?).map_err(|_| ArtifactError::Malformed {
            detail: format!("{context}: value exceeds this platform's usize"),
        })
    }

    fn string(&mut self, context: &str) -> ArtifactResult<String> {
        let len = self.usize(context)?;
        let bytes = self.take(len, context)?;
        String::from_utf8(bytes.to_vec()).map_err(|_| ArtifactError::Malformed {
            detail: format!("{context}: string is not valid UTF-8"),
        })
    }

    /// Read `count` scalar words at `E`'s native width. The byte length is
    /// computed with checked arithmetic and bounds-checked *before* any
    /// allocation, so a hostile count cannot trigger an allocation bomb.
    fn elems<E: Elem>(&mut self, count: usize, context: &str) -> ArtifactResult<Vec<E>> {
        let word = E::PRECISION.word_bytes();
        let n = count.checked_mul(word).ok_or_else(|| ArtifactError::Malformed {
            detail: format!("{context}: element count overflows"),
        })?;
        let bytes = self.take(n, context)?;
        Ok(bytes.chunks_exact(word).map(E::from_le).collect())
    }

    fn filter<E: Elem>(&mut self, context: &str) -> ArtifactResult<Filter4<E>> {
        let c_in = self.usize(context)?;
        let c_out = self.usize(context)?;
        let kh = self.usize(context)?;
        let kw = self.usize(context)?;
        let numel = c_in
            .checked_mul(c_out)
            .and_then(|v| v.checked_mul(kh))
            .and_then(|v| v.checked_mul(kw))
            .ok_or_else(|| ArtifactError::Malformed {
                detail: format!("{context}: filter shape overflows"),
            })?;
        let data = self.elems::<E>(numel, context)?;
        Ok(Filter4 { c_in, c_out, kh, kw, data })
    }

    fn done(&self) -> bool {
        self.pos == self.buf.len()
    }
}

fn read_section<'a>(r: &mut Reader<'a>, want: u32, name: &str) -> ArtifactResult<&'a [u8]> {
    let tag = r.u32(&format!("{name} section tag"))?;
    if tag != want {
        return Err(ArtifactError::Malformed {
            detail: format!("expected {name} section, found tag {tag:#010x}"),
        });
    }
    let len = r.usize(&format!("{name} section length"))?;
    let payload = r.take(len, &format!("{name} section payload"))?;
    let stored = r.u64(&format!("{name} section checksum"))?;
    if stored != fnv1a64(payload) {
        return Err(ArtifactError::ChecksumMismatch { section: name.to_string() });
    }
    Ok(payload)
}

// ---------------------------------------------------------------------------
// enum tags
// ---------------------------------------------------------------------------

fn precision_tag(p: Precision) -> u8 {
    match p {
        Precision::F32 => 1,
        Precision::F64 => 2,
    }
}

fn precision_from_tag(t: u8) -> ArtifactResult<Precision> {
    match t {
        1 => Ok(Precision::F32),
        2 => Ok(Precision::F64),
        other => Err(ArtifactError::Malformed { detail: format!("unknown precision tag {other}") }),
    }
}

fn kind_tag(k: Kind) -> u8 {
    match k {
        Kind::Deconv => 0,
        Kind::Conv => 1,
    }
}

fn kind_from_tag(t: u8) -> ArtifactResult<Kind> {
    match t {
        0 => Ok(Kind::Deconv),
        1 => Ok(Kind::Conv),
        other => Err(ArtifactError::Malformed { detail: format!("unknown layer kind tag {other}") }),
    }
}

fn act_tag(a: Activation) -> u8 {
    match a {
        Activation::Linear => 0,
        Activation::Relu => 1,
        Activation::LeakyRelu => 2,
        Activation::Tanh => 3,
    }
}

fn act_from_tag(t: u8) -> ArtifactResult<Activation> {
    match t {
        0 => Ok(Activation::Linear),
        1 => Ok(Activation::Relu),
        2 => Ok(Activation::LeakyRelu),
        3 => Ok(Activation::Tanh),
        other => Err(ArtifactError::Malformed { detail: format!("unknown activation tag {other}") }),
    }
}

fn method_tag(m: Method) -> u8 {
    match m {
        Method::ZeroPadded => 0,
        Method::Tdc => 1,
        Method::Winograd => 2,
    }
}

fn method_from_tag(t: u8) -> ArtifactResult<Method> {
    match t {
        0 => Ok(Method::ZeroPadded),
        1 => Ok(Method::Tdc),
        2 => Ok(Method::Winograd),
        other => Err(ArtifactError::Malformed { detail: format!("unknown method tag {other}") }),
    }
}

fn case_tag(c: Case) -> u8 {
    c.number() as u8
}

fn case_from_tag(t: u8) -> ArtifactResult<Case> {
    match t {
        0 => Ok(Case::Empty),
        1 => Ok(Case::Dense),
        2 => Ok(Case::OneLine),
        3 => Ok(Case::TwoLines),
        other => Err(ArtifactError::Malformed { detail: format!("unknown sparsity case tag {other}") }),
    }
}

fn kernel_tag(k: KernelKind) -> u8 {
    match k {
        KernelKind::Scalar => 0,
        KernelKind::Simd => 1,
    }
}

fn kernel_from_tag(t: u8) -> ArtifactResult<KernelKind> {
    match t {
        0 => Ok(KernelKind::Scalar),
        1 => Ok(KernelKind::Simd),
        other => Err(ArtifactError::Malformed { detail: format!("unknown kernel tag {other}") }),
    }
}

// ---------------------------------------------------------------------------
// encode
// ---------------------------------------------------------------------------

/// Deployment metadata stored in the artifact's META section alongside
/// what the plan itself carries (the store key's non-derivable half).
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    /// zoo scale label the plan was compiled at (`"tiny"` / `"small"` / ...)
    pub scale: String,
    /// serving route method the plan was compiled for (`"winograd"` /
    /// `"tdc"` — i.e. which [`crate::engine::Select`] policy produced it)
    pub method: String,
    /// deterministic weight seed the plan was compiled from
    pub seed: u64,
}

/// Serialize a compiled plan (at its native precision tier) plus its
/// deployment metadata into the current-format byte stream. Every scalar
/// word is written little-endian at `E`'s width; [`decode`] restores it
/// bit-exactly.
pub fn encode<E: Elem>(plan: &ModelPlan<E>, meta: &ArtifactMeta) -> Vec<u8> {
    encode_with_version(plan, meta, FORMAT_VERSION)
}

/// Versioned encoder: `version` selects the wire layout (v1 omits the
/// kernel tags and zero-skip sections). Only [`FORMAT_VERSION`] is written
/// in production; older layouts stay encodable so the back-compat decode
/// path is testable without fixture files.
fn encode_with_version<E: Elem>(plan: &ModelPlan<E>, meta: &ArtifactMeta, version: u32) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(&MAGIC);
    put_u32(&mut out, version);
    put_u8(&mut out, precision_tag(E::PRECISION));

    let mut m = Vec::new();
    put_str(&mut m, &plan.model);
    put_str(&mut m, &model_id(&plan.model));
    put_str(&mut m, &meta.scale);
    put_str(&mut m, &meta.method);
    put_u64(&mut m, meta.seed);
    for v in [plan.input_shape.0, plan.input_shape.1, plan.input_shape.2] {
        put_usize(&mut m, v);
    }
    for v in [plan.output_shape.0, plan.output_shape.1, plan.output_shape.2] {
        put_usize(&mut m, v);
    }
    put_usize(&mut m, plan.layers.len());
    put_section(&mut out, TAG_META, &m);

    for lp in &plan.layers {
        let payload = encode_layer(lp, version);
        put_section(&mut out, TAG_LAYER, &payload);
    }
    out
}

fn encode_layer<E: Elem>(lp: &LayerPlan<E>, version: u32) -> Vec<u8> {
    let mut p = Vec::new();
    let l = &lp.layer;
    put_u8(&mut p, kind_tag(l.kind));
    for v in [l.c_in, l.c_out, l.k, l.s, l.p, l.h_in, l.w_in] {
        put_usize(&mut p, v);
    }
    put_u8(&mut p, act_tag(l.act));
    put_u8(&mut p, method_tag(lp.method));
    put_usize(&mut p, lp.kc);
    for v in [lp.tiles.ho_t, lp.tiles.wo_t, lp.tiles.tiles_h, lp.tiles.tiles_w] {
        put_usize(&mut p, v);
    }
    if version >= 2 {
        // non-winograd layers carry the default (scalar, tag 0): the tag
        // only steers the winograd GEMM dispatch
        put_u8(&mut p, kernel_tag(lp.tiles.kernel));
    }
    put_usize(&mut p, lp.linebuf_depth);
    put_usize(&mut p, lp.linebuf_words);
    put_filter(&mut p, &lp.weights);
    put_usize(&mut p, lp.phases.len());
    for ph in &lp.phases {
        put_filter(&mut p, &ph.g);
        put_i64(&mut p, ph.d0y as i64);
        put_i64(&mut p, ph.d0x as i64);
        put_usize(&mut p, ph.ry);
        put_usize(&mut p, ph.rx);
    }
    put_usize(&mut p, lp.reordered.len());
    for rf in &lp.reordered {
        put_u8(&mut p, case_tag(rf.case));
        put_usize(&mut p, rf.live.len());
        for &pos in &rf.live {
            put_usize(&mut p, pos);
        }
        put_usize(&mut p, rf.c_in);
        put_usize(&mut p, rf.c_out);
        put_elems(&mut p, &rf.u);
        put_i64(&mut p, rf.d0y as i64);
        put_i64(&mut p, rf.d0x as i64);
        if version >= 2 {
            match &rf.skip {
                None => put_u8(&mut p, 0),
                Some(sk) => {
                    put_u8(&mut p, 1);
                    put_usize(&mut p, sk.offsets.len());
                    for &o in &sk.offsets {
                        put_u32(&mut p, o);
                    }
                    put_usize(&mut p, sk.runs.len());
                    for &(s, e) in &sk.runs {
                        put_u32(&mut p, s);
                        put_u32(&mut p, e);
                    }
                }
            }
        }
    }
    p
}

// ---------------------------------------------------------------------------
// decode
// ---------------------------------------------------------------------------

/// The parsed artifact header — everything [`decode`] learned before (and
/// about) the plan payload. `plan inspect` renders this; the store
/// validates it against the requested key.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ArtifactHeader {
    /// on-disk format version (within
    /// [`MIN_FORMAT_VERSION`]`..=`[`FORMAT_VERSION`] after a successful
    /// decode)
    pub version: u32,
    /// precision tier of every scalar word in the payload
    pub precision: Precision,
    /// zoo model name (e.g. `"DCGAN"`)
    pub model: String,
    /// route/model id (e.g. `"dcgan"`, matching the serving manifest)
    pub model_id: String,
    /// zoo scale label the plan was compiled at
    pub scale: String,
    /// serving route method the plan was compiled for
    pub method: String,
    /// deterministic weight seed the plan was compiled from
    pub seed: u64,
    /// `[C, H, W]` of one input sample
    pub input_shape: (usize, usize, usize),
    /// `[C, H, W]` of one output sample
    pub output_shape: (usize, usize, usize),
    /// number of per-layer sections (== decoded plan layers)
    pub layers: usize,
}

/// Size record for one decoded section (`plan inspect` reports these as
/// the artifact's payload budget).
#[derive(Clone, Debug)]
pub struct SectionInfo {
    /// section name ("META", "LAYR[i]")
    pub name: String,
    /// payload bytes (excluding the tag/length/checksum framing)
    pub bytes: usize,
}

/// A decoded plan at whichever precision tier the artifact was tagged
/// with. The store wraps this in `Arc` ([`crate::artifact::AnyPlan`]) for
/// sharing across routes.
#[derive(Clone, Debug)]
pub enum PlanPayload {
    /// single-precision (serving fast tier) plan
    F32(ModelPlan<f32>),
    /// double-precision (reference tier) plan
    F64(ModelPlan<f64>),
}

impl PlanPayload {
    /// The precision tier of the decoded plan.
    pub fn precision(&self) -> Precision {
        match self {
            PlanPayload::F32(_) => Precision::F32,
            PlanPayload::F64(_) => Precision::F64,
        }
    }
}

/// A fully decoded artifact: header, plan, and per-section byte sizes.
#[derive(Clone, Debug)]
pub struct DecodedArtifact {
    /// the parsed header/metadata
    pub header: ArtifactHeader,
    /// the plan, at the artifact's tagged precision
    pub payload: PlanPayload,
    /// per-section payload sizes, in file order (META first)
    pub sections: Vec<SectionInfo>,
}

/// Parse the prologue: magic, format version, precision tag.
fn decode_prologue(r: &mut Reader<'_>) -> ArtifactResult<(u32, Precision)> {
    let magic = r.take(MAGIC.len(), "magic")?;
    if magic != MAGIC {
        let mut found = [0u8; 8];
        found.copy_from_slice(magic);
        return Err(ArtifactError::BadMagic { found });
    }
    let version = r.u32("format version")?;
    if !(MIN_FORMAT_VERSION..=FORMAT_VERSION).contains(&version) {
        return Err(ArtifactError::UnsupportedVersion { found: version });
    }
    let precision = precision_from_tag(r.u8("precision tag")?)?;
    Ok((version, precision))
}

/// Parse the checksummed META section into a header; returns the header
/// plus the META payload's byte length (for section accounting).
fn decode_meta(
    r: &mut Reader<'_>,
    version: u32,
    precision: Precision,
) -> ArtifactResult<(ArtifactHeader, usize)> {
    let meta = read_section(r, TAG_META, "META")?;
    let mut mr = Reader::new(meta);
    let model = mr.string("model name")?;
    let model_id_field = mr.string("model id")?;
    let scale = mr.string("scale label")?;
    let method = mr.string("route method")?;
    let seed = mr.u64("weight seed")?;
    let input_shape =
        (mr.usize("input C")?, mr.usize("input H")?, mr.usize("input W")?);
    let output_shape =
        (mr.usize("output C")?, mr.usize("output H")?, mr.usize("output W")?);
    let layer_count = mr.usize("layer count")?;
    if !mr.done() {
        return Err(ArtifactError::Malformed { detail: "trailing bytes in META section".into() });
    }
    if layer_count == 0 || layer_count > MAX_LAYERS {
        return Err(ArtifactError::Malformed {
            detail: format!("implausible layer count {layer_count}"),
        });
    }
    let header = ArtifactHeader {
        version,
        precision,
        model,
        model_id: model_id_field,
        scale,
        method,
        seed,
        input_shape,
        output_shape,
        layers: layer_count,
    };
    Ok((header, meta.len()))
}

/// Decode only the header (prologue + checksummed META section), without
/// touching the — potentially multi-megabyte — layer payloads. The store
/// validates keys against this before paying for a full [`decode`], so a
/// mismatched artifact is rejected near-free.
pub fn decode_header(bytes: &[u8]) -> ArtifactResult<ArtifactHeader> {
    let mut r = Reader::new(bytes);
    let (version, precision) = decode_prologue(&mut r)?;
    Ok(decode_meta(&mut r, version, precision)?.0)
}

/// Decode a plan artifact from its byte stream. Never panics: corrupt or
/// hostile input yields a typed [`ArtifactError`] (see the module docs for
/// the validation contract).
pub fn decode(bytes: &[u8]) -> ArtifactResult<DecodedArtifact> {
    let mut r = Reader::new(bytes);
    let (version, precision) = decode_prologue(&mut r)?;
    let (header, meta_len) = decode_meta(&mut r, version, precision)?;
    match precision {
        Precision::F32 => {
            let (plan, sections) = decode_layers::<f32>(&mut r, &header, meta_len)?;
            Ok(DecodedArtifact { header, payload: PlanPayload::F32(plan), sections })
        }
        Precision::F64 => {
            let (plan, sections) = decode_layers::<f64>(&mut r, &header, meta_len)?;
            Ok(DecodedArtifact { header, payload: PlanPayload::F64(plan), sections })
        }
    }
}

fn decode_layers<E: Elem>(
    r: &mut Reader<'_>,
    header: &ArtifactHeader,
    meta_len: usize,
) -> ArtifactResult<(ModelPlan<E>, Vec<SectionInfo>)> {
    let mut sections = vec![SectionInfo { name: "META".into(), bytes: meta_len }];
    let mut layers = Vec::with_capacity(header.layers);
    for i in 0..header.layers {
        let name = format!("LAYR[{i}]");
        let payload = read_section(r, TAG_LAYER, &name)?;
        let mut lr = Reader::new(payload);
        let lp = decode_layer::<E>(&mut lr, i, header.version)?;
        if !lr.done() {
            return Err(ArtifactError::Malformed {
                detail: format!("trailing bytes in layer {i} section"),
            });
        }
        sections.push(SectionInfo { name, bytes: payload.len() });
        layers.push(lp);
    }
    if !r.done() {
        return Err(ArtifactError::Malformed {
            detail: "trailing data after the last section".into(),
        });
    }

    let (input_shape, output_shape) = (header.input_shape, header.output_shape);
    let plan = ModelPlan { model: header.model.clone(), layers, input_shape, output_shape };
    // the full layer-to-layer shape chain the engine walks — rejected at
    // load time so a checksummed-but-inconsistent artifact can never index
    // out of bounds (or panic) on the serving path
    let mut cur = input_shape;
    for (i, lp) in plan.layers.iter().enumerate() {
        let l = &lp.layer;
        if (l.c_in, l.h_in, l.w_in) != cur {
            return Err(ArtifactError::Malformed {
                detail: format!(
                    "layer {i} input geometry ({}, {}, {}) breaks the shape chain (expected \
                     ({}, {}, {}))",
                    l.c_in, l.h_in, l.w_in, cur.0, cur.1, cur.2
                ),
            });
        }
        if l.kind == Kind::Conv {
            // the conv datapath derives its output extent from (K, S, P);
            // it must agree with the declared h_out/w_out the chain uses
            if l.h_in + 2 * l.p < l.k
                || l.w_in + 2 * l.p < l.k
                || (l.h_in + 2 * l.p - l.k) / l.s + 1 != l.h_out()
                || (l.w_in + 2 * l.p - l.k) / l.s + 1 != l.w_out()
            {
                return Err(ArtifactError::Malformed {
                    detail: format!("layer {i}: conv geometry is inconsistent"),
                });
            }
        }
        cur = (l.c_out, l.h_out(), l.w_out());
    }
    if cur != output_shape {
        return Err(ArtifactError::Malformed {
            detail: format!(
                "declared output shape ({}, {}, {}) disagrees with the layer chain's \
                 ({}, {}, {})",
                output_shape.0, output_shape.1, output_shape.2, cur.0, cur.1, cur.2
            ),
        });
    }
    Ok((plan, sections))
}

fn decode_layer<E: Elem>(
    r: &mut Reader<'_>,
    i: usize,
    version: u32,
) -> ArtifactResult<LayerPlan<E>> {
    let bad = |detail: String| ArtifactError::Malformed { detail: format!("layer {i}: {detail}") };

    let kind = kind_from_tag(r.u8("layer kind")?)?;
    let c_in = r.usize("layer c_in")?;
    let c_out = r.usize("layer c_out")?;
    let k = r.usize("layer k")?;
    let s = r.usize("layer s")?;
    let p = r.usize("layer p")?;
    let h_in = r.usize("layer h_in")?;
    let w_in = r.usize("layer w_in")?;
    let act = act_from_tag(r.u8("layer activation")?)?;
    let layer = Layer { kind, c_in, c_out, k, s, p, h_in, w_in, act };
    if c_in == 0 || c_out == 0 || k == 0 || s == 0 || h_in == 0 || w_in == 0 {
        return Err(bad("zero-sized layer geometry".into()));
    }
    // geometry sanity caps: everything derived below (S² phase counts,
    // tile geometry, output extents) stays far from usize overflow and no
    // hostile header can drive a large pre-payload allocation
    if c_in > MAX_EXTENT || c_out > MAX_EXTENT || h_in > MAX_EXTENT || w_in > MAX_EXTENT {
        return Err(bad("implausible channel/spatial extent".into()));
    }
    if k > MAX_KERNEL || s > MAX_STRIDE || p >= k {
        return Err(bad(format!("implausible kernel geometry K={k} S={s} P={p}")));
    }

    let method = method_from_tag(r.u8("layer method")?)?;
    let kc = r.usize("layer kc")?;
    let mut tiles = TileGeometry {
        ho_t: r.usize("tiles ho_t")?,
        wo_t: r.usize("tiles wo_t")?,
        tiles_h: r.usize("tiles tiles_h")?,
        tiles_w: r.usize("tiles tiles_w")?,
        ..TileGeometry::default()
    };
    tiles.kernel = if version >= 2 {
        // a simd tag from a capable publishing host clamps to scalar on a
        // host without AVX2/NEON — the tag only picks the dispatch route,
        // the plan data is identical either way
        let k = kernel_from_tag(r.u8("kernel tag")?)?;
        if k == KernelKind::Simd && !simd_available() { KernelKind::Scalar } else { k }
    } else if method == Method::Winograd {
        // v1 artifacts predate kernel dispatch: resolve from the loading
        // host, exactly as a fresh Auto compile would
        crate::dse::recommend_kernel()
    } else {
        KernelKind::default()
    };
    let linebuf_depth = r.usize("linebuf depth")?;
    let linebuf_words = r.usize("linebuf words")?;

    let weights = r.filter::<E>("layer weights")?;
    if (weights.c_in, weights.c_out) != (c_in, c_out) || (weights.kh, weights.kw) != (k, k) {
        return Err(bad("weight bank shape disagrees with the layer geometry".into()));
    }

    // the structural invariants the execution engine indexes by — anything
    // violating them could read out of bounds, so they are load errors
    let expected_kc = match kind {
        Kind::Deconv => tdc::kc(k, s),
        Kind::Conv => k,
    };
    if kc != expected_kc {
        return Err(bad(format!("kc {kc} != derived K_C {expected_kc}")));
    }

    let n_phases = r.usize("phase count")?;
    let expected_phases = match kind {
        Kind::Deconv => s * s,
        Kind::Conv => 0,
    };
    if n_phases != expected_phases {
        return Err(bad(format!("phase count {n_phases} != S² = {expected_phases}")));
    }
    let mut phases = Vec::with_capacity(n_phases);
    for pi in 0..n_phases {
        let g = r.filter::<E>("phase filter")?;
        if (g.c_in, g.c_out) != (c_in, c_out) || (g.kh, g.kw) != (kc, kc) {
            return Err(bad(format!("phase {pi} filter shape is not C_in x C_out x K_C x K_C")));
        }
        let d0y = r.i64("phase d0y")? as isize;
        let d0x = r.i64("phase d0x")? as isize;
        // the engine materializes phase-padded views with these offsets;
        // out-of-range offsets would underflow the padding arithmetic
        let lo = -(kc as isize - 1);
        if !(lo..=0).contains(&d0y) || !(lo..=0).contains(&d0x) {
            return Err(bad(format!("phase {pi} offset ({d0y},{d0x}) outside [{lo},0]")));
        }
        let ry = r.usize("phase ry")?;
        let rx = r.usize("phase rx")?;
        if ry > kc || rx > kc {
            return Err(bad(format!("phase {pi} support ({ry},{rx}) exceeds K_C {kc}")));
        }
        phases.push(PhaseFilter { g, d0y, d0x, ry, rx });
    }

    let n_reordered = r.usize("reordered count")?;
    if n_reordered != 0 && n_reordered != n_phases {
        return Err(bad(format!(
            "reordered slab count {n_reordered} is neither 0 nor the phase count {n_phases}"
        )));
    }
    if method == Method::Winograd && n_reordered == 0 {
        return Err(bad("winograd-method layer without reordered slabs".into()));
    }
    // the F(2x2, 3x3) support bound the planner enforces in select_method:
    // a Winograd-method layer with K_C > R would underflow the engine's
    // phase-padding arithmetic at request time
    if method == Method::Winograd && kc > crate::winograd::R {
        return Err(bad(format!(
            "winograd-method layer with K_C {kc} > R {} (unsupported by F(2x2,3x3))",
            crate::winograd::R
        )));
    }
    let mut reordered = Vec::with_capacity(n_reordered);
    for ri in 0..n_reordered {
        let case = case_from_tag(r.u8("sparsity case")?)?;
        let n_live = r.usize("live count")?;
        if n_live != case.live_positions() {
            return Err(bad(format!(
                "slab {ri}: live count {n_live} != case live positions {}",
                case.live_positions()
            )));
        }
        let mut live = Vec::with_capacity(n_live);
        for _ in 0..n_live {
            let pos = r.usize("live position")?;
            // the batched GEMM indexes the gathered tile matrix by pos
            if pos >= 16 {
                return Err(bad(format!("slab {ri}: live position {pos} outside the 4x4 tile")));
            }
            live.push(pos);
        }
        let rf_cin = r.usize("slab c_in")?;
        let rf_cout = r.usize("slab c_out")?;
        if (rf_cin, rf_cout) != (c_in, c_out) {
            return Err(bad(format!("slab {ri}: channel shape disagrees with the layer")));
        }
        let numel = n_live
            .checked_mul(rf_cout)
            .and_then(|v| v.checked_mul(rf_cin))
            .ok_or_else(|| bad(format!("slab {ri}: size overflows")))?;
        let u = r.elems::<E>(numel, "slab weights")?;
        let d0y = r.i64("slab d0y")? as isize;
        let d0x = r.i64("slab d0x")? as isize;
        // reorder_filter copies the phase's offsets verbatim; anything else
        // is corruption (and would hand consumers an unguarded underflow)
        if (d0y, d0x) != (phases[ri].d0y, phases[ri].d0x) {
            return Err(bad(format!(
                "slab {ri}: offsets ({d0y},{d0x}) disagree with the phase's ({},{})",
                phases[ri].d0y, phases[ri].d0x
            )));
        }
        // the zero-skip run-list is derived data: always rebuilt from the
        // decoded weights (so v1 slabs gain skip for free), and a stored v2
        // section must agree with the rebuild bit for bit — a stale or
        // tampered list could otherwise elide live products at request time
        let rebuilt = RunList::build(n_live, rf_cout, rf_cin, &u);
        if version >= 2 {
            let stored = match r.u8("skip flag")? {
                0 => None,
                1 => {
                    let n_off = r.usize("skip offset count")?;
                    let off_bytes = r.take(
                        n_off.checked_mul(4).ok_or_else(|| {
                            bad(format!("slab {ri}: skip offset count overflows"))
                        })?,
                        "skip offsets",
                    )?;
                    let offsets: Vec<u32> = off_bytes
                        .chunks_exact(4)
                        .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                        .collect();
                    let n_runs = r.usize("skip run count")?;
                    let run_bytes = r.take(
                        n_runs.checked_mul(8).ok_or_else(|| {
                            bad(format!("slab {ri}: skip run count overflows"))
                        })?,
                        "skip runs",
                    )?;
                    let runs: Vec<(u32, u32)> = run_bytes
                        .chunks_exact(8)
                        .map(|c| {
                            (
                                u32::from_le_bytes([c[0], c[1], c[2], c[3]]),
                                u32::from_le_bytes([c[4], c[5], c[6], c[7]]),
                            )
                        })
                        .collect();
                    let sk = RunList { offsets, runs };
                    if !sk.is_well_formed(n_live, rf_cout, rf_cin) {
                        return Err(bad(format!("slab {ri}: malformed zero-skip run-list")));
                    }
                    Some(sk)
                }
                other => return Err(bad(format!("slab {ri}: unknown skip flag {other}"))),
            };
            if stored != rebuilt {
                return Err(bad(format!(
                    "slab {ri}: stored zero-skip run-list disagrees with a rebuild from the \
                     slab weights"
                )));
            }
        }
        reordered.push(ReorderedFilter {
            case,
            live,
            c_in: rf_cin,
            c_out: rf_cout,
            u,
            skip: rebuilt,
            d0y,
            d0x,
        });
    }

    // winograd layers execute through the precompiled tile geometry; it
    // must be exactly what the planner derives from the layer extent
    if method == Method::Winograd {
        let ho_t = h_in.div_ceil(M_TILE) * M_TILE;
        let wo_t = w_in.div_ceil(M_TILE) * M_TILE;
        // the kernel field is not derivable from the layer extent — it is
        // whatever the (clamped) tag resolved to above
        let want = TileGeometry {
            ho_t,
            wo_t,
            tiles_h: ho_t / M_TILE,
            tiles_w: wo_t / M_TILE,
            kernel: tiles.kernel,
        };
        if tiles != want {
            return Err(bad(format!("tile geometry {tiles:?} != derived {want:?}")));
        }
    }

    Ok(LayerPlan {
        layer,
        method,
        weights,
        phases,
        reordered,
        kc,
        tiles,
        linebuf_depth,
        linebuf_words,
    })
}

// ---------------------------------------------------------------------------
// inspect
// ---------------------------------------------------------------------------

/// Render the manifest view of one artifact's bytes — the
/// `wingan plan inspect` output: header metadata, per-layer method +
/// geometry rows, and per-section payload sizes.
pub fn describe(bytes: &[u8], origin: &str) -> ArtifactResult<String> {
    let dec = decode(bytes)?;
    let h = &dec.header;
    let mut out = String::new();
    out.push_str(&format!("artifact   {origin}\n"));
    out.push_str(&format!(
        "format     v{} · precision {} · {} bytes on disk\n",
        h.version,
        h.precision,
        bytes.len()
    ));
    out.push_str(&format!(
        "model      {} ({}) · scale {} · route method {} · weight seed {}\n",
        h.model, h.model_id, h.scale, h.method, h.seed
    ));
    out.push_str(&format!(
        "shape      [{}, {}, {}] -> [{}, {}, {}] · {} layers\n",
        h.input_shape.0,
        h.input_shape.1,
        h.input_shape.2,
        h.output_shape.0,
        h.output_shape.1,
        h.output_shape.2,
        h.layers
    ));
    match &dec.payload {
        PlanPayload::F32(p) => describe_layers(p, &dec.sections, &mut out),
        PlanPayload::F64(p) => describe_layers(p, &dec.sections, &mut out),
    }
    let total: usize = dec.sections.iter().map(|s| s.bytes).sum();
    out.push_str(&format!(
        "payload    {total} bytes across {} sections (META {} B)\n",
        dec.sections.len(),
        dec.sections[0].bytes
    ));
    Ok(out)
}

fn describe_layers<E: Elem>(plan: &ModelPlan<E>, sections: &[SectionInfo], out: &mut String) {
    out.push_str(
        "layer  kind    geometry                     method    kernel  phases  live  tiles    \
         zskip    payload\n",
    );
    for (i, lp) in plan.layers.iter().enumerate() {
        let l = &lp.layer;
        let geo = format!(
            "{}x{} K{} S{} {}x{}->{}x{}",
            l.c_in,
            l.c_out,
            l.k,
            l.s,
            l.h_in,
            l.w_in,
            l.h_out(),
            l.w_out()
        );
        let (tiles, kernel) = if lp.method == Method::Winograd {
            (format!("{}x{}", lp.tiles.tiles_h, lp.tiles.tiles_w), lp.tiles.kernel.label())
        } else {
            ("-".into(), "-")
        };
        // products the runtime zero-skip elides per tile on this layer
        // (dead `c_in` runs across all slabs), out of the dense total
        let skipped: usize = lp
            .reordered
            .iter()
            .filter_map(|rf| rf.skip.as_ref().map(|sk| sk.skipped_products(rf.c_out, rf.c_in)))
            .sum();
        let zskip = if lp.method == Method::Winograd { format!("{skipped}") } else { "-".into() };
        let bytes = sections.get(i + 1).map(|s| s.bytes).unwrap_or(0);
        out.push_str(&format!(
            "L{i:<5} {:<7} {geo:<28} {:<9} {kernel:<7} {:<7} {:<5} {tiles:<8} {zskip:<8} \
             {bytes} B\n",
            format!("{:?}", l.kind).to_ascii_lowercase(),
            format!("{:?}", lp.method).to_ascii_lowercase(),
            lp.phases.len(),
            lp.live_positions(),
        ));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::plan::Planner;
    use crate::gan::zoo::{self, Scale};

    fn meta() -> ArtifactMeta {
        ArtifactMeta { scale: "tiny".into(), method: "winograd".into(), seed: 7 }
    }

    fn tiny_plan() -> ModelPlan {
        Planner::default().compile_seeded(&zoo::dcgan(Scale::Tiny), 7)
    }

    /// Structural + bitwise equality of two plans at one precision.
    fn assert_plans_identical<E: Elem>(a: &ModelPlan<E>, b: &ModelPlan<E>) {
        assert_eq!(a.model, b.model);
        assert_eq!(a.input_shape, b.input_shape);
        assert_eq!(a.output_shape, b.output_shape);
        assert_eq!(a.layers.len(), b.layers.len());
        for (la, lb) in a.layers.iter().zip(&b.layers) {
            assert_eq!(la.method, lb.method);
            assert_eq!(la.kc, lb.kc);
            assert_eq!(la.tiles, lb.tiles);
            assert_eq!(la.linebuf_depth, lb.linebuf_depth);
            assert_eq!(la.linebuf_words, lb.linebuf_words);
            assert_eq!(la.layer.act, lb.layer.act);
            assert_eq!(la.weights.data, lb.weights.data);
            assert_eq!(la.phases.len(), lb.phases.len());
            for (pa, pb) in la.phases.iter().zip(&lb.phases) {
                assert_eq!(pa.g.data, pb.g.data);
                assert_eq!((pa.d0y, pa.d0x, pa.ry, pa.rx), (pb.d0y, pb.d0x, pb.ry, pb.rx));
            }
            assert_eq!(la.reordered.len(), lb.reordered.len());
            for (ra, rb) in la.reordered.iter().zip(&lb.reordered) {
                assert_eq!(ra.case, rb.case);
                assert_eq!(ra.live, rb.live);
                assert_eq!(ra.u, rb.u);
                assert_eq!(ra.skip, rb.skip);
                assert_eq!((ra.d0y, ra.d0x), (rb.d0y, rb.d0x));
            }
        }
    }

    #[test]
    fn roundtrip_f64_is_bit_exact() {
        let plan = tiny_plan();
        let bytes = encode(&plan, &meta());
        let dec = decode(&bytes).unwrap();
        assert_eq!(dec.header.version, FORMAT_VERSION);
        assert_eq!(dec.header.precision, Precision::F64);
        assert_eq!(dec.header.model, "DCGAN");
        assert_eq!(dec.header.model_id, "dcgan");
        assert_eq!(dec.header.scale, "tiny");
        assert_eq!(dec.header.method, "winograd");
        assert_eq!(dec.header.seed, 7);
        assert_eq!(dec.header.layers, plan.layers.len());
        assert_eq!(dec.sections.len(), plan.layers.len() + 1);
        match dec.payload {
            PlanPayload::F64(back) => assert_plans_identical(&plan, &back),
            PlanPayload::F32(_) => panic!("wrong tier decoded"),
        }
    }

    #[test]
    fn roundtrip_f32_preserves_the_lowered_plan() {
        let plan32: ModelPlan<f32> = tiny_plan().lower();
        let bytes = encode(&plan32, &meta());
        // half-width words: the f32 artifact is materially smaller
        let bytes64 = encode(&tiny_plan(), &meta());
        assert!(bytes.len() < bytes64.len());
        let dec = decode(&bytes).unwrap();
        assert_eq!(dec.header.precision, Precision::F32);
        match dec.payload {
            PlanPayload::F32(back) => assert_plans_identical(&plan32, &back),
            PlanPayload::F64(_) => panic!("wrong tier decoded"),
        }
    }

    #[test]
    fn bad_magic_is_typed() {
        let mut bytes = encode(&tiny_plan(), &meta());
        bytes[0] = b'X';
        assert!(matches!(decode(&bytes), Err(ArtifactError::BadMagic { .. })));
    }

    #[test]
    fn wrong_version_is_typed() {
        let mut bytes = encode(&tiny_plan(), &meta());
        bytes[8] = 99; // version u32 LE starts right after the magic
        assert!(matches!(
            decode(&bytes),
            Err(ArtifactError::UnsupportedVersion { found: 99 })
        ));
    }

    #[test]
    fn truncation_is_typed_at_any_cut() {
        let bytes = encode(&tiny_plan(), &meta());
        // every prefix must fail with a typed error, never panic
        for cut in [0, 3, 8, 11, 13, 40, bytes.len() / 2, bytes.len() - 1] {
            let err = decode(&bytes[..cut]).unwrap_err();
            assert!(
                matches!(
                    err,
                    ArtifactError::Truncated { .. }
                        | ArtifactError::BadMagic { .. }
                        | ArtifactError::ChecksumMismatch { .. }
                ),
                "cut {cut}: unexpected {err:?}"
            );
        }
    }

    #[test]
    fn payload_corruption_fails_the_section_checksum() {
        let mut bytes = encode(&tiny_plan(), &meta());
        // flip one bit deep inside a layer section's weight data
        let idx = bytes.len() - 64;
        bytes[idx] ^= 0x40;
        assert!(matches!(decode(&bytes), Err(ArtifactError::ChecksumMismatch { .. })));
    }

    #[test]
    fn trailing_garbage_is_rejected() {
        let mut bytes = encode(&tiny_plan(), &meta());
        bytes.extend_from_slice(&[0u8; 16]);
        assert!(matches!(decode(&bytes), Err(ArtifactError::Malformed { .. })));
    }

    #[test]
    fn unknown_precision_tag_is_malformed() {
        let mut bytes = encode(&tiny_plan(), &meta());
        bytes[12] = 7; // precision tag byte: magic(8) + version(4)
        assert!(matches!(decode(&bytes), Err(ArtifactError::Malformed { .. })));
    }

    #[test]
    fn decode_header_never_touches_the_layer_payloads() {
        let bytes = encode(&tiny_plan(), &meta());
        let h = decode_header(&bytes).unwrap();
        assert_eq!(h, decode(&bytes).unwrap().header);
        // cut the file right after the META section's checksum: the header
        // still decodes (key validation is payload-free) while a full
        // decode correctly fails
        let meta_len = u64::from_le_bytes(bytes[17..25].try_into().unwrap()) as usize;
        let cut = 25 + meta_len + 8;
        assert_eq!(decode_header(&bytes[..cut]).unwrap(), h);
        assert!(decode(&bytes[..cut]).is_err());
    }

    #[test]
    fn inconsistent_shape_chain_is_rejected() {
        // a checksummed-but-inconsistent artifact must fail at load time,
        // never reach the engine: break the declared output shape…
        let mut plan = tiny_plan();
        plan.output_shape = (3, 64, 65);
        assert!(matches!(
            decode(&encode(&plan, &meta())),
            Err(ArtifactError::Malformed { .. })
        ));
        // …and break the inter-layer chain
        let mut plan = tiny_plan();
        plan.layers[1].layer.c_in += 1;
        assert!(matches!(
            decode(&encode(&plan, &meta())),
            Err(ArtifactError::Malformed { .. })
        ));
    }

    #[test]
    fn implausible_geometry_is_rejected_before_any_derivation() {
        // a hostile stride may never drive S²-sized work or overflow
        let mut plan = tiny_plan();
        plan.layers[0].layer.s = MAX_STRIDE + 1;
        assert!(matches!(
            decode(&encode(&plan, &meta())),
            Err(ArtifactError::Malformed { .. })
        ));
        let mut plan = tiny_plan();
        plan.layers[0].layer.h_in = MAX_EXTENT + 1;
        assert!(matches!(
            decode(&encode(&plan, &meta())),
            Err(ArtifactError::Malformed { .. })
        ));
    }

    #[test]
    fn describe_renders_the_manifest_view() {
        let plan = tiny_plan();
        let bytes = encode(&plan, &meta());
        let text = describe(&bytes, "store/tiny/dcgan.winograd.f64.plan").unwrap();
        assert!(text.contains("DCGAN"), "{text}");
        assert!(text.contains("precision f64"), "{text}");
        assert!(text.contains("route method winograd"), "{text}");
        assert!(text.contains("L0"), "{text}");
        assert!(text.contains("winograd"), "{text}");
        // every layer row present
        for i in 0..plan.layers.len() {
            assert!(text.contains(&format!("L{i}")), "{text}");
        }
    }

    /// Zero a `c_in` range of slab 0 on the first winograd layer (every
    /// `(pos, c_out)` row) and rebuild its run-list, so the plan carries a
    /// real `Some(skip)` section. Returns the edited layer's index.
    fn inject_zero_run(plan: &mut ModelPlan) -> usize {
        let li = plan
            .layers
            .iter()
            .position(|lp| lp.method == Method::Winograd && !lp.reordered.is_empty())
            .expect("tiny DCGAN compiles winograd layers");
        let rf = &mut plan.layers[li].reordered[0];
        let dead = rf.c_in.min(4);
        for pi in 0..rf.live.len() {
            for co in 0..rf.c_out {
                for ci in 0..dead {
                    rf.u[(pi * rf.c_out + co) * rf.c_in + ci] = 0.0;
                }
            }
        }
        rf.skip = RunList::build(rf.live.len(), rf.c_out, rf.c_in, &rf.u);
        assert!(rf.skip.is_some(), "the injected dead run must surface in the run-list");
        li
    }

    #[test]
    fn v1_artifacts_still_decode_with_rederived_dispatch() {
        // v1 predates kernel tags and skip sections; both re-derive at load
        let mut plan = tiny_plan();
        inject_zero_run(&mut plan);
        let v1 = encode_with_version(&plan, &meta(), 1);
        let v2 = encode(&plan, &meta());
        assert!(v1.len() < v2.len(), "v1 layout must not carry the new sections");
        let dec = decode(&v1).unwrap();
        assert_eq!(dec.header.version, 1);
        match dec.payload {
            // kernel: the host probe, exactly what the Auto compile stamped;
            // skip: rebuilt from the decoded weights, injected run included
            PlanPayload::F64(back) => assert_plans_identical(&plan, &back),
            PlanPayload::F32(_) => panic!("wrong tier decoded"),
        }
        assert_eq!(decode_header(&v1).unwrap().version, 1);
    }

    #[test]
    fn roundtrip_preserves_injected_zero_skip() {
        let mut plan = tiny_plan();
        let li = inject_zero_run(&mut plan);
        let dec = decode(&encode(&plan, &meta())).unwrap();
        match dec.payload {
            PlanPayload::F64(back) => {
                assert_plans_identical(&plan, &back);
                let rf = &back.layers[li].reordered[0];
                let sk = rf.skip.as_ref().expect("skip section survives the roundtrip");
                assert!(sk.skipped_products(rf.c_out, rf.c_in) > 0);
            }
            PlanPayload::F32(_) => panic!("wrong tier decoded"),
        }
    }

    #[test]
    fn stale_or_malformed_skip_sections_are_rejected() {
        // a well-formed run-list that disagrees with the slab weights (here:
        // built from a zeroed copy of a dense slab) is checksummed-valid on
        // the wire but must fail the rebuild check — it would elide live
        // products at request time
        let mut plan = tiny_plan();
        let li = inject_zero_run(&mut plan);
        let rf = &mut plan.layers[li].reordered[0];
        let mut u2 = rf.u.clone();
        // extend position 0's dead c_in range (every c_out row, so the
        // whole register block goes dead) beyond what the real weights have
        let extra = rf.c_in.min(8);
        for co in 0..rf.c_out {
            for ci in 0..extra {
                u2[co * rf.c_in + ci] = 0.0;
            }
        }
        let stale = RunList::build(rf.live.len(), rf.c_out, rf.c_in, &u2);
        assert_ne!(stale, rf.skip);
        rf.skip = stale;
        let err = decode(&encode(&plan, &meta())).unwrap_err();
        assert!(
            matches!(&err, ArtifactError::Malformed { detail } if detail.contains("rebuild")),
            "{err:?}"
        );
        // structurally broken lists fail before the rebuild comparison
        let mut plan = tiny_plan();
        let li = inject_zero_run(&mut plan);
        plan.layers[li].reordered[0].skip =
            Some(RunList { offsets: vec![0], runs: Vec::new() });
        let err = decode(&encode(&plan, &meta())).unwrap_err();
        assert!(
            matches!(&err, ArtifactError::Malformed { detail } if detail.contains("malformed zero-skip")),
            "{err:?}"
        );
    }

    #[test]
    fn degenerate_phase_plan_roundtrips_with_empty_slabs() {
        use crate::engine::plan::{PlanOptions, Select};
        use crate::gan::zoo::Gan;
        // K=1 S=2: phase (0,0) carries the single tap, the other three
        // phases are zero-tap and compile to explicitly empty slabs
        let g = Gan {
            name: "DCGAN",
            year: 2015,
            layers: vec![Layer::deconv(3, 2, 1, 2, 4).with_act(Activation::Relu)],
        };
        let plan = Planner::new(PlanOptions {
            select: Select::Force(Method::Winograd),
            ..Default::default()
        })
        .compile_seeded(&g, 11);
        let empties = plan.layers[0]
            .reordered
            .iter()
            .filter(|rf| rf.case == Case::Empty)
            .count();
        assert_eq!(empties, 3, "three of the four S²=4 phases are degenerate");
        let dec = decode(&encode(&plan, &meta())).unwrap();
        match dec.payload {
            PlanPayload::F64(back) => {
                assert_plans_identical(&plan, &back);
                for rf in &back.layers[0].reordered {
                    if rf.case == Case::Empty {
                        assert!(rf.live.is_empty() && rf.u.is_empty() && rf.skip.is_none());
                    }
                }
            }
            PlanPayload::F32(_) => panic!("wrong tier decoded"),
        }
    }

    #[test]
    fn describe_reports_kernel_and_zero_skip() {
        let mut plan = tiny_plan();
        inject_zero_run(&mut plan);
        let text = describe(&encode(&plan, &meta()), "x.plan").unwrap();
        assert!(text.contains("kernel"), "{text}");
        assert!(text.contains("zskip"), "{text}");
        let want = crate::dse::recommend_kernel().label();
        assert!(text.contains(want), "{text}");
    }

    #[test]
    fn fnv_is_stable() {
        // pinned reference values (FNV-1a 64 test vectors)
        assert_eq!(fnv1a64(b""), 0xcbf29ce484222325);
        assert_eq!(fnv1a64(b"a"), 0xaf63dc4c8601ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x85944171f73967e8);
    }
}
