//! One fleet replica: a [`Coordinator`] served over the fleet wire
//! protocol ([`crate::fleet::wire`]).
//!
//! A replica **warm-boots** from a shared [`PlanStore`]
//! (`NativeConfig::plan_store`): startup is artifact loads, not compiles,
//! and the store's on-disk generation tag
//! ([`crate::artifact::read_generation`]) is recorded at boot so the
//! fleet router can tell which plan set each replica is serving.
//!
//! # Readiness and health
//!
//! A replica is not **ready** until warm-boot completes — requests that
//! arrive earlier get a typed `NOT_READY` wire error (retryable: the
//! router fails them over). **Health** is a machine-readable JSON
//! document served to any [`WireMsg::HealthQuery`]: readiness, plan
//! generation, in-flight count, the route table, and the full
//! [`Coordinator::health`] / [`Coordinator::metrics`] snapshots
//! ([`HealthReport::to_json`](crate::coordinator::HealthReport::to_json),
//! [`Metrics::to_json`](crate::coordinator::Metrics::to_json)).
//!
//! # Fates and retry idempotency
//!
//! Every **executed** outcome (a completion, a contained crash, an
//! execution error) is recorded in a bounded [`FateCache`] keyed by the
//! router-assigned request id. A resent id is answered from the cache —
//! bitwise identical bytes, no second execution — so router retries are
//! idempotent: one execution per fate, ever. Ids are *reserved* at
//! admission, before execution starts, so the invariant also holds for a
//! duplicate arriving while the first execution is still in flight: the
//! duplicate waits for the original's fate instead of starting a second
//! execution. Outcomes that never reached the engine (typed sheds,
//! not-ready, draining, a failed boot) are deliberately *not* cached:
//! they are the retryable verdicts.
//!
//! # Graceful shutdown and rolling reload
//!
//! `Drain` stops admission (typed `DRAINING` replies) while in-flight
//! requests finish; `Reload` drains, reboots the coordinator from the
//! store (picking up its current generation), and answers `Ok` only once
//! the replica is ready again — the `Ok` *is* the readiness gate the
//! router's rolling republish waits on. `Shutdown` (or SIGTERM via
//! [`ReplicaServer::shutdown`]) drains through the coordinator's
//! bounded `drain_deadline` path — leftovers get typed `EngineShutdown`,
//! never silence — and leaves the replica reporting `draining` so the
//! router's prober deregisters it *before* connections close: a clean
//! roll never looks like a crash.

use crate::artifact::read_generation;
use crate::coordinator::{Coordinator, ServeConfig};
use crate::engine::NativeConfig;
use crate::faultinject::{FaultAction, FaultPlane, FaultSite};
use crate::fleet::wire::{self, RecvError, WireMsg};
use crate::telemetry;
use crate::util::json::{self, Json};
use crate::util::lock_unpoisoned;
use anyhow::{anyhow, Context, Result};
use std::collections::{HashMap, HashSet, VecDeque};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

/// How a replica is built: the coordinator's own configs plus the
/// fleet-tier fault plane (sites `conn_drop` / `replica_stall` /
/// `replica_exit`; the engine-tier sites keep riding inside
/// `native.faults` / `serve.faults` as before).
#[derive(Clone)]
pub struct ReplicaConfig {
    /// engine/runtime configuration; set `plan_store` to warm-boot
    pub native: NativeConfig,
    /// coordinator serving configuration
    pub serve: ServeConfig,
    /// fleet-tier fault plane consulted in the connection loop
    pub fleet_faults: Option<Arc<FaultPlane>>,
}

/// Bounded first-fate-wins cache of executed request outcomes, keyed by
/// the router-assigned request id. `put` refuses to overwrite: the first
/// fate recorded for an id is the only fate that id will ever have, and
/// FIFO eviction bounds memory regardless of request count.
///
/// The cache also tracks **pending** ids — reserved at admission, before
/// the execution starts — so "at most one execution per id" holds even
/// when a duplicate arrives *while* the first execution is still in
/// flight (a router io timeout on a stalled replica can resend an id the
/// original handler is still working on). A duplicate of a pending id
/// must wait for the original's fate, never start a second execution.
pub struct FateCache {
    cap: usize,
    map: HashMap<u64, WireMsg>,
    order: VecDeque<u64>,
    /// ids admitted to execution whose fate is not yet recorded
    pending: HashSet<u64>,
}

impl FateCache {
    /// A cache remembering at most `cap` fates (oldest evicted first).
    pub fn new(cap: usize) -> FateCache {
        FateCache {
            cap: cap.max(1),
            map: HashMap::new(),
            order: VecDeque::new(),
            pending: HashSet::new(),
        }
    }

    /// The recorded fate for `id`, if any.
    pub fn get(&self, id: u64) -> Option<&WireMsg> {
        self.map.get(&id)
    }

    /// Reserve `id` for execution. `false` when the id already has a fate
    /// or another execution of it is in flight — the caller must replay
    /// the fate or wait for it, never execute.
    pub fn reserve(&mut self, id: u64) -> bool {
        if self.map.contains_key(&id) {
            return false;
        }
        self.pending.insert(id)
    }

    /// Drop a reservation that produced no fate (the request never
    /// executed — shed, fault drop, phase gate); a waiting duplicate may
    /// then claim the id itself.
    pub fn release(&mut self, id: u64) {
        self.pending.remove(&id);
    }

    /// True while `id` is reserved with its fate still unrecorded.
    pub fn pending(&self, id: u64) -> bool {
        self.pending.contains(&id)
    }

    /// Record `id`'s fate (clearing any reservation). Returns `false`
    /// (and changes nothing else) when the id already has one — first
    /// fate wins, always.
    pub fn put(&mut self, id: u64, fate: WireMsg) -> bool {
        self.pending.remove(&id);
        if self.map.contains_key(&id) {
            return false;
        }
        while self.order.len() >= self.cap {
            if let Some(old) = self.order.pop_front() {
                self.map.remove(&old);
            }
        }
        self.order.push_back(id);
        self.map.insert(id, fate);
        true
    }

    /// Fates currently held.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    /// True when no fate is held.
    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }
}

/// Replica lifecycle. `Ready`/`Draining` own the coordinator; everything
/// else is coordinator-free by construction.
enum Phase {
    /// warm-boot in progress
    Booting,
    /// serving
    Ready {
        coord: Arc<Coordinator>,
        generation: u64,
    },
    /// admission stopped; in-flight requests finishing
    Draining {
        coord: Arc<Coordinator>,
        generation: u64,
    },
    /// boot or reload failed (terminal until a new `Reload`)
    Failed(String),
    /// drained and exited
    Stopped,
}

/// State shared by the accept loop, connection threads, and the handle.
struct Shared {
    phase: Mutex<Phase>,
    /// ends the accept loop and makes connection loops exit after their
    /// current frame
    stop: AtomicBool,
    /// requests currently between phase-gate and reply
    in_flight: AtomicUsize,
    fates: Mutex<FateCache>,
    /// live connections (dup'd handles), so an abrupt kill can sever them
    conns: Mutex<HashMap<u64, TcpStream>>,
    conn_seq: AtomicU64,
    /// serializes Reload/Drain/Shutdown transitions
    control: Mutex<()>,
    cfg: ReplicaConfig,
    store_root: Option<PathBuf>,
}

impl Shared {
    fn store_generation(&self) -> u64 {
        self.store_root.as_deref().map(read_generation).unwrap_or(0)
    }
}

/// Boot a coordinator from the replica config, recording the store
/// generation it loaded under. If a republish lands *while* we are
/// booting (generation moved between start and finish), the boot is
/// thrown away and retried once so a fresh replica never reports a
/// generation it only half-loaded.
fn boot(cfg: &ReplicaConfig, store_root: &Option<PathBuf>) -> Result<(Arc<Coordinator>, u64), String> {
    for attempt in 0..2 {
        let before = store_root.as_deref().map(read_generation).unwrap_or(0);
        let coord = Coordinator::start_native(cfg.native.clone(), cfg.serve.clone())
            .map_err(|e| format!("warm-boot failed: {e}"))?;
        let after = store_root.as_deref().map(read_generation).unwrap_or(0);
        if before == after || attempt == 1 {
            return Ok((Arc::new(coord), after));
        }
        // republish raced the boot — drain this coordinator and retry
        drop(coord);
    }
    unreachable!("loop returns on attempt 1");
}

/// What a connection loop should do after handling one frame.
enum Verdict {
    Reply(WireMsg),
    /// drop the connection silently (hostile bytes, or `conn_drop` fault)
    Drop,
    /// reply, then close this connection (clean `Shutdown` handshake)
    ReplyClose(WireMsg),
}

/// Decrements `in_flight` on scope exit, whatever path the handler takes.
struct InFlightGuard<'a>(&'a AtomicUsize);

impl Drop for InFlightGuard<'_> {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::AcqRel);
    }
}

/// Releases a fate reservation on scope exit. Recording a fate via
/// [`FateCache::put`] clears the pending mark itself (making this drop a
/// no-op); every *non-executed* exit path — fault drop, phase gate,
/// admission shed — relies on the drop to unblock waiting duplicates.
struct FateReservation<'a> {
    fates: &'a Mutex<FateCache>,
    id: u64,
}

impl Drop for FateReservation<'_> {
    fn drop(&mut self) {
        lock_unpoisoned(self.fates).release(self.id);
    }
}

fn handle_request(
    shared: &Shared,
    id: u64,
    model: &str,
    method: &str,
    deadline_us: u64,
    input: Vec<f32>,
    trace: u64,
) -> Verdict {
    let budget = (deadline_us > 0).then(|| Duration::from_micros(deadline_us));
    // generous wait cap: the coordinator sheds or answers long before
    // this; it only exists so a wedged engine can't wedge the connection
    let wait = budget.map_or(Duration::from_secs(120), |b| b + Duration::from_secs(5));
    // 1. fates first: a resent id is answered with its recorded outcome,
    //    bitwise identical, no second execution — even across faults. An
    //    id whose first execution is still in flight is *reserved*: the
    //    duplicate waits for that execution's fate (or for the
    //    reservation to release without one) instead of executing again.
    let t0 = Instant::now();
    loop {
        {
            let mut fates = lock_unpoisoned(&shared.fates);
            if let Some(fate) = fates.get(id).cloned() {
                return Verdict::Reply(fate);
            }
            if fates.reserve(id) {
                break;
            }
        }
        if t0.elapsed() > wait {
            // the original execution outlived even the generous cap;
            // this handler executed nothing, so the verdict is retryable
            return Verdict::Reply(WireMsg::Error {
                id,
                code: wire::code::NOT_READY,
                a: 0,
                b: 0,
                detail: "duplicate of an in-flight request id; original still executing"
                    .to_string(),
            });
        }
        thread::sleep(Duration::from_millis(2));
    }
    let _reservation = FateReservation { fates: &shared.fates, id };
    // 2. fleet fault plane (deterministic, seeded)
    if let Some(plane) = &shared.cfg.fleet_faults {
        if plane.check(FaultSite::ConnDrop).is_some() {
            return Verdict::Drop;
        }
        if let Some(action) = plane.check(FaultSite::ReplicaStall) {
            let dwell = match action {
                FaultAction::Delay(d) => d,
                _ => Duration::from_millis(50),
            };
            thread::sleep(dwell);
        }
        if plane.check(FaultSite::ReplicaExit).is_some() {
            abrupt_stop(shared, "replica_exit fault injected");
            return Verdict::Drop;
        }
    }
    // 3. phase gate — the coordinator Arc is cloned and in_flight
    //    incremented under the same lock, so a drain that later observes
    //    in_flight == 0 knows no handler still holds the engine
    let (coord, _guard) = {
        let phase = lock_unpoisoned(&shared.phase);
        match &*phase {
            Phase::Booting => {
                return Verdict::Reply(WireMsg::Error {
                    id,
                    code: wire::code::NOT_READY,
                    a: 0,
                    b: 0,
                    detail: String::new(),
                })
            }
            Phase::Draining { .. } | Phase::Stopped => {
                return Verdict::Reply(WireMsg::Error {
                    id,
                    code: wire::code::DRAINING,
                    a: 0,
                    b: 0,
                    detail: String::new(),
                })
            }
            Phase::Failed(e) => {
                // retryable (FAILED, not EXECUTION): nothing executed
                // here, and the router must fail over to a healthy
                // replica instead of surfacing a replica-local boot
                // failure to the client as terminal
                return Verdict::Reply(WireMsg::Error {
                    id,
                    code: wire::code::FAILED,
                    a: 0,
                    b: 0,
                    detail: format!("replica failed: {e}"),
                })
            }
            Phase::Ready { coord, .. } => {
                shared.in_flight.fetch_add(1, Ordering::AcqRel);
                (Arc::clone(coord), InFlightGuard(&shared.in_flight))
            }
        }
    };
    // the wire-carried trace id (router-minted) keeps the cross-process
    // trace one tree; 0 lets this replica's own sampler decide
    let outcome = match coord.submit_traced(model, method, input, budget, trace) {
        Ok(rx) => match rx.recv_timeout(wait) {
            Ok(fate) => fate,
            Err(_) => Err(crate::coordinator::ServeError::Execution(
                "replica timed out waiting for the engine".to_string(),
            )),
        },
        Err(shed) => Err(shed),
    };
    drop(coord);
    let (reply, executed) = match outcome {
        Ok(resp) => (
            WireMsg::Response {
                id,
                batch_size: resp.batch_size as u32,
                queue_us: resp.queue_time.as_micros() as u64,
                exec_us: resp.exec_time.as_micros() as u64,
                output: resp.output,
            },
            true,
        ),
        Err(e) => {
            use crate::coordinator::ServeError as SE;
            // cache only outcomes the engine actually produced; sheds and
            // shutdown verdicts are retryable and must stay uncached
            let executed = matches!(e, SE::Crashed(_) | SE::Execution(_));
            (wire::error_to_wire(id, &e), executed)
        }
    };
    if executed {
        lock_unpoisoned(&shared.fates).put(id, reply.clone());
    }
    Verdict::Reply(reply)
}

/// The replica's scrapeable metrics document: readiness, the coordinator
/// metrics snapshot, and the flight recorder's per-stage latency rollup.
/// The `MetricsQuery` wire verb serves this as stable-key JSON or as
/// Prometheus text exposition ([`crate::telemetry::export`]), and
/// `wingan replica --stats-every` prints it periodically.
fn metrics_doc(shared: &Shared) -> Json {
    let (ready, generation, coord) = {
        let phase = lock_unpoisoned(&shared.phase);
        match &*phase {
            Phase::Ready { coord, generation } => (true, *generation, Some(Arc::clone(coord))),
            Phase::Draining { coord, generation } => (false, *generation, Some(Arc::clone(coord))),
            _ => (false, 0, None),
        }
    };
    let rec = telemetry::recorder();
    json::obj(vec![
        ("role", json::s("replica")),
        ("node", json::s(&rec.node())),
        ("ready", Json::Bool(ready)),
        ("generation", json::num(generation as f64)),
        ("in_flight", json::num(shared.in_flight.load(Ordering::Acquire) as f64)),
        ("metrics", coord.map(|c| c.metrics().to_json()).unwrap_or(Json::Null)),
        ("stages", rec.stages_json()),
    ])
}

/// Serve one `MetricsQuery`: an unknown format byte degrades to JSON so
/// newer scrapers stay compatible with older replicas and vice versa.
fn metrics_reply(shared: &Shared, format: u8) -> WireMsg {
    let doc = metrics_doc(shared);
    let body = if format == wire::format::PROMETHEUS {
        telemetry::export::prometheus(&doc)
    } else {
        json::to_string_pretty(&doc)
    };
    WireMsg::MetricsReply { body }
}

/// The replica's health/readiness document (see the module docs).
fn health_json(shared: &Shared) -> String {
    let (ready, draining, generation, coord) = {
        let phase = lock_unpoisoned(&shared.phase);
        match &*phase {
            Phase::Ready { coord, generation } => (true, false, *generation, Some(Arc::clone(coord))),
            Phase::Draining { coord, generation } => {
                (false, true, *generation, Some(Arc::clone(coord)))
            }
            Phase::Booting => (false, false, 0, None),
            Phase::Failed(_) | Phase::Stopped => (false, true, 0, None),
        }
    };
    let mut routes = Vec::new();
    let coordinator = match &coord {
        Some(c) => {
            for (model, method) in c.router().models() {
                if let Ok(r) = c.router().route(&model, &method) {
                    routes.push(json::obj(vec![
                        ("model", json::s(&model)),
                        ("method", json::s(&method)),
                        ("input_len", json::num(r.sample_input_len as f64)),
                        ("output_len", json::num(r.sample_output_len as f64)),
                    ]));
                }
            }
            json::obj(vec![
                ("health", c.health().to_json()),
                ("metrics", c.metrics().to_json()),
            ])
        }
        None => Json::Null,
    };
    json::to_string_pretty(&json::obj(vec![
        ("role", json::s("replica")),
        ("ready", Json::Bool(ready)),
        ("draining", Json::Bool(draining)),
        ("generation", json::num(generation as f64)),
        ("store_generation", json::num(shared.store_generation() as f64)),
        ("in_flight", json::num(shared.in_flight.load(Ordering::Acquire) as f64)),
        ("fates_cached", json::num(lock_unpoisoned(&shared.fates).len() as f64)),
        ("routes", Json::Arr(routes)),
        ("coordinator", coordinator),
    ]))
}

/// Move a `Ready` replica to `Draining` (idempotent; no-op in any other
/// phase). Returns once the phase is set — in-flight requests are still
/// finishing when this returns.
fn start_drain(shared: &Shared) {
    let mut phase = lock_unpoisoned(&shared.phase);
    if let Phase::Ready { coord, generation } = &*phase {
        let (coord, generation) = (Arc::clone(coord), *generation);
        *phase = Phase::Draining { coord, generation };
    }
}

/// Wait (bounded) until no handler holds the engine.
fn wait_in_flight_zero(shared: &Shared, deadline: Duration) -> bool {
    let t0 = Instant::now();
    while shared.in_flight.load(Ordering::Acquire) > 0 {
        if t0.elapsed() > deadline {
            return false;
        }
        thread::sleep(Duration::from_millis(2));
    }
    true
}

/// Take the coordinator out of the phase (leaving `Booting`) and shut it
/// down through the bounded drain path.
fn retire_coordinator(shared: &Shared) {
    let taken = {
        let mut phase = lock_unpoisoned(&shared.phase);
        match std::mem::replace(&mut *phase, Phase::Booting) {
            Phase::Ready { coord, .. } | Phase::Draining { coord, .. } => Some(coord),
            other => {
                *phase = other;
                None
            }
        }
    };
    if let Some(coord) = taken {
        // sole owner: drain explicitly with the configured deadline. A
        // straggler handler still holding a clone keeps the Err side, and
        // its drop runs the same bounded drain.
        if let Ok(c) = Arc::try_unwrap(coord) {
            c.shutdown_within(shared.cfg.serve.drain_deadline);
        }
    }
}

/// Drain → reboot from the store → ready. The caller already holds the
/// control lock. Returns the new generation.
fn reload(shared: &Shared) -> Result<u64, String> {
    start_drain(shared);
    wait_in_flight_zero(shared, shared.cfg.serve.drain_deadline + Duration::from_secs(5));
    retire_coordinator(shared);
    match boot(&shared.cfg, &shared.store_root) {
        Ok((coord, generation)) => {
            *lock_unpoisoned(&shared.phase) = Phase::Ready { coord, generation };
            Ok(generation)
        }
        Err(e) => {
            *lock_unpoisoned(&shared.phase) = Phase::Failed(e.clone());
            Err(e)
        }
    }
}

/// Graceful stop: drain, retire the coordinator (leftovers answered
/// `EngineShutdown` by its bounded drain), mark `Stopped`, end the
/// accept loop. Live connections keep getting typed `DRAINING` replies
/// until their peers close — deregistration, not conn-drop.
fn graceful_stop(shared: &Shared) {
    let _ctl = lock_unpoisoned(&shared.control);
    start_drain(shared);
    wait_in_flight_zero(shared, shared.cfg.serve.drain_deadline + Duration::from_secs(5));
    retire_coordinator(shared);
    *lock_unpoisoned(&shared.phase) = Phase::Stopped;
    shared.stop.store(true, Ordering::Release);
}

/// Abrupt stop (process-kill semantics, used by the `replica_exit` fault
/// and [`ReplicaServer::kill`]): no drain, connections severed.
fn abrupt_stop(shared: &Shared, reason: &str) {
    shared.stop.store(true, Ordering::Release);
    let prev = {
        let mut phase = lock_unpoisoned(&shared.phase);
        std::mem::replace(&mut *phase, Phase::Failed(reason.to_string()))
    };
    // drop any owned coordinator outside the phase lock: its Drop runs a
    // bounded drain, and health queries must not block behind it
    drop(prev);
    let conns = std::mem::take(&mut *lock_unpoisoned(&shared.conns));
    for (_, stream) in conns {
        let _ = stream.shutdown(std::net::Shutdown::Both);
    }
}

/// One connection's serve loop: recv → handle → send until the peer
/// closes, the bytes turn hostile, or the replica stops.
fn serve_conn(shared: &Arc<Shared>, mut stream: TcpStream, conn_id: u64) {
    loop {
        if shared.stop.load(Ordering::Acquire) {
            break;
        }
        // hostile bytes, a clean close, and a torn frame all end the
        // connection the same way: no reply a parser could misread
        let Ok(msg) = wire::recv(&mut stream) else { break };
        let verdict = match msg {
            WireMsg::Request { id, model, method, deadline_us, input, trace } => {
                handle_request(shared, id, &model, &method, deadline_us, input, trace)
            }
            WireMsg::HealthQuery => {
                Verdict::Reply(WireMsg::HealthReply { json: health_json(shared) })
            }
            WireMsg::MetricsQuery { format } => Verdict::Reply(metrics_reply(shared, format)),
            WireMsg::TraceQuery { trace } => {
                let filter = (trace != 0).then_some(trace);
                let doc = telemetry::recorder().trace_json(filter, wire::TRACE_DUMP_LIMIT);
                Verdict::Reply(WireMsg::TraceReply { json: json::to_string_pretty(&doc) })
            }
            WireMsg::Drain => {
                let _ctl = lock_unpoisoned(&shared.control);
                start_drain(shared);
                Verdict::Reply(WireMsg::Ok)
            }
            WireMsg::Reload => {
                let _ctl = lock_unpoisoned(&shared.control);
                match reload(shared) {
                    Ok(_) => Verdict::Reply(WireMsg::Ok),
                    Err(e) => Verdict::Reply(WireMsg::Error {
                        id: 0,
                        code: wire::code::EXECUTION,
                        a: 0,
                        b: 0,
                        detail: e,
                    }),
                }
            }
            WireMsg::Shutdown => {
                graceful_stop(shared);
                Verdict::ReplyClose(WireMsg::Ok)
            }
            // replies arriving at a replica are a protocol violation
            WireMsg::Response { .. }
            | WireMsg::Error { .. }
            | WireMsg::HealthReply { .. }
            | WireMsg::MetricsReply { .. }
            | WireMsg::TraceReply { .. }
            | WireMsg::Ok => Verdict::Drop,
        };
        match verdict {
            Verdict::Reply(reply) => {
                if wire::send(&mut stream, &reply).is_err() {
                    break;
                }
            }
            Verdict::ReplyClose(reply) => {
                let _ = wire::send(&mut stream, &reply);
                break;
            }
            Verdict::Drop => break,
        }
    }
    lock_unpoisoned(&shared.conns).remove(&conn_id);
}

/// A running replica: TCP listener + warm-booting coordinator. Binding
/// is synchronous (the address is known immediately); the boot happens on
/// a background thread, and the replica answers `NOT_READY` until it
/// lands. See the module docs for the full lifecycle.
pub struct ReplicaServer {
    addr: SocketAddr,
    shared: Arc<Shared>,
    accept: Option<thread::JoinHandle<()>>,
}

impl ReplicaServer {
    /// Bind `bind` (e.g. `127.0.0.1:0` for an ephemeral port) and start
    /// serving. Returns as soon as the socket is bound.
    pub fn spawn(bind: &str, cfg: ReplicaConfig) -> Result<ReplicaServer> {
        let listener = TcpListener::bind(bind).with_context(|| format!("binding {bind}"))?;
        listener.set_nonblocking(true).map_err(|e| anyhow!("set_nonblocking: {e}"))?;
        let addr = listener.local_addr().map_err(|e| anyhow!("local_addr: {e}"))?;
        let store_root = cfg.native.plan_store.clone();
        let shared = Arc::new(Shared {
            phase: Mutex::new(Phase::Booting),
            stop: AtomicBool::new(false),
            in_flight: AtomicUsize::new(0),
            fates: Mutex::new(FateCache::new(1024)),
            conns: Mutex::new(HashMap::new()),
            conn_seq: AtomicU64::new(0),
            control: Mutex::new(()),
            cfg,
            store_root,
        });
        // warm-boot off-thread so the listener (and health endpoint) are
        // up immediately; requests in the gap get typed NOT_READY
        {
            let shared = Arc::clone(&shared);
            thread::spawn(move || {
                let booted = boot(&shared.cfg, &shared.store_root);
                let mut phase = lock_unpoisoned(&shared.phase);
                if matches!(&*phase, Phase::Booting) {
                    *phase = match booted {
                        Ok((coord, generation)) => Phase::Ready { coord, generation },
                        Err(e) => Phase::Failed(e),
                    };
                }
            });
        }
        let accept = {
            let shared = Arc::clone(&shared);
            thread::spawn(move || loop {
                if shared.stop.load(Ordering::Acquire) {
                    break;
                }
                match listener.accept() {
                    Ok((stream, _peer)) => {
                        let _ = stream.set_nonblocking(false);
                        let conn_id = shared.conn_seq.fetch_add(1, Ordering::Relaxed);
                        if let Ok(dup) = stream.try_clone() {
                            lock_unpoisoned(&shared.conns).insert(conn_id, dup);
                        }
                        let shared = Arc::clone(&shared);
                        thread::spawn(move || serve_conn(&shared, stream, conn_id));
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        thread::sleep(Duration::from_millis(5));
                    }
                    Err(_) => thread::sleep(Duration::from_millis(5)),
                }
            })
        };
        Ok(ReplicaServer { addr, shared, accept: Some(accept) })
    }

    /// The bound address.
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// True once warm-boot completed and the replica is admitting.
    pub fn ready(&self) -> bool {
        matches!(&*lock_unpoisoned(&self.shared.phase), Phase::Ready { .. })
    }

    /// True while the serve loop is running (stops after a graceful or
    /// abrupt stop, local or remote).
    pub fn alive(&self) -> bool {
        !self.shared.stop.load(Ordering::Acquire)
    }

    /// Block until [`ReplicaServer::ready`] or the timeout. Returns the
    /// readiness verdict.
    pub fn wait_ready(&self, timeout: Duration) -> bool {
        let t0 = Instant::now();
        while t0.elapsed() < timeout {
            match &*lock_unpoisoned(&self.shared.phase) {
                Phase::Ready { .. } => return true,
                Phase::Failed(_) | Phase::Stopped => return false,
                _ => {}
            }
            thread::sleep(Duration::from_millis(10));
        }
        false
    }

    /// The replica's scrapeable metrics document — the same content the
    /// `MetricsQuery` wire verb serves (`wingan replica --stats-every`
    /// prints this periodically).
    pub fn metrics_json(&self) -> Json {
        metrics_doc(&self.shared)
    }

    /// If warm-boot failed, the error.
    pub fn boot_error(&self) -> Option<String> {
        match &*lock_unpoisoned(&self.shared.phase) {
            Phase::Failed(e) => Some(e.clone()),
            _ => None,
        }
    }

    /// Graceful shutdown: drain in-flight work (bounded by the serve
    /// config's `drain_deadline`; leftovers answered `EngineShutdown`),
    /// report `draining` to the prober so the router deregisters first,
    /// then stop.
    pub fn shutdown(mut self) {
        graceful_stop(&self.shared);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }

    /// Abrupt kill (process-death semantics, for chaos drills): no drain,
    /// live connections severed mid-request.
    pub fn kill(mut self) {
        abrupt_stop(&self.shared, "killed");
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }

    /// Block the calling thread until the serve loop ends (remote
    /// `Shutdown`, `replica_exit` fault, or [`ReplicaServer::shutdown`]
    /// from another thread).
    pub fn join(mut self) {
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

impl Drop for ReplicaServer {
    fn drop(&mut self) {
        // dropped without an explicit verdict: stop accepting; the
        // retired coordinator's own Drop runs its bounded drain
        self.shared.stop.store(true, Ordering::Release);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        retire_coordinator(&self.shared);
        *lock_unpoisoned(&self.shared.phase) = Phase::Stopped;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fate_cache_first_fate_wins_and_is_bitwise_stable() {
        let mut c = FateCache::new(8);
        let first = WireMsg::Response {
            id: 1,
            batch_size: 4,
            queue_us: 10,
            exec_us: 20,
            output: vec![1.0, 2.0],
        };
        let second = WireMsg::Response {
            id: 1,
            batch_size: 8,
            queue_us: 99,
            exec_us: 99,
            output: vec![9.0],
        };
        assert!(c.put(1, first.clone()));
        assert!(!c.put(1, second), "second fate for one id must be refused");
        let got = c.get(1).unwrap();
        assert_eq!(got, &first);
        assert_eq!(got.encode(), first.encode(), "replayed frame is bitwise identical");
        assert!(c.get(2).is_none());
    }

    #[test]
    fn fate_cache_reservation_admits_one_executor_per_id() {
        let mut c = FateCache::new(8);
        assert!(c.reserve(1), "first executor claims the id");
        assert!(!c.reserve(1), "a duplicate of an in-flight id must wait, not execute");
        assert!(c.pending(1));
        // fate recorded: the reservation clears and the replay path opens
        assert!(c.put(1, WireMsg::Ok));
        assert!(!c.pending(1));
        assert!(!c.reserve(1), "a fated id can never be re-reserved");
        assert_eq!(c.get(1), Some(&WireMsg::Ok));
    }

    #[test]
    fn fate_cache_release_without_a_fate_reopens_the_id() {
        let mut c = FateCache::new(8);
        assert!(c.reserve(2));
        c.release(2);
        assert!(!c.pending(2));
        assert!(c.reserve(2), "a never-executed id can be claimed again");
        assert!(c.get(2).is_none(), "release records no fate");
    }

    #[test]
    fn fate_cache_evicts_fifo_and_stays_bounded() {
        let mut c = FateCache::new(3);
        for id in 0..10u64 {
            assert!(c.put(id, WireMsg::Ok));
            assert!(c.len() <= 3, "cap violated at id {id}");
        }
        assert!(!c.is_empty());
        // the three newest survive; the oldest are gone
        assert!(c.get(9).is_some() && c.get(8).is_some() && c.get(7).is_some());
        assert!(c.get(0).is_none() && c.get(6).is_none());
    }
}
