//! The fleet router: least-loaded failover routing over N replicas.
//!
//! A [`FleetRouter`] fronts a set of [`crate::fleet::replica`] processes
//! (or in-process [`ReplicaServer`](crate::fleet::replica::ReplicaServer)s
//! — the wire doesn't care) with:
//!
//! * a background **prober** polling every replica's health JSON on an
//!   interval: readiness, drain state, plan generation, and the route
//!   table (cached for clients that want to know what the fleet serves);
//! * **least-loaded routing**: among admitting replicas, pick the one
//!   with the fewest in-flight requests, EWMA latency as the tie-break;
//! * a per-replica **circuit breaker** (consecutive transport failures
//!   open it; after a cooldown a single half-open probe request decides
//!   whether it closes again);
//! * **retry-with-backoff failover** under the request's deadline
//!   budget: transport failures and never-executed typed verdicts
//!   ([`wire::retryable`]) fail over to another replica with capped
//!   exponential backoff. The router assigns each request one wire id
//!   and reuses it across every attempt, so replicas recognise resends
//!   and replay the recorded fate — a retried completion is bitwise
//!   identical and never executes twice;
//! * **graceful degradation**: when no replica admits, requests shed
//!   *immediately* with typed
//!   [`Rejected::FleetUnavailable`] — the fleet never hangs a client on
//!   capacity it doesn't have;
//! * **rolling republish** ([`FleetRouter::roll_to_generation`]): when
//!   the shared store's generation tag moves, replicas are rolled one at
//!   a time — quiesce → `Drain` → `Reload` → readiness-gate (the reload
//!   `Ok` plus a health probe confirming the new generation) → readmit —
//!   so clients never see mixed-generation outputs and the fleet never
//!   loses more than one replica of capacity to a republish.

use crate::coordinator::{GenResponse, Rejected, ServeError};
use crate::fleet::wire::{self, RecvError, WireMsg};
use crate::telemetry::{self, Stage};
use crate::util::json::{self, Json};
use crate::util::lock_unpoisoned;
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

/// Router tuning. The defaults suit loopback test fleets; production
/// would stretch the probe interval and timeouts.
#[derive(Clone, Debug)]
pub struct FleetConfig {
    /// replica addresses (`host:port`) to front at startup
    pub replicas: Vec<String>,
    /// health-probe period
    pub probe_interval: Duration,
    /// EWMA smoothing factor for per-replica latency, in `(0, 1]`
    pub ewma_alpha: f64,
    /// consecutive transport failures that open a replica's breaker
    pub breaker_threshold: u32,
    /// how long an open breaker rejects before a half-open probe
    pub breaker_cooldown: Duration,
    /// first retry backoff (doubles per attempt)
    pub backoff_base: Duration,
    /// backoff cap
    pub backoff_max: Duration,
    /// attempts per request (first try + failovers)
    pub max_attempts: u32,
    /// TCP connect timeout per attempt
    pub connect_timeout: Duration,
    /// request round-trip cap when the request carries no deadline
    pub default_timeout: Duration,
    /// plan-store root to watch: when its generation tag moves past what
    /// the replicas are serving, a rolling reload starts automatically
    pub store: Option<std::path::PathBuf>,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            replicas: Vec::new(),
            probe_interval: Duration::from_millis(100),
            ewma_alpha: 0.3,
            breaker_threshold: 3,
            breaker_cooldown: Duration::from_millis(400),
            backoff_base: Duration::from_millis(5),
            backoff_max: Duration::from_millis(160),
            max_attempts: 4,
            connect_timeout: Duration::from_millis(500),
            default_timeout: Duration::from_secs(30),
            store: None,
        }
    }
}

/// Per-replica circuit breaker: a pure state machine (no clock of its
/// own — every transition takes `now`), so the trip/half-open/close
/// choreography is unit-testable without sleeping.
#[derive(Clone, Debug)]
pub struct Breaker {
    threshold: u32,
    cooldown: Duration,
    consecutive: u32,
    open_until: Option<Instant>,
    half_open: bool,
}

impl Breaker {
    /// Closed breaker tripping after `threshold` consecutive failures,
    /// cooling down for `cooldown` before the half-open probe.
    pub fn new(threshold: u32, cooldown: Duration) -> Breaker {
        Breaker { threshold: threshold.max(1), cooldown, consecutive: 0, open_until: None, half_open: false }
    }

    /// A request (or probe) succeeded: close fully.
    pub fn on_success(&mut self) {
        self.consecutive = 0;
        self.open_until = None;
        self.half_open = false;
    }

    /// A transport failure. A failure while half-open re-opens
    /// immediately (the probe failed); otherwise the consecutive count
    /// advances and trips the breaker at the threshold.
    pub fn on_failure(&mut self, now: Instant) {
        if self.half_open {
            self.half_open = false;
            self.open_until = now.checked_add(self.cooldown);
            return;
        }
        self.consecutive = self.consecutive.saturating_add(1);
        if self.consecutive >= self.threshold {
            self.open_until = now.checked_add(self.cooldown);
        }
    }

    /// Would [`Breaker::admits`] say yes right now, **without** consuming
    /// the half-open probe token? Candidate scans must peek with this and
    /// spend the token (via `admits`) only on the slot actually routed
    /// to — a cooled-down replica that merely loses a load comparison
    /// would otherwise burn its single probe with no request ever sent,
    /// ejecting it from routing forever.
    pub fn would_admit(&self, now: Instant) -> bool {
        match self.open_until {
            None => true,
            Some(t) if now >= t => !self.half_open,
            Some(_) => false,
        }
    }

    /// May a request be routed here right now? Once the cooldown
    /// expires this admits exactly **one** half-open probe; further
    /// requests are rejected until that probe's verdict arrives.
    pub fn admits(&mut self, now: Instant) -> bool {
        match self.open_until {
            None => true,
            Some(t) if now >= t => {
                if self.half_open {
                    false
                } else {
                    self.half_open = true;
                    true
                }
            }
            Some(_) => false,
        }
    }

    /// Position label for status reporting.
    pub fn state(&self, now: Instant) -> &'static str {
        match self.open_until {
            None => "closed",
            Some(t) if self.half_open || now >= t => "half-open",
            Some(_) => "open",
        }
    }

    /// Fully close (used when a rolled replica passes its readiness gate).
    pub fn reset(&mut self) {
        self.on_success();
    }
}

/// One route the fleet serves, as learned from replica health probes.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RouteInfo {
    /// zoo model id
    pub model: String,
    /// compute path ("winograd" / "tdc")
    pub method: String,
    /// per-sample flat input length
    pub input_len: usize,
    /// per-sample flat output length
    pub output_len: usize,
}

struct ReplicaSlot {
    addr: String,
    sock: SocketAddr,
    ready: bool,
    draining: bool,
    /// quiesced for a rolling reload; not routable until readmitted
    rolling: bool,
    generation: u64,
    /// EWMA request latency in ms (0 = no sample yet)
    ewma_ms: f64,
    in_flight: Arc<AtomicUsize>,
    breaker: Breaker,
    completed: u64,
    transport_failures: u64,
}

#[derive(Default)]
struct RouterStats {
    requests: AtomicU64,
    completed: AtomicU64,
    failovers: AtomicU64,
    shed_unavailable: AtomicU64,
}

struct Inner {
    cfg: FleetConfig,
    slots: Mutex<Vec<ReplicaSlot>>,
    routes: Mutex<Vec<RouteInfo>>,
    stop: AtomicBool,
    next_id: AtomicU64,
    stats: RouterStats,
    /// serializes rolling reloads (manual and store-watch triggered)
    roll_lock: Mutex<()>,
    /// an auto-roll thread is running (one in flight at a time)
    auto_roll: AtomicBool,
}

/// One replica's row in [`FleetStatus`].
#[derive(Clone, Debug)]
pub struct ReplicaStatus {
    /// replica address
    pub addr: String,
    /// admitting requests (probe verdict)
    pub ready: bool,
    /// drain in progress on the replica
    pub draining: bool,
    /// quiesced by a rolling reload
    pub rolling: bool,
    /// plan generation the replica serves
    pub generation: u64,
    /// breaker position label
    pub breaker: &'static str,
    /// EWMA request latency in ms
    pub ewma_ms: f64,
    /// requests in flight via this router
    pub in_flight: usize,
    /// completions via this router
    pub completed: u64,
    /// transport failures via this router
    pub transport_failures: u64,
}

/// Snapshot of the fleet as the router sees it.
#[derive(Clone, Debug)]
pub struct FleetStatus {
    /// per-replica rows
    pub replicas: Vec<ReplicaStatus>,
    /// routes learned from the fleet
    pub routes: Vec<RouteInfo>,
    /// requests submitted via this router
    pub requests: u64,
    /// completions via this router
    pub completed: u64,
    /// failover attempts (retries on another pick)
    pub failovers: u64,
    /// requests shed with [`Rejected::FleetUnavailable`]
    pub shed_unavailable: u64,
}

impl FleetStatus {
    /// Every replica admitting, none draining or mid-roll.
    pub fn all_ready(&self) -> bool {
        !self.replicas.is_empty()
            && self.replicas.iter().all(|r| r.ready && !r.draining && !r.rolling)
    }

    /// Replicas currently admitting.
    pub fn ready_count(&self) -> usize {
        self.replicas.iter().filter(|r| r.ready && !r.draining && !r.rolling).count()
    }

    /// Machine-readable form (CI smoke and `wingan probe` parse this;
    /// stable-key contract as elsewhere).
    pub fn to_json(&self) -> Json {
        let replicas: Vec<Json> = self
            .replicas
            .iter()
            .map(|r| {
                json::obj(vec![
                    ("addr", json::s(&r.addr)),
                    ("ready", Json::Bool(r.ready)),
                    ("draining", Json::Bool(r.draining)),
                    ("rolling", Json::Bool(r.rolling)),
                    ("generation", json::num(r.generation as f64)),
                    ("breaker", json::s(r.breaker)),
                    ("ewma_ms", json::num(r.ewma_ms)),
                    ("in_flight", json::num(r.in_flight as f64)),
                    ("completed", json::num(r.completed as f64)),
                    ("transport_failures", json::num(r.transport_failures as f64)),
                ])
            })
            .collect();
        let routes: Vec<Json> = self
            .routes
            .iter()
            .map(|r| {
                json::obj(vec![
                    ("model", json::s(&r.model)),
                    ("method", json::s(&r.method)),
                    ("input_len", json::num(r.input_len as f64)),
                    ("output_len", json::num(r.output_len as f64)),
                ])
            })
            .collect();
        json::obj(vec![
            ("role", json::s("router")),
            ("all_ready", Json::Bool(self.all_ready())),
            ("ready_count", json::num(self.ready_count() as f64)),
            ("replicas", Json::Arr(replicas)),
            ("routes", Json::Arr(routes)),
            ("requests", json::num(self.requests as f64)),
            ("completed", json::num(self.completed as f64)),
            ("failovers", json::num(self.failovers as f64)),
            ("shed_unavailable", json::num(self.shed_unavailable as f64)),
        ])
    }
}

/// One wire round-trip: connect, send, receive, with every stage under a
/// timeout so a stalled replica costs bounded time, never a hang.
fn call(sock: SocketAddr, msg: &WireMsg, connect: Duration, io: Duration) -> Result<WireMsg, String> {
    let mut stream =
        TcpStream::connect_timeout(&sock, connect).map_err(|e| format!("connect {sock}: {e}"))?;
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(io));
    let _ = stream.set_write_timeout(Some(io));
    wire::send(&mut stream, msg).map_err(|e| format!("send {sock}: {e}"))?;
    match wire::recv(&mut stream) {
        Ok(reply) => Ok(reply),
        Err(RecvError::Closed) => Err(format!("{sock} closed the connection")),
        Err(RecvError::Io(e)) => Err(format!("recv {sock}: {e}")),
        Err(RecvError::Wire(e)) => Err(format!("protocol error from {sock}: {e}")),
    }
}

fn parse_sock(addr: &str) -> Result<SocketAddr, String> {
    addr.to_socket_addrs()
        .map_err(|e| format!("bad replica address '{addr}': {e}"))?
        .next()
        .ok_or_else(|| format!("replica address '{addr}' resolves to nothing"))
}

impl Inner {
    /// Apply one health probe result to a slot.
    fn note_probe(&self, addr: &str, verdict: Option<&Json>) {
        let mut slots = lock_unpoisoned(&self.slots);
        let Some(slot) = slots.iter_mut().find(|s| s.addr == addr) else { return };
        match verdict {
            Some(doc) => {
                slot.ready = matches!(doc.get("ready"), Some(Json::Bool(true)));
                slot.draining = matches!(doc.get("draining"), Some(Json::Bool(true)));
                if let Some(g) = doc.get("generation").and_then(Json::as_usize) {
                    slot.generation = g as u64;
                }
            }
            None => {
                slot.ready = false;
            }
        }
    }

    /// Cache the fleet's route table from the first ready replica's doc.
    fn note_routes(&self, doc: &Json) {
        let Some(arr) = doc.get("routes").and_then(Json::as_arr) else { return };
        if arr.is_empty() {
            return;
        }
        let mut parsed = Vec::new();
        for r in arr {
            let (Some(model), Some(method), Some(input_len), Some(output_len)) = (
                r.get("model").and_then(Json::as_str),
                r.get("method").and_then(Json::as_str),
                r.get("input_len").and_then(Json::as_usize),
                r.get("output_len").and_then(Json::as_usize),
            ) else {
                return;
            };
            parsed.push(RouteInfo {
                model: model.to_string(),
                method: method.to_string(),
                input_len,
                output_len,
            });
        }
        *lock_unpoisoned(&self.routes) = parsed;
    }

    /// One prober sweep: health-query every replica, then check the
    /// watched store for a generation the fleet hasn't rolled to yet.
    fn probe_once(self: &Arc<Self>) {
        let addrs: Vec<(String, SocketAddr)> = lock_unpoisoned(&self.slots)
            .iter()
            .map(|s| (s.addr.clone(), s.sock))
            .collect();
        for (addr, sock) in addrs {
            let reply = call(
                sock,
                &WireMsg::HealthQuery,
                self.cfg.connect_timeout,
                Duration::from_secs(1),
            );
            match reply {
                Ok(WireMsg::HealthReply { json: text }) => match json::parse(&text) {
                    Ok(doc) => {
                        self.note_probe(&addr, Some(&doc));
                        if matches!(doc.get("ready"), Some(Json::Bool(true))) {
                            self.note_routes(&doc);
                        }
                    }
                    Err(_) => self.note_probe(&addr, None),
                },
                _ => self.note_probe(&addr, None),
            }
        }
        if let Some(store) = &self.cfg.store {
            let store_gen = crate::artifact::read_generation(store);
            let stale = lock_unpoisoned(&self.slots)
                .iter()
                .any(|s| s.ready && !s.rolling && s.generation < store_gen);
            // the roll runs on its own thread: a rolling reload can take
            // minutes (drain + warm-boot per replica), and the prober
            // must keep sweeping health the whole time — a replica that
            // crashes or drains mid-roll has to lose its ready bit on
            // schedule, not after the roll lands. `auto_roll` keeps one
            // roll in flight; a failed roll re-arms on a later sweep.
            if stale && !self.auto_roll.swap(true, Ordering::AcqRel) {
                let inner = Arc::clone(self);
                thread::spawn(move || {
                    // best-effort: a failed roll is retried on a later sweep
                    let _ = inner.roll_to_generation(store_gen, Duration::from_secs(300));
                    inner.auto_roll.store(false, Ordering::Release);
                });
            }
        }
    }

    /// Pick the least-loaded admitting replica. `None` = fleet out.
    fn pick(&self) -> Option<(String, SocketAddr, Arc<AtomicUsize>)> {
        let now = Instant::now();
        let mut slots = lock_unpoisoned(&self.slots);
        let mut best: Option<(usize, usize, f64)> = None; // (idx, in_flight, ewma)
        for (idx, slot) in slots.iter().enumerate() {
            // peek only: the half-open probe token is consumed below, for
            // the winner alone — a candidate that loses the comparison
            // must keep its token or it can never be probed back in
            if !slot.ready || slot.draining || slot.rolling || !slot.breaker.would_admit(now) {
                continue;
            }
            let load = slot.in_flight.load(Ordering::Acquire);
            let better = match best {
                None => true,
                Some((_, b_load, b_ewma)) => {
                    load < b_load || (load == b_load && slot.ewma_ms.total_cmp(&b_ewma).is_lt())
                }
            };
            if better {
                best = Some((idx, load, slot.ewma_ms));
            }
        }
        best.map(|(idx, _, _)| {
            let s = &mut slots[idx];
            // same lock, same `now`: the winner's admits() must agree
            // with the would_admit() that nominated it
            let admitted = s.breaker.admits(now);
            debug_assert!(admitted);
            (s.addr.clone(), s.sock, Arc::clone(&s.in_flight))
        })
    }

    fn fleet_size(&self) -> usize {
        lock_unpoisoned(&self.slots).len()
    }

    fn replica_socks(&self) -> Vec<(String, SocketAddr)> {
        lock_unpoisoned(&self.slots).iter().map(|s| (s.addr.clone(), s.sock)).collect()
    }

    fn note_outcome(&self, addr: &str, latency: Option<Duration>, transport_failure: bool) {
        let now = Instant::now();
        let mut slots = lock_unpoisoned(&self.slots);
        let Some(slot) = slots.iter_mut().find(|s| s.addr == addr) else { return };
        if transport_failure {
            slot.transport_failures += 1;
            slot.breaker.on_failure(now);
        } else {
            slot.breaker.on_success();
        }
        if let Some(lat) = latency {
            let ms = lat.as_secs_f64() * 1e3;
            slot.completed += 1;
            slot.ewma_ms = if slot.ewma_ms == 0.0 {
                ms
            } else {
                self.cfg.ewma_alpha * ms + (1.0 - self.cfg.ewma_alpha) * slot.ewma_ms
            };
        }
    }

    fn submit(
        &self,
        model: &str,
        method: &str,
        input: Vec<f32>,
        budget: Option<Duration>,
        trace: u64,
    ) -> Result<GenResponse, ServeError> {
        self.stats.requests.fetch_add(1, Ordering::Relaxed);
        // adopt the client-supplied trace id, or mint one here if the
        // router is the admission point and this request was sampled
        let trace = if trace != 0 { trace } else { telemetry::recorder().maybe_mint() };
        // request-shape gate: an input too large for one wire frame can
        // never be served — verdict here, typed, instead of every replica
        // dropping the oversized frame and eating a breaker failure
        let max_floats = wire::max_request_floats(model, method);
        if input.len() > max_floats {
            return Err(ServeError::BadInputLength { expected: max_floats, got: input.len() });
        }
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let t0 = Instant::now();
        let deadline = budget.and_then(|b| t0.checked_add(b));
        let mut backoff = self.cfg.backoff_base;
        let mut last_shed: Option<ServeError> = None;
        for attempt in 0..self.cfg.max_attempts {
            if attempt > 0 {
                self.stats.failovers.fetch_add(1, Ordering::Relaxed);
            }
            let remaining = match deadline {
                Some(d) => {
                    let rem = d.saturating_duration_since(Instant::now());
                    if rem.is_zero() {
                        return Err(ServeError::Rejected(Rejected::DeadlineInfeasible {
                            remaining: Duration::ZERO,
                            estimated_wait: Duration::ZERO,
                        }));
                    }
                    Some(rem)
                }
                None => None,
            };
            let Some((addr, sock, in_flight)) = self.pick() else {
                self.stats.shed_unavailable.fetch_add(1, Ordering::Relaxed);
                return Err(ServeError::Rejected(Rejected::FleetUnavailable {
                    replicas: self.fleet_size(),
                }));
            };
            let io_timeout = remaining
                .map_or(self.cfg.default_timeout, |r| r + Duration::from_secs(2));
            let msg = WireMsg::Request {
                id,
                model: model.to_string(),
                method: method.to_string(),
                deadline_us: remaining.map_or(0, |r| r.as_micros() as u64),
                input: input.clone(),
                trace,
            };
            in_flight.fetch_add(1, Ordering::AcqRel);
            let sent = Instant::now();
            let reply = call(sock, &msg, self.cfg.connect_timeout, io_timeout);
            in_flight.fetch_sub(1, Ordering::AcqRel);
            // attempt-level spans: one Wire span per round-trip and one
            // Attempt span carrying the verdict code (0 = ok, typed wire
            // codes as-is, 100 = transport failure, 101 = protocol
            // violation) so a trace shows every replica the request hit
            let span = |verdict: u64| {
                if trace != 0 {
                    let rtt = sent.elapsed();
                    telemetry::record_span(trace, Stage::Wire, sent, rtt, (attempt + 1) as u64, 0, &addr);
                    telemetry::record_span(trace, Stage::Attempt, sent, rtt, (attempt + 1) as u64, verdict, &addr);
                }
            };
            match reply {
                Ok(WireMsg::Response { id: _, batch_size, queue_us, exec_us, output }) => {
                    span(0);
                    self.note_outcome(&addr, Some(sent.elapsed()), false);
                    self.stats.completed.fetch_add(1, Ordering::Relaxed);
                    return Ok(GenResponse {
                        id,
                        output,
                        batch_size: batch_size as usize,
                        queue_time: Duration::from_micros(queue_us),
                        exec_time: Duration::from_micros(exec_us),
                    });
                }
                Ok(WireMsg::Error { code, a, b, detail, .. }) => {
                    span(code as u64);
                    // a typed verdict is a *transport success*: the
                    // replica is alive and talking
                    self.note_outcome(&addr, None, false);
                    let err = wire::error_from_wire(code, a, b, &detail);
                    if !wire::retryable(code) {
                        return Err(err);
                    }
                    if code == wire::code::NOT_READY
                        || code == wire::code::DRAINING
                        || code == wire::code::FAILED
                    {
                        // route around it until the prober re-admits it
                        let mut slots = lock_unpoisoned(&self.slots);
                        if let Some(s) = slots.iter_mut().find(|s| s.addr == addr) {
                            if code == wire::code::DRAINING {
                                s.draining = true;
                            } else {
                                s.ready = false;
                            }
                        }
                    }
                    last_shed = Some(err);
                }
                Ok(_) => {
                    span(101);
                    // protocol violation; treat like a transport failure
                    self.note_outcome(&addr, None, true);
                }
                Err(_) => {
                    span(100);
                    self.note_outcome(&addr, None, true);
                }
            }
            // capped exponential backoff, never past the deadline
            let mut dwell = backoff;
            if let Some(d) = deadline {
                dwell = dwell.min(d.saturating_duration_since(Instant::now()));
            }
            if !dwell.is_zero() {
                thread::sleep(dwell);
            }
            backoff = (backoff * 2).min(self.cfg.backoff_max);
        }
        // attempts exhausted: surface the last typed shed if we have one
        Err(last_shed.unwrap_or_else(|| {
            self.stats.shed_unavailable.fetch_add(1, Ordering::Relaxed);
            ServeError::Rejected(Rejected::FleetUnavailable { replicas: self.fleet_size() })
        }))
    }

    /// Roll every replica not already on `generation` through
    /// drain → reload → readiness-gate → readmit, **one at a time**.
    fn roll_to_generation(&self, generation: u64, deadline: Duration) -> Result<(), String> {
        let _roll = lock_unpoisoned(&self.roll_lock);
        let t0 = Instant::now();
        let addrs: Vec<(String, SocketAddr)> = lock_unpoisoned(&self.slots)
            .iter()
            .map(|s| (s.addr.clone(), s.sock))
            .collect();
        for (addr, sock) in addrs {
            if self.stop.load(Ordering::Acquire) {
                return Err("router stopping, roll abandoned".to_string());
            }
            let (needs_roll, in_flight) = {
                let slots = lock_unpoisoned(&self.slots);
                match slots.iter().find(|s| s.addr == addr) {
                    Some(s) => (s.generation < generation, Arc::clone(&s.in_flight)),
                    None => continue,
                }
            };
            if !needs_roll {
                continue;
            }
            // 1. quiesce: stop routing here, wait for our in-flight to land
            self.set_rolling(&addr, true);
            while in_flight.load(Ordering::Acquire) > 0 {
                if t0.elapsed() > deadline {
                    self.set_rolling(&addr, false);
                    return Err(format!("roll of {addr}: quiesce timed out"));
                }
                if self.stop.load(Ordering::Acquire) {
                    self.set_rolling(&addr, false);
                    return Err(format!("roll of {addr}: router stopping"));
                }
                thread::sleep(Duration::from_millis(2));
            }
            // 2. drain + reload; the reload Ok is the replica saying it
            //    warm-booted the new generation and is admitting again
            let step = |msg: &WireMsg, label: &str, io: Duration| -> Result<(), String> {
                match call(sock, msg, self.cfg.connect_timeout, io) {
                    Ok(WireMsg::Ok) => Ok(()),
                    Ok(WireMsg::Error { detail, .. }) => {
                        Err(format!("roll of {addr}: {label} failed: {detail}"))
                    }
                    Ok(other) => Err(format!("roll of {addr}: {label} got {other:?}")),
                    Err(e) => Err(format!("roll of {addr}: {label}: {e}")),
                }
            };
            let budget = deadline.saturating_sub(t0.elapsed()).max(Duration::from_secs(1));
            if let Err(e) = step(&WireMsg::Drain, "drain", Duration::from_secs(5))
                .and_then(|()| step(&WireMsg::Reload, "reload", budget))
            {
                self.set_rolling(&addr, false);
                return Err(e);
            }
            // 3. readiness gate: confirm via the health document that the
            //    replica is admitting *and* serving the target generation
            match call(sock, &WireMsg::HealthQuery, self.cfg.connect_timeout, Duration::from_secs(2))
            {
                Ok(WireMsg::HealthReply { json: text }) => {
                    let doc = json::parse(&text)
                        .map_err(|e| format!("roll of {addr}: bad health JSON: {e}"))?;
                    let ready = matches!(doc.get("ready"), Some(Json::Bool(true)));
                    let gen =
                        doc.get("generation").and_then(Json::as_usize).unwrap_or(0) as u64;
                    if !ready || gen != generation {
                        self.set_rolling(&addr, false);
                        return Err(format!(
                            "roll of {addr}: readiness gate failed (ready={ready}, \
                             generation={gen}, want {generation})"
                        ));
                    }
                }
                other => {
                    self.set_rolling(&addr, false);
                    return Err(format!("roll of {addr}: readiness probe failed: {other:?}"));
                }
            }
            // 4. readmit with a clean slate
            {
                let mut slots = lock_unpoisoned(&self.slots);
                if let Some(s) = slots.iter_mut().find(|s| s.addr == addr) {
                    s.rolling = false;
                    s.ready = true;
                    s.draining = false;
                    s.generation = generation;
                    s.breaker.reset();
                }
            }
        }
        Ok(())
    }

    fn set_rolling(&self, addr: &str, rolling: bool) {
        let mut slots = lock_unpoisoned(&self.slots);
        if let Some(s) = slots.iter_mut().find(|s| s.addr == addr) {
            s.rolling = rolling;
        }
    }

    fn status(&self) -> FleetStatus {
        let now = Instant::now();
        let replicas = lock_unpoisoned(&self.slots)
            .iter()
            .map(|s| ReplicaStatus {
                addr: s.addr.clone(),
                ready: s.ready,
                draining: s.draining,
                rolling: s.rolling,
                generation: s.generation,
                breaker: s.breaker.state(now),
                ewma_ms: s.ewma_ms,
                in_flight: s.in_flight.load(Ordering::Acquire),
                completed: s.completed,
                transport_failures: s.transport_failures,
            })
            .collect();
        FleetStatus {
            replicas,
            routes: lock_unpoisoned(&self.routes).clone(),
            requests: self.stats.requests.load(Ordering::Relaxed),
            completed: self.stats.completed.load(Ordering::Relaxed),
            failovers: self.stats.failovers.load(Ordering::Relaxed),
            shed_unavailable: self.stats.shed_unavailable.load(Ordering::Relaxed),
        }
    }

    fn make_slot(&self, addr: String, sock: SocketAddr) -> ReplicaSlot {
        ReplicaSlot {
            addr,
            sock,
            ready: false,
            draining: false,
            rolling: false,
            generation: 0,
            ewma_ms: 0.0,
            in_flight: Arc::new(AtomicUsize::new(0)),
            breaker: Breaker::new(self.cfg.breaker_threshold, self.cfg.breaker_cooldown),
            completed: 0,
            transport_failures: 0,
        }
    }
}

/// The fleet router handle (see the module docs). Cheap to share behind
/// an `Arc`; dropping the last handle stops the prober.
pub struct FleetRouter {
    inner: Arc<Inner>,
    prober: Option<thread::JoinHandle<()>>,
}

impl FleetRouter {
    /// Build a router over `cfg.replicas` and start the health prober.
    /// Replicas are born unready; the first probe sweep (immediate)
    /// admits the live ones.
    pub fn new(cfg: FleetConfig) -> Result<FleetRouter, String> {
        let mut slots = Vec::new();
        let inner = Arc::new(Inner {
            cfg: cfg.clone(),
            slots: Mutex::new(Vec::new()),
            routes: Mutex::new(Vec::new()),
            stop: AtomicBool::new(false),
            next_id: AtomicU64::new(1),
            stats: RouterStats::default(),
            roll_lock: Mutex::new(()),
            auto_roll: AtomicBool::new(false),
        });
        for addr in &cfg.replicas {
            let sock = parse_sock(addr)?;
            slots.push(inner.make_slot(addr.clone(), sock));
        }
        *lock_unpoisoned(&inner.slots) = slots;
        let prober = {
            let inner = Arc::clone(&inner);
            thread::spawn(move || {
                while !inner.stop.load(Ordering::Acquire) {
                    inner.probe_once();
                    let mut slept = Duration::ZERO;
                    while slept < inner.cfg.probe_interval {
                        if inner.stop.load(Ordering::Acquire) {
                            return;
                        }
                        let step = Duration::from_millis(10).min(inner.cfg.probe_interval);
                        thread::sleep(step);
                        slept += step;
                    }
                }
            })
        };
        Ok(FleetRouter { inner, prober: Some(prober) })
    }

    /// Route one request (see the module docs for the failover contract).
    /// `budget` is the request's total deadline across all attempts.
    pub fn submit(
        &self,
        model: &str,
        method: &str,
        input: Vec<f32>,
        budget: Option<Duration>,
    ) -> Result<GenResponse, ServeError> {
        self.inner.submit(model, method, input, budget, 0)
    }

    /// [`FleetRouter::submit`] with an explicit trace id. `trace == 0`
    /// means "untraced so far": the router's flight recorder may still
    /// sample the request and mint one. A nonzero id (e.g. carried in on
    /// the wire from a client) is adopted as-is, so one id names the
    /// request across every process it touches.
    pub fn submit_traced(
        &self,
        model: &str,
        method: &str,
        input: Vec<f32>,
        budget: Option<Duration>,
        trace: u64,
    ) -> Result<GenResponse, ServeError> {
        self.inner.submit(model, method, input, budget, trace)
    }

    /// Router telemetry document (stable keys: `role`, `node`, `fleet`,
    /// `stages`): the fleet status snapshot plus the router-side stage
    /// histograms from the flight recorder. This is what the wire
    /// `MetricsQuery` verb serves.
    pub fn metrics_json(&self) -> Json {
        let rec = telemetry::recorder();
        json::obj(vec![
            ("role", json::s("router")),
            ("node", json::s(&rec.node())),
            ("fleet", self.status().to_json()),
            ("stages", rec.stages_json()),
        ])
    }

    /// Cross-process trace document: the router's own recent spans merged
    /// with every replica's (each replica is asked over the wire with
    /// [`WireMsg::TraceQuery`]; unreachable replicas are skipped). With
    /// `trace == 0` this dumps recent spans from everywhere; nonzero
    /// filters to one request's end-to-end tree.
    pub fn trace_json(&self, trace: u64) -> Json {
        let rec = telemetry::recorder();
        let filter = (trace != 0).then_some(trace);
        let local = rec.trace_json(filter, wire::TRACE_DUMP_LIMIT);
        let mut spans: Vec<Json> = match local.get("spans").and_then(Json::as_arr) {
            Some(arr) => arr.to_vec(),
            None => Vec::new(),
        };
        for (_, sock) in self.inner.replica_socks() {
            let reply = call(
                sock,
                &WireMsg::TraceQuery { trace },
                self.inner.cfg.connect_timeout,
                Duration::from_secs(2),
            );
            if let Ok(WireMsg::TraceReply { json: text }) = reply {
                if let Ok(doc) = json::parse(&text) {
                    if let Some(arr) = doc.get("spans").and_then(Json::as_arr) {
                        spans.extend(arr.iter().cloned());
                    }
                }
            }
        }
        json::obj(vec![
            ("node", json::s(&rec.node())),
            ("trace", local.get("trace").cloned().unwrap_or(Json::Null)),
            ("sampled", local.get("sampled").cloned().unwrap_or(Json::Null)),
            ("spans", Json::Arr(spans)),
        ])
    }

    /// Current fleet snapshot.
    pub fn status(&self) -> FleetStatus {
        self.inner.status()
    }

    /// Routes the fleet serves (learned from health probes; empty until
    /// the first ready replica has been probed).
    pub fn routes(&self) -> Vec<RouteInfo> {
        lock_unpoisoned(&self.inner.routes).clone()
    }

    /// Roll the fleet to `generation`, one replica at a time.
    pub fn roll_to_generation(&self, generation: u64, deadline: Duration) -> Result<(), String> {
        self.inner.roll_to_generation(generation, deadline)
    }

    /// Front an additional replica (born unready; the prober admits it).
    pub fn add_replica(&self, addr: &str) -> Result<(), String> {
        let sock = parse_sock(addr)?;
        let slot = self.inner.make_slot(addr.to_string(), sock);
        lock_unpoisoned(&self.inner.slots).push(slot);
        Ok(())
    }

    /// Stop fronting `addr` (a dead or decommissioned replica).
    pub fn remove_replica(&self, addr: &str) {
        lock_unpoisoned(&self.inner.slots).retain(|s| s.addr != addr);
    }

    /// Block until [`FleetStatus::all_ready`] or the timeout; returns the
    /// final verdict.
    pub fn wait_all_ready(&self, timeout: Duration) -> bool {
        let t0 = Instant::now();
        while t0.elapsed() < timeout {
            if self.status().all_ready() {
                return true;
            }
            thread::sleep(Duration::from_millis(20));
        }
        self.status().all_ready()
    }
}

impl Drop for FleetRouter {
    fn drop(&mut self) {
        self.inner.stop.store(true, Ordering::Release);
        if let Some(h) = self.prober.take() {
            let _ = h.join();
        }
    }
}

/// A TCP front-end for a [`FleetRouter`]: clients speak the same wire
/// protocol to the router as the router speaks to replicas. `Request`
/// frames are routed with failover (the reply echoes the *client's*
/// request id; the router's own fleet-idempotency ids stay internal);
/// `HealthQuery` answers the fleet status JSON.
pub struct RouterServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<thread::JoinHandle<()>>,
}

impl RouterServer {
    /// Bind and serve. The router handle is shared with the caller, who
    /// keeps using it directly (status, rolls) while clients connect.
    pub fn spawn(bind: &str, router: Arc<FleetRouter>) -> anyhow::Result<RouterServer> {
        use anyhow::Context as _;
        let listener = TcpListener::bind(bind).with_context(|| format!("binding {bind}"))?;
        listener
            .set_nonblocking(true)
            .map_err(|e| anyhow::anyhow!("set_nonblocking: {e}"))?;
        let addr = listener.local_addr().map_err(|e| anyhow::anyhow!("local_addr: {e}"))?;
        let stop = Arc::new(AtomicBool::new(false));
        let accept = {
            let stop = Arc::clone(&stop);
            thread::spawn(move || loop {
                if stop.load(Ordering::Acquire) {
                    break;
                }
                match listener.accept() {
                    Ok((stream, _peer)) => {
                        let _ = stream.set_nonblocking(false);
                        let router = Arc::clone(&router);
                        let stop = Arc::clone(&stop);
                        thread::spawn(move || serve_client(&router, &stop, stream));
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        thread::sleep(Duration::from_millis(5));
                    }
                    Err(_) => thread::sleep(Duration::from_millis(5)),
                }
            })
        };
        Ok(RouterServer { addr, stop, accept: Some(accept) })
    }

    /// The bound address.
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting and join the accept loop.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }

    /// Block until the accept loop ends.
    pub fn join(mut self) {
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

impl Drop for RouterServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

fn serve_client(router: &FleetRouter, stop: &AtomicBool, mut stream: TcpStream) {
    loop {
        if stop.load(Ordering::Acquire) {
            break;
        }
        let Ok(msg) = wire::recv(&mut stream) else { break };
        let reply = match msg {
            WireMsg::Request { id, model, method, deadline_us, input, trace } => {
                let budget = (deadline_us > 0).then(|| Duration::from_micros(deadline_us));
                match router.submit_traced(&model, &method, input, budget, trace) {
                    Ok(resp) => WireMsg::Response {
                        id,
                        batch_size: resp.batch_size as u32,
                        queue_us: resp.queue_time.as_micros() as u64,
                        exec_us: resp.exec_time.as_micros() as u64,
                        output: resp.output,
                    },
                    Err(e) => wire::error_to_wire(id, &e),
                }
            }
            WireMsg::HealthQuery => WireMsg::HealthReply {
                json: json::to_string_pretty(&router.status().to_json()),
            },
            WireMsg::MetricsQuery { format } => {
                let doc = router.metrics_json();
                let body = if format == wire::format::PROMETHEUS {
                    telemetry::export::prometheus(&doc)
                } else {
                    json::to_string_pretty(&doc)
                };
                WireMsg::MetricsReply { body }
            }
            WireMsg::TraceQuery { trace } => WireMsg::TraceReply {
                json: json::to_string_pretty(&router.trace_json(trace)),
            },
            // the router front-end takes requests, probes, and telemetry
            // scrapes, nothing else
            _ => break,
        };
        if wire::send(&mut stream, &reply).is_err() {
            break;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn breaker_trips_after_threshold_consecutive_failures() {
        let t0 = Instant::now();
        let mut b = Breaker::new(3, Duration::from_millis(100));
        assert!(b.admits(t0));
        b.on_failure(t0);
        b.on_failure(t0);
        assert!(b.admits(t0), "below threshold stays closed");
        assert_eq!(b.state(t0), "closed");
        b.on_failure(t0);
        assert!(!b.admits(t0), "third consecutive failure opens it");
        assert_eq!(b.state(t0), "open");
    }

    #[test]
    fn breaker_success_resets_the_consecutive_count() {
        let t0 = Instant::now();
        let mut b = Breaker::new(3, Duration::from_millis(100));
        b.on_failure(t0);
        b.on_failure(t0);
        b.on_success();
        b.on_failure(t0);
        b.on_failure(t0);
        assert!(b.admits(t0), "count restarted after a success");
    }

    #[test]
    fn breaker_half_open_admits_exactly_one_probe() {
        let t0 = Instant::now();
        let mut b = Breaker::new(1, Duration::from_millis(100));
        b.on_failure(t0);
        assert!(!b.admits(t0), "open during cooldown");
        let later = t0 + Duration::from_millis(150);
        assert!(b.admits(later), "cooldown expiry admits the probe");
        assert_eq!(b.state(later), "half-open");
        assert!(!b.admits(later), "only one probe until a verdict");
        // probe succeeds → fully closed
        b.on_success();
        assert!(b.admits(later) && b.admits(later), "closed again");
        assert_eq!(b.state(later), "closed");
    }

    #[test]
    fn breaker_failed_probe_reopens_immediately() {
        let t0 = Instant::now();
        let mut b = Breaker::new(1, Duration::from_millis(100));
        b.on_failure(t0);
        let later = t0 + Duration::from_millis(150);
        assert!(b.admits(later));
        b.on_failure(later);
        assert!(!b.admits(later), "failed probe reopens without a new threshold count");
        assert_eq!(b.state(later), "open");
        let much_later = later + Duration::from_millis(150);
        assert!(b.admits(much_later), "and cools down again");
    }

    #[test]
    fn would_admit_peeks_without_consuming_the_half_open_token() {
        let t0 = Instant::now();
        let mut b = Breaker::new(1, Duration::from_millis(100));
        b.on_failure(t0);
        assert!(!b.would_admit(t0), "open during cooldown");
        let later = t0 + Duration::from_millis(150);
        assert!(b.would_admit(later));
        assert!(b.would_admit(later), "peeking is side-effect free");
        assert!(b.admits(later), "the probe token is still there after peeks");
        assert!(!b.would_admit(later), "token consumed: no second probe until a verdict");
        assert!(!b.admits(later));
        // a losing candidate's token survives the scan, so the next pick
        // that actually routes to it can still half-open it
        b.on_success();
        assert!(b.would_admit(later) && b.admits(later), "closed again");
    }

    #[test]
    fn oversized_input_is_a_typed_shape_error_before_any_routing() {
        // empty fleet: if the gate ran *after* pick(), this would shed
        // FleetUnavailable instead of naming the request's real defect
        let router = FleetRouter::new(FleetConfig::default()).unwrap();
        let cap = wire::max_request_floats("dcgan", "winograd");
        let err = router.submit("dcgan", "winograd", vec![0.0; cap + 1], None).unwrap_err();
        match err {
            ServeError::BadInputLength { expected, got } => {
                assert_eq!(expected, cap);
                assert_eq!(got, cap + 1);
            }
            other => panic!("expected BadInputLength, got {other:?}"),
        }
    }

    #[test]
    fn empty_fleet_sheds_immediately_with_a_typed_verdict() {
        let router = FleetRouter::new(FleetConfig::default()).unwrap();
        let t0 = Instant::now();
        let err = router.submit("dcgan", "winograd", vec![0.0; 4], None).unwrap_err();
        assert_eq!(err, ServeError::Rejected(Rejected::FleetUnavailable { replicas: 0 }));
        assert!(t0.elapsed() < Duration::from_secs(2), "shed, don't hang");
        assert!(err.is_shed());
        let status = router.status();
        assert_eq!(status.shed_unavailable, 1);
        assert!(!status.all_ready());
    }

    #[test]
    fn unreachable_replicas_shed_after_bounded_failover() {
        // a parseable but dead address: breakers absorb the failures and
        // the request comes back typed, not hung
        let cfg = FleetConfig {
            replicas: vec!["127.0.0.1:1".to_string()],
            connect_timeout: Duration::from_millis(50),
            backoff_base: Duration::from_millis(1),
            backoff_max: Duration::from_millis(2),
            max_attempts: 2,
            ..FleetConfig::default()
        };
        let router = FleetRouter::new(cfg).unwrap();
        let err = router.submit("dcgan", "winograd", vec![0.0; 4], None).unwrap_err();
        assert!(
            matches!(err, ServeError::Rejected(Rejected::FleetUnavailable { .. })),
            "got {err:?}"
        );
    }

    #[test]
    fn status_json_has_the_stable_keys() {
        let router = FleetRouter::new(FleetConfig {
            replicas: vec!["127.0.0.1:1".to_string()],
            ..FleetConfig::default()
        })
        .unwrap();
        let doc = router.status().to_json();
        let text = json::to_string_pretty(&doc);
        let back = json::parse(&text).unwrap();
        assert_eq!(back.get("role").and_then(Json::as_str), Some("router"));
        assert!(matches!(back.get("all_ready"), Some(Json::Bool(_))));
        let replicas = back.get("replicas").and_then(Json::as_arr).unwrap();
        assert_eq!(replicas.len(), 1);
        assert_eq!(replicas[0].get("addr").and_then(Json::as_str), Some("127.0.0.1:1"));
        assert!(replicas[0].get("breaker").and_then(Json::as_str).is_some());
    }
}
