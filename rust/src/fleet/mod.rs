//! Fleet-tier serving: health-probed replicas behind a failover router.
//!
//! The serving tier so far scales *within* one process: a
//! [`crate::coordinator::Coordinator`] batches across requests, contains
//! panics, and supervises engine incarnations. This module scales it
//! *across* processes — and makes the whole-process failure domain
//! survivable:
//!
//! ```text
//!                         ┌────────────────────────┐
//!   clients ── wire ────► │ wingan router          │
//!                         │  · health prober       │
//!                         │  · least-loaded pick   │
//!                         │  · breaker + failover  │
//!                         │  · rolling republish   │
//!                         └───┬──────┬──────┬──────┘
//!                        wire │      │      │
//!                      ┌──────▼─┐ ┌──▼─────┐ ┌─▼──────┐
//!                      │replica │ │replica │ │replica │   wingan replica
//!                      │ coord. │ │ coord. │ │ coord. │   (one Coordinator
//!                      └───┬────┘ └───┬────┘ └───┬────┘    each)
//!                          └──────────┼──────────┘
//!                                ┌────▼────┐
//!                                │PlanStore│  shared artifact store,
//!                                └─────────┘  generation-tagged
//! ```
//!
//! * [`wire`] — the std-only length-prefixed TCP protocol both hops
//!   speak: bounds-checked, panic-free decode with typed errors, the
//!   same hostile-bytes discipline as [`crate::artifact::codec`].
//! * [`replica`] — [`ReplicaServer`]: one coordinator behind the wire,
//!   warm-booting from the shared [`crate::artifact::PlanStore`], not
//!   *ready* until warm-boot completes, health/readiness exported as
//!   machine-readable JSON, drain/reload/shutdown control verbs, and a
//!   request-id **fate cache** making retries idempotent (at most one
//!   execution per id; a replayed fate is bitwise identical).
//! * [`router`] — [`FleetRouter`] / [`RouterServer`]: EWMA-probed
//!   least-loaded routing, per-replica circuit breakers,
//!   retry-with-backoff failover inside the request's deadline budget,
//!   typed [`crate::coordinator::Rejected::FleetUnavailable`] when the
//!   whole fleet is out, and one-replica-at-a-time rolling reload when
//!   the store's generation tag moves.
//!
//! The engine's bitwise determinism (same seed + weights → identical
//! bytes, regardless of worker count or batch composition) is what makes
//! fleet failover *safe*, not just available: a request re-executed on a
//! different replica after a crash returns the same bits the dead
//! replica would have — so `wingan chaos --fleet` can kill a replica
//! mid-run and still assert bitwise equality against a single-process
//! baseline.

pub mod replica;
pub mod router;
pub mod wire;

pub use replica::{FateCache, ReplicaConfig, ReplicaServer};
pub use router::{
    Breaker, FleetConfig, FleetRouter, FleetStatus, ReplicaStatus, RouteInfo, RouterServer,
};
pub use wire::{RecvError, WireError, WireMsg};

use crate::coordinator::{GenResponse, ServeError};
use crate::loadgen::{Arrival, ArrivalPlan};
use crate::util::lock_unpoisoned;
use std::sync::{mpsc, Mutex};
use std::thread;
use std::time::Instant;

/// Replay an open-loop arrival schedule through a blocking `submit`
/// (e.g. [`FleetRouter::submit`]) with a pool of client threads, so slow
/// responses never slow the offered rate — the same open-loop honesty as
/// [`crate::loadgen`], adapted to a synchronous RPC path.
///
/// The dispatcher (the calling thread) paces arrivals by their planned
/// offsets and hands them to `workers` client threads; `mid_run`, when
/// given, is a `(arrival_index, callback)` pair fired on the dispatcher
/// exactly once, just before that arrival is dispatched — the chaos and
/// failover harnesses use it to kill a replica mid-run at a
/// deterministic point in the schedule.
///
/// Returns one slot per arrival: `Some(fate)` for every request that was
/// dispatched (success or typed error), in arrival order. Conservation
/// is the caller's assertion; this driver just guarantees every arrival
/// gets exactly one slot.
pub fn drive_open_loop<F, M>(
    plan: &ArrivalPlan,
    workers: usize,
    mid_run: Option<(usize, M)>,
    submit: F,
) -> Vec<Option<Result<GenResponse, ServeError>>>
where
    F: Fn(usize, &Arrival) -> Result<GenResponse, ServeError> + Sync,
    M: FnOnce(),
{
    let n = plan.arrivals.len();
    let results: Mutex<Vec<Option<Result<GenResponse, ServeError>>>> =
        Mutex::new((0..n).map(|_| None).collect());
    let (tx, rx) = mpsc::channel::<(usize, &Arrival)>();
    let rx = Mutex::new(rx);
    let workers = workers.max(1);
    thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| loop {
                // hold the receiver lock only for the dequeue itself
                let msg = { lock_unpoisoned(&rx).recv() };
                let Ok((i, a)) = msg else { break };
                let fate = submit(i, a);
                lock_unpoisoned(&results)[i] = Some(fate);
            });
        }
        let t0 = Instant::now();
        let mut mid = mid_run;
        for (i, a) in plan.arrivals.iter().enumerate() {
            if mid.as_ref().is_some_and(|(at, _)| i >= *at) {
                if let Some((_, f)) = mid.take() {
                    f();
                }
            }
            let target = t0 + a.offset;
            let now = Instant::now();
            if target > now {
                thread::sleep(target - now);
            }
            if tx.send((i, a)).is_err() {
                break;
            }
        }
        // a mid-run event planned past the end of the schedule still fires
        if let Some((_, f)) = mid {
            f();
        }
        drop(tx);
    });
    match results.into_inner() {
        Ok(v) => v,
        Err(p) => p.into_inner(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::loadgen::{RouteLoad, TrafficProfile};
    use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
    use std::time::Duration;

    fn tiny_plan(n: usize) -> ArrivalPlan {
        let profile = TrafficProfile {
            routes: vec![RouteLoad { model: "m".into(), method: "w".into(), weight: 1.0 }],
        };
        ArrivalPlan::generate(&profile, &[4], n, 50_000.0, 9)
    }

    #[test]
    fn open_loop_driver_gives_every_arrival_exactly_one_fate() {
        let plan = tiny_plan(24);
        let calls = AtomicUsize::new(0);
        let fates = drive_open_loop(&plan, 4, None::<(usize, fn())>, |i, a| {
            calls.fetch_add(1, Ordering::Relaxed);
            Ok(GenResponse {
                id: i as u64,
                output: a.input.clone(),
                batch_size: 1,
                queue_time: Duration::ZERO,
                exec_time: Duration::ZERO,
            })
        });
        assert_eq!(fates.len(), 24);
        assert_eq!(calls.load(Ordering::Relaxed), 24);
        for (i, fate) in fates.iter().enumerate() {
            let resp = fate.as_ref().expect("every arrival dispatched").as_ref().unwrap();
            assert_eq!(resp.id, i as u64, "fates land in arrival order slots");
            assert_eq!(resp.output, plan.arrivals[i].input);
        }
    }

    #[test]
    fn mid_run_callback_fires_exactly_once_at_its_index() {
        let plan = tiny_plan(12);
        let fired = AtomicBool::new(false);
        let seen_after = AtomicUsize::new(usize::MAX);
        let fates = drive_open_loop(
            &plan,
            2,
            Some((6usize, || {
                assert!(!fired.swap(true, Ordering::SeqCst), "fires once");
            })),
            |i, _a| {
                if fired.load(Ordering::SeqCst) {
                    seen_after.fetch_min(i, Ordering::SeqCst);
                }
                Err(ServeError::EngineShutdown)
            },
        );
        assert!(fired.load(Ordering::SeqCst));
        assert_eq!(fates.iter().filter(|f| f.is_some()).count(), 12);
        // arrivals at or past the trigger index always see the event
        assert!(seen_after.load(Ordering::SeqCst) <= 6);
    }
}
