//! Length-prefixed TCP wire protocol for the fleet tier (std-only).
//!
//! Every frame is `[u32 LE body-len][u8 version][u8 tag][payload]`. The
//! codec holds the same hostile-bytes discipline as
//! [`crate::artifact::codec`]: decoding is panic-free and bounds-checked
//! end to end, every malformed input returns a typed [`WireError`], and
//! no length field can cause an allocation larger than the bytes actually
//! present on the wire (the frame length itself is capped at
//! [`MAX_BODY`] before any buffer is sized).
//!
//! # Message inventory
//!
//! | tag | message | direction |
//! |---|---|---|
//! | 1 | [`WireMsg::Request`] | client → replica/router |
//! | 2 | [`WireMsg::Response`] | replica/router → client |
//! | 3 | [`WireMsg::Error`] | replica/router → client |
//! | 4 | [`WireMsg::HealthQuery`] | prober → replica/router |
//! | 5 | [`WireMsg::HealthReply`] | replica/router → prober |
//! | 6 | [`WireMsg::Drain`] | router → replica |
//! | 7 | [`WireMsg::Reload`] | router → replica |
//! | 8 | [`WireMsg::Shutdown`] | operator → replica |
//! | 9 | [`WireMsg::Ok`] | replica → router |
//! | 10 | [`WireMsg::MetricsQuery`] | scraper → replica/router |
//! | 11 | [`WireMsg::MetricsReply`] | replica/router → scraper |
//! | 12 | [`WireMsg::TraceQuery`] | scraper → replica/router |
//! | 13 | [`WireMsg::TraceReply`] | replica/router → scraper |
//!
//! # Trace propagation (version-tolerant)
//!
//! A sampled request carries its [`crate::telemetry::TraceId`] as an
//! **optional trailing field** on [`WireMsg::Request`]: untraced requests
//! (`trace == 0`) encode byte-identically to the pre-telemetry frame
//! format, and the decoder accepts both forms — a frame with no trailing
//! field decodes with `trace = 0`, a frame with exactly 8 trailing bytes
//! decodes them as the trace id. Old peers therefore interoperate with
//! new ones as long as tracing is off, and a new decoder never rejects an
//! old frame. (Anything other than 0 or 8 leftover bytes is still the
//! usual typed [`WireError::Trailing`] verdict.)
//!
//! # Retry idempotency
//!
//! [`WireMsg::Request::id`] is assigned once per logical request by the
//! fleet router and reused verbatim on every retry attempt, so a replica
//! can recognise a resent request and answer it from its fate cache
//! ([`crate::fleet::replica::FateCache`]) — the retried completion is the
//! bitwise-identical frame the first execution produced.
//!
//! Typed serving errors cross the wire as `(code, a, b, detail)` tuples
//! ([`code`]) and round-trip losslessly through
//! [`error_to_wire`] / [`error_from_wire`].

use crate::coordinator::{Rejected, ServeError};
use std::fmt;
use std::io::{Read, Write};
use std::time::Duration;

/// Protocol version byte carried in every frame.
pub const WIRE_VERSION: u8 = 1;

/// Hard cap on a frame body (version + tag + payload). A length prefix
/// beyond this is rejected *before* any allocation — a hostile peer
/// cannot make a replica reserve gigabytes with four bytes.
pub const MAX_BODY: usize = 32 * 1024 * 1024;

const TAG_REQUEST: u8 = 1;
const TAG_RESPONSE: u8 = 2;
const TAG_ERROR: u8 = 3;
const TAG_HEALTH_QUERY: u8 = 4;
const TAG_HEALTH_REPLY: u8 = 5;
const TAG_DRAIN: u8 = 6;
const TAG_RELOAD: u8 = 7;
const TAG_SHUTDOWN: u8 = 8;
const TAG_OK: u8 = 9;
const TAG_METRICS_QUERY: u8 = 10;
const TAG_METRICS_REPLY: u8 = 11;
const TAG_TRACE_QUERY: u8 = 12;
const TAG_TRACE_REPLY: u8 = 13;

/// Scrape formats a [`WireMsg::MetricsQuery`] can ask for.
pub mod format {
    /// stable-key JSON (the default)
    pub const JSON: u8 = 0;
    /// Prometheus text exposition ([`crate::telemetry::export::prometheus`])
    pub const PROMETHEUS: u8 = 1;
}

/// Span cap for one [`WireMsg::TraceReply`] document — keeps a full
/// flight-recorder dump comfortably under [`MAX_BODY`].
pub const TRACE_DUMP_LIMIT: usize = 4096;

/// Typed wire error codes (the `code` byte of [`WireMsg::Error`]).
///
/// Codes 1–9 mirror [`ServeError`] variants; 10–12 are fleet-local
/// verdicts a replica can return before a request ever reaches its
/// coordinator (warm-boot incomplete, drain in progress, boot/reload
/// failed).
pub mod code {
    /// [`crate::coordinator::ServeError::UnknownModel`]
    pub const UNKNOWN_MODEL: u8 = 1;
    /// [`crate::coordinator::ServeError::BadInputLength`]
    pub const BAD_INPUT_LENGTH: u8 = 2;
    /// [`crate::coordinator::ServeError::EngineShutdown`]
    pub const ENGINE_SHUTDOWN: u8 = 3;
    /// [`crate::coordinator::ServeError::Execution`]
    pub const EXECUTION: u8 = 4;
    /// [`crate::coordinator::ServeError::Crashed`]
    pub const CRASHED: u8 = 5;
    /// [`crate::coordinator::Rejected::QueueFull`]
    pub const QUEUE_FULL: u8 = 6;
    /// [`crate::coordinator::Rejected::DeadlineInfeasible`]
    pub const DEADLINE_INFEASIBLE: u8 = 7;
    /// [`crate::coordinator::Rejected::Unhealthy`]
    pub const UNHEALTHY: u8 = 8;
    /// [`crate::coordinator::Rejected::FleetUnavailable`]
    pub const FLEET_UNAVAILABLE: u8 = 9;
    /// replica accepted the connection but warm-boot has not finished
    pub const NOT_READY: u8 = 10;
    /// replica is draining (clean roll or graceful shutdown in progress)
    pub const DRAINING: u8 = 11;
    /// replica's warm-boot or reload failed: terminal for the *replica*
    /// (until a new `Reload`), but retryable for the *fleet* — the
    /// request never executed, so the router fails it over
    pub const FAILED: u8 = 12;
}

/// True for error codes a router may fail over to another replica: the
/// request was **never executed** (admission shed, breaker open, boot,
/// drain or reload trouble, engine handed off), so a retry cannot
/// double-spend work. Execution verdicts (`EXECUTION`, `CRASHED`),
/// request-shape errors, and per-request deadline verdicts are terminal.
pub fn retryable(code: u8) -> bool {
    matches!(
        code,
        code::ENGINE_SHUTDOWN
            | code::QUEUE_FULL
            | code::UNHEALTHY
            | code::FLEET_UNAVAILABLE
            | code::NOT_READY
            | code::DRAINING
            | code::FAILED
    )
}

/// What went wrong decoding hostile or truncated bytes. Every variant is
/// a *verdict*, not a panic: the codec can be pointed at arbitrary bytes.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum WireError {
    /// the buffer ended before a field did
    Truncated {
        /// bytes the next field needed
        needed: usize,
        /// bytes actually remaining
        have: usize,
    },
    /// the length prefix exceeds [`MAX_BODY`]
    Oversized {
        /// declared body length
        len: usize,
        /// the cap it violated
        max: usize,
    },
    /// unknown protocol version byte
    BadVersion(u8),
    /// unknown message tag byte
    BadTag(u8),
    /// a string field was not valid UTF-8
    BadUtf8,
    /// the payload decoded cleanly but bytes were left over
    Trailing {
        /// leftover byte count
        extra: usize,
    },
}

impl fmt::Display for WireError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            WireError::Truncated { needed, have } => {
                write!(f, "truncated frame: field needs {needed} bytes, {have} remain")
            }
            WireError::Oversized { len, max } => {
                write!(f, "oversized frame: {len} bytes declared, cap is {max}")
            }
            WireError::BadVersion(v) => write!(f, "bad protocol version {v}"),
            WireError::BadTag(t) => write!(f, "bad message tag {t}"),
            WireError::BadUtf8 => write!(f, "string field is not valid UTF-8"),
            WireError::Trailing { extra } => {
                write!(f, "{extra} trailing byte(s) after a complete payload")
            }
        }
    }
}

impl std::error::Error for WireError {}

/// Why [`recv`] failed to produce a message.
#[derive(Debug)]
pub enum RecvError {
    /// the peer closed the connection cleanly at a frame boundary
    Closed,
    /// transport error (includes mid-frame EOF)
    Io(std::io::Error),
    /// the frame arrived but its bytes are malformed
    Wire(WireError),
}

impl fmt::Display for RecvError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            RecvError::Closed => write!(f, "connection closed"),
            RecvError::Io(e) => write!(f, "transport error: {e}"),
            RecvError::Wire(e) => write!(f, "protocol error: {e}"),
        }
    }
}

impl std::error::Error for RecvError {}

/// One fleet protocol message (see the module table for tags).
#[derive(Clone, Debug, PartialEq)]
pub enum WireMsg {
    /// one generation request, carrying its router-assigned id and the
    /// remaining deadline budget in µs (0 = best-effort)
    Request {
        /// router-assigned id, stable across retry attempts
        id: u64,
        /// zoo model id
        model: String,
        /// route method ("winograd" / "tdc")
        method: String,
        /// remaining deadline budget in µs; 0 = best-effort
        deadline_us: u64,
        /// flat f32 input tensor
        input: Vec<f32>,
        /// telemetry trace id (0 = untraced; encoded as an optional
        /// trailing field, see the module docs on trace propagation)
        trace: u64,
    },
    /// a completed request
    Response {
        /// echoed request id
        id: u64,
        /// batch bucket the request executed in
        batch_size: u32,
        /// queue wait in µs
        queue_us: u64,
        /// batch execution time in µs
        exec_us: u64,
        /// flat f32 output tensor
        output: Vec<f32>,
    },
    /// a typed failure (see [`code`]; `a`/`b` carry the variant's
    /// numeric fields so the error round-trips losslessly)
    Error {
        /// echoed request id (0 when not request-scoped)
        id: u64,
        /// error code ([`code`])
        code: u8,
        /// first numeric field of the typed variant (0 if unused)
        a: u64,
        /// second numeric field of the typed variant (0 if unused)
        b: u64,
        /// human-readable detail / string payload of the variant
        detail: String,
    },
    /// ask for the health/readiness document
    HealthQuery,
    /// the health document as one JSON string (see
    /// [`crate::fleet::replica`] for the replica schema)
    HealthReply {
        /// machine-readable health JSON
        json: String,
    },
    /// stop admitting new requests; in-flight requests finish
    Drain,
    /// drain, then reboot the coordinator from the plan store (picks up
    /// the store's current generation); `Ok` is sent once ready again
    Reload,
    /// drain, answer leftovers, and exit the serve loop
    Shutdown,
    /// generic acknowledgement
    Ok,
    /// ask for the telemetry document (metrics + stage histograms) in
    /// the given scrape format ([`format`])
    MetricsQuery {
        /// [`format::JSON`] or [`format::PROMETHEUS`]; unknown values
        /// degrade to JSON at the serving side, never an error
        format: u8,
    },
    /// the telemetry document in the requested format
    MetricsReply {
        /// the document text (JSON or Prometheus exposition)
        body: String,
    },
    /// ask for recorded spans: one trace's (`trace != 0`) or a dump of
    /// the recent flight-recorder contents (`trace == 0`). A router
    /// answering this fans the query out to its replicas and merges the
    /// spans into one cross-process document.
    TraceQuery {
        /// trace id to fetch, or 0 for "recent spans"
        trace: u64,
    },
    /// the trace document as one JSON string
    TraceReply {
        /// machine-readable trace JSON (`{node, spans: [...]}`)
        json: String,
    },
}

// ---------------------------------------------------------------- encode

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    put_u32(out, s.len() as u32);
    out.extend_from_slice(s.as_bytes());
}

fn put_f32s(out: &mut Vec<u8>, v: &[f32]) {
    put_u32(out, v.len() as u32);
    for x in v {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

impl WireMsg {
    /// The body length (version byte + tag + payload) this message
    /// encodes to, computed without encoding it.
    pub fn body_len(&self) -> usize {
        let payload = match self {
            WireMsg::Request { model, method, input, trace, .. } => {
                let trace_field = if *trace != 0 { 8 } else { 0 };
                28 + model.len() + method.len() + input.len().saturating_mul(4) + trace_field
            }
            WireMsg::Response { output, .. } => 32 + output.len().saturating_mul(4),
            WireMsg::Error { detail, .. } => 29 + detail.len(),
            WireMsg::HealthReply { json } => 4 + json.len(),
            WireMsg::MetricsQuery { .. } => 1,
            WireMsg::MetricsReply { body } => 4 + body.len(),
            WireMsg::TraceQuery { .. } => 8,
            WireMsg::TraceReply { json } => 4 + json.len(),
            WireMsg::HealthQuery
            | WireMsg::Drain
            | WireMsg::Reload
            | WireMsg::Shutdown
            | WireMsg::Ok => 0,
        };
        2 + payload
    }

    /// Reject a message whose frame would exceed [`MAX_BODY`] *before*
    /// it is encoded or written. The peer would refuse the frame as
    /// [`WireError::Oversized`] and drop the connection anyway, so the
    /// verdict belongs at the sender — typed, not a severed connection
    /// the router would count against a healthy replica's breaker.
    pub fn validate(&self) -> Result<(), WireError> {
        let len = self.body_len();
        if len > MAX_BODY {
            return Err(WireError::Oversized { len, max: MAX_BODY });
        }
        Ok(())
    }

    /// Encode as one full frame (length prefix included), ready to write.
    pub fn encode(&self) -> Vec<u8> {
        let mut body = Vec::with_capacity(64);
        body.push(WIRE_VERSION);
        match self {
            WireMsg::Request { id, model, method, deadline_us, input, trace } => {
                body.push(TAG_REQUEST);
                put_u64(&mut body, *id);
                put_str(&mut body, model);
                put_str(&mut body, method);
                put_u64(&mut body, *deadline_us);
                put_f32s(&mut body, input);
                // optional trailing trace field: omitted entirely for
                // untraced requests so their frames stay byte-identical
                // to the pre-telemetry encoding
                if *trace != 0 {
                    put_u64(&mut body, *trace);
                }
            }
            WireMsg::Response { id, batch_size, queue_us, exec_us, output } => {
                body.push(TAG_RESPONSE);
                put_u64(&mut body, *id);
                put_u32(&mut body, *batch_size);
                put_u64(&mut body, *queue_us);
                put_u64(&mut body, *exec_us);
                put_f32s(&mut body, output);
            }
            WireMsg::Error { id, code, a, b, detail } => {
                body.push(TAG_ERROR);
                put_u64(&mut body, *id);
                body.push(*code);
                put_u64(&mut body, *a);
                put_u64(&mut body, *b);
                put_str(&mut body, detail);
            }
            WireMsg::HealthQuery => body.push(TAG_HEALTH_QUERY),
            WireMsg::HealthReply { json } => {
                body.push(TAG_HEALTH_REPLY);
                put_str(&mut body, json);
            }
            WireMsg::Drain => body.push(TAG_DRAIN),
            WireMsg::Reload => body.push(TAG_RELOAD),
            WireMsg::Shutdown => body.push(TAG_SHUTDOWN),
            WireMsg::Ok => body.push(TAG_OK),
            WireMsg::MetricsQuery { format } => {
                body.push(TAG_METRICS_QUERY);
                body.push(*format);
            }
            WireMsg::MetricsReply { body: text } => {
                body.push(TAG_METRICS_REPLY);
                put_str(&mut body, text);
            }
            WireMsg::TraceQuery { trace } => {
                body.push(TAG_TRACE_QUERY);
                put_u64(&mut body, *trace);
            }
            WireMsg::TraceReply { json } => {
                body.push(TAG_TRACE_REPLY);
                put_str(&mut body, json);
            }
        }
        let mut frame = Vec::with_capacity(4 + body.len());
        put_u32(&mut frame, body.len() as u32);
        frame.extend_from_slice(&body);
        frame
    }
}

// ---------------------------------------------------------------- decode

/// Bounds-checked read cursor: every take is verified against the bytes
/// that actually exist, so no hostile length field can read or allocate
/// past the frame.
struct Cur<'a> {
    b: &'a [u8],
}

impl<'a> Cur<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        if self.b.len() < n {
            return Err(WireError::Truncated { needed: n, have: self.b.len() });
        }
        let (head, tail) = self.b.split_at(n);
        self.b = tail;
        Ok(head)
    }

    fn u8(&mut self) -> Result<u8, WireError> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32, WireError> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64, WireError> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    fn string(&mut self) -> Result<String, WireError> {
        let len = self.u32()? as usize;
        let bytes = self.take(len)?;
        String::from_utf8(bytes.to_vec()).map_err(|_| WireError::BadUtf8)
    }

    fn f32s(&mut self) -> Result<Vec<f32>, WireError> {
        let count = self.u32()? as usize;
        // reject before allocating: count * 4 must already be on the wire
        let needed = count.checked_mul(4).ok_or(WireError::Truncated {
            needed: usize::MAX,
            have: self.b.len(),
        })?;
        let bytes = self.take(needed)?;
        let mut out = Vec::with_capacity(count);
        for chunk in bytes.chunks_exact(4) {
            out.push(f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]));
        }
        Ok(out)
    }

    fn remaining(&self) -> usize {
        self.b.len()
    }

    fn done(&self) -> Result<(), WireError> {
        if self.b.is_empty() {
            Ok(())
        } else {
            Err(WireError::Trailing { extra: self.b.len() })
        }
    }
}

/// Validate a frame's 4-byte length prefix; returns the body length.
/// An oversized declaration is rejected here, before any allocation.
pub fn frame_len(header: [u8; 4]) -> Result<usize, WireError> {
    let len = u32::from_le_bytes(header) as usize;
    if len > MAX_BODY {
        return Err(WireError::Oversized { len, max: MAX_BODY });
    }
    Ok(len)
}

impl WireMsg {
    /// Decode one frame body (the bytes after the length prefix). Any
    /// malformed input — truncation at any cut, bad tag or version, bad
    /// UTF-8, trailing bytes — returns a typed [`WireError`]; nothing
    /// panics and nothing allocates beyond the bytes provided.
    pub fn decode(body: &[u8]) -> Result<WireMsg, WireError> {
        let mut c = Cur { b: body };
        let version = c.u8()?;
        if version != WIRE_VERSION {
            return Err(WireError::BadVersion(version));
        }
        let tag = c.u8()?;
        let msg = match tag {
            TAG_REQUEST => {
                let id = c.u64()?;
                let model = c.string()?;
                let method = c.string()?;
                let deadline_us = c.u64()?;
                let input = c.f32s()?;
                // version tolerance: the trailing trace field is present
                // iff exactly 8 bytes remain; an old-format frame (0
                // bytes left) decodes as untraced, anything else falls
                // through to the usual Trailing verdict in done()
                let trace = if c.remaining() == 8 { c.u64()? } else { 0 };
                WireMsg::Request { id, model, method, deadline_us, input, trace }
            }
            TAG_RESPONSE => WireMsg::Response {
                id: c.u64()?,
                batch_size: c.u32()?,
                queue_us: c.u64()?,
                exec_us: c.u64()?,
                output: c.f32s()?,
            },
            TAG_ERROR => WireMsg::Error {
                id: c.u64()?,
                code: c.u8()?,
                a: c.u64()?,
                b: c.u64()?,
                detail: c.string()?,
            },
            TAG_HEALTH_QUERY => WireMsg::HealthQuery,
            TAG_HEALTH_REPLY => WireMsg::HealthReply { json: c.string()? },
            TAG_DRAIN => WireMsg::Drain,
            TAG_RELOAD => WireMsg::Reload,
            TAG_SHUTDOWN => WireMsg::Shutdown,
            TAG_OK => WireMsg::Ok,
            TAG_METRICS_QUERY => WireMsg::MetricsQuery { format: c.u8()? },
            TAG_METRICS_REPLY => WireMsg::MetricsReply { body: c.string()? },
            TAG_TRACE_QUERY => WireMsg::TraceQuery { trace: c.u64()? },
            TAG_TRACE_REPLY => WireMsg::TraceReply { json: c.string()? },
            other => return Err(WireError::BadTag(other)),
        };
        c.done()?;
        Ok(msg)
    }
}

// ------------------------------------------------------------- transport

/// The largest flat f32 input a [`WireMsg::Request`] naming `model` and
/// `method` can carry without its frame exceeding [`MAX_BODY`]. The
/// router gates requests on this *before* routing, so an oversized input
/// surfaces as a typed request-shape error instead of a dropped frame.
/// The bound reserves room for the optional trailing trace field, so a
/// request that fits untraced still fits when sampling picks it.
pub fn max_request_floats(model: &str, method: &str) -> usize {
    let overhead = 2 + 28 + 8 + model.len() + method.len();
    MAX_BODY.saturating_sub(overhead) / 4
}

/// Write one message as a frame and flush. A message that would encode
/// past [`MAX_BODY`] is refused here ([`std::io::ErrorKind::InvalidInput`]
/// wrapping the typed [`WireError::Oversized`]) — nothing the peer must
/// reject is ever put on the wire.
pub fn send(w: &mut impl Write, msg: &WireMsg) -> std::io::Result<()> {
    if let Err(e) = msg.validate() {
        return Err(std::io::Error::new(std::io::ErrorKind::InvalidInput, e));
    }
    w.write_all(&msg.encode())?;
    w.flush()
}

/// Read one frame and decode it. A clean EOF *between* frames is
/// [`RecvError::Closed`]; an EOF mid-frame is a transport error; a frame
/// with hostile bytes is a typed [`RecvError::Wire`].
pub fn recv(r: &mut impl Read) -> Result<WireMsg, RecvError> {
    let mut header = [0u8; 4];
    // the first byte distinguishes a clean close from a torn frame
    let mut got = 0usize;
    while got == 0 {
        match r.read(&mut header[..1]) {
            Ok(0) => return Err(RecvError::Closed),
            Ok(n) => got = n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(RecvError::Io(e)),
        }
    }
    r.read_exact(&mut header[1..]).map_err(RecvError::Io)?;
    let len = frame_len(header).map_err(RecvError::Wire)?;
    let mut body = vec![0u8; len];
    r.read_exact(&mut body).map_err(RecvError::Io)?;
    WireMsg::decode(&body).map_err(RecvError::Wire)
}

// --------------------------------------------------- ServeError mapping

/// Map a [`ServeError`] onto its wire `(code, a, b, detail)` encoding.
pub fn error_to_wire(id: u64, e: &ServeError) -> WireMsg {
    let (code, a, b, detail) = match e {
        ServeError::UnknownModel(m) => (code::UNKNOWN_MODEL, 0, 0, m.clone()),
        ServeError::BadInputLength { expected, got } => {
            (code::BAD_INPUT_LENGTH, *expected as u64, *got as u64, String::new())
        }
        ServeError::EngineShutdown => (code::ENGINE_SHUTDOWN, 0, 0, String::new()),
        ServeError::Execution(m) => (code::EXECUTION, 0, 0, m.clone()),
        ServeError::Crashed(m) => (code::CRASHED, 0, 0, m.clone()),
        ServeError::Rejected(Rejected::QueueFull { depth, cap }) => {
            (code::QUEUE_FULL, *depth as u64, *cap as u64, String::new())
        }
        ServeError::Rejected(Rejected::DeadlineInfeasible { remaining, estimated_wait }) => (
            code::DEADLINE_INFEASIBLE,
            remaining.as_micros() as u64,
            estimated_wait.as_micros() as u64,
            String::new(),
        ),
        ServeError::Rejected(Rejected::Unhealthy { restarts }) => {
            (code::UNHEALTHY, *restarts, 0, String::new())
        }
        ServeError::Rejected(Rejected::FleetUnavailable { replicas }) => {
            (code::FLEET_UNAVAILABLE, *replicas as u64, 0, String::new())
        }
    };
    WireMsg::Error { id, code, a, b, detail }
}

/// Reconstruct the typed [`ServeError`] from its wire encoding. The
/// fleet-local codes map to typed sheds a client can count and retry:
/// `NOT_READY`/`DRAINING`/`FAILED` become
/// [`Rejected::FleetUnavailable`]`{ replicas: 1 }` (one replica counting
/// itself out). An unknown code degrades to [`ServeError::Execution`]
/// with the raw code in the message — never a panic.
pub fn error_from_wire(code: u8, a: u64, b: u64, detail: &str) -> ServeError {
    match code {
        code::UNKNOWN_MODEL => ServeError::UnknownModel(detail.to_string()),
        code::BAD_INPUT_LENGTH => {
            ServeError::BadInputLength { expected: a as usize, got: b as usize }
        }
        code::ENGINE_SHUTDOWN => ServeError::EngineShutdown,
        code::EXECUTION => ServeError::Execution(detail.to_string()),
        code::CRASHED => ServeError::Crashed(detail.to_string()),
        code::QUEUE_FULL => ServeError::Rejected(Rejected::QueueFull {
            depth: a as usize,
            cap: b as usize,
        }),
        code::DEADLINE_INFEASIBLE => ServeError::Rejected(Rejected::DeadlineInfeasible {
            remaining: Duration::from_micros(a),
            estimated_wait: Duration::from_micros(b),
        }),
        code::UNHEALTHY => ServeError::Rejected(Rejected::Unhealthy { restarts: a }),
        code::FLEET_UNAVAILABLE => {
            ServeError::Rejected(Rejected::FleetUnavailable { replicas: a as usize })
        }
        code::NOT_READY | code::DRAINING | code::FAILED => {
            ServeError::Rejected(Rejected::FleetUnavailable { replicas: 1 })
        }
        other => ServeError::Execution(format!("unknown wire error code {other}: {detail}")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn samples() -> Vec<WireMsg> {
        vec![
            WireMsg::Request {
                id: 7,
                model: "dcgan".into(),
                method: "winograd".into(),
                deadline_us: 250_000,
                input: vec![0.5, -1.25, 3.0],
                trace: 0,
            },
            WireMsg::Request {
                id: 8,
                model: "dcgan".into(),
                method: "winograd".into(),
                deadline_us: 0,
                input: vec![1.5; 4],
                trace: 0x0001_0000_0042,
            },
            WireMsg::Response {
                id: 7,
                batch_size: 4,
                queue_us: 1200,
                exec_us: 880,
                output: vec![1.0f32; 6],
            },
            WireMsg::Error {
                id: 9,
                code: code::QUEUE_FULL,
                a: 256,
                b: 256,
                detail: String::new(),
            },
            WireMsg::HealthQuery,
            WireMsg::HealthReply { json: "{\"ready\":true}".into() },
            WireMsg::Drain,
            WireMsg::Reload,
            WireMsg::Shutdown,
            WireMsg::Ok,
            WireMsg::MetricsQuery { format: format::PROMETHEUS },
            WireMsg::MetricsReply { body: "# TYPE wingan_requests gauge\nwingan_requests 3\n".into() },
            WireMsg::TraceQuery { trace: 0x0001_0000_0042 },
            WireMsg::TraceReply { json: "{\"node\":\"r1\",\"spans\":[]}".into() },
        ]
    }

    #[test]
    fn every_message_round_trips() {
        for msg in samples() {
            let frame = msg.encode();
            let len = frame_len([frame[0], frame[1], frame[2], frame[3]]).unwrap();
            assert_eq!(len, frame.len() - 4);
            let back = WireMsg::decode(&frame[4..]).unwrap_or_else(|e| {
                panic!("decode failed for {msg:?}: {e}");
            });
            assert_eq!(back, msg);
        }
    }

    #[test]
    fn truncation_at_every_cut_is_a_typed_error() {
        for msg in samples() {
            let frame = msg.encode();
            let body = &frame[4..];
            // one deliberate exception: a traced Request cut exactly at
            // the optional trailing trace field is a *valid old-format
            // frame* — that prefix-decodability is the version-tolerance
            // contract, so pin it as such instead of as an error
            let tolerated_cut = match &msg {
                WireMsg::Request { trace, .. } if *trace != 0 => Some(body.len() - 8),
                _ => None,
            };
            for cut in 0..body.len() {
                if Some(cut) == tolerated_cut {
                    let WireMsg::Request { trace, .. } = WireMsg::decode(&body[..cut])
                        .expect("cut at the trace field is an untraced frame")
                    else {
                        panic!("tolerated cut must still decode as a Request");
                    };
                    assert_eq!(trace, 0, "the shortened frame decodes as untraced");
                    continue;
                }
                match WireMsg::decode(&body[..cut]) {
                    Err(_) => {}
                    Ok(m) => panic!("prefix of len {cut} of {msg:?} decoded as {m:?}"),
                }
            }
        }
    }

    #[test]
    fn trace_field_is_tail_optional_and_version_tolerant() {
        let untraced = WireMsg::Request {
            id: 5,
            model: "dcgan".into(),
            method: "winograd".into(),
            deadline_us: 100,
            input: vec![2.0, 4.0],
            trace: 0,
        };
        let traced = WireMsg::Request {
            trace: 0x0001_0000_0007,
            ..untraced.clone()
        };
        // the traced frame is exactly the untraced frame + 8 bytes
        let uf = untraced.encode();
        let tf = traced.encode();
        assert_eq!(tf.len(), uf.len() + 8);
        assert_eq!(&tf[4..uf.len()], &uf[4..], "shared prefix is byte-identical");
        // both round-trip
        assert_eq!(WireMsg::decode(&uf[4..]).unwrap(), untraced);
        assert_eq!(WireMsg::decode(&tf[4..]).unwrap(), traced);
        // an old-format frame (no trailing field) decodes as untraced —
        // and a partial trace field is still a typed Trailing verdict
        for extra in 1..8usize {
            let mut body = uf[4..].to_vec();
            body.extend_from_slice(&vec![0xABu8; extra]);
            assert_eq!(
                WireMsg::decode(&body),
                Err(WireError::Trailing { extra }),
                "{extra} stray bytes"
            );
        }
    }

    #[test]
    fn oversized_length_prefix_is_rejected_before_allocation() {
        let err = frame_len(u32::MAX.to_le_bytes()).unwrap_err();
        assert_eq!(err, WireError::Oversized { len: u32::MAX as usize, max: MAX_BODY });
        // and through the stream path too
        let mut stream: &[u8] = &[0xFF, 0xFF, 0xFF, 0xFF, 0, 0];
        match recv(&mut stream) {
            Err(RecvError::Wire(WireError::Oversized { .. })) => {}
            other => panic!("expected oversized verdict, got {other:?}"),
        }
    }

    #[test]
    fn hostile_f32_count_cannot_over_allocate() {
        // a Request whose input count claims u32::MAX floats in a tiny body
        let mut body = vec![WIRE_VERSION, 1];
        body.extend_from_slice(&7u64.to_le_bytes());
        body.extend_from_slice(&1u32.to_le_bytes());
        body.push(b'm');
        body.extend_from_slice(&1u32.to_le_bytes());
        body.push(b'w');
        body.extend_from_slice(&0u64.to_le_bytes());
        body.extend_from_slice(&u32::MAX.to_le_bytes()); // hostile count
        match WireMsg::decode(&body) {
            Err(WireError::Truncated { .. }) => {}
            other => panic!("expected truncated verdict, got {other:?}"),
        }
    }

    #[test]
    fn bad_tag_version_utf8_and_trailing_are_typed() {
        assert_eq!(WireMsg::decode(&[9, TAG_OK]), Err(WireError::BadVersion(9)));
        assert_eq!(WireMsg::decode(&[WIRE_VERSION, 200]), Err(WireError::BadTag(200)));
        assert_eq!(
            WireMsg::decode(&[WIRE_VERSION, TAG_OK, 0xAA]),
            Err(WireError::Trailing { extra: 1 })
        );
        // HealthReply carrying invalid UTF-8
        let mut body = vec![WIRE_VERSION, TAG_HEALTH_REPLY];
        body.extend_from_slice(&2u32.to_le_bytes());
        body.extend_from_slice(&[0xC3, 0x28]); // invalid 2-byte sequence
        assert_eq!(WireMsg::decode(&body), Err(WireError::BadUtf8));
        // empty body
        assert_eq!(WireMsg::decode(&[]), Err(WireError::Truncated { needed: 1, have: 0 }));
    }

    #[test]
    fn random_bytes_never_panic_the_decoder() {
        use crate::util::prng::Rng;
        crate::prop::forall(
            "wire_decode_total",
            200,
            0x11EE,
            |r: &mut Rng| {
                let n = r.below(96);
                (0..n).map(|_| (r.next_u64() & 0xFF) as u8).collect::<Vec<u8>>()
            },
            |bytes| {
                // any outcome is fine; reaching here without a panic is the property
                let _ = WireMsg::decode(bytes);
                Ok(())
            },
        );
    }

    #[test]
    fn serve_errors_round_trip_losslessly() {
        let cases = vec![
            ServeError::UnknownModel("nope/xyz".into()),
            ServeError::BadInputLength { expected: 32, got: 7 },
            ServeError::EngineShutdown,
            ServeError::Execution("exec boom".into()),
            ServeError::Crashed("panic payload".into()),
            ServeError::Rejected(Rejected::QueueFull { depth: 12, cap: 8 }),
            ServeError::Rejected(Rejected::DeadlineInfeasible {
                remaining: Duration::from_micros(1500),
                estimated_wait: Duration::from_micros(9000),
            }),
            ServeError::Rejected(Rejected::Unhealthy { restarts: 3 }),
            ServeError::Rejected(Rejected::FleetUnavailable { replicas: 5 }),
        ];
        for e in cases {
            let msg = error_to_wire(42, &e);
            let WireMsg::Error { id, code, a, b, detail } = &msg else {
                panic!("error_to_wire produced {msg:?}");
            };
            assert_eq!(*id, 42);
            let back = error_from_wire(*code, *a, *b, detail);
            assert_eq!(back, e, "code {code} did not round-trip");
            // and the frame itself round-trips
            let frame = msg.encode();
            assert_eq!(WireMsg::decode(&frame[4..]).unwrap(), msg);
        }
    }

    #[test]
    fn retryability_is_never_executed_semantics() {
        for c in [
            code::NOT_READY,
            code::DRAINING,
            code::FAILED,
            code::QUEUE_FULL,
            code::UNHEALTHY,
            code::ENGINE_SHUTDOWN,
            code::FLEET_UNAVAILABLE,
        ] {
            assert!(retryable(c), "code {c} must be retryable");
        }
        for c in [
            code::UNKNOWN_MODEL,
            code::BAD_INPUT_LENGTH,
            code::EXECUTION,
            code::CRASHED,
            code::DEADLINE_INFEASIBLE,
        ] {
            assert!(!retryable(c), "code {c} must be terminal");
        }
    }

    #[test]
    fn body_len_matches_the_encoder_exactly() {
        for msg in samples() {
            assert_eq!(msg.body_len(), msg.encode().len() - 4, "for {msg:?}");
            assert!(msg.validate().is_ok(), "samples are all within MAX_BODY");
        }
    }

    #[test]
    fn failed_code_maps_to_a_retryable_fleet_shed() {
        let back = error_from_wire(code::FAILED, 0, 0, "replica failed: boot exploded");
        assert_eq!(back, ServeError::Rejected(Rejected::FleetUnavailable { replicas: 1 }));
        assert!(retryable(code::FAILED));
    }

    #[test]
    fn oversized_requests_are_refused_at_the_sender_not_the_wire() {
        let cap = max_request_floats("dcgan", "winograd");
        // the bound reserves the trailing trace field, so the boundary
        // case is a *traced* request: at the cap exactly the frame is
        // legal even when sampling picked this request…
        let fits = WireMsg::Request {
            id: 1,
            model: "dcgan".into(),
            method: "winograd".into(),
            deadline_us: 0,
            input: vec![0.0; cap],
            trace: 0x0001_0000_0001,
        };
        assert!(fits.validate().is_ok());
        assert!(fits.body_len() <= MAX_BODY);
        // …one float past it, validation yields the typed verdict…
        let over = WireMsg::Request {
            id: 1,
            model: "dcgan".into(),
            method: "winograd".into(),
            deadline_us: 0,
            input: vec![0.0; cap + 1],
            trace: 0x0001_0000_0001,
        };
        match over.validate() {
            Err(WireError::Oversized { len, max }) => {
                assert!(len > max);
                assert_eq!(max, MAX_BODY);
            }
            other => panic!("expected Oversized, got {other:?}"),
        }
        // …and send() refuses to put the frame on the wire at all
        let mut sink = Vec::new();
        let err = send(&mut sink, &over).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidInput);
        assert!(sink.is_empty(), "nothing written for a refused frame");
    }

    #[test]
    fn recv_distinguishes_clean_close_from_torn_frame() {
        let mut empty: &[u8] = &[];
        match recv(&mut empty) {
            Err(RecvError::Closed) => {}
            other => panic!("expected Closed, got {other:?}"),
        }
        let frame = WireMsg::Ok.encode();
        let mut torn: &[u8] = &frame[..frame.len() - 1];
        match recv(&mut torn) {
            Err(RecvError::Io(_)) => {}
            other => panic!("expected Io (mid-frame EOF), got {other:?}"),
        }
        let mut whole: &[u8] = &frame;
        assert_eq!(recv(&mut whole).unwrap(), WireMsg::Ok);
    }
}
