//! GAN substrate: the Table-I model zoo and its workload characterisation.

pub mod workload;
pub mod zoo;

pub use workload::Method;
pub use zoo::{Gan, Kind, Layer, Scale};
