//! GAN model zoo: the four generative networks of Table I with full layer
//! geometry (channel configs follow the original papers; see DESIGN.md §5).
//!
//! This is the single rust-side source of truth for every analytic bench
//! (Fig. 4 / Fig. 8 / Fig. 9 / Table II). It mirrors
//! `python/compile/model.py::zoo` — the integration tests cross-check the
//! two via the artifact manifest shapes.

use crate::tdc;
use crate::util::elem::Elem;
use crate::util::tensor::Tensor3;

/// Layer kind: the paper evaluates DeConv; Conv layers (DiscoGAN's encoder)
/// are modelled for completeness and run on the conv datapath.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kind {
    Deconv,
    Conv,
}

/// Per-layer activation on the generator hand-off path, mirroring the
/// python zoo (`python/compile/model.py`'s `act` field): hidden layers run
/// ReLU (leaky in DiscoGAN's encoder), output layers `tanh`. The execution
/// engine applies it elementwise after each layer at the plan's precision;
/// single-layer plans and the analytic workload models use [`Linear`].
///
/// [`Linear`]: Activation::Linear
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Activation {
    /// Identity — the layer hands its raw accumulator output on.
    Linear,
    /// `max(v, 0)`.
    Relu,
    /// Slope-0.2 leaky ReLU (DiscoGAN's encoder convs).
    LeakyRelu,
    /// Hyperbolic tangent (every generator's image-space output layer).
    Tanh,
}

impl Activation {
    /// Apply to one scalar at the element's precision. The same comparison
    /// and multiply sequence runs at either tier, so activations preserve
    /// the engine's bitwise worker-count/schedule invariance.
    #[inline]
    pub fn apply_scalar<E: Elem>(self, v: E) -> E {
        match self {
            Activation::Linear => v,
            Activation::Relu => {
                if v < E::ZERO {
                    E::ZERO
                } else {
                    v
                }
            }
            Activation::LeakyRelu => {
                if v < E::ZERO {
                    v * E::from_f64(0.2)
                } else {
                    v
                }
            }
            Activation::Tanh => v.tanh(),
        }
    }

    /// Apply elementwise in place ([`Activation::Linear`] is a no-op).
    pub fn apply<E: Elem>(self, t: &mut Tensor3<E>) {
        if self == Activation::Linear {
            return;
        }
        for v in t.data.iter_mut() {
            *v = self.apply_scalar(*v);
        }
    }
}

/// One generator layer's geometry plus its hand-off activation.
///
/// `PartialEq` compares every field — the plan-artifact staleness guard
/// (`engine::serve`) relies on that to track any future field
/// automatically, so keep it derived.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Layer {
    pub kind: Kind,
    pub c_in: usize,
    pub c_out: usize,
    pub k: usize,
    pub s: usize,
    pub p: usize,
    pub h_in: usize,
    pub w_in: usize,
    /// activation applied to this layer's output on the hand-off path
    pub act: Activation,
}

impl Layer {
    pub fn deconv(c_in: usize, c_out: usize, k: usize, s: usize, h: usize) -> Layer {
        Layer {
            kind: Kind::Deconv,
            c_in,
            c_out,
            k,
            s,
            p: tdc::default_padding(k, s),
            h_in: h,
            w_in: h,
            act: Activation::Linear,
        }
    }

    pub fn conv(c_in: usize, c_out: usize, k: usize, s: usize, p: usize, h: usize) -> Layer {
        Layer { kind: Kind::Conv, c_in, c_out, k, s, p, h_in: h, w_in: h, act: Activation::Linear }
    }

    /// Builder-style activation override (zoo constructors use it; layers
    /// default to [`Activation::Linear`]).
    pub fn with_act(mut self, act: Activation) -> Layer {
        self.act = act;
        self
    }

    pub fn h_out(&self) -> usize {
        match self.kind {
            Kind::Deconv => self.s * self.h_in,
            Kind::Conv => self.h_in / self.s,
        }
    }

    pub fn w_out(&self) -> usize {
        match self.kind {
            Kind::Deconv => self.s * self.w_in,
            Kind::Conv => self.w_in / self.s,
        }
    }

    /// Table I's K_C (TDC-converted kernel width) for deconv layers.
    pub fn kc(&self) -> usize {
        match self.kind {
            Kind::Deconv => tdc::kc(self.k, self.s),
            Kind::Conv => self.k,
        }
    }
}

/// A generative network.
#[derive(Clone, Debug)]
pub struct Gan {
    pub name: &'static str,
    pub year: u32,
    pub layers: Vec<Layer>,
}

impl Gan {
    pub fn deconv_layers(&self) -> impl Iterator<Item = &Layer> {
        self.layers.iter().filter(|l| l.kind == Kind::Deconv)
    }

    pub fn n_deconv(&self) -> usize {
        self.deconv_layers().count()
    }

    pub fn n_conv(&self) -> usize {
        self.layers.iter().filter(|l| l.kind == Kind::Conv).count()
    }
}

/// Model scale: `Paper` = original channel widths (all analytic benches);
/// `Small` = channels / 8 (matches the AOT artifacts for the CPU box);
/// `Tiny` = channels / 32 (rust-only: fast enough for debug-mode engine /
/// serving tests that execute real whole-generator tensors).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Scale {
    Paper,
    Small,
    Tiny,
}

impl Scale {
    /// Canonical lowercase label (`"paper"` / `"small"` / `"tiny"`) — the
    /// name the CLI flags speak and the plan store's directory layout uses.
    pub fn label(self) -> &'static str {
        match self {
            Scale::Paper => "paper",
            Scale::Small => "small",
            Scale::Tiny => "tiny",
        }
    }

    /// Parse a user-facing scale name (the inverse of [`Scale::label`]).
    pub fn parse(s: &str) -> Result<Scale, String> {
        match s.trim().to_ascii_lowercase().as_str() {
            "paper" => Ok(Scale::Paper),
            "small" => Ok(Scale::Small),
            "tiny" => Ok(Scale::Tiny),
            other => Err(format!("unknown scale '{other}' (expected paper, small or tiny)")),
        }
    }
}

fn ch(c: usize, scale: Scale) -> usize {
    match scale {
        Scale::Paper => c,
        Scale::Small => {
            if c <= 3 {
                c
            } else {
                (c / 8).max(4)
            }
        }
        Scale::Tiny => {
            if c <= 3 {
                c
            } else {
                (c / 32).max(4)
            }
        }
    }
}

/// DeConv stack with the zoo's standard activation pattern: hidden layers
/// ReLU, the stack's last layer `final_act` — exactly the python mirror's
/// `_deconv_stack(..., name_final_act=...)`.
fn deconv_stack(channels: &[usize], k: usize, s: usize, h0: usize, final_act: Activation) -> Vec<Layer> {
    let mut layers = Vec::new();
    let mut h = h0;
    for (i, win) in channels.windows(2).enumerate() {
        let act = if i + 2 == channels.len() { final_act } else { Activation::Relu };
        layers.push(Layer::deconv(win[0], win[1], k, s, h).with_act(act));
        h *= s;
    }
    layers
}

/// DCGAN [4]: 4 DeConv, K_D = 5, S = 2. z -> 4x4x1024 -> ... -> 64x64x3.
pub fn dcgan(scale: Scale) -> Gan {
    let c = |v| ch(v, scale);
    Gan {
        name: "DCGAN",
        year: 2015,
        layers: deconv_stack(&[c(1024), c(512), c(256), c(128), 3], 5, 2, 4, Activation::Tanh),
    }
}

/// ArtGAN [5]: 4 DeConv K_D=4 S=2 plus a final DeConv K_D=3 S=1.
pub fn artgan(scale: Scale) -> Gan {
    let c = |v| ch(v, scale);
    let mut layers =
        deconv_stack(&[c(512), c(256), c(128), c(64), c(64)], 4, 2, 4, Activation::Relu);
    layers.push(Layer::deconv(c(64), 3, 3, 1, 64).with_act(Activation::Tanh));
    Gan { name: "ArtGAN", year: 2017, layers }
}

/// DiscoGAN [6]: 5 Conv encoder + 4 DeConv K_D=4 S=2 decoder (image-to-image).
pub fn discogan(scale: Scale) -> Gan {
    let c = |v| ch(v, scale);
    let mut layers = vec![
        Layer::conv(3, c(64), 4, 2, 1, 64).with_act(Activation::LeakyRelu),
        Layer::conv(c(64), c(128), 4, 2, 1, 32).with_act(Activation::LeakyRelu),
        Layer::conv(c(128), c(256), 4, 2, 1, 16).with_act(Activation::LeakyRelu),
        Layer::conv(c(256), c(512), 4, 2, 1, 8).with_act(Activation::LeakyRelu),
        Layer::conv(c(512), c(512), 3, 1, 1, 4).with_act(Activation::LeakyRelu),
    ];
    layers.extend(deconv_stack(&[c(512), c(256), c(128), c(64), 3], 4, 2, 4, Activation::Tanh));
    Gan { name: "DiscoGAN", year: 2017, layers }
}

/// GP-GAN [7]: 4 DeConv K_D=4 S=2 from a latent bottleneck.
pub fn gpgan(scale: Scale) -> Gan {
    let c = |v| ch(v, scale);
    Gan {
        name: "GP-GAN",
        year: 2019,
        layers: deconv_stack(&[c(512), c(256), c(128), c(64), 3], 4, 2, 4, Activation::Tanh),
    }
}

/// All four models of Table I, in paper order.
pub fn all(scale: Scale) -> Vec<Gan> {
    vec![dcgan(scale), artgan(scale), discogan(scale), gpgan(scale)]
}

/// Render Table I (model descriptions).
pub fn table1() -> String {
    let mut out = String::from(
        "Table I — GAN model descriptions\n\
         model     year  #conv  #deconv  K_D  S  K_C\n",
    );
    for g in all(Scale::Paper) {
        // kernel classes among deconv layers
        let mut classes: Vec<(usize, usize, usize)> = Vec::new();
        for l in g.deconv_layers() {
            let t = (l.k, l.s, l.kc());
            if !classes.contains(&t) {
                classes.push(t);
            }
        }
        for (i, (k, s, kc)) in classes.iter().enumerate() {
            if i == 0 {
                out += &format!(
                    "{:<9} {:<5} {:<6} {:<8} {:<4} {:<2} {:<3}\n",
                    g.name,
                    g.year,
                    if g.n_conv() > 0 { g.n_conv().to_string() } else { "-".into() },
                    g.deconv_layers().filter(|l| l.k == *k && l.s == *s).count(),
                    k,
                    s,
                    kc
                );
            } else {
                out += &format!(
                    "{:<9} {:<5} {:<6} {:<8} {:<4} {:<2} {:<3}\n",
                    "",
                    "",
                    "",
                    g.deconv_layers().filter(|l| l.k == *k && l.s == *s).count(),
                    k,
                    s,
                    kc
                );
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_kernel_classes() {
        // Table I: DCGAN K_D=5 S=2 K_C=3; ArtGAN 4/2/2 + 3/1/3; Disco & GP 4/2/2
        let d = dcgan(Scale::Paper);
        assert_eq!(d.n_deconv(), 4);
        assert!(d.deconv_layers().all(|l| l.k == 5 && l.s == 2 && l.kc() == 3));

        let a = artgan(Scale::Paper);
        assert_eq!(a.n_deconv(), 5);
        assert_eq!(a.deconv_layers().filter(|l| l.k == 4).count(), 4);
        assert_eq!(a.deconv_layers().filter(|l| l.k == 3 && l.s == 1).count(), 1);

        let di = discogan(Scale::Paper);
        assert_eq!(di.n_conv(), 5);
        assert_eq!(di.n_deconv(), 4);

        let gp = gpgan(Scale::Paper);
        assert_eq!(gp.n_deconv(), 4);
        assert!(gp.deconv_layers().all(|l| l.kc() == 2));
    }

    #[test]
    fn spatial_chain_consistency() {
        for g in all(Scale::Paper) {
            let mut prev: Option<(usize, usize, usize)> = None;
            for l in &g.layers {
                if let Some((c, h, w)) = prev {
                    assert_eq!(c, l.c_in, "{} channel chain", g.name);
                    assert_eq!(h, l.h_in, "{} height chain", g.name);
                    assert_eq!(w, l.w_in, "{} width chain", g.name);
                }
                prev = Some((l.c_out, l.h_out(), l.w_out()));
            }
            // all generators end at 64x64x3
            let (c, h, w) = prev.unwrap();
            assert_eq!((c, h, w), (3, 64, 64), "{}", g.name);
        }
    }

    #[test]
    fn scale_labels_roundtrip() {
        for s in [Scale::Paper, Scale::Small, Scale::Tiny] {
            assert_eq!(Scale::parse(s.label()).unwrap(), s);
        }
        assert_eq!(Scale::parse(" TINY ").unwrap(), Scale::Tiny);
        assert!(Scale::parse("huge").is_err());
    }

    #[test]
    fn small_scale_divides_channels() {
        let d = dcgan(Scale::Small);
        assert_eq!(d.layers[0].c_in, 128);
        assert_eq!(d.layers[3].c_out, 3);
    }

    #[test]
    fn activation_pattern_mirrors_python_zoo() {
        // python/compile/model.py: hidden deconvs relu, output tanh;
        // DiscoGAN's encoder lrelu; ArtGAN's 4-stack ends relu before the
        // tanh K3S1 output layer
        for g in all(Scale::Paper) {
            assert_eq!(g.layers.last().unwrap().act, Activation::Tanh, "{}", g.name);
        }
        let d = dcgan(Scale::Paper);
        assert!(d.layers[..3].iter().all(|l| l.act == Activation::Relu));
        let a = artgan(Scale::Paper);
        assert!(a.layers[..4].iter().all(|l| l.act == Activation::Relu));
        let di = discogan(Scale::Paper);
        assert!(di.layers[..5].iter().all(|l| l.act == Activation::LeakyRelu));
        assert!(di.layers[5..8].iter().all(|l| l.act == Activation::Relu));
        // constructors stay Linear (single-layer plans, analytic models)
        assert_eq!(Layer::deconv(2, 2, 5, 2, 4).act, Activation::Linear);
    }

    #[test]
    fn activation_semantics_golden() {
        // hand-checkable values, both precisions (mirrored by the numpy
        // test_activation_semantics_match_rust golden)
        assert_eq!(Activation::Relu.apply_scalar(-1.5f64), 0.0);
        assert_eq!(Activation::Relu.apply_scalar(2.0f64), 2.0);
        assert_eq!(Activation::LeakyRelu.apply_scalar(-1.0f64), -0.2);
        assert_eq!(Activation::LeakyRelu.apply_scalar(3.0f32), 3.0);
        assert_eq!(Activation::Tanh.apply_scalar(0.0f64), 0.0);
        assert!((Activation::Tanh.apply_scalar(0.5f64) - 0.5f64.tanh()).abs() == 0.0);
        assert_eq!(Activation::Linear.apply_scalar(-7.25f32), -7.25);
        let mut t = Tensor3::from_vec(1, 1, 3, vec![-2.0f64, 0.0, 2.0]);
        Activation::Relu.apply(&mut t);
        assert_eq!(t.data, vec![0.0, 0.0, 2.0]);
    }
}
