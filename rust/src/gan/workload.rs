//! Workload characterisation: multiplication counts and data-movement
//! volumes per layer per DeConv method (paper Fig. 4 + the inputs to the
//! energy model of Fig. 9).

use crate::gan::zoo::{Gan, Kind, Layer};
use crate::tdc;
use crate::winograd::sparsity::c_of_kc;
use crate::winograd::transforms::{M as M_TILE, N as N_TILE};

/// The three DeConv implementation methods the paper compares.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Method {
    /// Fig. 1b — conv over the zero-dilated, border-padded feature map.
    ZeroPadded,
    /// Fig. 1c — the TDC conversion of [14-16]: S^2 convs of K_C^2 taps.
    Tdc,
    /// The paper's contribution: TDC + F(2x2,3x3) + vector-level sparsity.
    Winograd,
}

impl Method {
    pub const ALL: [Method; 3] = [Method::ZeroPadded, Method::Tdc, Method::Winograd];

    pub fn label(self) -> &'static str {
        match self {
            Method::ZeroPadded => "zero-padded",
            Method::Tdc => "TDC",
            Method::Winograd => "Winograd (ours)",
        }
    }
}

/// Multiplications for one layer under a method.
///
/// Conv layers (DiscoGAN's encoder) are method-independent: both baselines
/// and ours run them as spatial convs (the paper evaluates DeConv only; we
/// run encoder convs on the TDC conv datapath unchanged).
pub fn layer_mults(l: &Layer, method: Method) -> u64 {
    let (m_out, n_in) = (l.c_out as u64, l.c_in as u64);
    let (h, w) = (l.h_in as u64, l.w_in as u64);
    match l.kind {
        Kind::Conv => {
            let (ho, wo) = (l.h_out() as u64, l.w_out() as u64);
            m_out * n_in * ho * wo * (l.k * l.k) as u64
        }
        Kind::Deconv => match method {
            Method::ZeroPadded => {
                // full conv over the up-scaled H_O x W_O map with K_D^2 taps
                m_out * n_in * (l.s as u64 * h) * (l.s as u64 * w) * (l.k * l.k) as u64
            }
            Method::Tdc => {
                let kc = tdc::kc(l.k, l.s) as u64;
                (l.s * l.s) as u64 * m_out * n_in * h * w * kc * kc
            }
            Method::Winograd => {
                let tiles = h.div_ceil(M_TILE as u64) * w.div_ceil(M_TILE as u64);
                m_out * n_in * tiles * c_of_kc(l.k, l.s, l.p) as u64
            }
        },
    }
}

/// Total DeConv multiplications for a model (paper Fig. 4 counts DeConv
/// layers only — "most GANs consist of DeConv layers for the inference
/// step").
pub fn model_deconv_mults(g: &Gan, method: Method) -> u64 {
    g.deconv_layers().map(|l| layer_mults(l, method)).sum()
}

/// Off-chip data transfer for one deconv layer, in bytes (f32 words):
/// input map read once + output map written once + weights read once.
/// Method-dependent weight volume: Winograd stores transformed n^2-word
/// filters (the paper's extra BRAM cost in Table II), TDC stores K_C^2,
/// zero-padded stores K_D^2.
pub fn layer_offchip_bytes(l: &Layer, method: Method) -> u64 {
    let word = 4u64;
    let input = (l.c_in * l.h_in * l.w_in) as u64 * word;
    let output = (l.c_out * l.h_out() * l.w_out()) as u64 * word;
    let weights = match (l.kind, method) {
        (Kind::Conv, _) => (l.c_in * l.c_out * l.k * l.k) as u64 * word,
        (Kind::Deconv, Method::ZeroPadded) => (l.c_in * l.c_out * l.k * l.k) as u64 * word,
        (Kind::Deconv, Method::Tdc) => {
            let kc = tdc::kc(l.k, l.s);
            (l.s * l.s * l.c_in * l.c_out * kc * kc) as u64 * word
        }
        (Kind::Deconv, Method::Winograd) => {
            // live transformed weights only (zero rows are neither stored
            // in the reordered layout nor transferred)
            (l.c_in * l.c_out * c_of_kc(l.k, l.s, l.p)) as u64 * word
        }
    };
    input + output + weights
}

/// On-chip (BRAM <-> PE) accesses for one deconv layer: every issued
/// multiplication reads one activation operand and one weight operand;
/// accumulators live in registers. Zero-padded reads include the inserted
/// zeros (that is the inefficiency the paper highlights); TDC/Winograd do
/// not.
pub fn layer_onchip_accesses(l: &Layer, method: Method) -> u64 {
    2 * layer_mults(l, method)
}

/// Transform-stage add operations (pre-PE B^T Z B + post-PE A^T M A) for
/// the Winograd method; zero for the baselines. Sparse inverse transform:
/// adds are skipped in proportion to zero positions (paper §III.A).
pub fn layer_transform_adds(l: &Layer, method: Method) -> u64 {
    if method != Method::Winograd || l.kind != Kind::Deconv {
        return 0;
    }
    let tiles = (l.h_in as u64).div_ceil(M_TILE as u64) * (l.w_in as u64).div_ceil(M_TILE as u64);
    // pre-PE: 2*n*(n) adds per B^T Z B per input channel per phase tile; the
    // input transform is shared across output channels.
    let pre_per_tile = (2 * N_TILE * N_TILE) as u64 * l.c_in as u64 * (l.s * l.s) as u64;
    // post-PE: A^T M A costs at most 24 adds per tile; sparse skipping saves
    // proportionally to dead positions. live/16 scaling.
    let live: u64 = crate::winograd::sparsity::phase_cases(l.k, l.s, l.p)
        .iter()
        .map(|c| c.live_positions() as u64)
        .sum();
    let post_per_tile = 24 * l.c_out as u64 * live / 16;
    tiles * (pre_per_tile + post_per_tile)
}

/// Fig. 4 row: total DeConv multiplications per model per method.
pub fn fig4_row(g: &Gan) -> (u64, u64, u64) {
    (
        model_deconv_mults(g, Method::ZeroPadded),
        model_deconv_mults(g, Method::Tdc),
        model_deconv_mults(g, Method::Winograd),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gan::zoo::{self, Scale};

    #[test]
    fn dcgan_reduction_ratios_match_paper() {
        // Paper Fig. 9 text: "the number of multiplications required was up
        // to 8.16x greater than our design" for DCGAN; TDC/ZP = 100/36.
        let g = zoo::dcgan(Scale::Paper);
        let (zp, tdc_m, win) = fig4_row(&g);
        let r_zp_win = zp as f64 / win as f64;
        let r_zp_tdc = zp as f64 / tdc_m as f64;
        assert!((r_zp_win - 8.16).abs() < 0.05, "ZP/Win = {r_zp_win}");
        assert!((r_zp_tdc - 2.78).abs() < 0.05, "ZP/TDC = {r_zp_tdc}");
    }

    #[test]
    fn k4_models_ratios() {
        // K_D=4: ZP/Win = 64/9 ≈ 7.11, TDC/Win = 16/9 ≈ 1.78
        let g = zoo::gpgan(Scale::Paper);
        let (zp, tdc_m, win) = fig4_row(&g);
        assert!(((zp as f64 / win as f64) - 64.0 / 9.0).abs() < 0.01);
        assert!(((tdc_m as f64 / win as f64) - 16.0 / 9.0).abs() < 0.01);
    }

    #[test]
    fn winograd_never_more_mults() {
        for g in zoo::all(Scale::Paper) {
            for l in g.deconv_layers() {
                let zp = layer_mults(l, Method::ZeroPadded);
                let td = layer_mults(l, Method::Tdc);
                let wi = layer_mults(l, Method::Winograd);
                assert!(wi <= td, "{} layer {:?}", g.name, l);
                assert!(td <= zp, "{} layer {:?}", g.name, l);
            }
        }
    }

    #[test]
    fn weight_bytes_ordering() {
        // Winograd transfers more weight data than TDC for K_C=3 (49 > 36
        // spatial taps... actually 49 live vs S^2*K_C^2=36): Table II's
        // extra BRAM. For K_C=2: 36 live vs 16 spatial.
        let g = zoo::dcgan(Scale::Paper);
        let l = g.layers[0];
        let zp = layer_offchip_bytes(&l, Method::ZeroPadded);
        let td = layer_offchip_bytes(&l, Method::Tdc);
        let wi = layer_offchip_bytes(&l, Method::Winograd);
        assert!(wi > td, "winograd stores transformed weights");
        assert!(td > zp, "TDC stores S^2 K_C^2 >= K_D^2 taps");
    }
}
