//! `wingan` — CLI for the Winograd-DeConv GAN acceleration system.
//!
//! Subcommands:
//!   tables              reproduce the paper's tables/figures (analytic+sim)
//!   sim                 cycle-simulate one/all GANs under all three methods
//!   dse                 design-space exploration (eq. 5-9 roofline sweep)
//!   verify              load every artifact, execute, check vs jax goldens
//!   serve               run the serving coordinator on a synthetic workload
//!   loadgen             open-loop Poisson A/B of the batch schedulers
//!   chaos               deterministic fault-injection soak of the serving tier
//!   compile             AOT-compile zoo plans into an on-disk plan store
//!   plan inspect FILE   print the manifest view of one plan artifact
//!   replica             serve one coordinator behind the fleet wire protocol
//!   router              front N replicas with health-probed failover routing
//!   probe               query a replica/router health endpoint (CI gate)
//!   trace               dump/follow flight-recorder spans from a node
//!   top                 live per-stage latency table scraped from a node

use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use wingan::accel::{simulate_model, AccelConfig};
use wingan::artifact::{describe, PlanKey, PlanStore};
use wingan::cli::Args;
use wingan::coordinator::{Coordinator, ServeConfig};
use wingan::energy::EnergyParams;
use wingan::engine::{NativeConfig, PlanOptions, Planner, Precision, ROUTE_METHODS};
use wingan::gan::workload::Method;
use wingan::gan::zoo::{self, Scale};
use wingan::report;
use wingan::runtime::{Manifest, Runtime};
use wingan::util::json::{self, Json};
use wingan::util::prng::Rng;

const USAGE: &str = "\
wingan — Winograd DeConv acceleration for GANs (Chang et al., 2019 reproduction)

USAGE: wingan <subcommand> [flags]

  tables [--table1|--fig4|--fig8|--fig9|--table2|--dse|--all]
  sim    [--model dcgan|artgan|discogan|gpgan] [--full-model] [--zero-skip]
  dse
  verify [--artifacts DIR]
  serve  [--artifacts DIR] [--native] [--scale small|tiny] [--model dcgan]
         [--method winograd] [--requests 64] [--rate 200] [--max-wait-ms 20]
         [--seed 7] [--workers N] [--precision f32|f64|auto]
         [--kernel scalar|simd|auto] [--plan-store DIR] [--weight-seed 42]
         [--check-compile] [--scheduler continuous|bucket] [--queue-cap 256]
         [--slo-ms N] [--inject-faults SPEC] [--stats-every SECS]
         [--trace-sample N] [--trace-seed S]
  loadgen [--quick] [--scale tiny|small] [--requests 800] [--load 1.2]
          [--rate R] [--slo-ms N] [--queue-cap 256] [--max-wait-ms 20]
          [--seed 7] [--workers N] [--out BENCH_pr7.json]
          [--connect HOST:PORT] [--trace-sample N] [--trace-seed S]
  chaos  [--quick] [--fleet] [--scale tiny|small] [--requests 600]
         [--rate 300] [--queue-cap 512] [--seed 11] [--workers N]
         [--spec SPEC] [--out BENCH_pr8.json] [--trace-sample N]
         [--trace-seed S]
  compile [--store DIR] [--scale small|tiny|all] [--models dcgan,gpgan]
          [--seed 42]
  plan   inspect <artifact-file>
  replica [--bind 127.0.0.1:7411] [--plan-store DIR] [--scale small|tiny]
          [--models dcgan,gpgan] [--workers N] [--precision f32|f64|auto]
          [--kernel scalar|simd|auto] [--scheduler continuous|bucket]
          [--queue-cap 256] [--slo-ms N] [--weight-seed 42]
          [--inject-faults SPEC] [--watch-stdin] [--stats-every SECS]
          [--trace-sample N] [--trace-seed S]
  router [--bind 127.0.0.1:7410] --replicas HOST:PORT[,HOST:PORT...]
         [--store DIR] [--trace-sample N] [--trace-seed S]
  probe  --addr HOST:PORT [--wait-ready SECS] [--metrics]
         [--format json|prometheus]
  trace  <HOST:PORT | --addr HOST:PORT> [--id TRACE_ID] [--limit N]
         [--follow]
  top    <HOST:PORT | --addr HOST:PORT> [--interval SECS] [--count N]

serve runs on the native precompiled-plan engine when --native is given or
when the PJRT artifacts are unavailable (this offline build always is).
--workers sizes the one persistent worker pool every route's engine shares
(0/absent = WINGAN_WORKERS env, then one thread per core).
--precision picks the serving tier for the fast routes: f32 (half the
memory traffic), f64 (the bit-exact reference tier), or auto/absent
(WINGAN_PRECISION env, then the per-model dse recommendation). The tdc
reference route always serves f64.
--kernel picks the Winograd GEMM micro-kernel compiled into the fast
routes' plans: simd (explicit AVX2/NEON, bitwise-identical outputs), scalar
(the blocked portable loop), or auto/absent (WINGAN_KERNEL env, then SIMD
whenever the host supports it). Forcing simd on a host without it falls
back to scalar with a logged correction.
--plan-store boots route plans from AOT artifacts (see `compile`) instead
of compiling at startup; missing/corrupt artifacts fall back to in-process
compilation and are (re)published. --weight-seed picks the native weight
seed and must match the store's `compile --seed` to boot warm (both
default 42; --seed only seeds the request workload). --check-compile
additionally boots a store-free coordinator and asserts both serve
bitwise-identical outputs.

serve's scheduler flags: --scheduler picks the batch scheduler (continuous
= work-conserving continuous batching with SLO-aware admission, the
default; bucket = the PR-6 bucket-and-deadline baseline), --queue-cap
bounds each route's admission queue (typed queue-full sheds past it), and
--slo-ms sets a default per-request deadline (infeasible/expired requests
get typed deadline sheds; absent = best-effort, no deadline shedding).

serve's fault tooling: --inject-faults installs a deterministic seeded
fault plane (grammar: 'seed=N;site:action[*count][@rate]' with sites
worker_chunk|batch_exec|artifact_load and actions
panic|error|wrong_shape|delay=MSms — e.g.
'seed=7;batch_exec:panic@0.01'); the WINGAN_FAULTS env var is the
flagless equivalent. Injected panics are contained at the batch
boundary, poisoned batches are bisected so only the poison request
fails, and the per-route supervisor restarts dead engines (capped
backoff, circuit breaker, stuck-batch watchdog) — the serving report
ends with the per-route health verdict.

loadgen replays one open-loop Poisson arrival schedule (mixed models +
methods, so mixed precision tiers) against BOTH schedulers at equal
offered load and writes the A/B (achieved vs offered rate, shed fraction,
p50/p99/p999) to --out. --load expresses the offered rate as a multiple
of calibrated capacity (1.2 = 20% overload); --rate overrides it
absolutely. --quick is the CI smoke preset. --max-wait-ms is the bucket
baseline's hold window (continuous always runs work-conserving).

chaos replays one seeded arrival schedule twice — fault-free, then under
--spec (default: a guaranteed panic burst + ~1% background batch panics)
— and asserts the fault-isolation contract: every request gets exactly
one fate, requests completing in both runs are bitwise identical, storms
restart engines and every route is Healthy again by the end. The outcome
goes to --out (default BENCH_pr8.json). --quick is the CI smoke preset.

compile AOT-compiles zoo generator plans into a plan store: every model x
route method (winograd + tdc) x precision tier (f64 always, f32 for the
fast routes) at the serving scales, plus a human-readable manifest.json.
Each compile run also bumps the store's monotonic GENERATION tag, which a
running `router --store` notices and answers with a rolling reload.

Fleet tier: `replica` serves one coordinator behind a std-only
length-prefixed TCP wire protocol, warm-booting from --plan-store and
answering typed NOT_READY until the boot lands; `router` fronts N
replicas with least-loaded routing over a health prober, per-replica
circuit breakers, and retry-with-backoff failover (request ids make
retries idempotent — a replayed completion is bitwise identical). When
every replica is out, requests shed immediately with a typed
fleet-unavailable verdict. `probe --addr X --wait-ready S` polls the
health JSON until ready/all-ready (non-zero exit on timeout) — the CI
readiness gate. Replicas drain gracefully on SIGTERM/SIGINT (or stdin
EOF with --watch-stdin): in-flight work finishes inside the drain
deadline, the prober sees `draining` so the router deregisters first,
and leftovers get typed EngineShutdown — never an abrupt connection
drop. `chaos --fleet` is the kill-a-replica soak: one seeded schedule
against a single-process baseline and then a 3-replica fleet (with
conn-drop and stall faults) whose middle replica is killed mid-run;
asserts zero lost requests, bitwise equality with the baseline, and
timed recovery to all-ready after a replacement joins (BENCH_pr9.json).
`loadgen --connect HOST:PORT` drives a remote router instead of an
in-process coordinator (requires an explicit --rate; no local engine to
calibrate against).

Observability: --trace-sample N arms the in-process flight recorder on
serve/replica/router (1 = trace every request, N = one in N, seeded by
--trace-seed so a deterministic load replays with the same requests
traced; 0/absent = off, ~zero cost). Traced requests carry one id across
the wire, so a routed request's spans (admission, queue, batch, per-layer
input-transform/GEMM/inverse/activation, wire round-trips, per-attempt
failover verdicts) stitch into one cross-process tree. Scrape with:
`probe --metrics` (the telemetry document; --format prometheus for text
exposition), `trace HOST:PORT` (recent spans; --id for one request's
tree — ask the router and the reply merges every replica's spans;
--follow to tail), `top HOST:PORT` (per-stage latency table, refreshed
every --interval seconds). serve/replica additionally emit one compact
JSON metrics line to stderr every --stats-every seconds.
";

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n{USAGE}");
            std::process::exit(2);
        }
    };
    // only `plan` (an action word) and `trace`/`top` (a bare HOST:PORT)
    // take positional arguments after the subcommand; a stray positional
    // anywhere else is a typo, not a default to run with
    if !matches!(args.subcommand.as_deref(), Some("plan") | Some("trace") | Some("top")) {
        if let Err(e) = args.reject_positionals() {
            eprintln!("error: {e}\n{USAGE}");
            std::process::exit(2);
        }
    }
    let rc = match args.subcommand.as_deref() {
        Some("tables") | Some("bench-tables") => cmd_tables(&args),
        Some("sim") => cmd_sim(&args),
        Some("dse") => {
            print!("{}", report::dse_table());
            Ok(())
        }
        Some("verify") => cmd_verify(&args),
        Some("serve") => cmd_serve(&args),
        Some("loadgen") => cmd_loadgen(&args),
        Some("chaos") => cmd_chaos(&args),
        Some("compile") => cmd_compile(&args),
        Some("plan") => cmd_plan(&args),
        Some("replica") => cmd_replica(&args),
        Some("router") => cmd_router(&args),
        Some("probe") => cmd_probe(&args),
        Some("trace") => cmd_trace(&args),
        Some("top") => cmd_top(&args),
        Some("version") => {
            println!("wingan {}", wingan::version());
            Ok(())
        }
        _ => {
            print!("{USAGE}");
            Ok(())
        }
    };
    if let Err(e) = rc {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn cmd_tables(args: &Args) -> anyhow::Result<()> {
    let cfg = AccelConfig::default();
    let ep = EnergyParams::default();
    let all = args.has("all")
        || !["table1", "fig4", "fig8", "fig9", "table2", "dse"].iter().any(|f| args.has(f));
    if all || args.has("table1") {
        println!("{}", report::table1());
    }
    if all || args.has("fig4") {
        println!("{}", report::fig4());
    }
    if all || args.has("fig8") {
        println!("{}", report::fig8(&cfg));
    }
    if all || args.has("fig9") {
        println!("{}", report::fig9(&cfg, &ep));
    }
    if all || args.has("table2") {
        println!("{}", report::table2(&cfg));
    }
    if all || args.has("dse") {
        println!("{}", report::dse_table());
    }
    Ok(())
}

fn cmd_sim(args: &Args) -> anyhow::Result<()> {
    let mut cfg = AccelConfig::default();
    if args.has("zero-skip") {
        cfg.zp_zero_skip = true;
    }
    let deconv_only = !args.has("full-model");
    let wanted = args.get_or("model", "all");
    for g in zoo::all(Scale::Paper) {
        if wanted != "all" && !g.name.eq_ignore_ascii_case(wanted) {
            continue;
        }
        println!("== {} ({} deconv / {} conv layers) ==", g.name, g.n_deconv(), g.n_conv());
        for m in Method::ALL {
            let sim = simulate_model(&g, m, &cfg, deconv_only);
            println!(
                "  {:<16} t={:>8.3} ms   mults={:>7.2} G   DDR={:>7.1} MB   GOP/s={:>7.1}",
                m.label(),
                sim.t_total * 1e3,
                sim.mults as f64 / 1e9,
                sim.offchip_bytes as f64 / 1e6,
                sim.effective_gops(&g, deconv_only),
            );
        }
    }
    Ok(())
}

fn cmd_verify(args: &Args) -> anyhow::Result<()> {
    let dir = args.get_or("artifacts", "artifacts");
    let manifest = Manifest::load(Path::new(dir))?;
    let mut rt = Runtime::new()?;
    println!("platform: {}; {} artifacts", rt.platform(), manifest.entries.len());
    let mut worst = 0f32;
    for e in &manifest.entries {
        let t0 = Instant::now();
        rt.load(e)?;
        let compile = t0.elapsed();
        let t0 = Instant::now();
        let diff = rt.verify_golden(&e.name)?;
        worst = worst.max(diff);
        println!(
            "  {:<18} compile {compile:>7.2?}  exec {:>8.2?}  max|Δ| {diff:.2e}  {}",
            e.name,
            t0.elapsed(),
            if diff < 2e-4 { "OK" } else { "FAIL" }
        );
        if diff >= 2e-4 {
            anyhow::bail!("artifact {} exceeds tolerance: {diff:e}", e.name);
        }
    }
    println!("all {} artifacts verified (worst max|Δ| = {worst:.2e})", manifest.entries.len());
    Ok(())
}

fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    let dir = args.get_or("artifacts", "artifacts");
    // normalize to the manifest route ids shared by both backends
    // ("GP-GAN"/"gp-gan"/"gpgan" all mean "gpgan")
    let model = wingan::engine::model_id(args.get_or("model", "dcgan"));
    let method = args.get_or("method", "winograd").to_string();
    let n_requests = args.get_usize("requests", 64).map_err(anyhow::Error::msg)?;
    let rate = args.get_f64("rate", 200.0).map_err(anyhow::Error::msg)?;
    let max_wait = args.get_usize("max-wait-ms", 20).map_err(anyhow::Error::msg)?;
    let seed = args.get_usize("seed", 7).map_err(anyhow::Error::msg)? as u64;
    let workers = args.get_workers().map_err(anyhow::Error::msg)?;
    let precision = args.get_precision().map_err(anyhow::Error::msg)?;
    let kernel = args.get_kernel().map_err(anyhow::Error::msg)?;
    let plan_store = args.get("plan-store").map(PathBuf::from);
    // weight seed for the native plans — must match `compile --seed` for a
    // plan store to boot warm (both default to 42). Distinct from --seed,
    // which seeds the synthetic request workload.
    let weight_seed = args.get_usize("weight-seed", 42).map_err(anyhow::Error::msg)? as u64;

    let scheduler = args.get_scheduler().map_err(anyhow::Error::msg)?;
    let queue_cap = args.get_usize("queue-cap", 256).map_err(anyhow::Error::msg)?;
    let slo = match args.get_usize("slo-ms", 0).map_err(anyhow::Error::msg)? {
        0 if args.get("slo-ms").is_some() => {
            anyhow::bail!("--slo-ms: 0 would shed every request; omit the flag for best-effort")
        }
        0 => None,
        ms => Some(Duration::from_millis(ms as u64)),
    };
    // explicit --inject-faults wins; WINGAN_FAULTS env is the flagless
    // equivalent; production runs carry neither and pay one branch per batch
    let faults = match args.get("inject-faults") {
        Some(spec) => Some(std::sync::Arc::new(
            wingan::faultinject::FaultPlane::parse(spec)
                .map_err(|e| anyhow::anyhow!("--inject-faults: {e}"))?,
        )),
        None => wingan::faultinject::FaultPlane::from_env()
            .map_err(|e| anyhow::anyhow!("WINGAN_FAULTS: {e}"))?,
    };
    // observability: arm the flight recorder (0/absent = sampling off,
    // ~zero cost) and the periodic machine-readable stats line
    configure_recorder(args, "serve")?;
    let stats_every = args.get_usize("stats-every", 0).map_err(anyhow::Error::msg)?;
    let serve_cfg = ServeConfig {
        max_wait: Duration::from_millis(max_wait as u64),
        preload_models: Some(vec![model.clone()]),
        scheduler,
        queue_cap,
        slo,
        faults: faults.clone(),
        ..Default::default()
    };
    // a plan store only means something to the native backend
    let use_native = args.has("native")
        || plan_store.is_some()
        || !Path::new(dir).join("manifest.json").exists();
    let t0 = Instant::now();
    let mut native_cfg = None;
    let coord = if use_native {
        let scale = serving_scale(args)?;
        let cfg = NativeConfig {
            scale,
            workers,
            precision,
            kernel,
            seed: weight_seed,
            plan_store: plan_store.clone(),
            ..Default::default()
        };
        match &plan_store {
            Some(store) => println!(
                "booting native engine plans for {model} from plan store {} \
                 ({scale:?} scale, pool of {} workers, precision policy {:?}, \
                 kernel policy {:?})...",
                store.display(),
                wingan::engine::resolve_workers(workers),
                wingan::engine::resolve_precision(precision),
                wingan::engine::resolve_kernel(kernel),
            ),
            None => println!(
                "compiling native engine plans for {model} ({scale:?} scale, pool of {} workers, \
                 precision policy {:?}, kernel policy {:?})...",
                wingan::engine::resolve_workers(workers),
                wingan::engine::resolve_precision(precision),
                wingan::engine::resolve_kernel(kernel),
            ),
        }
        native_cfg = Some(cfg.clone());
        Coordinator::start_native(cfg, serve_cfg.clone())?
    } else {
        let manifest = Manifest::load(Path::new(dir))?;
        println!("loading + compiling {model} artifacts...");
        Coordinator::start(manifest, serve_cfg.clone())?
    };
    println!("engine ready in {:?}", t0.elapsed());
    if plan_store.is_some() {
        let s = coord.metrics().plan_cache;
        println!(
            "plan cache: {} artifact hits, {} fallback compiles, {} load failures, \
             {} published",
            s.artifact_hits, s.fallback_compiles, s.load_failures, s.published
        );
    }

    let route = coord.router().route(&model, &method).map_err(anyhow::Error::msg)?;
    let input_len = route.sample_input_len;

    // CI round-trip gate: the store-backed coordinator must serve exactly
    // what a compile-in-process coordinator serves
    if args.has("check-compile") {
        let cfg = native_cfg
            .clone()
            .ok_or_else(|| anyhow::anyhow!("--check-compile requires the native backend"))?;
        let baseline =
            Coordinator::start_native(NativeConfig { plan_store: None, ..cfg }, serve_cfg.clone())?;
        let mut crng = Rng::new(seed ^ 0x5EED_C0DE);
        for i in 0..4 {
            let input = crng.normal_vec_f32(input_len);
            let a = coord.generate(&model, &method, input.clone()).map_err(anyhow::Error::msg)?;
            let b = baseline.generate(&model, &method, input).map_err(anyhow::Error::msg)?;
            anyhow::ensure!(
                a.output == b.output,
                "request {i}: store-served output diverges from compile-in-process"
            );
        }
        baseline.shutdown();
        println!(
            "check-compile: store-served outputs match in-process compilation bit for bit \
             (4 probe requests are included in the serving report below)"
        );
    }
    let buckets = route.bucket_sizes();
    println!(
        "serving {n_requests} requests to {model}/{method} (Poisson {rate}/s, buckets {buckets:?})"
    );

    // open-loop Poisson arrivals; typed sheds (queue full / deadline
    // infeasible under --queue-cap and --slo-ms) are counted, not fatal
    let mut rng = Rng::new(seed);
    let mut pending = Vec::new();
    let mut shed = 0u64;
    let t_start = Instant::now();
    let mut last_stats = Instant::now();
    for i in 0..n_requests {
        let input = rng.normal_vec_f32(input_len);
        match coord.submit(&model, &method, input) {
            Ok(rx) => pending.push(rx),
            Err(e) if e.is_shed() => shed += 1,
            Err(e) => return Err(anyhow::Error::msg(e)),
        }
        if stats_every > 0 && last_stats.elapsed() >= Duration::from_secs(stats_every as u64) {
            emit_stats_line("serve", coord.metrics().to_json());
            last_stats = Instant::now();
        }
        if i + 1 < n_requests {
            std::thread::sleep(Duration::from_secs_f64(rng.exponential(rate)));
        }
    }
    let mut checksum = 0.0f64;
    let mut completed = 0u64;
    for rx in pending {
        match rx.recv()? {
            Ok(resp) => {
                completed += 1;
                checksum += resp.output.iter().map(|v| *v as f64).sum::<f64>();
            }
            Err(e) if e.is_shed() => shed += 1,
            Err(e) => return Err(anyhow::Error::msg(e)),
        }
    }
    let wall = t_start.elapsed();
    let m = coord.metrics();
    println!("\n== serving report ==");
    println!("{}", m.report());
    println!("{}", coord.health().report());
    if let Some(plane) = &faults {
        println!("{}", plane.summary());
    }
    println!(
        "wall={:.3}s  completed={completed}/{n_requests} (shed {shed})  \
         throughput={:.1} img/s  output checksum={checksum:.3}",
        wall.as_secs_f64(),
        completed as f64 / wall.as_secs_f64()
    );
    if stats_every > 0 {
        // one closing line so short runs still leave a scrapeable record
        emit_stats_line("serve", coord.metrics().to_json());
    }
    coord.shutdown();
    Ok(())
}

/// Wire up the process-global flight recorder from `--trace-sample N`
/// (0/absent = tracing off) and `--trace-seed S`, labelling this
/// process's spans with `node` so merged cross-process traces say where
/// each span ran.
fn configure_recorder(args: &Args, node: &str) -> anyhow::Result<()> {
    let sample = args.get_usize("trace-sample", 0).map_err(anyhow::Error::msg)? as u64;
    let seed = args.get_usize("trace-seed", 0).map_err(anyhow::Error::msg)? as u64;
    wingan::telemetry::recorder().configure(sample, seed, node);
    Ok(())
}

/// One compact machine-readable stats line on **stderr** (stdout stays
/// the human report): role, node, the coordinator metrics document, and
/// the flight recorder's per-stage histograms.
fn emit_stats_line(role: &str, metrics: Json) {
    let rec = wingan::telemetry::recorder();
    let doc = json::obj(vec![
        ("role", json::s(role)),
        ("node", json::s(&rec.node())),
        ("metrics", metrics),
        ("stages", rec.stages_json()),
    ]);
    eprintln!("{}", json::to_string(&doc));
}

/// `wingan loadgen` — open-loop Poisson A/B of the batch schedulers: one
/// pre-generated arrival schedule (mixed models + methods, so mixed
/// precision tiers) replayed against the continuous and bucket
/// coordinators at equal offered load; the machine-readable outcome goes
/// to `--out` (default `BENCH_pr7.json`).
fn cmd_loadgen(args: &Args) -> anyhow::Result<()> {
    // armed only on request: the A/B's headline numbers stay untraced
    // (and run-over-run comparable) unless --trace-sample asks for the
    // stage breakdown in the BENCH report
    configure_recorder(args, "loadgen")?;
    let mut opts = if args.has("quick") {
        wingan::loadgen::LoadgenOptions::quick()
    } else {
        wingan::loadgen::LoadgenOptions::default()
    };
    if args.get("scale").is_some() {
        opts.scale = serving_scale(args)?;
    }
    opts.requests = args.get_usize("requests", opts.requests).map_err(anyhow::Error::msg)?;
    opts.load = args.get_f64("load", opts.load).map_err(anyhow::Error::msg)?;
    anyhow::ensure!(opts.load > 0.0, "--load must be positive");
    if args.get("rate").is_some() {
        let r = args.get_f64("rate", 0.0).map_err(anyhow::Error::msg)?;
        anyhow::ensure!(r > 0.0, "--rate must be positive");
        opts.rate = Some(r);
    }
    if args.get("slo-ms").is_some() {
        let ms = args.get_usize("slo-ms", 0).map_err(anyhow::Error::msg)?;
        anyhow::ensure!(ms > 0, "--slo-ms: 0 would shed every request");
        opts.slo = Some(Duration::from_millis(ms as u64));
    }
    opts.queue_cap = args.get_usize("queue-cap", opts.queue_cap).map_err(anyhow::Error::msg)?;
    anyhow::ensure!(opts.queue_cap > 0, "--queue-cap must be at least 1");
    let hold = args.get_usize("max-wait-ms", 20).map_err(anyhow::Error::msg)?;
    opts.bucket_max_wait = Duration::from_millis(hold as u64);
    opts.seed = args.get_usize("seed", opts.seed as usize).map_err(anyhow::Error::msg)? as u64;
    opts.workers = args.get_workers().map_err(anyhow::Error::msg)?;
    if let Some(out) = args.get("out") {
        opts.out = PathBuf::from(out);
    }
    // --connect: drive a remote fleet router instead of in-process engines
    if let Some(router_addr) = args.get("connect") {
        anyhow::ensure!(
            opts.rate.is_some(),
            "--connect needs an explicit --rate (no local engine to calibrate against)"
        );
        opts.connect = Some(router_addr.to_string());
        if args.get("out").is_none() {
            // don't clobber the local A/B report with the remote run's
            opts.out = PathBuf::from("BENCH_pr9_fleet_loadgen.json");
        }
        return wingan::loadgen::run_remote(&opts, router_addr);
    }
    let (continuous, bucket) = wingan::loadgen::run(&opts)?;
    anyhow::ensure!(
        continuous.completed + bucket.completed > 0,
        "loadgen completed zero requests"
    );
    Ok(())
}

/// `wingan chaos` — deterministic fault-injection soak: one seeded arrival
/// schedule replayed fault-free and then under a fault plane, with the
/// conservation / bitwise-isolation / bounded-recovery contract asserted
/// and the outcome written to `--out` (default `BENCH_pr8.json`).
fn cmd_chaos(args: &Args) -> anyhow::Result<()> {
    configure_recorder(args, "chaos")?;
    let mut opts = if args.has("quick") {
        wingan::chaos::ChaosOptions::quick()
    } else {
        wingan::chaos::ChaosOptions::default()
    };
    if args.get("scale").is_some() {
        opts.scale = serving_scale(args)?;
    }
    opts.requests = args.get_usize("requests", opts.requests).map_err(anyhow::Error::msg)?;
    anyhow::ensure!(opts.requests > 0, "--requests must be at least 1");
    if args.get("rate").is_some() {
        let r = args.get_f64("rate", 0.0).map_err(anyhow::Error::msg)?;
        anyhow::ensure!(r > 0.0, "--rate must be positive");
        opts.rate = r;
    }
    opts.queue_cap = args.get_usize("queue-cap", opts.queue_cap).map_err(anyhow::Error::msg)?;
    anyhow::ensure!(opts.queue_cap > 0, "--queue-cap must be at least 1");
    opts.seed = args.get_usize("seed", opts.seed as usize).map_err(anyhow::Error::msg)? as u64;
    opts.workers = args.get_workers().map_err(anyhow::Error::msg)?;
    if let Some(spec) = args.get("spec") {
        opts.spec = Some(spec.to_string());
    }
    if let Some(out) = args.get("out") {
        opts.out = PathBuf::from(out);
    }
    if args.has("fleet") {
        if args.get("out").is_none() {
            // the fleet soak is the PR-9 bench artifact
            opts.out = PathBuf::from("BENCH_pr9.json");
        }
        return wingan::chaos::run_fleet(&opts);
    }
    wingan::chaos::run(&opts)
}

/// Process-wide graceful-shutdown latch: SIGTERM/SIGINT (unix) and the
/// optional stdin-EOF watcher all funnel into one atomic the serve loops
/// poll, so a clean roll never ends in an abrupt connection drop.
mod shutdown {
    use std::sync::atomic::{AtomicBool, Ordering};

    static STOP: AtomicBool = AtomicBool::new(false);

    pub fn request() {
        STOP.store(true, Ordering::SeqCst);
    }

    pub fn requested() -> bool {
        STOP.load(Ordering::SeqCst)
    }

    /// Route SIGTERM and SIGINT into the latch. The handler only stores
    /// an atomic — async-signal-safe by construction.
    #[cfg(unix)]
    pub fn install_signal_handlers() {
        extern "C" fn on_term(_sig: i32) {
            STOP.store(true, Ordering::SeqCst);
        }
        extern "C" {
            fn signal(signum: i32, handler: usize) -> usize;
        }
        const SIGINT: i32 = 2;
        const SIGTERM: i32 = 15;
        unsafe {
            signal(SIGTERM, on_term as usize);
            signal(SIGINT, on_term as usize);
        }
    }

    #[cfg(not(unix))]
    pub fn install_signal_handlers() {}

    /// Trip the latch when stdin reaches EOF — the idiom for a replica
    /// supervised through a pipe (the parent closing its end is the
    /// drain request).
    pub fn watch_stdin() {
        std::thread::spawn(|| {
            use std::io::Read;
            let mut stdin = std::io::stdin();
            let mut buf = [0u8; 256];
            loop {
                match stdin.read(&mut buf) {
                    Ok(0) | Err(_) => break,
                    Ok(_) => {}
                }
            }
            request();
        });
    }
}

/// `wingan replica` — one serving coordinator behind the fleet wire
/// protocol: binds immediately, warm-boots from `--plan-store` in the
/// background (typed `NOT_READY` in the gap), then serves requests and
/// drain/reload/shutdown control verbs until stopped. SIGTERM/SIGINT
/// (and stdin EOF under `--watch-stdin`) trigger the graceful path:
/// drain bounded by the serve config's drain deadline, `draining`
/// visible to the router's prober, leftovers answered `EngineShutdown`.
fn cmd_replica(args: &Args) -> anyhow::Result<()> {
    let bind = args.get_or("bind", "127.0.0.1:7411").to_string();
    let scale = serving_scale(args)?;
    let workers = args.get_workers().map_err(anyhow::Error::msg)?;
    let precision = args.get_precision().map_err(anyhow::Error::msg)?;
    let kernel = args.get_kernel().map_err(anyhow::Error::msg)?;
    let scheduler = args.get_scheduler().map_err(anyhow::Error::msg)?;
    let plan_store = args.get("plan-store").map(PathBuf::from);
    let weight_seed = args.get_usize("weight-seed", 42).map_err(anyhow::Error::msg)? as u64;
    let queue_cap = args.get_usize("queue-cap", 256).map_err(anyhow::Error::msg)?;
    let slo = match args.get_usize("slo-ms", 0).map_err(anyhow::Error::msg)? {
        0 if args.get("slo-ms").is_some() => {
            anyhow::bail!("--slo-ms: 0 would shed every request; omit the flag for best-effort")
        }
        0 => None,
        ms => Some(Duration::from_millis(ms as u64)),
    };
    let models: Option<Vec<String>> = args
        .get("models")
        .map(|list| list.split(',').map(wingan::engine::model_id).collect());
    // one fault spec covers both layers: engine/serving sites act inside
    // the coordinator, fleet sites (conn_drop/replica_stall/replica_exit)
    // act at the wire — the sites are disjoint, so sharing the plane is
    // exact, not approximate
    let faults = match args.get("inject-faults") {
        Some(spec) => Some(std::sync::Arc::new(
            wingan::faultinject::FaultPlane::parse(spec)
                .map_err(|e| anyhow::anyhow!("--inject-faults: {e}"))?,
        )),
        None => wingan::faultinject::FaultPlane::from_env()
            .map_err(|e| anyhow::anyhow!("WINGAN_FAULTS: {e}"))?,
    };
    let cfg = wingan::fleet::ReplicaConfig {
        native: NativeConfig {
            scale,
            workers,
            precision,
            kernel,
            seed: weight_seed,
            models,
            plan_store: plan_store.clone(),
            ..Default::default()
        },
        serve: ServeConfig {
            scheduler,
            queue_cap,
            slo,
            faults: faults.clone(),
            ..Default::default()
        },
        fleet_faults: faults,
    };
    // the bind address is the natural node label: it's what the router's
    // merged traces and the CI scrape will call this process
    configure_recorder(args, &format!("replica:{bind}"))?;
    let stats_every = args.get_usize("stats-every", 0).map_err(anyhow::Error::msg)?;
    let server = wingan::fleet::ReplicaServer::spawn(&bind, cfg)?;
    match &plan_store {
        Some(s) => println!(
            "replica listening on {} (warm-booting from {}...)",
            server.addr(),
            s.display()
        ),
        None => println!("replica listening on {} (compiling plans...)", server.addr()),
    }
    shutdown::install_signal_handlers();
    if args.has("watch-stdin") {
        shutdown::watch_stdin();
    }
    let mut announced = false;
    let mut last_stats = Instant::now();
    while server.alive() && !shutdown::requested() {
        if !announced && server.ready() {
            println!("replica ready on {}", server.addr());
            announced = true;
        }
        if let Some(e) = server.boot_error() {
            anyhow::bail!("replica boot failed: {e}");
        }
        if stats_every > 0 && last_stats.elapsed() >= Duration::from_secs(stats_every as u64) {
            // the replica document already carries role/node/stages
            eprintln!("{}", json::to_string(&server.metrics_json()));
            last_stats = Instant::now();
        }
        std::thread::sleep(Duration::from_millis(50));
    }
    if server.alive() {
        println!("replica: shutdown requested — draining...");
        server.shutdown();
    } else {
        // stopped over the wire (Shutdown verb) or by a replica_exit
        // fault; the serve loop is already winding down
        server.join();
    }
    println!("replica: stopped");
    Ok(())
}

/// `wingan router` — front N replicas with the fleet router: health
/// prober, least-loaded pick, circuit breakers, retry-with-backoff
/// failover, and (with `--store`) automatic rolling reloads when the
/// plan store's generation tag moves.
fn cmd_router(args: &Args) -> anyhow::Result<()> {
    let bind = args.get_or("bind", "127.0.0.1:7410").to_string();
    let replicas: Vec<String> = args
        .get("replicas")
        .ok_or_else(|| anyhow::anyhow!("--replicas HOST:PORT[,HOST:PORT...] is required"))?
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    anyhow::ensure!(!replicas.is_empty(), "--replicas lists no addresses");
    let store = args.get("store").map(PathBuf::from);
    configure_recorder(args, &format!("router:{bind}"))?;
    let n = replicas.len();
    let router = std::sync::Arc::new(
        wingan::fleet::FleetRouter::new(wingan::fleet::FleetConfig {
            replicas,
            store: store.clone(),
            ..Default::default()
        })
        .map_err(anyhow::Error::msg)?,
    );
    let server = wingan::fleet::RouterServer::spawn(&bind, std::sync::Arc::clone(&router))?;
    match &store {
        Some(s) => println!(
            "router listening on {} fronting {n} replica(s), watching {} for republishes",
            server.addr(),
            s.display()
        ),
        None => println!("router listening on {} fronting {n} replica(s)", server.addr()),
    }
    shutdown::install_signal_handlers();
    while !shutdown::requested() {
        std::thread::sleep(Duration::from_millis(100));
    }
    println!("router: shutdown requested — stopping");
    server.shutdown();
    Ok(())
}

/// `wingan probe` — one health query against a replica or router,
/// printed as JSON. With `--wait-ready SECS`, polls until the target
/// reports ready (replica) / all-ready (router), exiting non-zero on
/// timeout: the CI readiness gate for fleet smoke tests.
fn cmd_probe(args: &Args) -> anyhow::Result<()> {
    use wingan::fleet::{wire, WireMsg};
    let addr = args
        .get("addr")
        .ok_or_else(|| anyhow::anyhow!("--addr HOST:PORT is required"))?;
    let sock: std::net::SocketAddr = {
        use std::net::ToSocketAddrs;
        addr.to_socket_addrs()
            .map_err(|e| anyhow::anyhow!("bad address '{addr}': {e}"))?
            .next()
            .ok_or_else(|| anyhow::anyhow!("address '{addr}' resolves to nothing"))?
    };
    // --metrics: scrape the telemetry document instead of the health one
    if args.has("metrics") {
        let format = match args.get_or("format", "json") {
            "json" => wire::format::JSON,
            "prometheus" | "prom" => wire::format::PROMETHEUS,
            other => anyhow::bail!("--format: '{other}' is not one of json|prometheus"),
        };
        let mut s = std::net::TcpStream::connect_timeout(&sock, Duration::from_secs(2))
            .map_err(|e| anyhow::anyhow!("connect {sock}: {e}"))?;
        let _ = s.set_read_timeout(Some(Duration::from_secs(5)));
        let _ = s.set_write_timeout(Some(Duration::from_secs(5)));
        wire::send(&mut s, &WireMsg::MetricsQuery { format })?;
        return match wire::recv(&mut s) {
            Ok(WireMsg::MetricsReply { body }) => {
                println!("{body}");
                Ok(())
            }
            Ok(other) => anyhow::bail!("{addr} answered with a non-metrics frame: {other:?}"),
            Err(e) => anyhow::bail!("metrics query to {addr} failed: {e}"),
        };
    }
    let query = || -> anyhow::Result<Json> {
        let mut s = std::net::TcpStream::connect_timeout(&sock, Duration::from_secs(2))
            .map_err(|e| anyhow::anyhow!("connect {sock}: {e}"))?;
        let _ = s.set_read_timeout(Some(Duration::from_secs(2)));
        let _ = s.set_write_timeout(Some(Duration::from_secs(2)));
        wire::send(&mut s, &WireMsg::HealthQuery)?;
        match wire::recv(&mut s) {
            Ok(WireMsg::HealthReply { json: text }) => json::parse(&text)
                .map_err(|e| anyhow::anyhow!("unparsable health JSON from {addr}: {e}")),
            Ok(other) => anyhow::bail!("{addr} answered with a non-health frame: {other:?}"),
            Err(e) => anyhow::bail!("health query to {addr} failed: {e}"),
        }
    };
    let is_ready = |doc: &Json| {
        matches!(doc.get("ready"), Some(Json::Bool(true)))
            || matches!(doc.get("all_ready"), Some(Json::Bool(true)))
    };
    let wait = args.get_usize("wait-ready", 0).map_err(anyhow::Error::msg)?;
    if wait == 0 {
        let doc = query()?;
        println!("{}", json::to_string_pretty(&doc));
        return Ok(());
    }
    let deadline = Instant::now() + Duration::from_secs(wait as u64);
    loop {
        match query() {
            Ok(doc) if is_ready(&doc) => {
                println!("{}", json::to_string_pretty(&doc));
                println!("probe: {addr} ready");
                return Ok(());
            }
            Ok(_) | Err(_) if Instant::now() < deadline => {
                std::thread::sleep(Duration::from_millis(200));
            }
            Ok(doc) => {
                println!("{}", json::to_string_pretty(&doc));
                anyhow::bail!("probe: {addr} not ready within {wait}s");
            }
            Err(e) => anyhow::bail!("probe: {addr} unreachable within {wait}s: {e}"),
        }
    }
}

/// Target address for `trace`/`top`: `--addr HOST:PORT` or the bare
/// positional (`wingan trace 127.0.0.1:7410`).
fn telemetry_addr(args: &Args) -> anyhow::Result<String> {
    anyhow::ensure!(
        args.n_positionals() <= 1,
        "at most one positional HOST:PORT is accepted"
    );
    args.get("addr")
        .or_else(|| args.positional(0))
        .map(str::to_string)
        .ok_or_else(|| anyhow::anyhow!("an address is required (HOST:PORT or --addr HOST:PORT)"))
}

/// One wire round-trip against a replica or router telemetry endpoint.
fn telemetry_call(addr: &str, msg: &wingan::fleet::WireMsg) -> anyhow::Result<wingan::fleet::WireMsg> {
    use std::net::ToSocketAddrs;
    use wingan::fleet::wire;
    let sock = addr
        .to_socket_addrs()
        .map_err(|e| anyhow::anyhow!("bad address '{addr}': {e}"))?
        .next()
        .ok_or_else(|| anyhow::anyhow!("address '{addr}' resolves to nothing"))?;
    let mut s = std::net::TcpStream::connect_timeout(&sock, Duration::from_secs(2))
        .map_err(|e| anyhow::anyhow!("connect {sock}: {e}"))?;
    let _ = s.set_read_timeout(Some(Duration::from_secs(5)));
    let _ = s.set_write_timeout(Some(Duration::from_secs(5)));
    wire::send(&mut s, msg)?;
    match wire::recv(&mut s) {
        Ok(reply) => Ok(reply),
        Err(e) => anyhow::bail!("query to {addr} failed: {e}"),
    }
}

/// One human-readable span row.
fn span_line(sp: &Json) -> String {
    let s = |k: &str| sp.get(k).and_then(Json::as_str).unwrap_or("?");
    let n = |k: &str| sp.get(k).and_then(Json::as_usize).unwrap_or(0);
    format!(
        "{:<22} trace={:<16} {:<16} +{:>10}us {:>9}us a={:<4} b={:<3} {}",
        s("node"),
        n("trace"),
        s("stage"),
        n("start_us"),
        n("dur_us"),
        n("a"),
        n("b"),
        s("label"),
    )
}

/// `wingan trace` — dump recent flight-recorder spans from a replica or
/// router. `--id TRACE_ID` filters to one request's tree (a router's
/// reply already merges every replica's spans, so the tree is
/// cross-process); `--limit N` keeps only the newest N rows; `--follow`
/// polls twice a second, printing spans not seen yet.
fn cmd_trace(args: &Args) -> anyhow::Result<()> {
    use wingan::fleet::WireMsg;
    let addr = telemetry_addr(args)?;
    let id = args.get_usize("id", 0).map_err(anyhow::Error::msg)? as u64;
    let limit = args.get_usize("limit", 0).map_err(anyhow::Error::msg)?;
    let follow = args.has("follow");
    // (node, seq) names a span uniquely across the merged document
    let mut seen: std::collections::BTreeSet<(String, usize)> = Default::default();
    loop {
        let reply = telemetry_call(&addr, &WireMsg::TraceQuery { trace: id })?;
        let text = match reply {
            WireMsg::TraceReply { json: text } => text,
            other => anyhow::bail!("{addr} answered with a non-trace frame: {other:?}"),
        };
        let doc = json::parse(&text)
            .map_err(|e| anyhow::anyhow!("unparsable trace JSON from {addr}: {e}"))?;
        let spans = doc.get("spans").and_then(Json::as_arr).unwrap_or(&[]);
        let start = if limit > 0 && spans.len() > limit { spans.len() - limit } else { 0 };
        let mut printed = 0usize;
        for sp in &spans[start..] {
            let node = sp.get("node").and_then(Json::as_str).unwrap_or("?").to_string();
            let seq = sp.get("seq").and_then(Json::as_usize).unwrap_or(0);
            if !seen.insert((node, seq)) {
                continue;
            }
            println!("{}", span_line(sp));
            printed += 1;
        }
        if !follow {
            if printed == 0 {
                println!(
                    "(no spans recorded{}; is --trace-sample armed on the target?)",
                    if id != 0 { " for that trace id" } else { "" }
                );
            }
            return Ok(());
        }
        std::thread::sleep(Duration::from_millis(500));
    }
}

/// `wingan top` — live per-stage latency table scraped from a replica or
/// router's `MetricsQuery` verb, refreshed every `--interval` seconds
/// (`--count N` stops after N refreshes; 0 = until interrupted).
fn cmd_top(args: &Args) -> anyhow::Result<()> {
    use wingan::fleet::{wire, WireMsg};
    let addr = telemetry_addr(args)?;
    let interval = args.get_f64("interval", 2.0).map_err(anyhow::Error::msg)?;
    anyhow::ensure!(interval > 0.0, "--interval must be positive");
    let count = args.get_usize("count", 0).map_err(anyhow::Error::msg)?;
    let mut refreshes = 0usize;
    loop {
        let reply = telemetry_call(&addr, &WireMsg::MetricsQuery { format: wire::format::JSON })?;
        let body = match reply {
            WireMsg::MetricsReply { body } => body,
            other => anyhow::bail!("{addr} answered with a non-metrics frame: {other:?}"),
        };
        let doc = json::parse(&body)
            .map_err(|e| anyhow::anyhow!("unparsable metrics JSON from {addr}: {e}"))?;
        let role = doc.get("role").and_then(Json::as_str).unwrap_or("?");
        let node = doc.get("node").and_then(Json::as_str).unwrap_or("?");
        println!("== {role} {node} @ {addr} ==");
        println!(
            "{:<18} {:>8} {:>9} {:>9} {:>9} {:>9} {:>9}",
            "stage", "count", "mean_ms", "p50_ms", "p95_ms", "p99_ms", "max_ms"
        );
        let mut rows = 0usize;
        if let Some(stages) = doc.get("stages").and_then(Json::as_obj) {
            for (name, h) in stages {
                let f = |k: &str| h.get(k).and_then(Json::as_f64).unwrap_or(0.0);
                if f("count") == 0.0 {
                    continue;
                }
                println!(
                    "{:<18} {:>8} {:>9.3} {:>9.3} {:>9.3} {:>9.3} {:>9.3}",
                    name,
                    f("count"),
                    f("mean_ms"),
                    f("p50_ms"),
                    f("p95_ms"),
                    f("p99_ms"),
                    f("max_ms"),
                );
                rows += 1;
            }
        }
        if rows == 0 {
            println!("(no stage samples yet; is --trace-sample armed on the target?)");
        }
        refreshes += 1;
        if count > 0 && refreshes >= count {
            return Ok(());
        }
        std::thread::sleep(Duration::from_secs_f64(interval));
    }
}

/// Parse `--scale` for commands that execute real tensors (native serving,
/// AOT plan compilation): small|tiny only — paper-scale channel widths are
/// cycle-model territory.
fn serving_scale(args: &Args) -> anyhow::Result<Scale> {
    match Scale::parse(args.get_or("scale", "small")) {
        Ok(s) if s != Scale::Paper => Ok(s),
        // paper is a valid Scale elsewhere but not here, so don't forward
        // Scale::parse's generic message (which would suggest it)
        _ => anyhow::bail!(
            "--scale: '{}' is not one of small|tiny (native plans execute real tensors; \
             paper-scale channels are cycle-model territory)",
            args.get_or("scale", "small")
        ),
    }
}

/// `wingan compile` — AOT-compile zoo generator plans into a [`PlanStore`]:
/// for each model at each serving scale, the `winograd` route plan (DSE
/// Auto) at both precision tiers and the `tdc` reference plan at f64, plus
/// a human-readable `manifest.json` at the store root. `wingan serve
/// --plan-store <dir>` then boots from these files without invoking the
/// planner.
fn cmd_compile(args: &Args) -> anyhow::Result<()> {
    let store = PlanStore::open(args.get_or("store", "planstore"));
    let seed = args.get_usize("seed", 42).map_err(anyhow::Error::msg)? as u64;
    let scales: Vec<Scale> = match args.get("scale") {
        None | Some("all") => vec![Scale::Small, Scale::Tiny],
        Some(_) => vec![serving_scale(args)?],
    };
    let models: Option<Vec<String>> = args
        .get("models")
        .map(|list| list.split(',').map(wingan::engine::model_id).collect());
    if let Some(allow) = &models {
        // a typo'd model name must fail loudly, not produce a store that
        // silently cold-starts that model forever
        let known: Vec<String> =
            zoo::all(Scale::Tiny).iter().map(|g| wingan::engine::model_id(g.name)).collect();
        for m in allow {
            anyhow::ensure!(
                known.contains(m),
                "--models: unknown model '{m}' (known: {})",
                known.join(", ")
            );
        }
    }

    println!("compiling plan artifacts into {} (seed {seed})", store.root().display());
    let mut entries: Vec<Json> = Vec::new();
    let t0 = Instant::now();
    for &scale in &scales {
        for g in zoo::all(scale) {
            let id = wingan::engine::model_id(g.name);
            if let Some(allow) = &models {
                if !allow.contains(&id) {
                    continue;
                }
            }
            for (method, select) in ROUTE_METHODS {
                let planner = Planner::new(PlanOptions { select, ..Default::default() });
                let tc = Instant::now();
                let plan = planner.compile_seeded(&g, seed);
                let compile_time = tc.elapsed();
                // the tdc reference route only ever serves f64; the fast
                // route is published at both tiers so any resolved serving
                // precision boots warm
                let tiers: &[Precision] = if method == "tdc" {
                    &[Precision::F64]
                } else {
                    &[Precision::F64, Precision::F32]
                };
                for &tier in tiers {
                    let key = PlanKey::new(&id, scale, tier, method, seed);
                    let path = match tier {
                        Precision::F64 => store.publish(&key, &plan)?,
                        Precision::F32 => store.publish(&key, &plan.lower::<f32>())?,
                    };
                    let bytes = std::fs::metadata(&path)?.len();
                    println!(
                        "  {id:<8} {:<5} {method:<8} {tier}  {bytes:>12} B  \
                         (compiled in {compile_time:?})",
                        scale.label(),
                    );
                    entries.push(json::obj(vec![
                        ("model", json::s(&id)),
                        ("scale", json::s(scale.label())),
                        ("method", json::s(method)),
                        ("precision", json::s(tier.label())),
                        ("file", json::s(&key.rel_path().display().to_string())),
                        ("bytes", json::num(bytes as f64)),
                        ("layers", json::num(plan.layers.len() as f64)),
                        ("winograd_layers", json::num(plan.n_winograd_layers() as f64)),
                    ]));
                }
            }
        }
    }
    anyhow::ensure!(!entries.is_empty(), "no models matched the --models filter");
    let n = entries.len();
    let manifest = json::obj(vec![
        ("version", json::num(wingan::artifact::FORMAT_VERSION as f64)),
        ("seed", json::num(seed as f64)),
        ("artifacts", Json::Arr(entries)),
    ]);
    // same atomic write-then-rename contract the artifacts get: a reader
    // polling the manifest never observes a torn file (and a failed write
    // leaves no stray temp behind)
    let manifest_path = store.root().join("manifest.json");
    wingan::artifact::atomic_write(&manifest_path, json::to_string_pretty(&manifest).as_bytes())?;
    // a full republish moves the store's monotonic generation tag — the
    // signal a running `router --store` answers with a rolling reload.
    // (Serve-time fallback publishes deliberately do NOT bump it.)
    let generation = store.bump_generation()?;
    println!(
        "published {n} artifacts + {} (store generation {generation}) in {:?}",
        manifest_path.display(),
        t0.elapsed()
    );
    Ok(())
}

/// `wingan plan inspect <artifact>` — print one artifact's manifest view
/// (model, scale, precision, per-layer method/geometry, payload sizes).
fn cmd_plan(args: &Args) -> anyhow::Result<()> {
    match args.positional(0) {
        Some("inspect") => {
            anyhow::ensure!(
                args.n_positionals() == 2,
                "usage: wingan plan inspect <artifact-file>"
            );
            let path = args
                .positional(1)
                .ok_or_else(|| anyhow::anyhow!("usage: wingan plan inspect <artifact-file>"))?;
            let bytes = std::fs::read(path)
                .map_err(|e| anyhow::anyhow!("reading {path}: {e}"))?;
            print!("{}", describe(&bytes, path)?);
            Ok(())
        }
        other => anyhow::bail!(
            "unknown plan action {:?} (usage: wingan plan inspect <artifact-file>)",
            other.unwrap_or("<none>")
        ),
    }
}
