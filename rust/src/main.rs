//! `wingan` — CLI for the Winograd-DeConv GAN acceleration system.
//!
//! Subcommands:
//!   tables              reproduce the paper's tables/figures (analytic+sim)
//!   sim                 cycle-simulate one/all GANs under all three methods
//!   dse                 design-space exploration (eq. 5-9 roofline sweep)
//!   verify              load every artifact, execute, check vs jax goldens
//!   serve               run the serving coordinator on a synthetic workload

use std::path::Path;
use std::time::{Duration, Instant};

use wingan::accel::{simulate_model, AccelConfig};
use wingan::cli::Args;
use wingan::coordinator::{Coordinator, ServeConfig};
use wingan::energy::EnergyParams;
use wingan::gan::workload::Method;
use wingan::gan::zoo::{self, Scale};
use wingan::report;
use wingan::runtime::{Manifest, Runtime};
use wingan::util::prng::Rng;

const USAGE: &str = "\
wingan — Winograd DeConv acceleration for GANs (Chang et al., 2019 reproduction)

USAGE: wingan <subcommand> [flags]

  tables [--table1|--fig4|--fig8|--fig9|--table2|--dse|--all]
  sim    [--model dcgan|artgan|discogan|gpgan] [--full-model] [--zero-skip]
  dse
  verify [--artifacts DIR]
  serve  [--artifacts DIR] [--native] [--scale small|tiny] [--model dcgan]
         [--method winograd] [--requests 64] [--rate 200] [--max-wait-ms 20]
         [--seed 7] [--workers N] [--precision f32|f64|auto]

serve runs on the native precompiled-plan engine when --native is given or
when the PJRT artifacts are unavailable (this offline build always is).
--workers sizes the one persistent worker pool every route's engine shares
(0/absent = WINGAN_WORKERS env, then one thread per core).
--precision picks the serving tier for the fast routes: f32 (half the
memory traffic), f64 (the bit-exact reference tier), or auto/absent
(WINGAN_PRECISION env, then the per-model dse recommendation). The tdc
reference route always serves f64.
";

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n{USAGE}");
            std::process::exit(2);
        }
    };
    let rc = match args.subcommand.as_deref() {
        Some("tables") | Some("bench-tables") => cmd_tables(&args),
        Some("sim") => cmd_sim(&args),
        Some("dse") => {
            print!("{}", report::dse_table());
            Ok(())
        }
        Some("verify") => cmd_verify(&args),
        Some("serve") => cmd_serve(&args),
        Some("version") => {
            println!("wingan {}", wingan::version());
            Ok(())
        }
        _ => {
            print!("{USAGE}");
            Ok(())
        }
    };
    if let Err(e) = rc {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn cmd_tables(args: &Args) -> anyhow::Result<()> {
    let cfg = AccelConfig::default();
    let ep = EnergyParams::default();
    let all = args.has("all")
        || !["table1", "fig4", "fig8", "fig9", "table2", "dse"].iter().any(|f| args.has(f));
    if all || args.has("table1") {
        println!("{}", report::table1());
    }
    if all || args.has("fig4") {
        println!("{}", report::fig4());
    }
    if all || args.has("fig8") {
        println!("{}", report::fig8(&cfg));
    }
    if all || args.has("fig9") {
        println!("{}", report::fig9(&cfg, &ep));
    }
    if all || args.has("table2") {
        println!("{}", report::table2(&cfg));
    }
    if all || args.has("dse") {
        println!("{}", report::dse_table());
    }
    Ok(())
}

fn cmd_sim(args: &Args) -> anyhow::Result<()> {
    let mut cfg = AccelConfig::default();
    if args.has("zero-skip") {
        cfg.zp_zero_skip = true;
    }
    let deconv_only = !args.has("full-model");
    let wanted = args.get_or("model", "all");
    for g in zoo::all(Scale::Paper) {
        if wanted != "all" && !g.name.eq_ignore_ascii_case(wanted) {
            continue;
        }
        println!("== {} ({} deconv / {} conv layers) ==", g.name, g.n_deconv(), g.n_conv());
        for m in Method::ALL {
            let sim = simulate_model(&g, m, &cfg, deconv_only);
            println!(
                "  {:<16} t={:>8.3} ms   mults={:>7.2} G   DDR={:>7.1} MB   GOP/s={:>7.1}",
                m.label(),
                sim.t_total * 1e3,
                sim.mults as f64 / 1e9,
                sim.offchip_bytes as f64 / 1e6,
                sim.effective_gops(&g, deconv_only),
            );
        }
    }
    Ok(())
}

fn cmd_verify(args: &Args) -> anyhow::Result<()> {
    let dir = args.get_or("artifacts", "artifacts");
    let manifest = Manifest::load(Path::new(dir))?;
    let mut rt = Runtime::new()?;
    println!("platform: {}; {} artifacts", rt.platform(), manifest.entries.len());
    let mut worst = 0f32;
    for e in &manifest.entries {
        let t0 = Instant::now();
        rt.load(e)?;
        let compile = t0.elapsed();
        let t0 = Instant::now();
        let diff = rt.verify_golden(&e.name)?;
        worst = worst.max(diff);
        println!(
            "  {:<18} compile {compile:>7.2?}  exec {:>8.2?}  max|Δ| {diff:.2e}  {}",
            e.name,
            t0.elapsed(),
            if diff < 2e-4 { "OK" } else { "FAIL" }
        );
        if diff >= 2e-4 {
            anyhow::bail!("artifact {} exceeds tolerance: {diff:e}", e.name);
        }
    }
    println!("all {} artifacts verified (worst max|Δ| = {worst:.2e})", manifest.entries.len());
    Ok(())
}

fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    let dir = args.get_or("artifacts", "artifacts");
    // normalize to the manifest route ids shared by both backends
    // ("GP-GAN"/"gp-gan"/"gpgan" all mean "gpgan")
    let model = wingan::engine::model_id(args.get_or("model", "dcgan"));
    let method = args.get_or("method", "winograd").to_string();
    let n_requests = args.get_usize("requests", 64).map_err(anyhow::Error::msg)?;
    let rate = args.get_f64("rate", 200.0).map_err(anyhow::Error::msg)?;
    let max_wait = args.get_usize("max-wait-ms", 20).map_err(anyhow::Error::msg)?;
    let seed = args.get_usize("seed", 7).map_err(anyhow::Error::msg)? as u64;
    let workers = args.get_workers().map_err(anyhow::Error::msg)?;
    let precision = args.get_precision().map_err(anyhow::Error::msg)?;

    let serve_cfg = ServeConfig {
        max_wait: Duration::from_millis(max_wait as u64),
        preload_models: Some(vec![model.clone()]),
    };
    let use_native =
        args.has("native") || !Path::new(dir).join("manifest.json").exists();
    let t0 = Instant::now();
    let coord = if use_native {
        let scale = match args.get_or("scale", "small") {
            "tiny" => wingan::gan::zoo::Scale::Tiny,
            "small" => wingan::gan::zoo::Scale::Small,
            other => anyhow::bail!(
                "--scale: '{other}' is not one of small|tiny (native serving executes \
                 real tensors; paper-scale channels are cycle-model territory)"
            ),
        };
        println!(
            "compiling native engine plans for {model} ({scale:?} scale, pool of {} workers, \
             precision policy {:?})...",
            wingan::engine::resolve_workers(workers),
            wingan::engine::resolve_precision(precision),
        );
        Coordinator::start_native(
            wingan::engine::NativeConfig { scale, workers, precision, ..Default::default() },
            serve_cfg,
        )?
    } else {
        let manifest = Manifest::load(Path::new(dir))?;
        println!("loading + compiling {model} artifacts...");
        Coordinator::start(manifest, serve_cfg)?
    };
    println!("engine ready in {:?}", t0.elapsed());

    let route = coord.router().route(&model, &method).map_err(anyhow::Error::msg)?;
    let input_len = route.sample_input_len;
    let buckets = route.bucket_sizes();
    println!(
        "serving {n_requests} requests to {model}/{method} (Poisson {rate}/s, buckets {buckets:?})"
    );

    // open-loop Poisson arrivals
    let mut rng = Rng::new(seed);
    let mut pending = Vec::new();
    let t_start = Instant::now();
    for i in 0..n_requests {
        let input = rng.normal_vec_f32(input_len);
        pending.push(coord.submit(&model, &method, input).map_err(anyhow::Error::msg)?);
        if i + 1 < n_requests {
            std::thread::sleep(Duration::from_secs_f64(rng.exponential(rate)));
        }
    }
    let mut checksum = 0.0f64;
    for rx in pending {
        let resp = rx.recv()?.map_err(anyhow::Error::msg)?;
        checksum += resp.output.iter().map(|v| *v as f64).sum::<f64>();
    }
    let wall = t_start.elapsed();
    let m = coord.metrics();
    println!("\n== serving report ==");
    println!("{}", m.report());
    println!(
        "wall={:.3}s  throughput={:.1} img/s  output checksum={checksum:.3}",
        wall.as_secs_f64(),
        n_requests as f64 / wall.as_secs_f64()
    );
    coord.shutdown();
    Ok(())
}
