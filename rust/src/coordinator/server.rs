//! The serving coordinator: engine threads that own an execution backend
//! and drain per-route batch schedulers; callers talk to them through
//! channels (`Coordinator::submit`). Python is never on this path.
//!
//! Shape:
//!   caller -> gate -> mpsc -> engine thread [ scheduler -> pack ->
//!                       execute backend -> unpack -> respond per-request ]
//!
//! Two backends implement the same [`ExecBackend`] contract:
//! * **PJRT** ([`Coordinator::start`]) — AOT artifacts compiled and
//!   executed via the `xla` runtime (gated off in offline builds). The
//!   PJRT client is not `Send`, so this backend keeps the legacy
//!   single-engine-thread loop: panic containment applies, supervision
//!   does not.
//! * **native** ([`Coordinator::start_native`]) — whole generators run
//!   through precompiled [`crate::engine`] plans, no artifacts needed.
//!   The runtime is shared (`Arc`) across **supervised per-route engine
//!   threads** (see below).
//!
//! **Admission is bounded** (PR 7): every route has a fixed-capacity
//! admission gate ([`ServeConfig::queue_cap`]) spanning the channel *and*
//! the scheduler queue. `submit` sheds with a typed
//! [`ServeError::Rejected`] ([`Rejected::QueueFull`]) instead of queuing
//! unboundedly. With an SLO configured ([`ServeConfig::slo`], or a
//! per-request budget via [`Coordinator::submit_with_deadline`]) the
//! continuous scheduler also sheds deadline-infeasible requests, typed
//! [`Rejected::DeadlineInfeasible`].
//!
//! **Faults are isolated** (PR 8), at three nested boundaries:
//!
//! 1. *Batch boundary* — [`ExecBackend::execute_artifact`] runs under
//!    `catch_unwind`. A panic (or a wrong-shaped output) fails only the
//!    offending batch, typed [`ServeError::Crashed`]; multi-request
//!    batches are **bisected** so batch-mates of a poison request are
//!    retried and complete normally (the engine's bitwise
//!    batch-composition invariance makes the retried halves produce
//!    outputs identical to a fault-free run). Counted per route as
//!    `panics_contained` / `requests_quarantined` / `bisection_retries`.
//! 2. *Engine boundary* — on the native path every route runs its own
//!    supervised engine incarnation. A panic storm, an unwind that
//!    escapes the batch boundary, or a stuck batch (watchdog) kills the
//!    incarnation; the supervisor restarts it with capped exponential
//!    backoff ([`SupervisorConfig`]).
//! 3. *Route boundary* — too many deaths inside the restart window trip
//!    the route's circuit breaker: requests shed immediately with a typed
//!    [`Rejected::Unhealthy`] (instead of hanging on a dead engine) until
//!    a cooldown passes and a probe incarnation proves the route healthy.
//!    [`Coordinator::health`] reports per-route state.
//!
//! Deterministic fault injection ([`crate::faultinject`]) hooks the batch
//! boundary here (site `batch_exec`); `ServeConfig::faults` carries the
//! plane. Shutdown is bounded: [`Coordinator::shutdown`] drains pending
//! work up to [`ServeConfig::drain_deadline`], then answers anything left
//! with typed [`ServeError::EngineShutdown`] — no silent request loss,
//! no unbounded hang.
//!
//! On the native backend, compute threading is *not* per request: the
//! [`crate::engine::NativeRuntime`] built at startup owns one persistent
//! [`crate::engine::WorkerPool`] (sized by
//! [`NativeConfig::workers`](crate::engine::NativeConfig), default one
//! thread per core) that every route's engine dispatches to.

use crate::coordinator::batcher::{
    BatchPolicy, ContinuousBatcher, Dispatch, DynamicBatcher, ReadyBatch,
};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::request::{GenRequest, GenResponse, Rejected, RequestId, ServeError};
use crate::coordinator::router::{Route, Router};
use crate::coordinator::supervise::{
    DeathVerdict, HealthReport, RouteHealth, RouteHealthSnapshot, RoutePolicy, SupervisorAction,
    SupervisorConfig,
};
use crate::engine::serve::{native_manifest, NativeConfig, NativeRuntime};
use crate::faultinject::{FaultAction, FaultPlane, FaultSite};
use crate::runtime::{Manifest, Runtime};
use crate::telemetry::{self, Stage};
use crate::util::lock_unpoisoned;
use anyhow::Result;
use std::collections::{BTreeMap, HashMap};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::{Arc, Mutex, TryLockError};
use std::time::{Duration, Instant};

/// What an engine thread needs from an execution backend: run one packed
/// batch buffer against a named route artifact.
pub trait ExecBackend {
    fn execute_artifact(&self, name: &str, input: &[f32]) -> std::result::Result<Vec<f32>, String>;
}

impl ExecBackend for Runtime {
    fn execute_artifact(&self, name: &str, input: &[f32]) -> std::result::Result<Vec<f32>, String> {
        self.execute(name, input).map_err(|e| format!("{e:#}"))
    }
}

impl ExecBackend for NativeRuntime {
    fn execute_artifact(&self, name: &str, input: &[f32]) -> std::result::Result<Vec<f32>, String> {
        self.execute(name, input)
    }
}

type Reply = Sender<Result<GenResponse, ServeError>>;

enum Msg {
    Request(GenRequest, Reply),
    Shutdown,
}

/// Which batch scheduler the engine runs per route.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum SchedulerKind {
    /// Continuous batching with SLO-aware admission
    /// ([`ContinuousBatcher`]) — the default production scheduler.
    #[default]
    Continuous,
    /// The PR-6 bucket-and-deadline baseline ([`DynamicBatcher`]), kept
    /// so `wingan loadgen` can A/B the schedulers under identical
    /// traffic.
    Bucket,
}

impl SchedulerKind {
    /// Parse a `--scheduler` CLI value.
    pub fn parse(s: &str) -> std::result::Result<SchedulerKind, String> {
        match s.to_ascii_lowercase().as_str() {
            "continuous" => Ok(SchedulerKind::Continuous),
            "bucket" => Ok(SchedulerKind::Bucket),
            other => Err(format!("unknown scheduler '{other}' (continuous|bucket)")),
        }
    }
}

/// Per-route admission slot counter: the depth spans the request channel
/// plus the scheduler queue, so the bound holds no matter where a request
/// currently sits.
struct RouteGate {
    depth: AtomicUsize,
    peak: AtomicUsize,
}

/// The bounded admission gate shared by the caller-side `submit` and the
/// engine threads: one slot counter per route, capacity
/// [`ServeConfig::queue_cap`].
struct Gate {
    cap: usize,
    routes: HashMap<(String, String), RouteGate>,
}

impl Gate {
    fn new(router: &Router, cap: usize) -> Gate {
        let routes = router
            .models()
            .into_iter()
            .map(|key| (key, RouteGate { depth: AtomicUsize::new(0), peak: AtomicUsize::new(0) }))
            .collect();
        Gate { cap, routes }
    }

    /// Claim one slot for `key`, or report the queue full.
    fn try_acquire(&self, key: &(String, String)) -> std::result::Result<(), Rejected> {
        let g = self.routes.get(key).expect("gate covers every validated route");
        loop {
            let d = g.depth.load(Ordering::Acquire);
            if d >= self.cap {
                return Err(Rejected::QueueFull { depth: d, cap: self.cap });
            }
            if g.depth
                .compare_exchange(d, d + 1, Ordering::AcqRel, Ordering::Acquire)
                .is_ok()
            {
                g.peak.fetch_max(d + 1, Ordering::AcqRel);
                return Ok(());
            }
        }
    }

    /// Release `n` slots (requests dispatched, shed, or failed).
    fn release(&self, key: &(String, String), n: usize) {
        if let Some(g) = self.routes.get(key) {
            g.depth.fetch_sub(n, Ordering::AcqRel);
        }
    }
}

/// Coordinator configuration.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// max time a request may wait for batch-mates before a partial batch
    /// ships. `ZERO` (the default) makes the continuous scheduler fully
    /// work-conserving; the bucket baseline typically runs 5–20 ms here.
    pub max_wait: Duration,
    /// which artifacts to preload at startup (None = all generators)
    pub preload_models: Option<Vec<String>>,
    /// batch scheduler per route (continuous by default)
    pub scheduler: SchedulerKind,
    /// per-route admission bound: at most this many requests may be
    /// in flight (channel + scheduler queue) per route before `submit`
    /// sheds with [`Rejected::QueueFull`]
    pub queue_cap: usize,
    /// default per-request SLO budget: requests get `now + slo` as their
    /// deadline unless [`Coordinator::submit_with_deadline`] overrides it.
    /// `None` = best-effort (no deadline shedding).
    pub slo: Option<Duration>,
    /// deterministic fault-injection plane for the `batch_exec` site
    /// ([`crate::faultinject`]). `None` (the default, production) costs
    /// one branch per batch.
    pub faults: Option<Arc<FaultPlane>>,
    /// restart/backoff/breaker/watchdog policy for the supervised native
    /// path ([`Coordinator::start_native`] / [`Coordinator::start_supervised`]).
    pub supervisor: SupervisorConfig,
    /// how long [`Coordinator::shutdown`] (and `Drop`) waits for pending
    /// work to drain before answering what's left with typed
    /// [`ServeError::EngineShutdown`] and detaching the engine threads.
    pub drain_deadline: Duration,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            max_wait: Duration::ZERO,
            preload_models: None,
            scheduler: SchedulerKind::Continuous,
            queue_cap: 256,
            slo: None,
            faults: None,
            supervisor: SupervisorConfig::default(),
            drain_deadline: Duration::from_secs(5),
        }
    }
}

/// Sentinel for "no batch executing" in [`RouteShared::busy_gen`].
const IDLE_GEN: u64 = u64::MAX;

/// Milliseconds since the coordinator's epoch — the watchdog's clock.
fn elapsed_ms(epoch: Instant) -> u64 {
    epoch.elapsed().as_millis() as u64
}

/// State shared between one route's engine incarnations, the supervisor,
/// and `submit`. The receiver lives *here* (not in the engine thread) so
/// queued requests survive an engine death: the replacement incarnation —
/// or, with the breaker open, the supervisor — picks them up and every
/// request still gets exactly one fate.
struct RouteShared {
    rx: Mutex<Receiver<Msg>>,
    /// currently authorized incarnation; bumped to retire (watchdog,
    /// death) so stale incarnations see they were superseded
    generation: AtomicU64,
    /// generation currently executing a batch, [`IDLE_GEN`] when idle
    busy_gen: AtomicU64,
    /// when that batch started (ms since epoch) — watchdog deadline base
    busy_since_ms: AtomicU64,
    shutdown: AtomicBool,
    policy: Mutex<RoutePolicy>,
}

struct SupRoute {
    tx: Sender<Msg>,
    shared: Arc<RouteShared>,
}

struct Supervised {
    routes: BTreeMap<(String, String), SupRoute>,
    /// live engine incarnation count (for bounded shutdown)
    live: AtomicUsize,
    shutdown: AtomicBool,
    epoch: Instant,
}

/// Engine-death notification to the supervisor.
enum SupEvent {
    Died { key: (String, String), generation: u64 },
}

/// Everything an engine incarnation / the supervisor thread needs;
/// cheaply cloneable (all `Arc`s plus the config).
struct SupEnv<E> {
    backend: Arc<E>,
    router: Router,
    metrics: Arc<Mutex<Metrics>>,
    gate: Arc<Gate>,
    cfg: ServeConfig,
    sup: Arc<Supervised>,
    sup_tx: Sender<SupEvent>,
}

impl<E> Clone for SupEnv<E> {
    fn clone(&self) -> Self {
        SupEnv {
            backend: self.backend.clone(),
            router: self.router.clone(),
            metrics: self.metrics.clone(),
            gate: self.gate.clone(),
            cfg: self.cfg.clone(),
            sup: self.sup.clone(),
            sup_tx: self.sup_tx.clone(),
        }
    }
}

/// Decrements the live-incarnation count however the thread exits —
/// including by panic.
struct LiveGuard(Arc<Supervised>);

impl Drop for LiveGuard {
    fn drop(&mut self) {
        self.0.live.fetch_sub(1, Ordering::SeqCst);
    }
}

enum Mode {
    /// One engine thread owns the backend (PJRT: the client is not
    /// `Send`). Containment applies; supervision does not.
    Legacy { tx: Sender<Msg>, handle: Option<std::thread::JoinHandle<()>> },
    /// Per-route supervised engine incarnations over a shared backend.
    Supervised { sup: Arc<Supervised>, supervisor: Option<std::thread::JoinHandle<()>> },
}

/// Handle to a running coordinator.
pub struct Coordinator {
    next_id: AtomicU64,
    metrics: Arc<Mutex<Metrics>>,
    router: Router,
    gate: Arc<Gate>,
    slo: Option<Duration>,
    drain_deadline: Duration,
    mode: Mode,
}

impl Coordinator {
    /// Start the engine thread: compiles artifacts, then serves.
    pub fn start(manifest: Manifest, cfg: ServeConfig) -> Result<Coordinator> {
        let router = Router::from_manifest(&manifest);
        let metrics = Arc::new(Mutex::new(Metrics::new()));
        let gate = Arc::new(Gate::new(&router, cfg.queue_cap));
        let (tx, rx) = mpsc::channel::<Msg>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<(), String>>();

        // The PJRT client is not Send, so the runtime lives entirely inside
        // the engine thread; artifacts are preloaded there before the
        // coordinator reports ready (first requests never pay compile time).
        let engine_router = router.clone();
        let engine_metrics = metrics.clone();
        let engine_gate = gate.clone();
        let engine_cfg = cfg.clone();
        let handle = std::thread::Builder::new()
            .name("wingan-engine".into())
            .spawn(move || {
                let mut runtime = match Runtime::new() {
                    Ok(r) => r,
                    Err(e) => {
                        let _ = ready_tx.send(Err(e.to_string()));
                        return;
                    }
                };
                for e in manifest.entries.iter().filter(|e| e.kind == "generator") {
                    if let Some(models) = &engine_cfg.preload_models {
                        if !models.contains(&e.model) {
                            continue;
                        }
                    }
                    if let Err(e) = runtime.load(e) {
                        let _ = ready_tx.send(Err(e.to_string()));
                        return;
                    }
                }
                let _ = ready_tx.send(Ok(()));
                engine_loop(runtime, engine_router, engine_metrics, engine_gate, engine_cfg, rx)
            })
            .expect("spawn engine");
        ready_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("engine thread died during startup"))?
            .map_err(|e| anyhow::anyhow!("engine startup failed: {e}"))?;

        Ok(Coordinator {
            next_id: AtomicU64::new(1),
            metrics,
            router,
            gate,
            slo: cfg.slo,
            drain_deadline: cfg.drain_deadline,
            mode: Mode::Legacy { tx, handle: Some(handle) },
        })
    }

    /// Start supervised serving on the native execution backend: every
    /// route's [`crate::engine`] plan is compiled — and the one worker
    /// pool all routes share is spawned — before the coordinator reports
    /// ready, then each route gets its own supervised engine incarnation
    /// (restart-on-death, circuit breaker, stuck-batch watchdog).
    ///
    /// `cfg.preload_models`, when set, restricts which zoo models get
    /// compiled (same semantics as the PJRT path); `native.workers` sizes
    /// the shared pool (0 = env/core default).
    pub fn start_native(mut native: NativeConfig, cfg: ServeConfig) -> Result<Coordinator> {
        if let Some(models) = &cfg.preload_models {
            native.models = Some(models.clone());
        }
        if native.faults.is_none() {
            native.faults = cfg.faults.clone();
        }
        let manifest = native_manifest(&native);
        anyhow::ensure!(
            !manifest.entries.is_empty(),
            "native backend: no routes to serve (model filter {:?})",
            native.models
        );
        // plan compilation happens here, once, before any request — a
        // compile-time panic is a startup error, not an engine death
        let runtime = catch_unwind(AssertUnwindSafe(|| NativeRuntime::build(&native)))
            .map_err(|p| anyhow::anyhow!("native runtime build panicked: {}", panic_message(p)))?;
        let plan_stats = runtime.plan_stats();
        let coord = Coordinator::start_supervised(Arc::new(runtime), &manifest, cfg)?;
        // surface the warm-vs-cold startup accounting through the serving
        // metrics snapshot
        lock_unpoisoned(&coord.metrics).plan_cache = plan_stats;
        Ok(coord)
    }

    /// Start supervised serving over an arbitrary `Send + Sync` backend
    /// (shared by every route's engine incarnations). This is the
    /// fault-isolated production path; `start_native` delegates here, and
    /// tests use it with mock backends to exercise containment,
    /// bisection, and supervision deterministically.
    pub fn start_supervised<E>(
        backend: Arc<E>,
        manifest: &Manifest,
        cfg: ServeConfig,
    ) -> Result<Coordinator>
    where
        E: ExecBackend + Send + Sync + 'static,
    {
        let router = Router::from_manifest(manifest);
        anyhow::ensure!(!router.models().is_empty(), "supervised backend: no routes to serve");
        let metrics = Arc::new(Mutex::new(Metrics::new()));
        let gate = Arc::new(Gate::new(&router, cfg.queue_cap));
        let mut routes = BTreeMap::new();
        for key in router.models() {
            let (tx, rx) = mpsc::channel::<Msg>();
            let shared = Arc::new(RouteShared {
                rx: Mutex::new(rx),
                generation: AtomicU64::new(0),
                busy_gen: AtomicU64::new(IDLE_GEN),
                busy_since_ms: AtomicU64::new(0),
                shutdown: AtomicBool::new(false),
                policy: Mutex::new(RoutePolicy::new(cfg.supervisor.clone())),
            });
            routes.insert(key, SupRoute { tx, shared });
        }
        let sup = Arc::new(Supervised {
            routes,
            live: AtomicUsize::new(0),
            shutdown: AtomicBool::new(false),
            epoch: Instant::now(),
        });
        let (sup_tx, sup_rx) = mpsc::channel::<SupEvent>();
        let env = SupEnv {
            backend,
            router: router.clone(),
            metrics: metrics.clone(),
            gate: gate.clone(),
            cfg: cfg.clone(),
            sup: sup.clone(),
            sup_tx,
        };
        let keys: Vec<(String, String)> = env.sup.routes.keys().cloned().collect();
        for key in &keys {
            spawn_incarnation(&env, key);
        }
        let supervisor = std::thread::Builder::new()
            .name("wingan-supervisor".into())
            .spawn(move || supervisor_loop(env, sup_rx))
            .expect("spawn supervisor");

        Ok(Coordinator {
            next_id: AtomicU64::new(1),
            metrics,
            router,
            gate,
            slo: cfg.slo,
            drain_deadline: cfg.drain_deadline,
            mode: Mode::Supervised { sup, supervisor: Some(supervisor) },
        })
    }

    pub fn router(&self) -> &Router {
        &self.router
    }

    /// Submit a request with the configured default SLO (if any); returns
    /// a receiver for the response. Sheds with
    /// [`ServeError::Rejected`]`(`[`Rejected::QueueFull`]`)` when the
    /// route's admission gate is at capacity, and with
    /// [`Rejected::Unhealthy`] when the route's circuit breaker is open.
    pub fn submit(
        &self,
        model: &str,
        method: &str,
        input: Vec<f32>,
    ) -> Result<Receiver<Result<GenResponse, ServeError>>, ServeError> {
        self.submit_with_deadline(model, method, input, self.slo)
    }

    /// Submit a request with an explicit per-request SLO budget (`None` =
    /// best-effort, overriding any configured default). The deadline is
    /// stamped at submit time; an infeasible or expired deadline comes
    /// back as a typed [`Rejected::DeadlineInfeasible`] response.
    pub fn submit_with_deadline(
        &self,
        model: &str,
        method: &str,
        input: Vec<f32>,
        budget: Option<Duration>,
    ) -> Result<Receiver<Result<GenResponse, ServeError>>, ServeError> {
        self.submit_traced(model, method, input, budget, 0)
    }

    /// [`Coordinator::submit_with_deadline`] with an explicit telemetry
    /// trace id. `trace == 0` asks this process's flight-recorder sampler
    /// ([`crate::telemetry::FlightRecorder::maybe_mint`]) whether the
    /// admission should be traced; a nonzero id (minted by the fleet
    /// router, carried in over the wire) is adopted as-is so the
    /// cross-process trace stays one tree. The admission verdict —
    /// admitted or the typed shed — is recorded as a
    /// [`Stage::Admission`](crate::telemetry::Stage) span.
    pub fn submit_traced(
        &self,
        model: &str,
        method: &str,
        input: Vec<f32>,
        budget: Option<Duration>,
        trace: u64,
    ) -> Result<Receiver<Result<GenResponse, ServeError>>, ServeError> {
        let t_sub = Instant::now();
        self.router.validate(model, method, input.len())?;
        let rec = telemetry::recorder();
        let trace = if trace != 0 { trace } else { rec.maybe_mint() };
        let key = (model.to_string(), method.to_string());
        // a route with an open breaker sheds immediately: queuing on an
        // engine the supervisor refuses to restart would just hang
        if let Mode::Supervised { sup, .. } = &self.mode {
            if let Some(r) = sup.routes.get(&key) {
                let (open, restarts) = {
                    let pol = lock_unpoisoned(&r.shared.policy);
                    (pol.is_open(), pol.restarts())
                };
                if open {
                    let rej = Rejected::Unhealthy { restarts };
                    count_shed(&self.metrics, &key, &rej);
                    rec.stamp(trace, Stage::Admission, t_sub, 0, shed_code(&rej), model);
                    return Err(ServeError::Rejected(rej));
                }
            }
        }
        if let Err(rej) = self.gate.try_acquire(&key) {
            count_shed(&self.metrics, &key, &rej);
            rec.stamp(trace, Stage::Admission, t_sub, 0, shed_code(&rej), model);
            return Err(ServeError::Rejected(rej));
        }
        let id: RequestId = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (reply_tx, reply_rx) = mpsc::channel();
        let now = Instant::now();
        let req = GenRequest {
            id,
            model: model.to_string(),
            method: method.to_string(),
            input,
            enqueued: now,
            deadline: budget.and_then(|b| now.checked_add(b)),
            trace,
        };
        {
            let mut m = lock_unpoisoned(&self.metrics);
            m.requests += 1;
            let r = m.route_mut(&format!("{model}/{method}"));
            r.admitted += 1;
        }
        let sent = match &self.mode {
            Mode::Legacy { tx, .. } => tx.send(Msg::Request(req, reply_tx)).is_ok(),
            Mode::Supervised { sup, .. } => match sup.routes.get(&key) {
                // the receiver lives in RouteShared, so this succeeds even
                // across an engine death — the replacement drains it
                Some(r) => r.tx.send(Msg::Request(req, reply_tx)).is_ok(),
                None => false,
            },
        };
        if !sent {
            self.gate.release(&key, 1);
            return Err(ServeError::EngineShutdown);
        }
        if trace != 0 {
            let depth = self
                .gate
                .routes
                .get(&key)
                .map(|g| g.depth.load(Ordering::Acquire) as u64)
                .unwrap_or(0);
            rec.stamp(trace, Stage::Admission, t_sub, depth, 0, model);
        }
        Ok(reply_rx)
    }

    /// Submit and block for the result.
    pub fn generate(
        &self,
        model: &str,
        method: &str,
        input: Vec<f32>,
    ) -> Result<GenResponse, ServeError> {
        self.submit(model, method, input)?
            .recv()
            .map_err(|_| ServeError::EngineShutdown)?
    }

    /// Snapshot of the serving metrics, with per-route queue depth and
    /// high-water marks folded in from the admission gate.
    pub fn metrics(&self) -> Metrics {
        let mut m = lock_unpoisoned(&self.metrics).clone();
        for (key, g) in &self.gate.routes {
            let r = m.route_mut(&format!("{}/{}", key.0, key.1));
            r.depth = g.depth.load(Ordering::Acquire);
            r.peak_depth = g.peak.load(Ordering::Acquire);
        }
        m
    }

    /// Per-route supervision health: breaker state, restart counts, death
    /// counts. On the legacy (PJRT) path every route reports `Healthy`
    /// with a closed breaker — there is no supervisor to say otherwise.
    pub fn health(&self) -> HealthReport {
        let now = Instant::now();
        let mut report = HealthReport::default();
        match &self.mode {
            Mode::Supervised { sup, .. } => {
                for (key, r) in &sup.routes {
                    report.routes.insert(
                        format!("{}/{}", key.0, key.1),
                        lock_unpoisoned(&r.shared.policy).snapshot(now),
                    );
                }
            }
            Mode::Legacy { .. } => {
                for key in self.router.models() {
                    report.routes.insert(
                        format!("{}/{}", key.0, key.1),
                        RouteHealthSnapshot {
                            health: RouteHealth::Healthy,
                            breaker: "closed",
                            restarts: 0,
                            recent_deaths: 0,
                            total_deaths: 0,
                            watchdog_fires: 0,
                        },
                    );
                }
            }
        }
        report
    }

    /// Graceful bounded shutdown: flushes pending batches, waiting at
    /// most [`ServeConfig::drain_deadline`]; anything still queued past
    /// the deadline is answered with typed [`ServeError::EngineShutdown`]
    /// and counted as `abandoned_at_shutdown`.
    pub fn shutdown(mut self) {
        let deadline = self.drain_deadline;
        self.shutdown_impl(deadline);
    }

    /// [`Coordinator::shutdown`] with an explicit drain deadline.
    pub fn shutdown_within(mut self, deadline: Duration) {
        self.shutdown_impl(deadline);
    }

    fn shutdown_impl(&mut self, deadline: Duration) {
        let t0 = Instant::now();
        match &mut self.mode {
            Mode::Legacy { tx, handle } => {
                let Some(h) = handle.take() else { return };
                let _ = tx.send(Msg::Shutdown);
                while !h.is_finished() && t0.elapsed() < deadline {
                    std::thread::sleep(Duration::from_millis(1));
                }
                if h.is_finished() {
                    let _ = h.join();
                } else {
                    eprintln!(
                        "coordinator: drain deadline {deadline:?} expired; detaching engine thread"
                    );
                }
            }
            Mode::Supervised { sup, supervisor } => {
                let Some(h) = supervisor.take() else { return };
                sup.shutdown.store(true, Ordering::SeqCst);
                for r in sup.routes.values() {
                    r.shared.shutdown.store(true, Ordering::SeqCst);
                    let _ = r.tx.send(Msg::Shutdown);
                }
                while !(h.is_finished() && sup.live.load(Ordering::SeqCst) == 0)
                    && t0.elapsed() < deadline
                {
                    std::thread::sleep(Duration::from_millis(1));
                }
                if h.is_finished() {
                    let _ = h.join();
                } else {
                    eprintln!(
                        "coordinator: drain deadline {deadline:?} expired; detaching supervisor"
                    );
                }
                // anything still queued gets a typed answer, never silence
                // (idempotent with the supervisor's own exit drain)
                let sup = sup.clone();
                for (key, r) in &sup.routes {
                    abandon_queue(&self.metrics, &self.gate, key, r);
                }
            }
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        let deadline = self.drain_deadline;
        self.shutdown_impl(deadline);
    }
}

/// The per-route scheduler the engine loop drives — continuous or the
/// bucket baseline, behind one polling surface.
enum RouteBatcher {
    Bucket(DynamicBatcher),
    Continuous(ContinuousBatcher),
}

impl RouteBatcher {
    fn new(cfg: &ServeConfig, buckets: Vec<usize>) -> RouteBatcher {
        let policy = BatchPolicy::new(buckets, cfg.max_wait);
        match cfg.scheduler {
            SchedulerKind::Bucket => RouteBatcher::Bucket(DynamicBatcher::new(policy)),
            SchedulerKind::Continuous => {
                RouteBatcher::Continuous(ContinuousBatcher::new(policy, cfg.queue_cap))
            }
        }
    }

    /// Admit one request (the bucket baseline never rejects — its bound
    /// is enforced by the gate alone).
    fn admit(&mut self, req: GenRequest, now: Instant) -> Result<(), (GenRequest, Rejected)> {
        match self {
            RouteBatcher::Bucket(b) => {
                b.push(req);
                Ok(())
            }
            RouteBatcher::Continuous(b) => b.admit(req, now),
        }
    }

    fn poll(&mut self, now: Instant) -> Dispatch {
        match self {
            RouteBatcher::Bucket(b) => Dispatch { batch: b.poll(now), shed: Vec::new() },
            RouteBatcher::Continuous(b) => b.poll(now),
        }
    }

    fn next_deadline(&self) -> Option<Instant> {
        match self {
            RouteBatcher::Bucket(b) => b.next_deadline(),
            RouteBatcher::Continuous(b) => b.next_deadline(),
        }
    }

    fn flush(&mut self) -> Option<ReadyBatch> {
        match self {
            RouteBatcher::Bucket(b) => b.flush(),
            RouteBatcher::Continuous(b) => b.flush(),
        }
    }

    /// Feed an observed batch service time into the admission forecast
    /// (no-op for the bucket baseline).
    fn observe(&mut self, service: Duration) {
        if let RouteBatcher::Continuous(b) = self {
            b.observe(service);
        }
    }
}

struct RouteState {
    batcher: RouteBatcher,
    replies: HashMap<RequestId, Reply>,
    /// admission-gate slots held by requests currently *inside* the
    /// batcher — the exact amount to release if this engine dies with
    /// work queued, so the gate never leaks across restarts
    slots_held: usize,
}

impl RouteState {
    fn new(cfg: &ServeConfig, buckets: Vec<usize>) -> RouteState {
        RouteState {
            batcher: RouteBatcher::new(cfg, buckets),
            replies: HashMap::new(),
            slots_held: 0,
        }
    }
}

/// What one contained batch execution produced.
enum ExecResult {
    Done(Vec<f32>),
    /// typed backend error — fails the whole batch, no bisection
    Failed(String),
    /// a panic was caught at the batch boundary (or the output shape was
    /// wrong, which is the same trust violation)
    Crashed(String),
}

struct BatchOutcome {
    service: Duration,
    /// panics contained during this batch (bisection can contain several)
    contained: u32,
}

/// Everything needed to execute batches for one route, bundled so the
/// recursive bisection path stays at sane arity.
struct BatchCtx<'a, E: ExecBackend> {
    runtime: &'a E,
    router: &'a Router,
    metrics: &'a Mutex<Metrics>,
    faults: Option<&'a FaultPlane>,
    key: &'a (String, String),
}

impl<E: ExecBackend> BatchCtx<'_, E> {
    /// Execute one released batch and answer its requests; panics from the
    /// backend are contained here and bisected down to the poison request.
    fn run_batch(&self, batch: ReadyBatch, replies: &mut HashMap<RequestId, Reply>) -> BatchOutcome {
        let mut contained = 0u32;
        let bucket = batch.bucket;
        let service = self.exec_requests(batch.requests, bucket, replies, &mut contained);
        BatchOutcome { service, contained }
    }

    fn exec_requests(
        &self,
        requests: Vec<GenRequest>,
        bucket: usize,
        replies: &mut HashMap<RequestId, Reply>,
        contained: &mut u32,
    ) -> Duration {
        let route = self.router.route(&self.key.0, &self.key.1).expect("validated at submit");
        let artifact = match route.artifact_for_bucket(bucket) {
            Some(a) => a,
            None => {
                fail_requests(&requests, replies, ServeError::UnknownModel(self.key.0.clone()));
                return Duration::ZERO;
            }
        };
        // pack: bucket x sample_len, zero-padded tail
        let sample_in = route.sample_input_len;
        let sample_out = route.sample_output_len;
        let mut input = vec![0.0f32; bucket * sample_in];
        for (i, r) in requests.iter().enumerate() {
            input[i * sample_in..(i + 1) * sample_in].copy_from_slice(&r.input);
        }

        // one representative trace carries the batch-level spans (and the
        // thread-local trace context for the engine's per-layer stages);
        // per-request Queue/Dispatch spans attach to each member's own id
        let rep_trace = requests.iter().map(|r| r.trace).find(|&t| t != 0).unwrap_or(0);
        if rep_trace != 0 {
            let now = Instant::now();
            let oldest = requests.iter().map(|r| r.enqueued).min().unwrap_or(now);
            telemetry::record_span(
                rep_trace,
                Stage::BatchAssemble,
                oldest,
                now.duration_since(oldest),
                requests.len() as u64,
                bucket as u64,
                &self.key.0,
            );
        }

        let t0 = Instant::now();
        let result = telemetry::with_trace(rep_trace, || self.exec_contained(artifact, &input));
        let exec_time = t0.elapsed();

        match result {
            ExecResult::Done(out) if out.len() == bucket * sample_out => {
                let route_key = format!("{}/{}", self.key.0, self.key.1);
                let mut m = lock_unpoisoned(self.metrics);
                m.batches += 1;
                m.batched_samples += requests.len() as u64;
                m.padded_samples += (bucket - requests.len()) as u64;
                m.exec_latency.record(exec_time);
                m.route_mut(&route_key).batches += 1;
                for (i, r) in requests.iter().enumerate() {
                    let queue_time = t0.duration_since(r.enqueued);
                    let e2e = r.enqueued.elapsed();
                    m.queue_latency.record(queue_time);
                    m.e2e_latency.record(e2e);
                    m.responses += 1;
                    let rm = m.route_mut(&route_key);
                    rm.completed += 1;
                    rm.e2e.record(e2e);
                    if r.trace != 0 {
                        telemetry::record_span(
                            r.trace, Stage::Queue, r.enqueued, queue_time,
                            bucket as u64, 0, &route_key,
                        );
                        telemetry::record_span(
                            r.trace, Stage::Dispatch, t0, exec_time,
                            bucket as u64, 0, &route_key,
                        );
                    }
                    if let Some(reply) = replies.remove(&r.id) {
                        let _ = reply.send(Ok(GenResponse {
                            id: r.id,
                            output: out[i * sample_out..(i + 1) * sample_out].to_vec(),
                            batch_size: bucket,
                            queue_time,
                            exec_time,
                        }));
                    }
                }
                exec_time
            }
            ExecResult::Done(out) => {
                // a wrong-shaped output is the same trust violation as a
                // panic: contain, bisect, quarantine
                let msg = format!(
                    "wrong output shape: got {} values, expected {}",
                    out.len(),
                    bucket * sample_out
                );
                exec_time + self.contain_crash(requests, replies, contained, msg)
            }
            ExecResult::Crashed(msg) => {
                exec_time + self.contain_crash(requests, replies, contained, msg)
            }
            ExecResult::Failed(e) => {
                fail_requests(&requests, replies, ServeError::Execution(e));
                exec_time
            }
        }
    }

    /// Run the backend under `catch_unwind`, with the deterministic
    /// fault-injection hook for site `batch_exec` inside the same
    /// containment boundary.
    fn exec_contained(&self, artifact: &str, input: &[f32]) -> ExecResult {
        let faults = self.faults;
        let caught = catch_unwind(AssertUnwindSafe(|| {
            let mut truncate = false;
            if let Some(plane) = faults {
                match plane.check(FaultSite::BatchExec) {
                    Some(FaultAction::Panic) => panic!("fault injected: batch_exec panic"),
                    Some(FaultAction::Delay(d)) => std::thread::sleep(d),
                    Some(FaultAction::WrongShape) => truncate = true,
                    Some(FaultAction::Error) => {
                        return Err("fault injected: batch_exec error".to_string())
                    }
                    None => {}
                }
            }
            let mut out = self.runtime.execute_artifact(artifact, input)?;
            if truncate {
                out.truncate(out.len() / 2);
            }
            Ok(out)
        }));
        match caught {
            Ok(Ok(out)) => ExecResult::Done(out),
            Ok(Err(e)) => ExecResult::Failed(e),
            Err(p) => ExecResult::Crashed(panic_message(p)),
        }
    }

    /// A batch crashed: count the contained panic, then either quarantine
    /// (single request — it *is* the poison) or bisect so innocent
    /// batch-mates get retried. The engine's bitwise batch-composition
    /// invariance means the retried halves produce outputs identical to a
    /// fault-free run.
    fn contain_crash(
        &self,
        requests: Vec<GenRequest>,
        replies: &mut HashMap<RequestId, Reply>,
        contained: &mut u32,
        msg: String,
    ) -> Duration {
        *contained += 1;
        let route_key = format!("{}/{}", self.key.0, self.key.1);
        {
            let mut m = lock_unpoisoned(self.metrics);
            m.panics_contained += 1;
            m.route_mut(&route_key).panics_contained += 1;
        }
        if requests.len() <= 1 {
            let n = requests.len() as u64;
            let mut m = lock_unpoisoned(self.metrics);
            m.requests_quarantined += n;
            m.route_mut(&route_key).requests_quarantined += n;
            drop(m);
            // a quarantined crash is exactly what the flight recorder is
            // for: leave a Dispatch span (b = 1) naming the panic
            for r in &requests {
                if r.trace != 0 {
                    telemetry::record_span(
                        r.trace, Stage::Dispatch, Instant::now(), Duration::ZERO,
                        0, 1, &format!("crashed: {msg}"),
                    );
                }
            }
            fail_requests(&requests, replies, ServeError::Crashed(msg));
            return Duration::ZERO;
        }
        {
            let mut m = lock_unpoisoned(self.metrics);
            m.bisection_retries += 1;
            m.route_mut(&route_key).bisection_retries += 1;
        }
        let route = self.router.route(&self.key.0, &self.key.1).expect("validated at submit");
        let mut head = requests;
        let tail = head.split_off(head.len() / 2);
        let head_bucket = smallest_bucket(route, head.len());
        let tail_bucket = smallest_bucket(route, tail.len());
        self.exec_requests(head, head_bucket, replies, contained)
            + self.exec_requests(tail, tail_bucket, replies, contained)
    }
}

/// Smallest configured bucket that fits `n` requests (bisection halves
/// are smaller than the original bucket, which always exists).
fn smallest_bucket(route: &Route, n: usize) -> usize {
    route.buckets.range(n..).next().map(|(b, _)| *b).unwrap_or(n)
}

/// Render a caught panic payload for typed error reporting.
fn panic_message(p: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "opaque panic payload".to_string()
    }
}

fn fail_requests(
    requests: &[GenRequest],
    replies: &mut HashMap<RequestId, Reply>,
    err: ServeError,
) {
    for r in requests {
        if let Some(reply) = replies.remove(&r.id) {
            let _ = reply.send(Err(err.clone()));
        }
    }
}

/// Legacy single-engine loop (PJRT path, and any backend that is not
/// `Send`): one thread owns the backend and drains every route. Panic
/// containment and bisection apply; supervision does not.
fn engine_loop<E: ExecBackend>(
    runtime: E,
    router: Router,
    metrics: Arc<Mutex<Metrics>>,
    gate: Arc<Gate>,
    cfg: ServeConfig,
    rx: Receiver<Msg>,
) {
    let mut states: HashMap<(String, String), RouteState> = HashMap::new();
    loop {
        // wait for work, but never past the nearest scheduler deadline
        let deadline = states
            .values()
            .filter_map(|s| s.batcher.next_deadline())
            .min();
        let first = match deadline {
            Some(d) => {
                let timeout = d.saturating_duration_since(Instant::now());
                match rx.recv_timeout(timeout) {
                    Ok(m) => Some(m),
                    Err(RecvTimeoutError::Timeout) => None,
                    Err(RecvTimeoutError::Disconnected) => Some(Msg::Shutdown),
                }
            }
            None => match rx.recv() {
                Ok(m) => Some(m),
                Err(_) => Some(Msg::Shutdown),
            },
        };

        // drain everything already in the channel before polling: requests
        // that arrived while the previous batch executed all join the
        // forming batch in one go (continuous batching's join-in-flight)
        let mut shutdown = false;
        let mut msg = first;
        loop {
            match msg {
                Some(Msg::Request(req, reply)) => {
                    handle_request(&mut states, &router, &metrics, &gate, &cfg, req, reply)
                }
                Some(Msg::Shutdown) => {
                    shutdown = true;
                    break;
                }
                None => {} // deadline tick: fall through to polling
            }
            msg = match rx.try_recv() {
                Ok(m) => Some(m),
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => Some(Msg::Shutdown),
            };
        }

        if shutdown {
            // flush everything, then exit — shutdown is a drain, not a shed
            for (key, state) in states.iter_mut() {
                let ctx = BatchCtx {
                    runtime: &runtime,
                    router: &router,
                    metrics: &metrics,
                    faults: cfg.faults.as_deref(),
                    key,
                };
                drain_state(&ctx, &gate, state);
            }
            return;
        }

        let now = Instant::now();
        for (key, state) in states.iter_mut() {
            let ctx = BatchCtx {
                runtime: &runtime,
                router: &router,
                metrics: &metrics,
                faults: cfg.faults.as_deref(),
                key,
            };
            loop {
                let Dispatch { batch, shed } = state.batcher.poll(now);
                if !shed.is_empty() {
                    gate.release(key, shed.len());
                    state.slots_held = state.slots_held.saturating_sub(shed.len());
                    shed_requests(&metrics, key, shed, &mut state.replies);
                }
                let Some(batch) = batch else { break };
                gate.release(key, batch.requests.len());
                state.slots_held = state.slots_held.saturating_sub(batch.requests.len());
                let outcome = ctx.run_batch(batch, &mut state.replies);
                state.batcher.observe(outcome.service);
            }
        }
    }
}

/// Admit one request into its route's scheduler, creating the route state
/// on first touch; a typed admission rejection is answered immediately.
fn handle_request(
    states: &mut HashMap<(String, String), RouteState>,
    router: &Router,
    metrics: &Mutex<Metrics>,
    gate: &Gate,
    cfg: &ServeConfig,
    req: GenRequest,
    reply: Reply,
) {
    let key = (req.model.clone(), req.method.clone());
    let state = states.entry(key.clone()).or_insert_with(|| {
        let route = router.route(&key.0, &key.1).expect("validated");
        RouteState::new(cfg, route.bucket_sizes())
    });
    admit_to_state(state, metrics, gate, &key, req, reply);
}

/// Admit one request into an existing route state, answering a typed
/// rejection immediately and keeping the gate-slot ledger exact.
fn admit_to_state(
    state: &mut RouteState,
    metrics: &Mutex<Metrics>,
    gate: &Gate,
    key: &(String, String),
    req: GenRequest,
    reply: Reply,
) {
    let id = req.id;
    match state.batcher.admit(req, Instant::now()) {
        Ok(()) => {
            state.replies.insert(id, reply);
            state.slots_held += 1;
        }
        Err((req, rej)) => {
            gate.release(key, 1);
            count_shed(metrics, key, &rej);
            let _ = reply.send(Err(ServeError::Rejected(rej)));
            drop(req);
        }
    }
}

/// Answer dispatch-time sheds (expired deadlines) with their typed
/// verdicts and count them.
fn shed_requests(
    metrics: &Mutex<Metrics>,
    key: &(String, String),
    shed: Vec<(GenRequest, Rejected)>,
    replies: &mut HashMap<RequestId, Reply>,
) {
    for (req, rej) in shed {
        if req.trace != 0 {
            telemetry::record_span(
                req.trace, Stage::Queue, req.enqueued, req.enqueued.elapsed(),
                0, shed_code(&rej), &format!("shed: {rej}"),
            );
        }
        count_shed(metrics, key, &rej);
        if let Some(reply) = replies.remove(&req.id) {
            let _ = reply.send(Err(ServeError::Rejected(rej)));
        }
    }
}

/// Compact shed-verdict code for the `b` detail of telemetry spans
/// (`0` = admitted/served; see [`Stage::Admission`]).
fn shed_code(rej: &Rejected) -> u64 {
    match rej {
        Rejected::QueueFull { .. } => 1,
        Rejected::DeadlineInfeasible { .. } => 2,
        Rejected::Unhealthy { .. } => 3,
        Rejected::FleetUnavailable { .. } => 4,
    }
}

fn count_shed(metrics: &Mutex<Metrics>, key: &(String, String), rej: &Rejected) {
    let mut m = lock_unpoisoned(metrics);
    let route = format!("{}/{}", key.0, key.1);
    match rej {
        Rejected::QueueFull { .. } => {
            m.shed_queue_full += 1;
            m.route_mut(&route).shed_queue_full += 1;
        }
        Rejected::DeadlineInfeasible { .. } => {
            m.shed_deadline += 1;
            m.route_mut(&route).shed_deadline += 1;
        }
        Rejected::Unhealthy { .. } => {
            m.shed_unhealthy += 1;
            m.route_mut(&route).shed_unhealthy += 1;
        }
        // fleet-tier verdict; if one ever reaches an in-process coordinator
        // it still lands in a shed counter rather than vanishing
        Rejected::FleetUnavailable { .. } => {
            m.shed_unhealthy += 1;
            m.route_mut(&route).shed_unhealthy += 1;
        }
    }
}

/// Flush and execute everything still queued in a route's batcher —
/// shutdown and engine-handoff are *drains*, not sheds: every queued
/// request completes (bitwise identical to normal service).
fn drain_state<E: ExecBackend>(ctx: &BatchCtx<'_, E>, gate: &Gate, state: &mut RouteState) {
    while let Some(batch) = state.batcher.flush() {
        gate.release(ctx.key, batch.requests.len());
        state.slots_held = state.slots_held.saturating_sub(batch.requests.len());
        let _ = ctx.run_batch(batch, &mut state.replies);
    }
}

/// Fail every request this engine still holds (used when an unwind
/// escapes the batch boundary: scheduler state is suspect, so the work is
/// answered typed rather than retried) and zero the gate ledger.
fn abandon_state(
    metrics: &Mutex<Metrics>,
    gate: &Gate,
    key: &(String, String),
    state: &mut RouteState,
    err: ServeError,
) {
    let n = state.replies.len() as u64;
    if n > 0 {
        let mut m = lock_unpoisoned(metrics);
        m.requests_quarantined += n;
        m.route_mut(&format!("{}/{}", key.0, key.1)).requests_quarantined += n;
    }
    for (_, reply) in state.replies.drain() {
        let _ = reply.send(Err(err.clone()));
    }
    gate.release(key, state.slots_held);
    state.slots_held = 0;
}

/// Spawn one engine incarnation for `key` at a fresh generation.
fn spawn_incarnation<E>(env: &SupEnv<E>, key: &(String, String))
where
    E: ExecBackend + Send + Sync + 'static,
{
    let Some(r) = env.sup.routes.get(key) else { return };
    let my_gen = r.shared.generation.fetch_add(1, Ordering::SeqCst) + 1;
    env.sup.live.fetch_add(1, Ordering::SeqCst);
    let sup = env.sup.clone();
    let thread_env = env.clone();
    let thread_key = key.clone();
    let spawned = std::thread::Builder::new()
        .name(format!("wingan-engine-{}", key.0))
        .spawn(move || {
            let _live = LiveGuard(thread_env.sup.clone());
            run_incarnation(thread_env, thread_key, my_gen);
        });
    if spawned.is_err() {
        sup.live.fetch_sub(1, Ordering::SeqCst);
        eprintln!("supervisor: failed to spawn engine thread for {}/{}", key.0, key.1);
    }
}

/// One supervised engine incarnation: drains its route's shared channel,
/// schedules and executes batches with containment, and reports its own
/// death (panic storm or escaped unwind) to the supervisor. Exits
/// silently when superseded (watchdog bumped the generation) or on
/// shutdown — both after *completing* queued work, so every admitted
/// request gets exactly one fate.
fn run_incarnation<E>(env: SupEnv<E>, key: (String, String), my_gen: u64)
where
    E: ExecBackend + Send + Sync + 'static,
{
    let Some(shared) = env.sup.routes.get(&key).map(|r| r.shared.clone()) else { return };
    let Ok(route) = env.router.route(&key.0, &key.1) else { return };
    let mut state = RouteState::new(&env.cfg, route.bucket_sizes());
    let ctx = BatchCtx {
        runtime: env.backend.as_ref(),
        router: &env.router,
        metrics: &env.metrics,
        faults: env.cfg.faults.as_deref(),
        key: &key,
    };
    let epoch = env.sup.epoch;
    let idle_tick = Duration::from_millis(20);
    loop {
        if shared.generation.load(Ordering::SeqCst) != my_gen {
            // superseded (watchdog): complete what we hold, exit quietly —
            // the death was already charged by the supervisor
            drain_state(&ctx, &env.gate, &mut state);
            return;
        }
        if shared.shutdown.load(Ordering::SeqCst) {
            // graceful shutdown: pull everything still in the channel into
            // the batcher, then drain — shutdown completes work
            let mut msgs = Vec::new();
            {
                let rx = lock_unpoisoned(&shared.rx);
                while let Ok(m) = rx.try_recv() {
                    msgs.push(m);
                }
            }
            for m in msgs {
                if let Msg::Request(req, reply) = m {
                    admit_to_state(&mut state, &env.metrics, &env.gate, &key, req, reply);
                }
            }
            drain_state(&ctx, &env.gate, &mut state);
            return;
        }
        // wait for work, but never past the nearest scheduler deadline and
        // never past the idle tick (shutdown/supersession must be noticed)
        let timeout = state
            .batcher
            .next_deadline()
            .map(|d| d.saturating_duration_since(Instant::now()))
            .unwrap_or(idle_tick)
            .min(idle_tick);
        let mut msgs = Vec::new();
        {
            let rx = lock_unpoisoned(&shared.rx);
            match rx.recv_timeout(timeout) {
                Ok(m) => {
                    msgs.push(m);
                    while let Ok(m) = rx.try_recv() {
                        msgs.push(m);
                    }
                }
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => {
                    shared.shutdown.store(true, Ordering::SeqCst);
                    continue;
                }
            }
        }
        let mut saw_shutdown = false;
        for m in msgs {
            match m {
                Msg::Request(req, reply) => {
                    admit_to_state(&mut state, &env.metrics, &env.gate, &key, req, reply)
                }
                Msg::Shutdown => saw_shutdown = true,
            }
        }
        if saw_shutdown {
            shared.shutdown.store(true, Ordering::SeqCst);
            continue; // the shutdown branch above drains and exits
        }
        // the dispatch round itself runs under catch_unwind: a bug in the
        // scheduler/accounting path (not just the backend) still cannot
        // take the process down
        let round = catch_unwind(AssertUnwindSafe(|| {
            dispatch_round(&ctx, &env.gate, &mut state, &shared, my_gen, epoch)
        }));
        match round {
            Ok(0) => {}
            Ok(contained) => {
                let now = Instant::now();
                let storm = {
                    let mut pol = lock_unpoisoned(&shared.policy);
                    let mut s = false;
                    for _ in 0..contained {
                        s |= pol.note_contained_panic(now);
                    }
                    s
                };
                if storm {
                    // panic storm: containment is working but something is
                    // systematically wrong — finish what we hold (every
                    // request a fate), then die and let the supervisor
                    // apply backoff / the breaker
                    drain_state(&ctx, &env.gate, &mut state);
                    let _ = env
                        .sup_tx
                        .send(SupEvent::Died { key: key.clone(), generation: my_gen });
                    return;
                }
            }
            Err(p) => {
                // an unwind escaped the batch boundary: scheduler state is
                // suspect; answer everything typed and report the death
                let msg = panic_message(p);
                abandon_state(
                    &env.metrics,
                    &env.gate,
                    &key,
                    &mut state,
                    ServeError::Crashed(format!("engine incarnation died: {msg}")),
                );
                let _ = shared.busy_gen.compare_exchange(
                    my_gen,
                    IDLE_GEN,
                    Ordering::SeqCst,
                    Ordering::SeqCst,
                );
                let _ = env.sup_tx.send(SupEvent::Died { key: key.clone(), generation: my_gen });
                return;
            }
        }
    }
}

/// Poll the batcher until it has nothing dispatchable, executing released
/// batches with the watchdog heartbeat set; returns how many panics were
/// contained this round.
fn dispatch_round<E: ExecBackend>(
    ctx: &BatchCtx<'_, E>,
    gate: &Gate,
    state: &mut RouteState,
    shared: &RouteShared,
    my_gen: u64,
    epoch: Instant,
) -> u32 {
    let mut contained = 0u32;
    loop {
        let now = Instant::now();
        let Dispatch { batch, shed } = state.batcher.poll(now);
        if !shed.is_empty() {
            gate.release(ctx.key, shed.len());
            state.slots_held = state.slots_held.saturating_sub(shed.len());
            shed_requests(ctx.metrics, ctx.key, shed, &mut state.replies);
        }
        let Some(batch) = batch else { break };
        gate.release(ctx.key, batch.requests.len());
        state.slots_held = state.slots_held.saturating_sub(batch.requests.len());
        // heartbeat: the supervisor's watchdog sees (generation, since)
        // and supersedes us if a batch wedges past the deadline
        shared.busy_since_ms.store(elapsed_ms(epoch), Ordering::SeqCst);
        shared.busy_gen.store(my_gen, Ordering::SeqCst);
        let outcome = ctx.run_batch(batch, &mut state.replies);
        let _ = shared.busy_gen.compare_exchange(my_gen, IDLE_GEN, Ordering::SeqCst, Ordering::SeqCst);
        contained += outcome.contained;
        state.batcher.observe(outcome.service);
    }
    contained
}

/// The supervisor: owns restart policy for every route. Death events and
/// a periodic tick drive per-route [`RoutePolicy`] state machines —
/// backoff-scheduled restarts, breaker trips, the stuck-batch watchdog —
/// and an open breaker's queue is shed typed instead of hanging.
fn supervisor_loop<E>(env: SupEnv<E>, sup_rx: Receiver<SupEvent>)
where
    E: ExecBackend + Send + Sync + 'static,
{
    let tick = Duration::from_millis(2);
    let watchdog_ms = env.cfg.supervisor.watchdog.as_millis() as u64;
    loop {
        match sup_rx.recv_timeout(tick) {
            Ok(SupEvent::Died { key, generation }) => {
                if let Some(r) = env.sup.routes.get(&key) {
                    // only a *current* incarnation's death counts: a stale
                    // one was already superseded (and charged) by the
                    // watchdog. Retiring the generation here also stops a
                    // half-dead incarnation from racing its replacement.
                    if r.shared
                        .generation
                        .compare_exchange(generation, generation + 1, Ordering::SeqCst, Ordering::SeqCst)
                        .is_ok()
                    {
                        let _ = r.shared.busy_gen.compare_exchange(
                            generation,
                            IDLE_GEN,
                            Ordering::SeqCst,
                            Ordering::SeqCst,
                        );
                        let verdict = lock_unpoisoned(&r.shared.policy).note_death(Instant::now());
                        if verdict == DeathVerdict::BreakerOpen {
                            eprintln!(
                                "supervisor: route {}/{} circuit breaker OPEN (too many engine deaths)",
                                key.0, key.1
                            );
                        }
                    }
                }
            }
            Err(RecvTimeoutError::Timeout) => {}
            // unreachable while env holds a sender clone; exit defensively
            Err(RecvTimeoutError::Disconnected) => return,
        }
        let now = Instant::now();
        let now_ms = elapsed_ms(env.sup.epoch);
        let shutting = env.sup.shutdown.load(Ordering::SeqCst);
        for (key, r) in &env.sup.routes {
            // stuck-batch watchdog: a batch executing past the deadline
            // retires its incarnation (which exits at its next loop check)
            // and charges a death
            let gen = r.shared.generation.load(Ordering::SeqCst);
            let busy = r.shared.busy_gen.load(Ordering::SeqCst);
            if busy == gen
                && now_ms.saturating_sub(r.shared.busy_since_ms.load(Ordering::SeqCst))
                    > watchdog_ms
                && r.shared
                    .generation
                    .compare_exchange(gen, gen + 1, Ordering::SeqCst, Ordering::SeqCst)
                    .is_ok()
            {
                let _ = r.shared.busy_gen.compare_exchange(
                    busy,
                    IDLE_GEN,
                    Ordering::SeqCst,
                    Ordering::SeqCst,
                );
                eprintln!(
                    "supervisor: route {}/{} stuck batch (watchdog {watchdog_ms}ms); superseding engine",
                    key.0, key.1
                );
                let _ = lock_unpoisoned(&r.shared.policy).note_stuck(now);
            }
            if shutting {
                continue;
            }
            // an open breaker has no engine: shed its queue typed so
            // callers never hang on a dead route
            let (open, restarts) = {
                let pol = lock_unpoisoned(&r.shared.policy);
                (pol.is_open(), pol.restarts())
            };
            if open {
                shed_unhealthy_queue(&env.metrics, &env.gate, key, r, restarts);
            }
            // due restarts (backoff expiry, breaker half-open probe)
            let action = lock_unpoisoned(&r.shared.policy).poll(now);
            if action == Some(SupervisorAction::Restart) {
                spawn_incarnation(&env, key);
            }
        }
        if shutting && env.sup.live.load(Ordering::SeqCst) == 0 {
            // every incarnation has exited; whatever is still in a channel
            // (e.g. a breaker-open route with no engine) is answered typed
            for (key, r) in &env.sup.routes {
                abandon_queue(&env.metrics, &env.gate, key, r);
            }
            return;
        }
    }
}

/// Drain a breaker-open route's channel, answering each request with a
/// typed [`Rejected::Unhealthy`] shed.
fn shed_unhealthy_queue(
    metrics: &Mutex<Metrics>,
    gate: &Gate,
    key: &(String, String),
    r: &SupRoute,
    restarts: u64,
) {
    let rx = match r.shared.rx.try_lock() {
        Ok(g) => g,
        Err(TryLockError::Poisoned(p)) => p.into_inner(),
        Err(TryLockError::WouldBlock) => return,
    };
    while let Ok(m) = rx.try_recv() {
        if let Msg::Request(_, reply) = m {
            gate.release(key, 1);
            let rej = Rejected::Unhealthy { restarts };
            count_shed(metrics, key, &rej);
            let _ = reply.send(Err(ServeError::Rejected(rej)));
        }
    }
}

/// Answer whatever is still queued on a route with typed
/// [`ServeError::EngineShutdown`] and count it — the drain deadline
/// passed (or the route had no engine); requests are never silently lost.
fn abandon_queue(metrics: &Mutex<Metrics>, gate: &Gate, key: &(String, String), r: &SupRoute) {
    let rx = match r.shared.rx.try_lock() {
        Ok(g) => g,
        Err(TryLockError::Poisoned(p)) => p.into_inner(),
        Err(TryLockError::WouldBlock) => return,
    };
    let mut abandoned = 0u64;
    while let Ok(m) = rx.try_recv() {
        if let Msg::Request(_, reply) = m {
            gate.release(key, 1);
            abandoned += 1;
            let _ = reply.send(Err(ServeError::EngineShutdown));
        }
    }
    if abandoned > 0 {
        lock_unpoisoned(metrics).abandoned_at_shutdown += abandoned;
        eprintln!(
            "coordinator: abandoned {abandoned} queued requests on {}/{} at shutdown",
            key.0, key.1
        );
    }
}
