//! The serving coordinator: an engine thread that owns an execution
//! backend and drains per-route batch schedulers; callers talk to it
//! through channels (`Coordinator::submit`). Python is never on this path.
//!
//! Shape:
//!   caller -> gate -> mpsc -> engine thread [ scheduler -> pack ->
//!                       execute backend -> unpack -> respond per-request ]
//!
//! Two backends implement the same [`ExecBackend`] contract:
//! * **PJRT** ([`Coordinator::start`]) — AOT artifacts compiled and
//!   executed via the `xla` runtime (gated off in offline builds);
//! * **native** ([`Coordinator::start_native`]) — whole generators run
//!   through precompiled [`crate::engine`] plans, no artifacts needed.
//!
//! **Admission is bounded** (PR 7): every route has a fixed-capacity
//! admission gate ([`ServeConfig::queue_cap`]) spanning the channel *and*
//! the scheduler queue. `submit` sheds with a typed
//! [`ServeError::Rejected`] ([`Rejected::QueueFull`]) instead of queuing
//! unboundedly — the old path's OOM-shaped growth under overload is
//! structurally gone. With an SLO configured ([`ServeConfig::slo`], or a
//! per-request budget via [`Coordinator::submit_with_deadline`]) the
//! continuous scheduler also sheds deadline-infeasible requests, typed
//! [`Rejected::DeadlineInfeasible`].
//!
//! The engine blocks on the request channel with a timeout equal to the
//! nearest scheduler deadline, so held batches and deadline sheds happen
//! on time without a busy loop; after every wake it drains the whole
//! channel before polling, so requests that arrived while a batch was
//! executing join the next batch — continuous batching's join-in-flight.
//!
//! On the native backend, compute threading is *not* per request: the
//! [`crate::engine::NativeRuntime`] built at startup owns one persistent
//! [`crate::engine::WorkerPool`] (sized by
//! [`NativeConfig::workers`](crate::engine::NativeConfig), default one
//! thread per core) that every route's engine dispatches to. A released
//! batch executes via the engine's two-level scheduler — wide buckets fan
//! out across samples, narrow ones across stripes inside each sample — so
//! the pool stays busy without the spawn-per-phase threading of PR 1.

use crate::coordinator::batcher::{
    BatchPolicy, ContinuousBatcher, Dispatch, DynamicBatcher, ReadyBatch,
};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::request::{GenRequest, GenResponse, Rejected, RequestId, ServeError};
use crate::coordinator::router::Router;
use crate::engine::serve::{native_manifest, NativeConfig, NativeRuntime};
use crate::runtime::{Manifest, Runtime};
use anyhow::Result;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// What the engine thread needs from an execution backend: run one packed
/// batch buffer against a named route artifact.
pub trait ExecBackend {
    fn execute_artifact(&self, name: &str, input: &[f32]) -> std::result::Result<Vec<f32>, String>;
}

impl ExecBackend for Runtime {
    fn execute_artifact(&self, name: &str, input: &[f32]) -> std::result::Result<Vec<f32>, String> {
        self.execute(name, input).map_err(|e| format!("{e:#}"))
    }
}

impl ExecBackend for NativeRuntime {
    fn execute_artifact(&self, name: &str, input: &[f32]) -> std::result::Result<Vec<f32>, String> {
        self.execute(name, input)
    }
}

type Reply = Sender<Result<GenResponse, ServeError>>;

enum Msg {
    Request(GenRequest, Reply),
    Shutdown,
}

/// Which batch scheduler the engine runs per route.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum SchedulerKind {
    /// Continuous batching with SLO-aware admission
    /// ([`ContinuousBatcher`]) — the default production scheduler.
    #[default]
    Continuous,
    /// The PR-6 bucket-and-deadline baseline ([`DynamicBatcher`]), kept
    /// so `wingan loadgen` can A/B the schedulers under identical
    /// traffic.
    Bucket,
}

impl SchedulerKind {
    /// Parse a `--scheduler` CLI value.
    pub fn parse(s: &str) -> std::result::Result<SchedulerKind, String> {
        match s.to_ascii_lowercase().as_str() {
            "continuous" => Ok(SchedulerKind::Continuous),
            "bucket" => Ok(SchedulerKind::Bucket),
            other => Err(format!("unknown scheduler '{other}' (continuous|bucket)")),
        }
    }
}

/// Per-route admission slot counter: the depth spans the request channel
/// plus the scheduler queue, so the bound holds no matter where a request
/// currently sits.
struct RouteGate {
    depth: AtomicUsize,
    peak: AtomicUsize,
}

/// The bounded admission gate shared by the caller-side `submit` and the
/// engine thread: one slot counter per route, capacity
/// [`ServeConfig::queue_cap`].
struct Gate {
    cap: usize,
    routes: HashMap<(String, String), RouteGate>,
}

impl Gate {
    fn new(router: &Router, cap: usize) -> Gate {
        let routes = router
            .models()
            .into_iter()
            .map(|key| (key, RouteGate { depth: AtomicUsize::new(0), peak: AtomicUsize::new(0) }))
            .collect();
        Gate { cap, routes }
    }

    /// Claim one slot for `key`, or report the queue full.
    fn try_acquire(&self, key: &(String, String)) -> std::result::Result<(), Rejected> {
        let g = self.routes.get(key).expect("gate covers every validated route");
        loop {
            let d = g.depth.load(Ordering::Acquire);
            if d >= self.cap {
                return Err(Rejected::QueueFull { depth: d, cap: self.cap });
            }
            if g.depth
                .compare_exchange(d, d + 1, Ordering::AcqRel, Ordering::Acquire)
                .is_ok()
            {
                g.peak.fetch_max(d + 1, Ordering::AcqRel);
                return Ok(());
            }
        }
    }

    /// Release `n` slots (requests dispatched, shed, or failed).
    fn release(&self, key: &(String, String), n: usize) {
        if let Some(g) = self.routes.get(key) {
            g.depth.fetch_sub(n, Ordering::AcqRel);
        }
    }
}

/// Handle to a running coordinator.
pub struct Coordinator {
    tx: Sender<Msg>,
    next_id: AtomicU64,
    metrics: Arc<Mutex<Metrics>>,
    router: Router,
    gate: Arc<Gate>,
    slo: Option<Duration>,
    handle: Option<std::thread::JoinHandle<()>>,
}

/// Coordinator configuration.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// max time a request may wait for batch-mates before a partial batch
    /// ships. `ZERO` (the default) makes the continuous scheduler fully
    /// work-conserving; the bucket baseline typically runs 5–20 ms here.
    pub max_wait: Duration,
    /// which artifacts to preload at startup (None = all generators)
    pub preload_models: Option<Vec<String>>,
    /// batch scheduler per route (continuous by default)
    pub scheduler: SchedulerKind,
    /// per-route admission bound: at most this many requests may be
    /// in flight (channel + scheduler queue) per route before `submit`
    /// sheds with [`Rejected::QueueFull`]
    pub queue_cap: usize,
    /// default per-request SLO budget: requests get `now + slo` as their
    /// deadline unless [`Coordinator::submit_with_deadline`] overrides it.
    /// `None` = best-effort (no deadline shedding).
    pub slo: Option<Duration>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            max_wait: Duration::ZERO,
            preload_models: None,
            scheduler: SchedulerKind::Continuous,
            queue_cap: 256,
            slo: None,
        }
    }
}

impl Coordinator {
    /// Start the engine thread: compiles artifacts, then serves.
    pub fn start(manifest: Manifest, cfg: ServeConfig) -> Result<Coordinator> {
        let router = Router::from_manifest(&manifest);
        let metrics = Arc::new(Mutex::new(Metrics::new()));
        let gate = Arc::new(Gate::new(&router, cfg.queue_cap));
        let (tx, rx) = mpsc::channel::<Msg>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<(), String>>();

        // The PJRT client is not Send, so the runtime lives entirely inside
        // the engine thread; artifacts are preloaded there before the
        // coordinator reports ready (first requests never pay compile time).
        let engine_router = router.clone();
        let engine_metrics = metrics.clone();
        let engine_gate = gate.clone();
        let engine_cfg = cfg.clone();
        let handle = std::thread::Builder::new()
            .name("wingan-engine".into())
            .spawn(move || {
                let mut runtime = match Runtime::new() {
                    Ok(r) => r,
                    Err(e) => {
                        let _ = ready_tx.send(Err(e.to_string()));
                        return;
                    }
                };
                for e in manifest.entries.iter().filter(|e| e.kind == "generator") {
                    if let Some(models) = &engine_cfg.preload_models {
                        if !models.contains(&e.model) {
                            continue;
                        }
                    }
                    if let Err(e) = runtime.load(e) {
                        let _ = ready_tx.send(Err(e.to_string()));
                        return;
                    }
                }
                let _ = ready_tx.send(Ok(()));
                engine_loop(runtime, engine_router, engine_metrics, engine_gate, engine_cfg, rx)
            })
            .expect("spawn engine");
        ready_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("engine thread died during startup"))?
            .map_err(|e| anyhow::anyhow!("engine startup failed: {e}"))?;

        Ok(Coordinator {
            tx,
            next_id: AtomicU64::new(1),
            metrics,
            router,
            gate,
            slo: cfg.slo,
            handle: Some(handle),
        })
    }

    /// Start the engine thread on the native execution backend: every
    /// route's [`crate::engine`] plan is compiled — and the one worker
    /// pool all routes share is spawned — before the coordinator reports
    /// ready, then generation requests batch and execute through the
    /// precompiled plans — no PJRT, no artifacts on disk, no thread
    /// spawns on the request path.
    ///
    /// `cfg.preload_models`, when set, restricts which zoo models get
    /// compiled (same semantics as the PJRT path); `native.workers` sizes
    /// the shared pool (0 = env/core default).
    pub fn start_native(mut native: NativeConfig, cfg: ServeConfig) -> Result<Coordinator> {
        if let Some(models) = &cfg.preload_models {
            native.models = Some(models.clone());
        }
        let manifest = native_manifest(&native);
        anyhow::ensure!(
            !manifest.entries.is_empty(),
            "native backend: no routes to serve (model filter {:?})",
            native.models
        );
        let router = Router::from_manifest(&manifest);
        let metrics = Arc::new(Mutex::new(Metrics::new()));
        let gate = Arc::new(Gate::new(&router, cfg.queue_cap));
        let (tx, rx) = mpsc::channel::<Msg>();
        let (ready_tx, ready_rx) = mpsc::channel::<std::result::Result<(), String>>();

        let engine_router = router.clone();
        let engine_metrics = metrics.clone();
        let engine_gate = gate.clone();
        let engine_cfg = cfg.clone();
        let handle = std::thread::Builder::new()
            .name("wingan-engine".into())
            .spawn(move || {
                // plan compilation happens here, once, before ready — the
                // request path only ever executes precompiled plans (or,
                // with `native.plan_store`, loads them from artifacts)
                let runtime = NativeRuntime::build(&native);
                // surface the warm-vs-cold startup accounting through the
                // serving metrics snapshot
                engine_metrics.lock().unwrap().plan_cache = runtime.plan_stats();
                let _ = ready_tx.send(Ok(()));
                engine_loop(runtime, engine_router, engine_metrics, engine_gate, engine_cfg, rx)
            })
            .expect("spawn engine");
        ready_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("engine thread died during startup"))?
            .map_err(|e| anyhow::anyhow!("engine startup failed: {e}"))?;

        Ok(Coordinator {
            tx,
            next_id: AtomicU64::new(1),
            metrics,
            router,
            gate,
            slo: cfg.slo,
            handle: Some(handle),
        })
    }

    pub fn router(&self) -> &Router {
        &self.router
    }

    /// Submit a request with the configured default SLO (if any); returns
    /// a receiver for the response. Sheds with
    /// [`ServeError::Rejected`]`(`[`Rejected::QueueFull`]`)` when the
    /// route's admission gate is at capacity — the queue is bounded, so
    /// overload can never grow memory without bound.
    pub fn submit(
        &self,
        model: &str,
        method: &str,
        input: Vec<f32>,
    ) -> Result<Receiver<Result<GenResponse, ServeError>>, ServeError> {
        self.submit_with_deadline(model, method, input, self.slo)
    }

    /// Submit a request with an explicit per-request SLO budget (`None` =
    /// best-effort, overriding any configured default). The deadline is
    /// stamped at submit time; an infeasible or expired deadline comes
    /// back as a typed [`Rejected::DeadlineInfeasible`] response.
    pub fn submit_with_deadline(
        &self,
        model: &str,
        method: &str,
        input: Vec<f32>,
        budget: Option<Duration>,
    ) -> Result<Receiver<Result<GenResponse, ServeError>>, ServeError> {
        self.router.validate(model, method, input.len())?;
        let key = (model.to_string(), method.to_string());
        if let Err(rej) = self.gate.try_acquire(&key) {
            let mut m = self.metrics.lock().unwrap();
            m.shed_queue_full += 1;
            m.route_mut(&format!("{model}/{method}")).shed_queue_full += 1;
            return Err(ServeError::Rejected(rej));
        }
        let id: RequestId = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (reply_tx, reply_rx) = mpsc::channel();
        let now = Instant::now();
        let req = GenRequest {
            id,
            model: model.to_string(),
            method: method.to_string(),
            input,
            enqueued: now,
            deadline: budget.and_then(|b| now.checked_add(b)),
        };
        {
            let mut m = self.metrics.lock().unwrap();
            m.requests += 1;
            let r = m.route_mut(&format!("{model}/{method}"));
            r.admitted += 1;
        }
        if self.tx.send(Msg::Request(req, reply_tx)).is_err() {
            self.gate.release(&key, 1);
            return Err(ServeError::EngineShutdown);
        }
        Ok(reply_rx)
    }

    /// Submit and block for the result.
    pub fn generate(
        &self,
        model: &str,
        method: &str,
        input: Vec<f32>,
    ) -> Result<GenResponse, ServeError> {
        self.submit(model, method, input)?
            .recv()
            .map_err(|_| ServeError::EngineShutdown)?
    }

    /// Snapshot of the serving metrics, with per-route queue depth and
    /// high-water marks folded in from the admission gate.
    pub fn metrics(&self) -> Metrics {
        let mut m = self.metrics.lock().unwrap().clone();
        for (key, g) in &self.gate.routes {
            let r = m.route_mut(&format!("{}/{}", key.0, key.1));
            r.depth = g.depth.load(Ordering::Acquire);
            r.peak_depth = g.peak.load(Ordering::Acquire);
        }
        m
    }

    /// Graceful shutdown: flushes pending batches first.
    pub fn shutdown(mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// The per-route scheduler the engine loop drives — continuous or the
/// bucket baseline, behind one polling surface.
enum RouteBatcher {
    Bucket(DynamicBatcher),
    Continuous(ContinuousBatcher),
}

impl RouteBatcher {
    fn new(cfg: &ServeConfig, buckets: Vec<usize>) -> RouteBatcher {
        let policy = BatchPolicy::new(buckets, cfg.max_wait);
        match cfg.scheduler {
            SchedulerKind::Bucket => RouteBatcher::Bucket(DynamicBatcher::new(policy)),
            SchedulerKind::Continuous => {
                RouteBatcher::Continuous(ContinuousBatcher::new(policy, cfg.queue_cap))
            }
        }
    }

    /// Admit one request (the bucket baseline never rejects — its bound
    /// is enforced by the gate alone).
    fn admit(&mut self, req: GenRequest, now: Instant) -> Result<(), (GenRequest, Rejected)> {
        match self {
            RouteBatcher::Bucket(b) => {
                b.push(req);
                Ok(())
            }
            RouteBatcher::Continuous(b) => b.admit(req, now),
        }
    }

    fn poll(&mut self, now: Instant) -> Dispatch {
        match self {
            RouteBatcher::Bucket(b) => Dispatch { batch: b.poll(now), shed: Vec::new() },
            RouteBatcher::Continuous(b) => b.poll(now),
        }
    }

    fn next_deadline(&self) -> Option<Instant> {
        match self {
            RouteBatcher::Bucket(b) => b.next_deadline(),
            RouteBatcher::Continuous(b) => b.next_deadline(),
        }
    }

    fn flush(&mut self) -> Option<ReadyBatch> {
        match self {
            RouteBatcher::Bucket(b) => b.flush(),
            RouteBatcher::Continuous(b) => b.flush(),
        }
    }

    /// Feed an observed batch service time into the admission forecast
    /// (no-op for the bucket baseline).
    fn observe(&mut self, service: Duration) {
        if let RouteBatcher::Continuous(b) = self {
            b.observe(service);
        }
    }
}

struct RouteState {
    batcher: RouteBatcher,
    replies: HashMap<RequestId, Reply>,
}

fn engine_loop<E: ExecBackend>(
    runtime: E,
    router: Router,
    metrics: Arc<Mutex<Metrics>>,
    gate: Arc<Gate>,
    cfg: ServeConfig,
    rx: Receiver<Msg>,
) {
    let mut states: HashMap<(String, String), RouteState> = HashMap::new();
    loop {
        // wait for work, but never past the nearest scheduler deadline
        let deadline = states
            .values()
            .filter_map(|s| s.batcher.next_deadline())
            .min();
        let first = match deadline {
            Some(d) => {
                let timeout = d.saturating_duration_since(Instant::now());
                match rx.recv_timeout(timeout) {
                    Ok(m) => Some(m),
                    Err(RecvTimeoutError::Timeout) => None,
                    Err(RecvTimeoutError::Disconnected) => Some(Msg::Shutdown),
                }
            }
            None => match rx.recv() {
                Ok(m) => Some(m),
                Err(_) => Some(Msg::Shutdown),
            },
        };

        // drain everything already in the channel before polling: requests
        // that arrived while the previous batch executed all join the
        // forming batch in one go (continuous batching's join-in-flight)
        let mut shutdown = false;
        let mut msg = first;
        loop {
            match msg {
                Some(Msg::Request(req, reply)) => {
                    handle_request(&mut states, &router, &metrics, &gate, &cfg, req, reply)
                }
                Some(Msg::Shutdown) => {
                    shutdown = true;
                    break;
                }
                None => {} // deadline tick: fall through to polling
            }
            msg = match rx.try_recv() {
                Ok(m) => Some(m),
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => Some(Msg::Shutdown),
            };
        }

        if shutdown {
            // flush everything, then exit — shutdown is a drain, not a shed
            for (key, state) in states.iter_mut() {
                while let Some(batch) = state.batcher.flush() {
                    gate.release(key, batch.requests.len());
                    run_batch(&runtime, &router, &metrics, key, batch, &mut state.replies);
                }
            }
            return;
        }

        let now = Instant::now();
        for (key, state) in states.iter_mut() {
            loop {
                let Dispatch { batch, shed } = state.batcher.poll(now);
                if !shed.is_empty() {
                    gate.release(key, shed.len());
                    shed_requests(&metrics, key, shed, &mut state.replies);
                }
                let Some(batch) = batch else { break };
                gate.release(key, batch.requests.len());
                let service =
                    run_batch(&runtime, &router, &metrics, key, batch, &mut state.replies);
                state.batcher.observe(service);
            }
        }
    }
}

/// Admit one request into its route's scheduler, creating the route state
/// on first touch; a typed admission rejection is answered immediately.
fn handle_request(
    states: &mut HashMap<(String, String), RouteState>,
    router: &Router,
    metrics: &Arc<Mutex<Metrics>>,
    gate: &Arc<Gate>,
    cfg: &ServeConfig,
    req: GenRequest,
    reply: Reply,
) {
    let key = (req.model.clone(), req.method.clone());
    let state = states.entry(key.clone()).or_insert_with(|| {
        let route = router.route(&key.0, &key.1).expect("validated");
        RouteState {
            batcher: RouteBatcher::new(cfg, route.bucket_sizes()),
            replies: HashMap::new(),
        }
    });
    let id = req.id;
    match state.batcher.admit(req, Instant::now()) {
        Ok(()) => {
            state.replies.insert(id, reply);
        }
        Err((req, rej)) => {
            gate.release(&key, 1);
            count_shed(metrics, &key, &rej);
            let _ = reply.send(Err(ServeError::Rejected(rej)));
            drop(req);
        }
    }
}

/// Answer dispatch-time sheds (expired deadlines) with their typed
/// verdicts and count them.
fn shed_requests(
    metrics: &Arc<Mutex<Metrics>>,
    key: &(String, String),
    shed: Vec<(GenRequest, Rejected)>,
    replies: &mut HashMap<RequestId, Reply>,
) {
    for (req, rej) in shed {
        count_shed(metrics, key, &rej);
        if let Some(reply) = replies.remove(&req.id) {
            let _ = reply.send(Err(ServeError::Rejected(rej)));
        }
    }
}

fn count_shed(metrics: &Arc<Mutex<Metrics>>, key: &(String, String), rej: &Rejected) {
    let mut m = metrics.lock().unwrap();
    let route = format!("{}/{}", key.0, key.1);
    match rej {
        Rejected::QueueFull { .. } => {
            m.shed_queue_full += 1;
            m.route_mut(&route).shed_queue_full += 1;
        }
        Rejected::DeadlineInfeasible { .. } => {
            m.shed_deadline += 1;
            m.route_mut(&route).shed_deadline += 1;
        }
    }
}

/// Execute one released batch and answer its requests; returns the batch
/// service time (for the scheduler's admission forecast).
fn run_batch<E: ExecBackend>(
    runtime: &E,
    router: &Router,
    metrics: &Arc<Mutex<Metrics>>,
    key: &(String, String),
    batch: ReadyBatch,
    replies: &mut HashMap<RequestId, Reply>,
) -> Duration {
    let route = router.route(&key.0, &key.1).expect("validated at submit");
    let artifact = match route.artifact_for_bucket(batch.bucket) {
        Some(a) => a,
        None => {
            fail_batch(&batch, replies, ServeError::UnknownModel(key.0.clone()));
            return Duration::ZERO;
        }
    };
    // pack: bucket x sample_len, zero-padded tail
    let sample_in = route.sample_input_len;
    let mut input = vec![0.0f32; batch.bucket * sample_in];
    for (i, r) in batch.requests.iter().enumerate() {
        input[i * sample_in..(i + 1) * sample_in].copy_from_slice(&r.input);
    }

    let t0 = Instant::now();
    let out = runtime.execute_artifact(artifact, &input);
    let exec_time = t0.elapsed();

    match out {
        Ok(out) => {
            let sample_out = route.sample_output_len;
            let route_key = format!("{}/{}", key.0, key.1);
            let mut m = metrics.lock().unwrap();
            m.batches += 1;
            m.batched_samples += batch.requests.len() as u64;
            m.padded_samples += batch.padding() as u64;
            m.exec_latency.record(exec_time);
            m.route_mut(&route_key).batches += 1;
            for (i, r) in batch.requests.iter().enumerate() {
                let queue_time = t0.duration_since(r.enqueued);
                let e2e = r.enqueued.elapsed();
                m.queue_latency.record(queue_time);
                m.e2e_latency.record(e2e);
                m.responses += 1;
                let rm = m.route_mut(&route_key);
                rm.completed += 1;
                rm.e2e.record(e2e);
                if let Some(reply) = replies.remove(&r.id) {
                    let _ = reply.send(Ok(GenResponse {
                        id: r.id,
                        output: out[i * sample_out..(i + 1) * sample_out].to_vec(),
                        batch_size: batch.bucket,
                        queue_time,
                        exec_time,
                    }));
                }
            }
        }
        Err(e) => fail_batch(&batch, replies, ServeError::Execution(e.to_string())),
    }
    exec_time
}

fn fail_batch(
    batch: &ReadyBatch,
    replies: &mut HashMap<RequestId, Reply>,
    err: ServeError,
) {
    for r in &batch.requests {
        if let Some(reply) = replies.remove(&r.id) {
            let _ = reply.send(Err(err.clone()));
        }
    }
}
