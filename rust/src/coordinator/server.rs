//! The serving coordinator: an engine thread that owns an execution
//! backend and drains per-route dynamic batchers; callers talk to it
//! through channels (`Coordinator::submit`). Python is never on this path.
//!
//! Shape:
//!   caller -> mpsc -> engine thread [ batcher -> pack -> execute backend
//!                                     -> unpack -> respond per-request ]
//!
//! Two backends implement the same [`ExecBackend`] contract:
//! * **PJRT** ([`Coordinator::start`]) — AOT artifacts compiled and
//!   executed via the `xla` runtime (gated off in offline builds);
//! * **native** ([`Coordinator::start_native`]) — whole generators run
//!   through precompiled [`crate::engine`] plans, no artifacts needed.
//!
//! The engine blocks on the request channel with a timeout equal to the
//! nearest batcher deadline, so partial batches ship on time without a
//! busy loop.
//!
//! On the native backend, compute threading is *not* per request: the
//! [`crate::engine::NativeRuntime`] built at startup owns one persistent
//! [`crate::engine::WorkerPool`] (sized by
//! [`NativeConfig::workers`](crate::engine::NativeConfig), default one
//! thread per core) that every route's engine dispatches to. A released
//! batch executes via the engine's two-level scheduler — wide buckets fan
//! out across samples, narrow ones across stripes inside each sample — so
//! the pool stays busy without the spawn-per-phase threading of PR 1.

use crate::coordinator::batcher::{BatchPolicy, DynamicBatcher, ReadyBatch};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::request::{GenRequest, GenResponse, RequestId, ServeError};
use crate::coordinator::router::Router;
use crate::engine::serve::{native_manifest, NativeConfig, NativeRuntime};
use crate::runtime::{Manifest, Runtime};
use anyhow::Result;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// What the engine thread needs from an execution backend: run one packed
/// batch buffer against a named route artifact.
pub trait ExecBackend {
    fn execute_artifact(&self, name: &str, input: &[f32]) -> std::result::Result<Vec<f32>, String>;
}

impl ExecBackend for Runtime {
    fn execute_artifact(&self, name: &str, input: &[f32]) -> std::result::Result<Vec<f32>, String> {
        self.execute(name, input).map_err(|e| format!("{e:#}"))
    }
}

impl ExecBackend for NativeRuntime {
    fn execute_artifact(&self, name: &str, input: &[f32]) -> std::result::Result<Vec<f32>, String> {
        self.execute(name, input)
    }
}

type Reply = Sender<Result<GenResponse, ServeError>>;

enum Msg {
    Request(GenRequest, Reply),
    Shutdown,
}

/// Handle to a running coordinator.
pub struct Coordinator {
    tx: Sender<Msg>,
    next_id: AtomicU64,
    metrics: Arc<Mutex<Metrics>>,
    router: Router,
    handle: Option<std::thread::JoinHandle<()>>,
}

/// Coordinator configuration.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// max time a request may wait for batch-mates
    pub max_wait: Duration,
    /// which artifacts to preload at startup (None = all generators)
    pub preload_models: Option<Vec<String>>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig { max_wait: Duration::from_millis(20), preload_models: None }
    }
}

impl Coordinator {
    /// Start the engine thread: compiles artifacts, then serves.
    pub fn start(manifest: Manifest, cfg: ServeConfig) -> Result<Coordinator> {
        let router = Router::from_manifest(&manifest);
        let metrics = Arc::new(Mutex::new(Metrics::new()));
        let (tx, rx) = mpsc::channel::<Msg>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<(), String>>();

        // The PJRT client is not Send, so the runtime lives entirely inside
        // the engine thread; artifacts are preloaded there before the
        // coordinator reports ready (first requests never pay compile time).
        let engine_router = router.clone();
        let engine_metrics = metrics.clone();
        let engine_cfg = cfg.clone();
        let handle = std::thread::Builder::new()
            .name("wingan-engine".into())
            .spawn(move || {
                let mut runtime = match Runtime::new() {
                    Ok(r) => r,
                    Err(e) => {
                        let _ = ready_tx.send(Err(e.to_string()));
                        return;
                    }
                };
                for e in manifest.entries.iter().filter(|e| e.kind == "generator") {
                    if let Some(models) = &engine_cfg.preload_models {
                        if !models.contains(&e.model) {
                            continue;
                        }
                    }
                    if let Err(e) = runtime.load(e) {
                        let _ = ready_tx.send(Err(e.to_string()));
                        return;
                    }
                }
                let _ = ready_tx.send(Ok(()));
                engine_loop(runtime, engine_router, engine_metrics, engine_cfg, rx)
            })
            .expect("spawn engine");
        ready_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("engine thread died during startup"))?
            .map_err(|e| anyhow::anyhow!("engine startup failed: {e}"))?;

        Ok(Coordinator {
            tx,
            next_id: AtomicU64::new(1),
            metrics,
            router,
            handle: Some(handle),
        })
    }

    /// Start the engine thread on the native execution backend: every
    /// route's [`crate::engine`] plan is compiled — and the one worker
    /// pool all routes share is spawned — before the coordinator reports
    /// ready, then generation requests batch and execute through the
    /// precompiled plans — no PJRT, no artifacts on disk, no thread
    /// spawns on the request path.
    ///
    /// `cfg.preload_models`, when set, restricts which zoo models get
    /// compiled (same semantics as the PJRT path); `native.workers` sizes
    /// the shared pool (0 = env/core default).
    pub fn start_native(mut native: NativeConfig, cfg: ServeConfig) -> Result<Coordinator> {
        if let Some(models) = &cfg.preload_models {
            native.models = Some(models.clone());
        }
        let manifest = native_manifest(&native);
        anyhow::ensure!(
            !manifest.entries.is_empty(),
            "native backend: no routes to serve (model filter {:?})",
            native.models
        );
        let router = Router::from_manifest(&manifest);
        let metrics = Arc::new(Mutex::new(Metrics::new()));
        let (tx, rx) = mpsc::channel::<Msg>();
        let (ready_tx, ready_rx) = mpsc::channel::<std::result::Result<(), String>>();

        let engine_router = router.clone();
        let engine_metrics = metrics.clone();
        let engine_cfg = cfg.clone();
        let handle = std::thread::Builder::new()
            .name("wingan-engine".into())
            .spawn(move || {
                // plan compilation happens here, once, before ready — the
                // request path only ever executes precompiled plans (or,
                // with `native.plan_store`, loads them from artifacts)
                let runtime = NativeRuntime::build(&native);
                // surface the warm-vs-cold startup accounting through the
                // serving metrics snapshot
                engine_metrics.lock().unwrap().plan_cache = runtime.plan_stats();
                let _ = ready_tx.send(Ok(()));
                engine_loop(runtime, engine_router, engine_metrics, engine_cfg, rx)
            })
            .expect("spawn engine");
        ready_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("engine thread died during startup"))?
            .map_err(|e| anyhow::anyhow!("engine startup failed: {e}"))?;

        Ok(Coordinator {
            tx,
            next_id: AtomicU64::new(1),
            metrics,
            router,
            handle: Some(handle),
        })
    }

    pub fn router(&self) -> &Router {
        &self.router
    }

    /// Submit a request; returns a receiver for the response.
    pub fn submit(
        &self,
        model: &str,
        method: &str,
        input: Vec<f32>,
    ) -> Result<Receiver<Result<GenResponse, ServeError>>, ServeError> {
        self.router.validate(model, method, input.len())?;
        let id: RequestId = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (reply_tx, reply_rx) = mpsc::channel();
        let req = GenRequest {
            id,
            model: model.to_string(),
            method: method.to_string(),
            input,
            enqueued: Instant::now(),
        };
        self.metrics.lock().unwrap().requests += 1;
        self.tx.send(Msg::Request(req, reply_tx)).map_err(|_| ServeError::EngineShutdown)?;
        Ok(reply_rx)
    }

    /// Submit and block for the result.
    pub fn generate(
        &self,
        model: &str,
        method: &str,
        input: Vec<f32>,
    ) -> Result<GenResponse, ServeError> {
        self.submit(model, method, input)?
            .recv()
            .map_err(|_| ServeError::EngineShutdown)?
    }

    pub fn metrics(&self) -> Metrics {
        self.metrics.lock().unwrap().clone()
    }

    /// Graceful shutdown: flushes pending batches first.
    pub fn shutdown(mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

struct RouteState {
    batcher: DynamicBatcher,
    replies: HashMap<RequestId, Reply>,
}

fn engine_loop<E: ExecBackend>(
    runtime: E,
    router: Router,
    metrics: Arc<Mutex<Metrics>>,
    cfg: ServeConfig,
    rx: Receiver<Msg>,
) {
    let mut states: HashMap<(String, String), RouteState> = HashMap::new();
    loop {
        // wait for work, but never past the nearest batch deadline
        let deadline = states
            .values()
            .filter_map(|s| s.batcher.next_deadline())
            .min();
        let msg = match deadline {
            Some(d) => {
                let timeout = d.saturating_duration_since(Instant::now());
                match rx.recv_timeout(timeout) {
                    Ok(m) => Some(m),
                    Err(RecvTimeoutError::Timeout) => None,
                    Err(RecvTimeoutError::Disconnected) => Some(Msg::Shutdown),
                }
            }
            None => match rx.recv() {
                Ok(m) => Some(m),
                Err(_) => Some(Msg::Shutdown),
            },
        };

        match msg {
            Some(Msg::Request(req, reply)) => {
                let key = (req.model.clone(), req.method.clone());
                let state = states.entry(key.clone()).or_insert_with(|| {
                    let route = router.route(&key.0, &key.1).expect("validated");
                    RouteState {
                        batcher: DynamicBatcher::new(BatchPolicy::new(
                            route.bucket_sizes(),
                            cfg.max_wait,
                        )),
                        replies: HashMap::new(),
                    }
                });
                state.replies.insert(req.id, reply);
                state.batcher.push(req);
            }
            Some(Msg::Shutdown) => {
                // flush everything, then exit
                for (key, state) in states.iter_mut() {
                    while let Some(batch) = state.batcher.flush() {
                        run_batch(&runtime, &router, &metrics, key, batch, &mut state.replies);
                    }
                }
                return;
            }
            None => {} // deadline tick: fall through to polling
        }

        let now = Instant::now();
        for (key, state) in states.iter_mut() {
            while let Some(batch) = state.batcher.poll(now) {
                run_batch(&runtime, &router, &metrics, key, batch, &mut state.replies);
            }
        }
    }
}

fn run_batch<E: ExecBackend>(
    runtime: &E,
    router: &Router,
    metrics: &Arc<Mutex<Metrics>>,
    key: &(String, String),
    batch: ReadyBatch,
    replies: &mut HashMap<RequestId, Reply>,
) {
    let route = router.route(&key.0, &key.1).expect("validated at submit");
    let artifact = match route.artifact_for_bucket(batch.bucket) {
        Some(a) => a,
        None => {
            fail_batch(&batch, replies, ServeError::UnknownModel(key.0.clone()));
            return;
        }
    };
    // pack: bucket x sample_len, zero-padded tail
    let sample_in = route.sample_input_len;
    let mut input = vec![0.0f32; batch.bucket * sample_in];
    for (i, r) in batch.requests.iter().enumerate() {
        input[i * sample_in..(i + 1) * sample_in].copy_from_slice(&r.input);
    }

    let t0 = Instant::now();
    let out = runtime.execute_artifact(artifact, &input);
    let exec_time = t0.elapsed();

    match out {
        Ok(out) => {
            let sample_out = route.sample_output_len;
            let mut m = metrics.lock().unwrap();
            m.batches += 1;
            m.batched_samples += batch.requests.len() as u64;
            m.padded_samples += batch.padding() as u64;
            m.exec_latency.record(exec_time);
            for (i, r) in batch.requests.iter().enumerate() {
                let queue_time = t0.duration_since(r.enqueued);
                m.queue_latency.record(queue_time);
                m.e2e_latency.record(r.enqueued.elapsed());
                m.responses += 1;
                if let Some(reply) = replies.remove(&r.id) {
                    let _ = reply.send(Ok(GenResponse {
                        id: r.id,
                        output: out[i * sample_out..(i + 1) * sample_out].to_vec(),
                        batch_size: batch.bucket,
                        queue_time,
                        exec_time,
                    }));
                }
            }
        }
        Err(e) => fail_batch(&batch, replies, ServeError::Execution(e.to_string())),
    }
}

fn fail_batch(
    batch: &ReadyBatch,
    replies: &mut HashMap<RequestId, Reply>,
    err: ServeError,
) {
    for r in &batch.requests {
        if let Some(reply) = replies.remove(&r.id) {
            let _ = reply.send(Err(err.clone()));
        }
    }
}
