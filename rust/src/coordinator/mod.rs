//! L3 coordinator: the serving layer — request router, dynamic batcher
//! packing into batch buckets, a single-owner engine thread over a
//! pluggable execution backend (native precompiled-plan engine or PJRT),
//! and serving metrics (vLLM-router-style architecture scaled to this
//! system).

pub mod batcher;
pub mod metrics;
pub mod request;
pub mod router;
pub mod server;

pub use batcher::{BatchPolicy, DynamicBatcher};
pub use metrics::Metrics;
pub use request::{GenRequest, GenResponse, ServeError};
pub use router::Router;
pub use server::{Coordinator, ExecBackend, ServeConfig};
