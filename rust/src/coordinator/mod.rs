//! L3 coordinator: the serving layer (vLLM-router-style architecture
//! scaled to this system).
//!
//! A generation request travels:
//!
//! 1. [`Router`] ([`router`]) — validates `(model, method)` against the
//!    routes the artifact manifest advertises and checks sample shapes;
//! 2. [`DynamicBatcher`] ([`batcher`]) — per-route FIFO that packs
//!    requests into the advertised batch buckets, shipping a batch when
//!    the largest bucket fills or the oldest request has waited
//!    `max_wait`;
//! 3. [`Coordinator`] ([`server`]) — the single-owner engine thread that
//!    drains batchers into a pluggable [`ExecBackend`]: the native
//!    precompiled-plan engine ([`crate::engine::NativeRuntime`], whose
//!    routes all share one persistent worker pool) or PJRT
//!    ([`crate::runtime::Runtime`], gated off in offline builds);
//! 4. [`Metrics`] ([`metrics`]) — queue/exec/e2e latency histograms,
//!    batch-efficiency counters, and a one-line serving report.
//!
//! Requests and replies cross threads over channels ([`request`] defines
//! the wire types); python is never on this path.

pub mod batcher;
pub mod metrics;
pub mod request;
pub mod router;
pub mod server;

pub use batcher::{BatchPolicy, DynamicBatcher};
pub use metrics::Metrics;
pub use request::{GenRequest, GenResponse, ServeError};
pub use router::Router;
pub use server::{Coordinator, ExecBackend, ServeConfig};
