//! L3 coordinator: the serving layer (vLLM-router-style architecture
//! scaled to this system).
//!
//! A generation request travels:
//!
//! 1. [`Router`] ([`router`]) — validates `(model, method)` against the
//!    routes the artifact manifest advertises and checks sample shapes;
//! 2. a bounded **admission gate** ([`server`]) — per-route slot counter
//!    ([`ServeConfig::queue_cap`]); at capacity the submit sheds with a
//!    typed [`Rejected::QueueFull`] instead of queuing unboundedly;
//! 3. a batch scheduler ([`batcher`]), per route, selected by
//!    [`SchedulerKind`]: the production [`ContinuousBatcher`]
//!    (work-conserving continuous batching — arrivals join the forming
//!    batch up to the pool width — with SLO-aware admission and typed
//!    deadline sheds) or the PR-6 [`DynamicBatcher`] baseline (bucket
//!    fill or `max_wait` release), kept for A/B measurement;
//! 4. [`Coordinator`] ([`server`]) — the single-owner engine thread that
//!    drains schedulers into a pluggable [`ExecBackend`]: the native
//!    precompiled-plan engine ([`crate::engine::NativeRuntime`], whose
//!    routes all share one persistent worker pool) or PJRT
//!    ([`crate::runtime::Runtime`], gated off in offline builds);
//! 5. [`Metrics`] ([`metrics`]) — queue/exec/e2e latency histograms with
//!    p50/p99/p999, shed counters, per-route depth/latency counters
//!    ([`RouteMetrics`]), and a one-line serving report.
//!
//! The native path is **fault-isolated**: batch execution runs under
//! panic containment (a poisoned batch is bisected so only the poison
//! request fails, typed [`ServeError::Crashed`]), each route's engine
//! thread is owned by a supervisor ([`supervise`]) that restarts dead
//! incarnations with capped exponential backoff, detects panic storms and
//! stuck batches, and trips a per-route circuit breaker (typed
//! [`Rejected::Unhealthy`] sheds) when a route keeps dying —
//! [`Coordinator::health`] reports the verdict per route.
//!
//! Requests and replies cross threads over channels ([`request`] defines
//! the wire types); python is never on this path.

pub mod batcher;
pub mod metrics;
pub mod request;
pub mod router;
pub mod server;
pub mod supervise;

pub use batcher::{BatchPolicy, ContinuousBatcher, Dispatch, DynamicBatcher, ReadyBatch};
pub use metrics::{Histogram, Metrics, RouteMetrics};
pub use request::{GenRequest, GenResponse, Rejected, ServeError};
pub use router::Router;
pub use server::{Coordinator, ExecBackend, SchedulerKind, ServeConfig};
pub use supervise::{
    HealthReport, RouteHealth, RouteHealthSnapshot, RoutePolicy, SupervisorConfig,
};
