//! Request router: validates incoming requests against the artifact
//! manifest and resolves (model, method, batch-bucket) to a concrete
//! compiled executable name.

use crate::coordinator::request::ServeError;
use crate::runtime::Manifest;
use std::collections::BTreeMap;

/// Routing entry for one (model, method) pair.
#[derive(Clone, Debug)]
pub struct Route {
    pub model: String,
    pub method: String,
    pub sample_input_len: usize,
    pub sample_output_len: usize,
    /// bucket size -> artifact name, ascending bucket order
    pub buckets: BTreeMap<usize, String>,
}

impl Route {
    pub fn bucket_sizes(&self) -> Vec<usize> {
        self.buckets.keys().copied().collect()
    }

    pub fn artifact_for_bucket(&self, bucket: usize) -> Option<&str> {
        self.buckets.get(&bucket).map(String::as_str)
    }
}

/// The router table, built once from the manifest.
#[derive(Clone, Debug, Default)]
pub struct Router {
    routes: BTreeMap<(String, String), Route>,
}

impl Router {
    pub fn from_manifest(m: &Manifest) -> Router {
        let mut routes: BTreeMap<(String, String), Route> = BTreeMap::new();
        for e in m.entries.iter().filter(|e| e.kind == "generator") {
            let key = (e.model.clone(), e.method.clone());
            let route = routes.entry(key).or_insert_with(|| Route {
                model: e.model.clone(),
                method: e.method.clone(),
                sample_input_len: e.sample_input_len(),
                sample_output_len: e.sample_output_len(),
                buckets: BTreeMap::new(),
            });
            route.buckets.insert(e.batch, e.name.clone());
        }
        Router { routes }
    }

    pub fn route(&self, model: &str, method: &str) -> Result<&Route, ServeError> {
        self.routes
            .get(&(model.to_string(), method.to_string()))
            .ok_or_else(|| ServeError::UnknownModel(format!("{model}/{method}")))
    }

    /// Validate a request payload; returns its route.
    pub fn validate(
        &self,
        model: &str,
        method: &str,
        input_len: usize,
    ) -> Result<&Route, ServeError> {
        let r = self.route(model, method)?;
        if input_len != r.sample_input_len {
            return Err(ServeError::BadInputLength {
                expected: r.sample_input_len,
                got: input_len,
            });
        }
        Ok(r)
    }

    pub fn models(&self) -> Vec<(String, String)> {
        self.routes.keys().cloned().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::ArtifactEntry;
    use std::path::PathBuf;

    fn manifest() -> Manifest {
        let entry = |name: &str, model: &str, method: &str, batch: usize| ArtifactEntry {
            name: name.into(),
            kind: "generator".into(),
            model: model.into(),
            method: method.into(),
            batch,
            hlo: PathBuf::new(),
            input_shape: vec![batch, 32],
            output_shape: vec![batch, 3, 8, 8],
            golden_input: PathBuf::new(),
            golden_output: PathBuf::new(),
        };
        Manifest {
            dir: PathBuf::new(),
            scale: "small".into(),
            entries: vec![
                entry("dcgan_b1", "dcgan", "winograd", 1),
                entry("dcgan_b8", "dcgan", "winograd", 8),
                entry("dcgan_b4", "dcgan", "winograd", 4),
                entry("dcgan_tdc_b1", "dcgan", "tdc", 1),
            ],
        }
    }

    #[test]
    fn builds_routes_with_sorted_buckets() {
        let r = Router::from_manifest(&manifest());
        let route = r.route("dcgan", "winograd").unwrap();
        assert_eq!(route.bucket_sizes(), vec![1, 4, 8]);
        assert_eq!(route.artifact_for_bucket(4), Some("dcgan_b4"));
        assert_eq!(route.sample_input_len, 32);
        assert_eq!(route.sample_output_len, 192);
    }

    #[test]
    fn unknown_model_rejected() {
        let r = Router::from_manifest(&manifest());
        assert!(matches!(
            r.route("nope", "winograd"),
            Err(ServeError::UnknownModel(_))
        ));
    }

    #[test]
    fn validates_input_length() {
        let r = Router::from_manifest(&manifest());
        assert!(r.validate("dcgan", "winograd", 32).is_ok());
        assert!(matches!(
            r.validate("dcgan", "winograd", 31),
            Err(ServeError::BadInputLength { expected: 32, got: 31 })
        ));
    }
}
