//! Serving metrics: counters + latency histogram with percentile queries.
//! No external deps; a fixed log-bucketed histogram keeps memory bounded
//! regardless of request count, plus exact min/max/mean. Since PR 7 the
//! snapshot also carries **per-route** counters ([`RouteMetrics`]): queue
//! depth (gauge + high-water mark), admission/shed totals, and a
//! per-route e2e latency histogram with p50/p99/p999.

use crate::artifact::PlanCacheStats;
use crate::util::json::{self, Json};
use std::collections::BTreeMap;
use std::time::Duration;

/// Log-bucketed latency histogram: buckets of 10% growth from 1 µs to ~100 s.
#[derive(Clone, Debug)]
pub struct Histogram {
    buckets: Vec<u64>,
    bounds: Vec<f64>,
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        let mut bounds = Vec::new();
        let mut b = 1e-6;
        while b < 100.0 {
            bounds.push(b);
            b *= 1.1;
        }
        Histogram {
            buckets: vec![0; bounds.len() + 1],
            bounds,
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: 0.0,
        }
    }

    pub fn record(&mut self, d: Duration) {
        let s = d.as_secs_f64();
        let idx = self.bounds.partition_point(|&b| b < s);
        self.buckets[idx] += 1;
        self.count += 1;
        self.sum += s;
        self.min = self.min.min(s);
        self.max = self.max.max(s);
    }

    /// Fold `other` into `self` bucket-by-bucket. Both histograms share
    /// the same construction-time bucket bounds (1 µs, 10% growth), so the
    /// merge is an element-wise add that preserves every percentile query
    /// a scrape would have seen on the union of the two recorders — this
    /// is how per-worker and per-replica stage histograms aggregate into
    /// fleet rollups without shipping raw samples.
    ///
    /// The exact-tail property survives the merge: the top bucket's
    /// percentile still reports the exact observed maximum (now the max of
    /// both sides), not a bucket bound.
    pub fn merge(&mut self, other: &Histogram) {
        debug_assert_eq!(self.buckets.len(), other.buckets.len());
        for (b, o) in self.buckets.iter_mut().zip(&other.buckets) {
            *b += o;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Percentile (0..=100) as seconds; upper bucket bound (conservative).
    pub fn percentile(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = ((p / 100.0) * self.count as f64).ceil() as u64;
        let mut seen = 0;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target.max(1) {
                return if i < self.bounds.len() { self.bounds[i] } else { self.max };
            }
        }
        self.max
    }

    /// The serving-SLO tail triple — p50/p99/p999 in seconds — read as one
    /// tuple so report lines and the loadgen harness can never disagree on
    /// which percentiles "the tail" means.
    pub fn tail(&self) -> (f64, f64, f64) {
        (self.percentile(50.0), self.percentile(99.0), self.percentile(99.9))
    }

    /// Machine-readable snapshot: count, mean and the tail percentiles,
    /// all in **milliseconds** (the unit every report line prints).
    pub fn to_json(&self) -> Json {
        let (p50, p99, p999) = self.tail();
        json::obj(vec![
            ("count", json::num(self.count as f64)),
            ("mean_ms", json::num(self.mean() * 1e3)),
            ("p50_ms", json::num(p50 * 1e3)),
            ("p95_ms", json::num(self.percentile(95.0) * 1e3)),
            ("p99_ms", json::num(p99 * 1e3)),
            ("p999_ms", json::num(p999 * 1e3)),
            ("max_ms", json::num(if self.count > 0 { self.max * 1e3 } else { 0.0 })),
        ])
    }

    pub fn summary(&self, label: &str) -> String {
        let (p50, p99, p999) = self.tail();
        format!(
            "{label}: n={} mean={:.3}ms p50={:.3}ms p95={:.3}ms p99={:.3}ms p999={:.3}ms max={:.3}ms",
            self.count,
            self.mean() * 1e3,
            p50 * 1e3,
            self.percentile(95.0) * 1e3,
            p99 * 1e3,
            p999 * 1e3,
            if self.count > 0 { self.max * 1e3 } else { 0.0 },
        )
    }
}

/// Per-route serving counters: admission and shed totals, queue depth
/// (instantaneous + high-water mark, folded in from the admission gate at
/// snapshot time), dispatch counts, and the route's own e2e latency
/// histogram.
#[derive(Clone, Debug, Default)]
pub struct RouteMetrics {
    /// requests admitted past the gate (submitted and queued)
    pub admitted: u64,
    /// requests answered with an output
    pub completed: u64,
    /// typed sheds: admission gate at capacity
    pub shed_queue_full: u64,
    /// typed sheds: deadline infeasible at admission or expired in queue
    pub shed_deadline: u64,
    /// typed sheds: route circuit breaker open (engine restart storm)
    pub shed_unhealthy: u64,
    /// engine panics contained at this route's batch boundary
    pub panics_contained: u64,
    /// requests failed with [`crate::coordinator::ServeError::Crashed`]
    /// after bisection blamed them for a contained panic
    pub requests_quarantined: u64,
    /// sub-batch retries performed while bisecting a crashed batch
    pub bisection_retries: u64,
    /// batches dispatched for this route
    pub batches: u64,
    /// queued-but-undispatched requests right now (gauge)
    pub depth: usize,
    /// high-water mark of `depth` over the coordinator's lifetime
    pub peak_depth: usize,
    /// end-to-end latency (submit → response) for this route's completions
    pub e2e: Histogram,
}

impl RouteMetrics {
    /// Machine-readable snapshot of this route's counters; consumed by
    /// the fleet health endpoint and CI smoke checks, so the key set is a
    /// stable surface — add keys freely, never rename or remove.
    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("admitted", json::num(self.admitted as f64)),
            ("completed", json::num(self.completed as f64)),
            ("shed_queue_full", json::num(self.shed_queue_full as f64)),
            ("shed_deadline", json::num(self.shed_deadline as f64)),
            ("shed_unhealthy", json::num(self.shed_unhealthy as f64)),
            ("panics_contained", json::num(self.panics_contained as f64)),
            ("requests_quarantined", json::num(self.requests_quarantined as f64)),
            ("bisection_retries", json::num(self.bisection_retries as f64)),
            ("batches", json::num(self.batches as f64)),
            ("depth", json::num(self.depth as f64)),
            ("peak_depth", json::num(self.peak_depth as f64)),
            ("e2e", self.e2e.to_json()),
        ])
    }

    /// One compact report line for this route.
    pub fn summary(&self, route: &str) -> String {
        let (p50, p99, p999) = self.e2e.tail();
        let faults = if self.panics_contained + self.requests_quarantined + self.shed_unhealthy > 0
        {
            format!(
                " panics={} quarantined={} bisections={} shed_unhealthy={}",
                self.panics_contained,
                self.requests_quarantined,
                self.bisection_retries,
                self.shed_unhealthy,
            )
        } else {
            String::new()
        };
        format!(
            "route {route}: depth={} peak={} admitted={} completed={} \
             shed_full={} shed_slo={} batches={} p50={:.3}ms p99={:.3}ms p999={:.3}ms{faults}",
            self.depth,
            self.peak_depth,
            self.admitted,
            self.completed,
            self.shed_queue_full,
            self.shed_deadline,
            self.batches,
            p50 * 1e3,
            p99 * 1e3,
            p999 * 1e3,
        )
    }
}

/// Aggregated serving metrics.
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    pub requests: u64,
    pub responses: u64,
    pub batches: u64,
    pub batched_samples: u64,
    pub padded_samples: u64,
    /// total typed sheds at the admission gate (queue at capacity)
    pub shed_queue_full: u64,
    /// total typed sheds for deadline infeasibility (at admission or
    /// expired while queued)
    pub shed_deadline: u64,
    /// total typed sheds because a route's circuit breaker was open
    pub shed_unhealthy: u64,
    /// engine panics contained at the batch boundary (total)
    pub panics_contained: u64,
    /// requests failed with a typed `Crashed` after bisection blamed them
    pub requests_quarantined: u64,
    /// sub-batch retries performed while bisecting crashed batches
    pub bisection_retries: u64,
    /// requests still queued when the shutdown drain deadline expired;
    /// each was answered with a typed `EngineShutdown`, not silence
    pub abandoned_at_shutdown: u64,
    /// plan-cache counters from startup (warm-vs-cold: artifact hits,
    /// fallback compiles, load failures, republishes); all zeros when the
    /// server was built without a plan store
    pub plan_cache: PlanCacheStats,
    pub queue_latency: Histogram,
    pub exec_latency: Histogram,
    pub e2e_latency: Histogram,
    /// per-route counters keyed "model/method"
    pub routes: BTreeMap<String, RouteMetrics>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics { queue_latency: Histogram::new(), exec_latency: Histogram::new(), e2e_latency: Histogram::new(), ..Default::default() }
    }

    /// The per-route counters for `route` ("model/method"), created on
    /// first touch.
    pub fn route_mut(&mut self, route: &str) -> &mut RouteMetrics {
        self.routes.entry(route.to_string()).or_default()
    }

    /// Total typed sheds across all causes.
    pub fn shed_total(&self) -> u64 {
        self.shed_queue_full + self.shed_deadline + self.shed_unhealthy
    }

    /// Mean occupancy of executed batch slots (1.0 = no padding waste).
    pub fn batch_efficiency(&self) -> f64 {
        let total = self.batched_samples + self.padded_samples;
        if total == 0 {
            1.0
        } else {
            self.batched_samples as f64 / total as f64
        }
    }

    /// True when any route went through a plan store at startup (the
    /// counters are all zero when serving without one).
    pub fn used_plan_store(&self) -> bool {
        self.plan_cache != PlanCacheStats::default()
    }

    /// The whole snapshot as stable machine-readable JSON (the fleet
    /// health endpoint and CI smoke both parse this — same stability
    /// contract as [`RouteMetrics::to_json`]).
    pub fn to_json(&self) -> Json {
        let routes: BTreeMap<String, Json> =
            self.routes.iter().map(|(name, r)| (name.clone(), r.to_json())).collect();
        json::obj(vec![
            ("requests", json::num(self.requests as f64)),
            ("responses", json::num(self.responses as f64)),
            ("batches", json::num(self.batches as f64)),
            ("batch_efficiency", json::num(self.batch_efficiency())),
            ("shed_queue_full", json::num(self.shed_queue_full as f64)),
            ("shed_deadline", json::num(self.shed_deadline as f64)),
            ("shed_unhealthy", json::num(self.shed_unhealthy as f64)),
            ("shed_total", json::num(self.shed_total() as f64)),
            ("panics_contained", json::num(self.panics_contained as f64)),
            ("requests_quarantined", json::num(self.requests_quarantined as f64)),
            ("bisection_retries", json::num(self.bisection_retries as f64)),
            ("abandoned_at_shutdown", json::num(self.abandoned_at_shutdown as f64)),
            (
                "plan_cache",
                json::obj(vec![
                    ("artifact_hits", json::num(self.plan_cache.artifact_hits as f64)),
                    ("fallback_compiles", json::num(self.plan_cache.fallback_compiles as f64)),
                    ("load_failures", json::num(self.plan_cache.load_failures as f64)),
                    ("published", json::num(self.plan_cache.published as f64)),
                ]),
            ),
            ("queue_latency", self.queue_latency.to_json()),
            ("exec_latency", self.exec_latency.to_json()),
            ("e2e_latency", self.e2e_latency.to_json()),
            ("routes", Json::Obj(routes)),
        ])
    }

    pub fn report(&self) -> String {
        let plans = if self.used_plan_store() {
            format!(
                "\nplans: artifact_hits={} fallback_compiles={} load_failures={} published={}",
                self.plan_cache.artifact_hits,
                self.plan_cache.fallback_compiles,
                self.plan_cache.load_failures,
                self.plan_cache.published,
            )
        } else {
            String::new()
        };
        let faults = if self.panics_contained
            + self.requests_quarantined
            + self.shed_unhealthy
            + self.abandoned_at_shutdown
            > 0
        {
            format!(
                "\nfaults: panics_contained={} requests_quarantined={} bisection_retries={} \
                 shed_unhealthy={} abandoned_at_shutdown={}",
                self.panics_contained,
                self.requests_quarantined,
                self.bisection_retries,
                self.shed_unhealthy,
                self.abandoned_at_shutdown,
            )
        } else {
            String::new()
        };
        let routes: String = self
            .routes
            .iter()
            .map(|(name, r)| format!("\n{}", r.summary(name)))
            .collect();
        format!(
            "requests={} responses={} batches={} batch_eff={:.2} shed_full={} shed_slo={}{plans}{faults}\n{}\n{}\n{}{routes}",
            self.requests,
            self.responses,
            self.batches,
            self.batch_efficiency(),
            self.shed_queue_full,
            self.shed_deadline,
            self.queue_latency.summary("queue"),
            self.exec_latency.summary("exec "),
            self.e2e_latency.summary("e2e  "),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_ordered() {
        let mut h = Histogram::new();
        for ms in 1..=100u64 {
            h.record(Duration::from_millis(ms));
        }
        assert_eq!(h.count(), 100);
        let (p50, p99, p999) = h.tail();
        let p95 = h.percentile(95.0);
        assert!(p50 <= p95 && p95 <= p99 && p99 <= p999);
        // log buckets have 10% resolution
        assert!((p50 - 0.050).abs() / 0.050 < 0.15, "p50={p50}");
        assert!((p95 - 0.095).abs() / 0.095 < 0.15, "p95={p95}");
    }

    #[test]
    fn percentiles_exact_on_known_inputs() {
        // pin the percentile arithmetic exactly: record counts directly at
        // known magnitudes and assert the returned bucket bounds. 1ms and
        // 100ms land in distinct log buckets whose bounds bracket the
        // recorded value within the 10% growth factor.
        let mut h = Histogram::new();
        for _ in 0..99 {
            h.record(Duration::from_millis(1));
        }
        h.record(Duration::from_millis(100));
        let p50 = h.percentile(50.0);
        let p99 = h.percentile(99.0);
        let p999 = h.percentile(99.9);
        // 99 of 100 samples are 1ms: p50 and p99 must report the same
        // bucket bound, and it must bracket 1ms to one bucket's growth
        assert_eq!(p50, p99, "p50 and p99 sit in the same bucket");
        assert!(p50 >= 0.001 && p50 < 0.001 * 1.1 * 1.1, "p50={p50}");
        // the single 100ms outlier is exactly the p999 sample
        assert!(p999 >= 0.100 && p999 < 0.100 * 1.1 * 1.1, "p999={p999}");
        // deterministic: querying again returns bit-identical values
        assert_eq!(h.percentile(99.9), p999);
        // and the extremes are exact, not bucketed
        assert_eq!(h.count(), 100);
        assert!((h.mean() - (99.0 * 0.001 + 0.100) / 100.0).abs() < 1e-12);
    }

    #[test]
    fn empty_histogram_safe() {
        let h = Histogram::new();
        assert_eq!(h.percentile(99.0), 0.0);
        assert_eq!(h.percentile(99.9), 0.0);
        assert_eq!(h.mean(), 0.0);
    }

    #[test]
    fn batch_efficiency() {
        let mut m = Metrics::new();
        m.batched_samples = 6;
        m.padded_samples = 2;
        assert!((m.batch_efficiency() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn plan_cache_counters_surface_in_the_report() {
        let mut m = Metrics::new();
        assert!(!m.used_plan_store());
        assert!(!m.report().contains("plans:"));
        m.plan_cache.artifact_hits = 3;
        m.plan_cache.fallback_compiles = 1;
        m.plan_cache.published = 1;
        assert!(m.used_plan_store());
        let r = m.report();
        assert!(r.contains("artifact_hits=3"), "{r}");
        assert!(r.contains("fallback_compiles=1"), "{r}");
        assert!(r.contains("load_failures=0"), "{r}");
        assert!(r.contains("published=1"), "{r}");
    }

    #[test]
    fn route_counters_surface_in_the_report() {
        let mut m = Metrics::new();
        {
            let r = m.route_mut("dcgan/winograd");
            r.admitted = 10;
            r.completed = 8;
            r.shed_queue_full = 1;
            r.shed_deadline = 1;
            r.peak_depth = 5;
            r.e2e.record(Duration::from_millis(3));
        }
        m.shed_queue_full = 1;
        m.shed_deadline = 1;
        assert_eq!(m.shed_total(), 2);
        let rep = m.report();
        assert!(rep.contains("route dcgan/winograd:"), "{rep}");
        assert!(rep.contains("peak=5"), "{rep}");
        assert!(rep.contains("shed_full=1 shed_slo=1"), "{rep}");
        assert!(rep.contains("p999="), "{rep}");
    }

    #[test]
    fn fault_counters_surface_only_when_nonzero() {
        let mut m = Metrics::new();
        assert!(!m.report().contains("faults:"), "quiet when nothing ever crashed");
        m.panics_contained = 2;
        m.requests_quarantined = 1;
        m.bisection_retries = 2;
        m.shed_unhealthy = 3;
        m.abandoned_at_shutdown = 1;
        {
            let r = m.route_mut("dcgan/winograd");
            r.panics_contained = 2;
            r.requests_quarantined = 1;
            r.bisection_retries = 2;
            r.shed_unhealthy = 3;
        }
        assert_eq!(m.shed_total(), 3);
        let rep = m.report();
        assert!(rep.contains("panics_contained=2"), "{rep}");
        assert!(rep.contains("requests_quarantined=1"), "{rep}");
        assert!(rep.contains("bisection_retries=2"), "{rep}");
        assert!(rep.contains("shed_unhealthy=3"), "{rep}");
        assert!(rep.contains("abandoned_at_shutdown=1"), "{rep}");
        assert!(rep.contains("panics=2 quarantined=1 bisections=2"), "{rep}");
    }

    #[test]
    fn merge_is_elementwise_and_preserves_counts() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        for ms in 1..=50u64 {
            a.record(Duration::from_millis(ms));
        }
        for ms in 51..=100u64 {
            b.record(Duration::from_millis(ms));
        }
        // reference: everything recorded into one histogram
        let mut whole = Histogram::new();
        for ms in 1..=100u64 {
            whole.record(Duration::from_millis(ms));
        }
        a.merge(&b);
        assert_eq!(a.count(), 100);
        assert_eq!(a.buckets, whole.buckets, "merge must be element-wise bucket addition");
        assert!((a.mean() - whole.mean()).abs() < 1e-12);
        // every percentile query agrees with the single-recorder reference
        for p in [50.0, 95.0, 99.0, 99.9] {
            assert_eq!(a.percentile(p), whole.percentile(p), "p{p} diverged under merge");
        }
    }

    #[test]
    fn merge_preserves_the_exact_tail() {
        // the top-bucket percentile reports the exact observed max, not a
        // bucket bound — that exactness must survive a merge in both
        // directions (max on the left, max on the right).
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        a.record(Duration::from_millis(1));
        b.record(Duration::from_secs(200)); // beyond the last bound -> overflow bucket
        a.merge(&b);
        assert_eq!(a.percentile(100.0), 200.0, "overflow-bucket tail must stay exact");
        let mut c = Histogram::new();
        let mut d = Histogram::new();
        c.record(Duration::from_secs(300));
        d.record(Duration::from_millis(1));
        c.merge(&d);
        assert_eq!(c.percentile(100.0), 300.0);
        // min/max fold across the merge too
        assert!((c.mean() - (300.0 + 0.001) / 2.0).abs() < 1e-9);
    }

    #[test]
    fn merge_with_empty_is_identity() {
        let mut a = Histogram::new();
        for ms in [2u64, 4, 8] {
            a.record(Duration::from_millis(ms));
        }
        let before = (a.count(), a.mean(), a.tail());
        a.merge(&Histogram::new());
        assert_eq!((a.count(), a.mean(), a.tail()), before);
        let mut empty = Histogram::new();
        empty.merge(&a);
        assert_eq!(empty.count(), a.count());
        assert_eq!(empty.tail(), a.tail());
    }

    #[test]
    fn mean_tracks_sum() {
        let mut h = Histogram::new();
        h.record(Duration::from_millis(10));
        h.record(Duration::from_millis(30));
        assert!((h.mean() - 0.020).abs() < 1e-9);
    }
}
