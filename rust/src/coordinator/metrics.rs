//! Serving metrics: counters + latency histogram with percentile queries.
//! No external deps; a fixed log-bucketed histogram keeps memory bounded
//! regardless of request count, plus exact min/max/mean.

use crate::artifact::PlanCacheStats;
use std::time::Duration;

/// Log-bucketed latency histogram: buckets of 10% growth from 1 µs to ~100 s.
#[derive(Clone, Debug)]
pub struct Histogram {
    buckets: Vec<u64>,
    bounds: Vec<f64>,
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        let mut bounds = Vec::new();
        let mut b = 1e-6;
        while b < 100.0 {
            bounds.push(b);
            b *= 1.1;
        }
        Histogram {
            buckets: vec![0; bounds.len() + 1],
            bounds,
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: 0.0,
        }
    }

    pub fn record(&mut self, d: Duration) {
        let s = d.as_secs_f64();
        let idx = self.bounds.partition_point(|&b| b < s);
        self.buckets[idx] += 1;
        self.count += 1;
        self.sum += s;
        self.min = self.min.min(s);
        self.max = self.max.max(s);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Percentile (0..=100) as seconds; upper bucket bound (conservative).
    pub fn percentile(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = ((p / 100.0) * self.count as f64).ceil() as u64;
        let mut seen = 0;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target.max(1) {
                return if i < self.bounds.len() { self.bounds[i] } else { self.max };
            }
        }
        self.max
    }

    pub fn summary(&self, label: &str) -> String {
        format!(
            "{label}: n={} mean={:.3}ms p50={:.3}ms p95={:.3}ms p99={:.3}ms max={:.3}ms",
            self.count,
            self.mean() * 1e3,
            self.percentile(50.0) * 1e3,
            self.percentile(95.0) * 1e3,
            self.percentile(99.0) * 1e3,
            if self.count > 0 { self.max * 1e3 } else { 0.0 },
        )
    }
}

/// Aggregated serving metrics.
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    pub requests: u64,
    pub responses: u64,
    pub batches: u64,
    pub batched_samples: u64,
    pub padded_samples: u64,
    /// plan-cache counters from startup (warm-vs-cold: artifact hits,
    /// fallback compiles, load failures, republishes); all zeros when the
    /// server was built without a plan store
    pub plan_cache: PlanCacheStats,
    pub queue_latency: Histogram,
    pub exec_latency: Histogram,
    pub e2e_latency: Histogram,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics { queue_latency: Histogram::new(), exec_latency: Histogram::new(), e2e_latency: Histogram::new(), ..Default::default() }
    }

    /// Mean occupancy of executed batch slots (1.0 = no padding waste).
    pub fn batch_efficiency(&self) -> f64 {
        let total = self.batched_samples + self.padded_samples;
        if total == 0 {
            1.0
        } else {
            self.batched_samples as f64 / total as f64
        }
    }

    /// True when any route went through a plan store at startup (the
    /// counters are all zero when serving without one).
    pub fn used_plan_store(&self) -> bool {
        self.plan_cache != PlanCacheStats::default()
    }

    pub fn report(&self) -> String {
        let plans = if self.used_plan_store() {
            format!(
                "\nplans: artifact_hits={} fallback_compiles={} load_failures={} published={}",
                self.plan_cache.artifact_hits,
                self.plan_cache.fallback_compiles,
                self.plan_cache.load_failures,
                self.plan_cache.published,
            )
        } else {
            String::new()
        };
        format!(
            "requests={} responses={} batches={} batch_eff={:.2}{plans}\n{}\n{}\n{}",
            self.requests,
            self.responses,
            self.batches,
            self.batch_efficiency(),
            self.queue_latency.summary("queue"),
            self.exec_latency.summary("exec "),
            self.e2e_latency.summary("e2e  "),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_ordered() {
        let mut h = Histogram::new();
        for ms in 1..=100u64 {
            h.record(Duration::from_millis(ms));
        }
        assert_eq!(h.count(), 100);
        let p50 = h.percentile(50.0);
        let p95 = h.percentile(95.0);
        let p99 = h.percentile(99.0);
        assert!(p50 <= p95 && p95 <= p99);
        // log buckets have 10% resolution
        assert!((p50 - 0.050).abs() / 0.050 < 0.15, "p50={p50}");
        assert!((p95 - 0.095).abs() / 0.095 < 0.15, "p95={p95}");
    }

    #[test]
    fn empty_histogram_safe() {
        let h = Histogram::new();
        assert_eq!(h.percentile(99.0), 0.0);
        assert_eq!(h.mean(), 0.0);
    }

    #[test]
    fn batch_efficiency() {
        let mut m = Metrics::new();
        m.batched_samples = 6;
        m.padded_samples = 2;
        assert!((m.batch_efficiency() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn plan_cache_counters_surface_in_the_report() {
        let mut m = Metrics::new();
        assert!(!m.used_plan_store());
        assert!(!m.report().contains("plans:"));
        m.plan_cache.artifact_hits = 3;
        m.plan_cache.fallback_compiles = 1;
        m.plan_cache.published = 1;
        assert!(m.used_plan_store());
        let r = m.report();
        assert!(r.contains("artifact_hits=3"), "{r}");
        assert!(r.contains("fallback_compiles=1"), "{r}");
        assert!(r.contains("load_failures=0"), "{r}");
        assert!(r.contains("published=1"), "{r}");
    }

    #[test]
    fn mean_tracks_sum() {
        let mut h = Histogram::new();
        h.record(Duration::from_millis(10));
        h.record(Duration::from_millis(30));
        assert!((h.mean() - 0.020).abs() < 1e-9);
    }
}
