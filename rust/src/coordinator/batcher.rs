//! Batch schedulers for the serving coordinator.
//!
//! Two schedulers implement batch formation, and the engine loop can run
//! either per route ([`crate::coordinator::SchedulerKind`]):
//!
//! * [`ContinuousBatcher`] — **continuous batching with SLO-aware
//!   admission** (the production scheduler). Arriving requests join the
//!   not-yet-dispatched batch at the head of the queue up to the pool
//!   width; whenever the engine is free the head batch ships immediately
//!   (work-conserving — no fixed coalescing stall), so batch width grows
//!   with load instead of with a timer. Admission is bounded
//!   (`queue_cap`) and deadline-aware: a request whose SLO budget is
//!   already smaller than the scheduler's service-time forecast is shed
//!   at admission with a typed [`Rejected`], and a request whose deadline
//!   passes while queued is shed at dispatch instead of wasting engine
//!   time.
//! * [`DynamicBatcher`] — the PR-6 bucket-and-deadline baseline: a batch
//!   is released when the largest bucket fills or the oldest request has
//!   waited `max_wait`. Kept as the A/B anchor the `wingan loadgen`
//!   harness measures the continuous scheduler against.
//!
//! Both pick the executable shape with the smallest advertised bucket
//! that fits the ready requests (missing slots are zero-padded and
//! tracked), and both are **pure state machines** — time is passed in,
//! so the deterministic-time unit tests below drive them with a mock
//! clock and no real sleeps.

use crate::coordinator::request::{GenRequest, Rejected};
use std::collections::VecDeque;
use std::time::{Duration, Instant};

/// Batching policy knobs shared by both schedulers.
#[derive(Clone, Debug)]
pub struct BatchPolicy {
    /// available batch buckets, ascending (from the artifact manifest)
    pub buckets: Vec<usize>,
    /// max time the oldest request may wait before a partial batch ships.
    /// For the continuous scheduler `Duration::ZERO` means fully
    /// work-conserving (ship whatever is queued the moment the engine is
    /// free) and `Duration::MAX` means "never ship partials" (hold until
    /// the width fills or the stream flushes) — preserved from PR 6.
    pub max_wait: Duration,
}

impl BatchPolicy {
    pub fn new(mut buckets: Vec<usize>, max_wait: Duration) -> BatchPolicy {
        assert!(!buckets.is_empty(), "need at least one batch bucket");
        buckets.sort_unstable();
        BatchPolicy { buckets, max_wait }
    }

    pub fn max_bucket(&self) -> usize {
        *self.buckets.last().unwrap()
    }

    /// Smallest bucket that fits n requests (n > 0), or the max bucket.
    pub fn bucket_for(&self, n: usize) -> usize {
        assert!(n > 0);
        *self.buckets.iter().find(|&&b| b >= n).unwrap_or(self.buckets.last().unwrap())
    }

    /// The hold deadline of one queued request: its enqueue instant plus
    /// `max_wait`. `checked_add` guards the degenerate `max_wait` that
    /// overflows `Instant` (e.g. `Duration::MAX` meaning "never ship
    /// partials"): `None` then reads as "no hold deadline", so a partial
    /// batch waits for a full width or a flush instead of panicking.
    fn hold_deadline(&self, r: &GenRequest) -> Option<Instant> {
        r.enqueued.checked_add(self.max_wait)
    }
}

/// A batch ready for execution.
#[derive(Debug)]
pub struct ReadyBatch {
    pub requests: Vec<GenRequest>,
    /// bucket size the executable expects (>= requests.len())
    pub bucket: usize,
}

impl ReadyBatch {
    pub fn padding(&self) -> usize {
        self.bucket - self.requests.len()
    }
}

/// Per-(model, method) FIFO queue with deadline-based release — the PR-6
/// bucket-and-deadline scheduler, kept as the measured baseline
/// ([`crate::coordinator::SchedulerKind::Bucket`]).
#[derive(Debug)]
pub struct DynamicBatcher {
    policy: BatchPolicy,
    queue: VecDeque<GenRequest>,
}

impl DynamicBatcher {
    pub fn new(policy: BatchPolicy) -> DynamicBatcher {
        DynamicBatcher { policy, queue: VecDeque::new() }
    }

    pub fn push(&mut self, req: GenRequest) {
        self.queue.push_back(req);
    }

    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    /// Next instant at which `poll` would release a partial batch, if any.
    pub fn next_deadline(&self) -> Option<Instant> {
        self.queue.front().and_then(|r| self.policy.hold_deadline(r))
    }

    /// Release a batch if policy says so at time `now`.
    pub fn poll(&mut self, now: Instant) -> Option<ReadyBatch> {
        if self.queue.is_empty() {
            return None;
        }
        let full = self.queue.len() >= self.policy.max_bucket();
        let expired = self
            .queue
            .front()
            .and_then(|r| self.policy.hold_deadline(r))
            .map_or(false, |d| now >= d);
        if full || expired {
            Some(self.take_batch())
        } else {
            None
        }
    }

    /// Force-release whatever is queued (stream end).
    pub fn flush(&mut self) -> Option<ReadyBatch> {
        if self.queue.is_empty() {
            None
        } else {
            Some(self.take_batch())
        }
    }

    fn take_batch(&mut self) -> ReadyBatch {
        let n = self.queue.len().min(self.policy.max_bucket());
        let bucket = self.policy.bucket_for(n);
        let requests: Vec<GenRequest> = self.queue.drain(..n).collect();
        ReadyBatch { requests, bucket }
    }
}

/// What one continuous-batcher poll produced: at most one dispatchable
/// batch, plus the requests whose deadline expired while queued (shed
/// with a typed verdict instead of executed).
#[derive(Debug, Default)]
pub struct Dispatch {
    pub batch: Option<ReadyBatch>,
    pub shed: Vec<(GenRequest, Rejected)>,
}

/// EWMA smoothing factor for the batch service-time estimate. High enough
/// to track warmup → steady-state quickly, low enough that one outlier
/// batch does not swing admission verdicts.
const SERVICE_EWMA_ALPHA: f64 = 0.3;

/// Continuous batcher: the queue head *is* the forming batch. Arrivals
/// join it up to the pool width ([`BatchPolicy::max_bucket`]); the engine
/// takes the head the moment it is free (subject to the `max_wait` hold
/// window, `ZERO` by default = fully work-conserving). Under load,
/// requests arriving while a batch executes accumulate and ship as one
/// wide batch next — batch width grows with pressure, not with a timer.
///
/// Admission is **SLO-aware**: [`ContinuousBatcher::admit`] rejects with
/// a typed [`Rejected`] when the queue is at `queue_cap` (backpressure)
/// or when the request's deadline budget is smaller than the estimated
/// queue wait (an EWMA of observed batch service times, fed by
/// [`ContinuousBatcher::observe`]). Requests whose deadline passes while
/// queued are shed at dispatch ([`Dispatch::shed`]) instead of occupying
/// engine time they can no longer use.
///
/// Like [`DynamicBatcher`], this is a pure state machine — `now` is
/// always passed in, so tests drive it deterministically with a mock
/// clock.
#[derive(Debug)]
pub struct ContinuousBatcher {
    policy: BatchPolicy,
    /// bound on queued (admitted, undispatched) requests
    queue_cap: usize,
    queue: VecDeque<GenRequest>,
    /// EWMA of observed batch service time, seconds (None until the
    /// first observation — admission then only sheds already-expired
    /// deadlines, never forecast-based)
    service_ewma: Option<f64>,
}

impl ContinuousBatcher {
    pub fn new(policy: BatchPolicy, queue_cap: usize) -> ContinuousBatcher {
        assert!(queue_cap > 0, "need a positive queue bound");
        ContinuousBatcher { policy, queue_cap, queue: VecDeque::new(), service_ewma: None }
    }

    /// The join-in-flight limit: requests join the forming batch up to
    /// this width (the widest executable bucket, i.e. the pool width the
    /// engine fans a wide batch across).
    pub fn width(&self) -> usize {
        self.policy.max_bucket()
    }

    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    /// The current batch service-time forecast in seconds (0 until the
    /// first [`ContinuousBatcher::observe`]).
    pub fn service_estimate(&self) -> f64 {
        self.service_ewma.unwrap_or(0.0)
    }

    /// Feed one observed batch service time into the admission forecast.
    pub fn observe(&mut self, service: Duration) {
        let s = service.as_secs_f64();
        self.service_ewma = Some(match self.service_ewma {
            None => s,
            Some(e) => SERVICE_EWMA_ALPHA * s + (1.0 - SERVICE_EWMA_ALPHA) * e,
        });
    }

    /// Estimated wait until a request admitted *now* would complete:
    /// whole batches ahead of it (its own included) times the service
    /// forecast.
    fn estimated_wait(&self) -> Duration {
        let batches_ahead = self.queue.len() / self.width() + 1;
        Duration::from_secs_f64(self.service_estimate() * batches_ahead as f64)
    }

    /// Admit one request at time `now`, or return it with a typed
    /// rejection: [`Rejected::QueueFull`] when the queue is at capacity,
    /// [`Rejected::DeadlineInfeasible`] when the request carries a
    /// deadline whose remaining budget is below the estimated wait (or
    /// already zero). Best-effort requests (`deadline: None`) are only
    /// ever rejected for capacity.
    pub fn admit(&mut self, req: GenRequest, now: Instant) -> Result<(), (GenRequest, Rejected)> {
        if self.queue.len() >= self.queue_cap {
            let rej = Rejected::QueueFull { depth: self.queue.len(), cap: self.queue_cap };
            return Err((req, rej));
        }
        if let Some(d) = req.deadline {
            let remaining = d.saturating_duration_since(now);
            let estimated_wait = self.estimated_wait();
            if remaining.is_zero() || remaining < estimated_wait {
                return Err((req, Rejected::DeadlineInfeasible { remaining, estimated_wait }));
            }
        }
        self.queue.push_back(req);
        Ok(())
    }

    /// Next instant the engine should wake to act on this queue even if
    /// no new request arrives: the head's hold deadline (when `max_wait`
    /// is finite) or the earliest per-request deadline (to shed expired
    /// work promptly). `None` = nothing to do until traffic or flush.
    pub fn next_deadline(&self) -> Option<Instant> {
        let hold = self.queue.front().and_then(|r| self.policy.hold_deadline(r));
        let slo = self.queue.iter().filter_map(|r| r.deadline).min();
        match (hold, slo) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (a, b) => a.or(b),
        }
    }

    /// Poll at time `now`: shed queued requests whose deadline has
    /// passed, then dispatch the head batch if the width is full or the
    /// oldest request's hold window (`max_wait`) has elapsed. With
    /// `max_wait == ZERO` a non-empty queue always dispatches — the
    /// work-conserving continuous-batching default.
    pub fn poll(&mut self, now: Instant) -> Dispatch {
        let mut out = Dispatch::default();
        // shed expired work first so it neither ships nor holds the batch
        let estimated_wait = self.estimated_wait();
        let mut live = VecDeque::with_capacity(self.queue.len());
        for r in self.queue.drain(..) {
            match r.deadline {
                Some(d) if d <= now => out.shed.push((
                    r,
                    Rejected::DeadlineInfeasible { remaining: Duration::ZERO, estimated_wait },
                )),
                _ => live.push_back(r),
            }
        }
        self.queue = live;

        if self.queue.is_empty() {
            return out;
        }
        let full = self.queue.len() >= self.width();
        let held = self
            .queue
            .front()
            .and_then(|r| self.policy.hold_deadline(r))
            .map_or(false, |d| now >= d);
        if full || held {
            out.batch = Some(self.take_batch());
        }
        out
    }

    /// Force-release whatever is queued (stream end / shutdown drain):
    /// every admitted request ships, even past its deadline — shutdown is
    /// a drain, not a shed.
    pub fn flush(&mut self) -> Option<ReadyBatch> {
        if self.queue.is_empty() {
            None
        } else {
            Some(self.take_batch())
        }
    }

    fn take_batch(&mut self) -> ReadyBatch {
        let n = self.queue.len().min(self.width());
        let bucket = self.policy.bucket_for(n);
        let requests: Vec<GenRequest> = self.queue.drain(..n).collect();
        ReadyBatch { requests, bucket }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, t: Instant) -> GenRequest {
        GenRequest {
            id,
            model: "dcgan".into(),
            method: "winograd".into(),
            input: vec![0.0; 4],
            enqueued: t,
            deadline: None,
            trace: 0,
        }
    }

    fn req_slo(id: u64, t: Instant, budget: Duration) -> GenRequest {
        GenRequest { deadline: Some(t + budget), ..req(id, t) }
    }

    fn policy() -> BatchPolicy {
        BatchPolicy::new(vec![1, 4, 8], Duration::from_millis(5))
    }

    fn greedy() -> ContinuousBatcher {
        ContinuousBatcher::new(BatchPolicy::new(vec![1, 4, 8], Duration::ZERO), 32)
    }

    #[test]
    fn bucket_selection() {
        let p = policy();
        assert_eq!(p.bucket_for(1), 1);
        assert_eq!(p.bucket_for(2), 4);
        assert_eq!(p.bucket_for(4), 4);
        assert_eq!(p.bucket_for(5), 8);
        assert_eq!(p.bucket_for(8), 8);
        assert_eq!(p.bucket_for(9), 8); // clamps to max
    }

    #[test]
    fn releases_when_full() {
        let mut b = DynamicBatcher::new(policy());
        let t = Instant::now();
        for i in 0..8 {
            b.push(req(i, t));
        }
        let batch = b.poll(t).expect("full batch");
        assert_eq!(batch.requests.len(), 8);
        assert_eq!(batch.bucket, 8);
        assert_eq!(batch.padding(), 0);
        assert_eq!(b.queued(), 0);
    }

    #[test]
    fn holds_partial_until_deadline() {
        let mut b = DynamicBatcher::new(policy());
        let t = Instant::now();
        b.push(req(0, t));
        b.push(req(1, t));
        assert!(b.poll(t).is_none(), "should wait for more work");
        let late = t + Duration::from_millis(6);
        let batch = b.poll(late).expect("deadline batch");
        assert_eq!(batch.requests.len(), 2);
        assert_eq!(batch.bucket, 4);
        assert_eq!(batch.padding(), 2);
    }

    #[test]
    fn preserves_fifo_order_and_loses_nothing() {
        let mut b = DynamicBatcher::new(policy());
        let t = Instant::now();
        for i in 0..13 {
            b.push(req(i, t));
        }
        let mut ids = Vec::new();
        while let Some(batch) = b.poll(t + Duration::from_secs(1)) {
            ids.extend(batch.requests.iter().map(|r| r.id));
        }
        assert_eq!(ids, (0..13).collect::<Vec<_>>());
    }

    #[test]
    fn flush_empties_queue() {
        let mut b = DynamicBatcher::new(policy());
        let t = Instant::now();
        b.push(req(0, t));
        let batch = b.flush().unwrap();
        assert_eq!(batch.requests.len(), 1);
        assert_eq!(batch.bucket, 1);
        assert!(b.flush().is_none());
    }

    #[test]
    fn next_deadline_tracks_oldest() {
        let mut b = DynamicBatcher::new(policy());
        assert!(b.next_deadline().is_none());
        let t = Instant::now();
        b.push(req(0, t));
        assert_eq!(b.next_deadline(), Some(t + Duration::from_millis(5)));
    }

    #[test]
    fn unrepresentable_deadline_means_wait_for_full_or_flush() {
        // regression: `max_wait: Duration::MAX` ("never ship partials")
        // used to overflow-panic in both `next_deadline` and `poll` the
        // moment anything queued. Now it reads as "no deadline": partials
        // hold until the bucket fills or the stream flushes.
        let mut b =
            DynamicBatcher::new(BatchPolicy::new(vec![1, 4, 8], Duration::MAX));
        let t = Instant::now();
        b.push(req(0, t));
        assert_eq!(b.next_deadline(), None);
        assert!(b.poll(t + Duration::from_secs(3600)).is_none(), "no deadline release");
        for i in 1..8 {
            b.push(req(i, t));
        }
        let batch = b.poll(t).expect("full-bucket release still works");
        assert_eq!(batch.requests.len(), 8);
        b.push(req(8, t));
        assert_eq!(b.flush().expect("flush release still works").requests.len(), 1);
    }

    // ---- continuous batcher (deterministic mock-clock tests) ----

    #[test]
    fn continuous_dispatches_immediately_when_work_conserving() {
        let mut b = greedy();
        let t = Instant::now();
        b.admit(req(0, t), t).unwrap();
        b.admit(req(1, t), t).unwrap();
        // max_wait == ZERO: the moment the engine polls, the partial ships
        let d = b.poll(t);
        assert!(d.shed.is_empty());
        let batch = d.batch.expect("work-conserving dispatch");
        assert_eq!(batch.requests.len(), 2);
        assert_eq!(batch.bucket, 4);
        assert_eq!(b.queued(), 0);
    }

    #[test]
    fn continuous_joins_in_flight_up_to_width() {
        let mut b = greedy();
        let t = Instant::now();
        // a batch is executing; 11 requests arrive meanwhile and join the
        // forming batch — the next dispatch takes exactly the pool width,
        // the overflow stays queued for the batch after
        for i in 0..11 {
            b.admit(req(i, t), t).unwrap();
        }
        let first = b.poll(t).batch.expect("head batch");
        assert_eq!(first.requests.len(), b.width());
        assert_eq!(first.requests.iter().map(|r| r.id).collect::<Vec<_>>(), (0..8).collect::<Vec<_>>());
        let second = b.poll(t).batch.expect("overflow batch");
        assert_eq!(second.requests.iter().map(|r| r.id).collect::<Vec<_>>(), vec![8, 9, 10]);
        assert_eq!(b.queued(), 0);
    }

    #[test]
    fn continuous_hold_window_coalesces_then_ships() {
        // finite max_wait: a lone request holds for the window (letting
        // batch-mates join), then ships at the deadline
        let mut b =
            ContinuousBatcher::new(BatchPolicy::new(vec![1, 4, 8], Duration::from_millis(5)), 32);
        let t = Instant::now();
        b.admit(req(0, t), t).unwrap();
        assert!(b.poll(t).batch.is_none(), "held for batch-mates");
        assert_eq!(b.next_deadline(), Some(t + Duration::from_millis(5)));
        b.admit(req(1, t + Duration::from_millis(2)), t + Duration::from_millis(2)).unwrap();
        let batch = b.poll(t + Duration::from_millis(5)).batch.expect("hold window elapsed");
        assert_eq!(batch.requests.len(), 2);
    }

    #[test]
    fn continuous_preserves_duration_max_hold_from_pr6() {
        // `max_wait: Duration::MAX` ("never ship partials") must not
        // overflow-panic, and must hold partials until the width fills or
        // the stream flushes — the PR-6 DynamicBatcher contract.
        let mut b = ContinuousBatcher::new(BatchPolicy::new(vec![1, 4, 8], Duration::MAX), 32);
        let t = Instant::now();
        b.admit(req(0, t), t).unwrap();
        assert_eq!(b.next_deadline(), None);
        assert!(b.poll(t + Duration::from_secs(3600)).batch.is_none(), "no hold release");
        for i in 1..8 {
            b.admit(req(i, t), t).unwrap();
        }
        assert_eq!(b.poll(t).batch.expect("full width ships").requests.len(), 8);
        b.admit(req(8, t), t).unwrap();
        assert_eq!(b.flush().expect("flush ships the tail").requests.len(), 1);
    }

    #[test]
    fn admission_rejects_at_queue_cap() {
        let mut b = ContinuousBatcher::new(BatchPolicy::new(vec![1, 2], Duration::ZERO), 3);
        let t = Instant::now();
        for i in 0..3 {
            b.admit(req(i, t), t).unwrap();
        }
        let (back, rej) = b.admit(req(3, t), t).unwrap_err();
        assert_eq!(back.id, 3, "the rejected request comes back to the caller");
        assert_eq!(rej, Rejected::QueueFull { depth: 3, cap: 3 });
        assert_eq!(b.queued(), 3, "rejection must not disturb the queue");
    }

    #[test]
    fn admission_rejects_infeasible_deadlines_from_the_forecast() {
        let mut b = greedy();
        let t = Instant::now();
        // teach the forecast: batches take 10ms
        b.observe(Duration::from_millis(10));
        assert!((b.service_estimate() - 0.010).abs() < 1e-12);
        // 50ms of budget against a ~10ms wait: feasible
        b.admit(req_slo(0, t, Duration::from_millis(50)), t).unwrap();
        // 5ms of budget against a ~10ms wait: shed at admission
        let (_, rej) = b.admit(req_slo(1, t, Duration::from_millis(5)), t).unwrap_err();
        match rej {
            Rejected::DeadlineInfeasible { remaining, estimated_wait } => {
                assert_eq!(remaining, Duration::from_millis(5));
                assert_eq!(estimated_wait, Duration::from_millis(10));
            }
            other => panic!("expected DeadlineInfeasible, got {other:?}"),
        }
        // an already-expired deadline is always infeasible, forecast or not
        let late = t + Duration::from_secs(1);
        let (_, rej) = b.admit(req_slo(2, t, Duration::from_millis(100)), late).unwrap_err();
        match rej {
            Rejected::DeadlineInfeasible { remaining, .. } => {
                assert_eq!(remaining, Duration::ZERO)
            }
            other => panic!("expected DeadlineInfeasible, got {other:?}"),
        }
        // without a deadline the forecast never sheds
        b.admit(req(3, t), late).unwrap();
    }

    #[test]
    fn expired_requests_shed_at_dispatch_not_served() {
        let mut b = greedy();
        let t = Instant::now();
        b.admit(req_slo(0, t, Duration::from_millis(2)), t).unwrap();
        b.admit(req(1, t), t).unwrap();
        b.admit(req_slo(2, t, Duration::from_millis(100)), t).unwrap();
        // 5ms later request 0's deadline has passed: it must shed, the
        // live requests ship
        let d = b.poll(t + Duration::from_millis(5));
        assert_eq!(d.shed.len(), 1);
        assert_eq!(d.shed[0].0.id, 0);
        assert!(matches!(d.shed[0].1, Rejected::DeadlineInfeasible { .. }));
        let batch = d.batch.expect("live requests dispatch");
        assert_eq!(batch.requests.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 2]);
    }

    #[test]
    fn next_deadline_wakes_for_slo_sheds() {
        // even with max_wait == MAX (no hold deadline), a queued deadline
        // must produce a wake-up so expired work is shed promptly
        let mut b = ContinuousBatcher::new(BatchPolicy::new(vec![1, 4, 8], Duration::MAX), 32);
        let t = Instant::now();
        b.admit(req_slo(0, t, Duration::from_millis(7)), t).unwrap();
        assert_eq!(b.next_deadline(), Some(t + Duration::from_millis(7)));
    }

    #[test]
    fn service_forecast_is_an_ewma() {
        let mut b = greedy();
        b.observe(Duration::from_millis(10));
        b.observe(Duration::from_millis(20));
        // 0.3 * 20ms + 0.7 * 10ms = 13ms
        assert!((b.service_estimate() - 0.013).abs() < 1e-12);
    }

    #[test]
    fn continuous_fifo_conservation() {
        let mut b = greedy();
        let t = Instant::now();
        for i in 0..13 {
            b.admit(req(i, t), t).unwrap();
        }
        let mut ids = Vec::new();
        loop {
            let d = b.poll(t);
            assert!(d.shed.is_empty());
            match d.batch {
                Some(batch) => ids.extend(batch.requests.iter().map(|r| r.id)),
                None => break,
            }
        }
        assert_eq!(ids, (0..13).collect::<Vec<_>>());
    }
}
