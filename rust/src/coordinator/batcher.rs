//! Dynamic batcher: packs queued generation requests into the AOT batch
//! buckets (vLLM-style bucketed continuous batching, adapted to fixed-shape
//! PJRT executables).
//!
//! Policy: a batch is released when (a) the largest bucket fills, or
//! (b) the oldest queued request has waited `max_wait`, or (c) `flush` is
//! forced at stream end. The released batch uses the smallest bucket that
//! fits the ready requests; missing slots are padded with zero samples
//! (tracked, so batch-efficiency is observable).
//!
//! The bucket width this batcher picks is what drives the execution-side
//! scheduling decision downstream: on the native backend a wide bucket
//! runs sample-parallel on the shared worker pool, a narrow one runs
//! stripe-parallel inside each sample (see
//! [`crate::engine::BatchSchedule`]).
//!
//! Pure state machine — time is passed in, so tests drive it deterministically.

use crate::coordinator::request::GenRequest;
use std::collections::VecDeque;
use std::time::{Duration, Instant};

/// Batching policy knobs.
#[derive(Clone, Debug)]
pub struct BatchPolicy {
    /// available batch buckets, ascending (from the artifact manifest)
    pub buckets: Vec<usize>,
    /// max time the oldest request may wait before a partial batch ships
    pub max_wait: Duration,
}

impl BatchPolicy {
    pub fn new(mut buckets: Vec<usize>, max_wait: Duration) -> BatchPolicy {
        assert!(!buckets.is_empty(), "need at least one batch bucket");
        buckets.sort_unstable();
        BatchPolicy { buckets, max_wait }
    }

    pub fn max_bucket(&self) -> usize {
        *self.buckets.last().unwrap()
    }

    /// Smallest bucket that fits n requests (n > 0), or the max bucket.
    pub fn bucket_for(&self, n: usize) -> usize {
        assert!(n > 0);
        *self.buckets.iter().find(|&&b| b >= n).unwrap_or(self.buckets.last().unwrap())
    }
}

/// A batch ready for execution.
#[derive(Debug)]
pub struct ReadyBatch {
    pub requests: Vec<GenRequest>,
    /// bucket size the executable expects (>= requests.len())
    pub bucket: usize,
}

impl ReadyBatch {
    pub fn padding(&self) -> usize {
        self.bucket - self.requests.len()
    }
}

/// Per-(model, method) FIFO queue with deadline-based release.
#[derive(Debug)]
pub struct DynamicBatcher {
    policy: BatchPolicy,
    queue: VecDeque<GenRequest>,
}

impl DynamicBatcher {
    pub fn new(policy: BatchPolicy) -> DynamicBatcher {
        DynamicBatcher { policy, queue: VecDeque::new() }
    }

    pub fn push(&mut self, req: GenRequest) {
        self.queue.push_back(req);
    }

    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    /// The release deadline of one request: its enqueue instant plus the
    /// policy's `max_wait`. Both `next_deadline` and `poll` route through
    /// this helper so the two can never disagree on the expression — they
    /// used to duplicate it inline. `checked_add` guards the degenerate
    /// `max_wait` that overflows `Instant` (e.g. `Duration::MAX` meaning
    /// "never ship partials"): `None` then reads as "no deadline", so the
    /// batch waits for a full bucket or a flush instead of panicking.
    fn deadline(&self, r: &GenRequest) -> Option<Instant> {
        r.enqueued.checked_add(self.policy.max_wait)
    }

    /// Next instant at which `poll` would release a partial batch, if any.
    pub fn next_deadline(&self) -> Option<Instant> {
        self.queue.front().and_then(|r| self.deadline(r))
    }

    /// Release a batch if policy says so at time `now`.
    pub fn poll(&mut self, now: Instant) -> Option<ReadyBatch> {
        if self.queue.is_empty() {
            return None;
        }
        let full = self.queue.len() >= self.policy.max_bucket();
        let expired =
            self.queue.front().and_then(|r| self.deadline(r)).map_or(false, |d| now >= d);
        if full || expired {
            Some(self.take_batch())
        } else {
            None
        }
    }

    /// Force-release whatever is queued (stream end).
    pub fn flush(&mut self) -> Option<ReadyBatch> {
        if self.queue.is_empty() {
            None
        } else {
            Some(self.take_batch())
        }
    }

    fn take_batch(&mut self) -> ReadyBatch {
        let n = self.queue.len().min(self.policy.max_bucket());
        let bucket = self.policy.bucket_for(n);
        let requests: Vec<GenRequest> = self.queue.drain(..n).collect();
        ReadyBatch { requests, bucket }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, t: Instant) -> GenRequest {
        GenRequest {
            id,
            model: "dcgan".into(),
            method: "winograd".into(),
            input: vec![0.0; 4],
            enqueued: t,
        }
    }

    fn policy() -> BatchPolicy {
        BatchPolicy::new(vec![1, 4, 8], Duration::from_millis(5))
    }

    #[test]
    fn bucket_selection() {
        let p = policy();
        assert_eq!(p.bucket_for(1), 1);
        assert_eq!(p.bucket_for(2), 4);
        assert_eq!(p.bucket_for(4), 4);
        assert_eq!(p.bucket_for(5), 8);
        assert_eq!(p.bucket_for(8), 8);
        assert_eq!(p.bucket_for(9), 8); // clamps to max
    }

    #[test]
    fn releases_when_full() {
        let mut b = DynamicBatcher::new(policy());
        let t = Instant::now();
        for i in 0..8 {
            b.push(req(i, t));
        }
        let batch = b.poll(t).expect("full batch");
        assert_eq!(batch.requests.len(), 8);
        assert_eq!(batch.bucket, 8);
        assert_eq!(batch.padding(), 0);
        assert_eq!(b.queued(), 0);
    }

    #[test]
    fn holds_partial_until_deadline() {
        let mut b = DynamicBatcher::new(policy());
        let t = Instant::now();
        b.push(req(0, t));
        b.push(req(1, t));
        assert!(b.poll(t).is_none(), "should wait for more work");
        let late = t + Duration::from_millis(6);
        let batch = b.poll(late).expect("deadline batch");
        assert_eq!(batch.requests.len(), 2);
        assert_eq!(batch.bucket, 4);
        assert_eq!(batch.padding(), 2);
    }

    #[test]
    fn preserves_fifo_order_and_loses_nothing() {
        let mut b = DynamicBatcher::new(policy());
        let t = Instant::now();
        for i in 0..13 {
            b.push(req(i, t));
        }
        let mut ids = Vec::new();
        while let Some(batch) = b.poll(t + Duration::from_secs(1)) {
            ids.extend(batch.requests.iter().map(|r| r.id));
        }
        assert_eq!(ids, (0..13).collect::<Vec<_>>());
    }

    #[test]
    fn flush_empties_queue() {
        let mut b = DynamicBatcher::new(policy());
        let t = Instant::now();
        b.push(req(0, t));
        let batch = b.flush().unwrap();
        assert_eq!(batch.requests.len(), 1);
        assert_eq!(batch.bucket, 1);
        assert!(b.flush().is_none());
    }

    #[test]
    fn next_deadline_tracks_oldest() {
        let mut b = DynamicBatcher::new(policy());
        assert!(b.next_deadline().is_none());
        let t = Instant::now();
        b.push(req(0, t));
        assert_eq!(b.next_deadline(), Some(t + Duration::from_millis(5)));
    }

    #[test]
    fn unrepresentable_deadline_means_wait_for_full_or_flush() {
        // regression: `max_wait: Duration::MAX` ("never ship partials")
        // used to overflow-panic in both `next_deadline` and `poll` the
        // moment anything queued. Now it reads as "no deadline": partials
        // hold until the bucket fills or the stream flushes.
        let mut b =
            DynamicBatcher::new(BatchPolicy::new(vec![1, 4, 8], Duration::MAX));
        let t = Instant::now();
        b.push(req(0, t));
        assert_eq!(b.next_deadline(), None);
        assert!(b.poll(t + Duration::from_secs(3600)).is_none(), "no deadline release");
        for i in 1..8 {
            b.push(req(i, t));
        }
        let batch = b.poll(t).expect("full-bucket release still works");
        assert_eq!(batch.requests.len(), 8);
        b.push(req(8, t));
        assert_eq!(b.flush().expect("flush release still works").requests.len(), 1);
    }
}
