//! Supervision policy for route engine threads: restart backoff, circuit
//! breaker, stuck-batch watchdog, and the health-report types.
//!
//! Following the batcher's design rule, the policy here is a **pure state
//! machine over injected time**: [`RoutePolicy`] never reads the clock or
//! touches a thread — the supervisor thread in
//! [`crate::coordinator::server`] feeds it observations
//! ([`RoutePolicy::note_contained_panic`], [`RoutePolicy::note_death`],
//! [`RoutePolicy::note_stuck`]) and polls it for due actions
//! ([`RoutePolicy::poll`]), all stamped with an explicit `now: Instant`.
//! That keeps the breaker schedule unit-testable on a mock clock, exactly
//! like the continuous batcher's admission logic.
//!
//! Lifecycle of a route under faults:
//!
//! 1. A contained panic is just a counter — until `storm_panics` of them
//!    land inside `storm_window`, which declares a **panic storm**: the
//!    engine incarnation is asked to drain and exit, counting as a death.
//! 2. Each death (storm, unwind that escaped the batch boundary, or a
//!    watchdog-declared stuck batch) schedules a restart after a **capped
//!    exponential backoff** (`backoff_base · 2^(recent deaths − 1)`, capped
//!    at `backoff_max`).
//! 3. `max_restarts` deaths inside `restart_window` **trip the breaker**:
//!    the route goes [`RouteHealth::Unhealthy`] and sheds with a typed
//!    [`crate::coordinator::Rejected::Unhealthy`] instead of queueing onto
//!    a dead engine.
//! 4. After `breaker_cooldown` the breaker **half-opens**: one probe
//!    incarnation starts, and the route is [`RouteHealth::Degraded`] for a
//!    `probation` period. Surviving probation closes the breaker and
//!    clears the death window; dying during probation re-opens it.

use std::collections::{BTreeMap, VecDeque};
use std::fmt;
use std::time::{Duration, Instant};

/// Tunables for the per-route supervision policy. The defaults suit the
/// serving binary; the chaos tests shrink every window to milliseconds.
#[derive(Clone, Debug)]
pub struct SupervisorConfig {
    /// A batch executing longer than this is declared stuck: the zombie
    /// incarnation is superseded (its results discarded) and the death is
    /// charged to the route.
    pub watchdog: Duration,
    /// Backoff before the first restart; doubles per recent death.
    pub backoff_base: Duration,
    /// Cap on the exponential backoff.
    pub backoff_max: Duration,
    /// Deaths inside `restart_window` that trip the circuit breaker.
    pub max_restarts: u32,
    /// Sliding window the breaker counts deaths over.
    pub restart_window: Duration,
    /// How long a tripped breaker stays open before half-opening a probe
    /// incarnation.
    pub breaker_cooldown: Duration,
    /// How long the probe incarnation must survive to close the breaker.
    pub probation: Duration,
    /// Contained panics inside `storm_window` that count as a death (the
    /// incarnation drains and exits rather than grinding through a
    /// poisoned stream one contained panic at a time).
    pub storm_panics: u32,
    /// Sliding window the storm detector counts contained panics over.
    pub storm_window: Duration,
}

impl Default for SupervisorConfig {
    fn default() -> Self {
        SupervisorConfig {
            watchdog: Duration::from_secs(10),
            backoff_base: Duration::from_millis(10),
            backoff_max: Duration::from_secs(2),
            max_restarts: 5,
            restart_window: Duration::from_secs(30),
            breaker_cooldown: Duration::from_secs(5),
            probation: Duration::from_secs(5),
            storm_panics: 8,
            storm_window: Duration::from_secs(1),
        }
    }
}

/// Probe-surface health of one route (the tri-state the scale-out
/// ROADMAP item's readiness probes need).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RouteHealth {
    /// Breaker closed; engine serving normally.
    Healthy,
    /// Engine restarting (backoff) or on probation after a half-open.
    Degraded,
    /// Breaker open: requests shed with [`crate::coordinator::Rejected::Unhealthy`].
    Unhealthy,
}

impl fmt::Display for RouteHealth {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            RouteHealth::Healthy => "healthy",
            RouteHealth::Degraded => "degraded",
            RouteHealth::Unhealthy => "unhealthy",
        })
    }
}

/// Breaker position (internal; surfaced as a label in the snapshot).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Breaker {
    Closed,
    /// Restart scheduled at the instant.
    Backoff { until: Instant },
    /// Tripped; half-opens at the instant.
    Open { until: Instant },
    /// Probe incarnation running; closes at the instant if it survives.
    Probation { until: Instant },
}

/// What the policy tells the supervisor after a death is recorded.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DeathVerdict {
    /// Spawn a replacement when [`RoutePolicy::poll`] says so (at the
    /// given instant).
    RestartAt(Instant),
    /// Too many deaths in the window — the breaker is now open; shed
    /// instead of restarting until it half-opens.
    BreakerOpen,
}

/// A due action from [`RoutePolicy::poll`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SupervisorAction {
    /// Spawn a new engine incarnation now (backoff elapsed, or the open
    /// breaker half-opened a probe).
    Restart,
    /// The probe survived probation: the breaker closed and the death
    /// window was cleared. Nothing to spawn.
    BreakerClosed,
}

/// Pure supervision state machine for one route. All methods take an
/// explicit `now`; nothing here reads the clock.
#[derive(Debug)]
pub struct RoutePolicy {
    cfg: SupervisorConfig,
    breaker: Breaker,
    /// death instants inside `restart_window` (pruned on observation)
    deaths: VecDeque<Instant>,
    /// contained-panic instants inside `storm_window`
    storm: VecDeque<Instant>,
    restarts: u64,
    watchdog_fires: u64,
    total_deaths: u64,
}

impl RoutePolicy {
    pub fn new(cfg: SupervisorConfig) -> RoutePolicy {
        RoutePolicy {
            cfg,
            breaker: Breaker::Closed,
            deaths: VecDeque::new(),
            storm: VecDeque::new(),
            restarts: 0,
            watchdog_fires: 0,
            total_deaths: 0,
        }
    }

    pub fn config(&self) -> &SupervisorConfig {
        &self.cfg
    }

    /// Record a contained panic at `now`. Returns `true` when this panic
    /// completes a storm (`storm_panics` inside `storm_window`) — the
    /// caller should have the incarnation drain and exit, then report the
    /// death via [`RoutePolicy::note_death`]. The storm window resets on a
    /// verdict so the replacement incarnation starts clean.
    pub fn note_contained_panic(&mut self, now: Instant) -> bool {
        let cutoff = now.checked_sub(self.cfg.storm_window);
        while let Some(&t) = self.storm.front() {
            match cutoff {
                Some(c) if t < c => {
                    self.storm.pop_front();
                }
                _ => break,
            }
        }
        self.storm.push_back(now);
        if self.storm.len() as u32 >= self.cfg.storm_panics {
            self.storm.clear();
            true
        } else {
            false
        }
    }

    /// Record an engine death (panic storm, escaped unwind, or watchdog
    /// supersession) at `now` and decide what happens next.
    pub fn note_death(&mut self, now: Instant) -> DeathVerdict {
        self.total_deaths += 1;
        let cutoff = now.checked_sub(self.cfg.restart_window);
        while let Some(&t) = self.deaths.front() {
            match cutoff {
                Some(c) if t < c => {
                    self.deaths.pop_front();
                }
                _ => break,
            }
        }
        self.deaths.push_back(now);
        let died_on_probation = matches!(self.breaker, Breaker::Probation { .. });
        if died_on_probation || self.deaths.len() as u32 >= self.cfg.max_restarts {
            self.breaker = Breaker::Open { until: now + self.cfg.breaker_cooldown };
            return DeathVerdict::BreakerOpen;
        }
        // capped exponential: base · 2^(recent deaths − 1)
        let exp = (self.deaths.len() as u32).saturating_sub(1).min(20);
        let backoff = self
            .cfg
            .backoff_base
            .saturating_mul(1u32 << exp)
            .min(self.cfg.backoff_max);
        let until = now + backoff;
        self.breaker = Breaker::Backoff { until };
        DeathVerdict::RestartAt(until)
    }

    /// Record a watchdog firing (stuck batch) at `now`. The zombie
    /// incarnation is superseded by the caller (generation bump); the
    /// policy charges it as a death.
    pub fn note_stuck(&mut self, now: Instant) -> DeathVerdict {
        self.watchdog_fires += 1;
        self.note_death(now)
    }

    /// Pop the action that is due at `now`, if any.
    pub fn poll(&mut self, now: Instant) -> Option<SupervisorAction> {
        match self.breaker {
            Breaker::Closed => None,
            Breaker::Backoff { until } if now >= until => {
                self.breaker = Breaker::Closed;
                self.restarts += 1;
                Some(SupervisorAction::Restart)
            }
            Breaker::Open { until } if now >= until => {
                // half-open: one probe incarnation, on probation
                self.breaker = Breaker::Probation { until: now + self.cfg.probation };
                self.restarts += 1;
                Some(SupervisorAction::Restart)
            }
            Breaker::Probation { until } if now >= until => {
                self.breaker = Breaker::Closed;
                self.deaths.clear();
                Some(SupervisorAction::BreakerClosed)
            }
            _ => None,
        }
    }

    /// True when the breaker is open (requests should shed with
    /// [`crate::coordinator::Rejected::Unhealthy`]).
    pub fn is_open(&self) -> bool {
        matches!(self.breaker, Breaker::Open { .. })
    }

    /// Probe-surface health of this route.
    pub fn health(&self) -> RouteHealth {
        match self.breaker {
            Breaker::Closed => RouteHealth::Healthy,
            Breaker::Backoff { .. } | Breaker::Probation { .. } => RouteHealth::Degraded,
            Breaker::Open { .. } => RouteHealth::Unhealthy,
        }
    }

    /// Lifetime restarts actually performed (spawned replacements).
    pub fn restarts(&self) -> u64 {
        self.restarts
    }

    /// Lifetime watchdog (stuck-batch) firings.
    pub fn watchdog_fires(&self) -> u64 {
        self.watchdog_fires
    }

    /// Point-in-time snapshot for the health report.
    pub fn snapshot(&self, now: Instant) -> RouteHealthSnapshot {
        let cutoff = now.checked_sub(self.cfg.restart_window);
        let recent = self
            .deaths
            .iter()
            .filter(|&&t| match cutoff {
                Some(c) => t >= c,
                None => true,
            })
            .count() as u32;
        RouteHealthSnapshot {
            health: self.health(),
            breaker: match self.breaker {
                Breaker::Closed => "closed",
                Breaker::Backoff { .. } => "backoff",
                Breaker::Open { .. } => "open",
                Breaker::Probation { .. } => "probation",
            },
            restarts: self.restarts,
            recent_deaths: recent,
            total_deaths: self.total_deaths,
            watchdog_fires: self.watchdog_fires,
        }
    }
}

/// One route's entry in the health report.
#[derive(Clone, Debug)]
pub struct RouteHealthSnapshot {
    pub health: RouteHealth,
    /// breaker position label: `closed` / `backoff` / `open` / `probation`
    pub breaker: &'static str,
    /// lifetime engine restarts
    pub restarts: u64,
    /// deaths inside the current restart window
    pub recent_deaths: u32,
    /// lifetime engine deaths
    pub total_deaths: u64,
    /// lifetime stuck-batch watchdog firings
    pub watchdog_fires: u64,
}

/// The probe surface: per-route health snapshots, from
/// [`crate::coordinator::Coordinator::health`].
#[derive(Clone, Debug, Default)]
pub struct HealthReport {
    /// keyed `"model/method"`, like the metrics routes
    pub routes: BTreeMap<String, RouteHealthSnapshot>,
}

impl HealthReport {
    /// True when every route is [`RouteHealth::Healthy`] — the readiness
    /// verdict a fleet router would gate traffic on.
    pub fn all_healthy(&self) -> bool {
        self.routes.values().all(|r| r.health == RouteHealth::Healthy)
    }

    /// One route's snapshot.
    pub fn route(&self, name: &str) -> Option<&RouteHealthSnapshot> {
        self.routes.get(name)
    }

    /// Machine-readable report: the probe surface the fleet router and CI
    /// smoke consume. Stable-key contract: add keys freely, never rename
    /// or remove.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::{self, Json};
        let routes: std::collections::BTreeMap<String, Json> = self
            .routes
            .iter()
            .map(|(name, r)| {
                (
                    name.clone(),
                    json::obj(vec![
                        ("health", json::s(&r.health.to_string())),
                        ("breaker", json::s(r.breaker)),
                        ("restarts", json::num(r.restarts as f64)),
                        ("recent_deaths", json::num(r.recent_deaths as f64)),
                        ("total_deaths", json::num(r.total_deaths as f64)),
                        ("watchdog_fires", json::num(r.watchdog_fires as f64)),
                    ]),
                )
            })
            .collect();
        json::obj(vec![
            ("all_healthy", Json::Bool(self.all_healthy())),
            ("routes", Json::Obj(routes)),
        ])
    }

    /// Multi-line human report (one line per route).
    pub fn report(&self) -> String {
        if self.routes.is_empty() {
            return "health: no supervised routes".to_string();
        }
        self.routes
            .iter()
            .map(|(name, r)| {
                format!(
                    "health {name}: {} breaker={} restarts={} recent_deaths={} \
                     total_deaths={} watchdog_fires={}",
                    r.health, r.breaker, r.restarts, r.recent_deaths, r.total_deaths,
                    r.watchdog_fires,
                )
            })
            .collect::<Vec<_>>()
            .join("\n")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> SupervisorConfig {
        SupervisorConfig {
            watchdog: Duration::from_millis(100),
            backoff_base: Duration::from_millis(10),
            backoff_max: Duration::from_millis(80),
            max_restarts: 4,
            restart_window: Duration::from_secs(10),
            breaker_cooldown: Duration::from_millis(500),
            probation: Duration::from_millis(300),
            storm_panics: 3,
            storm_window: Duration::from_millis(200),
        }
    }

    #[test]
    fn backoff_schedule_doubles_and_caps() {
        let mut p = RoutePolicy::new(cfg());
        let t0 = Instant::now();
        // deaths at the same mock instant: backoff 10, 20, then breaker at
        // the 4th... use max_restarts 10 here to see the cap
        let mut c = cfg();
        c.max_restarts = 10;
        let mut p2 = RoutePolicy::new(c);
        let expect = [10u64, 20, 40, 80, 80, 80];
        let mut now = t0;
        for (i, ms) in expect.iter().enumerate() {
            match p2.note_death(now) {
                DeathVerdict::RestartAt(at) => {
                    assert_eq!(at - now, Duration::from_millis(*ms), "death #{i}");
                    // restart exactly when due, not before
                    assert_eq!(p2.poll(at - Duration::from_millis(1)), None);
                    assert_eq!(p2.poll(at), Some(SupervisorAction::Restart));
                    assert_eq!(p2.health(), RouteHealth::Healthy);
                    now = at;
                }
                v => panic!("death #{i}: unexpected {v:?}"),
            }
        }
        assert_eq!(p2.restarts(), 6);
        // and the default-config policy starts Healthy with no restarts
        assert_eq!(p.health(), RouteHealth::Healthy);
        assert_eq!(p.poll(t0), None);
        assert_eq!(p.restarts(), 0);
    }

    #[test]
    fn breaker_trips_half_opens_and_resets() {
        let mut p = RoutePolicy::new(cfg());
        let t0 = Instant::now();
        let mut now = t0;
        // 3 deaths restart; the 4th (max_restarts) trips the breaker
        for _ in 0..3 {
            match p.note_death(now) {
                DeathVerdict::RestartAt(at) => {
                    assert_eq!(p.poll(at), Some(SupervisorAction::Restart));
                    now = at;
                }
                v => panic!("unexpected {v:?}"),
            }
        }
        assert_eq!(p.note_death(now), DeathVerdict::BreakerOpen);
        assert_eq!(p.health(), RouteHealth::Unhealthy);
        assert!(p.is_open());
        // nothing due while the cooldown runs
        assert_eq!(p.poll(now + Duration::from_millis(499)), None);
        // half-open: a probe restarts and the route is Degraded
        now += Duration::from_millis(500);
        assert_eq!(p.poll(now), Some(SupervisorAction::Restart));
        assert_eq!(p.health(), RouteHealth::Degraded);
        assert!(!p.is_open());
        assert_eq!(p.snapshot(now).breaker, "probation");
        // surviving probation closes the breaker and clears the window
        now += Duration::from_millis(300);
        assert_eq!(p.poll(now), Some(SupervisorAction::BreakerClosed));
        assert_eq!(p.health(), RouteHealth::Healthy);
        assert_eq!(p.snapshot(now).recent_deaths, 0, "probation survival clears the window");
        // a fresh death after reset is an ordinary first-death backoff
        assert_eq!(
            p.note_death(now),
            DeathVerdict::RestartAt(now + Duration::from_millis(10))
        );
    }

    #[test]
    fn death_during_probation_reopens_the_breaker() {
        let mut p = RoutePolicy::new(cfg());
        let mut now = Instant::now();
        for _ in 0..3 {
            if let DeathVerdict::RestartAt(at) = p.note_death(now) {
                p.poll(at);
                now = at;
            }
        }
        assert_eq!(p.note_death(now), DeathVerdict::BreakerOpen);
        now += Duration::from_millis(500);
        assert_eq!(p.poll(now), Some(SupervisorAction::Restart));
        // probe dies mid-probation → straight back to open, no backoff
        now += Duration::from_millis(100);
        assert_eq!(p.note_death(now), DeathVerdict::BreakerOpen);
        assert_eq!(p.health(), RouteHealth::Unhealthy);
    }

    #[test]
    fn deaths_outside_the_window_do_not_trip() {
        let mut p = RoutePolicy::new(cfg());
        let mut now = Instant::now();
        // 3 deaths, then the window slides past them
        for _ in 0..3 {
            if let DeathVerdict::RestartAt(at) = p.note_death(now) {
                p.poll(at);
                now = at;
            }
        }
        now += Duration::from_secs(11); // > restart_window
        // this 4th death is alone in its window: backoff, not breaker —
        // and at the first-death exponent again
        assert_eq!(
            p.note_death(now),
            DeathVerdict::RestartAt(now + Duration::from_millis(10))
        );
    }

    #[test]
    fn storm_detector_counts_inside_the_window_only() {
        let mut p = RoutePolicy::new(cfg());
        let t0 = Instant::now();
        assert!(!p.note_contained_panic(t0));
        assert!(!p.note_contained_panic(t0 + Duration::from_millis(50)));
        // third inside 200ms → storm
        assert!(p.note_contained_panic(t0 + Duration::from_millis(100)));
        // verdict resets the window: the next panic starts a fresh count
        assert!(!p.note_contained_panic(t0 + Duration::from_millis(110)));
        // spaced-out panics never storm
        let mut q = RoutePolicy::new(cfg());
        for i in 0..10u64 {
            assert!(!q.note_contained_panic(t0 + Duration::from_millis(300 * i)));
        }
    }

    #[test]
    fn watchdog_counts_as_a_death_and_is_tracked() {
        let mut p = RoutePolicy::new(cfg());
        let now = Instant::now();
        match p.note_stuck(now) {
            DeathVerdict::RestartAt(_) => {}
            v => panic!("unexpected {v:?}"),
        }
        assert_eq!(p.watchdog_fires(), 1);
        assert_eq!(p.snapshot(now).total_deaths, 1);
        assert_eq!(p.snapshot(now).recent_deaths, 1);
    }

    #[test]
    fn health_report_surface() {
        let mut p = RoutePolicy::new(cfg());
        let now = Instant::now();
        let mut report = HealthReport::default();
        report.routes.insert("dcgan/winograd".into(), p.snapshot(now));
        assert!(report.all_healthy());
        assert!(report.report().contains("health dcgan/winograd: healthy breaker=closed"));
        for _ in 0..4 {
            p.note_death(now);
        }
        report.routes.insert("dcgan/winograd".into(), p.snapshot(now));
        assert!(!report.all_healthy());
        let r = report.route("dcgan/winograd").unwrap();
        assert_eq!(r.health, RouteHealth::Unhealthy);
        assert_eq!(r.breaker, "open");
        assert_eq!(r.recent_deaths, 4);
        assert!(report.report().contains("unhealthy breaker=open"), "{}", report.report());
        assert_eq!(HealthReport::default().report(), "health: no supervised routes");
    }
}
