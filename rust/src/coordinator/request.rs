//! Request/response types for the generation-serving coordinator.

use std::time::{Duration, Instant};

/// Unique request id.
pub type RequestId = u64;

/// One generation request: produce an image from a latent (or input image)
/// with a given model.
#[derive(Clone, Debug)]
pub struct GenRequest {
    pub id: RequestId,
    pub model: String,
    /// compute path ("winograd" default; "tdc" for A/B comparisons)
    pub method: String,
    /// flat f32 input of the model's per-sample input shape
    pub input: Vec<f32>,
    pub enqueued: Instant,
    /// per-request completion deadline (SLO). `None` = best-effort: the
    /// request is never deadline-shed. A request whose deadline is judged
    /// unmeetable at admission — or has passed by dispatch time — gets a
    /// typed [`Rejected::DeadlineInfeasible`] response instead of engine
    /// time.
    pub deadline: Option<Instant>,
    /// telemetry trace id ([`crate::telemetry::TraceId`]); `0` =
    /// untraced. Minted at admission when sampling picks the request, or
    /// carried in from the fleet wire when the router minted it.
    pub trace: u64,
}

/// The serving result for one request.
#[derive(Clone, Debug)]
pub struct GenResponse {
    pub id: RequestId,
    /// flat f32 output of the model's per-sample output shape
    pub output: Vec<f32>,
    /// batch bucket the request was executed in
    pub batch_size: usize,
    /// time spent waiting in the batcher queue
    pub queue_time: std::time::Duration,
    /// executable run time (shared by the whole batch)
    pub exec_time: std::time::Duration,
}

/// Why a request was shed instead of served. Shedding is the coordinator's
/// overload contract: a request that cannot be served within its
/// constraints gets a typed rejection *immediately* (at submit or at
/// dispatch) rather than queuing unboundedly — callers can retry
/// elsewhere, degrade, or surface the error, and the queue stays bounded.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Rejected {
    /// The route's admission queue is at capacity (backpressure). `depth`
    /// is the occupancy observed at rejection time, `cap` the configured
    /// bound ([`crate::coordinator::ServeConfig::queue_cap`]).
    QueueFull { depth: usize, cap: usize },
    /// The request's deadline cannot be met: either the estimated queue
    /// wait already exceeds the remaining budget at admission, or the
    /// deadline passed while the request was queued. `remaining` is the
    /// budget left when the verdict was reached (zero once expired),
    /// `estimated_wait` the scheduler's service-time forecast at that
    /// moment.
    DeadlineInfeasible { remaining: Duration, estimated_wait: Duration },
    /// The route's circuit breaker is open: its engine died too many times
    /// inside the restart window and the supervisor stopped restarting it
    /// for a cooldown. Requests shed immediately (instead of hanging on a
    /// dead engine) until the breaker half-opens and a probe incarnation
    /// proves the route healthy again. `restarts` is the route's lifetime
    /// restart count at shed time.
    Unhealthy { restarts: u64 },
    /// No fleet replica can take the request right now: every replica the
    /// router knows is unready, draining, rolling, or behind an open
    /// circuit breaker. The fleet sheds immediately instead of hanging —
    /// same contract as the in-process sheds, one level up. `replicas` is
    /// the fleet size the verdict was reached over.
    FleetUnavailable { replicas: usize },
}

impl std::fmt::Display for Rejected {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Rejected::QueueFull { depth, cap } => {
                write!(f, "queue full ({depth}/{cap})")
            }
            Rejected::DeadlineInfeasible { remaining, estimated_wait } => write!(
                f,
                "deadline infeasible ({remaining:?} budget remaining, \
                 estimated wait {estimated_wait:?})"
            ),
            Rejected::Unhealthy { restarts } => {
                write!(f, "route unhealthy (circuit breaker open after {restarts} restarts)")
            }
            Rejected::FleetUnavailable { replicas } => {
                write!(f, "fleet unavailable (no healthy replica among {replicas})")
            }
        }
    }
}

/// Failure modes a request can observe.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ServeError {
    UnknownModel(String),
    BadInputLength { expected: usize, got: usize },
    EngineShutdown,
    Execution(String),
    /// The engine **panicked** while executing this request's batch and
    /// the unwind was contained at the batch boundary. After bisection the
    /// blame is narrowed to this request (or the batch was a single
    /// request); batch-mates were retried and completed normally. The
    /// string is the panic payload.
    Crashed(String),
    /// Typed shed-on-overload response (see [`Rejected`]); the request was
    /// never executed.
    Rejected(Rejected),
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::UnknownModel(m) => write!(f, "unknown model '{m}'"),
            ServeError::BadInputLength { expected, got } => {
                write!(f, "bad input length: expected {expected}, got {got}")
            }
            ServeError::EngineShutdown => write!(f, "engine shut down"),
            ServeError::Execution(e) => write!(f, "execution failed: {e}"),
            ServeError::Crashed(p) => write!(f, "engine crashed executing this request: {p}"),
            ServeError::Rejected(r) => write!(f, "request shed: {r}"),
        }
    }
}

impl std::error::Error for ServeError {}

impl ServeError {
    /// True for the typed shed responses ([`ServeError::Rejected`]) — the
    /// load-shedding outcomes a client should count separately from hard
    /// failures when computing goodput.
    pub fn is_shed(&self) -> bool {
        matches!(self, ServeError::Rejected(_))
    }
}
