//! Request/response types for the generation-serving coordinator.

use std::time::Instant;

/// Unique request id.
pub type RequestId = u64;

/// One generation request: produce an image from a latent (or input image)
/// with a given model.
#[derive(Clone, Debug)]
pub struct GenRequest {
    pub id: RequestId,
    pub model: String,
    /// compute path ("winograd" default; "tdc" for A/B comparisons)
    pub method: String,
    /// flat f32 input of the model's per-sample input shape
    pub input: Vec<f32>,
    pub enqueued: Instant,
}

/// The serving result for one request.
#[derive(Clone, Debug)]
pub struct GenResponse {
    pub id: RequestId,
    /// flat f32 output of the model's per-sample output shape
    pub output: Vec<f32>,
    /// batch bucket the request was executed in
    pub batch_size: usize,
    /// time spent waiting in the batcher queue
    pub queue_time: std::time::Duration,
    /// executable run time (shared by the whole batch)
    pub exec_time: std::time::Duration,
}

/// Failure modes a request can observe.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ServeError {
    UnknownModel(String),
    BadInputLength { expected: usize, got: usize },
    EngineShutdown,
    Execution(String),
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::UnknownModel(m) => write!(f, "unknown model '{m}'"),
            ServeError::BadInputLength { expected, got } => {
                write!(f, "bad input length: expected {expected}, got {got}")
            }
            ServeError::EngineShutdown => write!(f, "engine shut down"),
            ServeError::Execution(e) => write!(f, "execution failed: {e}"),
        }
    }
}

impl std::error::Error for ServeError {}
